"""L1 correctness gate: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and activations; exact agreement is required for
the integer kernel and tight allclose for the float kernel.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fused_dense import fused_dense, vmem_bytes, _pick_block_rows
from compile.kernels.masked_sum import masked_sum
from compile.kernels.masked_sum import vmem_bytes as agg_vmem_bytes
from compile.kernels.ref import dense_ref, masked_sum_ref

ACTIVATIONS = ("none", "relu", "tanh")


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([1, 2, 3, 8, 20, 32, 33, 128]),
    k=st.sampled_from([1, 5, 16, 64, 192]),
    n=st.sampled_from([1, 4, 10, 40, 256]),
    act=st.sampled_from(ACTIVATIONS),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_dense_matches_ref(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, m, k), _rand(rng, k, n)
    b = _rand(rng, n)
    out = fused_dense(x, w, b, act)
    ref = dense_ref(x, w, b, act)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([8, 32, 64]),
    k=st.sampled_from([16, 48]),
    n=st.sampled_from([8, 24]),
    act=st.sampled_from(ACTIVATIONS),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_dense_gradients_match_ref(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, m, k), _rand(rng, k, n)
    b = _rand(rng, n)

    def loss_pallas(x, w, b):
        return jnp.sum(fused_dense(x, w, b, act) ** 2)

    def loss_ref(x, w, b):
        return jnp.sum(dense_ref(x, w, b, act) ** 2)

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for a, r, name in zip(gp, gr, "xwb"):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(r), rtol=5e-4, atol=5e-4, err_msg=f"grad {name}"
        )


def test_fused_dense_rejects_unknown_activation():
    x = jnp.zeros((2, 2))
    w = jnp.zeros((2, 2))
    b = jnp.zeros((2,))
    with pytest.raises(ValueError):
        fused_dense(x, w, b, "gelu")


@settings(max_examples=20, deadline=None)
@given(
    clients=st.sampled_from([1, 2, 7, 16, 64]),
    m=st.sampled_from([1, 3, 32, 100, 1024, 4096]),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_sum_matches_ref_exactly(clients, m, seed):
    rng = np.random.default_rng(seed)
    stacked = jnp.asarray(
        rng.integers(0, 2**32, size=(clients, m), dtype=np.uint32)
    )
    out = masked_sum(stacked)
    ref = masked_sum_ref(stacked)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_masked_sum_wraps_mod_2_32():
    # two clients both at 2^32 - 1: sum mod 2^32 = 2^32 - 2
    stacked = jnp.full((2, 4), 2**32 - 1, jnp.uint32)
    out = np.asarray(masked_sum(stacked))
    assert (out == np.uint32(2**32 - 2)).all()


def test_mask_cancellation_through_kernel():
    # additive masks that cancel pairwise leave the plain sum — the
    # secure-aggregation identity, exercised on the L1 kernel
    rng = np.random.default_rng(0)
    n, m = 4, 256
    plain = rng.integers(0, 1000, size=(n, m), dtype=np.uint32)
    masks = rng.integers(0, 2**32, size=(n, n, m), dtype=np.uint32)
    masked = plain.astype(np.int64)
    for i in range(n):
        for j in range(n):
            if i < j:
                masked[i] = (masked[i] + masks[i][j]) % (2**32)
            elif i > j:
                masked[i] = (masked[i] - masks[j][i]) % (2**32)
    out = np.asarray(masked_sum(jnp.asarray(masked.astype(np.uint32))))
    ref = np.asarray(masked_sum_ref(jnp.asarray(plain)))
    np.testing.assert_array_equal(out, ref)


def test_vmem_estimates_within_tpu_budget():
    # structural §Perf check: AOT shapes fit a 16 MiB VMEM comfortably
    assert vmem_bytes(32, 192, 256) < 4 * 2**20
    assert vmem_bytes(20, 1024, 40) < 4 * 2**20
    assert agg_vmem_bytes(64, 65536) < 8 * 2**20


def test_block_rows_divide():
    for m in [1, 2, 7, 30, 32, 100, 128, 999]:
        bm = _pick_block_rows(m)
        assert m % bm == 0 and bm <= 128


# --- quantize kernel -------------------------------------------------------

from compile.kernels.quantize import quantize, _pick_block
from compile.kernels.ref import quantize_ref


@settings(max_examples=15, deadline=None)
@given(
    m=st.sampled_from([1, 7, 64, 1000, 4096]),
    clip=st.sampled_from([1.0, 4.0]),
    scale=st.sampled_from([100.0, 65536.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantize_matches_ref(m, clip, scale, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray((rng.standard_normal(m) * 2).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(quantize(x, clip, scale)), np.asarray(quantize_ref(x, clip, scale))
    )


def test_quantize_two_complement_wrap():
    x = jnp.asarray(np.array([-1.0, 1.0, 0.0], np.float32))
    out = np.asarray(quantize(x, 4.0, 100.0))
    assert out[1] == 100
    assert out[0] == np.uint32(2**32 - 100)  # -100 wraps
    assert out[2] == 0


def test_quantize_clips():
    x = jnp.asarray(np.array([100.0, -100.0], np.float32))
    out = np.asarray(quantize(x, 2.0, 10.0))
    assert out[0] == 20
    assert out[1] == np.uint32(2**32 - 20)
