"""L2 correctness: training steps decrease loss, pallas and reference paths
agree, the inversion step recovers class templates on a toy problem."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def _toy_batch(rng, batch, d, c):
    y = rng.integers(0, c, size=batch)
    x = rng.standard_normal((batch, d)).astype(np.float32) + y[:, None] / c
    onehot = np.eye(c, dtype=np.float32)[y]
    return jnp.asarray(x), jnp.asarray(onehot), jnp.asarray(y.astype(np.int32))


def test_mlp_pallas_matches_ref_path():
    rng = np.random.default_rng(1)
    d, h, c, b = 24, 16, 5, 8
    w1, b1, w2, b2 = model.mlp_init(jax.random.PRNGKey(0), d, h, c)
    x, y1h, _ = _toy_batch(rng, b, d, c)
    lr = jnp.float32(0.1)
    out_p = model.mlp_train_step(w1, b1, w2, b2, x, y1h, lr, use_pallas=True)
    out_r = model.mlp_train_step(w1, b1, w2, b2, x, y1h, lr, use_pallas=False)
    for a, r in zip(out_p, out_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r), rtol=2e-4, atol=2e-4)


def test_mlp_training_reduces_loss():
    rng = np.random.default_rng(2)
    d, h, c, b = 16, 32, 4, 32
    params = model.mlp_init(jax.random.PRNGKey(1), d, h, c)
    x, y1h, labels = _toy_batch(rng, b, d, c)
    lr = jnp.float32(0.5)
    first_loss = None
    loss = None
    for _ in range(30):
        *params, loss = model.mlp_train_step(*params, x, y1h, lr)
        if first_loss is None:
            first_loss = float(loss)
    assert float(loss) < 0.7 * first_loss, (first_loss, float(loss))
    (correct,) = model.mlp_eval_step(*params, x, labels)
    assert int(correct) >= b // 2


def test_softreg_training_and_prediction():
    rng = np.random.default_rng(3)
    d, c, b = 32, 6, 24
    w = jnp.zeros((d, c), jnp.float32)
    bb = jnp.zeros((c,), jnp.float32)
    x, y1h, labels = _toy_batch(rng, b, d, c)
    loss0 = None
    loss = None
    for _ in range(40):
        w, bb, loss = model.softreg_train_step(w, bb, x, y1h, jnp.float32(0.5))
        if loss0 is None:
            loss0 = float(loss)
    assert float(loss) < loss0
    (probs,) = model.softreg_predict(w, bb, x)
    probs = np.asarray(probs)
    assert probs.shape == (b, c)
    np.testing.assert_allclose(probs.sum(axis=1), 1.0, rtol=1e-5)
    acc = (probs.argmax(axis=1) == np.asarray(labels)).mean()
    assert acc > 0.5


def test_inversion_recovers_class_template():
    # identities are distinct templates; softmax regression trained on them
    # must leak the template through gradient inversion (the FedAvg row of
    # Fig 2). This is the attack's unit-level ground truth.
    rng = np.random.default_rng(4)
    d, c = 64, 4
    templates = rng.uniform(0.0, 1.0, size=(c, d)).astype(np.float32)
    x_train = np.repeat(templates, 16, axis=0) + 0.05 * rng.standard_normal(
        (c * 16, d)
    ).astype(np.float32)
    y_train = np.repeat(np.arange(c), 16)
    y1h = np.eye(c, dtype=np.float32)[y_train]

    w = jnp.zeros((d, c), jnp.float32)
    b = jnp.zeros((c,), jnp.float32)
    for _ in range(200):
        w, b, _ = model.softreg_train_step(
            w, b, jnp.asarray(x_train), jnp.asarray(y1h), jnp.float32(1.0)
        )

    target = 2
    x = jnp.full((1, d), 0.5, jnp.float32)
    t1h = jnp.asarray(np.eye(c, dtype=np.float32)[[target]])
    for _ in range(100):
        x, _ = model.softreg_inversion_step(w, b, x, t1h, jnp.float32(5.0))
    rec = np.asarray(x)[0]

    def cos(a, bb):
        return float(np.dot(a, bb) / (np.linalg.norm(a) * np.linalg.norm(bb) + 1e-9))

    target_sim = cos(rec - rec.mean(), templates[target] - templates[target].mean())
    other_sims = [
        cos(rec - rec.mean(), templates[k] - templates[k].mean())
        for k in range(c)
        if k != target
    ]
    assert target_sim > 0.4, target_sim
    assert target_sim > max(other_sims) + 0.15, (target_sim, other_sims)


def test_inversion_stays_in_unit_box():
    d, c = 16, 3
    w = jnp.zeros((d, c), jnp.float32)
    b = jnp.zeros((c,), jnp.float32)
    x = jnp.full((1, d), 0.5, jnp.float32)
    t1h = jnp.asarray(np.eye(c, dtype=np.float32)[[0]])
    x, loss = model.softreg_inversion_step(w, b, x, t1h, jnp.float32(100.0))
    arr = np.asarray(x)
    assert (arr >= 0.0).all() and (arr <= 1.0).all()
    assert np.isfinite(float(loss))


def test_loss_is_cross_entropy():
    logits = jnp.asarray([[10.0, 0.0], [0.0, 10.0]], jnp.float32)
    y = jnp.asarray([[1.0, 0.0], [0.0, 1.0]], jnp.float32)
    assert float(model.softmax_cross_entropy(logits, y)) < 1e-3
    y_wrong = jnp.asarray([[0.0, 1.0], [1.0, 0.0]], jnp.float32)
    assert float(model.softmax_cross_entropy(logits, y_wrong)) > 5.0
