"""AOT pipeline tests: every entry point lowers to HLO text that (a) is
non-trivial, (b) parses back through the XLA HLO parser (the exact
operation the Rust runtime performs via `HloModuleProto::from_text_file`),
and (c) the underlying jitted functions have the semantics the Rust side
assumes (SGD step learns, eval counts, masked_sum wraps).

Execution of the HLO artifacts themselves is validated from Rust
(`rust/tests/runtime_roundtrip.rs`) — that is the production path.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model


@pytest.fixture(scope="module")
def lowered_entries():
    out = {}
    for name, fn, specs, n_out in aot.entries():
        lowered = jax.jit(fn).lower(*specs)
        out[name] = (aot.to_hlo_text(lowered), specs, n_out)
    return out


def test_all_entries_emit_hlo_text(lowered_entries):
    assert set(lowered_entries) == {
        "mlp_train",
        "mlp_eval",
        "softreg_train",
        "softreg_predict",
        "inversion",
        "masked_sum",
        "quantize",
    }
    for name, (text, _, _) in lowered_entries.items():
        assert text.startswith("HloModule"), name
        assert len(text) > 500, name


def test_hlo_round_trips_through_parser(lowered_entries):
    for name, (text, _, _) in lowered_entries.items():
        comp = xc._xla.hlo_module_from_text(text)
        assert comp is not None, name
        # the text must embed the expected parameter count
        assert text.count("parameter(") >= len(lowered_entries[name][1]), name


def test_entry_signatures_match_manifest_shapes(lowered_entries):
    cfg = aot.MLP
    text, specs, n_out = lowered_entries["mlp_train"]
    assert [tuple(s.shape) for s in specs[:4]] == [
        (cfg["d"], cfg["h"]),
        (cfg["h"],),
        (cfg["h"], cfg["c"]),
        (cfg["c"],),
    ]
    assert n_out == 5
    _, specs, n_out = lowered_entries["masked_sum"]
    assert tuple(specs[0].shape) == (aot.AGG["clients"], aot.AGG["m"])
    assert n_out == 1


def test_jitted_train_step_learns_at_aot_shapes():
    # semantic ground truth for the Rust driver: at the exact AOT shapes,
    # repeated application of the train step reduces loss
    rng = np.random.default_rng(0)
    cfg = aot.MLP
    d, h, c, b = cfg["d"], cfg["h"], cfg["c"], cfg["batch"]
    params = model.mlp_init(jax.random.PRNGKey(0), d, h, c)
    y = rng.integers(0, c, size=b)
    x = (rng.standard_normal((b, d)) * 0.3 + y[:, None] / c).astype(np.float32)
    y1h = np.eye(c, dtype=np.float32)[y]
    step = jax.jit(model.mlp_train_step)
    losses = []
    p = list(params)
    for _ in range(10):
        *p, loss = step(*p, jnp.asarray(x), jnp.asarray(y1h), jnp.float32(0.5))
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
    (correct,) = jax.jit(model.mlp_eval_step)(
        *p, jnp.asarray(x), jnp.asarray(y.astype(np.int32))
    )
    assert 0 <= int(correct) <= b


def test_manifest_written_and_consistent(tmp_path):
    out = tmp_path / "artifacts"
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--only", "masked_sum"],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        capture_output=True,
        text=True,
    )
    assert r.returncode == 0, r.stderr
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text/v1"
    art = manifest["artifacts"]["masked_sum"]
    assert (out / art["file"]).exists()
    assert art["inputs"][0]["dtype"] == "uint32"
    assert art["num_outputs"] == 1
    assert (out / art["file"]).read_text().startswith("HloModule")


def test_masked_sum_semantics_at_aot_shape():
    shape = (aot.AGG["clients"], aot.AGG["m"])
    rng = np.random.default_rng(7)
    stacked = rng.integers(0, 2**32, size=shape, dtype=np.uint32)
    from compile.kernels.masked_sum import masked_sum

    got = np.asarray(masked_sum(jnp.asarray(stacked)))
    np.testing.assert_array_equal(got, stacked.sum(axis=0, dtype=np.uint32))
