"""L1 Pallas kernel: fused dense layer  y = act(x @ W + b).

Every layer of the L2 models (the federated MLP and the softmax-regression
face classifier) lowers through this kernel, so the whole training hot path
runs through Pallas.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the grid tiles rows of the
activation matrix so each program instance holds an (bm × K) activation
block, the full (K × N) weight panel and a (bm × N) output block in
VMEM — an MXU-friendly schedule in which the weight panel is reused across
the row grid (the HBM→VMEM transfer pattern a GPU kernel would express with
threadblock tiling). For the dimensions used here (K, N ≤ 1024) the panels
fit VMEM comfortably; larger layers would add a K-loop with an accumulator.

interpret=True is mandatory on this image: CPU PJRT cannot run Mosaic
custom-calls (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str):
    """One grid step: o = act(x_block @ W + b)."""
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "tanh":
        y = jnp.tanh(y)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    o_ref[...] = y


def _pick_block_rows(m: int) -> int:
    """Largest divisor of m that is ≤ 128 (MXU-shaped when possible)."""
    for bm in (128, 64, 32, 16, 8, 4, 2, 1):
        if m % bm == 0:
            return bm
    return 1


def _fused_dense_raw(x, w, b, activation: str):
    """act(x @ w + b) via Pallas. x: (M, K), w: (K, N), b: (N,)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"shape mismatch {x.shape} @ {w.shape}"
    assert b.shape == (n,)
    bm = _pick_block_rows(m)
    kernel = functools.partial(_dense_kernel, activation=activation)
    return pl.pallas_call(
        kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),   # activation rows
            pl.BlockSpec((k, n), lambda i: (0, 0)),    # full weight panel
            pl.BlockSpec((n,), lambda i: (0,)),        # bias
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b)


def _pallas_matmul(a, b):
    """a @ b through the same Pallas kernel (zero bias, no activation) —
    the backward pass stays on the L1 path too."""
    zeros = jnp.zeros((b.shape[1],), jnp.float32)
    return _fused_dense_raw(a, b, zeros, "none")


# interpret-mode pallas_call has no transpose rule, so reverse-mode AD is
# provided explicitly; the backward matmuls reuse the Pallas kernel.
@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _fused_dense_ad(x, w, b, activation):
    return _fused_dense_raw(x, w, b, activation)


def _fused_dense_fwd(x, w, b, activation):
    y = _fused_dense_raw(x, w, b, activation)
    return y, (x, w, y)


def _fused_dense_bwd(activation, res, g):
    x, w, y = res
    if activation == "relu":
        gpre = g * (y > 0.0).astype(g.dtype)
    elif activation == "tanh":
        gpre = g * (1.0 - y * y)
    elif activation == "none":
        gpre = g
    else:  # pragma: no cover — rejected in the forward pass
        raise ValueError(f"unknown activation {activation!r}")
    dx = _pallas_matmul(gpre, w.T)
    dw = _pallas_matmul(x.T, gpre)
    db = jnp.sum(gpre, axis=0)
    return dx, dw, db


_fused_dense_ad.defvjp(_fused_dense_fwd, _fused_dense_bwd)


@functools.partial(jax.jit, static_argnames=("activation",))
def fused_dense(x, w, b, activation: str = "none"):
    """Differentiable fused dense layer act(x @ w + b) on the Pallas path."""
    if activation not in ("none", "relu", "tanh"):
        raise ValueError(f"unknown activation {activation!r}")
    return _fused_dense_ad(x, w, b, activation)


def vmem_bytes(m: int, k: int, n: int) -> int:
    """Estimated per-program VMEM footprint (f32) for the chosen schedule.

    Used by the §Perf structural analysis: must stay well under ~16 MiB
    (TPUv4 VMEM) for the shapes we AOT.
    """
    bm = _pick_block_rows(m)
    return 4 * (bm * k + k * n + n + bm * n)
