"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has a reference here; pytest asserts
allclose/equal agreement across a hypothesis-driven sweep of shapes and
activations (python/tests/test_kernels.py). This is the build-time
correctness gate for L1.
"""

import jax.numpy as jnp


def dense_ref(x, w, b, activation: str = "none"):
    """Reference for kernels.fused_dense.fused_dense."""
    y = x @ w + b[None, :]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "tanh":
        y = jnp.tanh(y)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return y


def masked_sum_ref(stacked):
    """Reference for kernels.masked_sum.masked_sum (sum mod 2^32)."""
    assert stacked.dtype == jnp.uint32
    return jnp.sum(stacked, axis=0, dtype=jnp.uint32)


def quantize_ref(x, clip: float, scale: float):
    """Reference for kernels.quantize.quantize."""
    import jax
    q = jnp.round(jnp.clip(x, -clip, clip) * scale).astype(jnp.int32)
    return jax.lax.bitcast_convert_type(q, jnp.uint32)
