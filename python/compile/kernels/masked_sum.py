"""L1 Pallas kernel: modular column-sum of masked client vectors.

The server-side aggregation hot spot (Eq. 4's Σ_{i∈V3} θ̃_i): given the
stacked masked updates as a (clients × m) uint32 matrix, produce the
column-wise sum mod 2^32 (uint32 wrap-around addition IS the modular sum —
the masking domain Z_{2^32} maps directly onto the hardware word).

TPU adaptation: the grid tiles the model dimension m; each program instance
reduces a (clients × bm) VMEM-resident panel along the client axis. The
client axis is small (≤ a few thousand) and the m axis large (10^4–10^6),
so tiling m keeps VMEM bounded while the reduction stays vectorized on the
VPU (this is a bandwidth-bound kernel — no MXU involvement).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _masked_sum_kernel(x_ref, o_ref):
    x = x_ref[...]
    # uint32 accumulate wraps mod 2^32 — exactly the masked-domain sum
    o_ref[...] = jnp.sum(x, axis=0, dtype=jnp.uint32)


def _pick_block_cols(m: int) -> int:
    for bm in (4096, 2048, 1024, 512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if m % bm == 0:
            return bm
    return 1


@jax.jit
def masked_sum(stacked):
    """Column sum mod 2^32. stacked: (clients, m) uint32 → (m,) uint32."""
    assert stacked.dtype == jnp.uint32, stacked.dtype
    c, m = stacked.shape
    bm = _pick_block_cols(m)
    return pl.pallas_call(
        _masked_sum_kernel,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((c, bm), lambda i: (0, i))],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.uint32),
        interpret=True,
    )(stacked)


def vmem_bytes(clients: int, m: int) -> int:
    """Per-program VMEM footprint estimate (uint32)."""
    bm = _pick_block_cols(m)
    return 4 * (clients * bm + bm)


masked_sum_kernel = functools.partial(_masked_sum_kernel)
