"""L1 Pallas kernel: fixed-point quantization into the masked domain.

The client-side step between local training and masking (Step 2's input):
`q(x) = round(clamp(x, -clip, clip) * scale) mod 2^32`, emitted as uint32
(two's-complement wrap for negatives). On TPU this fuses with the mask
addition into a single VMEM pass; here it is exercised standalone and
compared against the Rust `masking::Quantizer` (which matches up to
rounding mode at exact .5 boundaries).

TPU adaptation: a pure VPU elementwise kernel tiled along m; one (bm,)
block in VMEM per program instance.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quantize_kernel(x_ref, o_ref, *, clip: float, scale: float):
    x = x_ref[...]
    clamped = jnp.clip(x, -clip, clip)
    q = jnp.round(clamped * scale).astype(jnp.int32)
    o_ref[...] = jax.lax.bitcast_convert_type(q, jnp.uint32)


def _pick_block(m: int) -> int:
    for bm in (4096, 2048, 1024, 512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if m % bm == 0:
            return bm
    return 1


@functools.partial(jax.jit, static_argnames=("clip", "scale"))
def quantize(x, clip: float, scale: float):
    """Quantize a 1-D f32 vector into uint32 masked-domain words."""
    (m,) = x.shape
    bm = _pick_block(m)
    kernel = functools.partial(_quantize_kernel, clip=clip, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm,), lambda i: (i,))],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.uint32),
        interpret=True,
    )(x)


def vmem_bytes(m: int) -> int:
    bm = _pick_block(m)
    return 4 * 2 * bm
