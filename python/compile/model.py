"""L2: JAX compute graphs for the federated-learning workloads.

Two models, both built exclusively on the L1 `fused_dense` Pallas kernel:

* **MLP classifier** — the CIFAR-like reliability experiments (Fig 5.2 /
  Fig A.3): one hidden layer, softmax cross-entropy, SGD.
* **Softmax regression** — the AT&T-faces privacy experiments (Fig 2 /
  A.4, Tables 5.2 / A.3), matching Fredrikson et al.'s model-inversion
  setting. `inversion_step` is the attacker's gradient step on the input.

Each entry point is a pure function over flat parameter arguments so that
`aot.py` can lower it with fixed shapes and the Rust runtime can feed
parameters positionally.
"""

import jax
import jax.numpy as jnp

from compile.kernels.fused_dense import fused_dense
from compile.kernels.ref import dense_ref


def _dense(x, w, b, activation, use_pallas):
    if use_pallas:
        return fused_dense(x, w, b, activation)
    return dense_ref(x, w, b, activation)


def softmax_cross_entropy(logits, y_onehot):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))


# --------------------------------------------------------------------------
# MLP: x → relu(xW1+b1) → (·W2+b2) → logits
# --------------------------------------------------------------------------

def mlp_logits(w1, b1, w2, b2, x, use_pallas=True):
    h = _dense(x, w1, b1, "relu", use_pallas)
    return _dense(h, w2, b2, "none", use_pallas)


def mlp_loss(w1, b1, w2, b2, x, y_onehot, use_pallas=True):
    return softmax_cross_entropy(mlp_logits(w1, b1, w2, b2, x, use_pallas), y_onehot)


def mlp_train_step(w1, b1, w2, b2, x, y_onehot, lr, use_pallas=True):
    """One SGD step; returns (w1', b1', w2', b2', loss)."""
    loss, grads = jax.value_and_grad(mlp_loss, argnums=(0, 1, 2, 3))(
        w1, b1, w2, b2, x, y_onehot, use_pallas
    )
    g1, gb1, g2, gb2 = grads
    return (
        w1 - lr * g1,
        b1 - lr * gb1,
        w2 - lr * g2,
        b2 - lr * gb2,
        loss,
    )


def mlp_eval_step(w1, b1, w2, b2, x, y_labels, use_pallas=True):
    """Returns (correct_count, mean_loss_proxy). y_labels: int32 (B,)."""
    logits = mlp_logits(w1, b1, w2, b2, x, use_pallas)
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    correct = jnp.sum((pred == y_labels).astype(jnp.int32))
    return (correct,)


def mlp_init(rng_key, d, h, c):
    k1, k2 = jax.random.split(rng_key)
    w1 = jax.random.normal(k1, (d, h), jnp.float32) * jnp.sqrt(2.0 / d)
    w2 = jax.random.normal(k2, (h, c), jnp.float32) * jnp.sqrt(1.0 / h)
    return w1, jnp.zeros((h,), jnp.float32), w2, jnp.zeros((c,), jnp.float32)


# --------------------------------------------------------------------------
# Softmax regression (faces): x → xW+b → logits
# --------------------------------------------------------------------------

def softreg_logits(w, b, x, use_pallas=True):
    return _dense(x, w, b, "none", use_pallas)


def softreg_loss(w, b, x, y_onehot, use_pallas=True):
    return softmax_cross_entropy(softreg_logits(w, b, x, use_pallas), y_onehot)


def softreg_train_step(w, b, x, y_onehot, lr, use_pallas=True):
    loss, (gw, gb) = jax.value_and_grad(softreg_loss, argnums=(0, 1))(
        w, b, x, y_onehot, use_pallas
    )
    return w - lr * gw, b - lr * gb, loss


def softreg_predict(w, b, x, use_pallas=True):
    """Class probabilities — the membership-inference attack surface."""
    return (jax.nn.softmax(softreg_logits(w, b, x, use_pallas), axis=-1),)


def softreg_inversion_step(w, b, x, y_onehot, step_size, use_pallas=True):
    """One step of the Fredrikson et al. model-inversion attack: gradient
    DESCENT on the class loss wrt the *input*, clamped to [0, 1].

    Returns (x', loss). The attacker iterates this from x = 0.5·1 to
    reconstruct the training template of the target class.
    """
    loss, gx = jax.value_and_grad(softreg_loss, argnums=2)(w, b, x, y_onehot, use_pallas)
    x_new = jnp.clip(x - step_size * gx, 0.0, 1.0)
    return x_new, loss
