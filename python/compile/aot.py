"""AOT pipeline: lower every L2 entry point to HLO *text* + a manifest.

HLO text (not `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the Rust `xla` crate) rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (written to --out-dir, default ../artifacts):
  mlp_train.hlo.txt       (w1,b1,w2,b2,x,y1h,lr) -> (w1',b1',w2',b2',loss)
  mlp_eval.hlo.txt        (w1,b1,w2,b2,x,labels) -> (correct,)
  softreg_train.hlo.txt   (w,b,x,y1h,lr)         -> (w',b',loss)
  softreg_predict.hlo.txt (w,b,x)                -> (probs,)
  inversion.hlo.txt       (w,b,x,y1h,step)       -> (x',loss)
  masked_sum.hlo.txt      (stacked u32)          -> (colsum u32,)
  quantize.hlo.txt        (x f32[m])             -> (words u32[m],)
  manifest.json           shapes/dtypes/orderings for the Rust runtime

Run via `make artifacts` (no-op when inputs are unchanged).
"""

import argparse
import functools
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels.masked_sum import masked_sum
from compile.kernels.quantize import quantize as quantize_kernel

# Fixed AOT shapes (recorded in the manifest; the Rust side reads them).
MLP = dict(batch=32, d=192, h=256, c=10)
FACE = dict(batch=20, d=1024, c=40)
INV = dict(batch=1)
AGG = dict(clients=64, m=65536)
# scale matching masking::Quantizer::for_sum_of(32, 4.0, 64): 2^31/(2*64*4)
QUANT_SCALE = float(2**31) / (2.0 * 64 * 4.0)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def u32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.uint32)


def spec_json(s):
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def entries():
    """(name, fn, input_specs, output_arity) for every artifact."""
    b, d, h, c = MLP["batch"], MLP["d"], MLP["h"], MLP["c"]
    fb, fd, fc = FACE["batch"], FACE["d"], FACE["c"]
    return [
        (
            "mlp_train",
            functools.partial(model.mlp_train_step, use_pallas=True),
            [f32(d, h), f32(h), f32(h, c), f32(c), f32(b, d), f32(b, c), f32()],
            5,
        ),
        (
            "mlp_eval",
            functools.partial(model.mlp_eval_step, use_pallas=True),
            [f32(d, h), f32(h), f32(h, c), f32(c), f32(b, d), i32(b)],
            1,
        ),
        (
            "softreg_train",
            functools.partial(model.softreg_train_step, use_pallas=True),
            [f32(fd, fc), f32(fc), f32(fb, fd), f32(fb, fc), f32()],
            3,
        ),
        (
            "softreg_predict",
            functools.partial(model.softreg_predict, use_pallas=True),
            [f32(fd, fc), f32(fc), f32(fb, fd)],
            1,
        ),
        (
            "inversion",
            functools.partial(model.softreg_inversion_step, use_pallas=True),
            [f32(fd, fc), f32(fc), f32(INV["batch"], fd), f32(INV["batch"], fc), f32()],
            2,
        ),
        (
            "masked_sum",
            masked_sum,
            [u32(AGG["clients"], AGG["m"])],
            1,
        ),
        (
            "quantize",
            functools.partial(quantize_kernel, clip=4.0, scale=QUANT_SCALE),
            [f32(AGG["m"])],
            1,
        ),
    ]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--only", default=None, help="emit a single artifact by name")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "format": "hlo-text/v1",
        "mlp": MLP,
        "face": FACE,
        "agg": AGG,
        "artifacts": {},
    }
    for name, fn, specs, n_out in entries():
        if args.only and name != args.only:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [spec_json(s) for s in specs],
            "num_outputs": n_out,
        }
        print(f"wrote {path} ({len(text)} chars)", file=sys.stderr)

    mpath = os.path.join(args.out_dir, "manifest.json")
    if args.only and os.path.exists(mpath):
        with open(mpath) as f:
            old = json.load(f)
        old["artifacts"].update(manifest["artifacts"])
        manifest = old
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
