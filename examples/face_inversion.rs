//! Fig 2 / Fig A.4 reproduction (E6): the model-inversion attack against
//! FedAvg, SA and CCESA on the synthetic face dataset.
//!
//! Federated softmax regression over n = 40 identity-clients (Appendix
//! F.1's setup); the eavesdropper grabs a client's upload and runs
//! Fredrikson-style gradient inversion through the AOT `inversion` HLO
//! step. Reported per scheme: identification rate and mean centered-cosine
//! similarity to the victim template — high for FedAvg, chance for
//! SA/CCESA.
//!
//! ```bash
//! cargo run --release --example face_inversion
//! ```

use ccesa::analysis::bounds::{p_star, t_rule};
use ccesa::attacks::inversion::invert;
use ccesa::attacks::{centered_cosine, eavesdropped_model, Scheme};
use ccesa::fl::data::SyntheticFaces;
use ccesa::masking::Quantizer;
use ccesa::protocol::engine::run_round;
use ccesa::protocol::{ProtocolConfig, Topology};
use ccesa::runtime::softreg::{SoftregParams, SoftregRuntime};
use ccesa::runtime::Runtime;
use ccesa::util::cli::Args;
use ccesa::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    ccesa::util::logging::init();
    let args = Args::new("face_inversion", "Fig 2: model inversion vs FedAvg/SA/CCESA")
        .flag("rounds", Some("40"), "federated training rounds")
        .flag("targets", Some("10"), "identities to attack")
        .flag("steps", Some("80"), "inversion gradient steps")
        .flag("seed", Some("21"), "master seed")
        .parse();
    let rounds: usize = args.req("rounds");
    let n_targets: usize = args.req("targets");
    let inv_steps: usize = args.req("steps");
    let seed: u64 = args.req("seed");

    let rt = Runtime::cpu_default()?;
    let sr = SoftregRuntime::load(&rt)?;
    let dims = sr.dims;
    let side = (dims.d as f64).sqrt() as usize;
    assert_eq!(side * side, dims.d, "face dim must be a square image");

    // one client per identity (paper F.1): each holds its own face images
    let mut rng = Rng::new(seed);
    let (ds, templates) = SyntheticFaces::generate(dims.c, 12, side, 0.05, &mut rng);
    println!("faces: {} identities, {} images, {side}x{side}", dims.c, ds.len());

    // --- federated training: every round each identity-client trains on
    // its own images; the global model is the plain average (training
    // dynamics are identical across schemes — only the *wire format* of
    // the upload differs, which is what the attacker sees).
    let mut global = SoftregParams::zeros(dims);
    let per_identity: Vec<Vec<usize>> = (0..dims.c)
        .map(|id| (0..ds.len()).filter(|&i| ds.ys[i] == id).collect())
        .collect();
    let mut victim_upload = global.clone();
    for r in 0..rounds {
        let mut acc = vec![0.0f32; dims.param_count()];
        for shard in &per_identity {
            let mut local = global.clone();
            let (x, onehot, _) = ds.batch(shard, dims.batch);
            let _ = sr.train_step(&mut local, &x, &onehot, 0.5)?;
            for (a, v) in acc.iter_mut().zip(local.flatten()) {
                *a += v;
            }
            if r == rounds - 1 {
                victim_upload = local; // last round's upload is attacked
            }
        }
        for a in acc.iter_mut() {
            *a /= dims.c as f32;
        }
        global = SoftregParams::from_flat(dims, &acc)?;
    }
    println!("federated training done ({rounds} rounds)");

    // --- what the eavesdropper sees per scheme
    let k = dims.c; // all identity-clients participate
    let q = Quantizer::for_sum_of(32, 4.0, k);
    let plain_flat = victim_upload.flatten();
    let quantized = q.quantize(&plain_flat);

    // run a real CCESA round over the identity-clients' uploads to obtain
    // an actual masked wire payload for the victim (client 0)
    let p = p_star(k, 0.0).min(1.0);
    let models: Vec<Vec<u64>> = (0..k).map(|_| quantized.clone()).collect();
    let cfg_ccesa = ProtocolConfig::builder()
        .clients(k)
        .threshold(t_rule(k, p).min(k / 2))
        .model_dim(dims.param_count())
        .topology(Topology::ErdosRenyi { p })
        .seed(seed)
        .build()?;
    let ccesa_round = run_round(&cfg_ccesa, &models)?;
    let cfg_sa = ProtocolConfig::builder()
        .clients(k)
        .threshold(k / 2 + 1)
        .model_dim(dims.param_count())
        .seed(seed)
        .build()?;
    let sa_round = run_round(&cfg_sa, &models)?;
    let masked_of = |r: &ccesa::protocol::engine::RoundResult| {
        r.transcript.masked.first().map(|(_, v)| v.clone()).unwrap()
    };

    let schemes: Vec<(&str, Vec<f32>)> = vec![
        ("FedAvg", eavesdropped_model(Scheme::FedAvg, &plain_flat, &q, &[])),
        ("SA", eavesdropped_model(Scheme::Masked, &plain_flat, &q, &masked_of(&sa_round))),
        ("CCESA", eavesdropped_model(Scheme::Masked, &plain_flat, &q, &masked_of(&ccesa_round))),
    ];

    println!(
        "\nscheme   identified  mean-sim(target)  mean-sim(best-other)   (targets={n_targets}, steps={inv_steps})"
    );
    for (name, view) in schemes {
        let params = SoftregParams::from_flat(dims, &view)?;
        let mut hits = 0;
        let mut sim_t = 0.0f32;
        let mut sim_o = 0.0f32;
        for target in 0..n_targets.min(dims.c) {
            let out = invert(&sr, &params, target, &templates, inv_steps, 5.0)?;
            if out.identified() {
                hits += 1;
            }
            sim_t += out.target_similarity;
            sim_o += out.best_other_similarity;
        }
        let nt = n_targets.min(dims.c) as f32;
        println!(
            "{name:<8} {:>6.1}%     {:>8.3}          {:>8.3}",
            100.0 * hits as f32 / nt,
            sim_t / nt,
            sim_o / nt
        );
    }
    println!(
        "\nchance identification = {:.1}% (1/{})  — FedAvg should be ≈100%, SA/CCESA ≈ chance",
        100.0 / dims.c as f32,
        dims.c
    );
    println!(
        "CCESA round used p = {p:.3} ({:.0}% of SA's key/share traffic)",
        100.0 * p
    );
    Ok(())
}
