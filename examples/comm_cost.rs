//! Table 1 / Table F.4 / Turbo-aggregate comparison (E1, E8): the cost
//! model columns AND measured wire bytes from real protocol rounds, with
//! log–log scaling-exponent fits validating the asymptotics.
//!
//! ```bash
//! cargo run --release --example comm_cost
//! ```

use ccesa::analysis::bounds::{p_star, t_rule, table_f4};
use ccesa::analysis::costs::{
    ccesa_client_extra_bits, client_compute_ops, sa_client_extra_bits, server_compute_ops,
    turbo_comparison_ratio, CostParams, Scheme,
};
use ccesa::protocol::engine::run_round;
use ccesa::protocol::{ProtocolConfig, Topology};
use ccesa::util::cli::Args;
use ccesa::util::rng::Rng;
use ccesa::util::stats::power_law_exponent;

fn main() -> anyhow::Result<()> {
    ccesa::util::logging::init();
    let args = Args::new("comm_cost", "Table 1 cost models + measured scaling")
        .flag("dim", Some("1000"), "model dimension for measured rounds")
        .flag("seed", Some("5"), "seed")
        .parse();
    let dim: usize = args.req("dim");
    let seed: u64 = args.req("seed");

    // ---- Table F.4: p*(n, q_total) -------------------------------------
    println!("== Table F.4: threshold connection probability p* ==");
    println!("{:>6} {:>8} {:>8}", "n", "q_total", "p*");
    for (n, qt, p) in table_f4() {
        if n % 200 == 100 || n == 1000 {
            println!("{n:>6} {qt:>8.2} {p:>8.3}");
        }
    }

    // ---- Table 1: model columns ----------------------------------------
    println!("\n== Table 1 (cost model, a_K=a_S=256 bits, m=10^4, R=32) ==");
    println!(
        "{:>6} {:>8} | {:>12} {:>12} {:>8} | {:>12} {:>12} | {:>12} {:>12}",
        "n", "p*", "B_ccesa(b)", "B_sa(b)", "ratio", "cl ops CC", "cl ops SA", "sv ops CC", "sv ops SA"
    );
    for n in [100usize, 200, 400, 800, 1600] {
        let p = p_star(n, 0.0);
        let cp = CostParams::paper_defaults(n, 10_000);
        let bc = ccesa_client_extra_bits(&cp, p);
        let bs = sa_client_extra_bits(&cp);
        println!(
            "{n:>6} {p:>8.3} | {bc:>12.3e} {bs:>12.3e} {:>8.3} | {:>12.3e} {:>12.3e} | {:>12.3e} {:>12.3e}",
            bc / bs,
            client_compute_ops(&cp, Scheme::Ccesa, p),
            client_compute_ops(&cp, Scheme::Sa, p),
            server_compute_ops(&cp, Scheme::Ccesa, p),
            server_compute_ops(&cp, Scheme::Sa, p),
        );
    }

    // ---- measured wire bytes from real rounds + scaling fits -----------
    println!("\n== measured per-client key/share traffic (real rounds, dim={dim}) ==");
    let ns = [50usize, 100, 200, 400];
    let mut cc_meas = Vec::new();
    let mut sa_meas = Vec::new();
    println!("{:>6} {:>8} {:>14} {:>14} {:>8}", "n", "p*", "ccesa (B)", "sa (B)", "ratio");
    for &n in &ns {
        let mut rng = Rng::new(seed);
        let models: Vec<Vec<u64>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect())
            .collect();
        let p = p_star(n, 0.0);
        let t = t_rule(n, p);
        let mk = |t: usize, topology: Topology| -> anyhow::Result<ProtocolConfig> {
            ProtocolConfig::builder()
                .clients(n)
                .threshold(t)
                .model_dim(dim)
                .topology(topology)
                .seed(seed)
                .build()
        };
        let cc = run_round(&mk(t, Topology::ErdosRenyi { p })?, &models)?;
        let sa = run_round(&mk(n / 2 + 1, Topology::Complete)?, &models)?;
        // per-client non-model traffic: total minus the masked upload
        let model_bytes = (dim * 4) as f64;
        let cc_extra = cc.stats.mean_client_total() - model_bytes;
        let sa_extra = sa.stats.mean_client_total() - model_bytes;
        println!(
            "{n:>6} {p:>8.3} {cc_extra:>14.0} {sa_extra:>14.0} {:>8.3}",
            cc_extra / sa_extra
        );
        cc_meas.push(cc_extra);
        sa_meas.push(sa_extra);
    }
    let nsf: Vec<f64> = ns.iter().map(|&n| n as f64).collect();
    let (k_cc, r2c) = power_law_exponent(&nsf, &cc_meas);
    let (k_sa, r2s) = power_law_exponent(&nsf, &sa_meas);
    println!(
        "\nscaling fits: CCESA extra-bytes ~ n^{k_cc:.2} (r²={r2c:.3}, paper: √(n log n) ≈ n^0.6), \
         SA ~ n^{k_sa:.2} (r²={r2s:.3}, paper: n^1.0)"
    );

    // ---- Turbo-aggregate comparison (§1) --------------------------------
    let ratio = turbo_comparison_ratio(1_000_000, 100, 32, 10);
    println!(
        "\n== Turbo-aggregate comparison (m=1e6, R=32, n=100, L=10, a_K=a_S=256) ==\n\
         CCESA / Turbo bandwidth ratio = {:.3} (paper claims ≈ 0.03)",
        ratio
    );
    Ok(())
}
