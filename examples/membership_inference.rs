//! Tables 5.2 / A.3 reproduction (E7): membership-inference attack
//! accuracy and precision against FedAvg, SA and CCESA, for a sweep of
//! training-set sizes.
//!
//! The victim model is the softmax-regression face classifier trained to
//! overfit its members; the attacker eavesdrops one upload and thresholds
//! true-label confidence (median rule). Expected shape: FedAvg well above
//! 50% (more so for smaller n_train), SA/CCESA pinned at ≈50%.
//!
//! ```bash
//! cargo run --release --example membership_inference
//! ```

use ccesa::analysis::bounds::{p_star, t_rule};
use ccesa::attacks::membership::attack;
use ccesa::attacks::{eavesdropped_model, Scheme};
use ccesa::fl::data::SyntheticFaces;
use ccesa::masking::Quantizer;
use ccesa::protocol::engine::run_round;
use ccesa::protocol::{ProtocolConfig, Topology};
use ccesa::runtime::softreg::{SoftregParams, SoftregRuntime};
use ccesa::runtime::Runtime;
use ccesa::util::cli::Args;
use ccesa::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    ccesa::util::logging::init();
    let args = Args::new(
        "membership_inference",
        "Tables 5.2/A.3: membership inference vs FedAvg/SA/CCESA",
    )
    .flag("sizes", Some("240,480,960"), "comma-separated member-set sizes")
    .flag("epochs", Some("60"), "victim training epochs (overfitting)")
    .flag("noise", Some("0.65"), "pixel noise (higher = larger member/non-member gap)")
    .flag("seed", Some("33"), "master seed")
    .parse();
    let sizes: Vec<usize> = args
        .req::<String>("sizes")
        .split(',')
        .map(|s| s.trim().parse().expect("size"))
        .collect();
    let epochs: usize = args.req("epochs");
    let seed: u64 = args.req("seed");

    let rt = Runtime::cpu_default()?;
    let sr = SoftregRuntime::load(&rt)?;
    let dims = sr.dims;
    let side = (dims.d as f64).sqrt() as usize;

    println!("scheme   n_train  accuracy  precision  recall");
    for &n_train in &sizes {
        let mut rng = Rng::new(seed ^ n_train as u64);
        let per_id = (2 * n_train / dims.c).max(2);
        let noise: f32 = args.req("noise");
        let (ds, _templates) = SyntheticFaces::generate(dims.c, per_id, side, noise, &mut rng);
        // split into members / non-members (balanced)
        let half: Vec<usize> = (0..ds.len()).step_by(2).collect();
        let other: Vec<usize> = (1..ds.len()).step_by(2).collect();
        let members = ds.subset(&half);
        let nonmembers = ds.subset(&other);

        // victim training: overfit members only
        let mut victim = SoftregParams::zeros(dims);
        let all_members: Vec<usize> = (0..members.len()).collect();
        for _ in 0..epochs {
            for chunk in all_members.chunks(dims.batch) {
                let (x, onehot, _) = members.batch(chunk, dims.batch);
                let _ = sr.train_step(&mut victim, &x, &onehot, 0.5)?;
            }
        }

        // eavesdropped views: plain (FedAvg) and masked via real protocol
        // rounds (SA = complete graph, CCESA = ER at p*)
        let k = 10usize; // paper: n = 10 clients
        let q = Quantizer::for_sum_of(32, 4.0, k);
        let flat = victim.flatten();
        let words = q.quantize(&flat);
        let models: Vec<Vec<u64>> = (0..k).map(|_| words.clone()).collect();
        let sa_round = run_round(
            &ProtocolConfig::builder()
                .clients(k)
                .threshold(k / 2 + 1)
                .model_dim(flat.len())
                .seed(seed)
                .build()?,
            &models,
        )?;
        let p = p_star(40, 0.0).min(1.0); // small-n guard: use n=40's p*
        let cc_round = run_round(
            &ProtocolConfig::builder()
                .clients(k)
                .threshold(t_rule(k, p).min(k / 2 + 1))
                .model_dim(flat.len())
                .topology(Topology::ErdosRenyi { p })
                .seed(seed)
                .build()?,
            &models,
        )?;
        let masked_of = |r: &ccesa::protocol::engine::RoundResult| {
            r.transcript.masked.first().map(|(_, v)| v.clone()).unwrap()
        };

        for (name, view) in [
            ("FedAvg", eavesdropped_model(Scheme::FedAvg, &flat, &q, &[])),
            ("SA", eavesdropped_model(Scheme::Masked, &flat, &q, &masked_of(&sa_round))),
            ("CCESA", eavesdropped_model(Scheme::Masked, &flat, &q, &masked_of(&cc_round))),
        ] {
            let params = SoftregParams::from_flat(dims, &view)?;
            let rep = attack(&sr, &params, &members, &nonmembers)?;
            println!(
                "{name:<8} {n_train:<8} {:<9.4} {:<10.4} {:<.4}",
                rep.accuracy, rep.precision, rep.recall
            );
        }
    }
    println!("\nexpected shape: FedAvg ≳ 0.6; SA/CCESA ≈ 0.5 (random guess)");
    Ok(())
}
