//! Ablation (DESIGN.md §5): assignment-graph family comparison at equal
//! mean degree — Erdős–Rényi (this paper) vs Harary (Bell et al. 2020)
//! vs the complete graph (SA), plus a below-threshold ER point.
//!
//! For each topology: Monte-Carlo reliability/privacy failure rates under
//! dropout, measured per-client key/share bytes from a real round, and
//! single-round wall time.
//!
//! ```bash
//! cargo run --release --example graph_ablation -- --n 100 --qtotal 0.1
//! ```

use ccesa::analysis::bounds::{p_star, per_step_q, t_rule};
use ccesa::analysis::montecarlo::{sample_evolution, theorem2_predicate};
use ccesa::protocol::dropout::DropoutModel;
use ccesa::protocol::engine::run_round;
use ccesa::protocol::server::theorem1_predicate;
use ccesa::protocol::{ProtocolConfig, Topology};
use ccesa::util::cli::Args;
use ccesa::util::rng::Rng;
use ccesa::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    ccesa::util::logging::init();
    let args = Args::new("graph_ablation", "ER vs Harary vs complete assignment graphs")
        .flag("n", Some("100"), "clients")
        .flag("dim", Some("5000"), "model dimension")
        .flag("qtotal", Some("0.1"), "protocol dropout")
        .flag("trials", Some("300"), "Monte-Carlo trials")
        .flag("seed", Some("3"), "seed")
        .parse();
    let n: usize = args.req("n");
    let dim: usize = args.req("dim");
    let q_total: f64 = args.req("qtotal");
    let trials: usize = args.req("trials");
    let seed: u64 = args.req("seed");

    let q = per_step_q(q_total);
    let ps = p_star(n, q_total); // already clamped to ≤ 1 (builder-valid)
    let t = t_rule(n, ps);
    let harary_k = ((n as f64 - 1.0) * ps).round() as usize; // equal mean degree
    println!("n={n} q_total={q_total} p*={ps:.4} t={t} harary_k={harary_k}\n");

    let cases: Vec<(&str, Topology, usize)> = vec![
        ("SA (complete)", Topology::Complete, n / 2 + 1),
        ("CCESA ER p=p*", Topology::ErdosRenyi { p: ps }, t),
        ("CCESA ER p=p*/2", Topology::ErdosRenyi { p: ps / 2.0 }, t_rule(n, ps / 2.0)),
        ("Harary k=⌈(n-1)p*⌉", Topology::Harary { k: harary_k }, t.min(harary_k / 2 + 1)),
    ];

    println!(
        "{:<20} {:>10} {:>10} {:>14} {:>12} {:>10}",
        "topology", "rel fail", "priv fail", "client B", "round ms", "reliable?"
    );
    for (label, topo, tt) in cases {
        // Monte-Carlo rates (graph-level, fast). Harary/complete are not
        // random, so build them once and evaluate dropout-only trials.
        let (mut rel_fail, mut priv_fail) = (0usize, 0usize);
        let mut mc_rng = Rng::new(seed ^ 0xAB);
        for _ in 0..trials {
            let ev = match &topo {
                Topology::ErdosRenyi { p } => sample_evolution(n, *p, q, tt, &mut mc_rng),
                other => {
                    // fixed graph + random dropout via the p=1 sampler on a
                    // custom evolution: emulate by sampling with p=1 then
                    // replacing the graph
                    let mut ev = sample_evolution(n, 1.0, q, tt, &mut mc_rng);
                    ev.graph = other.build(n, &mut mc_rng);
                    ev
                }
            };
            if ev.sets.v3.len() < tt || !theorem1_predicate(&ev.graph, &ev.sets, tt) {
                rel_fail += 1;
            }
            if !theorem2_predicate(&ev, tt) {
                priv_fail += 1;
            }
        }

        // one real round for bytes + latency
        let mut rng = Rng::new(seed);
        let models: Vec<Vec<u64>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect())
            .collect();
        let cfg = ProtocolConfig::builder()
            .clients(n)
            .threshold(tt)
            .model_dim(dim)
            .topology(topo)
            .dropout(DropoutModel::iid_from_total(q_total))
            .seed(seed)
            .build()?;
        let timer = Timer::start();
        let round = run_round(&cfg, &models);
        let ms = timer.elapsed_ms();
        let (client_b, reliable) = match &round {
            Ok(r) => (r.stats.mean_client_total() - (dim * 4) as f64, r.reliable),
            Err(_) => (f64::NAN, false),
        };
        println!(
            "{label:<20} {:>10.4} {:>10.4} {:>14.0} {:>12.1} {:>10}",
            rel_fail as f64 / trials as f64,
            priv_fail as f64 / trials as f64,
            client_b,
            ms,
            reliable
        );
    }
    println!(
        "\nexpected: ER at p* and Harary at equal degree both ≈ SA on reliability/privacy at \
         a fraction of the bytes; ER at p*/2 shows reliability failures (below Theorem 3)."
    );
    Ok(())
}
