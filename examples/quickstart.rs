//! End-to-end driver (E10 in DESIGN.md): full-stack federated learning
//! with CCESA secure aggregation.
//!
//! Every layer participates: synthetic CIFAR-like data → local SGD via the
//! Pallas/JAX AOT train step executed through PJRT from Rust → fixed-point
//! quantization → the CCESA protocol over an Erdős–Rényi graph at the
//! paper's operating point p* → dequantized global update. Logs the loss
//! curve, accuracy, communication and round latency; results are recorded
//! in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use ccesa::analysis::bounds::{p_star, t_rule};
use ccesa::codec::Codec;
use ccesa::fl::data::{partition_iid, SyntheticCifar};
use ccesa::fl::rounds::{run_fl_mlp, Aggregation, FlConfig};
use ccesa::protocol::dropout::DropoutModel;
use ccesa::protocol::Topology;
use ccesa::runtime::mlp::MlpRuntime;
use ccesa::runtime::Runtime;
use ccesa::util::cli::Args;
use ccesa::util::rng::Rng;
use ccesa::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    ccesa::util::logging::init();
    let args = Args::new("quickstart", "CCESA end-to-end federated learning")
        .flag("clients", Some("60"), "number of clients n")
        .flag("rounds", Some("40"), "FL rounds")
        .flag("fraction", Some("0.5"), "client fraction per round")
        .flag("qtotal", Some("0.05"), "protocol-level dropout probability")
        .flag("samples", Some("3000"), "training samples")
        .flag("seed", Some("7"), "master seed")
        .parse();
    let n: usize = args.req("clients");
    let rounds: usize = args.req("rounds");
    let fraction: f64 = args.req("fraction");
    let q_total: f64 = args.req("qtotal");
    let samples: usize = args.req("samples");
    let seed: u64 = args.req("seed");

    let rt = Runtime::cpu_default()?;
    let mlp = MlpRuntime::load(&rt)?;
    println!(
        "platform={}  model: MLP {}→{}→{} ({} params)",
        rt.platform(),
        mlp.dims.d,
        mlp.dims.h,
        mlp.dims.c,
        mlp.dims.param_count()
    );

    let mut rng = Rng::new(seed);
    let (train, test) = SyntheticCifar::generate_split(
        samples,
        samples / 5,
        mlp.dims.d,
        mlp.dims.c,
        0.45,
        &mut rng,
    );
    let parts = partition_iid(&train, n, &mut rng);

    let k = ((n as f64) * fraction).round() as usize;
    let p = p_star(k, q_total);
    let t = t_rule(k, p).min(k - 1);
    println!("CCESA operating point: k={k} selected/round, p*={p:.4}, t={t}, q_total={q_total}");

    let cfg = FlConfig {
        n_clients: n,
        rounds,
        client_fraction: fraction,
        local_epochs: 1,
        lr: 0.3,
        clip: 4.0,
        aggregation: Aggregation::Secure {
            topology: Topology::ErdosRenyi { p },
            t_override: Some(t),
            mask_bits: 32,
            dropout: DropoutModel::iid_from_total(q_total),
            codec: Codec::Dense,
        },
        seed,
    };

    let wall = Timer::start();
    let hist = run_fl_mlp(&cfg, &mlp, &train, &parts, &test)?;
    let total_s = wall.elapsed().as_secs_f64();

    println!("\nround  loss    accuracy  reliable  up(KiB)  down(KiB)");
    for l in &hist.logs {
        println!(
            "{:>5}  {:<7.4} {:<9.4} {:<9} {:<8.1} {:<8.1}",
            l.round,
            l.mean_local_loss,
            l.test_accuracy,
            l.reliable,
            l.bytes_up as f64 / 1024.0,
            l.bytes_down as f64 / 1024.0
        );
    }
    println!(
        "\nfinal accuracy        : {:.4}\nunreliable rounds     : {}/{}\ntotal secure-agg bytes: {:.2} MiB\nwall time             : {:.1} s ({:.2} s/round)",
        hist.final_accuracy(),
        hist.unreliable_rounds(),
        rounds,
        hist.total_stats.server_total() as f64 / (1024.0 * 1024.0),
        total_s,
        total_s / rounds as f64
    );
    Ok(())
}
