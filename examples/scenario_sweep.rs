//! Scenario sweep: the reliability/privacy claims under every churn model
//! the `sim` subsystem knows, at the paper's operating point p = p*(n),
//! plus a randomized engine↔coordinator differential check.
//!
//! Per churn model: reliable/aborted/breached round counts, Theorem-1
//! agreement, and total traffic through the server; a payload-codec sweep
//! shows the masked-payload savings of top-k/rand-k sparsification. The
//! differential rows confirm the event-loop deployment shape is
//! bit-identical to the engine on every generated scenario (and shrink +
//! report any divergence).
//!
//! ```bash
//! cargo run --release --example scenario_sweep
//! cargo run --release --example scenario_sweep -- --n 100 --rounds 6 --diff 50
//! ```

use ccesa::analysis::bounds::p_star;
use ccesa::protocol::Topology;
use ccesa::sim::{
    run_campaign, run_differential_batch, AdversarySpec, ChurnModel, CodecSpec, Executor, Scenario,
    ThresholdRule, TopologySchedule,
};
use ccesa::util::cli::Args;

fn main() -> anyhow::Result<()> {
    ccesa::util::logging::init();
    let args = Args::new("scenario_sweep", "churn-model sweep + differential harness")
        .flag("n", Some("60"), "clients per scenario")
        .flag("rounds", Some("4"), "rounds per campaign")
        .flag("seed", Some("7"), "base seed")
        .flag("diff", Some("25"), "randomized differential scenarios (0 = skip)")
        .parse();
    let n: usize = args.req("n");
    let rounds: usize = args.req("rounds");
    let seed: u64 = args.req("seed");
    let p = p_star(n, 0.05);

    let churns: Vec<(&str, ChurnModel)> = vec![
        ("none", ChurnModel::None),
        ("iid q=3%", ChurnModel::Iid { q: 0.03 }),
        (
            "bursty",
            ChurnModel::Bursty { q_calm: 0.01, q_storm: 0.2, p_enter: 0.35, p_exit: 0.5 },
        ),
        (
            "regional",
            ChurnModel::CorrelatedRegional { regions: 4, q_region: 0.15, q_local: 0.01 },
        ),
        ("adaptive", ChurnModel::TargetedAdaptive { count: n / 20 + 1, step: 2 }),
    ];

    println!("== scenario sweep: n={n} rounds={rounds} ER p*={p:.3} ==");
    println!(
        "{:<10} {:>8} {:>8} {:>8} {:>8} {:>10} {:>12}",
        "churn", "reliable", "aborted", "breached", "exposed", "thm1 viol", "server KiB"
    );
    for (label, churn) in churns {
        let sc = Scenario {
            name: label.to_string(),
            n,
            dim: 128,
            mask_bits: 32,
            rounds,
            topology: TopologySchedule::Static(Topology::ErdosRenyi { p }),
            churn,
            adversary: AdversarySpec::Colluding((0..n / 10).collect()),
            threshold: ThresholdRule::Auto,
            codec: CodecSpec::Dense,
            clip: 4.0,
            seed,
        };
        let rep = run_campaign(&sc, Executor::Engine)?;
        println!(
            "{:<10} {:>8} {:>8} {:>8} {:>8} {:>10} {:>12.1}",
            label,
            rep.reliable_rounds(),
            rep.aborted_rounds(),
            rep.breached_rounds(),
            rep.exposed_honest_total(),
            rep.theorem1_violations(),
            rep.total_stats.server_total() as f64 / 1024.0,
        );
    }

    // payload-codec sweep: same campaign, masked-payload bytes per codec —
    // the bandwidth lever the codec layer adds on top of the sparse graph
    println!("\n== codec sweep: n={n} rounds={rounds} (iid 3% churn) ==");
    println!("{:<12} {:>8} {:>16} {:>12}", "codec", "reliable", "payload KiB", "vs dense");
    let mut dense_payload = 0u64;
    for codec in [
        CodecSpec::Dense,
        CodecSpec::TopK { frac: 0.1 },
        CodecSpec::RandK { frac: 0.1 },
    ] {
        let sc = Scenario {
            name: format!("codec-{}", codec.name()),
            n,
            dim: 128,
            mask_bits: 32,
            rounds,
            topology: TopologySchedule::Static(Topology::ErdosRenyi { p }),
            churn: ChurnModel::Iid { q: 0.03 },
            adversary: AdversarySpec::Eavesdropper,
            threshold: ThresholdRule::Auto,
            codec,
            clip: 4.0,
            seed,
        };
        let rep = run_campaign(&sc, Executor::Engine)?;
        let payload = rep.total_stats.masked_payload_bytes;
        if matches!(codec, CodecSpec::Dense) {
            dense_payload = payload;
        }
        println!(
            "{:<12} {:>8} {:>16.1} {:>11.1}x",
            codec.name(),
            rep.reliable_rounds(),
            payload as f64 / 1024.0,
            dense_payload as f64 / payload.max(1) as f64,
        );
    }

    let diff_count: usize = args.req("diff");
    if diff_count > 0 {
        println!("\n== differential: {diff_count} random scenarios, engine vs coordinator ==");
        let report = run_differential_batch(seed.wrapping_mul(0x9E37_79B9), diff_count);
        println!(
            "scenarios={} rounds={} mismatches={}",
            report.scenarios_run,
            report.rounds_run,
            report.failures.len()
        );
        for f in &report.failures {
            println!(
                "MISMATCH seed={:#x} round={} field={}: {}\n  shrunk repro: {:?}",
                f.mismatch.seed, f.mismatch.round, f.mismatch.field, f.mismatch.detail, f.shrunk
            );
        }
        anyhow::ensure!(report.ok(), "differential harness found divergences");
    }
    Ok(())
}
