//! Fig 5.2 / Fig A.3 reproduction (E4/E5): test accuracy of SA vs
//! CCESA(n, p) for a sweep of connection probabilities, under i.i.d. and
//! non-i.i.d. data allocation.
//!
//! The paper's claim: CCESA at p ≥ p* tracks SA's accuracy exactly, while
//! p well below p* degrades (unreliable rounds keep the previous global
//! model and learning stalls). Emits one CSV row per (setting, p, round).
//!
//! ```bash
//! cargo run --release --example cifar_fl -- --clients 100 --rounds 30
//! cargo run --release --example cifar_fl -- --noniid
//! ```

use ccesa::analysis::bounds::{p_star, t_rule};
use ccesa::codec::Codec;
use ccesa::fl::data::{partition_iid, partition_noniid, SyntheticCifar};
use ccesa::fl::rounds::{run_fl_mlp, Aggregation, FlConfig, FlHistory};
use ccesa::protocol::dropout::DropoutModel;
use ccesa::protocol::Topology;
use ccesa::runtime::mlp::MlpRuntime;
use ccesa::runtime::Runtime;
use ccesa::util::cli::Args;
use ccesa::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    ccesa::util::logging::init();
    let args = Args::new("cifar_fl", "Fig 5.2: accuracy of SA vs CCESA(p) over rounds")
        .flag("clients", Some("120"), "number of clients n")
        .flag("rounds", Some("12"), "FL rounds")
        .flag("fraction", Some("1.0"), "client fraction per round")
        .flag("qtotal", Some("0.1"), "protocol dropout q_total")
        .flag("samples", Some("4000"), "training samples")
        .flag("seed", Some("11"), "master seed")
        .flag("csv", Some("results_fig52.csv"), "output CSV path")
        .switch("noniid", "use the non-i.i.d. shard partition (McMahan)")
        .parse();
    let n: usize = args.req("clients");
    let rounds: usize = args.req("rounds");
    let fraction: f64 = args.req("fraction");
    let q_total: f64 = args.req("qtotal");
    let samples: usize = args.req("samples");
    let seed: u64 = args.req("seed");
    let noniid = args.get_bool("noniid");
    let csv_path: String = args.req("csv");

    let rt = Runtime::cpu_default()?;
    let mlp = MlpRuntime::load(&rt)?;
    let mut rng = Rng::new(seed);
    let (train, test) = SyntheticCifar::generate_split(
        samples,
        samples / 5,
        mlp.dims.d,
        mlp.dims.c,
        0.40,
        &mut rng,
    );
    let parts = if noniid {
        partition_noniid(&train, n, &mut rng)
    } else {
        partition_iid(&train, n, &mut rng)
    };

    let k = ((n as f64) * fraction).round() as usize;
    let ps = p_star(k, q_total);
    println!(
        "setting: n={n} k={k} q_total={q_total} partition={} p*={ps:.4}",
        if noniid { "non-iid" } else { "iid" }
    );

    // sweep: SA (complete) + CCESA at p relative to the threshold p* —
    // below (degrades), at (matches SA), and above
    let mut sweep: Vec<(String, Option<f64>)> = vec![("SA".into(), None)];
    let mut pts = vec![0.6 * ps, 0.85 * ps, ps, (1.0 + ps) / 2.0, 1.0];
    pts.dedup_by(|a, b| (*a - *b).abs() < 1e-6);
    for p in pts {
        let p = p.min(1.0);
        sweep.push((format!("CCESA p={p:.3}"), Some(p)));
    }

    let mut csv = String::from("setting,p,round,accuracy,reliable\n");
    let mut finals = Vec::new();
    for (label, popt) in &sweep {
        let aggregation = match popt {
            None => Aggregation::Secure {
                topology: Topology::Complete,
                t_override: Some(k / 2 + 1),
                mask_bits: 32,
                dropout: DropoutModel::iid_from_total(q_total),
                codec: Codec::Dense,
            },
            Some(p) => Aggregation::Secure {
                topology: Topology::ErdosRenyi { p: *p },
                t_override: Some(t_rule(k, *p).min(k * 2 / 3)),
                mask_bits: 32,
                dropout: DropoutModel::iid_from_total(q_total),
                codec: Codec::Dense,
            },
        };
        let cfg = FlConfig {
            n_clients: n,
            rounds,
            client_fraction: fraction,
            local_epochs: 2,
            lr: 0.5,
            clip: 4.0,
            aggregation,
            seed,
        };
        let hist: FlHistory = run_fl_mlp(&cfg, &mlp, &train, &parts, &test)?;
        for l in &hist.logs {
            csv.push_str(&format!(
                "{label},{},{},{:.4},{}\n",
                popt.map(|p| format!("{p:.4}")).unwrap_or_else(|| "1.0(SA)".into()),
                l.round,
                l.test_accuracy,
                l.reliable as u8
            ));
        }
        println!(
            "{label:<16} final acc {:.4}  unreliable {}/{}  comm {:.1} MiB",
            hist.final_accuracy(),
            hist.unreliable_rounds(),
            rounds,
            hist.total_stats.server_total() as f64 / (1024.0 * 1024.0)
        );
        finals.push((label.clone(), hist.final_accuracy(), hist.unreliable_rounds()));
    }

    std::fs::write(&csv_path, csv)?;
    println!("\nwrote {csv_path}");

    // the Fig 5.2 shape: CCESA at p ≥ p* within noise of SA
    let sa_acc = finals[0].1;
    for (label, acc, _) in &finals[1..] {
        let tag = if *acc >= sa_acc - 0.05 { "≈SA" } else { "DEGRADED" };
        println!("{label:<16} {acc:.4} [{tag}]");
    }
    Ok(())
}
