//! Fig A.3 reproduction (E5): federated softmax regression on the face
//! dataset, n = 40 identity-clients, t = 21 (the paper's setting), SA vs
//! CCESA(p) for a sweep of connection probabilities.
//!
//! Each client holds one identity's images (Appendix F.1). Per round each
//! client runs one local SGD step via the AOT `softreg_train` HLO, and the
//! updates are aggregated through the real SA/CCESA protocol (quantize →
//! mask → aggregate → dequantize). Unreliable rounds keep the previous
//! global model.
//!
//! ```bash
//! cargo run --release --example faces_fl
//! ```

use ccesa::analysis::bounds::p_star;
use ccesa::fl::data::SyntheticFaces;
use ccesa::masking::Quantizer;
use ccesa::protocol::dropout::DropoutModel;
use ccesa::protocol::engine::run_round;
use ccesa::protocol::{ProtocolConfig, Topology};
use ccesa::runtime::softreg::{SoftregParams, SoftregRuntime};
use ccesa::runtime::Runtime;
use ccesa::util::cli::Args;
use ccesa::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    ccesa::util::logging::init();
    let args = Args::new("faces_fl", "Fig A.3: faces FL, SA vs CCESA(p), n=40, t=21")
        .flag("rounds", Some("25"), "FL rounds")
        .flag("t", Some("21"), "secret-sharing threshold (paper: 21)")
        .flag("qtotal", Some("0.05"), "protocol dropout")
        .flag("seed", Some("41"), "seed")
        .parse();
    let rounds: usize = args.req("rounds");
    let t: usize = args.req("t");
    let q_total: f64 = args.req("qtotal");
    let seed: u64 = args.req("seed");

    let rt = Runtime::cpu_default()?;
    let sr = SoftregRuntime::load(&rt)?;
    let dims = sr.dims;
    let n = dims.c; // one client per identity (n = 40)
    let side = (dims.d as f64).sqrt() as usize;

    let mut rng = Rng::new(seed);
    let (ds, _templates) = SyntheticFaces::generate(n, 14, side, 0.30, &mut rng);
    // per-identity shards; last 4 images per identity held out for eval
    let mut shards: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut test_idx: Vec<usize> = Vec::new();
    let mut seen = vec![0usize; n];
    for i in 0..ds.len() {
        let id = ds.ys[i];
        seen[id] += 1;
        if seen[id] <= 10 {
            shards[id].push(i);
        } else {
            test_idx.push(i);
        }
    }
    let test = ds.subset(&test_idx);
    let ps = p_star(n, q_total);
    println!("n={n} t={t} q_total={q_total} p*={ps:.3} test={} images", test.len());

    let accuracy = |params: &SoftregParams| -> anyhow::Result<f64> {
        let mut correct = 0usize;
        let mut total = 0usize;
        let b = dims.batch;
        let mut i = 0;
        while i < test.len() {
            let idx: Vec<usize> = (i..(i + b).min(test.len())).collect();
            let real = idx.len();
            let (x, _, labels) = test.batch(&idx, b);
            let probs = sr.predict(params, &x)?;
            for k in 0..real {
                let row = &probs[k * dims.c..(k + 1) * dims.c];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred == labels[k] as usize {
                    correct += 1;
                }
                total += 1;
            }
            i += b;
        }
        Ok(correct as f64 / total as f64)
    };

    let sweep: Vec<(String, Option<f64>)> = vec![
        ("SA".into(), None),
        (format!("CCESA p={:.2}", 0.7), Some(0.7)), // the paper's Fig A.3 point
        (format!("CCESA p={ps:.2} (p*)"), Some(ps.min(1.0))),
        ("CCESA p=0.40".into(), Some(0.40)),
    ];
    println!("\n{:<20} {:>9} {:>12} {:>12}", "setting", "final acc", "unreliable", "comm (MiB)");
    for (label, popt) in sweep {
        let mut global = SoftregParams::zeros(dims);
        let dim = dims.param_count();
        let mut unreliable = 0usize;
        let mut bytes = 0u64;
        for r in 0..rounds {
            // local training (each identity-client: one SGD step on its shard)
            let mut locals: Vec<Vec<f32>> = Vec::with_capacity(n);
            for shard in &shards {
                let mut local = global.clone();
                let (x, onehot, _) = ds.batch(shard, dims.batch);
                sr.train_step(&mut local, &x, &onehot, 0.5)?;
                locals.push(local.flatten());
            }
            // secure aggregation
            let q = Quantizer::for_sum_of(32, 4.0, n);
            let models: Vec<Vec<u64>> = locals.iter().map(|l| q.quantize(l)).collect();
            let topology = match popt {
                None => Topology::Complete,
                Some(p) => Topology::ErdosRenyi { p },
            };
            let cfg = ProtocolConfig::builder()
                .clients(n)
                .threshold(t)
                .model_dim(dim)
                .topology(topology)
                .dropout(DropoutModel::iid_from_total(q_total))
                .seed(seed ^ (r as u64) << 8)
                .build()?;
            match run_round(&cfg, &models) {
                Ok(res) => {
                    bytes += res.stats.server_total();
                    if let Some(sum) = res.sum {
                        let k = res.sets.v3.len().max(1) as f64;
                        let mean: Vec<f32> =
                            q.dequantize(&sum).iter().map(|v| (v / k) as f32).collect();
                        global = SoftregParams::from_flat(dims, &mean)?;
                    } else {
                        unreliable += 1;
                    }
                }
                Err(_) => unreliable += 1,
            }
        }
        let acc = accuracy(&global)?;
        println!(
            "{label:<20} {acc:>9.4} {unreliable:>9}/{rounds} {:>12.1}",
            bytes as f64 / (1024.0 * 1024.0)
        );
    }
    println!("\nexpected (paper Fig A.3): p = 0.7 suffices to match SA at n=40; lower p degrades");
    Ok(())
}
