//! Fig 4.1 reproduction (E2): upper bounds on the reliability and privacy
//! failure probabilities at p = p*(n, q_total), for n = 100..1000 and
//! q_total ∈ {0, 0.01, 0.05, 0.1} — plus Monte-Carlo empirical rates
//! validating that the bounds hold (E9).
//!
//! ```bash
//! cargo run --release --example bounds_fig41
//! ```

use ccesa::analysis::bounds::{
    p_star, per_step_q, t_rule, theorem5_reliability_bound, theorem6_privacy_bound,
};
use ccesa::analysis::montecarlo::estimate_failure_rates;
use ccesa::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::new("bounds_fig41", "Fig 4.1: P_e bounds at p = p*")
        .flag("trials", Some("300"), "Monte-Carlo trials per point")
        .flag("csv", Some("results_fig41.csv"), "output CSV path")
        .switch("no-mc", "skip the Monte-Carlo validation columns")
        .parse();
    let trials: usize = args.req("trials");
    let run_mc = !args.get_bool("no-mc");
    let csv_path: String = args.req("csv");

    let mut csv =
        String::from("n,q_total,p_star,t,bound_rel,bound_priv,mc_rel,mc_priv\n");
    println!(
        "{:>6} {:>8} {:>8} {:>6} {:>12} {:>12} {:>10} {:>10}",
        "n", "q_total", "p*", "t", "P_e^r bound", "P_e^p bound", "mc rel", "mc priv"
    );
    for &q_total in &[0.0f64, 0.01, 0.05, 0.1] {
        for n in (100..=1000).step_by(100) {
            let p = p_star(n, q_total);
            let q = per_step_q(q_total);
            let t = t_rule(n, p);
            let b5 = theorem5_reliability_bound(n, p, q, t);
            let b6 = theorem6_privacy_bound(n, p, q);
            let (mc_r, mc_p) = if run_mc && n <= 500 {
                let est = estimate_failure_rates(n, p, q, t, trials, 99 + n as u64);
                (est.p_e_reliability, est.p_e_privacy)
            } else {
                (f64::NAN, f64::NAN)
            };
            println!(
                "{n:>6} {q_total:>8.2} {p:>8.3} {t:>6} {b5:>12.3e} {b6:>12.3e} {mc_r:>10.4} {mc_p:>10.4}"
            );
            csv.push_str(&format!(
                "{n},{q_total},{p:.6},{t},{b5:.6e},{b6:.6e},{mc_r},{mc_p}\n"
            ));
        }
    }
    std::fs::write(&csv_path, csv)?;
    println!("\nwrote {csv_path}");
    println!(
        "shape check (paper): P_e^p ≤ 1e-40 everywhere; P_e^r ≤ 1e-2; both decrease with n"
    );
    Ok(())
}
