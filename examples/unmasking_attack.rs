//! Appendix E / Proposition 1 (the t design rule): simulate the malicious
//! server's *unmasking attack* and show that Remark 4's
//! `t = ⌈((n−1)p + √((n−1)ln(n−1)) + 1)/2⌉` makes it infeasible, while
//! smaller t opens the attack as dropout tolerance grows.
//!
//! The attack: a malicious server requests shares of `b_i` from one set of
//! t live holders and shares of `s_i^SK` from a *disjoint* set of t
//! holders — possible iff client i has ≥ 2t live holders. With both
//! secrets the server strips every mask from θ̃_i and reads θ_i.
//!
//! ```bash
//! cargo run --release --example unmasking_attack -- --n 200
//! ```

use ccesa::analysis::bounds::{p_star, t_rule};
use ccesa::graph::Graph;
use ccesa::protocol::adversary::unmasking_attack_feasible;
use ccesa::util::cli::Args;
use ccesa::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::new("unmasking_attack", "Prop. 1: t rule vs the malicious server")
        .flag("n", Some("200"), "clients")
        .flag("trials", Some("50"), "graphs per t")
        .flag("seed", Some("17"), "seed")
        .parse();
    let n: usize = args.req("n");
    let trials: usize = args.req("trials");
    let seed: u64 = args.req("seed");

    let p = p_star(n, 0.0);
    let t_star = t_rule(n, p);
    println!("n={n} p*={p:.4} Remark-4 t = {t_star}\n");
    println!(
        "{:>6} {:>22} {:>18}",
        "t", "vulnerable clients (%)", "note"
    );
    // sweep t from permissive to the rule (and slightly above)
    let expected_degree = ((n - 1) as f64 * p) as usize;
    let ts: Vec<usize> = vec![
        2,
        expected_degree / 4,
        expected_degree / 2,
        t_star.saturating_sub(10),
        t_star,
        t_star + 10,
    ];
    for t in ts {
        if t < 1 {
            continue;
        }
        let mut vulnerable = 0usize;
        let mut total = 0usize;
        for trial in 0..trials {
            let mut rng = Rng::new(seed + trial as u64);
            let g = Graph::erdos_renyi(n, p, &mut rng);
            let v4: Vec<usize> = (0..n).collect(); // worst case: no dropout
            for i in 0..n {
                total += 1;
                if unmasking_attack_feasible(&g, &v4, t, i) {
                    vulnerable += 1;
                }
            }
        }
        let pct = 100.0 * vulnerable as f64 / total as f64;
        let note = if t == t_star {
            "← Remark 4"
        } else if pct > 50.0 {
            "broken"
        } else {
            ""
        };
        println!("{t:>6} {pct:>21.2}% {note:>18}");
    }
    println!(
        "\nexpected: ~100% of clients attackable for t ≪ (n−1)p/2; \
         ≈0% at the Remark-4 threshold (Prop. 1)."
    );
    Ok(())
}
