#!/usr/bin/env python3
"""Bench-trajectory regression gate.

Compares fresh ``BENCH_<target>.json`` reports (written by the bench
binaries via ``bench::json_sink``) against a committed baseline directory
and fails when any case's median regresses by more than the threshold.

Schema: every report is the ``Bench::to_json`` object —
``{"group": ..., "host_cores": ..., "default_threads": ...,
"results": [{"name": ..., "median_s": ..., ...}, ...]}``.
Cases are matched by ``name`` within the file of the same basename.

Usage:
    python3 tools/bench_gate.py                     # gate against BENCH_baseline/
    python3 tools/bench_gate.py --threshold 0.12    # explicit threshold
    python3 tools/bench_gate.py --update            # adopt fresh runs as baseline
    python3 tools/bench_gate.py BENCH_crypto_primitives.json  # gate a subset

Bootstrap: a fresh file (or case) with no committed baseline is reported
and skipped — commit the uploaded ``bench-json`` CI artifact into
``BENCH_baseline/`` (or run with ``--update`` on the reference machine) to
arm the gate for it. ``CCESA_BENCH_GATE_THRESHOLD`` overrides the default
threshold without touching CI configuration.

Exit status: 0 = no regressions, 1 = at least one regression, 2 = usage or
unreadable input.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import shutil
import sys

# Cases faster than this are dominated by timer/scheduler noise at the
# short CI measurement budget; they are reported but never gated.
DEFAULT_NOISE_FLOOR_S = 2e-5


def load_report(path):
    with open(path) as f:
        doc = json.load(f)
    if "results" not in doc or "group" not in doc:
        raise ValueError(f"{path}: not a Bench::to_json report (missing group/results)")
    cases = {}
    for row in doc["results"]:
        cases[row["name"]] = (float(row["median_s"]), int(row.get("iters", 1)))
    return doc["group"], cases


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh", nargs="*", help="fresh BENCH_*.json files (default: glob cwd)")
    ap.add_argument("--baseline", default="BENCH_baseline", help="committed baseline directory")
    ap.add_argument(
        "--threshold",
        type=float,
        default=float(os.environ.get("CCESA_BENCH_GATE_THRESHOLD", "0.12")),
        help="fail when fresh_median > baseline_median * (1 + threshold); default 0.12",
    )
    ap.add_argument(
        "--noise-floor",
        type=float,
        default=DEFAULT_NOISE_FLOOR_S,
        help=f"skip cases with baseline median below this (s); default {DEFAULT_NOISE_FLOOR_S}",
    )
    ap.add_argument("--update", action="store_true", help="copy fresh reports into the baseline")
    ap.add_argument(
        "--strict",
        action="store_true",
        help="fail on coverage gaps too (missing baselines, renamed/removed cases)",
    )
    ap.add_argument(
        "--strict-if-armed",
        action="store_true",
        help="behave like --strict once the baseline directory holds at least one "
        "BENCH_*.json (bootstrap stays lenient; an armed gate refuses coverage gaps)",
    )
    args = ap.parse_args()

    if args.strict_if_armed and not args.strict:
        armed = os.path.isdir(args.baseline) and any(
            f.startswith("BENCH_") and f.endswith(".json")
            for f in os.listdir(args.baseline)
        )
        if armed:
            args.strict = True
            print("bench_gate: baselines present — strict mode armed")

    fresh_paths = args.fresh or sorted(glob.glob("BENCH_*.json"))
    if not fresh_paths:
        print("bench_gate: no BENCH_*.json files found — run the bench targets first")
        return 2

    if args.update:
        os.makedirs(args.baseline, exist_ok=True)
        for path in fresh_paths:
            dst = os.path.join(args.baseline, os.path.basename(path))
            shutil.copyfile(path, dst)
            print(f"bench_gate: baseline updated: {dst}")
        return 0

    regressions = []
    improvements = 0
    gated = 0
    skipped = []
    coverage_gaps = []
    seen_basenames = set()
    for path in fresh_paths:
        try:
            group, fresh = load_report(path)
        except (OSError, ValueError, KeyError) as e:
            print(f"bench_gate: cannot read {path}: {e}")
            return 2
        seen_basenames.add(os.path.basename(path))
        base_path = os.path.join(args.baseline, os.path.basename(path))
        if not os.path.exists(base_path):
            coverage_gaps.append(
                f"{path}: no committed baseline ({base_path}) — bootstrap pending"
            )
            continue
        try:
            _, base = load_report(base_path)
        except (OSError, ValueError, KeyError) as e:
            print(f"bench_gate: cannot read baseline {base_path}: {e}")
            return 2
        # a baseline case the fresh run no longer reports is a rename or a
        # removed case: a regression could hide behind it, so surface it
        for name in sorted(set(base) - set(fresh)):
            coverage_gaps.append(
                f"{group} / {name}: in baseline but not in fresh run (renamed/removed?)"
            )
        for name, (fresh_med, fresh_iters) in sorted(fresh.items()):
            if name not in base:
                coverage_gaps.append(f"{group} / {name}: new case, no baseline median")
                continue
            base_med, base_iters = base[name]
            if base_med < args.noise_floor:
                skipped.append(
                    f"{group} / {name}: baseline {base_med:.3g}s below noise floor"
                )
                continue
            if base_iters < 2 or fresh_iters < 2:
                # a single sample on either side (table-style targets, or a
                # case so slow the CI budget allowed one cold-start
                # iteration) is not a median; report, don't gate
                which = "baseline" if base_iters < 2 else "fresh run"
                skipped.append(f"{group} / {name}: single-sample {which}, not gated")
                continue
            gated += 1
            ratio = fresh_med / base_med
            line = f"{group} / {name}: {base_med:.6g}s -> {fresh_med:.6g}s ({ratio:.2f}x)"
            if ratio > 1.0 + args.threshold:
                regressions.append(line)
                print(f"REGRESSION  {line}")
            else:
                if ratio < 1.0:
                    improvements += 1
                print(f"ok          {line}")

    # committed baseline files whose target produced no fresh report at all
    # (target deleted, or dropped out of the CI sweep)
    if os.path.isdir(args.baseline):
        for fname in sorted(os.listdir(args.baseline)):
            if fname.startswith("BENCH_") and fname.endswith(".json"):
                if fname not in seen_basenames:
                    coverage_gaps.append(
                        f"{args.baseline}/{fname}: baseline has no fresh report — "
                        "target removed or missing from the sweep"
                    )

    for line in skipped:
        print(f"skipped     {line}")
    for line in coverage_gaps:
        print(f"coverage    {line}")
    print(
        f"bench_gate: {gated} cases gated at +{args.threshold:.0%}, "
        f"{len(regressions)} regressions, {improvements} improvements, "
        f"{len(skipped)} skipped, {len(coverage_gaps)} coverage gaps"
    )
    if regressions:
        print("bench_gate: FAIL — medians regressed beyond the threshold:")
        for line in regressions:
            print(f"  {line}")
        return 1
    if args.strict and coverage_gaps:
        print("bench_gate: FAIL (--strict) — coverage gaps listed above")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
