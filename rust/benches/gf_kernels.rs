//! The kernels layer at the Step-3 scale (dim = 2^17): GF(2^16) slice
//! multiply / multiply-accumulate per backend, a batched Lagrange Step-3
//! shape (t weights over one concatenated group slice), and fused-vs-
//! sequential multi-seed mask application.
//!
//! Always emits `BENCH_gf_kernels.json` (override with `--json PATH` or
//! `CCESA_BENCH_JSON`); the report's `kernel_backend` field names the
//! dispatched backend, and the per-case names carry the explicit backend
//! of each row, so the acceptance comparison (vector backend ≥2× the
//! scalar rows on a clmul-capable runner) reads straight off one file.

use ccesa::bench::{black_box, Bench};
use ccesa::crypto::prg::{NONCE_PAIRWISE, NONCE_SELF};
use ccesa::kernels::{self, Backend, MaskStream};
use ccesa::util::rng::Rng;

const DIM: usize = 1 << 17;
const BITS: u32 = 32;
/// Lagrange weights in the Step-3 shape row (t at the paper's n=128 scale).
const T: usize = 64;

fn main() {
    let mut b = Bench::new("gf_kernels");
    let mut rng = Rng::new(0x6F16);

    let src: Vec<u16> = (0..DIM).map(|_| rng.next_u32() as u16).collect();
    let mut acc: Vec<u16> = (0..DIM).map(|_| rng.next_u32() as u16).collect();
    let w = 0xA53B;

    // Sanity: every available backend is bit-identical to scalar before
    // anything is timed (a diverging lane must fail loudly, not get
    // benchmarked).
    for &bk in &kernels::available_backends() {
        let mut got = src.clone();
        kernels::gf_mul_slice_const_with(bk, &mut got, w);
        let mut oracle = src.clone();
        kernels::gf_mul_slice_const_with(Backend::Scalar, &mut oracle, w);
        assert_eq!(got, oracle, "{bk:?} diverged from scalar");
    }

    for &bk in &kernels::available_backends() {
        b.throughput(
            &format!("gf_mul_slice dim={DIM} backend={}", bk.name()),
            DIM as f64,
            "elem/s",
            || {
                kernels::gf_mul_slice_const_with(bk, &mut acc, w);
                black_box(acc[0]);
            },
        );
        b.throughput(
            &format!("gf_fma_slice dim={DIM} backend={}", bk.name()),
            DIM as f64,
            "elem/s",
            || {
                kernels::gf_fma_slice_with(bk, &mut acc, &src, w);
                black_box(acc[0]);
            },
        );
        // reconstruct_batch Step-3 shape: t weight applications over one
        // concatenated m·owners slice
        b.throughput(
            &format!("step3 fma t={T} dim={DIM} backend={}", bk.name()),
            (T * DIM) as f64,
            "elem/s",
            || {
                for i in 0..T {
                    kernels::gf_fma_slice_with(bk, &mut acc, &src, 0xA001 ^ (i as u16));
                }
                black_box(acc[0]);
            },
        );
    }

    // Fused vs sequential multi-seed mask application (backend-independent:
    // the win is keystream-major accumulator blocking).
    let mut acc64: Vec<u64> = (0..DIM as u64).map(|i| (i * 2654435761) & 0xFFFF_FFFF).collect();
    for seeds in [2usize, 5, 9] {
        let streams: Vec<MaskStream> = (0..seeds)
            .map(|k| MaskStream {
                seed: [k as u8 + 1; 32],
                nonce: if k == 0 { NONCE_SELF } else { NONCE_PAIRWISE },
                negate: k % 2 == 1,
            })
            .collect();
        b.throughput(
            &format!("apply_masks seeds={seeds} dim={DIM} sequential"),
            (seeds * DIM * 8) as f64,
            "B/s",
            || {
                for s in &streams {
                    kernels::apply_mask_stream(&mut acc64, &s.seed, &s.nonce, BITS, s.negate, 0);
                }
                black_box(acc64[0]);
            },
        );
        b.throughput(
            &format!("apply_masks seeds={seeds} dim={DIM} fused"),
            (seeds * DIM * 8) as f64,
            "B/s",
            || {
                kernels::apply_masks_fused(&mut acc64, &streams, BITS, 0);
                black_box(acc64[0]);
            },
        );
    }

    b.report();
    // cargo runs bench binaries with cwd = the package root (rust/);
    // anchor the default artifact at the workspace root so CI and humans
    // find it where the repo documents it.
    b.write_report_to_sink(concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_gf_kernels.json"));
}
