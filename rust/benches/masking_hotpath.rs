//! The Step-2 / unmasking hot path at production scale (E-perf): PRG mask
//! expansion + wrapping adds at m = 10^6 (the paper's running example) and
//! at the E2E model size, plus quantize/dequantize throughput.
//!
//! §Perf target: apply_mask at memory-bandwidth-limited rate — ChaCha20
//! generation dominates, so the keystream rate is the roofline.

use ccesa::bench::{black_box, Bench};
use ccesa::crypto::prg::{apply_mask, expand_masks, NONCE_PAIRWISE};
use ccesa::masking::{add_assign, Quantizer};
use ccesa::util::rng::Rng;

fn main() {
    let mut b = Bench::new("masking_hotpath");
    let seed = [0xA5u8; 32];

    for &m in &[10_000usize, 100_000, 1_000_000] {
        let mut acc = vec![0u64; m];
        b.throughput(
            &format!("apply_mask m={m} b=32 (fused)"),
            (m * 4) as f64,
            "B/s",
            || {
                apply_mask(&mut acc, &seed, &NONCE_PAIRWISE, 32, false);
                black_box(acc[0]);
            },
        );
    }

    // unfused baseline: expand then add (what the naive Eq.-3 code does)
    let m = 1_000_000;
    let mut acc = vec![0u64; m];
    let mut mask = vec![0u64; m];
    b.throughput("expand+add m=1e6 b=32 (unfused)", (m * 4) as f64, "B/s", || {
        expand_masks(&seed, &NONCE_PAIRWISE, 32, &mut mask);
        add_assign(&mut acc, &mask, 32);
        black_box(acc[0]);
    });

    // 16-bit domain (Table 5.1's field)
    let mut acc16 = vec![0u64; m];
    b.throughput("apply_mask m=1e6 b=16", (m * 2) as f64, "B/s", || {
        apply_mask(&mut acc16, &seed, &NONCE_PAIRWISE, 16, false);
        black_box(acc16[0]);
    });

    // quantizer
    let mut rng = Rng::new(3);
    let xs: Vec<f32> = (0..m).map(|_| rng.normal_f32(0.0, 0.5)).collect();
    let q = Quantizer::for_sum_of(32, 4.0, 100);
    b.throughput("quantize m=1e6", m as f64, "elem/s", || {
        black_box(q.quantize(&xs));
    });
    let words = q.quantize(&xs);
    b.throughput("dequantize m=1e6", m as f64, "elem/s", || {
        black_box(q.dequantize(&words));
    });

    // server-side aggregation of 64 masked vectors (cf. the masked_sum
    // HLO kernel benched in round_latency)
    let vecs: Vec<Vec<u64>> = (0..64)
        .map(|i| (0..10_000).map(|j| (i * j) as u64 & 0xFFFF_FFFF).collect())
        .collect();
    let mut agg = vec![0u64; 10_000];
    b.throughput(
        "server sum 64 x m=1e4 (rust)",
        (64 * 10_000 * 4) as f64,
        "B/s",
        || {
            agg.fill(0);
            for v in &vecs {
                add_assign(&mut agg, v, 32);
            }
            black_box(agg[0]);
        },
    );

    b.report();
    b.write_report_to_sink(concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_masking_hotpath.json"));
}
