//! Flat vs hierarchical round latency. The two-level topology trades one
//! extra (small) root round for shard-local graphs whose pairwise setup
//! cost no longer scales with the full population — the regime the flat
//! protocol cannot reach at all: a single-level round over n = 10⁶ clients
//! would need pairwise key agreement across the whole population and never
//! finishes. The 10⁶ campaign row is therefore hier-only and env-gated
//! (`CCESA_BENCH_HIER_SCALE=1`, release, run by the scale CI job).

use ccesa::analysis::bounds::{p_star, t_rule};
use ccesa::bench::{black_box, Bench};
use ccesa::coordinator::Executor;
use ccesa::hier::{HierOptions, HierRunner};
use ccesa::protocol::engine::run_round;
use ccesa::protocol::{ProtocolConfig, Topology};
use ccesa::util::rng::Rng;

fn models_for(n: usize, dim: usize, seed: u64) -> Vec<Vec<u64>> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect()).collect()
}

fn hier_cfg(n: usize, shards: usize, dim: usize) -> ProtocolConfig {
    // p and t are governed by the shard size, not the population: that is
    // the whole point of the two-level topology.
    let m = n / shards;
    let p = p_star(m, 0.0);
    let t = t_rule(m, p).min(m.saturating_sub(1)).max(1);
    ProtocolConfig::builder()
        .clients(n)
        .threshold(t)
        .model_dim(dim)
        .topology(Topology::Hierarchical {
            shards,
            intra: Box::new(Topology::ErdosRenyi { p }),
            root: Box::new(Topology::Complete),
        })
        .seed(4)
        .build()
        .unwrap()
}

fn bench_runner() -> HierRunner {
    // Theorem-1 audits and the plaintext truth pass are sim concerns;
    // the bench measures the protocol path alone.
    HierRunner::new(HierOptions {
        executor: Executor::EventLoop,
        check_theorem1: false,
        check_truth: false,
        ..HierOptions::default()
    })
}

fn main() {
    let mut b = Bench::new("hier_round");

    // Flat-vs-hier at populations both can complete: the same clients, the
    // same dense payload, one level vs two.
    for &(n, shards, dim) in &[(200usize, 4usize, 2_000usize), (600, 12, 1_000)] {
        let models = models_for(n, dim, 9);
        let p = p_star(n, 0.0);
        let flat_cfg = ProtocolConfig::builder()
            .clients(n)
            .threshold(t_rule(n, p))
            .model_dim(dim)
            .topology(Topology::ErdosRenyi { p })
            .seed(4)
            .build()
            .unwrap();
        b.bench(&format!("flat n={n} dim={dim}"), || {
            black_box(run_round(&flat_cfg, &models).unwrap());
        });
        let cfg = hier_cfg(n, shards, dim);
        let runner = bench_runner();
        b.bench(&format!("hier n={n} shards={shards} dim={dim}"), || {
            let r = runner.run(&cfg, &models).unwrap();
            assert!(r.reliable, "bench round must be reliable");
            black_box(r.sum);
        });
    }

    // The campaign row: n = 10⁶ clients in 100 shards of 10⁴. Flat CCESA
    // (let alone complete-graph SA) cannot complete this row — there is no
    // flat baseline to record. Inside each shard, p* would dictate mean
    // degree ≈ 0.25·m (about 124M X25519 agreements across the population),
    // so the scale row fixes a sparse degree-8 graph with t = 3 instead:
    // ~4M edge agreements total, with the ~1.4% of members whose
    // neighborhood falls below t simply withdrawing at step 1. Gated: ~GBs
    // of model state and a minutes-long round; the scale CI job opts in.
    if std::env::var("CCESA_BENCH_HIER_SCALE").ok().as_deref() == Some("1") {
        let (n, shards, dim) = (1_000_000usize, 100usize, 64usize);
        let m = n / shards;
        eprintln!("generating {n}x{dim} models…");
        let models = models_for(n, dim, 9);
        let cfg = ProtocolConfig::builder()
            .clients(n)
            .threshold(3)
            .model_dim(dim)
            .topology(Topology::Hierarchical {
                shards,
                intra: Box::new(Topology::ErdosRenyi { p: 8.0 / (m - 1) as f64 }),
                root: Box::new(Topology::Complete),
            })
            .seed(4)
            .build()
            .unwrap();
        let runner = bench_runner();
        b.throughput(&format!("hier n=1e6 shards={shards} dim={dim}"), n as f64, "clients/s", || {
            let r = runner.run(&cfg, &models).unwrap();
            assert!(r.reliable, "scale round must be reliable");
            black_box(r.global_v3.len());
        });
    } else {
        eprintln!("skipping n=10^6 hier row: set CCESA_BENCH_HIER_SCALE=1 (scale CI)");
    }

    b.report();
    b.write_report_to_sink(concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hier.json"));
}
