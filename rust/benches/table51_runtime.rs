//! Table 5.1 reproduction (E3): per-step running time of SA vs CCESA.
//!
//! Mirrors the paper's setup: m = 10000 model elements in F_{2^16},
//! n ∈ {100, 300 (500 with CCESA_BENCH_FULL=1)}, q_total ∈ {0, 0.1};
//! t per the paper (SA: n/2+1, CCESA: Remark 4), p = p*(n, q_total).
//! Reports mean per-client milliseconds for Steps 0–3 and total server
//! time — the paper's claim is the CCESA/SA ratio ≈ p.

use ccesa::analysis::bounds::{p_star, t_rule};
use ccesa::bench::{Bench, BenchResult};
use ccesa::protocol::dropout::DropoutModel;
use ccesa::protocol::engine::run_round;
use ccesa::protocol::{ProtocolConfig, Topology};
use ccesa::util::rng::Rng;
use ccesa::util::stats::Summary;
use std::time::Instant;

fn main() {
    let full = std::env::var("CCESA_BENCH_FULL").ok().as_deref() == Some("1");
    let mut b = Bench::new("table51_runtime");
    let ns: &[usize] = if full { &[100, 300, 500] } else { &[100, 300] };
    let dim = 10_000;
    let mask_bits = 16;

    println!("== Table 5.1: running time (ms), m={dim}, field 2^16 ==");
    println!(
        "{:<6} {:>5} {:>7} {:>5} {:>7} | {:>9} {:>9} {:>9} {:>9} | {:>9} | {:>9}",
        "scheme", "n", "q_tot", "t", "p", "step0", "step1", "step2", "step3", "client Σ", "server"
    );

    let mut ratios: Vec<f64> = Vec::new();
    for &n in ns {
        for &q_total in &[0.0, 0.1] {
            let mut rng = Rng::new(0x51);
            let models: Vec<Vec<u64>> = (0..n)
                .map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF).collect())
                .collect();
            let mut row = |scheme: &str, topology: Topology, t: usize, p_label: f64| -> f64 {
                let cfg = ProtocolConfig::builder()
                    .clients(n)
                    .threshold(t)
                    .model_dim(dim)
                    .mask_bits(mask_bits)
                    .topology(topology)
                    .dropout(if q_total > 0.0 {
                        DropoutModel::iid_from_total(q_total)
                    } else {
                        DropoutModel::None
                    })
                    .seed(0xBE7C + n as u64)
                    .build()
                    .expect("bench config");
                let t0 = Instant::now();
                let r = run_round(&cfg, &models).expect("round");
                // one wall-clock sample per configuration into the standard
                // bench schema (one full round per table row — no
                // iteration loop to hand to Bench::bench)
                b.results.push(BenchResult {
                    name: format!("{scheme} round n={n} q={q_total}"),
                    iters: 1,
                    summary: Summary::of(&[t0.elapsed().as_secs_f64()]),
                    throughput_label: None,
                });
                let per_client = |name: &str| {
                    // engine buckets aggregate all clients; report mean/client
                    r.times.total_ms(name) / n as f64
                };
                let c0 = per_client("client_step0");
                let c1 = per_client("client_step1");
                let c2 = per_client("client_step2");
                let c3 = per_client("client_step3");
                let server = r.times.total_ms("server_step0")
                    + r.times.total_ms("server_step1")
                    + r.times.total_ms("server_step2")
                    + r.times.total_ms("server_finalize");
                let client_total = c0 + c1 + c2 + c3;
                println!(
                    "{scheme:<6} {n:>5} {q_total:>7.2} {t:>5} {p_label:>7.3} | {c0:>9.3} {c1:>9.3} {c2:>9.3} {c3:>9.3} | {client_total:>9.3} | {server:>9.1}",
                );
                client_total
            };
            let sa_t = n / 2 + 1;
            let sa_total = row("SA", Topology::Complete, sa_t, 1.0);
            let p = p_star(n, q_total);
            let cc_t = t_rule(n, p);
            let cc_total = row("CCESA", Topology::ErdosRenyi { p }, cc_t, p);
            let ratio = cc_total / sa_total;
            println!(
                "       -> CCESA/SA client-time ratio = {ratio:.3} (paper predicts ≈ p = {p:.3})"
            );
            ratios.push(ratio / p);
        }
    }
    let mean_rel = ratios.iter().sum::<f64>() / ratios.len() as f64;
    println!(
        "\nmean (measured ratio)/(predicted p) = {mean_rel:.2} — 1.0 is a perfect Table 5.1 match"
    );

    b.write_report_to_sink(concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_table51_runtime.json"));
}
