//! Crypto-substrate microbenchmarks — the §Perf instrument for L3 hot
//! paths: ChaCha20 keystream (the PRG), SHA-256/HKDF (key derivation),
//! x25519 (key agreement), AEAD (share encryption), GF(2^16) and Shamir
//! (share generation / reconstruction at Table-5.1 scales).

use ccesa::bench::{black_box, Bench};
use ccesa::crypto::{aead, chacha20::ChaCha20, dh, hkdf, prg, sha256};
use ccesa::shamir;
use ccesa::util::rng::Rng;

fn main() {
    let mut b = Bench::new("crypto_primitives");

    // ChaCha20 raw block throughput — the PRG inner loop
    let cipher = ChaCha20::new(&[7u8; 32], &[1u8; 12]);
    let mut block = [0u32; 16];
    b.throughput("chacha20 block (64B)", 64.0, "B/s", || {
        cipher.block_words(black_box(1), &mut block);
        black_box(block[0]);
    });

    // PRG mask expansion at the paper's m=10^4 and the E2E m≈5·10^4
    for &m in &[10_000usize, 52_000] {
        let mut acc = vec![0u64; m];
        let seed = [9u8; 32];
        b.throughput(
            &format!("prg apply_mask m={m} (b=32)"),
            (m * 4) as f64,
            "B/s",
            || {
                prg::apply_mask(&mut acc, &seed, &prg::NONCE_PAIRWISE, 32, false);
                black_box(acc[0]);
            },
        );
    }

    // SHA-256 / HKDF
    let data = vec![0xABu8; 1024];
    b.throughput("sha256 1KiB", 1024.0, "B/s", || {
        black_box(sha256::sha256(&data));
    });
    b.bench("hkdf32 (extract+expand)", || {
        black_box(hkdf::hkdf32(b"salt", &data[..32], b"info"));
    });

    // x25519: keygen + agreement — Step 0/2 cost per neighbor
    let mut rng = Rng::new(1);
    let alice = dh::KeyPair::generate(&mut rng);
    let bob = dh::KeyPair::generate(&mut rng);
    b.bench("x25519 key agreement", || {
        black_box(dh::agree_mask_seed(&alice.sk, &bob.pk));
    });

    // AEAD seal/open of one share pair (the Step-1 payload)
    let key = [3u8; 32];
    let nonce = [4u8; 12];
    let pt = vec![0x5Au8; 70];
    let ct = aead::seal(&key, &nonce, b"aad", &pt);
    b.bench("aead seal 70B share pair", || {
        black_box(aead::seal(&key, &nonce, b"aad", &pt));
    });
    b.bench("aead open 70B share pair", || {
        black_box(aead::open(&key, &nonce, b"aad", &ct).unwrap());
    });

    // Shamir at Table-5.1 scale: n=100 holders, t=51
    let secret = [0xC5u8; 32];
    let points: Vec<u16> = (1..=100).collect();
    let mut srng = Rng::new(2);
    b.bench("shamir split 32B t=51 n=100", || {
        black_box(shamir::split(&secret, 51, &points, &mut srng).unwrap());
    });
    let shares = shamir::split(&secret, 51, &points, &mut srng).unwrap();
    b.bench("shamir reconstruct t=51", || {
        black_box(shamir::reconstruct(&shares[..51], 51, 32).unwrap());
    });

    b.report();
    b.write_report_to_sink(concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_crypto_primitives.json"));
}
