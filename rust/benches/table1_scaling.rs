//! Table 1 reproduction (E1): measured communication and computation vs n
//! for CCESA / SA / FedAvg, with log–log exponent fits against the paper's
//! asymptotic columns.
//!
//! Client comm:  CCESA O(√(n log n)+m)  SA O(n+m)   FedAvg O(m)
//! Server comm:  CCESA O(n√(n log n)+mn) SA O(n²+mn) FedAvg O(mn)
//! Client time:  CCESA ≈ p·SA           SA O(n²+mn)

use ccesa::analysis::bounds::{p_star, t_rule};
use ccesa::bench::{Bench, BenchResult};
use ccesa::protocol::engine::run_round;
use ccesa::protocol::{ProtocolConfig, Topology};
use ccesa::util::rng::Rng;
use ccesa::util::stats::{power_law_exponent, Summary};
use std::time::Instant;

fn main() {
    let full = std::env::var("CCESA_BENCH_FULL").ok().as_deref() == Some("1");
    let mut b = Bench::new("table1_scaling");
    let ns: Vec<usize> = if full {
        vec![50, 100, 200, 400, 800]
    } else {
        vec![50, 100, 200, 400]
    };
    let dim = 2_000; // keep the m-term visible but not dominant

    println!("== Table 1: measured scaling vs n (dim={dim}) ==");
    println!(
        "{:>5} {:>7} | {:>12} {:>12} | {:>12} {:>12} | {:>10} {:>10}",
        "n", "p*", "cl B ccesa", "cl B sa", "sv B ccesa", "sv B sa", "cl ms cc", "cl ms sa"
    );

    let mut rows: Vec<(f64, [f64; 6])> = Vec::new();
    for &n in &ns {
        let mut rng = Rng::new(1);
        let models: Vec<Vec<u64>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect())
            .collect();
        let p = p_star(n, 0.0);
        let mk = |t: usize, topology: Topology| {
            ProtocolConfig::builder()
                .clients(n)
                .threshold(t)
                .model_dim(dim)
                .topology(topology)
                .seed(7)
                .build()
                .unwrap()
        };
        let t0 = Instant::now();
        let cc = run_round(&mk(t_rule(n, p), Topology::ErdosRenyi { p }), &models)
            .expect("ccesa round");
        let cc_s = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let sa = run_round(&mk(n / 2 + 1, Topology::Complete), &models).expect("sa round");
        let sa_s = t0.elapsed().as_secs_f64();
        // one wall-clock sample per round into the standard bench schema
        // (this target measures one full round per configuration — it has
        // no iteration loop to hand to Bench::bench)
        for (scheme, secs) in [("ccesa", cc_s), ("sa", sa_s)] {
            b.results.push(BenchResult {
                name: format!("round n={n} {scheme} (dim={dim})"),
                iters: 1,
                summary: Summary::of(&[secs]),
                throughput_label: None,
            });
        }
        let model_bytes = (dim * 4) as f64;
        let cl_cc = cc.stats.mean_client_total() - model_bytes;
        let cl_sa = sa.stats.mean_client_total() - model_bytes;
        let sv_cc = cc.stats.server_total() as f64;
        let sv_sa = sa.stats.server_total() as f64;
        let t_cc: f64 = ["client_step0", "client_step1", "client_step2", "client_step3"]
            .iter()
            .map(|s| cc.times.total_ms(s))
            .sum::<f64>()
            / n as f64;
        let t_sa: f64 = ["client_step0", "client_step1", "client_step2", "client_step3"]
            .iter()
            .map(|s| sa.times.total_ms(s))
            .sum::<f64>()
            / n as f64;
        println!(
            "{n:>5} {p:>7.3} | {cl_cc:>12.0} {cl_sa:>12.0} | {sv_cc:>12.0} {sv_sa:>12.0} | {t_cc:>10.3} {t_sa:>10.3}"
        );
        rows.push((n as f64, [cl_cc, cl_sa, sv_cc, sv_sa, t_cc, t_sa]));
    }

    let xs: Vec<f64> = rows.iter().map(|(n, _)| *n).collect();
    let col = |i: usize| -> Vec<f64> { rows.iter().map(|(_, r)| r[i]).collect() };
    let fits = [
        ("client extra bytes CCESA", 0, "≈0.6 (√(n log n))"),
        ("client extra bytes SA", 1, "≈1.0 (n)"),
        ("server bytes CCESA", 2, "1.0–1.6 (n√(n log n)+mn)"),
        ("server bytes SA", 3, "1.0–2.0 (n²+mn)"),
    ];
    println!("\nlog–log exponent fits (paper's asymptotic column in parens):");
    for (name, i, expect) in fits {
        let (k, r2) = power_law_exponent(&xs, &col(i));
        println!("  {name:<28} n^{k:.2}  (r²={r2:.3}; paper {expect})");
    }
    let (k_tcc, _) = power_law_exponent(&xs, &col(4));
    let (k_tsa, _) = power_law_exponent(&xs, &col(5));
    println!("  client time CCESA            n^{k_tcc:.2}   vs SA n^{k_tsa:.2} (CCESA flatter)");

    b.write_report_to_sink(concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_table1_scaling.json"));
}
