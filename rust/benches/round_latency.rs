//! End-to-end round latency vs n (E-perf / Table 5.1 aggregate), the
//! event-loop deployment shape vs the sync engine (untimed and under the
//! virtual-clock scheduler), the sparse payload codecs vs dense,
//! cold-start vs steady-state session rounds, and the PJRT masked_sum
//! kernel vs the pure-Rust server aggregation.

use ccesa::analysis::bounds::{p_star, t_rule};
use ccesa::bench::{black_box, Bench};
use ccesa::codec::Codec;
use ccesa::coordinator::{RoundOptions, RoundRunner, TimeoutPolicy};
use ccesa::protocol::engine::run_round;
use ccesa::sim::clock::{clock_seed, ClockSpec, LatencyModel};
use ccesa::protocol::session::Session;
use ccesa::protocol::{ProtocolConfig, Topology};
use ccesa::runtime::{to_u32, Input, Manifest, Runtime};
use ccesa::util::rng::Rng;

fn cfg(n: usize, t: usize, dim: usize, topology: Topology, codec: Codec) -> ProtocolConfig {
    ProtocolConfig::builder()
        .clients(n)
        .threshold(t)
        .model_dim(dim)
        .topology(topology)
        .codec(codec)
        .seed(4)
        .build()
        .unwrap()
}

fn main() {
    let mut b = Bench::new("round_latency");
    let dim = 10_000;

    for &n in &[50usize, 100, 200] {
        let mut rng = Rng::new(9);
        let models: Vec<Vec<u64>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect())
            .collect();
        let p = p_star(n, 0.0);
        let cc_cfg = cfg(n, t_rule(n, p), dim, Topology::ErdosRenyi { p }, Codec::Dense);
        let sa_cfg = cfg(n, n / 2 + 1, dim, Topology::Complete, Codec::Dense);
        b.bench(&format!("round n={n} CCESA(p*) sync"), || {
            black_box(run_round(&cc_cfg, &models).unwrap());
        });
        b.bench(&format!("round n={n} SA sync"), || {
            black_box(run_round(&sa_cfg, &models).unwrap());
        });
        if n == 100 {
            let runner = RoundRunner::new(RoundOptions::default());
            b.bench(&format!("round n={n} CCESA(p*) event-loop"), || {
                black_box(runner.run(&cc_cfg, &models).unwrap());
            });
            // the virtual clock's scheduling overhead next to the untimed
            // loop: same round under a materialized latency schedule with a
            // generous (never-dropping) phase deadline
            let sched = std::sync::Arc::new(
                ClockSpec {
                    link: LatencyModel::Uniform { lo_us: 50, hi_us: 5_000 },
                    compute_us: (10, 200),
                }
                .materialize(n, clock_seed(cc_cfg.seed, 0)),
            );
            let clocked = RoundRunner::new(
                RoundOptions::builder()
                    .clock(sched)
                    .timeout_policy(TimeoutPolicy::uniform(
                        std::time::Duration::from_secs(10),
                    ))
                    .build()
                    .unwrap(),
            );
            b.bench(&format!("round n={n} CCESA(p*) clocked event-loop"), || {
                black_box(clocked.run(&cc_cfg, &models).unwrap());
            });
            // sparse payload at k = dim/10: Step 2 masks and the server
            // accumulator shrink 10×
            let topk_cfg =
                cfg(n, t_rule(n, p), dim, Topology::ErdosRenyi { p }, Codec::TopK { k: dim / 10 });
            b.bench(&format!("round n={n} CCESA(p*) topk10%"), || {
                black_box(run_round(&topk_cfg, &models).unwrap());
            });

            // cross-round sessions: cold start (full key agreement + AEAD
            // share dealing) vs a steady-state warm round (cached channel
            // secrets, ratcheted seeds, bitmap handshake)
            b.bench(&format!("session n={n} cold-start"), || {
                black_box(Session::establish(&cc_cfg, &models).unwrap());
            });
            let (mut session, cold_result) = Session::establish(&cc_cfg, &models).unwrap();
            let active = vec![true; n];
            let opts = RoundOptions::default();
            let mut warm_stats = None;
            b.bench(&format!("session n={n} steady-state"), || {
                let r = session.run_round(&models, &active, &opts).unwrap();
                warm_stats.get_or_insert(r.stats.clone());
                black_box(r.reliable);
            });
            if let Some(warm) = &warm_stats {
                // the amortization ledger next to the latency rows: the CI
                // session campaign asserts the < 30% bound; here it is
                // printed with the report for the human reading it
                eprintln!(
                    "session n={n}: setup bytes cold={} warm={} ({:.1}%)",
                    cold_result.stats.setup_bytes(),
                    warm.setup_bytes(),
                    warm.setup_bytes() as f64 / cold_result.stats.setup_bytes().max(1) as f64
                        * 100.0,
                );
            }
        }
    }

    // PJRT masked_sum kernel vs rust loop at the AOT shape
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        let rt = Runtime::cpu(&dir).expect("pjrt");
        let exe = rt.load("masked_sum").expect("masked_sum artifact");
        let (clients, m) = rt.manifest.agg_dims();
        let mut rng = Rng::new(11);
        let stacked: Vec<u32> = (0..clients * m).map(|_| rng.next_u32()).collect();
        b.throughput(
            &format!("masked_sum HLO {clients}x{m}"),
            (clients * m * 4) as f64,
            "B/s",
            || {
                let outs = exe
                    .run(&[Input::U32(stacked.clone(), vec![clients as i64, m as i64])])
                    .unwrap();
                black_box(to_u32(&outs[0]).unwrap());
            },
        );
        b.throughput(
            &format!("masked_sum rust {clients}x{m}"),
            (clients * m * 4) as f64,
            "B/s",
            || {
                let mut acc = vec![0u32; m];
                for c in 0..clients {
                    let row = &stacked[c * m..(c + 1) * m];
                    for (a, x) in acc.iter_mut().zip(row) {
                        *a = a.wrapping_add(*x);
                    }
                }
                black_box(acc[0]);
            },
        );
    } else {
        eprintln!("skipping PJRT kernel bench: artifacts not built");
    }

    b.report();
    b.write_report_to_sink(concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_round_latency.json"));
}
