//! End-to-end round latency vs n (E-perf / Table 5.1 aggregate), the
//! deployment shapes (thread-per-client, worker-pool event loop) vs the
//! sync engine, and the PJRT masked_sum kernel vs the pure-Rust server
//! aggregation.

use ccesa::analysis::bounds::{p_star, t_rule};
use ccesa::bench::{black_box, Bench};
use ccesa::coordinator::{run_round_event_loop, run_round_threaded};
use ccesa::protocol::engine::run_round;
use ccesa::protocol::{ProtocolConfig, Topology};
use ccesa::runtime::{to_u32, Input, Manifest, Runtime};
use ccesa::util::rng::Rng;

fn main() {
    let mut b = Bench::new("round_latency");
    let dim = 10_000;

    for &n in &[50usize, 100, 200] {
        let mut rng = Rng::new(9);
        let models: Vec<Vec<u64>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect())
            .collect();
        let p = p_star(n, 0.0);
        let cc_cfg = ProtocolConfig::new(n, t_rule(n, p), dim, Topology::ErdosRenyi { p }, 4);
        let sa_cfg = ProtocolConfig::new(n, n / 2 + 1, dim, Topology::Complete, 4);
        b.bench(&format!("round n={n} CCESA(p*) sync"), || {
            black_box(run_round(&cc_cfg, &models).unwrap());
        });
        b.bench(&format!("round n={n} SA sync"), || {
            black_box(run_round(&sa_cfg, &models).unwrap());
        });
        if n == 100 {
            b.bench(&format!("round n={n} CCESA(p*) threaded"), || {
                black_box(run_round_threaded(&cc_cfg, &models).unwrap());
            });
            b.bench(&format!("round n={n} CCESA(p*) event-loop"), || {
                black_box(run_round_event_loop(&cc_cfg, &models).unwrap());
            });
        }
    }

    // PJRT masked_sum kernel vs rust loop at the AOT shape
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        let rt = Runtime::cpu(&dir).expect("pjrt");
        let exe = rt.load("masked_sum").expect("masked_sum artifact");
        let (clients, m) = rt.manifest.agg_dims();
        let mut rng = Rng::new(11);
        let stacked: Vec<u32> = (0..clients * m).map(|_| rng.next_u32()).collect();
        b.throughput(
            &format!("masked_sum HLO {clients}x{m}"),
            (clients * m * 4) as f64,
            "B/s",
            || {
                let outs = exe
                    .run(&[Input::U32(stacked.clone(), vec![clients as i64, m as i64])])
                    .unwrap();
                black_box(to_u32(&outs[0]).unwrap());
            },
        );
        b.throughput(
            &format!("masked_sum rust {clients}x{m}"),
            (clients * m * 4) as f64,
            "B/s",
            || {
                let mut acc = vec![0u32; m];
                for c in 0..clients {
                    let row = &stacked[c * m..(c + 1) * m];
                    for (a, x) in acc.iter_mut().zip(row) {
                        *a = a.wrapping_add(*x);
                    }
                }
                black_box(acc[0]);
            },
        );
    } else {
        eprintln!("skipping PJRT kernel bench: artifacts not built");
    }

    b.report();
    b.write_report_to_sink(concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_round_latency.json"));
}
