//! Campaign throughput at production scale: full scenario rounds per
//! second through the sync engine and the threaded coordinator, up to
//! n ≈ 1000 clients (the paper's largest regime).
//!
//! The Harary topology keeps the per-client degree fixed (8), so the cost
//! per round scales linearly in n and the rounds/s numbers compare across
//! population sizes. `CCESA_BENCH_BUDGET_MS` caps the per-case measurement
//! budget (one warmup iteration per case still runs — the floor for the
//! n=1000 cases is a handful of full campaign rounds).
//!
//! ```bash
//! cargo bench --bench campaign_throughput
//! CCESA_BENCH_BUDGET_MS=500 cargo bench --bench campaign_throughput
//! ```

use ccesa::bench::{black_box, Bench};
use ccesa::protocol::Topology;
use ccesa::sim::{
    run_campaign, AdversarySpec, ChurnModel, Driver, Scenario, ThresholdRule, TopologySchedule,
};

fn scenario(n: usize, rounds: usize) -> Scenario {
    Scenario {
        name: format!("bench-n{n}"),
        n,
        dim: 64,
        mask_bits: 32,
        rounds,
        topology: TopologySchedule::Static(Topology::Harary { k: 8 }),
        churn: ChurnModel::Iid { q: 0.005 },
        adversary: AdversarySpec::Eavesdropper,
        threshold: ThresholdRule::Fixed(4),
        clip: 4.0,
        seed: 0xBE2C,
    }
}

fn main() {
    let mut b = Bench::new("campaign_throughput");

    for &n in &[100usize, 400, 1000] {
        let sc = scenario(n, 1);
        b.throughput(&format!("campaign round n={n} (engine)"), n as f64, "client/s", || {
            black_box(run_campaign(&sc, Driver::Engine).unwrap());
        });
    }

    for &n in &[100usize, 1000] {
        let sc = scenario(n, 1);
        b.throughput(
            &format!("campaign round n={n} (coordinator)"),
            n as f64,
            "client/s",
            || {
                black_box(run_campaign(&sc, Driver::Coordinator).unwrap());
            },
        );
    }

    b.report();
}
