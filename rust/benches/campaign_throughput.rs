//! Campaign throughput at production scale: full scenario rounds per
//! second through the sync engine, the thread-per-client coordinator, and
//! the worker-pool event loop, up to n ≈ 1000 clients — plus an n = 10⁵
//! smoke path for the event loop, the regime the thread-per-client shape
//! cannot reach at all.
//!
//! The Harary topology keeps the per-client degree fixed (8), so the cost
//! per round scales linearly in n and the rounds/s numbers compare across
//! population sizes. `CCESA_BENCH_BUDGET_MS` caps the per-case measurement
//! budget (one warmup iteration per case still runs — the floor for the
//! n=1000 cases is a handful of full campaign rounds). The n = 10⁵ case
//! costs seconds per iteration and only runs with `CCESA_BENCH_FULL=1`;
//! CI exercises the same scale through the ignored
//! `event_loop_n100k_round` test instead.
//!
//! ```bash
//! cargo bench --bench campaign_throughput
//! CCESA_BENCH_BUDGET_MS=500 cargo bench --bench campaign_throughput
//! CCESA_BENCH_FULL=1 cargo bench --bench campaign_throughput
//! ```

use ccesa::bench::{black_box, Bench};
use ccesa::protocol::Topology;
use ccesa::sim::{
    run_campaign, AdversarySpec, ChurnModel, Executor, Scenario, ThresholdRule, TopologySchedule,
};

fn scenario(n: usize, rounds: usize) -> Scenario {
    Scenario {
        name: format!("bench-n{n}"),
        n,
        dim: 64,
        mask_bits: 32,
        rounds,
        topology: TopologySchedule::Static(Topology::Harary { k: 8 }),
        churn: ChurnModel::Iid { q: 0.005 },
        adversary: AdversarySpec::Eavesdropper,
        threshold: ThresholdRule::Fixed(4),
        clip: 4.0,
        seed: 0xBE2C,
    }
}

fn main() {
    let mut b = Bench::new("campaign_throughput");

    for &n in &[100usize, 400, 1000] {
        let sc = scenario(n, 1);
        b.throughput(&format!("campaign round n={n} (engine)"), n as f64, "client/s", || {
            black_box(run_campaign(&sc, Executor::Engine).unwrap());
        });
    }

    // the two deployment shapes, side by side at the same populations
    for &n in &[100usize, 1000] {
        let sc = scenario(n, 1);
        b.throughput(&format!("campaign round n={n} (threaded)"), n as f64, "client/s", || {
            black_box(run_campaign(&sc, Executor::Threaded).unwrap());
        });
        b.throughput(&format!("campaign round n={n} (event-loop)"), n as f64, "client/s", || {
            black_box(run_campaign(&sc, Executor::EventLoop).unwrap());
        });
    }

    // n = 10⁵ smoke path: thread cost stays O(par::threads()) while the
    // thread-per-client shape would need 100k OS threads here
    if std::env::var("CCESA_BENCH_FULL").ok().as_deref() == Some("1") {
        let n = 100_000;
        let sc = scenario(n, 1);
        b.throughput(&format!("campaign round n={n} (event-loop)"), n as f64, "client/s", || {
            black_box(run_campaign(&sc, Executor::EventLoop).unwrap());
        });
    } else {
        eprintln!("skipping n=100000 event-loop smoke (set CCESA_BENCH_FULL=1)");
    }

    b.report();
    // cargo runs bench binaries with cwd = the package root (rust/);
    // anchor the default artifact at the workspace root so CI and humans
    // find it where the repo documents it.
    let default = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_campaign_throughput.json");
    b.write_report_to_sink(default);
}
