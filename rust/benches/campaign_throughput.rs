//! Campaign throughput at production scale: full scenario rounds per
//! second through the sync engine and the worker-pool event loop, up to
//! n ≈ 1000 clients, across the payload-codec axis (dense / top-k /
//! rand-k) — plus an n = 10⁵ smoke path for the event loop.
//!
//! The Harary topology keeps the per-client degree fixed (8), so the cost
//! per round scales linearly in n and the rounds/s numbers compare across
//! population sizes. `CCESA_BENCH_BUDGET_MS` caps the per-case measurement
//! budget (one warmup iteration per case still runs — the floor for the
//! n=1000 cases is a handful of full campaign rounds). The n = 10⁵ case
//! costs seconds per iteration and only runs with `CCESA_BENCH_FULL=1`;
//! CI exercises the same scale through the ignored
//! `event_loop_n100k` tests instead.
//!
//! ```bash
//! cargo bench --bench campaign_throughput
//! CCESA_BENCH_BUDGET_MS=500 cargo bench --bench campaign_throughput
//! CCESA_BENCH_FULL=1 cargo bench --bench campaign_throughput
//! ```

use ccesa::bench::{black_box, Bench};
use ccesa::protocol::Topology;
use ccesa::sim::{
    run_campaign, AdversarySpec, ChurnModel, CodecSpec, Executor, Scenario, ThresholdRule,
    TopologySchedule,
};

fn scenario(n: usize, rounds: usize) -> Scenario {
    Scenario {
        name: format!("bench-n{n}"),
        n,
        dim: 64,
        mask_bits: 32,
        rounds,
        topology: TopologySchedule::Static(Topology::Harary { k: 8 }),
        churn: ChurnModel::Iid { q: 0.005 },
        adversary: AdversarySpec::Eavesdropper,
        threshold: ThresholdRule::Fixed(4),
        codec: CodecSpec::Dense,
        clip: 4.0,
        seed: 0xBE2C,
    }
}

fn main() {
    let mut b = Bench::new("campaign_throughput");

    for &n in &[100usize, 400, 1000] {
        let sc = scenario(n, 1);
        b.throughput(&format!("campaign round n={n} (engine)"), n as f64, "client/s", || {
            black_box(run_campaign(&sc, Executor::Engine).unwrap());
        });
    }

    // the event-loop deployment shape at the same populations
    for &n in &[100usize, 1000] {
        let sc = scenario(n, 1);
        b.throughput(&format!("campaign round n={n} (event-loop)"), n as f64, "client/s", || {
            black_box(run_campaign(&sc, Executor::EventLoop).unwrap());
        });
    }

    // the payload-codec axis at fixed n: dense vs top-k vs rand-k at 10%
    // sparsity — Step-2 payload bytes drop ~10×, and the rows land in
    // BENCH_campaign_throughput.json for the regression gate
    for (label, codec) in [
        ("dense", CodecSpec::Dense),
        ("topk10", CodecSpec::TopK { frac: 0.1 }),
        ("randk10", CodecSpec::RandK { frac: 0.1 }),
    ] {
        let mut sc = scenario(400, 1);
        sc.name = format!("bench-codec-{label}");
        sc.codec = codec;
        b.throughput(
            &format!("campaign round n=400 codec={label} (engine)"),
            400.0,
            "client/s",
            || {
                black_box(run_campaign(&sc, Executor::Engine).unwrap());
            },
        );
    }

    // n = 10⁵ smoke path: thread cost stays O(par::threads()) while the
    // thread-per-client shape would need 100k OS threads here
    if std::env::var("CCESA_BENCH_FULL").ok().as_deref() == Some("1") {
        let n = 100_000;
        let sc = scenario(n, 1);
        b.throughput(&format!("campaign round n={n} (event-loop)"), n as f64, "client/s", || {
            black_box(run_campaign(&sc, Executor::EventLoop).unwrap());
        });
    } else {
        eprintln!("skipping n=100000 event-loop smoke (set CCESA_BENCH_FULL=1)");
    }

    b.report();
    // cargo runs bench binaries with cwd = the package root (rust/);
    // anchor the default artifact at the workspace root so CI and humans
    // find it where the repo documents it.
    let default = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_campaign_throughput.json");
    b.write_report_to_sink(default);
}
