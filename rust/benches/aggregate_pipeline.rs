//! The multi-core aggregation pipeline at the acceptance scale (n = 128,
//! dim = 2^17): server-style unmasking — masked-input summation plus every
//! mask-cancellation job — swept over worker counts, against the serial
//! baseline, plus batched-vs-per-owner Shamir reconstruction.
//!
//! Always emits a machine-readable `BENCH_aggregate.json` (override with
//! `--json PATH` or `CCESA_BENCH_JSON`) so the repo's bench trajectory is
//! populated: median/p95 per case, host core count, and the thread sweep
//! (thread count is encoded in each case name).

use ccesa::bench::{black_box, Bench};
use ccesa::crypto::prg::{apply_mask, apply_mask_jobs_range, MaskJob};
use ccesa::masking::random_vector;
use ccesa::par;
use ccesa::shamir;
use ccesa::util::mod_mask;
use ccesa::util::rng::Rng;

const N: usize = 128;
const DIM: usize = 1 << 17;
const BITS: u32 = 32;
/// Pairwise streams left by simulated V2∖V3 dropouts.
const PAIRWISE_JOBS: usize = 16;

/// The planned mask-cancellation jobs of one server finalize: n self masks
/// + the dropouts' pairwise masks.
fn mask_jobs() -> Vec<MaskJob> {
    let mut jobs = Vec::with_capacity(N + PAIRWISE_JOBS);
    for i in 0..N {
        let mut seed = [0u8; 32];
        seed[0] = i as u8;
        seed[1] = 0x5E;
        jobs.push(MaskJob { seed, pairwise: false, negate: true });
    }
    for k in 0..PAIRWISE_JOBS {
        let mut seed = [0u8; 32];
        seed[0] = k as u8;
        seed[1] = 0xFA;
        jobs.push(MaskJob { seed, pairwise: true, negate: k % 2 == 0 });
    }
    jobs
}

fn unmask_serial(acc: &mut [u64], inputs: &[Vec<u64>], jobs: &[MaskJob]) {
    let mask = mod_mask(BITS);
    acc.fill(0);
    for v in inputs {
        for (a, x) in acc.iter_mut().zip(v.iter()) {
            *a = a.wrapping_add(*x) & mask;
        }
    }
    for job in jobs {
        apply_mask(acc, &job.seed, job.nonce(), BITS, job.negate);
    }
}

fn unmask_parallel(acc: &mut [u64], inputs: &[Vec<u64>], jobs: &[MaskJob], threads: usize) {
    let mask = mod_mask(BITS);
    par::for_each_slice(acc, threads, |offset, slice| {
        let n = slice.len();
        slice.fill(0);
        for v in inputs {
            for (a, x) in slice.iter_mut().zip(v[offset..offset + n].iter()) {
                *a = a.wrapping_add(*x) & mask;
            }
        }
        apply_mask_jobs_range(slice, jobs, BITS, offset);
    });
}

fn main() {
    let mut b = Bench::new("aggregate_pipeline");
    let mut rng = Rng::new(0xA66);

    let inputs: Vec<Vec<u64>> = (0..N).map(|_| random_vector(DIM, BITS, &mut rng)).collect();
    let jobs = mask_jobs();

    // Sanity: every thread count is bit-identical to the serial pass.
    let mut serial = vec![0u64; DIM];
    unmask_serial(&mut serial, &inputs, &jobs);
    for threads in [1usize, 2, 4, 8] {
        let mut par_acc = vec![0u64; DIM];
        unmask_parallel(&mut par_acc, &inputs, &jobs, threads);
        assert_eq!(par_acc, serial, "threads={threads} diverged from serial");
    }

    let mut acc = vec![0u64; DIM];
    b.throughput(
        &format!("unmask n={N} dim={DIM} serial"),
        (jobs.len() * DIM * 4) as f64,
        "B/s",
        || {
            unmask_serial(&mut acc, &inputs, &jobs);
            black_box(acc[0]);
        },
    );
    for threads in [1usize, 2, 4, 8] {
        b.throughput(
            &format!("unmask n={N} dim={DIM} threads={threads}"),
            (jobs.len() * DIM * 4) as f64,
            "B/s",
            || {
                unmask_parallel(&mut acc, &inputs, &jobs, threads);
                black_box(acc[0]);
            },
        );
    }

    // Shamir reconstruction: per-owner O(t²) solve vs one basis per
    // distinct holder set. All owners share one holder set — the common
    // no-dropout round shape.
    let t = 64;
    let points: Vec<u16> = (1..=N as u16).collect();
    let owners: Vec<Vec<shamir::Share>> = (0..N)
        .map(|_| {
            let mut secret = [0u8; 32];
            rng.fill_bytes(&mut secret);
            shamir::split(&secret, t, &points, &mut rng).unwrap()
        })
        .collect();
    let jobs_shamir: Vec<&[shamir::Share]> = owners.iter().map(|s| &s[..t]).collect();
    b.bench(&format!("shamir per-owner n={N} t={t}"), || {
        for shares in &jobs_shamir {
            black_box(shamir::reconstruct(shares, t, 32).unwrap());
        }
    });
    b.bench(&format!("shamir batched n={N} t={t}"), || {
        let batch = shamir::reconstruct_batch(&jobs_shamir, t, 32).unwrap();
        assert_eq!(batch.bases_computed, 1);
        black_box(batch.secrets.len());
    });

    b.report();
    // cargo runs bench binaries with cwd = the package root (rust/);
    // anchor the default artifact at the workspace root so CI and humans
    // find it where the repo documents it.
    b.write_report_to_sink(concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_aggregate.json"));
}
