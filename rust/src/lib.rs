//! # CCESA — Communication-Computation Efficient Secure Aggregation
//!
//! Reproduction of Choi, Sohn, Han & Moon (2020): privacy-preserving
//! federated learning via secure aggregation over *sparse* (Erdős–Rényi)
//! secret-sharing graphs, at 20–30% of the communication/computation cost
//! of Bonawitz et al.'s complete-graph secure aggregation.
//!
//! Three-layer architecture (see DESIGN.md):
//! * **L3 (this crate)** — the protocol engine, FL orchestrator, simnet,
//!   analysis and attacks;
//! * **L2 (python/compile/model.py)** — JAX train/eval/inversion steps,
//!   AOT-lowered to HLO text;
//! * **L1 (python/compile/kernels/)** — Pallas kernels called from L2.
//!
//! Python never runs on the request path: `runtime` loads the AOT
//! artifacts via the PJRT C API and executes them from Rust.
pub mod analysis;
pub mod bench;
pub mod attacks;
pub mod codec;
pub mod coordinator;
pub mod crypto;
pub mod fl;
pub mod gf;
pub mod graph;
pub mod hier;
pub mod journal;
pub mod kernels;
pub mod masking;
pub mod net;
pub mod par;
pub mod protocol;
pub mod runtime;
pub mod shamir;
pub mod sim;
pub mod spec;
pub mod util;
pub mod wire;
