//! Synthetic datasets for the paper's experiments.
//!
//! * [`SyntheticCifar`] — 10-class Gaussian-blob images replacing CIFAR-10
//!   in the Fig 5.2 reliability experiments: the claim under test is about
//!   *aggregation reliability vs p*, which depends on the protocol, not on
//!   the vision model (DESIGN.md substitution table).
//! * [`SyntheticFaces`] — per-identity smooth templates + noise replacing
//!   the AT&T database for the model-inversion experiments: Fredrikson et
//!   al.'s attack reconstructs the class template from softmax-regression
//!   weights, so template recovery is measurable identically.

use crate::util::rng::Rng;

/// A labeled dataset with flattened f32 features.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// n_samples × dim, row-major.
    pub xs: Vec<f32>,
    pub ys: Vec<usize>,
    pub dim: usize,
    pub classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.ys.len()
    }
    pub fn is_empty(&self) -> bool {
        self.ys.is_empty()
    }
    pub fn x(&self, i: usize) -> &[f32] {
        &self.xs[i * self.dim..(i + 1) * self.dim]
    }

    /// Materialize a batch (features, one-hot, labels) for sample indices,
    /// repeating indices if needed to fill `batch`.
    pub fn batch(&self, idx: &[usize], batch: usize) -> (Vec<f32>, Vec<f32>, Vec<i32>) {
        let mut x = Vec::with_capacity(batch * self.dim);
        let mut onehot = vec![0.0f32; batch * self.classes];
        let mut labels = Vec::with_capacity(batch);
        for k in 0..batch {
            let i = idx[k % idx.len()];
            x.extend_from_slice(self.x(i));
            onehot[k * self.classes + self.ys[i]] = 1.0;
            labels.push(self.ys[i] as i32);
        }
        (x, onehot, labels)
    }

    /// Subset view (copying).
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let mut xs = Vec::with_capacity(idx.len() * self.dim);
        let mut ys = Vec::with_capacity(idx.len());
        for &i in idx {
            xs.extend_from_slice(self.x(i));
            ys.push(self.ys[i]);
        }
        Dataset { xs, ys, dim: self.dim, classes: self.classes }
    }
}

/// CIFAR-like blobs: class k has a unit-norm mean direction; samples are
/// mean + isotropic noise, giving a linearly-separable-but-noisy task.
pub struct SyntheticCifar;

impl SyntheticCifar {
    pub fn generate(n_samples: usize, dim: usize, classes: usize, noise: f32, rng: &mut Rng) -> Dataset {
        // class means: random unit vectors, held apart by construction
        let means: Vec<Vec<f32>> = (0..classes)
            .map(|_| {
                let v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
                let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-6);
                v.into_iter().map(|x| x / norm).collect()
            })
            .collect();
        let mut xs = Vec::with_capacity(n_samples * dim);
        let mut ys = Vec::with_capacity(n_samples);
        for i in 0..n_samples {
            let y = i % classes;
            for j in 0..dim {
                xs.push(means[y][j] + noise * rng.normal() as f32);
            }
            ys.push(y);
        }
        // shuffle sample order
        let mut order: Vec<usize> = (0..n_samples).collect();
        rng.shuffle(&mut order);
        let ds = Dataset { xs, ys, dim, classes };
        ds.subset(&order)
    }

    /// Generate a train/test pair drawn from the *same* class means.
    pub fn generate_split(
        n_train: usize,
        n_test: usize,
        dim: usize,
        classes: usize,
        noise: f32,
        rng: &mut Rng,
    ) -> (Dataset, Dataset) {
        let all = Self::generate(n_train + n_test, dim, classes, noise, rng);
        let train_idx: Vec<usize> = (0..n_train).collect();
        let test_idx: Vec<usize> = (n_train..n_train + n_test).collect();
        (all.subset(&train_idx), all.subset(&test_idx))
    }
}

/// Face-like identities: smooth random templates in [0,1]^(side²) made by
/// low-pass filtering white noise; samples add pixel noise.
pub struct SyntheticFaces;

impl SyntheticFaces {
    pub fn template(side: usize, rng: &mut Rng) -> Vec<f32> {
        let dim = side * side;
        let raw: Vec<f32> = (0..dim).map(|_| rng.next_f32()).collect();
        // two passes of 5x5 box blur ⇒ smooth, face-ish blobs
        let blur = |img: &[f32]| -> Vec<f32> {
            let mut out = vec![0.0f32; dim];
            let r = 2i64;
            for y in 0..side as i64 {
                for x in 0..side as i64 {
                    let mut acc = 0.0;
                    let mut cnt = 0.0;
                    for dy in -r..=r {
                        for dx in -r..=r {
                            let yy = y + dy;
                            let xx = x + dx;
                            if yy >= 0 && yy < side as i64 && xx >= 0 && xx < side as i64 {
                                acc += img[(yy as usize) * side + xx as usize];
                                cnt += 1.0;
                            }
                        }
                    }
                    out[(y as usize) * side + x as usize] = acc / cnt;
                }
            }
            out
        };
        let sm = blur(&blur(&raw));
        // stretch to [0,1]
        let lo = sm.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = sm.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        sm.into_iter().map(|v| (v - lo) / (hi - lo + 1e-9)).collect()
    }

    /// Generate (dataset, templates): `per_identity` samples per identity.
    pub fn generate(
        identities: usize,
        per_identity: usize,
        side: usize,
        noise: f32,
        rng: &mut Rng,
    ) -> (Dataset, Vec<Vec<f32>>) {
        let dim = side * side;
        let templates: Vec<Vec<f32>> = (0..identities).map(|_| Self::template(side, rng)).collect();
        let mut xs = Vec::with_capacity(identities * per_identity * dim);
        let mut ys = Vec::with_capacity(identities * per_identity);
        for (id, t) in templates.iter().enumerate() {
            for _ in 0..per_identity {
                for &p in t {
                    xs.push((p + noise * rng.normal() as f32).clamp(0.0, 1.0));
                }
                ys.push(id);
            }
        }
        (Dataset { xs, ys, dim, classes: identities }, templates)
    }
}

/// I.i.d. partition: shuffle and deal evenly to `n_clients`.
pub fn partition_iid(ds: &Dataset, n_clients: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    let mut order: Vec<usize> = (0..ds.len()).collect();
    rng.shuffle(&mut order);
    let mut parts = vec![Vec::new(); n_clients];
    for (k, i) in order.into_iter().enumerate() {
        parts[k % n_clients].push(i);
    }
    parts
}

/// Non-i.i.d. shard partition (McMahan et al. §3 / paper §F.2.1): sort by
/// label, cut into `2·n_clients` shards, give each client 2 random shards —
/// each client sees at most ~2 classes.
pub fn partition_noniid(ds: &Dataset, n_clients: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    let mut order: Vec<usize> = (0..ds.len()).collect();
    order.sort_by_key(|&i| ds.ys[i]);
    let n_shards = 2 * n_clients;
    let shard_size = ds.len() / n_shards;
    assert!(shard_size > 0, "dataset too small for {n_clients} clients");
    let mut shard_ids: Vec<usize> = (0..n_shards).collect();
    rng.shuffle(&mut shard_ids);
    let mut parts = vec![Vec::new(); n_clients];
    for (k, &s) in shard_ids.iter().enumerate() {
        let start = s * shard_size;
        let end = if s == n_shards - 1 { ds.len() } else { start + shard_size };
        parts[k / 2].extend_from_slice(&order[start..end]);
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cifar_blobs_are_separable_ish() {
        let mut rng = Rng::new(1);
        let ds = SyntheticCifar::generate(500, 32, 10, 0.3, &mut rng);
        assert_eq!(ds.len(), 500);
        assert_eq!(ds.dim, 32);
        // nearest-class-mean classification beats chance comfortably
        let mut means = vec![vec![0.0f32; 32]; 10];
        let mut counts = vec![0usize; 10];
        for i in 0..ds.len() {
            counts[ds.ys[i]] += 1;
            for (m, v) in means[ds.ys[i]].iter_mut().zip(ds.x(i)) {
                *m += v;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c.max(1) as f32;
            }
        }
        let mut correct = 0;
        for i in 0..ds.len() {
            let best = (0..10)
                .min_by(|&a, &b| {
                    let da: f32 =
                        means[a].iter().zip(ds.x(i)).map(|(m, x)| (m - x) * (m - x)).sum();
                    let db: f32 =
                        means[b].iter().zip(ds.x(i)).map(|(m, x)| (m - x) * (m - x)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == ds.ys[i] {
                correct += 1;
            }
        }
        assert!(correct as f64 > 0.8 * ds.len() as f64, "correct={correct}");
    }

    #[test]
    fn faces_templates_are_smooth_and_distinct() {
        let mut rng = Rng::new(2);
        let (ds, templates) = SyntheticFaces::generate(8, 5, 16, 0.05, &mut rng);
        assert_eq!(ds.len(), 40);
        assert_eq!(templates.len(), 8);
        // smoothness: neighbor diffs well below range
        for t in &templates {
            let mut acc = 0.0f32;
            for i in 0..t.len() - 1 {
                acc += (t[i + 1] - t[i]).abs();
            }
            assert!(acc / (t.len() as f32) < 0.12, "template too rough");
            assert!(t.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
        // identities differ
        let d01: f32 = templates[0]
            .iter()
            .zip(&templates[1])
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        assert!(d01 > 1.0);
    }

    #[test]
    fn iid_partition_covers_all_evenly() {
        let mut rng = Rng::new(3);
        let ds = SyntheticCifar::generate(100, 8, 10, 0.2, &mut rng);
        let parts = partition_iid(&ds, 7, &mut rng);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, 100);
        let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
        let mut all: Vec<usize> = parts.concat();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn noniid_partition_limits_classes_per_client() {
        let mut rng = Rng::new(4);
        let ds = SyntheticCifar::generate(400, 8, 10, 0.2, &mut rng);
        let parts = partition_noniid(&ds, 10, &mut rng);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, 400);
        for (k, p) in parts.iter().enumerate() {
            let classes: std::collections::HashSet<usize> =
                p.iter().map(|&i| ds.ys[i]).collect();
            assert!(classes.len() <= 3, "client {k} sees {} classes", classes.len());
        }
    }

    #[test]
    fn batch_fills_and_wraps() {
        let mut rng = Rng::new(5);
        let ds = SyntheticCifar::generate(10, 4, 2, 0.1, &mut rng);
        let (x, onehot, labels) = ds.batch(&[0, 1, 2], 8);
        assert_eq!(x.len(), 8 * 4);
        assert_eq!(onehot.len(), 8 * 2);
        assert_eq!(labels.len(), 8);
        // wrapped: samples 3..8 repeat 0,1,2
        assert_eq!(labels[0], labels[3]);
        for row in onehot.chunks(2) {
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        }
    }
}
