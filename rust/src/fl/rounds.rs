//! The federated-learning round loop with pluggable aggregation.
//!
//! Mirrors the experimental setup of §5 / Appendix F: per round, a
//! fraction of clients is selected, each runs local SGD epochs via the
//! AOT-compiled HLO train step, and the updated models are aggregated
//! either in plaintext (FedAvg) or through the SA/CCESA protocol. An
//! unreliable secure round leaves the global model unchanged (§4.3.2) —
//! the server *knows* the round failed.

use super::data::Dataset;
use crate::analysis::bounds::t_rule;
use crate::masking::Quantizer;
use crate::net::NetStats;
use crate::protocol::dropout::DropoutModel;
use crate::protocol::engine::{run_round, RoundResult};
use crate::protocol::{ProtocolConfig, Topology};
use crate::runtime::mlp::{MlpParams, MlpRuntime};
use crate::util::rng::Rng;
use anyhow::Result;

/// How client updates are combined.
#[derive(Debug, Clone)]
pub enum Aggregation {
    /// FedAvg: plaintext mean (no privacy — the eavesdropper baseline).
    Plain,
    /// Secure aggregation over the given assignment-graph family.
    Secure {
        topology: Topology,
        /// Secret-sharing threshold; `None` applies Remark 4's rule
        /// (Complete topology defaults to ⌊k/2⌋+1 as in Table 5.1).
        t_override: Option<usize>,
        mask_bits: u32,
        dropout: DropoutModel,
    },
}

/// FL experiment configuration.
#[derive(Debug, Clone)]
pub struct FlConfig {
    pub n_clients: usize,
    pub rounds: usize,
    /// Fraction c of clients selected per round (paper's S_t has c·n).
    pub client_fraction: f64,
    pub local_epochs: usize,
    pub lr: f32,
    /// Quantization clip for secure aggregation.
    pub clip: f32,
    pub aggregation: Aggregation,
    pub seed: u64,
}

/// Per-round record.
#[derive(Debug, Clone)]
pub struct RoundLog {
    pub round: usize,
    pub selected: usize,
    pub mean_local_loss: f32,
    pub test_accuracy: f64,
    pub reliable: bool,
    pub bytes_up: u64,
    pub bytes_down: u64,
}

/// Full experiment history.
#[derive(Debug, Clone, Default)]
pub struct FlHistory {
    pub logs: Vec<RoundLog>,
    pub total_stats: NetStats,
}

impl FlHistory {
    pub fn final_accuracy(&self) -> f64 {
        self.logs.last().map(|l| l.test_accuracy).unwrap_or(0.0)
    }
    pub fn unreliable_rounds(&self) -> usize {
        self.logs.iter().filter(|l| !l.reliable).count()
    }
}

/// Test-set accuracy using the fixed-batch eval executable.
pub fn eval_accuracy(mlp: &MlpRuntime, params: &MlpParams, test: &Dataset) -> Result<f64> {
    let b = mlp.dims.batch;
    let mut correct = 0usize;
    let mut counted = 0usize;
    let mut i = 0;
    while i < test.len() {
        let idx: Vec<usize> = (i..(i + b).min(test.len())).collect();
        let real = idx.len();
        let (x, _, labels) = test.batch(&idx, b);
        let c = mlp.eval_batch(params, &x, &labels)?;
        // padded entries repeat real samples; rescale by counting only a
        // full batch when it is full, otherwise recompute conservatively
        if real == b {
            correct += c;
            counted += b;
        } else {
            // evaluate padded batch but only trust the prefix statistically:
            // count the batch result scaled to the real prefix
            correct += (c * real).div_euclid(b);
            counted += real;
        }
        i += b;
    }
    Ok(correct as f64 / counted.max(1) as f64)
}

/// Local SGD for one client: `epochs` passes over its shard.
pub fn local_train(
    mlp: &MlpRuntime,
    global: &MlpParams,
    ds: &Dataset,
    shard: &[usize],
    epochs: usize,
    lr: f32,
    rng: &mut Rng,
) -> Result<(MlpParams, f32)> {
    let mut params = global.clone();
    let b = mlp.dims.batch;
    let mut idx = shard.to_vec();
    let mut last_loss = 0.0;
    for _ in 0..epochs {
        rng.shuffle(&mut idx);
        for chunk in idx.chunks(b) {
            let (x, onehot, _) = ds.batch(chunk, b);
            last_loss = mlp.train_step(&mut params, &x, &onehot, lr)?;
        }
    }
    Ok((params, last_loss))
}

/// Run a full FL experiment on the MLP workload.
pub fn run_fl_mlp(
    cfg: &FlConfig,
    mlp: &MlpRuntime,
    train: &Dataset,
    partitions: &[Vec<usize>],
    test: &Dataset,
) -> Result<FlHistory> {
    assert_eq!(partitions.len(), cfg.n_clients);
    let mut rng = Rng::new(cfg.seed);
    let mut global = MlpParams::init(mlp.dims, &mut rng);
    let dim = mlp.dims.param_count();
    let mut history = FlHistory { total_stats: NetStats::new(cfg.n_clients), ..Default::default() };

    for round in 0..cfg.rounds {
        let k = ((cfg.n_clients as f64 * cfg.client_fraction).round() as usize)
            .clamp(1, cfg.n_clients);
        let selected = rng.sample_indices(cfg.n_clients, k);

        // local training
        let mut locals: Vec<Vec<f32>> = Vec::with_capacity(k);
        let mut loss_acc = 0.0f32;
        for &ci in &selected {
            let mut crng = rng.split(0x10CA1 + ci as u64);
            let (p, loss) =
                local_train(mlp, &global, train, &partitions[ci], cfg.local_epochs, cfg.lr, &mut crng)?;
            locals.push(p.flatten());
            loss_acc += loss;
        }
        let mean_loss = loss_acc / k as f32;

        // aggregation
        let (new_global, reliable, bytes_up, bytes_down) = match &cfg.aggregation {
            Aggregation::Plain => {
                let mut mean = vec![0.0f32; dim];
                for l in &locals {
                    for (m, v) in mean.iter_mut().zip(l) {
                        *m += v;
                    }
                }
                for m in mean.iter_mut() {
                    *m /= k as f32;
                }
                (Some(MlpParams::from_flat(mlp.dims, &mean)?), true, 0, 0)
            }
            Aggregation::Secure { topology, t_override, mask_bits, dropout } => {
                let q = Quantizer::for_sum_of(*mask_bits, cfg.clip, k);
                let models: Vec<Vec<u64>> = locals.iter().map(|l| q.quantize(l)).collect();
                let t = t_override.unwrap_or_else(|| match topology {
                    Topology::Complete => k / 2 + 1,
                    Topology::ErdosRenyi { p } => t_rule(k, *p).min(k),
                    Topology::Harary { k: deg } => (deg / 2 + 1).max(2),
                    Topology::Custom(_) => k / 2 + 1,
                });
                let pcfg = ProtocolConfig {
                    n: k,
                    t,
                    mask_bits: *mask_bits,
                    dim,
                    topology: topology.clone(),
                    dropout: dropout.clone(),
                    seed: cfg.seed ^ (round as u64).wrapping_mul(0x9E3779B97F4A7C15),
                };
                match run_round(&pcfg, &models) {
                    Ok(RoundResult { sum: Some(sum), sets, stats, .. }) => {
                        let denom = sets.v3.len().max(1) as f64;
                        let mean: Vec<f32> =
                            q.dequantize(&sum).iter().map(|v| (v / denom) as f32).collect();
                        let up = stats.bytes_up.iter().sum();
                        let down = stats.bytes_down.iter().sum();
                        history.total_stats.merge(&stats);
                        (Some(MlpParams::from_flat(mlp.dims, &mean)?), true, up, down)
                    }
                    Ok(RoundResult { sum: None, stats, .. }) => {
                        let up = stats.bytes_up.iter().sum();
                        let down = stats.bytes_down.iter().sum();
                        history.total_stats.merge(&stats);
                        (None, false, up, down)
                    }
                    Err(e) => {
                        log::warn!("round {round}: protocol aborted: {e}");
                        (None, false, 0, 0)
                    }
                }
            }
        };

        if let Some(g) = new_global {
            global = g;
        } // else: unreliable round — keep previous global (paper §4.3.2)

        let test_accuracy = eval_accuracy(mlp, &global, test)?;
        log::info!(
            "round {round}: k={k} loss={mean_loss:.4} acc={test_accuracy:.4} reliable={reliable}"
        );
        history.logs.push(RoundLog {
            round,
            selected: k,
            mean_local_loss: mean_loss,
            test_accuracy,
            reliable,
            bytes_up,
            bytes_down,
        });
    }
    Ok(history)
}
