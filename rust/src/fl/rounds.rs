//! The federated-learning round loop with pluggable aggregation.
//!
//! Mirrors the experimental setup of §5 / Appendix F: per round, a
//! fraction of clients is selected, each runs local SGD epochs via the
//! AOT-compiled HLO train step, and the updated models are aggregated
//! either in plaintext (FedAvg) or through the SA/CCESA protocol. An
//! unreliable secure round leaves the global model unchanged (§4.3.2) —
//! the server *knows* the round failed.

use super::data::Dataset;
use crate::analysis::bounds::t_rule;
use crate::codec::{Codec, IndexPlan};
use crate::masking::Quantizer;
use crate::net::NetStats;
use crate::protocol::dropout::DropoutModel;
use crate::protocol::engine::{run_round, RoundResult};
use crate::protocol::{ProtocolConfig, Topology};
use crate::runtime::mlp::{MlpParams, MlpRuntime};
use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::Arc;

/// How client updates are combined.
#[derive(Debug, Clone)]
pub enum Aggregation {
    /// FedAvg: plaintext mean (no privacy — the eavesdropper baseline).
    Plain,
    /// Secure aggregation over the given assignment-graph family.
    Secure {
        topology: Topology,
        /// Secret-sharing threshold; `None` applies Remark 4's rule
        /// (Complete topology defaults to ⌊k/2⌋+1 as in Table 5.1).
        t_override: Option<usize>,
        mask_bits: u32,
        dropout: DropoutModel,
        /// Payload codec for the masked uploads ([`Codec::Dense`] is the
        /// classic full-model path; sparse codecs update only the round's
        /// shared support, leaving other global coordinates untouched).
        codec: Codec,
    },
}

/// FL experiment configuration.
#[derive(Debug, Clone)]
pub struct FlConfig {
    pub n_clients: usize,
    pub rounds: usize,
    /// Fraction c of clients selected per round (paper's S_t has c·n).
    pub client_fraction: f64,
    pub local_epochs: usize,
    pub lr: f32,
    /// Quantization clip for secure aggregation.
    pub clip: f32,
    pub aggregation: Aggregation,
    pub seed: u64,
}

/// Per-round record.
#[derive(Debug, Clone)]
pub struct RoundLog {
    pub round: usize,
    pub selected: usize,
    pub mean_local_loss: f32,
    pub test_accuracy: f64,
    pub reliable: bool,
    pub bytes_up: u64,
    pub bytes_down: u64,
}

/// Full experiment history.
#[derive(Debug, Clone, Default)]
pub struct FlHistory {
    pub logs: Vec<RoundLog>,
    pub total_stats: NetStats,
}

impl FlHistory {
    pub fn final_accuracy(&self) -> f64 {
        self.logs.last().map(|l| l.test_accuracy).unwrap_or(0.0)
    }
    pub fn unreliable_rounds(&self) -> usize {
        self.logs.iter().filter(|l| !l.reliable).count()
    }
}

/// Outcome of one secure-aggregation step over f32 updates.
#[derive(Debug, Clone)]
pub struct SecureMeanOutcome {
    /// Dequantized mean over V3, when the round was reliable.
    pub mean: Option<Vec<f32>>,
    pub reliable: bool,
    /// Traffic charged to the round; `None` if the protocol aborted before
    /// any accounting (|V_k| < t).
    pub stats: Option<NetStats>,
    /// |V3| — the clients whose updates entered the mean.
    pub survivors: usize,
    /// The abort error when the protocol gave up mid-round; callers log it
    /// with their round context.
    pub abort: Option<String>,
    /// The round's payload plan: which coordinates of `mean` carry this
    /// round's aggregate. Off-support coordinates of a sparse mean are 0.0
    /// and must not overwrite state a caller keeps per coordinate.
    pub plan: Arc<IndexPlan>,
}

impl SecureMeanOutcome {
    /// Round bookkeeping shared by every FL loop: log an abort with its
    /// round number, merge this round's traffic into `total`, and return
    /// the (bytes_up, bytes_down) charged.
    pub fn charge(&self, round: usize, total: &mut NetStats) -> (u64, u64) {
        if let Some(e) = &self.abort {
            log::warn!("round {round}: protocol aborted: {e}");
        }
        match &self.stats {
            Some(stats) => {
                total.merge(stats);
                (stats.bytes_up.iter().sum(), stats.bytes_down.iter().sum())
            }
            None => (0, 0),
        }
    }
}

/// Quantize the updates, run one secure round, and decode the V3 mean —
/// the Secure arm of [`run_fl_mlp`], shared with scenario campaigns
/// ([`run_fl_scenario`]).
pub fn secure_mean(locals: &[Vec<f32>], q: &Quantizer, pcfg: &ProtocolConfig) -> SecureMeanOutcome {
    let models: Vec<Vec<u64>> = locals.iter().map(|l| q.quantize(l)).collect();
    match run_round(pcfg, &models) {
        Ok(RoundResult { sum: Some(sum), sets, stats, plan, .. }) => {
            let denom = sets.v3.len().max(1) as f64;
            let mean: Vec<f32> =
                q.dequantize(&sum).iter().map(|v| (v / denom) as f32).collect();
            SecureMeanOutcome {
                mean: Some(mean),
                reliable: true,
                stats: Some(stats),
                survivors: sets.v3.len(),
                abort: None,
                plan,
            }
        }
        Ok(RoundResult { sum: None, sets, stats, plan, .. }) => SecureMeanOutcome {
            mean: None,
            reliable: false,
            stats: Some(stats),
            survivors: sets.v3.len(),
            abort: None,
            plan,
        },
        // Aborted before the round ran its course: derive the same plan the
        // round would have used so the field is always meaningful (cold
        // path — this is the only place it is re-derived).
        Err(e) => SecureMeanOutcome {
            mean: None,
            reliable: false,
            stats: None,
            survivors: 0,
            abort: Some(e.to_string()),
            plan: pcfg.codec.plan(pcfg.dim, pcfg.mask_bits, pcfg.seed, &models),
        },
    }
}

/// Test-set accuracy using the fixed-batch eval executable.
pub fn eval_accuracy(mlp: &MlpRuntime, params: &MlpParams, test: &Dataset) -> Result<f64> {
    let b = mlp.dims.batch;
    let mut correct = 0usize;
    let mut counted = 0usize;
    let mut i = 0;
    while i < test.len() {
        let idx: Vec<usize> = (i..(i + b).min(test.len())).collect();
        let real = idx.len();
        let (x, _, labels) = test.batch(&idx, b);
        let c = mlp.eval_batch(params, &x, &labels)?;
        // padded entries repeat real samples; rescale by counting only a
        // full batch when it is full, otherwise recompute conservatively
        if real == b {
            correct += c;
            counted += b;
        } else {
            // evaluate padded batch but only trust the prefix statistically:
            // count the batch result scaled to the real prefix
            correct += (c * real).div_euclid(b);
            counted += real;
        }
        i += b;
    }
    Ok(correct as f64 / counted.max(1) as f64)
}

/// Local SGD for one client: `epochs` passes over its shard.
pub fn local_train(
    mlp: &MlpRuntime,
    global: &MlpParams,
    ds: &Dataset,
    shard: &[usize],
    epochs: usize,
    lr: f32,
    rng: &mut Rng,
) -> Result<(MlpParams, f32)> {
    let mut params = global.clone();
    let b = mlp.dims.batch;
    let mut idx = shard.to_vec();
    let mut last_loss = 0.0;
    for _ in 0..epochs {
        rng.shuffle(&mut idx);
        for chunk in idx.chunks(b) {
            let (x, onehot, _) = ds.batch(chunk, b);
            last_loss = mlp.train_step(&mut params, &x, &onehot, lr)?;
        }
    }
    Ok((params, last_loss))
}

/// Run a full FL experiment on the MLP workload.
pub fn run_fl_mlp(
    cfg: &FlConfig,
    mlp: &MlpRuntime,
    train: &Dataset,
    partitions: &[Vec<usize>],
    test: &Dataset,
) -> Result<FlHistory> {
    assert_eq!(partitions.len(), cfg.n_clients);
    let mut rng = Rng::new(cfg.seed);
    let mut global = MlpParams::init(mlp.dims, &mut rng);
    let dim = mlp.dims.param_count();
    let mut history = FlHistory { total_stats: NetStats::new(cfg.n_clients), ..Default::default() };

    for round in 0..cfg.rounds {
        let k = ((cfg.n_clients as f64 * cfg.client_fraction).round() as usize)
            .clamp(1, cfg.n_clients);
        let selected = rng.sample_indices(cfg.n_clients, k);

        // local training
        let mut locals: Vec<Vec<f32>> = Vec::with_capacity(k);
        let mut loss_acc = 0.0f32;
        for &ci in &selected {
            let mut crng = rng.split(0x10CA1 + ci as u64);
            let (p, loss) =
                local_train(mlp, &global, train, &partitions[ci], cfg.local_epochs, cfg.lr, &mut crng)?;
            locals.push(p.flatten());
            loss_acc += loss;
        }
        let mean_loss = loss_acc / k as f32;

        // aggregation
        let (new_global, reliable, bytes_up, bytes_down) = match &cfg.aggregation {
            Aggregation::Plain => {
                let mut mean = vec![0.0f32; dim];
                for l in &locals {
                    for (m, v) in mean.iter_mut().zip(l) {
                        *m += v;
                    }
                }
                for m in mean.iter_mut() {
                    *m /= k as f32;
                }
                (Some(MlpParams::from_flat(mlp.dims, &mean)?), true, 0, 0)
            }
            Aggregation::Secure { topology, t_override, mask_bits, dropout, codec } => {
                let q = Quantizer::for_sum_of(*mask_bits, cfg.clip, k);
                let t = t_override.unwrap_or_else(|| match topology {
                    Topology::Complete => k / 2 + 1,
                    Topology::ErdosRenyi { p } => t_rule(k, *p).min(k),
                    Topology::Harary { k: deg } => (deg / 2 + 1).max(2),
                    Topology::Custom(_) => k / 2 + 1,
                });
                let pcfg = ProtocolConfig::builder()
                    .clients(k)
                    .threshold(t)
                    .model_dim(dim)
                    .mask_bits(*mask_bits)
                    .topology(topology.clone())
                    .dropout(dropout.clone())
                    .codec(*codec)
                    .seed(cfg.seed ^ (round as u64).wrapping_mul(0x9E3779B97F4A7C15))
                    .build()?;
                let outcome = secure_mean(&locals, &q, &pcfg);
                let (up, down) = outcome.charge(round, &mut history.total_stats);
                let new_global = match outcome.mean {
                    // A sparse round aggregates only the plan's support: take
                    // the secure mean there and keep the previous global on
                    // every off-support coordinate (whose mean slots are
                    // 0.0 by scatter, not "the clients agreed on 0").
                    Some(mean) => match outcome.plan.indices() {
                        Some(support) => {
                            let mut merged = global.flatten();
                            for &i in support {
                                merged[i as usize] = mean[i as usize];
                            }
                            Some(MlpParams::from_flat(mlp.dims, &merged)?)
                        }
                        None => Some(MlpParams::from_flat(mlp.dims, &mean)?),
                    },
                    None => None,
                };
                (new_global, outcome.reliable, up, down)
            }
        };

        if let Some(g) = new_global {
            global = g;
        } // else: unreliable round — keep previous global (paper §4.3.2)

        let test_accuracy = eval_accuracy(mlp, &global, test)?;
        log::info!(
            "round {round}: k={k} loss={mean_loss:.4} acc={test_accuracy:.4} reliable={reliable}"
        );
        history.logs.push(RoundLog {
            round,
            selected: k,
            mean_local_loss: mean_loss,
            test_accuracy,
            reliable,
            bytes_up,
            bytes_down,
        });
    }
    Ok(history)
}

/// Per-round record of a scenario-driven FL campaign.
#[derive(Debug, Clone)]
pub struct ScenarioRoundLog {
    pub round: usize,
    pub reliable: bool,
    pub survivors: usize,
    pub bytes_up: u64,
    pub bytes_down: u64,
}

/// Outcome of [`run_fl_scenario`].
#[derive(Debug, Clone)]
pub struct ScenarioFlHistory {
    /// The global model after the last round.
    pub global: Vec<f32>,
    pub logs: Vec<ScenarioRoundLog>,
    pub total_stats: NetStats,
}

impl ScenarioFlHistory {
    pub fn unreliable_rounds(&self) -> usize {
        self.logs.iter().filter(|l| !l.reliable).count()
    }
}

/// Drive a [`crate::sim::Scenario`] campaign through the FL update loop
/// with a pluggable local-update oracle — no PJRT runtime required.
///
/// Per round, every client produces a `dim`-length f32 update via
/// `local_update(round, client, &global, rng)`; the updates then take the
/// full secure path (quantize → SA/CCESA round under the scenario's
/// topology and compiled churn schedule → dequantized V3 mean) and the mean
/// is *added* to the global model. An unreliable round leaves the global
/// unchanged (§4.3.2). This is how scale experiments exercise multi-round
/// training dynamics (churn-induced stalls, topology ramps) without the
/// AOT-artifact dependency of [`run_fl_mlp`].
pub fn run_fl_scenario<F>(sc: &crate::sim::Scenario, mut local_update: F) -> Result<ScenarioFlHistory>
where
    F: FnMut(usize, usize, &[f32], &mut Rng) -> Vec<f32>,
{
    let plans = sc.compile();
    let q = Quantizer::for_sum_of(sc.mask_bits, sc.clip, sc.n);
    let mut history = ScenarioFlHistory {
        global: vec![0.0f32; sc.dim],
        logs: Vec::with_capacity(plans.len()),
        total_stats: NetStats::new(sc.n),
    };
    let mut rng = Rng::new(sc.seed ^ 0xF1);
    for plan in &plans {
        let locals: Vec<Vec<f32>> = (0..sc.n)
            .map(|client| {
                let mut crng = rng.split(0x10CA1 + client as u64);
                let update = local_update(plan.round, client, &history.global, &mut crng);
                assert_eq!(update.len(), sc.dim, "client {client} update dimension");
                update
            })
            .collect();
        let outcome = secure_mean(&locals, &q, &plan.cfg);
        let (up, down) = outcome.charge(plan.round, &mut history.total_stats);
        if let Some(mean) = &outcome.mean {
            for (g, m) in history.global.iter_mut().zip(mean) {
                *g += m;
            }
        }
        history.logs.push(ScenarioRoundLog {
            round: plan.round,
            reliable: outcome.reliable,
            survivors: outcome.survivors,
            bytes_up: up,
            bytes_down: down,
        });
    }
    Ok(history)
}

/// Drive a [`crate::sim::SessionScenario`] through the FL update loop: one
/// cold establishing round, then the scenario's warm rounds over a live
/// [`crate::protocol::session::Session`] — amortized setup, ratcheted
/// seeds, and (under a TopK codec) per-client local ranking with
/// cross-round error feedback. The companion to [`run_fl_scenario`], which
/// re-runs cold setup every round.
///
/// Per round, every client produces a `dim`-length f32 update via
/// `local_update(round, client, &global, rng)` (round 0 is the cold
/// round); updates are quantized into the modular domain, aggregated, and
/// the dequantized V3 mean is added to the global on the round's support.
/// Off-support coordinates are untouched — but unlike the oracle-TopK cold
/// path, their quantized mass is *not lost*: it stays in each client's
/// session residual and ships in a later round.
pub fn run_fl_session<F>(
    sc: &crate::sim::SessionScenario,
    clip: f32,
    mut local_update: F,
) -> Result<ScenarioFlHistory>
where
    F: FnMut(u64, usize, &[f32], &mut Rng) -> Vec<f32>,
{
    use crate::coordinator::CoordRoundResult;
    use crate::protocol::session::Session;
    let cfg = sc.config()?;
    let q = Quantizer::for_sum_of(sc.mask_bits, clip, sc.n);
    let opts = crate::coordinator::RoundOptions::default();
    let mut history = ScenarioFlHistory {
        global: vec![0.0f32; sc.dim],
        logs: Vec::with_capacity(sc.warm_rounds as usize + 1),
        total_stats: NetStats::new(sc.n),
    };
    let mut rng = Rng::new(sc.seed ^ 0xF1);
    let mut locals_for = |round: u64, global: &[f32], rng: &mut Rng| -> Vec<Vec<u64>> {
        (0..sc.n)
            .map(|client| {
                let mut crng = rng.split(0x10CA1 + client as u64);
                let update = local_update(round, client, global, &mut crng);
                assert_eq!(update.len(), sc.dim, "client {client} update dimension");
                q.quantize(&update)
            })
            .collect()
    };
    let mut apply = |history: &mut ScenarioFlHistory, round: u64, r: &CoordRoundResult| {
        history.total_stats.merge(&r.stats);
        if let Some(sum) = &r.sum {
            let denom = r.sets.v3.len().max(1) as f64;
            for (g, v) in history.global.iter_mut().zip(q.dequantize(sum)) {
                *g += (v / denom) as f32;
            }
        }
        history.logs.push(ScenarioRoundLog {
            round: round as usize,
            reliable: r.reliable,
            survivors: r.sets.v3.len(),
            bytes_up: r.stats.bytes_up.iter().sum(),
            bytes_down: r.stats.bytes_down.iter().sum(),
        });
    };

    let models = locals_for(0, &history.global.clone(), &mut rng);
    let (mut session, cold) = Session::establish(&cfg, &models)?;
    apply(&mut history, 0, &cold);
    let members = session.members();
    for round in 1..=sc.warm_rounds {
        let models = locals_for(round, &history.global.clone(), &mut rng);
        let active = sc.active_for(round, &members);
        match session.run_round(&models, &active, &opts) {
            Ok(r) => apply(&mut history, round, &r),
            Err(e) => {
                log::warn!("warm round {round}: protocol aborted: {e}");
                history.logs.push(ScenarioRoundLog {
                    round: round as usize,
                    reliable: false,
                    survivors: 0,
                    bytes_up: 0,
                    bytes_down: 0,
                });
            }
        }
    }
    Ok(history)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{
        AdversarySpec, Attendance, ChurnModel, CodecSpec, Scenario, SessionScenario,
        ThresholdRule, TopologySchedule,
    };

    fn scenario(n: usize, rounds: usize, churn: ChurnModel) -> Scenario {
        Scenario {
            name: "fl-scenario-test".to_string(),
            n,
            dim: 5,
            mask_bits: 32,
            rounds,
            topology: TopologySchedule::Static(Topology::Complete),
            churn,
            adversary: AdversarySpec::Eavesdropper,
            threshold: ThresholdRule::Fixed(n / 2 + 1),
            codec: CodecSpec::Dense,
            clip: 4.0,
            seed: 0xF15C,
        }
    }

    fn pcfg_complete(n: usize, t: usize, dim: usize, seed: u64) -> ProtocolConfig {
        ProtocolConfig::builder()
            .clients(n)
            .threshold(t)
            .model_dim(dim)
            .seed(seed)
            .build()
            .unwrap()
    }

    #[test]
    fn secure_mean_matches_plain_mean_within_quantization() {
        let n = 8;
        let dim = 12;
        let mut rng = Rng::new(4);
        let locals: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.normal_f32(0.0, 0.5)).collect())
            .collect();
        let q = Quantizer::for_sum_of(32, 4.0, n);
        let pcfg = pcfg_complete(n, n / 2 + 1, dim, 77);
        let outcome = secure_mean(&locals, &q, &pcfg);
        assert!(outcome.reliable);
        assert_eq!(outcome.survivors, n);
        let mean = outcome.mean.unwrap();
        let tol = (q.sum_error_bound(n) / n as f64 + 1e-6) as f32;
        for d in 0..dim {
            let plain: f32 = locals.iter().map(|l| l[d]).sum::<f32>() / n as f32;
            assert!((mean[d] - plain).abs() <= tol, "dim {d}: {} vs {plain}", mean[d]);
        }
    }

    #[test]
    fn secure_mean_abort_reports_unreliable() {
        let locals = vec![vec![0.5f32; 4]; 3];
        let q = Quantizer::for_sum_of(32, 4.0, 3);
        // two of three clients drop at step 0: |V1| = 1 < t = 3, so the
        // server aborts mid-round (the builder rejects a *statically*
        // impossible t > n at construction instead)
        let pcfg = ProtocolConfig {
            dropout: DropoutModel::Targeted {
                per_step: [vec![1, 2], vec![], vec![], vec![]],
            },
            ..pcfg_complete(3, 3, 4, 1)
        };
        let outcome = secure_mean(&locals, &q, &pcfg);
        assert!(!outcome.reliable);
        assert!(outcome.mean.is_none());
        assert!(outcome.abort.is_some(), "abort reason must be surfaced");
    }

    #[test]
    fn sparse_secure_mean_updates_only_the_round_support() {
        // constant 1.0 updates under RandK: the decoded mean is ≈1.0 on the
        // round's support and exactly 0.0 elsewhere (0 dequantizes to 0)
        let n = 6;
        let dim = 10;
        let k = 4;
        let locals = vec![vec![1.0f32; dim]; n];
        let q = Quantizer::for_sum_of(32, 4.0, n);
        let pcfg = ProtocolConfig {
            codec: Codec::RandK { k },
            ..pcfg_complete(n, n / 2 + 1, dim, 0xF00D)
        };
        let outcome = secure_mean(&locals, &q, &pcfg);
        assert!(outcome.reliable);
        let mean = outcome.mean.unwrap();
        let support = outcome.plan.indices().unwrap().to_vec();
        let tol = (q.sum_error_bound(n) / n as f64 + 1e-6) as f32;
        for (j, m) in mean.iter().enumerate() {
            if support.contains(&(j as u32)) {
                assert!((m - 1.0).abs() <= tol, "support coord {j}: {m}");
            } else {
                assert_eq!(*m, 0.0, "off-support coord {j} must stay untouched");
            }
        }
    }

    #[test]
    fn fl_scenario_accumulates_round_means() {
        let n = 6;
        let rounds = 4;
        let sc = scenario(n, rounds, ChurnModel::None);
        // client c always pushes a constant update of (c+1)/10
        let hist = run_fl_scenario(&sc, |_, client, _, _| {
            vec![(client as f32 + 1.0) / 10.0; 5]
        })
        .unwrap();
        assert_eq!(hist.logs.len(), rounds);
        assert_eq!(hist.unreliable_rounds(), 0);
        let per_round_mean: f32 =
            (1..=n).map(|c| c as f32 / 10.0).sum::<f32>() / n as f32;
        let expect = per_round_mean * rounds as f32;
        for g in &hist.global {
            assert!((g - expect).abs() < 5e-3, "global {g} vs {expect}");
        }
        assert!(hist.total_stats.server_total() > 0);
    }

    #[test]
    fn fl_scenario_unreliable_round_keeps_global() {
        let n = 6;
        // round 0 loses 4 of 6 clients at step 3 → |V4| = 2 < t → unreliable
        let script = vec![
            [vec![], vec![], vec![], vec![0, 1, 2, 3]],
            [vec![], vec![], vec![], vec![]],
        ];
        let sc = scenario(n, 2, ChurnModel::Scripted { rounds: script });
        let hist = run_fl_scenario(&sc, |_, _, _, _| vec![1.0f32; 5]).unwrap();
        assert!(!hist.logs[0].reliable);
        assert!(hist.logs[1].reliable);
        // only the reliable round contributed its mean (= 1.0)
        for g in &hist.global {
            assert!((g - 1.0).abs() < 5e-3, "global {g}");
        }
    }

    #[test]
    fn fl_scenario_sees_running_global() {
        let sc = scenario(5, 3, ChurnModel::None);
        // update = current global's first element + 1, so the global grows
        // 1, 2, 4 → the oracle genuinely observes the evolving model
        let hist = run_fl_scenario(&sc, |_, _, global, _| vec![global[0] + 1.0; 5]).unwrap();
        assert!((hist.global[0] - 7.0).abs() < 0.05, "global {}", hist.global[0]);
    }

    #[test]
    fn session_error_feedback_beats_oracle_topk_at_equal_k() {
        // 2 "big" coordinates (1.0/round) and 6 "small" ones (0.4/round),
        // identical across clients, aggregated under TopK k=2 for one cold
        // round plus six more. The oracle cold path re-picks the two big
        // coordinates every round and the small mass is lost forever; the
        // session's error feedback banks it in residuals until it outranks
        // the big coordinates and ships with interest. Equal k, equal
        // rounds — the only difference is the residual.
        let n = 6;
        let dim = 8;
        let rounds = 7u64; // cold + 6 warm
        let update = |_: u64, _: usize, _: &[f32], _: &mut Rng| {
            let mut u = vec![0.4f32; dim];
            u[0] = 1.0;
            u[1] = 1.0;
            u
        };
        let dense_ref: Vec<f32> = (0..dim)
            .map(|j| (if j < 2 { 1.0f32 } else { 0.4 }) * rounds as f32)
            .collect();
        let l1 = |global: &[f32]| -> f32 {
            global.iter().zip(&dense_ref).map(|(g, d)| (g - d).abs()).sum()
        };

        let ssc = SessionScenario {
            name: "ef-convergence".to_string(),
            n,
            dim,
            mask_bits: 32,
            t: n / 2 + 1,
            topology: Topology::Complete,
            codec: CodecSpec::TopK { frac: 2.0 / dim as f64 },
            warm_rounds: rounds - 1,
            attendance: Attendance::Full,
            seed: 0xEF,
        };
        let ef = run_fl_session(&ssc, 4.0, update).unwrap();
        assert_eq!(ef.unreliable_rounds(), 0);

        let oracle = Scenario {
            name: "ef-oracle-baseline".to_string(),
            n,
            dim,
            mask_bits: 32,
            rounds: rounds as usize,
            topology: TopologySchedule::Static(Topology::Complete),
            churn: ChurnModel::None,
            adversary: AdversarySpec::Eavesdropper,
            threshold: ThresholdRule::Fixed(n / 2 + 1),
            codec: CodecSpec::TopK { frac: 2.0 / dim as f64 },
            clip: 4.0,
            seed: 0xEF,
        };
        let or = run_fl_scenario(&oracle, |r, c, g, rng| update(r as u64, c, g, rng)).unwrap();
        assert_eq!(or.unreliable_rounds(), 0);

        // the oracle path never touches the small coordinates at all
        for j in 2..dim {
            assert_eq!(or.global[j], 0.0, "oracle starves coordinate {j}");
        }
        // error feedback does: every coordinate moves by the end
        assert!(
            ef.global[2..].iter().all(|&g| g > 0.0),
            "EF must eventually ship the starved coordinates: {:?}",
            ef.global
        );
        // and the headline: at equal k and equal rounds, the EF trajectory
        // is strictly closer to the dense reference (≈9.6 vs ≈16.8 here;
        // the wide margin absorbs quantization noise and tie-break choice)
        assert!(
            l1(&ef.global) < l1(&or.global) * 0.8,
            "EF L1 error {} vs oracle {}",
            l1(&ef.global),
            l1(&or.global)
        );
    }

    #[test]
    fn session_fl_loop_matches_cold_loop_on_dense_rounds() {
        // with the dense codec there is no support selection and no
        // residual: cold-per-round and warm-session aggregation see the
        // same updates, so the trajectories must agree to quantization
        // precision (the transports differ, the math must not)
        let n = 6;
        let dim = 5;
        let update = |_: u64, client: usize, _: &[f32], _: &mut Rng| {
            vec![(client as f32 + 1.0) / 10.0; dim]
        };
        let ssc = SessionScenario {
            name: "dense-session".to_string(),
            n,
            dim,
            mask_bits: 32,
            t: n / 2 + 1,
            topology: Topology::Complete,
            codec: CodecSpec::Dense,
            warm_rounds: 3,
            attendance: Attendance::Full,
            seed: 0xDE5E,
        };
        let hist = run_fl_session(&ssc, 4.0, update).unwrap();
        assert_eq!(hist.logs.len(), 4);
        assert_eq!(hist.unreliable_rounds(), 0);
        let per_round_mean: f32 = (1..=n).map(|c| c as f32 / 10.0).sum::<f32>() / n as f32;
        let expect = per_round_mean * 4.0;
        for g in &hist.global {
            assert!((g - expect).abs() < 5e-3, "global {g} vs {expect}");
        }
        // the session rounds actually amortized: warm setup traffic per
        // round is below the cold round's
        let cold_up = hist.logs[0].bytes_up;
        for l in &hist.logs[1..] {
            assert!(l.bytes_up < cold_up, "round {}: {} vs cold {cold_up}", l.round, l.bytes_up);
        }
    }

    #[test]
    fn fl_scenario_sparse_codec_accumulates_on_support_only() {
        // constant 1.0 updates through RandK rounds: each coordinate of the
        // global grows by exactly its per-round support hit count
        let n = 6;
        let rounds = 3;
        let mut sc = scenario(n, rounds, ChurnModel::None);
        sc.codec = CodecSpec::RandK { frac: 0.4 }; // dim 5 → k = 2
        let hist = run_fl_scenario(&sc, |_, _, _, _| vec![1.0f32; 5]).unwrap();
        assert_eq!(hist.unreliable_rounds(), 0);
        let mut hits = vec![0u32; sc.dim];
        for plan in sc.compile() {
            let p = plan.cfg.codec.plan(sc.dim, sc.mask_bits, plan.cfg.seed, &[]);
            for &i in p.indices().unwrap() {
                hits[i as usize] += 1;
            }
        }
        assert!(hits.iter().sum::<u32>() > 0, "supports must be non-empty");
        for (j, g) in hist.global.iter().enumerate() {
            assert!((g - hits[j] as f32).abs() < 0.01, "coord {j}: {g} vs {}", hits[j]);
        }
    }
}

