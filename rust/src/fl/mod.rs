//! Federated-learning orchestration on top of the secure-aggregation
//! protocol and the PJRT model runtime.
//!
//! * [`data`] — synthetic datasets standing in for CIFAR-10 and the AT&T
//!   face database (DESIGN.md documents the substitutions), plus the
//!   i.i.d. and non-i.i.d. (shard) partitions of McMahan et al.;
//! * [`rounds`] — the FL round loop: client selection, local SGD via the
//!   HLO train step, quantization, the SA/CCESA aggregation round,
//!   dequantization and the global update. An unreliable round keeps the
//!   previous global model (§4.3.2 of the paper).

pub mod data;
pub mod rounds;
