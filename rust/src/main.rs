//! `ccesa` — the leader binary: analysis reports, single protocol rounds,
//! and config-driven federated-learning runs.
//!
//! ```text
//! ccesa analyze pstar          # Table F.4
//! ccesa analyze costs          # Table 1 cost model
//! ccesa analyze turbo          # §1 Turbo-aggregate comparison
//! ccesa analyze montecarlo     # empirical P_e vs Theorems 5/6
//! ccesa round --n 100 --p 0.64 --dim 10000   # one secure-agg round
//! ccesa round --spec specs/sweep.toml        # TOML round spec (flags override)
//! ccesa round --n 1000 --shards 10 --dim 100 # two-level hierarchical round
//! ccesa round --session runs/s --rounds 10   # cold round + 10 warm rounds
//! ccesa topology --n 1000 --shards 10        # planned shard layout + degrees
//! ccesa fl --config configs/quickstart.json  # config-driven FL run
//! ccesa kernels                              # kernel-dispatch report (JSON)
//! ccesa serve --n 1000 --addr 127.0.0.1:7171 # socket round server
//! ccesa serve --journal runs/j ...           # …with a crash-recovery journal
//! ccesa recover --journal runs/j ...         # finish an interrupted round
//! ccesa connect --n 1000 --addr ...          # drive n loopback clients
//! ```
//!
//! `round`, `topology`, `serve`, `recover` and `connect` all resolve one
//! [`RoundSpec`]: built-in defaults, overlaid by `--spec <file.toml>`,
//! overlaid by any flag explicitly passed (see `src/spec.rs` for the file
//! format). A spec with `[clock]` + `[timeouts]` sections runs virtual-
//! clock rounds; a `timeouts.sweep_ms` axis scores the phase-deadline
//! tradeoff (reliability/privacy/latency per deadline); `serve` maps the
//! same `[timeouts]` policy onto wall-clock poll deadlines.
//!
//! A journaled `serve` that dies — crash, kill, SIGTERM — leaves a
//! resumable round on disk; `recover` replays the journal and finishes the
//! round with the reconnecting clients (`connect` retries and resubmits
//! automatically). SIGTERM/SIGINT exit nonzero with the named
//! "round interrupted, resumable" error instead of dying mid-write.

use anyhow::{anyhow, bail, Result};
use ccesa::analysis::bounds::{
    p_star, per_step_q, t_rule, table_f4, theorem5_reliability_bound, theorem6_privacy_bound,
};
use ccesa::analysis::costs::{table1_row, turbo_comparison_ratio};
use ccesa::analysis::montecarlo::estimate_failure_rates;
use ccesa::fl::data::{partition_iid, partition_noniid, SyntheticCifar};
use ccesa::fl::rounds::{run_fl_mlp, Aggregation, FlConfig};
use ccesa::hier::{root_seed, shard_seed, HierOptions, HierRunner, ShardPlan};
use ccesa::protocol::dropout::DropoutModel;
use ccesa::protocol::engine::run_round;
use ccesa::protocol::{ProtocolConfig, Topology};
use ccesa::runtime::mlp::MlpRuntime;
use ccesa::runtime::Runtime;
use ccesa::spec::{parse_codec, RoundSpec};
use ccesa::util::cli::Args;
use ccesa::util::json::Json;
use ccesa::util::rng::Rng;
use std::sync::Arc;

fn main() -> Result<()> {
    ccesa::util::logging::init();
    let args = Args::new(
        "ccesa",
        "Communication-Computation Efficient Secure Aggregation (Choi et al. 2020)\n\
         subcommands: analyze {pstar|costs|turbo|montecarlo} | round | topology | fl \
         | kernels | serve | recover | connect",
    )
    .flag(
        "spec",
        None,
        "TOML round spec for round|topology|serve|recover|connect \
         (defaults ← file ← explicitly passed flags; see src/spec.rs)",
    )
    .flag("n", Some("100"), "number of clients")
    .flag("p", None, "ER connection probability (default: p*(n, qtotal))")
    .flag("t", None, "secret-sharing threshold (default: Remark 4 rule)")
    .flag("dim", Some("10000"), "model dimension for `round`")
    .flag("qtotal", Some("0.0"), "protocol-level dropout probability")
    .flag("trials", Some("500"), "Monte-Carlo trials")
    .flag("seed", Some("1"), "seed")
    .flag("config", None, "JSON config path for `fl`")
    .flag("codec", Some("dense"), "payload codec: dense | topk:<frac> | randk:<frac>")
    .flag("addr", Some("127.0.0.1:7171"), "listen/connect address for serve|connect")
    .flag("timeout-s", Some("120"), "wire round wall-clock budget in seconds")
    .flag(
        "journal",
        None,
        "serve: journal directory for crash recovery; recover: journal file (or its directory)",
    )
    .flag(
        "session",
        None,
        "round: session directory — establish a cross-round session with one cold \
         round, then run --rounds journaled warm rounds in it",
    )
    .flag("rounds", Some("5"), "warm rounds to run under `round --session`")
    .flag(
        "shards",
        None,
        "round|topology: shard count — run a two-level hierarchical round \
         (CCESA inside each shard, then across shard aggregators)",
    )
    .flag(
        "shard-size",
        None,
        "round|topology: target clients per shard (alternative to --shards)",
    )
    .switch("sa", "use the complete graph (Bonawitz et al. SA)")
    .switch("check", "serve: verify the wire round against the in-process engine")
    .parse();

    let sub: Vec<&str> = args.positional().iter().map(|s| s.as_str()).collect();
    match sub.first().copied() {
        Some("analyze") => analyze(&args, sub.get(1).copied().unwrap_or("pstar")),
        Some("round") => round(&RoundSpec::resolve(&args)?),
        Some("topology") => topology_cmd(&RoundSpec::resolve(&args)?),
        Some("fl") => fl(&args),
        // kernel-dispatch audit: which GF(2^16)/mask backend this process
        // selected (cpuid + CCESA_KERNEL), as JSON on stdout — CI asserts
        // on it and archives it next to the bench reports
        Some("kernels") => {
            println!("{}", ccesa::kernels::report_json());
            Ok(())
        }
        Some("serve") => serve_cmd(&RoundSpec::resolve(&args)?, args.get_bool("check")),
        Some("recover") => recover_cmd(&RoundSpec::resolve(&args)?),
        Some("connect") => connect_cmd(&RoundSpec::resolve(&args)?),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand {o:?}\n");
            }
            eprintln!("{}", args.help_text());
            Ok(())
        }
    }
}

fn analyze(args: &Args, what: &str) -> Result<()> {
    match what {
        "pstar" => {
            println!("n, q_total, p* (Table F.4)");
            for (n, qt, p) in table_f4() {
                println!("{n},{qt},{p:.4}");
            }
        }
        "costs" => {
            for n in [100usize, 300, 500, 1000] {
                println!("{}", table1_row(n, 10_000, p_star(n, 0.0)));
            }
        }
        "turbo" => {
            let r = turbo_comparison_ratio(1_000_000, 100, 32, 10);
            println!(
                "CCESA / Turbo-aggregate client bandwidth = {r:.4} (paper: ≈0.03) \
                 at m=1e6, R=32, n=100, L=10, a_K=a_S=256"
            );
        }
        "montecarlo" => {
            let n: usize = args.req("n");
            let qt: f64 = args.req("qtotal");
            let trials: usize = args.req("trials");
            let p = args.get::<f64>("p").unwrap_or_else(|| p_star(n, qt));
            let t = args.get::<usize>("t").unwrap_or_else(|| t_rule(n, p));
            let q = per_step_q(qt);
            let est = estimate_failure_rates(n, p, q, t, trials, args.req("seed"));
            println!(
                "n={n} p={p:.4} t={t} q_total={qt} trials={trials}\n\
                 empirical P_e(reliability) = {:.5}  (Theorem 5 bound {:.3e})\n\
                 empirical P_e(privacy)     = {:.5}  (Theorem 6 bound {:.3e})",
                est.p_e_reliability,
                theorem5_reliability_bound(n, p, q, t),
                est.p_e_privacy,
                theorem6_privacy_bound(n, p, q),
            );
        }
        other => bail!("unknown analyze target {other:?} (pstar|costs|turbo|montecarlo)"),
    }
    Ok(())
}

fn round(spec: &RoundSpec) -> Result<()> {
    if let Some(plan) = spec.shard_plan()? {
        return hier_round(spec, plan);
    }
    if let Some(t) = &spec.timeouts {
        if !t.sweep_ms.is_empty() {
            return timeout_sweep(spec);
        }
    }
    if spec.clock.is_some() {
        return clocked_rounds(spec);
    }
    let cfg = spec.protocol_config()?;
    if let Some(dir) = spec.session.clone() {
        return session_rounds(spec, &cfg, &dir);
    }
    let (n, dim) = (spec.n, spec.dim);
    let (p, t) = spec.graph_params();
    let mut rng = Rng::new(spec.seed);
    let models: Vec<Vec<u64>> = (0..n)
        .map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect())
        .collect();
    let r = run_round(&cfg, &models)?;
    println!(
        "scheme={} n={n} t={t} p={p:.4} dim={dim} codec={}\n\
         reliable={} |V1..V4|={},{},{},{}\n\
         sum==truth: {}\nbytes up/down per step: {:?} / {:?}\nmasked payload bytes: {}\n\
         client ms (mean): step0={:.3} step1={:.3} step2={:.3} step3={:.3}; server total={:.1} ms",
        if spec.sa { "SA" } else { "CCESA" },
        cfg.codec.name(),
        r.reliable,
        r.sets.v1.len(),
        r.sets.v2.len(),
        r.sets.v3.len(),
        r.sets.v4.len(),
        r.sum.as_deref() == Some(&r.true_sum_v3[..]),
        r.stats.bytes_up,
        r.stats.bytes_down,
        r.stats.masked_payload_bytes,
        r.times.total_ms("client_step0") / n as f64,
        r.times.total_ms("client_step1") / n as f64,
        r.times.total_ms("client_step2") / n as f64,
        r.times.total_ms("client_step3") / n as f64,
        r.times.total_ms("server_step0")
            + r.times.total_ms("server_step1")
            + r.times.total_ms("server_step2")
            + r.times.total_ms("server_finalize"),
    );
    Ok(())
}

/// `[timeouts] sweep_ms` + `[clock]`: score reliability/privacy/simulated
/// latency at each uniform phase deadline — the campaign's deadline axis.
fn timeout_sweep(spec: &RoundSpec) -> Result<()> {
    let ts = spec.timeouts.as_ref().expect("validate: sweep implies [timeouts]");
    let clock = spec.clock.as_ref().expect("validate: sweep implies [clock]");
    let sc = spec.scenario("spec-sweep");
    let deadlines_us: Vec<u64> = ts.sweep_ms.iter().map(|ms| ms * 1_000).collect();
    let rep = ccesa::sim::run_timeout_sweep(&sc, clock, &deadlines_us, ts.min_survivors);
    print!("{}", rep.render());
    Ok(())
}

/// `[clock]` + `[timeouts]` without a sweep: run the spec's rounds on the
/// virtual clock and report each timeline next to the engine reference.
fn clocked_rounds(spec: &RoundSpec) -> Result<()> {
    let csc = spec.clocked_scenario("spec-clocked").expect("validate: clock implies [timeouts]");
    let plans = csc.base.compile();
    let colluders = csc.base.adversary.colluders();
    println!(
        "clocked rounds: n={} dim={} rounds={} phase deadlines {:?} ms min_survivors={}",
        spec.n,
        spec.dim,
        plans.len(),
        spec.timeouts.as_ref().map(|t| t.phase_ms).unwrap_or_default(),
        csc.policy.min_survivors,
    );
    for plan in &plans {
        let models = csc.base.round_models(plan.round);
        let sched = Arc::new(csc.schedule_for(plan.round));
        let out = ccesa::sim::run_clocked_plan(plan, &models, &sched, &csc.policy, colluders);
        let drops: Vec<usize> = out.timeline.dropped.iter().map(|d| d.len()).collect();
        println!(
            "round {}: reliable={} aborted={} |V3|={} timeout drops per phase {:?} \
             simulated latency {} µs (engine reference agrees: {})",
            plan.round,
            out.clocked.reliable,
            out.clocked.aborted,
            out.clocked.sets.v3.len(),
            drops,
            out.timeline.total_us(),
            out.clocked.sum == out.engine.sum && out.clocked.sets == out.engine.sets,
        );
    }
    Ok(())
}

/// `ccesa round` with `[shards]`: one two-level hierarchical round —
/// CCESA inside every shard, then CCESA across the shard aggregators —
/// driven by [`HierRunner`].
fn hier_round(spec: &RoundSpec, plan: ShardPlan) -> Result<()> {
    let n = plan.n();
    let dim = spec.dim;
    let seed = spec.seed;
    let (p, t, sa) = spec.shard_graph_params(&plan);
    let intra = if sa { Topology::Complete } else { Topology::ErdosRenyi { p } };
    let cfg = ProtocolConfig::builder()
        .clients(n)
        .threshold(t)
        .model_dim(dim)
        .topology(Topology::Hierarchical {
            shards: plan.shards(),
            intra: Box::new(intra),
            root: Box::new(Topology::Complete),
        })
        .dropout(if spec.qtotal > 0.0 {
            DropoutModel::iid_from_total(spec.qtotal)
        } else {
            DropoutModel::None
        })
        .codec(spec.codec.resolve(dim))
        .seed(seed)
        .build()?;
    let mut rng = Rng::new(seed);
    let models: Vec<Vec<u64>> = (0..n)
        .map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect())
        .collect();
    let runner = HierRunner::new(HierOptions { check_theorem1: true, ..HierOptions::default() });
    let r = runner.run(&cfg, &models)?;
    let shards_ok = r.shard_reports.iter().filter(|s| s.completed && s.reliable).count();
    let shards_in_root = match &r.root {
        Some(l) => l.sets.v3.len(),
        None => usize::from(r.reliable),
    };
    let theorem1_all = r
        .shard_reports
        .iter()
        .map(|s| s.theorem1_holds)
        .chain(r.root.as_ref().map(|l| l.theorem1_holds))
        .all(|h| h != Some(false));
    println!(
        "scheme={} hierarchical n={n} shards={} (sizes {}..={}) t={t} p={:.4} dim={dim} codec={}\n\
         reliable={} shard rounds ok: {shards_ok}/{} in root V3: {shards_in_root}\n\
         |global V3|={} coverage={:.1}% theorem1(all levels)={theorem1_all}\n\
         sum==truth: {}\nbytes: intra {} + root {} = {} total",
        if sa { "SA" } else { "CCESA" },
        plan.shards(),
        plan.min_size(),
        plan.max_size(),
        p,
        cfg.codec.name(),
        r.reliable,
        plan.shards(),
        r.global_v3.len(),
        r.global_v3.len() as f64 / n as f64 * 100.0,
        r.sum.is_some() && r.sum == r.true_sum,
        r.stats.intra.server_total(),
        r.stats.root.server_total(),
        r.stats.total_bytes(),
    );
    Ok(())
}

/// `ccesa topology`: print the planned shard layout and the per-level
/// graphs exactly as a hierarchical round would build them (each shard
/// graph from its ratcheted shard seed, the root graph from the root seed).
/// Without shards it reports the flat single-level graph.
fn topology_cmd(spec: &RoundSpec) -> Result<()> {
    let n = spec.n;
    let seed = spec.seed;
    let plan = match spec.shard_plan()? {
        Some(p) => p,
        None => ShardPlan::new(n, 1)?,
    };
    let (p, t, sa) = spec.shard_graph_params(&plan);
    let intra = if sa { Topology::Complete } else { Topology::ErdosRenyi { p } };
    println!(
        "n={n} shards={} sizes {}..={} t={t} intra={} root=Complete",
        plan.shards(),
        plan.min_size(),
        plan.max_size(),
        if sa { "Complete".to_string() } else { format!("ErdosRenyi(p={p:.4})") },
    );
    const SHOWN: usize = 8;
    for s in 0..plan.shards().min(SHOWN) {
        let (lo, hi) = plan.range(s);
        println!("  shard {s}: clients {lo}..{hi} ({} members)", hi - lo);
    }
    if plan.shards() > SHOWN {
        println!("  … {} more shards", plan.shards() - SHOWN);
    }
    let (mut dmin, mut dmax, mut dsum, mut disconnected) = (usize::MAX, 0usize, 0.0f64, 0usize);
    for s in 0..plan.shards() {
        // the single-shard degenerate case runs as a *flat* round on the
        // master seed; multi-shard rounds ratchet a seed per shard
        let level_seed = if plan.shards() == 1 { seed } else { shard_seed(seed, s) };
        let g = intra.build(plan.len_of(s), &mut Rng::new(level_seed));
        let (lo, hi) = g.degree_range();
        dmin = dmin.min(lo);
        dmax = dmax.max(hi);
        dsum += g.mean_degree();
        disconnected += usize::from(!g.is_connected());
    }
    println!(
        "intra-shard graphs: degree min/mean/max = {dmin}/{:.2}/{dmax}, \
         {disconnected}/{} disconnected",
        dsum / plan.shards() as f64,
        plan.shards(),
    );
    if plan.shards() > 1 {
        let g = Topology::Complete.build(plan.shards(), &mut Rng::new(root_seed(seed)));
        let (lo, hi) = g.degree_range();
        println!(
            "root graph over {} aggregators: degree min/mean/max = {lo}/{:.2}/{hi}, \
             connected={}",
            plan.shards(),
            g.mean_degree(),
            g.is_connected(),
        );
    }
    Ok(())
}

/// `ccesa round` with `[session]`: establish a cross-round session with one
/// cold round, then run the spec's warm rounds over fresh synthetic models,
/// each journaled under the session dir (one recoverable `.ccj` per warm
/// round). Prints the amortization ledger: per-round setup bytes as a
/// fraction of the cold round's, plus coordinate-map and re-key traffic.
fn session_rounds(spec: &RoundSpec, cfg: &ProtocolConfig, dir: &str) -> Result<()> {
    use ccesa::protocol::session::Session;
    let rounds = spec.rounds;
    let seed = spec.seed;
    let modmask = 0xFFFF_FFFFu64;
    let models_for = |round: u64| -> Vec<Vec<u64>> {
        let mut rng = Rng::new(ccesa::protocol::session::round_seed(seed, round) ^ 0x5E55);
        (0..cfg.n)
            .map(|_| (0..cfg.dim).map(|_| rng.next_u64() & modmask).collect())
            .collect()
    };
    let (mut session, cold) = Session::establish(cfg, &models_for(0))?;
    let cold_setup = cold.stats.setup_bytes();
    println!(
        "session established: {} members, cold round setup {} bytes, journal dir {dir}",
        session.members().len(),
        cold_setup,
    );
    let opts = ccesa::coordinator::RoundOptions::builder().journal(dir.to_string()).build()?;
    let active = vec![true; cfg.n];
    for round in 1..=rounds {
        let r = session.run_round(&models_for(round), &active, &opts)?;
        let s = &r.stats;
        println!(
            "warm round {round}: reliable={} |V3|={} setup {} bytes ({:.1}% of cold) \
             coord-map {} rekey {}/{} bytes",
            r.reliable,
            r.sets.v3.len(),
            s.setup_bytes(),
            s.setup_bytes() as f64 / cold_setup.max(1) as f64 * 100.0,
            s.coord_map_bytes,
            s.rekey_up,
            s.rekey_down,
        );
    }
    Ok(())
}

/// Shared setup for `serve`/`connect`: both endpoints derive the identical
/// round config, synthetic models and round tag from the same spec, so
/// the wire carries the protocol rather than the training pipeline.
///
/// `--check` is only meaningful for rng-free dropout (the default
/// `qtotal = 0`, where wire, event loop and engine are promised
/// bit-identical); under `Iid` dropout the engine draws lazily while wire
/// clients pre-draw, like the event loop.
fn wire_round_config(spec: &RoundSpec) -> Result<(ProtocolConfig, Vec<Vec<u64>>, u32)> {
    let mut rng = Rng::new(spec.seed ^ 0x5EED_CAFE);
    let models: Vec<Vec<u64>> = (0..spec.n)
        .map(|_| (0..spec.dim).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect())
        .collect();
    let cfg = spec.protocol_config()?;
    let round = ccesa::net::socket::round_tag(spec.seed);
    Ok((cfg, models, round))
}

fn print_round_result(r: &ccesa::coordinator::CoordRoundResult) {
    println!(
        "reliable={} |V1..V4|={},{},{},{} framed up/down = {}/{} bytes (logical {}/{})",
        r.reliable,
        r.sets.v1.len(),
        r.sets.v2.len(),
        r.sets.v3.len(),
        r.sets.v4.len(),
        r.stats.framed_up,
        r.stats.framed_down,
        r.stats.bytes_up.iter().sum::<u64>(),
        r.stats.bytes_down.iter().sum::<u64>(),
    );
    if let Some(tl) = &r.timeline {
        println!(
            "phase deadlines: dropped {:?} (per phase), elapsed {:?} µs, {} timeout drops",
            tl.dropped,
            tl.phase_elapsed_us,
            r.stats.timeout_drops.iter().sum::<u64>(),
        );
    }
}

fn serve_cmd(spec: &RoundSpec, check: bool) -> Result<()> {
    ccesa::util::shutdown::install_handlers();
    let (cfg, models, round) = wire_round_config(spec)?;
    let listener = std::net::TcpListener::bind(&spec.addr)?;
    println!("serving round {round:#010x} for n={} clients on {}", cfg.n, listener.local_addr()?);
    let setup = ccesa::coordinator::derive_round_setup(&cfg, &models);
    let mut opts = ccesa::coordinator::RoundOptions::builder()
        .executor(ccesa::coordinator::Executor::Wire)
        .timeout(spec.wire_timeout());
    if let Some(policy) = spec.timeout_policy() {
        println!(
            "phase deadlines {:?} ms, min_survivors {}",
            spec.timeouts.as_ref().map(|t| t.phase_ms).unwrap_or_default(),
            policy.min_survivors,
        );
        opts = opts.timeout_policy(policy);
    }
    if let Some(dir) = &spec.journal {
        opts = opts.journal(dir.clone());
        println!(
            "journaling to {} (resume with `ccesa recover --journal …` after a crash)",
            ccesa::journal::Journal::path_for(std::path::Path::new(dir), round).display()
        );
    }
    let opts = opts.build()?;
    let r = ccesa::net::socket::serve(&listener, &cfg, setup.plan, setup.graph, round, &opts)?;
    print_round_result(&r);
    if check {
        let sync = run_round(&cfg, &models)?;
        if r.reliable != sync.reliable {
            bail!("check: reliable {} over the wire vs {} in-process", r.reliable, sync.reliable);
        }
        if r.sets != sync.sets {
            bail!("check: survivor sets diverge: wire {:?} vs engine {:?}", r.sets, sync.sets);
        }
        if r.sum != sync.sum {
            bail!("check: aggregate sums diverge between wire and engine");
        }
        if !r.stats.logical_eq(&sync.stats) {
            bail!("check: logical NetStats diverge: wire {:?} vs engine {:?}", r.stats, sync.stats);
        }
        println!("check: wire round is bit-identical to the in-process engine");
    }
    Ok(())
}

/// Finish a round an interrupted journaled `serve` left on disk. Accepts
/// the journal file itself or the directory `serve --journal` was given
/// (the file name is then derived from the seed, like `serve` derived it).
fn recover_cmd(spec: &RoundSpec) -> Result<()> {
    ccesa::util::shutdown::install_handlers();
    let journal = spec
        .journal
        .clone()
        .ok_or_else(|| anyhow!("recover requires --journal <file-or-directory>"))?;
    let mut path = std::path::PathBuf::from(&journal);
    if path.is_dir() {
        path = ccesa::journal::Journal::path_for(&path, ccesa::net::socket::round_tag(spec.seed));
    }
    let listener = std::net::TcpListener::bind(&spec.addr)?;
    println!("resuming round from {} on {}", path.display(), listener.local_addr()?);
    let mut opts = ccesa::coordinator::RoundOptions::builder()
        .executor(ccesa::coordinator::Executor::Wire)
        .timeout(spec.wire_timeout());
    if let Some(policy) = spec.timeout_policy() {
        opts = opts.timeout_policy(policy);
    }
    let opts = opts.build()?;
    let r = ccesa::net::socket::serve_resume(&listener, &path, &opts)?;
    print_round_result(&r);
    Ok(())
}

fn connect_cmd(spec: &RoundSpec) -> Result<()> {
    let (cfg, models, round) = wire_round_config(spec)?;
    let addr: std::net::SocketAddr =
        spec.addr.parse().map_err(|e| anyhow!("bad --addr {:?}: {e}", spec.addr))?;
    // retries failed connects with jittered backoff and resubmits after a
    // server restart — the client side of `serve --journal` + `recover`
    ccesa::net::socket::drive_clients_retry(
        move || addr,
        &cfg,
        &models,
        round,
        spec.wire_timeout(),
    )?;
    println!("drove {} clients through round {round:#010x} against {addr}", cfg.n);
    Ok(())
}

fn fl(args: &Args) -> Result<()> {
    let path: String = args
        .get_str("config")
        .ok_or_else(|| anyhow!("fl requires --config <path> (see configs/)"))?;
    let text = std::fs::read_to_string(&path)?;
    let j = Json::parse(&text).map_err(|e| anyhow!("parsing {path}: {e}"))?;

    let n = j.get("clients").as_usize().unwrap_or(60);
    let rounds = j.get("rounds").as_usize().unwrap_or(30);
    let fraction = j.get("fraction").as_f64().unwrap_or(0.5);
    let qt = j.get("qtotal").as_f64().unwrap_or(0.0);
    let samples = j.get("samples").as_usize().unwrap_or(3000);
    let noise = j.get("noise").as_f64().unwrap_or(0.4) as f32;
    let seed = j.get("seed").as_u64().unwrap_or(7);
    let noniid = j.get("noniid").as_bool().unwrap_or(false);
    let scheme = j.get("scheme").as_str().unwrap_or("ccesa").to_string();

    let rt = Runtime::cpu_default()?;
    let mlp = MlpRuntime::load(&rt)?;
    let mut rng = Rng::new(seed);
    let (train, test) = SyntheticCifar::generate_split(
        samples,
        samples / 5,
        mlp.dims.d,
        mlp.dims.c,
        noise,
        &mut rng,
    );
    let parts = if noniid {
        partition_noniid(&train, n, &mut rng)
    } else {
        partition_iid(&train, n, &mut rng)
    };

    let k = ((n as f64) * fraction).round().max(1.0) as usize;
    // optional payload codec: {"codec": "randk:0.1"} etc., default dense
    let codec_spec = parse_codec(j.get("codec").as_str().unwrap_or("dense"))?;
    let codec = codec_spec.resolve(mlp.dims.param_count());
    let aggregation = match scheme.as_str() {
        "plain" | "fedavg" => Aggregation::Plain,
        "sa" => Aggregation::Secure {
            topology: Topology::Complete,
            t_override: Some(k / 2 + 1),
            mask_bits: 32,
            dropout: if qt > 0.0 { DropoutModel::iid_from_total(qt) } else { DropoutModel::None },
            codec,
        },
        "ccesa" => {
            let p = j.get("p").as_f64().unwrap_or_else(|| p_star(k, qt));
            Aggregation::Secure {
                topology: Topology::ErdosRenyi { p },
                t_override: Some(t_rule(k, p).min(k.saturating_sub(1).max(1))),
                mask_bits: 32,
                dropout: if qt > 0.0 {
                    DropoutModel::iid_from_total(qt)
                } else {
                    DropoutModel::None
                },
                codec,
            }
        }
        other => bail!("unknown scheme {other:?} (plain|sa|ccesa)"),
    };
    let cfg = FlConfig {
        n_clients: n,
        rounds,
        client_fraction: fraction,
        local_epochs: j.get("local_epochs").as_usize().unwrap_or(2),
        lr: j.get("lr").as_f64().unwrap_or(0.5) as f32,
        clip: j.get("clip").as_f64().unwrap_or(4.0) as f32,
        aggregation,
        seed,
    };
    let hist = run_fl_mlp(&cfg, &mlp, &train, &parts, &test)?;
    for l in &hist.logs {
        println!(
            "round={} loss={:.4} acc={:.4} reliable={}",
            l.round, l.mean_local_loss, l.test_accuracy, l.reliable
        );
    }
    println!(
        "final_accuracy={:.4} unreliable={}/{} comm_MiB={:.2}",
        hist.final_accuracy(),
        hist.unreliable_rounds(),
        rounds,
        hist.total_stats.server_total() as f64 / (1024.0 * 1024.0)
    );
    Ok(())
}
