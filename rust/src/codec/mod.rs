//! The payload codec layer: how a client's dense model update becomes the
//! maskable field vector that travels on the wire.
//!
//! Sparse secret-sharing graphs (this paper) cut the *key/share* traffic;
//! sparsifying the *payload* itself — Beguier et al. (Efficient Sparse
//! Secure Aggregation), Ergün et al. (Sparsified Secure Aggregation) —
//! cuts the dominant masked-model bytes too. A [`Codec`] chooses which
//! coordinates of the dense update enter a round:
//!
//! * [`Codec::Dense`] — the identity codec: every coordinate, bit-identical
//!   to the pre-codec protocol (same wire bytes, same keystream positions,
//!   same aggregate).
//! * [`Codec::TopK`] — global top-k sparsification: the k coordinates with
//!   the largest summed two's-complement magnitude across the round's
//!   updates. The scoring is an oracle computed by the round driver (which,
//!   in simulation, holds every update); a deployment would rank by the
//!   previous round's public global update instead, so the map is shared
//!   knowledge either way and costs no extra wire bytes.
//! * [`Codec::RandK`] — random-k sparsification: k coordinates drawn from
//!   `Rng::new(seed ^ INDEX_SEED_SALT)` — derivable by every party from
//!   the public round seed alone.
//!
//! **Why a shared index plan.** Pairwise masks cancel *positionally*:
//! survivor i adds `PRG(s_{i,j})[p]` where survivor j subtracts it, so both
//! must agree on which dense coordinate position p refers to. A single
//! per-round [`IndexPlan`] — same for every client — keeps the packed
//! windows aligned, which is what lets the server unmask a sparse round
//! with the unchanged counter-seekable range APIs
//! ([`crate::crypto::prg::apply_mask_range`] / `MaskJob`): the packed
//! vector of length k simply *is* the mask domain, and any shard `[a, b)`
//! of it regenerates exactly keystream elements `a..b`.
//!
//! An [`EncodedUpdate`] is the value windows plus (a shared handle to) the
//! coordinate map; [`IndexPlan::scatter`] lifts a packed aggregate back to
//! the dense domain with zeros off support, so a reliable round's sum is
//! always a `dim`-length vector whatever the codec.

use crate::util::mod_mask;
use crate::util::rng::Rng;
use std::sync::Arc;

/// Domain-separation salt for the RandK index seed: the coordinate draw
/// must not correlate with the graph/key/share streams that also derive
/// from the round seed.
pub const INDEX_SEED_SALT: u64 = 0x1DE5_EED0_C0DE_C0DE;

/// Which payload codec a round runs (carried by
/// [`crate::protocol::ProtocolConfig`], validated by its builder).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Codec {
    /// Identity: the full dense vector (the pre-codec protocol).
    Dense,
    /// Global top-k by summed magnitude (oracle scoring, see module docs).
    TopK { k: usize },
    /// k coordinates drawn from the public round seed.
    RandK { k: usize },
}

impl Codec {
    pub fn name(&self) -> &'static str {
        match self {
            Codec::Dense => "dense",
            Codec::TopK { .. } => "topk",
            Codec::RandK { .. } => "randk",
        }
    }

    /// Build the round's shared index plan. `models` is the TopK scoring
    /// oracle (one quantized update per client); Dense and RandK ignore it.
    /// Every driver (sync engine, event loop) calls this with the same
    /// inputs and therefore derives the same plan.
    pub fn plan(
        &self,
        dim: usize,
        mask_bits: u32,
        seed: u64,
        models: &[Vec<u64>],
    ) -> Arc<IndexPlan> {
        match self {
            Codec::Dense => IndexPlan::identity(dim),
            Codec::RandK { k } => {
                assert!(*k >= 1 && *k <= dim, "RandK k={k} out of 1..=dim={dim}");
                let mut rng = Rng::new(seed ^ INDEX_SEED_SALT);
                let mut idx: Vec<u32> =
                    rng.sample_indices(dim, *k).into_iter().map(|i| i as u32).collect();
                idx.sort_unstable();
                IndexPlan::sparse(idx, dim)
            }
            Codec::TopK { k } => {
                assert!(*k >= 1 && *k <= dim, "TopK k={k} out of 1..=dim={dim}");
                // Score = Σ_i |update_i[j]| in two's complement over Z_{2^b};
                // ties break toward the lower coordinate so the selection is
                // a pure function of (models, mask_bits).
                let mut scores = vec![0u128; dim];
                for m in models {
                    for (s, &w) in scores.iter_mut().zip(m.iter()) {
                        *s += magnitude(w, mask_bits) as u128;
                    }
                }
                let mut order: Vec<u32> = (0..dim as u32).collect();
                // Partial select: only the top-k set is needed, not a full
                // ranking — O(dim + k log k) instead of O(dim log dim). The
                // comparator is a total order (index tie-break), so the
                // selected set is identical to a full sort's prefix.
                order.select_nth_unstable_by(*k - 1, |a, b| {
                    scores[*b as usize]
                        .cmp(&scores[*a as usize])
                        .then_with(|| a.cmp(b))
                });
                let mut idx: Vec<u32> = order[..*k].to_vec();
                idx.sort_unstable();
                IndexPlan::sparse(idx, dim)
            }
        }
    }
}

/// A client's *local* top-k support: the k coordinates of `eff` (its
/// error-feedback-corrected update, see `protocol::session`) with the
/// largest two's-complement magnitude, ties toward the lower coordinate.
/// Returned sorted ascending — the per-client half of the deployment-grade
/// TopK path, where ranking needs only local knowledge (vs the
/// [`Codec::plan`] oracle, which sums magnitudes across all clients).
pub fn local_topk(eff: &[u64], bits: u32, k: usize) -> Vec<u32> {
    let dim = eff.len();
    assert!(k >= 1 && k <= dim, "local_topk k={k} out of 1..=dim={dim}");
    let mut order: Vec<u32> = (0..dim as u32).collect();
    order.select_nth_unstable_by(k - 1, |a, b| {
        magnitude(eff[*b as usize], bits)
            .cmp(&magnitude(eff[*a as usize], bits))
            .then_with(|| a.cmp(b))
    });
    let mut idx = order[..k].to_vec();
    idx.sort_unstable();
    idx
}

/// Union of per-client supports into one round coordinate map (sorted,
/// deduplicated) — what the server assembles from the uploaded local-top-k
/// sets before announcing the round's shared [`IndexPlan`].
pub fn union_support(supports: &[Vec<u32>], dim: usize) -> Vec<u32> {
    let mut present = vec![false; dim];
    for s in supports {
        for &i in s {
            assert!((i as usize) < dim, "support index {i} out of dim {dim}");
            present[i as usize] = true;
        }
    }
    (0..dim as u32).filter(|&i| present[i as usize]).collect()
}

/// Two's-complement magnitude of a masked-domain word: |x| where x is the
/// signed interpretation of `w` in Z_{2^bits}.
#[inline]
pub(crate) fn magnitude(w: u64, bits: u32) -> u64 {
    let m = (w & mod_mask(bits)) as u128;
    let half = 1u128 << (bits - 1);
    if m >= half {
        ((1u128 << bits) - m) as u64
    } else {
        m as u64
    }
}

/// The round's shared coordinate map: which dense coordinates the packed
/// payload covers, in ascending order. One plan per round, shared by every
/// client and the server (`Arc`), so windows align and pairwise masks
/// cancel positionally.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IndexPlan {
    /// Sorted, deduplicated selected coordinates; `None` = identity
    /// (all of `0..dim`, no gather/scatter on the hot path).
    indices: Option<Vec<u32>>,
    dim: usize,
}

impl IndexPlan {
    /// The identity plan: every coordinate of a `dim`-length model.
    pub fn identity(dim: usize) -> Arc<IndexPlan> {
        Arc::new(IndexPlan { indices: None, dim })
    }

    /// A sparse plan over the given sorted coordinate set.
    pub fn sparse(indices: Vec<u32>, dim: usize) -> Arc<IndexPlan> {
        assert!(
            indices.windows(2).all(|w| w[0] < w[1]),
            "index plan must be strictly ascending"
        );
        if let Some(&last) = indices.last() {
            assert!((last as usize) < dim, "index {last} out of dim {dim}");
        }
        Arc::new(IndexPlan { indices: Some(indices), dim })
    }

    /// Packed payload length (= masked-vector length on the wire).
    pub fn len(&self) -> usize {
        match &self.indices {
            None => self.dim,
            Some(idx) => idx.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Dense model dimension this plan was built for.
    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn is_identity(&self) -> bool {
        self.indices.is_none()
    }

    /// The selected dense coordinates, or `None` for the identity plan.
    pub fn indices(&self) -> Option<&[u32]> {
        self.indices.as_deref()
    }

    /// Gather the plan's coordinates from a dense vector, reducing each
    /// word into Z_{2^bits}. For the identity plan this is exactly the
    /// pre-codec `model.iter().map(|&w| w & mask)` pass — bit-identical.
    pub fn encode(&self, dense: &[u64], bits: u32) -> Vec<u64> {
        assert_eq!(dense.len(), self.dim, "encode: model dimension mismatch");
        let mask = mod_mask(bits);
        match &self.indices {
            None => dense.iter().map(|&w| w & mask).collect(),
            Some(idx) => idx.iter().map(|&i| dense[i as usize] & mask).collect(),
        }
    }

    /// Lift a packed aggregate back to the dense domain: selected
    /// coordinates take the packed values, everything else is 0 (which
    /// dequantizes to 0.0 under the two's-complement quantizer).
    pub fn scatter(&self, packed: &[u64]) -> Vec<u64> {
        assert_eq!(packed.len(), self.len(), "scatter: payload length mismatch");
        match &self.indices {
            None => packed.to_vec(),
            Some(idx) => {
                let mut dense = vec![0u64; self.dim];
                for (&i, &v) in idx.iter().zip(packed.iter()) {
                    dense[i as usize] = v;
                }
                dense
            }
        }
    }

    /// Zero every off-support coordinate of a dense vector in place — the
    /// projection that makes a dense ground-truth sum comparable with a
    /// scattered sparse aggregate.
    pub fn project(&self, dense: &mut [u64]) {
        assert_eq!(dense.len(), self.dim, "project: dimension mismatch");
        let Some(idx) = &self.indices else { return };
        let mut next = idx.iter().copied().peekable();
        for (j, w) in dense.iter_mut().enumerate() {
            if next.peek() == Some(&(j as u32)) {
                next.next();
            } else {
                *w = 0;
            }
        }
    }
}

/// A client update encoded for one round: the maskable value windows plus
/// a handle to the round's shared coordinate map. `values[p]` is the
/// (masked) field element for dense coordinate `plan.indices()[p]` (or
/// `p` itself under the identity plan).
#[derive(Debug, Clone)]
pub struct EncodedUpdate {
    pub values: Vec<u64>,
    pub plan: Arc<IndexPlan>,
}

impl EncodedUpdate {
    /// Wire bytes of the masked value windows (the coordinate map is
    /// derived knowledge — round seed or public scoring — and costs none).
    pub fn payload_bytes(&self, bits: u32) -> usize {
        self.values.len() * bits.div_ceil(8) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_plan_is_identity() {
        let plan = Codec::Dense.plan(6, 32, 9, &[]);
        assert!(plan.is_identity());
        assert_eq!(plan.len(), 6);
        assert_eq!(plan.dim(), 6);
        let v = vec![1u64 << 40, 2, 3, 4, 5, 6];
        let enc = plan.encode(&v, 32);
        assert_eq!(enc, vec![0, 2, 3, 4, 5, 6], "encode reduces mod 2^32");
        assert_eq!(plan.scatter(&enc), enc, "identity scatter is a copy");
        let mut w = v.clone();
        plan.project(&mut w);
        assert_eq!(w, v, "identity projection is a no-op");
    }

    #[test]
    fn sparse_encode_scatter_project_round_trip() {
        let plan = IndexPlan::sparse(vec![1, 3, 4], 6);
        assert_eq!(plan.len(), 3);
        assert!(!plan.is_identity());
        let dense = vec![10u64, 11, 12, 13, 14, 15];
        let enc = plan.encode(&dense, 32);
        assert_eq!(enc, vec![11, 13, 14]);
        assert_eq!(plan.scatter(&enc), vec![0, 11, 0, 13, 14, 0]);
        let mut proj = dense.clone();
        plan.project(&mut proj);
        assert_eq!(proj, vec![0, 11, 0, 13, 14, 0]);
        // scatter ∘ encode == project for any dense vector already in-field
        assert_eq!(plan.scatter(&enc), proj);
    }

    #[test]
    fn randk_plan_is_seed_deterministic_and_seed_sensitive() {
        let c = Codec::RandK { k: 8 };
        let a = c.plan(100, 32, 7, &[]);
        let b = c.plan(100, 32, 7, &[]);
        let d = c.plan(100, 32, 8, &[]);
        assert_eq!(a, b);
        assert_ne!(a, d, "different round seeds must draw different supports");
        let idx = a.indices().unwrap();
        assert_eq!(idx.len(), 8);
        assert!(idx.windows(2).all(|w| w[0] < w[1]));
        assert!(idx.iter().all(|&i| (i as usize) < 100));
    }

    #[test]
    fn topk_plan_selects_largest_magnitudes() {
        // two clients; coordinate 2 carries a large negative (two's
        // complement) value — magnitude scoring must still select it
        let neg = (1u64 << 32) - 1000; // -1000 mod 2^32
        let models = vec![vec![1u64, 0, neg, 5, 2], vec![2u64, 0, 0, 900, 1]];
        let plan = Codec::TopK { k: 2 }.plan(5, 32, 3, &models);
        assert_eq!(plan.indices().unwrap(), &[2, 3], "|−1000| and 905 dominate");
    }

    #[test]
    fn topk_ties_break_toward_lower_index() {
        let models = vec![vec![7u64, 7, 7, 7]];
        let plan = Codec::TopK { k: 2 }.plan(4, 32, 0, &models);
        assert_eq!(plan.indices().unwrap(), &[0, 1]);
    }

    #[test]
    fn local_topk_ranks_by_own_magnitude() {
        let neg = (1u64 << 32) - 2000; // -2000 mod 2^32
        let eff = vec![5u64, neg, 0, 1999, 7];
        assert_eq!(local_topk(&eff, 32, 2), vec![1, 3]);
        // ties break toward the lower coordinate
        assert_eq!(local_topk(&[4u64, 4, 4], 32, 2), vec![0, 1]);
    }

    #[test]
    fn union_support_merges_and_dedupes() {
        let u = union_support(&[vec![3, 1], vec![1, 7], vec![]], 8);
        assert_eq!(u, vec![1, 3, 7]);
        assert_eq!(union_support(&[], 4), Vec::<u32>::new());
    }

    #[test]
    #[should_panic(expected = "out of dim")]
    fn union_support_rejects_out_of_range() {
        let _ = union_support(&[vec![4]], 4);
    }

    #[test]
    fn magnitude_is_twos_complement_abs() {
        assert_eq!(magnitude(5, 32), 5);
        assert_eq!(magnitude((1u64 << 32) - 3, 32), 3);
        assert_eq!(magnitude(1u64 << 31, 32), 1u64 << 31);
        assert_eq!(magnitude(u64::MAX, 64), 1);
        assert_eq!(magnitude(3, 16), 3);
        assert_eq!(magnitude(0xFFFF, 16), 1);
    }

    #[test]
    fn payload_bytes_follow_bit_width() {
        let plan = IndexPlan::sparse(vec![0, 2], 4);
        let up = EncodedUpdate { values: vec![1, 2], plan };
        assert_eq!(up.payload_bytes(32), 8);
        assert_eq!(up.payload_bytes(16), 4);
        assert_eq!(up.payload_bytes(64), 16);
    }

    #[test]
    fn masking_in_packed_domain_matches_full_vector_prefix() {
        // The packed vector is its own mask domain: masking k packed values
        // consumes keystream elements 0..k, exactly like a dense vector of
        // length k — the property that lets sparse rounds reuse the range
        // APIs unchanged.
        use crate::crypto::prg::{apply_mask, apply_mask_range, NONCE_SELF};
        let seed = [9u8; 32];
        let plan = IndexPlan::sparse(vec![2, 5, 11, 17], 20);
        let dense: Vec<u64> = (0..20u64).map(|i| i * 31).collect();
        let mut packed = plan.encode(&dense, 32);
        let mut reference = packed.clone();
        apply_mask(&mut reference, &seed, &NONCE_SELF, 32, false);
        // shard the packed vector at an arbitrary split — same result
        let (lo, hi) = packed.split_at_mut(1);
        apply_mask_range(lo, &seed, &NONCE_SELF, 32, false, 0);
        apply_mask_range(hi, &seed, &NONCE_SELF, 32, false, 1);
        assert_eq!(packed, reference);
    }

    #[test]
    #[should_panic(expected = "strictly ascending")]
    fn unsorted_plan_rejected() {
        let _ = IndexPlan::sparse(vec![3, 1], 5);
    }

    #[test]
    #[should_panic(expected = "out of dim")]
    fn out_of_range_plan_rejected() {
        let _ = IndexPlan::sparse(vec![1, 5], 5);
    }
}
