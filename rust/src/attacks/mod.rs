//! The paper's privacy attacks, run against what an eavesdropper actually
//! observes under each scheme.
//!
//! * [`inversion`] — Fredrikson et al. model inversion (Fig 2 / A.4);
//! * [`membership`] — confidence-based membership inference (Tables 5.2 /
//!   A.3).
//!
//! The central abstraction is [`EavesdroppedModel`]: under FedAvg the
//! wire carries the plaintext model; under SA/CCESA it carries the masked
//! words θ̃_i, whose dequantization is (computationally) uniform noise, so
//! both attacks degrade to chance — exactly the paper's experimental
//! claim.

pub mod inversion;
pub mod membership;

use crate::masking::Quantizer;

/// What the eavesdropper reconstructs from one client's upload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    /// FedAvg: plaintext f32 model on the wire.
    FedAvg,
    /// SA or CCESA: masked Z_{2^b} words on the wire.
    Masked,
}

/// The model parameters as seen by the eavesdropper.
///
/// For `Masked`, the adversary's best effort is to dequantize the masked
/// words with the public quantizer — the result carries zero information
/// about θ (the masks are fresh PRG output), but it is a *valid f32
/// parameter vector*, so the attacks run unchanged and their failure is
/// measured rather than assumed.
pub fn eavesdropped_model(
    scheme: Scheme,
    plain: &[f32],
    quantizer: &Quantizer,
    masked_words: &[u64],
) -> Vec<f32> {
    match scheme {
        Scheme::FedAvg => plain.to_vec(),
        Scheme::Masked => masked_words
            .iter()
            .map(|&w| quantizer.dequantize_one(w) as f32)
            .collect(),
    }
}

/// Centered cosine similarity — the reconstruction-quality metric for the
/// inversion experiments.
pub fn centered_cosine(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let ma = a.iter().sum::<f32>() / a.len() as f32;
    let mb = b.iter().sum::<f32>() / b.len() as f32;
    let mut num = 0.0;
    let mut da = 0.0;
    let mut db = 0.0;
    for (x, y) in a.iter().zip(b) {
        let xa = x - ma;
        let yb = y - mb;
        num += xa * yb;
        da += xa * xa;
        db += yb * yb;
    }
    num / (da.sqrt() * db.sqrt() + 1e-9)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::prg::{apply_mask, NONCE_SELF};
    use crate::util::rng::Rng;

    #[test]
    fn fedavg_view_is_plaintext() {
        let q = Quantizer::for_sum_of(32, 1.0, 4);
        let plain = vec![0.5f32, -0.25];
        let v = eavesdropped_model(Scheme::FedAvg, &plain, &q, &[]);
        assert_eq!(v, plain);
    }

    #[test]
    fn masked_view_is_uncorrelated_with_plaintext() {
        let mut rng = Rng::new(9);
        let q = Quantizer::for_sum_of(32, 1.0, 4);
        let plain: Vec<f32> = (0..2000).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let mut words = q.quantize(&plain);
        let mut seed = [0u8; 32];
        rng.fill_bytes(&mut seed);
        apply_mask(&mut words, &seed, &NONCE_SELF, 32, false);
        let view = eavesdropped_model(Scheme::Masked, &plain, &q, &words);
        let corr = centered_cosine(&view, &plain);
        assert!(corr.abs() < 0.08, "masked view correlates: {corr}");
    }

    #[test]
    fn centered_cosine_basics() {
        let a = [1.0f32, 2.0, 3.0];
        assert!((centered_cosine(&a, &a) - 1.0).abs() < 1e-5);
        let b = [3.0f32, 2.0, 1.0];
        assert!((centered_cosine(&a, &b) + 1.0).abs() < 1e-5);
        let c = [5.0f32, 5.0, 5.0]; // zero variance → ~0
        assert!(centered_cosine(&a, &c).abs() < 1e-3);
    }
}
