//! Model-inversion attack (Fredrikson et al. 2015), as run in Fig 2 / A.4.
//!
//! The attacker eavesdrops a client's uploaded model, interprets it as
//! softmax-regression parameters, and gradient-descends the class loss
//! with respect to the *input image* (via the AOT `inversion` HLO step).
//! Success is measured as centered-cosine similarity between the
//! reconstruction and the victim identity's template — high for FedAvg,
//! chance-level for SA/CCESA.

use super::centered_cosine;
use crate::runtime::softreg::{SoftregParams, SoftregRuntime};
use anyhow::Result;

/// Result of attacking one target identity.
#[derive(Debug, Clone)]
pub struct InversionOutcome {
    pub target: usize,
    /// Reconstructed image (d pixels in [0,1]).
    pub reconstruction: Vec<f32>,
    /// Similarity to the target's template.
    pub target_similarity: f32,
    /// Best similarity to any *other* identity's template.
    pub best_other_similarity: f32,
}

impl InversionOutcome {
    /// The attack "identifies" the victim if the target template is the
    /// best match by a margin.
    pub fn identified(&self) -> bool {
        self.target_similarity > self.best_other_similarity
    }
}

/// Run the iterative inversion against eavesdropped parameters.
pub fn invert(
    sr: &SoftregRuntime,
    eavesdropped: &SoftregParams,
    target: usize,
    templates: &[Vec<f32>],
    steps: usize,
    step_size: f32,
) -> Result<InversionOutcome> {
    let d = sr.dims;
    assert!(target < d.c && templates.len() == d.c);
    let mut onehot = vec![0.0f32; d.c];
    onehot[target] = 1.0;
    let mut img = vec![0.5f32; d.d];
    for _ in 0..steps {
        let (next, _) = sr.inversion_step(eavesdropped, &img, &onehot, step_size)?;
        img = next;
    }
    let target_similarity = centered_cosine(&img, &templates[target]);
    let best_other_similarity = (0..d.c)
        .filter(|&k| k != target)
        .map(|k| centered_cosine(&img, &templates[k]))
        .fold(f32::NEG_INFINITY, f32::max);
    Ok(InversionOutcome {
        target,
        reconstruction: img,
        target_similarity,
        best_other_similarity,
    })
}

/// Attack several identities and report the identification rate — the
/// Fig 2 aggregate (1.0 under FedAvg, ≈1/c chance under SA/CCESA).
pub fn identification_rate(
    sr: &SoftregRuntime,
    eavesdropped: &SoftregParams,
    templates: &[Vec<f32>],
    targets: &[usize],
    steps: usize,
    step_size: f32,
) -> Result<f64> {
    let mut hits = 0usize;
    for &t in targets {
        if invert(sr, eavesdropped, t, templates, steps, step_size)?.identified() {
            hits += 1;
        }
    }
    Ok(hits as f64 / targets.len().max(1) as f64)
}
