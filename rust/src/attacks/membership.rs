//! Membership-inference attack (Shokri et al. 2017), as run in
//! Tables 5.2 / A.3.
//!
//! Simplified confidence attack (Yeom et al. 2018): the attacker scores
//! each record by the eavesdropped model's confidence in its true label
//! and predicts "member" above a threshold set to the median score over
//! the mixed evaluation set (no label-oracle tuning). Overfit models give
//! members systematically higher confidence (≈65–72% accuracy in the
//! paper's FedAvg column); a masked model scores both sets identically
//! (≈50%, random guessing).

use crate::fl::data::Dataset;
use crate::runtime::softreg::{SoftregParams, SoftregRuntime};
use anyhow::Result;

/// Attack metrics matching the paper's Tables 5.2 (accuracy) and A.3
/// (precision); recall reported for completeness (the paper notes ≈1).
#[derive(Debug, Clone, Copy)]
pub struct MembershipReport {
    pub accuracy: f64,
    pub precision: f64,
    pub recall: f64,
    pub n_members: usize,
    pub n_nonmembers: usize,
}

/// Confidence in the *true* label for every record of `ds`.
fn true_label_confidences(
    sr: &SoftregRuntime,
    params: &SoftregParams,
    ds: &Dataset,
) -> Result<Vec<f32>> {
    let b = sr.dims.batch;
    let c = sr.dims.c;
    let mut out = Vec::with_capacity(ds.len());
    let mut i = 0;
    while i < ds.len() {
        let idx: Vec<usize> = (i..(i + b).min(ds.len())).collect();
        let real = idx.len();
        let (x, _, labels) = ds.batch(&idx, b);
        let probs = sr.predict(params, &x)?;
        for k in 0..real {
            out.push(probs[k * c + labels[k] as usize]);
        }
        i += b;
    }
    Ok(out)
}

/// Run the attack: balanced member/non-member evaluation (the paper uses
/// 5000 + 5000).
pub fn attack(
    sr: &SoftregRuntime,
    eavesdropped: &SoftregParams,
    members: &Dataset,
    nonmembers: &Dataset,
) -> Result<MembershipReport> {
    let m_scores = true_label_confidences(sr, eavesdropped, members)?;
    let n_scores = true_label_confidences(sr, eavesdropped, nonmembers)?;

    // threshold = median of the pooled scores (attacker-side heuristic)
    let mut pooled: Vec<f32> = m_scores.iter().chain(&n_scores).copied().collect();
    pooled.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let tau = pooled[pooled.len() / 2];

    let tp = m_scores.iter().filter(|&&s| s > tau).count();
    let fn_ = m_scores.len() - tp;
    let fp = n_scores.iter().filter(|&&s| s > tau).count();
    let tn = n_scores.len() - fp;

    let accuracy = (tp + tn) as f64 / (m_scores.len() + n_scores.len()) as f64;
    let precision = if tp + fp == 0 { 0.5 } else { tp as f64 / (tp + fp) as f64 };
    let recall = tp as f64 / (tp + fn_).max(1) as f64;
    Ok(MembershipReport {
        accuracy,
        precision,
        recall,
        n_members: m_scores.len(),
        n_nonmembers: n_scores.len(),
    })
}
