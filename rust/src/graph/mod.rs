//! Assignment-graph machinery (Section 3 of the paper).
//!
//! The CCESA protocol is parameterized by an undirected *assignment graph*
//! `G = (V, E)`: clients i and j exchange public keys and secret shares iff
//! `{i,j} ∈ E`. SA (Bonawitz et al.) is the complete-graph special case.
//!
//! Generators:
//! * [`Graph::complete`] — SA;
//! * [`Graph::erdos_renyi`] — the paper's construction, `G(n, p)`;
//! * [`Graph::harary`] — the k-connected construction of Bell et al. 2020,
//!   included for the related-work comparison bench;
//! * [`Graph::ring`], [`Graph::star`], [`Graph::empty`] — test topologies.
//!
//! Analysis helpers: connectivity, connected components, induced subgraphs
//! (the `G_i = G − (V \ V_i)` evolution of the protocol), degree stats.

use crate::util::rng::Rng;

/// Undirected simple graph on vertices `0..n`, adjacency-list backed with
/// a parallel bitset for O(1) membership tests.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Graph {
    n: usize,
    adj: Vec<Vec<usize>>,
    bits: Vec<u64>, // n x n bitmatrix, row-major
}

impl Graph {
    pub fn empty(n: usize) -> Graph {
        let words_per_row = n.div_ceil(64);
        Graph { n, adj: vec![Vec::new(); n], bits: vec![0u64; n * words_per_row] }
    }

    #[inline]
    fn words_per_row(&self) -> usize {
        self.n.div_ceil(64)
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of edges.
    pub fn m(&self) -> usize {
        self.adj.iter().map(|a| a.len()).sum::<usize>() / 2
    }

    #[inline]
    pub fn has_edge(&self, i: usize, j: usize) -> bool {
        debug_assert!(i < self.n && j < self.n);
        let w = self.words_per_row();
        self.bits[i * w + j / 64] & (1u64 << (j % 64)) != 0
    }

    /// Insert an undirected edge; no-op on duplicates and self-loops.
    pub fn add_edge(&mut self, i: usize, j: usize) {
        assert!(i < self.n && j < self.n, "edge ({i},{j}) out of range n={}", self.n);
        if i == j || self.has_edge(i, j) {
            return;
        }
        let w = self.words_per_row();
        self.bits[i * w + j / 64] |= 1u64 << (j % 64);
        self.bits[j * w + i / 64] |= 1u64 << (i % 64);
        self.adj[i].push(j);
        self.adj[j].push(i);
    }

    /// Neighbors of `i` (Adj(i) in the paper), unsorted.
    #[inline]
    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.adj[i]
    }

    /// Rebuild a graph from verbatim adjacency rows — the journal's
    /// deserialization path. [`Graph::add_edge`] cannot reproduce arbitrary
    /// per-row neighbor orders (it appends to *both* endpoints in one global
    /// call order), but replay bit-identity requires `neighbors(i)` to come
    /// back in exactly the recorded order, so this constructor installs the
    /// rows directly after validating them: every entry in range, no
    /// self-loops, no duplicates within a row, and perfect symmetry (j
    /// appears in row i iff i appears in row j). Returns `Err` on any
    /// violation — corrupted journal bytes must never panic.
    pub fn from_adjacency(n: usize, adj: Vec<Vec<usize>>) -> Result<Graph, String> {
        if adj.len() != n {
            return Err(format!("adjacency has {} rows for n={n}", adj.len()));
        }
        let words_per_row = n.div_ceil(64);
        let mut bits = vec![0u64; n * words_per_row];
        for (i, row) in adj.iter().enumerate() {
            for &j in row {
                if j >= n {
                    return Err(format!("row {i}: neighbor {j} out of range n={n}"));
                }
                if j == i {
                    return Err(format!("row {i}: self-loop"));
                }
                let w = i * words_per_row + j / 64;
                if bits[w] & (1u64 << (j % 64)) != 0 {
                    return Err(format!("row {i}: duplicate neighbor {j}"));
                }
                bits[w] |= 1u64 << (j % 64);
            }
        }
        // symmetry: the bitmatrix must equal its transpose
        for i in 0..n {
            for &j in &adj[i] {
                if bits[j * words_per_row + i / 64] & (1u64 << (i % 64)) == 0 {
                    return Err(format!("asymmetric edge ({i},{j})"));
                }
            }
        }
        Ok(Graph { n, adj, bits })
    }

    pub fn degree(&self, i: usize) -> usize {
        self.adj[i].len()
    }

    // ----- generators ----------------------------------------------------

    /// Complete graph K_n — the SA topology.
    pub fn complete(n: usize) -> Graph {
        let mut g = Graph::empty(n);
        for i in 0..n {
            for j in (i + 1)..n {
                g.add_edge(i, j);
            }
        }
        g
    }

    /// Erdős–Rényi G(n, p): each pair independently connected w.p. `p`.
    pub fn erdos_renyi(n: usize, p: f64, rng: &mut Rng) -> Graph {
        assert!((0.0..=1.0).contains(&p), "p={p} out of [0,1]");
        let mut g = Graph::empty(n);
        if p >= 1.0 {
            return Graph::complete(n);
        }
        if p <= 0.0 || n < 2 {
            return g;
        }
        // geometric skipping for sparse p: expected O(n²p) work
        let ln_q = (1.0 - p).ln();
        let total_pairs = n * (n - 1) / 2;
        let mut idx: i64 = -1;
        loop {
            let u = rng.next_f64().max(1e-300);
            let skip = (u.ln() / ln_q).floor() as i64 + 1;
            idx += skip.max(1);
            if idx as usize >= total_pairs {
                break;
            }
            let (i, j) = pair_from_index(idx as usize, n);
            g.add_edge(i, j);
        }
        g
    }

    /// Harary graph H_{k,n}: the minimal k-connected graph used by
    /// Bell et al. (CCS'20). Implemented for even k (circulant with
    /// offsets 1..k/2) plus the diameter chord when k is odd.
    pub fn harary(n: usize, k: usize) -> Graph {
        assert!(k < n, "harary requires k < n");
        let mut g = Graph::empty(n);
        let half = k / 2;
        for i in 0..n {
            for d in 1..=half {
                g.add_edge(i, (i + d) % n);
            }
        }
        if k % 2 == 1 {
            for i in 0..n.div_ceil(2) {
                g.add_edge(i, (i + n / 2) % n);
            }
        }
        g
    }

    /// Cycle graph C_n.
    pub fn ring(n: usize) -> Graph {
        let mut g = Graph::empty(n);
        if n >= 2 {
            for i in 0..n {
                g.add_edge(i, (i + 1) % n);
            }
        }
        g
    }

    /// Star graph with center 0.
    pub fn star(n: usize) -> Graph {
        let mut g = Graph::empty(n);
        for i in 1..n {
            g.add_edge(0, i);
        }
        g
    }

    // ----- analysis -------------------------------------------------------

    /// Induced subgraph on `keep` (must be sorted/deduped ids). Returns the
    /// subgraph and the mapping from new ids to original ids.
    pub fn induced(&self, keep: &[usize]) -> (Graph, Vec<usize>) {
        let mut remap = vec![usize::MAX; self.n];
        for (new, &old) in keep.iter().enumerate() {
            assert!(old < self.n);
            remap[old] = new;
        }
        let mut g = Graph::empty(keep.len());
        for (new_i, &old_i) in keep.iter().enumerate() {
            for &old_j in self.neighbors(old_i) {
                let new_j = remap[old_j];
                if new_j != usize::MAX && new_i < new_j {
                    g.add_edge(new_i, new_j);
                }
            }
        }
        (g, keep.to_vec())
    }

    /// Connected components as sorted vertex lists (BFS).
    pub fn components(&self) -> Vec<Vec<usize>> {
        let mut seen = vec![false; self.n];
        let mut comps = Vec::new();
        let mut queue = std::collections::VecDeque::new();
        for s in 0..self.n {
            if seen[s] {
                continue;
            }
            seen[s] = true;
            queue.push_back(s);
            let mut comp = vec![s];
            while let Some(v) = queue.pop_front() {
                for &u in self.neighbors(v) {
                    if !seen[u] {
                        seen[u] = true;
                        comp.push(u);
                        queue.push_back(u);
                    }
                }
            }
            comp.sort_unstable();
            comps.push(comp);
        }
        comps
    }

    /// Is the graph connected? (Vacuously true for n ≤ 1.)
    pub fn is_connected(&self) -> bool {
        self.n <= 1 || self.components().len() == 1
    }

    /// Mean degree.
    pub fn mean_degree(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        self.adj.iter().map(|a| a.len() as f64).sum::<f64>() / self.n as f64
    }

    /// Min / max degree.
    pub fn degree_range(&self) -> (usize, usize) {
        let mut lo = usize::MAX;
        let mut hi = 0;
        for a in &self.adj {
            lo = lo.min(a.len());
            hi = hi.max(a.len());
        }
        if self.n == 0 {
            (0, 0)
        } else {
            (lo, hi)
        }
    }
}

/// Map a linear index in [0, n(n-1)/2) to the (i, j) pair with i < j,
/// enumerating row by row.
fn pair_from_index(mut idx: usize, n: usize) -> (usize, usize) {
    for i in 0..n {
        let row = n - 1 - i;
        if idx < row {
            return (i, i + 1 + idx);
        }
        idx -= row;
    }
    unreachable!("pair index out of range")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn complete_graph_properties() {
        let g = Graph::complete(5);
        assert_eq!(g.m(), 10);
        assert_eq!(g.degree_range(), (4, 4));
        assert!(g.is_connected());
        assert!(g.has_edge(0, 4) && g.has_edge(4, 0));
        assert!(!g.has_edge(2, 2));
    }

    #[test]
    fn add_edge_idempotent_no_self_loops() {
        let mut g = Graph::empty(3);
        g.add_edge(0, 1);
        g.add_edge(1, 0);
        g.add_edge(1, 1);
        assert_eq!(g.m(), 1);
        assert_eq!(g.degree(1), 1);
    }

    #[test]
    fn pair_index_bijection() {
        let n = 13;
        let mut seen = std::collections::HashSet::new();
        for idx in 0..n * (n - 1) / 2 {
            let (i, j) = pair_from_index(idx, n);
            assert!(i < j && j < n);
            assert!(seen.insert((i, j)));
        }
    }

    #[test]
    fn erdos_renyi_edge_count_concentrates() {
        let mut rng = Rng::new(0xE2);
        let n = 300;
        let p = 0.1;
        let g = Graph::erdos_renyi(n, p, &mut rng);
        let expect = p * (n * (n - 1) / 2) as f64;
        let got = g.m() as f64;
        assert!(
            (got - expect).abs() < 4.0 * expect.sqrt() + 30.0,
            "edges={got} expected≈{expect}"
        );
    }

    #[test]
    fn erdos_renyi_extremes() {
        let mut rng = Rng::new(1);
        assert_eq!(Graph::erdos_renyi(10, 0.0, &mut rng).m(), 0);
        assert_eq!(Graph::erdos_renyi(10, 1.0, &mut rng).m(), 45);
        assert_eq!(Graph::erdos_renyi(1, 0.5, &mut rng).m(), 0);
        assert_eq!(Graph::erdos_renyi(0, 0.5, &mut rng).m(), 0);
    }

    #[test]
    fn erdos_renyi_deterministic_in_seed() {
        let g1 = Graph::erdos_renyi(50, 0.3, &mut Rng::new(7));
        let g2 = Graph::erdos_renyi(50, 0.3, &mut Rng::new(7));
        assert_eq!(g1, g2);
    }

    #[test]
    fn erdos_renyi_above_connectivity_threshold_connected() {
        // p = 3 ln n / n ≫ ln n / n ⇒ a.a.s. connected
        let n = 200;
        let p = 3.0 * (n as f64).ln() / n as f64;
        let mut connected = 0;
        for seed in 0..20 {
            if Graph::erdos_renyi(n, p, &mut Rng::new(seed)).is_connected() {
                connected += 1;
            }
        }
        assert!(connected >= 19, "connected {connected}/20");
    }

    #[test]
    fn harary_min_degree_k() {
        for (n, k) in [(10usize, 4usize), (11, 4), (10, 5), (17, 3), (8, 2)] {
            let g = Graph::harary(n, k);
            let (lo, _) = g.degree_range();
            assert!(lo >= k, "H_{{{k},{n}}} min degree {lo}");
            assert!(g.is_connected());
            // edge count ≈ ceil(kn/2)
            assert!(g.m() <= (k * n + n) / 2 + 1);
        }
    }

    #[test]
    fn ring_and_star() {
        let r = Graph::ring(6);
        assert_eq!(r.m(), 6);
        assert_eq!(r.degree_range(), (2, 2));
        assert!(r.is_connected());
        let s = Graph::star(6);
        assert_eq!(s.m(), 5);
        assert_eq!(s.degree(0), 5);
        assert!(s.is_connected());
    }

    #[test]
    fn components_and_connectivity() {
        let mut g = Graph::empty(7);
        g.add_edge(0, 1);
        g.add_edge(1, 2);
        g.add_edge(3, 4);
        // 5, 6 isolated
        let comps = g.components();
        assert_eq!(comps.len(), 4);
        assert_eq!(comps[0], vec![0, 1, 2]);
        assert_eq!(comps[1], vec![3, 4]);
        assert!(!g.is_connected());
        assert!(Graph::empty(1).is_connected());
        assert!(Graph::empty(0).is_connected());
    }

    #[test]
    fn induced_subgraph_matches_paper_evolution() {
        // G3 = G − (V \ V3): survivors keep exactly their mutual edges
        let g = Graph::complete(6);
        let keep = vec![0, 2, 5];
        let (sub, map) = g.induced(&keep);
        assert_eq!(sub.n(), 3);
        assert_eq!(sub.m(), 3);
        assert_eq!(map, keep);

        let r = Graph::ring(6); // 0-1-2-3-4-5-0
        let (sub, _) = r.induced(&[0, 1, 3, 4]);
        // edges kept: (0,1), (3,4) → new ids (0,1), (2,3)
        assert_eq!(sub.m(), 2);
        assert!(sub.has_edge(0, 1));
        assert!(sub.has_edge(2, 3));
        assert!(!sub.is_connected());
    }

    #[test]
    fn property_er_degree_distribution() {
        // mean degree of G(n,p) ≈ (n-1)p
        let n = 400;
        let p = 0.2;
        let g = Graph::erdos_renyi(n, p, &mut Rng::new(0xDE6));
        let expect = (n - 1) as f64 * p;
        assert!((g.mean_degree() - expect).abs() < 0.1 * expect);
    }
}
