//! GF(2^16) arithmetic with the primitive polynomial
//! x^16 + x^12 + x^3 + x + 1 (0x1100B), generator α = x (i.e. 2).
//!
//! 64 KiB log + 128 KiB exp tables, built once. This is the field used by
//! the production Shamir implementation (supports up to 65535 share
//! holders, comfortably covering the paper's n = 1000 experiments).

/// The reduction polynomial, exported for `crate::kernels`' carry-less
/// multiply backends (their Barrett constants derive from it).
pub const POLY: u32 = 0x1100B;

struct Tables {
    exp: Vec<u16>, // length 2*65535 to avoid mod in mul
    log: Vec<u16>, // length 65536; log[0] unused
}

fn tables() -> &'static Tables {
    use std::sync::OnceLock;
    static T: OnceLock<Tables> = OnceLock::new();
    T.get_or_init(|| {
        let mut exp = vec![0u16; 2 * 65535];
        let mut log = vec![0u16; 65536];
        let mut x: u32 = 1;
        for i in 0..65535usize {
            exp[i] = x as u16;
            log[x as usize] = i as u16;
            x <<= 1;
            if x & 0x10000 != 0 {
                x ^= POLY;
            }
        }
        for i in 65535..(2 * 65535) {
            exp[i] = exp[i - 65535];
        }
        Tables { exp, log }
    })
}

/// Addition = XOR.
#[inline]
pub fn add(a: u16, b: u16) -> u16 {
    a ^ b
}

/// Multiplication via log/exp tables.
#[inline]
pub fn mul(a: u16, b: u16) -> u16 {
    if a == 0 || b == 0 {
        return 0;
    }
    let t = tables();
    t.exp[t.log[a as usize] as usize + t.log[b as usize] as usize]
}

/// Multiplicative inverse; panics on 0.
#[inline]
pub fn inv(a: u16) -> u16 {
    assert!(a != 0, "inverse of zero in GF(2^16)");
    let t = tables();
    t.exp[65535 - t.log[a as usize] as usize]
}

/// Division a/b.
#[inline]
pub fn div(a: u16, b: u16) -> u16 {
    mul(a, inv(b))
}

/// Slow carry-less multiply + reduce, the correctness oracle for the tables.
pub fn mul_slow(a: u16, b: u16) -> u16 {
    let mut acc: u32 = 0;
    let a = a as u32;
    for bit in 0..16 {
        if b & (1 << bit) != 0 {
            acc ^= a << bit;
        }
    }
    // reduce degree-31 polynomial mod POLY
    for bit in (16..32).rev() {
        if acc & (1 << bit) != 0 {
            acc ^= POLY << (bit - 16);
        }
    }
    acc as u16
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn generator_is_primitive() {
        // the exp table covers all 65535 nonzero elements exactly once
        let t = tables();
        let mut seen = vec![false; 65536];
        for i in 0..65535 {
            let v = t.exp[i] as usize;
            assert!(v != 0);
            assert!(!seen[v], "exp cycle shorter than 65535 at {i}");
            seen[v] = true;
        }
    }

    #[test]
    fn table_mul_matches_slow_mul_random() {
        let mut rng = Rng::new(0x6F65536);
        for _ in 0..2000 {
            let a = rng.next_u32() as u16;
            let b = rng.next_u32() as u16;
            assert_eq!(mul(a, b), mul_slow(a, b), "a={a} b={b}");
        }
        assert_eq!(mul(0, 1234), 0);
        assert_eq!(mul(1234, 0), 0);
    }

    #[test]
    fn field_axioms_random() {
        let mut rng = Rng::new(0xAB);
        for _ in 0..500 {
            let a = (rng.next_u32() as u16).max(1);
            let b = (rng.next_u32() as u16).max(1);
            let c = rng.next_u32() as u16;
            assert_eq!(mul(a, b), mul(b, a));
            assert_eq!(mul(mul(a, b), c), mul(a, mul(b, c)));
            assert_eq!(mul(a, add(b, c)), add(mul(a, b), mul(a, c)));
            assert_eq!(mul(a, inv(a)), 1);
            assert_eq!(mul(a, 1), a);
            assert_eq!(div(mul(a, b), b), a);
        }
    }

    #[test]
    fn inverse_edge_elements() {
        for a in [1u16, 2, 3, 0x8000, 0xFFFF, 0x1001] {
            assert_eq!(mul(a, inv(a)), 1, "a={a:#x}");
        }
    }
}
