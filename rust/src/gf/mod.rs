//! Finite-field arithmetic for Shamir secret sharing.
//!
//! Two fields are provided:
//! * [`gf256`] — GF(2^8), the classic byte-wise SSS field. Simple and fast,
//!   but caps the number of share holders at 255; kept for small-n
//!   deployments and as a cross-validation oracle.
//! * [`gf65536`] — GF(2^16), the production field. The paper's experiments
//!   run up to n = 1000 clients (Fig 5.2), beyond GF(2^8)'s capacity, so
//!   shares are evaluated at x ∈ GF(2^16) \ {0} supporting n ≤ 65535.
//!
//! These modules provide the *scalar* arithmetic; whole-vector GF(2^16)
//! operations on the Shamir hot path (constant-weight slice multiply and
//! multiply-accumulate) go through the runtime-dispatched
//! [`crate::kernels`] layer, for which [`gf65536::mul`] is the oracle.

pub mod gf256;
pub mod gf65536;
