//! GF(2^8) arithmetic with the AES polynomial x^8 + x^4 + x^3 + x + 1.
//!
//! Log/antilog tables over generator 3 give O(1) mul/div/inv.

const POLY: u16 = 0x11B;

/// Precomputed exp/log tables (built at first use).
struct Tables {
    exp: [u8; 512], // doubled to skip the mod-255 in mul
    log: [u8; 256],
}

fn tables() -> &'static Tables {
    use std::sync::OnceLock;
    static T: OnceLock<Tables> = OnceLock::new();
    T.get_or_init(|| {
        let mut exp = [0u8; 512];
        let mut log = [0u8; 256];
        let mut x: u16 = 1;
        for i in 0..255 {
            exp[i] = x as u8;
            log[x as usize] = i as u8;
            // multiply x by the generator 3 = x + 1: x*2 ^ x
            let x2 = x << 1;
            x = (if x2 & 0x100 != 0 { x2 ^ POLY } else { x2 }) ^ x;
        }
        for i in 255..512 {
            exp[i] = exp[i - 255];
        }
        Tables { exp, log }
    })
}

/// Addition = XOR (characteristic 2).
#[inline]
pub fn add(a: u8, b: u8) -> u8 {
    a ^ b
}

/// Multiplication via log tables.
#[inline]
pub fn mul(a: u8, b: u8) -> u8 {
    if a == 0 || b == 0 {
        return 0;
    }
    let t = tables();
    t.exp[t.log[a as usize] as usize + t.log[b as usize] as usize]
}

/// Multiplicative inverse; panics on 0.
#[inline]
pub fn inv(a: u8) -> u8 {
    assert!(a != 0, "inverse of zero in GF(256)");
    let t = tables();
    t.exp[255 - t.log[a as usize] as usize]
}

/// Division a/b.
#[inline]
pub fn div(a: u8, b: u8) -> u8 {
    mul(a, inv(b))
}

/// Slow reference multiplication (Russian peasant) for cross-checks.
pub fn mul_slow(mut a: u8, mut b: u8) -> u8 {
    let mut r = 0u8;
    while b != 0 {
        if b & 1 != 0 {
            r ^= a;
        }
        let hi = a & 0x80 != 0;
        a <<= 1;
        if hi {
            a ^= (POLY & 0xFF) as u8;
        }
        b >>= 1;
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_mul_matches_slow_mul() {
        for a in 0..=255u8 {
            for b in [0u8, 1, 2, 3, 0x53, 0xCA, 255] {
                assert_eq!(mul(a, b), mul_slow(a, b), "a={a} b={b}");
            }
        }
    }

    #[test]
    fn known_aes_product() {
        // classic AES example: 0x53 * 0xCA = 0x01
        assert_eq!(mul(0x53, 0xCA), 0x01);
        assert_eq!(inv(0x53), 0xCA);
    }

    #[test]
    fn field_axioms_sampled() {
        let elems = [1u8, 2, 3, 7, 0x1D, 0x80, 0xFE, 0xFF];
        for &a in &elems {
            assert_eq!(mul(a, 1), a);
            assert_eq!(mul(a, inv(a)), 1);
            assert_eq!(add(a, a), 0);
            for &b in &elems {
                assert_eq!(mul(a, b), mul(b, a));
                for &c in &elems {
                    assert_eq!(mul(a, add(b, c)), add(mul(a, b), mul(a, c)));
                    assert_eq!(mul(mul(a, b), c), mul(a, mul(b, c)));
                }
            }
        }
    }

    #[test]
    fn all_nonzero_invertible() {
        for a in 1..=255u8 {
            assert_eq!(mul(a, inv(a)), 1, "a={a}");
            assert_eq!(div(a, a), 1);
        }
    }
}
