//! Two-level (sharded) secure aggregation — the million-client shape.
//!
//! One flat assignment graph over 10⁶ clients is neither the paper's regime
//! (Choi et al. evaluate n ≤ 500) nor deployable: per-client degree, Shamir
//! fan-out and the server's reconstruction work all scale with the flat
//! graph, and the event loop must hold every client lane at once. The
//! hierarchical topology (cf. "Private Aggregation in Hierarchical Wireless
//! FL", arXiv 2306.14088) runs the *existing* protocol twice instead of
//! forking it:
//!
//! * **Intra-shard level** — clients are partitioned into contiguous shards
//!   (`ShardPlan`); each shard runs a full CCESA round on its own `intra`
//!   graph, `ProtocolConfig` and mask-seed domain (`shard_seed`), producing
//!   a masked-then-unmasked shard sum over its local V3.
//! * **Root level** — the shard aggregators become the clients of one more
//!   round on the `root` graph: aggregator s's "model" is shard s's sum,
//!   and the same self-mask + pairwise-mask + Shamir machinery merges them
//!   securely (an aggregator that vanishes after submitting is recovered by
//!   `reconstruct_batch` exactly like any flat client).
//!
//! Both levels go through [`crate::coordinator::RoundRunner`] — engine and
//! event-loop executors today, wire as a ROADMAP follow-up — so the fused
//! mask kernels, `derive_round_setup` and batched reconstruction are reused
//! per level rather than reimplemented.
//!
//! **Payload plan.** Sparse codecs are planned **once, globally**, with the
//! flat engine's exact derivation (`cfg.codec.plan(dim, bits, seed, models)`
//! — the public round seed / summed-magnitude oracle over *all* models).
//! Every client model is encoded into that packed domain up front and both
//! levels run `Codec::Dense` at `dim = plan.len()`; the root sum is
//! scattered back to dense at the end. Per-shard plans would diverge
//! (shard-local TopK oracles, shard-seeded RandK draws) and break the
//! flat-oracle differential; one global plan keeps the support bit-identical
//! to the flat protocol's.
//!
//! **Aggregator failure semantics.** A shard that aborts or reports
//! unreliable is withheld from the root round (a targeted step-0 drop of
//! its aggregator): the global sum degrades to *dropping that shard*, never
//! to including a possibly mask-corrupted partial sum. Scheduled aggregator
//! failures ([`HierOptions::agg_dropout`]) compose with this — a lost
//! aggregator at any root step is handled by the root protocol like any
//! dropped client.

use crate::codec::IndexPlan;
use crate::coordinator::{CoordRoundResult, Executor, RoundOptions, RoundRunner};
use crate::net::NetStats;
use crate::protocol::dropout::DropoutModel;
use crate::protocol::server::theorem1_predicate;
use crate::protocol::{ClientId, ProtocolConfig, SurvivorSets, Topology};
use crate::util::mod_mask;
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Result};
use std::sync::Arc;

/// Salt mixed into per-shard master seeds so each shard is its own
/// mask-seed domain (no pairwise seed or self-mask can collide across
/// shards even for adjacent shard indices).
pub const SHARD_SEED_SALT: u64 = 0x5AA6_6D0A_11A5_EED5;

/// Salt for the root level's master seed.
pub const ROOT_SEED_SALT: u64 = 0x2007_AA66_E007_1EE7;

/// Master seed for shard `s`'s intra-shard round.
pub fn shard_seed(master: u64, s: usize) -> u64 {
    master ^ (s as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ SHARD_SEED_SALT
}

/// Master seed for the root (aggregator) round.
pub fn root_seed(master: u64) -> u64 {
    master ^ ROOT_SEED_SALT
}

/// Contiguous partition of `0..n` into shards: the first `n % shards`
/// shards hold one extra client (sizes balanced to ±1, same rule as
/// `par::partition`). Shard s's local client i is global client
/// `range(s).0 + i` — the offset `NetStats::merge_at` re-homes by.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    n: usize,
    ranges: Vec<(usize, usize)>,
}

impl ShardPlan {
    /// Partition `n` clients into exactly `shards` shards.
    pub fn new(n: usize, shards: usize) -> Result<ShardPlan> {
        ensure!(shards >= 1, "ShardPlan: shards must be ≥ 1");
        ensure!(shards <= n, "ShardPlan: shards={shards} must be ≤ n={n}");
        let ranges = crate::par::partition(n, shards).into_iter().map(|r| (r.start, r.end)).collect();
        Ok(ShardPlan { n, ranges })
    }

    /// Partition by *target* shard size: `shards = max(1, n / size)`, so
    /// actual shard sizes are ≥ `size` (never below the threshold the size
    /// was picked for).
    pub fn from_shard_size(n: usize, size: usize) -> Result<ShardPlan> {
        ensure!(size >= 1, "ShardPlan: shard size must be ≥ 1");
        ShardPlan::new(n, (n / size).max(1))
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn shards(&self) -> usize {
        self.ranges.len()
    }

    /// Global id range `[lo, hi)` of shard `s`.
    pub fn range(&self, s: usize) -> (usize, usize) {
        self.ranges[s]
    }

    pub fn len_of(&self, s: usize) -> usize {
        let (lo, hi) = self.ranges[s];
        hi - lo
    }

    /// Which shard holds global client `id`.
    pub fn shard_of(&self, id: ClientId) -> usize {
        assert!(id < self.n, "client {id} out of range (n={})", self.n);
        self.ranges.partition_point(|&(_, hi)| hi <= id)
    }

    pub fn min_size(&self) -> usize {
        (0..self.shards()).map(|s| self.len_of(s)).min().unwrap_or(0)
    }

    pub fn max_size(&self) -> usize {
        (0..self.shards()).map(|s| self.len_of(s)).max().unwrap_or(0)
    }
}

/// Knobs for one hierarchical round. Plain struct + `Default` (the knobs
/// are orthogonal; there is no contradictory combination to reject beyond
/// the executor check in [`HierRunner::run`]).
#[derive(Debug, Clone)]
pub struct HierOptions {
    /// Per-level execution shape: [`Executor::Engine`] or
    /// [`Executor::EventLoop`]. Wire is a ROADMAP follow-up and rejected.
    pub executor: Executor,
    /// How many shards run concurrently; `None` → `par::threads()` capped
    /// by the shard count.
    pub shard_parallelism: Option<usize>,
    /// Event-loop worker budget *inside* each shard round; `None` → 1 when
    /// shards themselves run in parallel (no nested oversubscription), else
    /// the event loop's own default sizing.
    pub workers: Option<usize>,
    /// Targeted root-level failures: `agg_dropout[step]` lists aggregator
    /// (= shard) indices that drop at that root step.
    pub agg_dropout: [Vec<usize>; 4],
    /// Recompute the Theorem-1 reliability predicate per level graph
    /// (one extra graph build per level; sim turns this on, benches off).
    pub check_theorem1: bool,
    /// Compute the plaintext `true_sum` over the covered clients (the
    /// differential self-check; off for the 10⁶ campaign rows).
    pub check_truth: bool,
}

impl Default for HierOptions {
    fn default() -> HierOptions {
        HierOptions {
            executor: Executor::EventLoop,
            shard_parallelism: None,
            workers: None,
            agg_dropout: std::array::from_fn(|_| Vec::new()),
            check_theorem1: false,
            check_truth: true,
        }
    }
}

/// One level's outcome (shard-local or aggregator ids — see the field on
/// [`HierRoundResult`] carrying it).
#[derive(Debug, Clone)]
pub struct LevelReport {
    /// The level produced a sum (did not abort).
    pub completed: bool,
    /// The level's server believed its sum covers exactly its V3.
    pub reliable: bool,
    /// Survivor sets in the level's local id space.
    pub sets: SurvivorSets,
    /// Theorem-1 predicate on the level's graph ([`HierOptions::check_theorem1`]).
    pub theorem1_holds: Option<bool>,
}

/// Per-level traffic roll-up. `intra` is indexed by *global* client id
/// (each shard's `NetStats` merged at its range offset); `root` by
/// aggregator (= shard) id — two genuinely different id spaces, kept apart.
#[derive(Debug, Clone)]
pub struct HierStats {
    pub intra: NetStats,
    pub root: NetStats,
}

impl HierStats {
    /// Total logical bytes moved across both levels, both directions.
    pub fn total_bytes(&self) -> u64 {
        self.intra.server_total() + self.root.server_total()
    }
}

/// Outcome of one hierarchical round.
#[derive(Debug)]
pub struct HierRoundResult {
    /// The dense global sum (root sum scattered through the global plan);
    /// `None` when the root round aborted.
    pub sum: Option<Vec<u64>>,
    /// Root-level reliability (participating shards are reliable by
    /// construction — unreliable shards are withheld from the root round).
    pub reliable: bool,
    /// Global ids of every client whose input the sum covers: the union of
    /// shard-local V3s over shards whose aggregator made the root V3.
    pub global_v3: Vec<ClientId>,
    /// Per-shard outcomes, shard-local ids.
    pub shard_reports: Vec<LevelReport>,
    /// Root-level outcome, aggregator ids; `None` for the single-shard
    /// degenerate case (no root round runs — the round *is* flat).
    pub root: Option<LevelReport>,
    pub stats: HierStats,
    /// Plaintext sum over `global_v3` projected on the plan
    /// ([`HierOptions::check_truth`]; `None` when off or when `sum` is).
    pub true_sum: Option<Vec<u64>>,
    /// The round's global payload plan (flat-engine derivation).
    pub plan: Arc<IndexPlan>,
    pub shard_plan: ShardPlan,
}

/// Drives one hierarchical round: shard rounds (in parallel), then the
/// root round over the shard sums. The hierarchical analogue of
/// [`RoundRunner`], and built on it per level.
pub struct HierRunner {
    opts: HierOptions,
}

impl HierRunner {
    pub fn new(opts: HierOptions) -> HierRunner {
        HierRunner { opts }
    }

    pub fn options(&self) -> &HierOptions {
        &self.opts
    }

    /// Run one hierarchical round. `cfg.topology` must be
    /// [`Topology::Hierarchical`] (the builder has already validated shard
    /// sizes ≥ t+1 and the per-level graph families).
    pub fn run(&self, cfg: &ProtocolConfig, models: &[Vec<u64>]) -> Result<HierRoundResult> {
        let Topology::Hierarchical { shards, intra, root } = &cfg.topology else {
            bail!("HierRunner requires Topology::Hierarchical (got a flat topology)");
        };
        if self.opts.executor == Executor::Wire {
            bail!("wire executor for hierarchical rounds is not implemented yet (ROADMAP)");
        }
        ensure!(models.len() == cfg.n, "one model vector per client");
        for (i, m) in models.iter().enumerate() {
            ensure!(m.len() == cfg.dim, "client {i} model dimension");
        }
        let shard_plan = ShardPlan::new(cfg.n, *shards)?;
        for (step, drops) in self.opts.agg_dropout.iter().enumerate() {
            for &a in drops {
                ensure!(a < shard_plan.shards(), "agg_dropout step {step}: aggregator {a} out of range");
            }
        }

        // Single shard: the round *is* the flat protocol — delegate
        // wholesale (same cfg minus the hierarchical wrapper) so the
        // degenerate case is bit-identical by construction.
        if shard_plan.shards() == 1 {
            return self.run_single_shard(cfg, models, intra);
        }

        // The global payload plan, with the flat engine's exact derivation
        // (public round seed / scoring oracle over all n models).
        let plan = cfg.codec.plan(cfg.dim, cfg.mask_bits, cfg.seed, models);

        // Pre-draw the global dropout schedule once at the hier layer so
        // both executors shard it identically. (Targeted schedules pass
        // through untouched — the rng-free replay path sim relies on.)
        let sched: [Vec<ClientId>; 4] = match &cfg.dropout {
            DropoutModel::Targeted { per_step } => per_step.clone(),
            other => other.materialize(cfg.n, &mut Rng::new(cfg.seed).split(0xD20)),
        };

        // Encode every model into the packed domain once; shards then run
        // Codec::Dense over contiguous slices. The identity plan borrows
        // the caller's models — no copy on the Dense path.
        let packed_storage: Vec<Vec<u64>>;
        let packed: &[Vec<u64>] = if plan.is_identity() {
            models
        } else {
            packed_storage = models.iter().map(|m| plan.encode(m, cfg.mask_bits)).collect();
            &packed_storage
        };

        // Inner round options: when shards run concurrently, each inner
        // event loop defaults to one worker — shard-level parallelism is
        // the parallelism (same no-oversubscription rule as campaigns).
        let shard_par = self
            .opts
            .shard_parallelism
            .unwrap_or_else(crate::par::threads)
            .clamp(1, shard_plan.shards());
        let mut inner = RoundOptions::builder().executor(self.opts.executor);
        if self.opts.executor == Executor::EventLoop {
            if let Some(w) = self.opts.workers {
                inner = inner.workers(w);
            } else if shard_par > 1 {
                inner = inner.workers(1);
            }
        }
        let inner_opts = inner.build()?;

        let check_t1 = self.opts.check_theorem1;
        let run_shard = |s: usize| -> Result<(CoordRoundResult, Option<bool>)> {
            let (lo, hi) = shard_plan.range(s);
            let local_sched: [Vec<ClientId>; 4] = std::array::from_fn(|k| {
                sched[k].iter().filter(|&&c| c >= lo && c < hi).map(|&c| c - lo).collect()
            });
            let shard_cfg = ProtocolConfig::builder()
                .clients(hi - lo)
                .threshold(cfg.t)
                .model_dim(plan.len())
                .mask_bits(cfg.mask_bits)
                .topology((**intra).clone())
                .dropout(DropoutModel::Targeted { per_step: local_sched })
                .seed(shard_seed(cfg.seed, s))
                .build()?;
            let r = RoundRunner::new(inner_opts.clone()).run(&shard_cfg, &packed[lo..hi])?;
            let t1 = check_t1
                .then(|| theorem1_predicate(&shard_cfg.build_graph(), &r.sets, shard_cfg.t));
            Ok((r, t1))
        };
        let shard_runs = crate::par::map_indexed(shard_plan.shards(), shard_par, run_shard);
        let mut shard_results = Vec::with_capacity(shard_runs.len());
        for (s, r) in shard_runs.into_iter().enumerate() {
            shard_results.push(r.map_err(|e| e.context(format!("shard {s}")))?);
        }

        // Root inputs: a completed, reliable shard contributes its sum; an
        // aborted or unreliable shard is withheld (targeted step-0 drop of
        // its aggregator) — the global sum degrades to dropping that shard,
        // never to folding in a possibly mask-corrupted partial sum.
        let k = plan.len();
        let mut agg_models = Vec::with_capacity(shard_plan.shards());
        let mut root_sched = self.opts.agg_dropout.clone();
        for (s, (r, _)) in shard_results.iter().enumerate() {
            match (&r.sum, r.reliable) {
                (Some(sum), true) => agg_models.push(sum.clone()),
                _ => {
                    agg_models.push(vec![0u64; k]);
                    root_sched[0].push(s);
                }
            }
        }
        for v in &mut root_sched {
            v.sort_unstable();
            v.dedup();
        }

        let n_root = shard_plan.shards();
        let root_cfg = ProtocolConfig::builder()
            .clients(n_root)
            .threshold(n_root / 2 + 1) // majority of aggregators
            .model_dim(k)
            .mask_bits(cfg.mask_bits)
            .topology((**root).clone())
            .dropout(DropoutModel::Targeted { per_step: root_sched })
            .seed(root_seed(cfg.seed))
            .build()?;
        let root_opts = RoundOptions::builder().executor(self.opts.executor).build()?;
        let root_r = RoundRunner::new(root_opts).run(&root_cfg, &agg_models)?;
        let root_t1 = check_t1
            .then(|| theorem1_predicate(&root_cfg.build_graph(), &root_r.sets, root_cfg.t));

        // The sum covers exactly the shards whose aggregator made root-V3
        // (a later root dropout is recovered by reconstruction, like any
        // flat client); within each, the shard's own V3.
        let mut global_v3 = Vec::new();
        for &s in &root_r.sets.v3 {
            let lo = shard_plan.range(s).0;
            global_v3.extend(shard_results[s].0.sets.v3.iter().map(|&c| c + lo));
        }

        let sum = root_r.sum.as_ref().map(|packed_sum| plan.scatter(packed_sum));
        let reliable = root_r.reliable && sum.is_some();
        let true_sum = (self.opts.check_truth && sum.is_some())
            .then(|| truth_over(models, &global_v3, cfg.mask_bits, plan.as_ref()));

        let mut intra = NetStats::new(cfg.n);
        for (s, (r, _)) in shard_results.iter().enumerate() {
            intra.merge_at(&r.stats, shard_plan.range(s).0);
        }
        let shard_reports = shard_results
            .into_iter()
            .map(|(r, t1)| LevelReport {
                completed: r.sum.is_some(),
                reliable: r.reliable,
                sets: r.sets,
                theorem1_holds: t1,
            })
            .collect();

        Ok(HierRoundResult {
            sum,
            reliable,
            global_v3,
            shard_reports,
            root: Some(LevelReport {
                completed: root_r.sum.is_some(),
                reliable: root_r.reliable,
                sets: root_r.sets,
                theorem1_holds: root_t1,
            }),
            stats: HierStats { intra, root: root_r.stats },
            true_sum,
            plan,
            shard_plan,
        })
    }

    /// `shards == 1`: run the flat protocol under the `intra` family with
    /// the caller's codec/dropout untouched — bit-identical to a flat round
    /// by construction.
    fn run_single_shard(
        &self,
        cfg: &ProtocolConfig,
        models: &[Vec<u64>],
        intra: &Topology,
    ) -> Result<HierRoundResult> {
        let flat_cfg = ProtocolConfig { topology: intra.clone(), ..cfg.clone() };
        let mut inner = RoundOptions::builder().executor(self.opts.executor);
        if self.opts.executor == Executor::EventLoop {
            if let Some(w) = self.opts.workers {
                inner = inner.workers(w);
            }
        }
        let r = RoundRunner::new(inner.build()?).run(&flat_cfg, models)?;
        let plan = flat_cfg.codec.plan(flat_cfg.dim, flat_cfg.mask_bits, flat_cfg.seed, models);
        let t1 = self
            .opts
            .check_theorem1
            .then(|| theorem1_predicate(&flat_cfg.build_graph(), &r.sets, flat_cfg.t));
        let global_v3 = r.sets.v3.clone();
        let completed = r.sum.is_some();
        let reliable = r.reliable && completed;
        let true_sum = (self.opts.check_truth && completed)
            .then(|| truth_over(models, &global_v3, cfg.mask_bits, plan.as_ref()));
        Ok(HierRoundResult {
            sum: r.sum,
            reliable,
            global_v3,
            shard_reports: vec![LevelReport {
                completed,
                reliable: r.reliable,
                sets: r.sets,
                theorem1_holds: t1,
            }],
            root: None,
            stats: HierStats { intra: r.stats, root: NetStats::new(0) },
            true_sum,
            plan,
            shard_plan: ShardPlan::new(cfg.n, 1)?,
        })
    }
}

/// Plaintext sum of `models[c]` over `ids` in Z_{2^bits}, projected on the
/// round's plan support — the oracle the differential harness compares
/// every hierarchical sum against.
pub fn truth_over(models: &[Vec<u64>], ids: &[ClientId], bits: u32, plan: &IndexPlan) -> Vec<u64> {
    let modmask = mod_mask(bits);
    let dim = plan.dim();
    let mut truth = vec![0u64; dim];
    for &c in ids {
        for (j, w) in models[c].iter().enumerate() {
            truth[j] = truth[j].wrapping_add(w & modmask) & modmask;
        }
    }
    plan.project(&mut truth);
    truth
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::Codec;

    fn hier_cfg(n: usize, t: usize, shards: usize, seed: u64) -> ProtocolConfig {
        ProtocolConfig::builder()
            .clients(n)
            .threshold(t)
            .model_dim(8)
            .topology(Topology::Hierarchical {
                shards,
                intra: Box::new(Topology::Complete),
                root: Box::new(Topology::Complete),
            })
            .seed(seed)
            .build()
            .unwrap()
    }

    fn models(n: usize, dim: usize, seed: u64) -> Vec<Vec<u64>> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect()).collect()
    }

    #[test]
    fn shard_plan_partitions_with_remainder() {
        let p = ShardPlan::new(10, 3).unwrap();
        assert_eq!(p.shards(), 3);
        assert_eq!((p.range(0), p.range(1), p.range(2)), ((0, 4), (4, 7), (7, 10)));
        assert_eq!((p.min_size(), p.max_size()), (3, 4));
        for id in 0..10 {
            let s = p.shard_of(id);
            let (lo, hi) = p.range(s);
            assert!(id >= lo && id < hi, "id={id} s={s}");
        }
        assert!(ShardPlan::new(4, 0).is_err());
        assert!(ShardPlan::new(4, 5).is_err());
        // target-size construction keeps sizes ≥ the target
        let q = ShardPlan::from_shard_size(10, 4).unwrap();
        assert_eq!(q.shards(), 2);
        assert_eq!(q.min_size(), 5);
    }

    /// The partition law for arbitrary (n, shards): contiguous coverage of
    /// [0, n), balanced sizes (the first n % shards shards carry the one
    /// extra client), and `shard_of` as the exact inverse of `range` —
    /// exhaustively for every small pair, then across a seeded sweep of
    /// large ones.
    #[test]
    fn shard_plan_partition_properties_hold_for_arbitrary_shapes() {
        fn check(n: usize, shards: usize) {
            let p = ShardPlan::new(n, shards).unwrap();
            assert_eq!(p.n(), n);
            assert_eq!(p.shards(), shards);
            // contiguous cover of [0, n)
            let mut cursor = 0;
            for s in 0..shards {
                let (lo, hi) = p.range(s);
                assert_eq!(lo, cursor, "n={n} shards={shards} s={s}: gap or overlap");
                assert!(hi > lo, "n={n} shards={shards} s={s}: empty shard");
                cursor = hi;
            }
            assert_eq!(cursor, n, "n={n} shards={shards}: partition must cover [0, n)");
            // balance: sizes differ by ≤ 1, extras go to the first n % shards
            let (base, extra) = (n / shards, n % shards);
            for s in 0..shards {
                let want = base + usize::from(s < extra);
                assert_eq!(p.len_of(s), want, "n={n} shards={shards} s={s}");
            }
            assert_eq!(p.min_size(), base + usize::from(extra == shards));
            assert_eq!(p.max_size(), base + usize::from(extra > 0));
            // shard_of inverts range on every id
            for id in 0..n {
                let s = p.shard_of(id);
                let (lo, hi) = p.range(s);
                assert!((lo..hi).contains(&id), "n={n} shards={shards} id={id} s={s}");
            }
        }
        for n in 1..=24 {
            for shards in 1..=n {
                check(n, shards);
            }
        }
        let mut rng = Rng::new(0x5AA2D);
        for _ in 0..50 {
            let n = 25 + rng.gen_range(4_000) as usize;
            let shards = 1 + rng.gen_range(n as u64) as usize;
            check(n, shards);
            // target-size construction never undershoots its target
            let size = 1 + rng.gen_range(n as u64) as usize;
            let q = ShardPlan::from_shard_size(n, size).unwrap();
            assert!(
                q.shards() == 1 || q.min_size() >= size,
                "n={n} size={size}: min shard {} below target",
                q.min_size()
            );
        }
    }

    #[test]
    fn level_seeds_are_distinct_domains() {
        let master = 42;
        let mut seen = std::collections::BTreeSet::new();
        seen.insert(master);
        seen.insert(root_seed(master));
        for s in 0..100 {
            seen.insert(shard_seed(master, s));
        }
        assert_eq!(seen.len(), 102, "all level seeds must be pairwise distinct");
    }

    #[test]
    fn builder_validates_hierarchical_bounds() {
        let hier = |shards| Topology::Hierarchical {
            shards,
            intra: Box::new(Topology::Complete),
            root: Box::new(Topology::Complete),
        };
        let base = |t| ProtocolConfig::builder().clients(12).threshold(t).model_dim(4);
        assert!(base(3).topology(hier(3)).build().is_ok());
        // shard size 12/4 = 3 < t+1 = 4 → rejected at build time
        assert!(base(3).topology(hier(4)).build().is_err());
        assert!(base(3).topology(hier(0)).build().is_err());
        assert!(base(1).topology(hier(13)).build().is_err());
        // nested hierarchy rejected
        assert!(base(2)
            .topology(Topology::Hierarchical {
                shards: 2,
                intra: Box::new(hier(2)),
                root: Box::new(Topology::Complete),
            })
            .build()
            .is_err());
        // root family validated against the shard count
        assert!(base(2)
            .topology(Topology::Hierarchical {
                shards: 2,
                intra: Box::new(Topology::Complete),
                root: Box::new(Topology::Harary { k: 2 }),
            })
            .build()
            .is_err());
    }

    #[test]
    fn flat_drivers_reject_hierarchical_configs() {
        let cfg = hier_cfg(12, 3, 3, 7);
        let ms = models(12, 8, 7);
        let err = crate::protocol::engine::run_round(&cfg, &ms).unwrap_err();
        assert!(err.to_string().contains("hier"), "{err}");
        let runner = RoundRunner::new(RoundOptions::default());
        assert!(runner.run(&cfg, &ms).is_err());
    }

    #[test]
    fn healthy_round_sums_exactly() {
        let cfg = hier_cfg(13, 3, 3, 11);
        let ms = models(13, 8, 11);
        let r = HierRunner::new(HierOptions {
            executor: Executor::Engine,
            check_theorem1: true,
            ..HierOptions::default()
        })
        .run(&cfg, &ms)
        .unwrap();
        assert!(r.reliable);
        assert_eq!(r.global_v3, (0..13).collect::<Vec<_>>());
        assert_eq!(r.sum, r.true_sum, "secure sum must equal the plaintext truth");
        assert_eq!(r.shard_reports.len(), 3);
        assert!(r.shard_reports.iter().all(|s| s.completed && s.reliable));
        let root = r.root.as_ref().unwrap();
        assert_eq!(root.sets.v3, vec![0, 1, 2]);
        assert_eq!(root.theorem1_holds, Some(true));
        // per-level stats: every global client was charged intra traffic,
        // every aggregator root traffic
        assert!(r.stats.intra.client_up.iter().all(|&b| b > 0));
        assert_eq!(r.stats.root.client_up.len(), 3);
        assert!(r.stats.total_bytes() > 0);
    }

    #[test]
    fn engine_and_event_loop_agree_bit_for_bit() {
        for codec in [Codec::Dense, Codec::TopK { k: 3 }, Codec::RandK { k: 4 }] {
            let cfg = ProtocolConfig::builder()
                .clients(14)
                .threshold(2)
                .model_dim(8)
                .topology(Topology::Hierarchical {
                    shards: 4,
                    intra: Box::new(Topology::Complete),
                    root: Box::new(Topology::Complete),
                })
                .codec(codec.clone())
                .dropout(DropoutModel::Targeted {
                    per_step: [vec![1], vec![], vec![7], vec![12]],
                })
                .seed(23)
                .build()
                .unwrap();
            let ms = models(14, 8, 23);
            let run = |ex| {
                HierRunner::new(HierOptions { executor: ex, ..HierOptions::default() })
                    .run(&cfg, &ms)
                    .unwrap()
            };
            let a = run(Executor::Engine);
            let b = run(Executor::EventLoop);
            assert_eq!(a.sum, b.sum, "{codec:?}");
            assert_eq!(a.global_v3, b.global_v3, "{codec:?}");
            assert_eq!(a.reliable, b.reliable, "{codec:?}");
            assert!(a.stats.intra.logical_eq(&b.stats.intra), "{codec:?}");
            assert!(a.stats.root.logical_eq(&b.stats.root), "{codec:?}");
            assert_eq!(a.sum, a.true_sum, "{codec:?}");
        }
    }

    #[test]
    fn lost_aggregator_drops_one_shard_only() {
        let cfg = hier_cfg(15, 3, 3, 31);
        let ms = models(15, 8, 31);
        // aggregator 1 never shows up at the root level
        let opts = HierOptions {
            executor: Executor::Engine,
            agg_dropout: [vec![1], vec![], vec![], vec![]],
            ..HierOptions::default()
        };
        let r = HierRunner::new(opts).run(&cfg, &ms).unwrap();
        assert!(r.reliable);
        let (lo, hi) = r.shard_plan.range(1);
        assert!(r.global_v3.iter().all(|&c| c < lo || c >= hi), "shard 1 must be excluded");
        assert_eq!(r.global_v3.len(), 15 - (hi - lo));
        // the sum is the exact truth over the two surviving shards — the
        // lost aggregator degraded to a dropped shard, nothing corrupted
        assert_eq!(r.sum, r.true_sum);
        assert!(r.root.as_ref().unwrap().sets.v3.iter().all(|&a| a != 1));
    }

    #[test]
    fn single_shard_degenerates_to_flat_bit_identically() {
        let cfg = ProtocolConfig::builder()
            .clients(9)
            .threshold(3)
            .model_dim(8)
            .topology(Topology::Hierarchical {
                shards: 1,
                intra: Box::new(Topology::ErdosRenyi { p: 0.9 }),
                root: Box::new(Topology::Complete),
            })
            .dropout(DropoutModel::Targeted { per_step: [vec![], vec![2], vec![], vec![5]] })
            .seed(77)
            .build()
            .unwrap();
        let ms = models(9, 8, 77);
        let flat_cfg =
            ProtocolConfig { topology: Topology::ErdosRenyi { p: 0.9 }, ..cfg.clone() };
        let flat = crate::protocol::engine::run_round(&flat_cfg, &ms).unwrap();
        let hier = HierRunner::new(HierOptions {
            executor: Executor::Engine,
            ..HierOptions::default()
        })
        .run(&cfg, &ms)
        .unwrap();
        assert_eq!(hier.sum, flat.sum);
        assert_eq!(hier.global_v3, flat.sets.v3);
        assert_eq!(hier.shard_reports[0].sets, flat.sets);
        assert!(hier.root.is_none());
        assert!(hier.stats.intra.logical_eq(&flat.stats));
    }

    #[test]
    fn truth_over_projects_on_plan_support() {
        let ms = vec![vec![5u64, 6, 7, 8], vec![1u64, 2, 3, 4]];
        let plan = IndexPlan::sparse(vec![1, 3], 4);
        let t = truth_over(&ms, &[0, 1], 32, plan.as_ref());
        assert_eq!(t, vec![0, 8, 0, 12]);
    }
}
