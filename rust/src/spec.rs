//! `RoundSpec` — the one round-configuration surface behind the `ccesa`
//! CLI (`round`, `topology`, `serve`, `connect`, `recover`).
//!
//! Resolution order is **defaults ← `--spec <file.toml>` ← explicitly
//! passed flags**: the spec file overrides the built-in defaults, and any
//! flag the user actually typed overrides the file (declared flag
//! defaults do *not* override it — see [`crate::util::cli::Args::is_set`]).
//! The same struct feeds the campaign machinery: [`RoundSpec::scenario`]
//! compiles to a [`Scenario`], and a `[timeouts] sweep_ms` axis plus a
//! `[clock]` section drive [`crate::sim::run_timeout_sweep`] — so a
//! sim-tuned spec file is byte-for-byte the file handed to `serve`.
//!
//! ```toml
//! [round]
//! n = 12
//! dim = 64
//! seed = 0x51EE9
//! qtotal = 0.0           # iid protocol-level dropout, like --qtotal
//! codec = "topk:0.1"     # dense | topk:<frac> | randk:<frac>
//! rounds = 3             # session warm rounds / sweep rounds per point
//! # p = 0.64             # ER edge probability (default p*(n, qtotal))
//! # t = 9                # threshold (default Remark 4 rule)
//! # sa = true            # complete graph (Bonawitz et al. SA)
//!
//! [timeouts]
//! phase_ms = [5, 5, 5, 5]   # or: uniform_ms = 5
//! min_survivors = 0
//! sweep_ms = [5, 100]       # optional: score the deadline axis instead
//!
//! [clock]                   # virtual-clock delays (sim only)
//! link = "bimodal"          # none | uniform | bimodal
//! fast_lo_us = 200
//! fast_hi_us = 1500
//! slow_lo_us = 20000
//! slow_hi_us = 40000
//! slow_frac = 0.5
//! compute_lo_us = 50
//! compute_hi_us = 300
//!
//! [shards]                  # two-level hierarchical round
//! count = 10                # or: size = 100
//!
//! [session]                 # cross-round session (`ccesa round`)
//! dir = "runs/s"
//! rounds = 10
//!
//! [journal]
//! dir = "runs/j"
//!
//! [wire]
//! addr = "127.0.0.1:7171"
//! timeout_s = 120
//! ```

use crate::analysis::bounds::{p_star, per_step_q, t_rule};
use crate::coordinator::TimeoutPolicy;
use crate::hier::ShardPlan;
use crate::protocol::dropout::DropoutModel;
use crate::protocol::{ProtocolConfig, Topology};
use crate::sim::{
    AdversarySpec, ChurnModel, ClockSpec, ClockedScenario, CodecSpec, LatencyModel, Scenario,
    ThresholdRule, TopologySchedule,
};
use crate::util::cli::Args;
use crate::util::toml::{Toml, TomlValue};
use anyhow::{anyhow, bail, Result};
use std::path::Path;
use std::time::Duration;

/// `--shards <count>` / `--shard-size <size>` / `[shards]` — mutually
/// exclusive by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardSpec {
    Count(usize),
    Size(usize),
}

/// `[timeouts]`: the phase-deadline policy, plus an optional sweep axis.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TimeoutSpec {
    /// Per-phase deadlines in milliseconds (`phase_ms`, or `uniform_ms`
    /// replicated four times).
    pub phase_ms: [u64; 4],
    /// Grace floor forwarded to [`TimeoutPolicy::min_survivors`].
    pub min_survivors: usize,
    /// Non-empty ⇒ `ccesa round` scores reliability/privacy/latency at
    /// each of these uniform deadlines instead of running one round.
    pub sweep_ms: Vec<u64>,
}

impl TimeoutSpec {
    pub fn policy(&self) -> TimeoutPolicy {
        TimeoutPolicy {
            per_phase_deadlines: self.phase_ms.map(Duration::from_millis),
            min_survivors: self.min_survivors,
        }
    }
}

/// The resolved round configuration — see the module docs for the file
/// format and precedence rules.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundSpec {
    pub n: usize,
    pub dim: usize,
    pub seed: u64,
    pub qtotal: f64,
    /// ER edge probability; `None` = `p*(n, qtotal)`.
    pub p: Option<f64>,
    /// Secret-sharing threshold; `None` = Remark 4 rule.
    pub t: Option<usize>,
    /// Complete graph (Bonawitz et al. SA) instead of Erdős–Rényi.
    pub sa: bool,
    pub codec: CodecSpec,
    /// Session warm rounds, and rounds per sweep point.
    pub rounds: u64,
    pub shards: Option<ShardSpec>,
    /// Session directory for `ccesa round` (cold round + warm rounds).
    pub session: Option<String>,
    /// Journal directory for `serve` / session rounds.
    pub journal: Option<String>,
    pub addr: String,
    /// Whole-round wire deadline in seconds.
    pub timeout_s: u64,
    pub timeouts: Option<TimeoutSpec>,
    pub clock: Option<ClockSpec>,
}

impl Default for RoundSpec {
    fn default() -> Self {
        RoundSpec {
            n: 100,
            dim: 10_000,
            seed: 1,
            qtotal: 0.0,
            p: None,
            t: None,
            sa: false,
            codec: CodecSpec::Dense,
            rounds: 5,
            shards: None,
            session: None,
            journal: None,
            addr: "127.0.0.1:7171".to_string(),
            timeout_s: 120,
            timeouts: None,
            clock: None,
        }
    }
}

/// Parse `dense | topk:<frac> | randk:<frac>` (the `--codec` flag and the
/// `codec` spec key share this grammar).
pub fn parse_codec(spec: &str) -> Result<CodecSpec> {
    let spec = spec.trim();
    if spec == "dense" {
        return Ok(CodecSpec::Dense);
    }
    let (kind, frac) = spec
        .split_once(':')
        .ok_or_else(|| anyhow!("codec {spec:?}: expected dense | topk:<frac> | randk:<frac>"))?;
    let frac: f64 = frac
        .parse()
        .map_err(|_| anyhow!("codec {spec:?}: fraction must be a number in (0, 1]"))?;
    if !frac.is_finite() || frac <= 0.0 || frac > 1.0 {
        bail!("codec {spec:?}: fraction {frac} must be in (0, 1]");
    }
    match kind {
        "topk" => Ok(CodecSpec::TopK { frac }),
        "randk" => Ok(CodecSpec::RandK { frac }),
        other => bail!("unknown codec family {other:?} (dense|topk|randk)"),
    }
}

/// Allowed sections/keys — unknown ones are typos, not extensions, and
/// fail loudly with the full allow-list.
const SECTIONS: &[(&str, &[&str])] = &[
    ("", &[]),
    ("round", &["n", "dim", "seed", "qtotal", "p", "t", "sa", "codec", "rounds"]),
    ("shards", &["count", "size"]),
    ("session", &["dir", "rounds"]),
    ("journal", &["dir"]),
    ("wire", &["addr", "timeout_s"]),
    ("timeouts", &["phase_ms", "uniform_ms", "min_survivors", "sweep_ms"]),
    (
        "clock",
        &[
            "link",
            "lo_us",
            "hi_us",
            "fast_lo_us",
            "fast_hi_us",
            "slow_lo_us",
            "slow_hi_us",
            "slow_frac",
            "compute_lo_us",
            "compute_hi_us",
        ],
    ),
];

impl RoundSpec {
    /// Resolve the full precedence chain for one CLI invocation:
    /// defaults ← `--spec` file (if any) ← explicitly passed flags.
    pub fn resolve(args: &Args) -> Result<RoundSpec> {
        let mut spec = match args.get_str("spec") {
            Some(path) => RoundSpec::load(Path::new(&path))?,
            None => RoundSpec::default(),
        };
        spec.apply_overrides(args)?;
        spec.validate()?;
        Ok(spec)
    }

    pub fn load(path: &Path) -> Result<RoundSpec> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading spec {}: {e}", path.display()))?;
        RoundSpec::from_toml_str(&text).map_err(|e| anyhow!("spec {}: {e}", path.display()))
    }

    /// Apply a spec file on top of the defaults.
    pub fn from_toml_str(text: &str) -> Result<RoundSpec> {
        let doc = Toml::parse(text)?;
        for section in doc.section_names() {
            let allowed = SECTIONS.iter().find(|(name, _)| *name == section);
            let Some((_, keys)) = allowed else {
                bail!(
                    "unknown section [{section}] (expected one of: {})",
                    SECTIONS.iter().map(|(n, _)| *n).filter(|n| !n.is_empty()).collect::<Vec<_>>().join(", ")
                );
            };
            for key in doc.keys(section) {
                if !keys.contains(&key) {
                    bail!(
                        "unknown key {key:?} in [{section}] (expected one of: {})",
                        keys.join(", ")
                    );
                }
            }
        }

        let mut spec = RoundSpec::default();
        let usize_of = |s: &str, k: &str| doc.typed(s, k, "integer", TomlValue::as_usize);
        let u64_of = |s: &str, k: &str| doc.typed(s, k, "integer", TomlValue::as_u64);
        let f64_of = |s: &str, k: &str| doc.typed(s, k, "number", TomlValue::as_f64);
        let str_of =
            |s: &str, k: &str| doc.typed(s, k, "string", |v| v.as_str().map(str::to_string));
        let bool_of = |s: &str, k: &str| doc.typed(s, k, "boolean", TomlValue::as_bool);

        if let Some(n) = usize_of("round", "n")? {
            spec.n = n;
        }
        if let Some(dim) = usize_of("round", "dim")? {
            spec.dim = dim;
        }
        if let Some(seed) = u64_of("round", "seed")? {
            spec.seed = seed;
        }
        if let Some(qt) = f64_of("round", "qtotal")? {
            spec.qtotal = qt;
        }
        spec.p = f64_of("round", "p")?;
        spec.t = usize_of("round", "t")?;
        if let Some(sa) = bool_of("round", "sa")? {
            spec.sa = sa;
        }
        if let Some(codec) = str_of("round", "codec")? {
            spec.codec = parse_codec(&codec)?;
        }
        if let Some(rounds) = u64_of("round", "rounds")? {
            spec.rounds = rounds;
        }

        spec.shards = match (usize_of("shards", "count")?, usize_of("shards", "size")?) {
            (Some(_), Some(_)) => {
                bail!("[shards]: `count` and `size` are mutually exclusive — pick one")
            }
            (Some(c), None) => Some(ShardSpec::Count(c)),
            (None, Some(m)) => Some(ShardSpec::Size(m)),
            (None, None) => None,
        };

        spec.session = str_of("session", "dir")?;
        if spec.session.is_none() && doc.has_section("session") {
            bail!("[session] requires `dir`");
        }
        if let Some(rounds) = u64_of("session", "rounds")? {
            spec.rounds = rounds;
        }
        spec.journal = str_of("journal", "dir")?;
        if spec.journal.is_none() && doc.has_section("journal") {
            bail!("[journal] requires `dir`");
        }
        if let Some(addr) = str_of("wire", "addr")? {
            spec.addr = addr;
        }
        if let Some(ts) = u64_of("wire", "timeout_s")? {
            spec.timeout_s = ts;
        }

        if doc.has_section("timeouts") {
            let uniform = u64_of("timeouts", "uniform_ms")?;
            let phase = match doc.get("timeouts", "phase_ms") {
                None => None,
                Some(v) => {
                    let arr = v
                        .as_arr()
                        .ok_or_else(|| anyhow!("timeouts.phase_ms must be an array of 4 integers"))?;
                    let ms: Vec<u64> = arr
                        .iter()
                        .map(|x| {
                            x.as_u64().ok_or_else(|| {
                                anyhow!("timeouts.phase_ms entries must be non-negative integers")
                            })
                        })
                        .collect::<Result<_>>()?;
                    let ms: [u64; 4] = ms.try_into().map_err(|v: Vec<u64>| {
                        anyhow!(
                            "timeouts.phase_ms needs exactly 4 entries (one per protocol phase), got {}",
                            v.len()
                        )
                    })?;
                    Some(ms)
                }
            };
            let phase_ms = match (phase, uniform) {
                (Some(_), Some(_)) => {
                    bail!("[timeouts]: `phase_ms` and `uniform_ms` are mutually exclusive")
                }
                (Some(p), None) => p,
                (None, Some(u)) => [u; 4],
                (None, None) => {
                    bail!("[timeouts] requires `phase_ms = [..4 entries..]` or `uniform_ms`")
                }
            };
            let sweep_ms = match doc.get("timeouts", "sweep_ms") {
                None => Vec::new(),
                Some(v) => v
                    .as_arr()
                    .ok_or_else(|| anyhow!("timeouts.sweep_ms must be an array of integers"))?
                    .iter()
                    .map(|x| {
                        x.as_u64().filter(|ms| *ms > 0).ok_or_else(|| {
                            anyhow!("timeouts.sweep_ms entries must be positive integers")
                        })
                    })
                    .collect::<Result<_>>()?,
            };
            spec.timeouts = Some(TimeoutSpec {
                phase_ms,
                min_survivors: usize_of("timeouts", "min_survivors")?.unwrap_or(0),
                sweep_ms,
            });
        }

        if doc.has_section("clock") {
            let link = str_of("clock", "link")?.unwrap_or_else(|| "uniform".to_string());
            let link = match link.as_str() {
                "none" => LatencyModel::None,
                "uniform" => LatencyModel::Uniform {
                    lo_us: u64_of("clock", "lo_us")?.unwrap_or(50),
                    hi_us: u64_of("clock", "hi_us")?.unwrap_or(5_000),
                },
                "bimodal" => LatencyModel::Bimodal {
                    fast_lo_us: u64_of("clock", "fast_lo_us")?.unwrap_or(50),
                    fast_hi_us: u64_of("clock", "fast_hi_us")?.unwrap_or(1_000),
                    slow_lo_us: u64_of("clock", "slow_lo_us")?.unwrap_or(5_000),
                    slow_hi_us: u64_of("clock", "slow_hi_us")?.unwrap_or(30_000),
                    slow_frac: f64_of("clock", "slow_frac")?.unwrap_or(0.1),
                },
                other => bail!("clock.link {other:?} (none | uniform | bimodal)"),
            };
            if let LatencyModel::Bimodal { slow_frac, .. } = link {
                if !(0.0..=1.0).contains(&slow_frac) {
                    bail!("clock.slow_frac {slow_frac} must be in [0, 1]");
                }
            }
            spec.clock = Some(ClockSpec {
                link,
                compute_us: (
                    u64_of("clock", "compute_lo_us")?.unwrap_or(10),
                    u64_of("clock", "compute_hi_us")?.unwrap_or(200),
                ),
            });
        }
        Ok(spec)
    }

    /// Overlay every *explicitly passed* flag (spec-file keys already
    /// applied; flag defaults deliberately ignored).
    fn apply_overrides(&mut self, args: &Args) -> Result<()> {
        if args.is_set("n") {
            self.n = args.req("n");
        }
        if args.is_set("dim") {
            self.dim = args.req("dim");
        }
        if args.is_set("seed") {
            self.seed = args.req("seed");
        }
        if args.is_set("qtotal") {
            self.qtotal = args.req("qtotal");
        }
        if args.is_set("p") {
            self.p = Some(args.req("p"));
        }
        if args.is_set("t") {
            self.t = Some(args.req("t"));
        }
        if args.is_set("sa") {
            self.sa = true;
        }
        if args.is_set("codec") {
            self.codec = parse_codec(&args.req::<String>("codec"))?;
        }
        if args.is_set("rounds") {
            self.rounds = args.req("rounds");
        }
        match (args.is_set("shards"), args.is_set("shard-size")) {
            (true, true) => bail!("--shards and --shard-size are mutually exclusive"),
            (true, false) => self.shards = Some(ShardSpec::Count(args.req("shards"))),
            (false, true) => self.shards = Some(ShardSpec::Size(args.req("shard-size"))),
            (false, false) => {}
        }
        if args.is_set("session") {
            self.session = args.get_str("session");
        }
        if args.is_set("journal") {
            self.journal = args.get_str("journal");
        }
        if args.is_set("addr") {
            self.addr = args.req("addr");
        }
        if args.is_set("timeout-s") {
            self.timeout_s = args.req("timeout-s");
        }
        Ok(())
    }

    /// Cross-section rules, named like the `RoundOptions` builder names
    /// its conflicts.
    fn validate(&self) -> Result<()> {
        if self.n == 0 {
            bail!("round.n must be ≥ 1");
        }
        if self.dim == 0 {
            bail!("round.dim must be ≥ 1");
        }
        if !(0.0..1.0).contains(&self.qtotal) {
            bail!("round.qtotal {} must be in [0, 1)", self.qtotal);
        }
        if let Some(p) = self.p {
            if !(0.0..=1.0).contains(&p) {
                bail!("round.p {p} must be in [0, 1]");
            }
        }
        if self.shards.is_some() && self.session.is_some() {
            bail!("[shards] conflicts with [session]: hierarchical rounds have no session support");
        }
        if self.shards.is_some() && self.timeouts.is_some() {
            bail!(
                "[shards] conflicts with [timeouts]: clocked hierarchical rounds are not \
                 supported yet (flat rounds only)"
            );
        }
        if self.session.is_some() && self.timeouts.is_some() {
            bail!("[session] conflicts with [timeouts]: warm rounds are not clocked yet");
        }
        if self.clock.is_some() && self.timeouts.is_none() {
            bail!("[clock] requires [timeouts]: a latency schedule without deadlines is inert");
        }
        if let Some(t) = &self.timeouts {
            if !t.sweep_ms.is_empty() && self.clock.is_none() {
                bail!("timeouts.sweep_ms requires a [clock] section to simulate delays against");
            }
        }
        Ok(())
    }

    /// `(p, t)` after defaulting: `p*(n, qtotal)` and the Remark 4 rule
    /// (SA: complete graph, majority threshold).
    pub fn graph_params(&self) -> (f64, usize) {
        let p = if self.sa { 1.0 } else { self.p.unwrap_or_else(|| p_star(self.n, self.qtotal)) };
        let t = self.t.unwrap_or_else(|| {
            if self.sa {
                self.n / 2 + 1
            } else {
                t_rule(self.n, p)
            }
        });
        (p, t)
    }

    /// Flat-round topology (hier rounds wrap this per shard).
    pub fn topology(&self) -> Topology {
        let (p, _) = self.graph_params();
        if self.sa {
            Topology::Complete
        } else {
            Topology::ErdosRenyi { p }
        }
    }

    fn dropout(&self) -> DropoutModel {
        if self.qtotal > 0.0 {
            DropoutModel::iid_from_total(self.qtotal)
        } else {
            DropoutModel::None
        }
    }

    /// The flat-round [`ProtocolConfig`] (`round` without shards, and the
    /// shared `serve`/`connect` wire config).
    pub fn protocol_config(&self) -> Result<ProtocolConfig> {
        let (_, t) = self.graph_params();
        ProtocolConfig::builder()
            .clients(self.n)
            .threshold(t)
            .model_dim(self.dim)
            .topology(self.topology())
            .dropout(self.dropout())
            .codec(self.codec.resolve(self.dim))
            .seed(self.seed)
            .build()
    }

    pub fn shard_plan(&self) -> Result<Option<ShardPlan>> {
        Ok(match self.shards {
            None => None,
            Some(ShardSpec::Count(c)) => Some(ShardPlan::new(self.n, c)?),
            Some(ShardSpec::Size(m)) => Some(ShardPlan::from_shard_size(self.n, m)?),
        })
    }

    /// Per-shard `(p, t, sa)` for hierarchical rounds: defaults derive
    /// from the *minimum* shard size (the builder requires every shard to
    /// hold ≥ t+1 clients, so the smallest shard governs).
    pub fn shard_graph_params(&self, plan: &ShardPlan) -> (f64, usize, bool) {
        // `t_rule`/`p_star` need n ≥ 2; the builder rejects genuinely
        // undersized shards later with its own ≥ t+1 message.
        let m = plan.min_size().max(2);
        let p = if self.sa { 1.0 } else { self.p.unwrap_or_else(|| p_star(m, self.qtotal)) };
        let t = self.t.unwrap_or_else(|| {
            let t = if self.sa { m / 2 + 1 } else { t_rule(m, p) };
            t.min(m.saturating_sub(1)).max(1)
        });
        (p, t, self.sa)
    }

    /// Compile to a campaign [`Scenario`] (flat rounds): qtotal becomes
    /// i.i.d. churn, the resolved threshold is pinned, no adversary.
    pub fn scenario(&self, name: &str) -> Scenario {
        let (_, t) = self.graph_params();
        Scenario {
            name: name.to_string(),
            n: self.n,
            dim: self.dim,
            mask_bits: 32,
            rounds: self.rounds.max(1) as usize,
            topology: TopologySchedule::Static(self.topology()),
            churn: if self.qtotal > 0.0 {
                ChurnModel::Iid { q: per_step_q(self.qtotal) }
            } else {
                ChurnModel::None
            },
            adversary: AdversarySpec::Eavesdropper,
            threshold: ThresholdRule::Fixed(t),
            codec: self.codec,
            clip: 4.0,
            seed: self.seed,
        }
    }

    /// The clocked-campaign view, when `[clock]` + `[timeouts]` are both
    /// present.
    pub fn clocked_scenario(&self, name: &str) -> Option<ClockedScenario> {
        let (clock, timeouts) = (self.clock.as_ref()?, self.timeouts.as_ref()?);
        Some(ClockedScenario {
            base: self.scenario(name),
            clock: clock.clone(),
            policy: timeouts.policy(),
        })
    }

    /// The wire timeout policy for `serve`, if one is configured.
    pub fn timeout_policy(&self) -> Option<TimeoutPolicy> {
        self.timeouts.as_ref().map(|t| t.policy())
    }

    pub fn wire_timeout(&self) -> Duration {
        Duration::from_secs(self.timeout_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args_with(toks: &[&str]) -> Args {
        let argv: Vec<String> = toks.iter().map(|s| s.to_string()).collect();
        crate::util::cli::Args::new("test", "about")
            .flag("n", Some("100"), "")
            .flag("p", None, "")
            .flag("t", None, "")
            .flag("dim", Some("10000"), "")
            .flag("qtotal", Some("0.0"), "")
            .flag("seed", Some("1"), "")
            .flag("codec", Some("dense"), "")
            .flag("addr", Some("127.0.0.1:7171"), "")
            .flag("timeout-s", Some("120"), "")
            .flag("journal", None, "")
            .flag("session", None, "")
            .flag("rounds", Some("5"), "")
            .flag("shards", None, "")
            .flag("shard-size", None, "")
            .flag("spec", None, "")
            .switch("sa", "")
            .parse_from(argv)
            .unwrap()
    }

    #[test]
    fn defaults_match_the_historical_cli_defaults() {
        let spec = RoundSpec::resolve(&args_with(&[])).unwrap();
        assert_eq!(spec, RoundSpec::default());
        assert_eq!(spec.n, 100);
        assert_eq!(spec.dim, 10_000);
        assert_eq!(spec.timeout_s, 120);
        assert_eq!(spec.addr, "127.0.0.1:7171");
        assert!(spec.timeouts.is_none() && spec.clock.is_none());
    }

    #[test]
    fn file_overrides_defaults_and_flags_override_file() {
        let text = "[round]\nn = 40\ndim = 16\nseed = 9\ncodec = \"topk:0.25\"";
        let spec = RoundSpec::from_toml_str(text).unwrap();
        assert_eq!((spec.n, spec.dim, spec.seed), (40, 16, 9));
        assert_eq!(spec.codec, CodecSpec::TopK { frac: 0.25 });

        let dir = std::env::temp_dir().join(format!("ccesa-spec-{}.toml", std::process::id()));
        std::fs::write(&dir, text).unwrap();
        let path = dir.to_str().unwrap().to_string();
        // --n explicitly passed beats the file; dim stays the file's
        let spec = RoundSpec::resolve(&args_with(&["--spec", &path, "--n", "7"])).unwrap();
        assert_eq!((spec.n, spec.dim, spec.seed), (7, 16, 9));
        // defaulted flags do NOT beat the file
        let spec = RoundSpec::resolve(&args_with(&["--spec", &path])).unwrap();
        assert_eq!(spec.n, 40);
        std::fs::remove_file(&dir).unwrap();
    }

    #[test]
    fn parses_every_section() {
        let spec = RoundSpec::from_toml_str(
            r#"
[round]
n = 12
dim = 8
seed = 0x51EE9
sa = true
[wire]
addr = "0.0.0.0:9999"
timeout_s = 7
[journal]
dir = "runs/j"
[timeouts]
phase_ms = [5, 5, 5, 5]
min_survivors = 9
sweep_ms = [5, 100]
[clock]
link = "bimodal"
fast_lo_us = 200
fast_hi_us = 1500
slow_lo_us = 20000
slow_hi_us = 40000
slow_frac = 0.5
compute_lo_us = 50
compute_hi_us = 300
"#,
        )
        .unwrap();
        assert!(spec.sa);
        assert_eq!(spec.addr, "0.0.0.0:9999");
        assert_eq!(spec.timeout_s, 7);
        assert_eq!(spec.journal.as_deref(), Some("runs/j"));
        let t = spec.timeouts.as_ref().unwrap();
        assert_eq!(t.phase_ms, [5; 4]);
        assert_eq!(t.min_survivors, 9);
        assert_eq!(t.sweep_ms, vec![5, 100]);
        assert_eq!(
            t.policy(),
            TimeoutPolicy::uniform(Duration::from_millis(5)).with_min_survivors(9)
        );
        match spec.clock.as_ref().unwrap().link {
            LatencyModel::Bimodal { slow_frac, .. } => assert_eq!(slow_frac, 0.5),
            ref other => panic!("expected bimodal, got {other:?}"),
        }
        let csc = spec.clocked_scenario("pinned").unwrap();
        assert_eq!(csc.base.n, 12);
        assert!(matches!(csc.base.threshold, ThresholdRule::Fixed(t) if t == 12 / 2 + 1));
    }

    #[test]
    fn named_errors_for_conflicts_and_typos() {
        for (src, needle) in [
            ("[rnd]\nn = 3", "unknown section [rnd]"),
            ("[round]\nclients = 3", "unknown key \"clients\" in [round]"),
            ("[shards]\ncount = 2\nsize = 5", "`count` and `size` are mutually exclusive"),
            ("[timeouts]\nuniform_ms = 5\nphase_ms = [1,2,3,4]", "mutually exclusive"),
            ("[timeouts]\nphase_ms = [1,2,3]", "exactly 4 entries"),
            ("[timeouts]\nmin_survivors = 2", "requires `phase_ms"),
            ("[clock]\nlink = \"warp\"", "none | uniform | bimodal"),
            ("[session]\nrounds = 2", "[session] requires `dir`"),
            ("[journal]\n", "[journal] requires `dir`"),
            ("[round]\nn = \"many\"", "expected integer, got string"),
        ] {
            let e = RoundSpec::from_toml_str(src).unwrap_err().to_string();
            assert!(e.contains(needle), "{src:?} → {e}");
        }
        // cross-section rules fire in validate() via resolve()
        for (src, needle) in [
            ("[clock]\nlink = \"none\"", "[clock] requires [timeouts]"),
            (
                "[timeouts]\nuniform_ms = 5\nsweep_ms = [1]",
                "sweep_ms requires a [clock] section",
            ),
            (
                "[shards]\ncount = 2\n[timeouts]\nuniform_ms = 5",
                "[shards] conflicts with [timeouts]",
            ),
            ("[shards]\ncount = 2\n[session]\ndir = \"s\"", "[shards] conflicts with [session]"),
            (
                "[session]\ndir = \"s\"\n[timeouts]\nuniform_ms = 5",
                "[session] conflicts with [timeouts]",
            ),
        ] {
            let mut spec = RoundSpec::from_toml_str(src).unwrap();
            spec.n = 10;
            let e = spec.validate().unwrap_err().to_string();
            assert!(e.contains(needle), "{src:?} → {e}");
        }
    }

    #[test]
    fn flag_conflicts_still_fire_through_the_spec_path() {
        let e = RoundSpec::resolve(&args_with(&["--shards", "2", "--shard-size", "5"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("mutually exclusive"), "{e}");
        let spec = RoundSpec::resolve(&args_with(&["--shards", "4", "--n", "100"])).unwrap();
        let plan = spec.shard_plan().unwrap().unwrap();
        assert_eq!(plan.shards(), 4);
    }

    #[test]
    fn committed_example_spec_stays_loadable() {
        // the spec shipped in the repo (`ccesa round --spec
        // specs/straggler_sweep.toml`) must keep parsing and validating,
        // and must keep describing the CI-pinned straggler tradeoff
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../specs/straggler_sweep.toml");
        let spec = RoundSpec::load(Path::new(path)).unwrap();
        spec.validate().unwrap();
        assert_eq!((spec.n, spec.dim, spec.seed), (12, 8, 0x51EE9));
        assert!(spec.sa);
        assert_eq!(spec.t, Some(9));
        let ts = spec.timeouts.as_ref().unwrap();
        assert_eq!(ts.sweep_ms, vec![5, 100]);
        let csc = spec.clocked_scenario("straggler").unwrap();
        assert!(matches!(
            csc.clock.link,
            LatencyModel::Bimodal { slow_frac, .. } if slow_frac == 0.5
        ));
        assert!(matches!(csc.base.threshold, ThresholdRule::Fixed(9)));
    }

    #[test]
    fn scenario_compiles_and_respects_qtotal() {
        let mut spec = RoundSpec { n: 10, dim: 4, qtotal: 0.1, ..RoundSpec::default() };
        spec.rounds = 2;
        let sc = spec.scenario("spec-run");
        assert_eq!(sc.rounds, 2);
        assert!(matches!(sc.churn, ChurnModel::Iid { q } if q > 0.0));
        let plans = sc.compile();
        assert_eq!(plans.len(), 2);
        assert_eq!(plans[0].cfg.n, 10);
    }
}
