//! Threaded deployment shape: the server event loop and one worker thread
//! per client, exchanging the protocol messages over mpsc channels.
//!
//! `protocol::engine` is the deterministic synchronous core used by tests
//! and benches; this module is the "real service" arrangement — clients
//! are concurrent, the server collects each phase as messages arrive, and
//! per-phase completion is detected by counting (every live client either
//! responds or reports that it dropped). With `DropoutModel::None` or
//! `Targeted` the result is bit-identical to the sync engine for the same
//! seed (asserted in tests).

use crate::net::{Dir, NetStats};
use crate::protocol::client::Client;
use crate::protocol::messages::*;
use crate::protocol::server::{RoundOutput, Server};
use crate::protocol::{ClientId, ProtocolConfig, SurvivorSets};
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::sync::mpsc;

/// Client → server messages; every live client sends exactly one per phase.
enum Up {
    Adv(AdvertiseKeys),
    Shares(ShareUpload),
    Masked(MaskedInput),
    Unmask(UnmaskShares),
    /// client dropped during the given phase
    Dropped(ClientId, u8),
    /// client hit an internal error — treated as a drop, but logged
    Failed(ClientId, u8, String),
}

/// Server → client phase inputs.
enum Down {
    Bundle(KeyBundle),
    Delivery(ShareDelivery),
    Announce(SurvivorAnnounce),
    /// round over (client not needed further)
    Finish,
}

/// Outcome of a threaded round (mirrors the engine's essentials).
#[derive(Debug)]
pub struct CoordRoundResult {
    pub sum: Option<Vec<u64>>,
    pub reliable: bool,
    pub sets: SurvivorSets,
    pub stats: NetStats,
}

/// Run one aggregation round with real threads.
pub fn run_round_threaded(cfg: &ProtocolConfig, models: &[Vec<u64>]) -> Result<CoordRoundResult> {
    assert_eq!(models.len(), cfg.n);
    let mut rng = Rng::new(cfg.seed);
    let graph = cfg.build_graph_with(&mut rng);
    let mut dropout_rng = rng.split(0xD20);

    // Pre-draw dropout decisions in the engine's order so None/Targeted
    // models produce identical survivor sets to the sync engine.
    let mut survives = vec![[true; 4]; cfg.n];
    for step in 0..4 {
        for (id, s) in survives.iter_mut().enumerate() {
            s[step] = cfg.dropout.survives(step, id, &mut dropout_rng);
        }
    }

    let (tx_up, rx_up) = mpsc::channel::<Up>();
    let mut to_clients: BTreeMap<ClientId, mpsc::Sender<Down>> = BTreeMap::new();

    std::thread::scope(|scope| -> Result<CoordRoundResult> {
        // spawn client workers
        for id in 0..cfg.n {
            let (tx_down, rx_down) = mpsc::channel::<Down>();
            to_clients.insert(id, tx_down);
            let tx_up = tx_up.clone();
            let neighbors = graph.neighbors(id).to_vec();
            let mut key_rng = rng.split(0xC11E27 + id as u64);
            let mut share_rng = rng.split(0x5A12E + id as u64);
            let model = models[id].clone();
            let surv = survives[id];
            let t = cfg.t;
            let bits = cfg.mask_bits;
            scope.spawn(move || {
                let mut me = Client::new(id, t, bits, neighbors, &mut key_rng);
                // phase 0
                if !surv[0] {
                    let _ = tx_up.send(Up::Dropped(id, 0));
                    return;
                }
                let _ = tx_up.send(Up::Adv(me.step0_advertise()));
                // phase 1
                let Ok(Down::Bundle(bundle)) = rx_down.recv() else { return };
                if !surv[1] {
                    let _ = tx_up.send(Up::Dropped(id, 1));
                    return;
                }
                match me.step1_share_keys(&bundle, &mut share_rng) {
                    Ok(up) => {
                        let _ = tx_up.send(Up::Shares(up));
                    }
                    Err(e) => {
                        // small live neighborhood ⇒ secure withdrawal
                        let _ = tx_up.send(Up::Failed(id, 1, e.to_string()));
                        return;
                    }
                }
                // phase 2
                let Ok(Down::Delivery(delivery)) = rx_down.recv() else { return };
                if !surv[2] {
                    let _ = tx_up.send(Up::Dropped(id, 2));
                    return;
                }
                match me.step2_masked_input(&delivery, &model) {
                    Ok(mi) => {
                        let _ = tx_up.send(Up::Masked(mi));
                    }
                    Err(e) => {
                        let _ = tx_up.send(Up::Failed(id, 2, e.to_string()));
                        return;
                    }
                }
                // phase 3
                let Ok(Down::Announce(announce)) = rx_down.recv() else { return };
                if !surv[3] {
                    let _ = tx_up.send(Up::Dropped(id, 3));
                    return;
                }
                match me.step3_unmask(&announce) {
                    Ok(um) => {
                        let _ = tx_up.send(Up::Unmask(um));
                    }
                    Err(e) => {
                        let _ = tx_up.send(Up::Failed(id, 3, e.to_string()));
                    }
                }
                let _ = rx_down.recv(); // Finish
            });
        }
        drop(tx_up);

        // The server phases run in an inner closure so that EVERY exit path
        // — including a mid-protocol abort like |V_k| < t — falls through to
        // the wake-up loop below. Without it, an early `?` return would
        // leave worker threads parked on `rx_down.recv()` with their senders
        // still alive, and `thread::scope` would deadlock joining them.
        let result = (|| -> Result<CoordRoundResult> {
            let mut server = Server::new(cfg.n, cfg.t, cfg.mask_bits, cfg.dim, graph.clone());
            let mut stats = NetStats::new(cfg.n);

            // ---- phase 0: every client reports (advert or drop)
            let mut advs = Vec::new();
            for _ in 0..cfg.n {
                match rx_up.recv().map_err(|_| anyhow!("client channel closed"))? {
                    Up::Adv(a) => {
                        stats.record(0, Dir::Up, a.id, a.size_bytes());
                        advs.push(a);
                    }
                    Up::Dropped(id, step) => log::trace!("client {id} dropped at step {step}"),
                    Up::Failed(id, step, e) => log::debug!("client {id} failed step {step}: {e}"),
                    _ => return Err(anyhow!("protocol order violation in phase 0")),
                }
            }
            // deterministic drain order regardless of thread scheduling
            advs.sort_by_key(|a| a.id);
            let bundles = server.step0_route_keys(advs)?;
            let expect1 = bundles.len();
            for (id, b) in bundles {
                stats.record(0, Dir::Down, id, b.size_bytes());
                let _ = to_clients[&id].send(Down::Bundle(b));
            }

            // ---- phase 1
            let mut uploads = Vec::new();
            for _ in 0..expect1 {
                match rx_up.recv()? {
                    Up::Shares(u) => {
                        stats.record(1, Dir::Up, u.from, u.size_bytes());
                        uploads.push(u);
                    }
                    Up::Dropped(id, step) => log::trace!("client {id} dropped at step {step}"),
                    Up::Failed(id, step, e) => {
                        log::debug!("client {id} withdrew step {step}: {e}")
                    }
                    _ => return Err(anyhow!("protocol order violation in phase 1")),
                }
            }
            uploads.sort_by_key(|u| u.from);
            let deliveries = server.step1_route_shares(uploads)?;
            let expect2 = deliveries.len();
            for (id, d) in deliveries {
                stats.record(1, Dir::Down, id, d.size_bytes());
                let _ = to_clients[&id].send(Down::Delivery(d));
            }

            // ---- phase 2
            let mut masked = Vec::new();
            for _ in 0..expect2 {
                match rx_up.recv()? {
                    Up::Masked(m) => {
                        stats.record(2, Dir::Up, m.id, m.size_bytes());
                        masked.push(m);
                    }
                    Up::Dropped(id, step) => log::trace!("client {id} dropped at step {step}"),
                    Up::Failed(id, step, e) => log::debug!("client {id} failed step {step}: {e}"),
                    _ => return Err(anyhow!("protocol order violation in phase 2")),
                }
            }
            masked.sort_by_key(|m| m.id);
            let announce = server.step2_collect_masked(masked)?;
            let expect3 = announce.v3.len();
            for &id in &announce.v3 {
                stats.record(2, Dir::Down, id, announce.size_bytes());
                let _ = to_clients[&id].send(Down::Announce(announce.clone()));
            }

            // ---- phase 3
            let mut responses = Vec::new();
            for _ in 0..expect3 {
                match rx_up.recv()? {
                    Up::Unmask(u) => {
                        stats.record(3, Dir::Up, u.from, u.size_bytes());
                        responses.push(u);
                    }
                    Up::Dropped(id, step) => log::trace!("client {id} dropped at step {step}"),
                    Up::Failed(id, step, e) => log::debug!("client {id} failed step {step}: {e}"),
                    _ => return Err(anyhow!("protocol order violation in phase 3")),
                }
            }
            responses.sort_by_key(|r| r.from);
            let RoundOutput { sum, reliable, sets } = server.finalize(responses)?;
            Ok(CoordRoundResult { sum, reliable, sets, stats })
        })();

        // Unblock every worker that is still waiting for its next phase
        // input: Finish fails the worker's expected-message pattern match,
        // so it exits; workers that already returned just drop the send.
        for tx in to_clients.values() {
            let _ = tx.send(Down::Finish);
        }
        result
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::dropout::DropoutModel;
    use crate::protocol::engine;
    use crate::protocol::Topology;

    fn models(n: usize, dim: usize, seed: u64) -> Vec<Vec<u64>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect())
            .collect()
    }

    #[test]
    fn threaded_matches_sync_engine_no_dropout() {
        let n = 12;
        let dim = 40;
        let cfg = ProtocolConfig::new(n, 5, dim, Topology::ErdosRenyi { p: 0.7 }, 2024);
        let m = models(n, dim, 3);
        let sync = engine::run_round(&cfg, &m).unwrap();
        let threaded = run_round_threaded(&cfg, &m).unwrap();
        assert_eq!(threaded.reliable, sync.reliable);
        assert_eq!(threaded.sets, sync.sets);
        assert_eq!(threaded.sum, sync.sum);
        assert_eq!(threaded.stats.server_total(), sync.stats.server_total());
    }

    #[test]
    fn threaded_matches_sync_engine_targeted_dropout() {
        let n = 10;
        let dim = 16;
        let cfg = ProtocolConfig {
            dropout: DropoutModel::Targeted {
                per_step: [vec![1], vec![3], vec![5], vec![7]],
            },
            ..ProtocolConfig::new(n, 4, dim, Topology::Complete, 77)
        };
        let m = models(n, dim, 4);
        let sync = engine::run_round(&cfg, &m).unwrap();
        let threaded = run_round_threaded(&cfg, &m).unwrap();
        assert_eq!(threaded.reliable, sync.reliable);
        assert_eq!(threaded.sets, sync.sets);
        assert_eq!(threaded.sum, sync.sum);
    }

    #[test]
    fn threaded_sum_is_true_sum() {
        let n = 8;
        let dim = 30;
        let cfg = ProtocolConfig::new(n, 4, dim, Topology::Complete, 5);
        let m = models(n, dim, 6);
        let r = run_round_threaded(&cfg, &m).unwrap();
        assert!(r.reliable);
        let mut expect = vec![0u64; dim];
        for mv in &m {
            for (a, x) in expect.iter_mut().zip(mv) {
                *a = a.wrapping_add(*x) & 0xFFFF_FFFF;
            }
        }
        assert_eq!(r.sum.unwrap(), expect);
    }

    #[test]
    fn aborted_round_terminates_and_errors() {
        // every client dropping at step 0 leaves |V1| = 0 < t: the server
        // aborts mid-protocol; the call must return Err rather than
        // deadlock joining workers that never got their phase input
        let n = 6;
        let cfg = ProtocolConfig {
            dropout: DropoutModel::Targeted {
                per_step: [(0..n).collect(), vec![], vec![], vec![]],
            },
            ..ProtocolConfig::new(n, 3, 4, Topology::Complete, 3)
        };
        let m = models(n, 4, 3);
        assert!(run_round_threaded(&cfg, &m).is_err());
    }

    #[test]
    fn abort_after_step1_terminates_and_errors() {
        // all clients past V1 drop at step 2 → |V3| = 0 < t: abort happens
        // after workers have consumed one phase input — the late-phase
        // unblocking path
        let n = 5;
        let cfg = ProtocolConfig {
            dropout: DropoutModel::Targeted {
                per_step: [vec![], vec![], (0..n).collect(), vec![]],
            },
            ..ProtocolConfig::new(n, 2, 4, Topology::Complete, 4)
        };
        let m = models(n, 4, 4);
        assert!(run_round_threaded(&cfg, &m).is_err());
    }

    #[test]
    fn threaded_iid_dropout_terminates_and_is_consistent() {
        // Iid dropout draws happen in a fixed pre-pass, so the run is
        // deterministic; the protocol must terminate and, when reliable,
        // produce exactly the V3 sum.
        for seed in 0..5 {
            let n = 14;
            let cfg = ProtocolConfig {
                dropout: DropoutModel::Iid { q: 0.15 },
                ..ProtocolConfig::new(n, 5, 8, Topology::ErdosRenyi { p: 0.8 }, 100 + seed)
            };
            let m = models(n, 8, seed);
            match run_round_threaded(&cfg, &m) {
                Ok(r) => {
                    if r.reliable {
                        let sum = r.sum.unwrap();
                        let mut expect = vec![0u64; 8];
                        for &i in &r.sets.v3 {
                            for (a, x) in expect.iter_mut().zip(&m[i]) {
                                *a = a.wrapping_add(*x) & 0xFFFF_FFFF;
                            }
                        }
                        assert_eq!(sum, expect, "seed={seed}");
                    }
                }
                Err(_) => { /* |V_k| < t abort is acceptable under dropout */ }
            }
        }
    }
}
