//! Deployment shape for one aggregation round: how n client state machines
//! and one server actually execute.
//!
//! `protocol::engine` is the deterministic synchronous core used by tests
//! and benches. This module provides the "real service" arrangement built
//! on the same poll-able [`ClientSm`]:
//!
//! * [`run_round_event_loop`] — **the scaling shape.** A single event loop
//!   multiplexes all n client state machines over a fixed worker pool
//!   (`par::threads()`-sized): clients are sharded deterministically across
//!   workers, each protocol phase is one parallel sweep over the shards,
//!   and the server drains the resulting `Up` messages in client-id order.
//!   Thread cost is O(workers), independent of n — a 10⁵-client round runs
//!   on a handful of OS threads.
//!
//! The legacy thread-per-client `run_round_threaded` (one OS thread + mpsc
//! channel pair per client) served as the event loop's differential witness
//! through its first green CI cycles and was deleted once the equivalence
//! suite and the randomized differential harness pinned the event loop
//! against the engine directly (see ROADMAP).
//!
//! With `DropoutModel::None` or `Targeted` (rng-free models), the event
//! loop produces sums, survivor sets and `NetStats` bit-identical to the
//! sync engine for the same seed — under every payload codec — as asserted
//! in tests and in the randomized differential harness
//! (`sim::differential`).

use crate::net::{Dir, NetStats};
use crate::protocol::client::ClientSm;
use crate::protocol::messages::*;
use crate::protocol::server::{RoundOutput, Server};
use crate::protocol::{ProtocolConfig, SurvivorSets};
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Outcome of a coordinated round (mirrors the engine's essentials).
#[derive(Debug)]
pub struct CoordRoundResult {
    pub sum: Option<Vec<u64>>,
    pub reliable: bool,
    pub sets: SurvivorSets,
    pub stats: NetStats,
}

/// How the event loop actually ran — the observable for "no thread-per-
/// client" assertions.
#[derive(Debug, Clone, Copy)]
pub struct LoopTelemetry {
    /// Worker budget the loop ran with.
    pub workers: usize,
    /// Maximum number of concurrently live pool threads observed across
    /// all sweeps (1 when a sweep ran inline on the caller's thread).
    pub peak_live_workers: usize,
    /// Parallel sweeps executed — one per protocol phase reached.
    pub sweeps: usize,
    /// GF(2^16)/mask kernel backend the round's hot paths dispatched to
    /// (`crate::kernels::selected`) — recorded so the scale jobs can audit
    /// which backend a run actually exercised.
    pub kernel_backend: &'static str,
}

/// Minimum clients a pool worker should own before a sweep is worth its
/// thread spawns: a client step costs tens of µs of crypto (x25519
/// agreements, Shamir splits), so ~16 clients dwarf the ~10 µs spawn+join.
/// Below `workers · MIN_CLIENTS_PER_WORKER` clients the sweep degrades
/// toward fewer workers (1 at simulation sizes) and runs inline,
/// bit-identically.
pub const MIN_CLIENTS_PER_WORKER: usize = 16;

/// Default worker count for an n-client event loop: [`crate::par::threads`]
/// capped so each worker owns at least [`MIN_CLIENTS_PER_WORKER`] clients.
pub fn event_loop_workers(n: usize) -> usize {
    crate::par::threads().min(n / MIN_CLIENTS_PER_WORKER).max(1)
}

/// Pre-draw every client's per-step dropout decision in the sync engine's
/// draw order (step-major, client-minor), so rng-free models produce
/// identical survivor sets in every execution shape.
fn predraw_survivals(cfg: &ProtocolConfig, dropout_rng: &mut Rng) -> Vec<[bool; 4]> {
    let mut survives = vec![[true; 4]; cfg.n];
    for step in 0..4 {
        for (id, s) in survives.iter_mut().enumerate() {
            s[step] = cfg.dropout.survives(step, id, dropout_rng);
        }
    }
    survives
}

/// Everything a round's executors derive from `cfg.seed` before the first
/// message moves: the secret-sharing graph, the pre-drawn dropout schedule,
/// the codec's shared index plan and each client's RNG stream pair.
///
/// The derivation order is load-bearing — `Rng::split` advances the base
/// stream, so graph → dropout → plan → per-client streams must happen in
/// exactly this sequence for every execution shape (sync engine, event
/// loop, socket transport) to agree bit-for-bit. Extracting it into one
/// function is what lets the wire path (`net::socket`) share the event
/// loop's derivation instead of re-implementing the recipe.
pub struct RoundSetup {
    pub graph: crate::graph::Graph,
    /// `survives[id][step]` — the pre-drawn per-step dropout decisions, in
    /// the sync engine's draw order (step-major, client-minor).
    pub survives: Vec<[bool; 4]>,
    pub plan: Arc<crate::codec::IndexPlan>,
    /// Per-client `(key_rng, share_rng)` stream pairs, indexed by id.
    pub streams: Vec<(Rng, Rng)>,
}

/// Derive a [`RoundSetup`] from the round config — the single source of
/// truth for the seed → round-state recipe shared by all executors.
pub fn derive_round_setup(cfg: &ProtocolConfig, models: &[Vec<u64>]) -> RoundSetup {
    assert_eq!(models.len(), cfg.n);
    let mut rng = Rng::new(cfg.seed);
    let graph = cfg.build_graph_with(&mut rng);
    let mut dropout_rng = rng.split(0xD20);
    let survives = predraw_survivals(cfg, &mut dropout_rng);
    // The round's shared payload plan — same derivation as the sync engine
    // (public round seed / scoring oracle, never the protocol RNG stream),
    // so all shapes encode identical windows.
    let plan = cfg.codec.plan(cfg.dim, cfg.mask_bits, cfg.seed, models);
    // RNG derivation is order-dependent (`split` advances the base), so the
    // per-client streams are drawn serially — that part is cheap. The
    // expensive part, key generation (two x25519 ladders per client inside
    // `Client::new`), derives only from the already-split streams, so lane
    // construction itself can run on a worker pool.
    let streams: Vec<(Rng, Rng)> = (0..cfg.n)
        .map(|id| (rng.split(0xC11E27 + id as u64), rng.split(0x5A12E + id as u64)))
        .collect();
    RoundSetup { graph, survives, plan, streams }
}

/// One client's slot in the event loop: its state machine plus single-entry
/// mailboxes. The loop writes `inbox` while routing, a sweep moves
/// `inbox → step → outbox`, and the drain empties `outbox` in id order.
struct Lane<'m> {
    sm: ClientSm<'m>,
    inbox: Option<Down>,
    outbox: Option<Up>,
}

/// One parallel sweep: step every lane holding a phase input, sharding the
/// lane vector contiguously across at most `workers` pool threads. The
/// gauge pair records the peak number of concurrently live workers.
fn sweep_lanes(lanes: &mut [Lane<'_>], workers: usize, live: &AtomicUsize, peak: &AtomicUsize) {
    crate::par::for_each_slice(lanes, workers, |_, chunk| {
        let cur = live.fetch_add(1, Ordering::SeqCst) + 1;
        peak.fetch_max(cur, Ordering::SeqCst);
        for lane in chunk.iter_mut() {
            if let Some(down) = lane.inbox.take() {
                lane.outbox = Some(lane.sm.step(down));
            }
        }
        live.fetch_sub(1, Ordering::SeqCst);
    });
}

/// Run one aggregation round through the worker-pool event loop with the
/// default worker count ([`event_loop_workers`]).
pub fn run_round_event_loop(
    cfg: &ProtocolConfig,
    models: &[Vec<u64>],
) -> Result<CoordRoundResult> {
    run_round_event_loop_with(cfg, models, event_loop_workers(cfg.n)).map(|(r, _)| r)
}

/// [`run_round_event_loop`] with an explicit worker budget, returning the
/// loop telemetry alongside the result.
pub fn run_round_event_loop_with(
    cfg: &ProtocolConfig,
    models: &[Vec<u64>],
    workers: usize,
) -> Result<(CoordRoundResult, LoopTelemetry)> {
    run_round_event_loop_inner(cfg, models, workers, None)
}

/// [`run_round_event_loop`] writing an fsync'd `crate::journal` round log:
/// every server state transition hits `<journal_dir>/round-<tag>.ccj`
/// before it takes effect, so a crashed in-process round is recoverable by
/// `journal::recover` exactly like a crashed wire round.
pub fn run_round_event_loop_journaled(
    cfg: &ProtocolConfig,
    models: &[Vec<u64>],
    journal_dir: &std::path::Path,
) -> Result<CoordRoundResult> {
    let round = crate::net::socket::round_tag(cfg.seed);
    let setup = derive_round_setup(cfg, models);
    let journal = crate::journal::Journal::create(
        journal_dir,
        round,
        cfg.n,
        cfg.t,
        cfg.mask_bits,
        &setup.plan,
        &setup.graph,
    )
    .context("create round journal")?;
    drop(setup);
    let sink: Box<dyn crate::protocol::server::RoundSink> =
        Box::new(crate::journal::JournalSink::new(journal));
    run_round_event_loop_inner(cfg, models, event_loop_workers(cfg.n), Some(sink))
        .map(|(r, _)| r)
}

fn run_round_event_loop_inner(
    cfg: &ProtocolConfig,
    models: &[Vec<u64>],
    workers: usize,
    sink: Option<Box<dyn crate::protocol::server::RoundSink>>,
) -> Result<(CoordRoundResult, LoopTelemetry)> {
    assert_eq!(models.len(), cfg.n);
    let workers = workers.max(1);
    let RoundSetup { graph, survives, plan, streams } = derive_round_setup(cfg, models);
    // The per-machine Step-2 mask budget splits the host budget across the
    // sweep workers, so sweep × mask parallelism never exceeds
    // `par::threads()` live threads — the "no thread-per-client" claim
    // holds at any dim, not just when vectors are too short to shard.
    let mask_workers = (crate::par::threads() / workers).max(1);
    let mut lanes: Vec<Lane<'_>> = crate::par::map_indexed(cfg.n, workers, |id| {
        let (mut key_rng, share_rng) = streams[id].clone();
        let mut sm = ClientSm::new(
            id,
            cfg.t,
            cfg.mask_bits,
            graph.neighbors(id).to_vec(),
            &mut key_rng,
            share_rng,
            &models[id],
            plan.clone(),
            survives[id],
        );
        sm.set_mask_workers(mask_workers);
        Lane { sm, inbox: Some(Down::Start), outbox: None }
    });
    drop(streams); // lanes cloned their pairs; free ~2n ChaCha states

    let mut server = Server::new(cfg.n, cfg.t, cfg.mask_bits, plan, graph.clone());
    if let Some(sink) = sink {
        server.set_sink(sink);
    }
    let mut stats = NetStats::new(cfg.n);
    let live = AtomicUsize::new(0);
    let peak = AtomicUsize::new(0);
    let mut sweeps = 0usize;

    // ---- phase 0: advertise keys
    sweep_lanes(&mut lanes, workers, &live, &peak);
    sweeps += 1;
    let mut advs = Vec::new();
    for lane in lanes.iter_mut() {
        match lane.outbox.take() {
            Some(Up::Adv(a)) => {
                stats.record(0, Dir::Up, a.id, a.size_bytes());
                advs.push(a);
            }
            Some(Up::Dropped(id, step)) => log::trace!("client {id} dropped at step {step}"),
            Some(Up::Failed(id, step, e)) => {
                log::debug!("client {id} failed step {step}: {e}")
            }
            Some(_) => bail!("protocol order violation in phase 0"),
            None => bail!("client {} produced no phase-0 output", lane.sm.id()),
        }
    }
    let bundles = server.step0_route_keys(advs)?;
    for (id, b) in bundles {
        stats.record(0, Dir::Down, id, b.size_bytes());
        lanes[id].inbox = Some(Down::Bundle(b));
    }

    // ---- phase 1: share keys
    sweep_lanes(&mut lanes, workers, &live, &peak);
    sweeps += 1;
    let mut uploads = Vec::new();
    for lane in lanes.iter_mut() {
        match lane.outbox.take() {
            Some(Up::Shares(u)) => {
                stats.record(1, Dir::Up, u.from, u.size_bytes());
                uploads.push(u);
            }
            Some(Up::Dropped(id, step)) => log::trace!("client {id} dropped at step {step}"),
            Some(Up::Failed(id, step, e)) => {
                log::debug!("client {id} withdrew step {step}: {e}")
            }
            Some(_) => bail!("protocol order violation in phase 1"),
            None => {}
        }
    }
    let deliveries = server.step1_route_shares(uploads)?;
    for (id, d) in deliveries {
        stats.record(1, Dir::Down, id, d.size_bytes());
        lanes[id].inbox = Some(Down::Delivery(d));
    }

    // ---- phase 2: masked inputs
    sweep_lanes(&mut lanes, workers, &live, &peak);
    sweeps += 1;
    let mut masked = Vec::new();
    for lane in lanes.iter_mut() {
        match lane.outbox.take() {
            Some(Up::Masked(m)) => {
                stats.record(2, Dir::Up, m.id, m.size_bytes());
                stats.record_masked_payload(m.payload_bytes());
                masked.push(m);
            }
            Some(Up::Dropped(id, step)) => log::trace!("client {id} dropped at step {step}"),
            Some(Up::Failed(id, step, e)) => {
                log::debug!("client {id} failed step {step}: {e}")
            }
            Some(_) => bail!("protocol order violation in phase 2"),
            None => {}
        }
    }
    let announce = Arc::new(server.step2_collect_masked(masked)?);
    for &id in &announce.v3 {
        stats.record(2, Dir::Down, id, announce.size_bytes());
        lanes[id].inbox = Some(Down::Announce(announce.clone()));
    }

    // ---- phase 3: unmask shares
    sweep_lanes(&mut lanes, workers, &live, &peak);
    sweeps += 1;
    let mut responses = Vec::new();
    for lane in lanes.iter_mut() {
        match lane.outbox.take() {
            Some(Up::Unmask(u)) => {
                stats.record(3, Dir::Up, u.from, u.size_bytes());
                responses.push(u);
            }
            Some(Up::Dropped(id, step)) => log::trace!("client {id} dropped at step {step}"),
            Some(Up::Failed(id, step, e)) => {
                log::debug!("client {id} failed step {step}: {e}")
            }
            Some(_) => bail!("protocol order violation in phase 3"),
            None => {}
        }
    }
    let RoundOutput { sum, reliable, sets } = server.finalize(responses)?;

    let telemetry = LoopTelemetry {
        workers,
        peak_live_workers: peak.load(Ordering::SeqCst).max(1),
        sweeps,
        kernel_backend: crate::kernels::selected().name(),
    };
    Ok((CoordRoundResult { sum, reliable, sets, stats }, telemetry))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::Codec;
    use crate::protocol::dropout::DropoutModel;
    use crate::protocol::engine;
    use crate::protocol::Topology;

    fn models(n: usize, dim: usize, seed: u64) -> Vec<Vec<u64>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect())
            .collect()
    }

    /// Σ over the given clients in Z_{2^32} — the tests' sum oracle.
    fn expected_sum(m: &[Vec<u64>], ids: impl Iterator<Item = usize>, dim: usize) -> Vec<u64> {
        let mut expect = vec![0u64; dim];
        for i in ids {
            for (a, x) in expect.iter_mut().zip(&m[i]) {
                *a = a.wrapping_add(*x) & 0xFFFF_FFFF;
            }
        }
        expect
    }

    /// The event loop against the sync engine, field by field.
    fn assert_matches_engine(cfg: &ProtocolConfig, m: &[Vec<u64>]) {
        let sync = engine::run_round(cfg, m).unwrap();
        let r = run_round_event_loop(cfg, m).unwrap();
        assert_eq!(r.reliable, sync.reliable, "event-loop: reliable");
        assert_eq!(r.sets, sync.sets, "event-loop: survivor sets");
        assert_eq!(r.sum, sync.sum, "event-loop: sum");
        assert_eq!(r.stats, sync.stats, "event-loop: NetStats");
    }

    #[test]
    fn event_loop_matches_sync_engine_no_dropout() {
        let n = 12;
        let dim = 40;
        let cfg = ProtocolConfig::for_test(n, 5, dim, Topology::ErdosRenyi { p: 0.7 }, 2024);
        let m = models(n, dim, 3);
        assert_matches_engine(&cfg, &m);
    }

    #[test]
    fn event_loop_matches_sync_engine_targeted_dropout() {
        let n = 10;
        let dim = 16;
        let cfg = ProtocolConfig {
            dropout: DropoutModel::Targeted {
                per_step: [vec![1], vec![3], vec![5], vec![7]],
            },
            ..ProtocolConfig::for_test(n, 4, dim, Topology::Complete, 77)
        };
        let m = models(n, dim, 4);
        assert_matches_engine(&cfg, &m);
    }

    #[test]
    fn event_loop_matches_sync_engine_under_sparse_codecs() {
        let n = 10;
        let dim = 32;
        let m = models(n, dim, 5);
        for codec in [Codec::TopK { k: 5 }, Codec::RandK { k: 5 }] {
            let cfg = ProtocolConfig {
                codec,
                dropout: DropoutModel::Targeted {
                    per_step: [vec![], vec![2], vec![6], vec![]],
                },
                ..ProtocolConfig::for_test(n, 4, dim, Topology::ErdosRenyi { p: 0.85 }, 88)
            };
            assert_matches_engine(&cfg, &m);
        }
    }

    #[test]
    fn event_loop_sum_is_true_sum_across_worker_counts() {
        // the result must not depend on how lanes shard across workers
        let n = 9;
        let dim = 20;
        let cfg = ProtocolConfig::for_test(n, 4, dim, Topology::Complete, 6);
        let m = models(n, dim, 7);
        let expect = expected_sum(&m, 0..n, dim);
        for workers in [1usize, 2, 3, 8] {
            let (r, tel) = run_round_event_loop_with(&cfg, &m, workers).unwrap();
            assert!(r.reliable, "workers={workers}");
            assert_eq!(r.sum.as_ref().unwrap(), &expect, "workers={workers}");
            assert!(tel.peak_live_workers <= workers.max(1), "workers={workers}");
            assert_eq!(tel.sweeps, 4);
        }
    }

    #[test]
    fn event_loop_worker_default_scales_with_population() {
        assert_eq!(event_loop_workers(0), 1);
        assert_eq!(event_loop_workers(MIN_CLIENTS_PER_WORKER - 1), 1);
        let big = event_loop_workers(MIN_CLIENTS_PER_WORKER * 1024);
        assert!(big >= 1 && big <= crate::par::threads());
        assert!(event_loop_workers(MIN_CLIENTS_PER_WORKER * 2) <= 2);
    }

    #[test]
    fn aborted_round_terminates_and_errors() {
        // every client dropping at step 0 leaves |V1| = 0 < t: the server
        // aborts mid-protocol; the event loop must return Err
        let n = 6;
        let cfg = ProtocolConfig {
            dropout: DropoutModel::Targeted {
                per_step: [(0..n).collect(), vec![], vec![], vec![]],
            },
            ..ProtocolConfig::for_test(n, 3, 4, Topology::Complete, 3)
        };
        let m = models(n, 4, 3);
        assert!(run_round_event_loop(&cfg, &m).is_err());
    }

    #[test]
    fn abort_after_step1_terminates_and_errors() {
        // all clients past V1 drop at step 2 → |V3| = 0 < t: abort happens
        // after lanes have consumed one phase input
        let n = 5;
        let cfg = ProtocolConfig {
            dropout: DropoutModel::Targeted {
                per_step: [vec![], vec![], (0..n).collect(), vec![]],
            },
            ..ProtocolConfig::for_test(n, 2, 4, Topology::Complete, 4)
        };
        let m = models(n, 4, 4);
        assert!(run_round_event_loop(&cfg, &m).is_err());
    }

    #[test]
    fn materialized_iid_dropout_terminates_and_is_consistent() {
        // Bit-identity between the engine and the event loop is promised
        // for rng-free dropout only (the engine draws Iid lazily over
        // survivors, the loop pre-draws all n×4 decisions — different
        // stream positions once anyone drops). Materializing the Iid model
        // into an explicit schedule, exactly as the sim scenario compiler
        // does, restores a shared schedule: the round must terminate and,
        // when reliable, produce exactly the V3 sum in engine agreement.
        for seed in 0..5 {
            let n = 14;
            let per_step =
                DropoutModel::Iid { q: 0.15 }.materialize(n, &mut Rng::new(0x1D1D + seed));
            let cfg = ProtocolConfig {
                dropout: DropoutModel::Targeted { per_step },
                ..ProtocolConfig::for_test(n, 5, 8, Topology::ErdosRenyi { p: 0.8 }, 100 + seed)
            };
            let m = models(n, 8, seed);
            let sync = engine::run_round(&cfg, &m);
            let looped = run_round_event_loop(&cfg, &m);
            match (sync, looped) {
                (Ok(a), Ok(b)) => {
                    assert_eq!(a.sets, b.sets, "seed={seed}");
                    assert_eq!(a.sum, b.sum, "seed={seed}");
                    assert_eq!(a.stats, b.stats, "seed={seed}");
                    if b.reliable {
                        let expect = expected_sum(&m, b.sets.v3.iter().copied(), 8);
                        assert_eq!(b.sum.unwrap(), expect, "seed={seed}");
                    }
                }
                (Err(_), Err(_)) => { /* |V_k| < t abort is acceptable under dropout */ }
                (a, b) => panic!("shapes disagree on abort: seed={seed} {a:?} vs {b:?}"),
            }
        }
    }
}
