//! Deployment shape for one aggregation round: how n client state machines
//! and one server actually execute.
//!
//! `protocol::engine` is the deterministic synchronous core used by tests
//! and benches. This module provides the "real service" arrangement built
//! on the same poll-able [`ClientSm`]:
//!
//! * the event-loop executor ([`RoundRunner`] with the default
//!   [`Executor::EventLoop`]) — **the scaling shape.** A single event loop
//!   multiplexes all n client state machines over a fixed worker pool
//!   (`par::threads()`-sized): clients are sharded deterministically across
//!   workers, each protocol phase is one parallel sweep over the shards,
//!   and the server drains the resulting `Up` messages in client-id order.
//!   Thread cost is O(workers), independent of n — a 10⁵-client round runs
//!   on a handful of OS threads.
//!
//! The legacy thread-per-client `run_round_threaded` (one OS thread + mpsc
//! channel pair per client) served as the event loop's differential witness
//! through its first green CI cycles and was deleted once the equivalence
//! suite and the randomized differential harness pinned the event loop
//! against the engine directly (see ROADMAP).
//!
//! With `DropoutModel::None` or `Targeted` (rng-free models), the event
//! loop produces sums, survivor sets and `NetStats` bit-identical to the
//! sync engine for the same seed — under every payload codec — as asserted
//! in tests and in the randomized differential harness
//! (`sim::differential`).

pub use crate::net::socket::StopAfter;

use crate::net::{Dir, NetStats};
use crate::protocol::client::ClientSm;
use crate::protocol::messages::*;
use crate::protocol::server::{RoundOutput, RoundSink, Server};
use crate::protocol::{ProtocolConfig, SurvivorSets};
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Outcome of a coordinated round (mirrors the engine's essentials).
#[derive(Debug)]
pub struct CoordRoundResult {
    pub sum: Option<Vec<u64>>,
    pub reliable: bool,
    pub sets: SurvivorSets,
    pub stats: NetStats,
    /// What the virtual clock observed, when the round ran clocked
    /// (event-loop executor with a [`TimeoutPolicy`] + schedule); `None`
    /// on untimed executors.
    pub timeline: Option<RoundTimeline>,
}

/// Server patience, per protocol phase: how long to wait for stragglers
/// before closing the phase without them, and the delivery floor that
/// overrides the deadline.
///
/// On the event-loop executor the deadlines are *virtual* — measured on the
/// deterministic [`crate::sim::clock::ClockSchedule`] — so the same policy
/// replays bit-identically. On the wire executor the same numbers become
/// real wall-clock `poll` deadlines (`net::socket`), which is what makes a
/// sim-tuned policy directly deployable.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TimeoutPolicy {
    /// Budget for each of the four phases, measured from the phase open
    /// (the server finishing the previous phase's downloads).
    pub per_phase_deadlines: [Duration; 4],
    /// Grace floor: past a deadline the server keeps accepting deliveries
    /// in arrival order until at least this many landed in the phase
    /// (0 = the deadline is absolute). A floor ≥ t keeps a slow-but-alive
    /// cohort from aborting the round.
    pub min_survivors: usize,
}

impl TimeoutPolicy {
    /// The same deadline for all four phases, no grace floor.
    pub fn uniform(d: Duration) -> TimeoutPolicy {
        TimeoutPolicy { per_phase_deadlines: [d; 4], min_survivors: 0 }
    }

    pub fn with_min_survivors(mut self, floor: usize) -> TimeoutPolicy {
        self.min_survivors = floor;
        self
    }
}

/// What the clock observed in one round: who each phase deadline dropped,
/// and the virtual time each phase took (the latency axis the campaign
/// runner scores against reliability/privacy).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RoundTimeline {
    /// dropped[phase] — clients whose delivery missed the phase deadline
    /// (sorted by id). Bit-identical across executors for the same seed.
    pub dropped: [Vec<usize>; 4],
    /// Virtual time each phase stayed open, µs.
    pub phase_elapsed_us: [u64; 4],
}

impl RoundTimeline {
    /// Simulated wall time of the whole round, µs.
    pub fn total_us(&self) -> u64 {
        self.phase_elapsed_us.iter().sum()
    }

    /// Did any phase deadline actually drop someone?
    pub fn dropped_any(&self) -> bool {
        self.dropped.iter().any(|d| !d.is_empty())
    }
}

/// How the event loop actually ran — the observable for "no thread-per-
/// client" assertions.
#[derive(Debug, Clone, Copy)]
pub struct LoopTelemetry {
    /// Worker budget the loop ran with.
    pub workers: usize,
    /// Maximum number of concurrently live pool threads observed across
    /// all sweeps (1 when a sweep ran inline on the caller's thread).
    pub peak_live_workers: usize,
    /// Parallel sweeps executed — one per protocol phase reached.
    pub sweeps: usize,
    /// GF(2^16)/mask kernel backend the round's hot paths dispatched to
    /// (`crate::kernels::selected`) — recorded so the scale jobs can audit
    /// which backend a run actually exercised.
    pub kernel_backend: &'static str,
}

/// Minimum clients a pool worker should own before a sweep is worth its
/// thread spawns: a client step costs tens of µs of crypto (x25519
/// agreements, Shamir splits), so ~16 clients dwarf the ~10 µs spawn+join.
/// Below `workers · MIN_CLIENTS_PER_WORKER` clients the sweep degrades
/// toward fewer workers (1 at simulation sizes) and runs inline,
/// bit-identically.
pub const MIN_CLIENTS_PER_WORKER: usize = 16;

/// Default worker count for an n-client event loop: [`crate::par::threads`]
/// capped so each worker owns at least [`MIN_CLIENTS_PER_WORKER`] clients.
pub fn event_loop_workers(n: usize) -> usize {
    crate::par::threads().min(n / MIN_CLIENTS_PER_WORKER).max(1)
}

/// Pre-draw every client's per-step dropout decision in the sync engine's
/// draw order (step-major, client-minor), so rng-free models produce
/// identical survivor sets in every execution shape.
pub(crate) fn predraw_survivals(cfg: &ProtocolConfig, dropout_rng: &mut Rng) -> Vec<[bool; 4]> {
    let mut survives = vec![[true; 4]; cfg.n];
    for step in 0..4 {
        for (id, s) in survives.iter_mut().enumerate() {
            s[step] = cfg.dropout.survives(step, id, dropout_rng);
        }
    }
    survives
}

/// Everything a round's executors derive from `cfg.seed` before the first
/// message moves: the secret-sharing graph, the pre-drawn dropout schedule,
/// the codec's shared index plan and each client's RNG stream pair.
///
/// The derivation order is load-bearing — `Rng::split` advances the base
/// stream, so graph → dropout → plan → per-client streams must happen in
/// exactly this sequence for every execution shape (sync engine, event
/// loop, socket transport) to agree bit-for-bit. Extracting it into one
/// function is what lets the wire path (`net::socket`) share the event
/// loop's derivation instead of re-implementing the recipe.
pub struct RoundSetup {
    pub graph: crate::graph::Graph,
    /// `survives[id][step]` — the pre-drawn per-step dropout decisions, in
    /// the sync engine's draw order (step-major, client-minor).
    pub survives: Vec<[bool; 4]>,
    pub plan: Arc<crate::codec::IndexPlan>,
    /// Per-client `(key_rng, share_rng)` stream pairs, indexed by id.
    pub streams: Vec<(Rng, Rng)>,
}

/// Derive a [`RoundSetup`] from the round config — the single source of
/// truth for the seed → round-state recipe shared by all executors.
pub fn derive_round_setup(cfg: &ProtocolConfig, models: &[Vec<u64>]) -> RoundSetup {
    assert_eq!(models.len(), cfg.n);
    let mut rng = Rng::new(cfg.seed);
    let graph = cfg.build_graph_with(&mut rng);
    let mut dropout_rng = rng.split(0xD20);
    let survives = predraw_survivals(cfg, &mut dropout_rng);
    // The round's shared payload plan — same derivation as the sync engine
    // (public round seed / scoring oracle, never the protocol RNG stream),
    // so all shapes encode identical windows.
    let plan = cfg.codec.plan(cfg.dim, cfg.mask_bits, cfg.seed, models);
    // RNG derivation is order-dependent (`split` advances the base), so the
    // per-client streams are drawn serially — that part is cheap. The
    // expensive part, key generation (two x25519 ladders per client inside
    // `Client::new`), derives only from the already-split streams, so lane
    // construction itself can run on a worker pool.
    let streams: Vec<(Rng, Rng)> = (0..cfg.n)
        .map(|id| (rng.split(0xC11E27 + id as u64), rng.split(0x5A12E + id as u64)))
        .collect();
    RoundSetup { graph, survives, plan, streams }
}

/// One client's slot in the event loop: its state machine plus single-entry
/// mailboxes. The loop writes `inbox` while routing, a sweep moves
/// `inbox → step → outbox`, and the drain empties `outbox` in id order.
struct Lane<'m> {
    sm: ClientSm<'m>,
    inbox: Option<Down>,
    outbox: Option<Up>,
}

/// One parallel sweep: step every lane holding a phase input, sharding the
/// lane vector contiguously across at most `workers` pool threads. The
/// gauge pair records the peak number of concurrently live workers.
fn sweep_lanes(lanes: &mut [Lane<'_>], workers: usize, live: &AtomicUsize, peak: &AtomicUsize) {
    crate::par::for_each_slice(lanes, workers, |_, chunk| {
        let cur = live.fetch_add(1, Ordering::SeqCst) + 1;
        peak.fetch_max(cur, Ordering::SeqCst);
        for lane in chunk.iter_mut() {
            if let Some(down) = lane.inbox.take() {
                lane.outbox = Some(lane.sm.step(down));
            }
        }
        live.fetch_sub(1, Ordering::SeqCst);
    });
}

/// Which execution shape drives a round.
///
/// The legacy thread-per-client `Threaded` executor was deleted with its
/// coordinator once the event loop's equivalence suite had green CI cycles
/// (ROADMAP follow-up): the event loop is now pinned against the engine
/// directly. Lives here (not in `sim::campaign`) since [`RoundRunner`]
/// made it part of the round API; the campaign re-exports it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Executor {
    /// The deterministic synchronous engine (`protocol::engine`).
    Engine,
    /// The worker-pool event-loop coordinator (the scaling shape).
    EventLoop,
    /// The loopback socket transport (`net::socket`) — every message
    /// crosses a real TCP stream as wire frames.
    Wire,
}

impl Executor {
    /// Every executor, in reference-first order.
    pub const ALL: [Executor; 3] = [Executor::Engine, Executor::EventLoop, Executor::Wire];

    /// Every executor except the [`Executor::Engine`] reference — the list
    /// the differential harness and equivalence suites iterate, derived
    /// from [`Executor::ALL`] so a future executor joins them by
    /// construction.
    pub fn non_reference() -> impl Iterator<Item = Executor> {
        Executor::ALL.into_iter().filter(|e| *e != Executor::Engine)
    }

    pub fn name(&self) -> &'static str {
        match self {
            Executor::Engine => "engine",
            Executor::EventLoop => "event-loop",
            Executor::Wire => "wire",
        }
    }
}

/// Validated knobs for one round execution — the single options surface
/// shared by [`RoundRunner`], the wire transport (`net::socket::serve` /
/// `serve_resume`) and the session layer (`protocol::session`). Built via
/// [`RoundOptions::builder`], which rejects contradictory combinations
/// instead of silently ignoring knobs (mirroring
/// `ProtocolConfig::builder`).
#[derive(Debug, Clone)]
pub struct RoundOptions {
    /// Execution shape. Defaults to [`Executor::EventLoop`].
    pub executor: Executor,
    /// Event-loop sweep worker budget; `None` → [`event_loop_workers`].
    pub workers: Option<usize>,
    /// Journal directory: when set, every server state transition is
    /// fsync'd to `<dir>/round-<tag>.ccj` before it takes effect, so a
    /// crashed round is recoverable (`journal::recover` / `serve_resume`).
    pub journal_dir: Option<PathBuf>,
    /// Wall-clock budget for wire rounds (accept + 4 phases). `None` →
    /// `net::socket::DEFAULT_TIMEOUT`. In-process executors ignore it.
    pub timeout: Option<Duration>,
    /// Crash injection point (tests only; wire executor with a journal).
    pub stop_after: Option<StopAfter>,
    /// Per-phase straggler policy. Event loop: requires [`RoundOptions::clock`]
    /// and closes phases on the virtual clock. Wire: becomes real per-phase
    /// poll deadlines inside the whole-round `timeout`.
    pub timeout_policy: Option<TimeoutPolicy>,
    /// Pre-materialized per-client delivery delays driving the virtual
    /// clock (event-loop executor only; rng-free, so rounds replay
    /// bit-identically).
    pub clock: Option<Arc<crate::sim::clock::ClockSchedule>>,
}

impl Default for RoundOptions {
    fn default() -> RoundOptions {
        RoundOptions {
            executor: Executor::EventLoop,
            workers: None,
            journal_dir: None,
            timeout: None,
            stop_after: None,
            timeout_policy: None,
            clock: None,
        }
    }
}

impl RoundOptions {
    pub fn builder() -> RoundOptionsBuilder {
        RoundOptionsBuilder::default()
    }

    /// The effective wire deadline.
    pub fn timeout_or_default(&self) -> Duration {
        self.timeout.unwrap_or(crate::net::socket::DEFAULT_TIMEOUT)
    }
}

/// Builder for [`RoundOptions`]; `build()` validates cross-knob rules.
/// Every rejection names the offending field and the setting it conflicts
/// with, so a caller can fix the combination without reading this source.
#[derive(Debug, Clone, Default)]
pub struct RoundOptionsBuilder {
    executor: Option<Executor>,
    workers: Option<usize>,
    journal_dir: Option<PathBuf>,
    timeout: Option<Duration>,
    stop_after: Option<StopAfter>,
    timeout_policy: Option<TimeoutPolicy>,
    clock: Option<Arc<crate::sim::clock::ClockSchedule>>,
}

impl RoundOptionsBuilder {
    pub fn executor(mut self, e: Executor) -> Self {
        self.executor = Some(e);
        self
    }

    pub fn workers(mut self, w: usize) -> Self {
        self.workers = Some(w);
        self
    }

    pub fn journal(mut self, dir: impl Into<PathBuf>) -> Self {
        self.journal_dir = Some(dir.into());
        self
    }

    pub fn timeout(mut self, t: Duration) -> Self {
        self.timeout = Some(t);
        self
    }

    pub fn stop_after(mut self, point: StopAfter) -> Self {
        self.stop_after = Some(point);
        self
    }

    pub fn timeout_policy(mut self, p: TimeoutPolicy) -> Self {
        self.timeout_policy = Some(p);
        self
    }

    pub fn clock(mut self, sched: Arc<crate::sim::clock::ClockSchedule>) -> Self {
        self.clock = Some(sched);
        self
    }

    pub fn build(self) -> Result<RoundOptions> {
        let executor = self.executor.unwrap_or(Executor::EventLoop);
        if let Some(w) = self.workers {
            if w == 0 {
                bail!("RoundOptions: workers = 0 is invalid — the sweep needs at least one worker");
            }
            if executor != Executor::EventLoop {
                bail!(
                    "RoundOptions: workers conflicts with executor = {}: an explicit worker \
                     budget only applies to the event-loop executor",
                    executor.name()
                );
            }
        }
        if self.journal_dir.is_some() && executor == Executor::Engine {
            bail!(
                "RoundOptions: journal_dir conflicts with executor = engine: the sync engine \
                 does not journal (use the event-loop or wire executor)"
            );
        }
        if self.stop_after.is_some() {
            if self.journal_dir.is_none() {
                bail!(
                    "RoundOptions: stop_after requires journal_dir — crash injection resumes \
                     from the journal"
                );
            }
            if executor != Executor::Wire {
                bail!(
                    "RoundOptions: stop_after conflicts with executor = {}: crash injection is \
                     a wire-executor knob",
                    executor.name()
                );
            }
        }
        if self.timeout_policy.is_some() && executor == Executor::Engine {
            bail!(
                "RoundOptions: timeout_policy conflicts with executor = engine: the sync engine \
                 has no clock (use the event-loop executor with a clock schedule, or the wire)"
            );
        }
        if self.clock.is_some() {
            if executor != Executor::EventLoop {
                bail!(
                    "RoundOptions: clock conflicts with executor = {}: a virtual-clock schedule \
                     only drives the event-loop executor (the wire runs on wall time)",
                    executor.name()
                );
            }
            if self.timeout_policy.is_none() {
                bail!(
                    "RoundOptions: clock requires timeout_policy — a schedule without phase \
                     deadlines never closes a phase early"
                );
            }
        }
        if self.timeout_policy.is_some() && executor == Executor::EventLoop && self.clock.is_none()
        {
            bail!(
                "RoundOptions: timeout_policy requires clock on the event-loop executor — \
                 virtual deadlines need a virtual clock (the wire executor maps them to wall \
                 time instead)"
            );
        }
        Ok(RoundOptions {
            executor,
            workers: self.workers,
            journal_dir: self.journal_dir,
            timeout: self.timeout,
            stop_after: self.stop_after,
            timeout_policy: self.timeout_policy,
            clock: self.clock,
        })
    }
}

/// The one way to run a cold aggregation round: every executor (sync
/// engine, worker-pool event loop, loopback wire), optional journaling and
/// crash injection behind a single validated options surface. Replaces the
/// old `run_round_event_loop{,_with,_journaled}` / `run_round_wire{,_with}`
/// function family.
///
/// Warm (session) rounds go through `protocol::session::Session::run_round`,
/// which takes the same [`RoundOptions`].
pub struct RoundRunner {
    opts: RoundOptions,
}

impl RoundRunner {
    pub fn new(opts: RoundOptions) -> RoundRunner {
        RoundRunner { opts }
    }

    pub fn options(&self) -> &RoundOptions {
        &self.opts
    }

    /// Run one cold round over `models` under this runner's options.
    pub fn run(&self, cfg: &ProtocolConfig, models: &[Vec<u64>]) -> Result<CoordRoundResult> {
        if cfg.topology.is_hierarchical() {
            bail!("hierarchical topology: drive rounds through hier::HierRunner");
        }
        match self.opts.executor {
            Executor::Engine => {
                let r = crate::protocol::engine::run_round(cfg, models)?;
                Ok(CoordRoundResult {
                    sum: r.sum,
                    reliable: r.reliable,
                    sets: r.sets,
                    stats: r.stats,
                    timeline: None,
                })
            }
            Executor::EventLoop => self.run_event_loop(cfg, models).map(|(r, _)| r),
            Executor::Wire => crate::net::socket::run_round_wire_opts(cfg, models, &self.opts),
        }
    }

    /// [`RoundRunner::run`] returning the loop telemetry. Event-loop
    /// executor only — the other shapes have no sweep telemetry.
    pub fn run_with_telemetry(
        &self,
        cfg: &ProtocolConfig,
        models: &[Vec<u64>],
    ) -> Result<(CoordRoundResult, LoopTelemetry)> {
        if self.opts.executor != Executor::EventLoop {
            bail!("loop telemetry is only observable on the event-loop executor");
        }
        self.run_event_loop(cfg, models)
    }

    /// Run one clocked round, handing back the [`RoundTimeline`] even when
    /// the round aborts (a |V_k| < t error) — the clocked differential
    /// needs the observed timeout classification to build the engine
    /// reference schedule regardless of how the round ended. Requires the
    /// event-loop executor with both `timeout_policy` and `clock` set.
    pub fn run_clocked(
        &self,
        cfg: &ProtocolConfig,
        models: &[Vec<u64>],
    ) -> (Result<CoordRoundResult>, RoundTimeline) {
        if self.opts.executor != Executor::EventLoop
            || self.opts.clock.is_none()
            || self.opts.timeout_policy.is_none()
        {
            return (
                Err(anyhow::anyhow!(
                    "run_clocked needs the event-loop executor with clock + timeout_policy set"
                )),
                RoundTimeline::default(),
            );
        }
        let (res, timeline) = self.run_event_loop_timed(cfg, models);
        (res.map(|(r, _)| r), timeline)
    }

    fn run_event_loop(
        &self,
        cfg: &ProtocolConfig,
        models: &[Vec<u64>],
    ) -> Result<(CoordRoundResult, LoopTelemetry)> {
        self.run_event_loop_timed(cfg, models).0
    }

    fn run_event_loop_timed(
        &self,
        cfg: &ProtocolConfig,
        models: &[Vec<u64>],
    ) -> (Result<(CoordRoundResult, LoopTelemetry)>, RoundTimeline) {
        let mut timeline = RoundTimeline::default();
        let workers = self.opts.workers.unwrap_or_else(|| event_loop_workers(cfg.n));
        let sink = match &self.opts.journal_dir {
            Some(dir) => match cold_journal_sink(dir, cfg, models) {
                Ok(s) => Some(s),
                Err(e) => return (Err(e), timeline),
            },
            None => None,
        };
        let clock = match (&self.opts.clock, &self.opts.timeout_policy) {
            (Some(sched), Some(policy)) => Some((sched.as_ref(), policy)),
            _ => None,
        };
        let clocked = clock.is_some();
        let res = run_round_event_loop_inner(cfg, models, workers, sink, clock, &mut timeline)
            .map(|(mut r, t, _)| {
                if clocked {
                    r.timeline = Some(timeline.clone());
                }
                (r, t)
            });
        (res, timeline)
    }
}

/// Create the fsync'd round journal for an in-process cold round — the
/// setup record is on disk before the first lane steps.
fn cold_journal_sink(
    dir: &std::path::Path,
    cfg: &ProtocolConfig,
    models: &[Vec<u64>],
) -> Result<Box<dyn RoundSink>> {
    let round = crate::net::socket::round_tag(cfg.seed);
    let setup = derive_round_setup(cfg, models);
    let journal = crate::journal::Journal::create(
        dir,
        round,
        cfg.n,
        cfg.t,
        cfg.mask_bits,
        &setup.plan,
        &setup.graph,
    )
    .context("create round journal")?;
    Ok(Box::new(crate::journal::JournalSink::new(journal)))
}

/// The event loop, also handing back the client state machines after the
/// round — `protocol::session::Session::establish` retains the clients
/// (with their session caches) for the warm rounds that follow.
pub(crate) fn run_cold_round_capture<'m>(
    cfg: &ProtocolConfig,
    models: &'m [Vec<u64>],
    workers: usize,
) -> Result<(CoordRoundResult, Vec<ClientSm<'m>>)> {
    let mut timeline = RoundTimeline::default();
    run_round_event_loop_inner(cfg, models, workers, None, None, &mut timeline)
        .map(|(r, _, sms)| (r, sms))
}

/// Time-driven phase closure: with a clock, decide which lanes' phase
/// outputs arrived before the deadline. A lane whose delivery is late has
/// its output replaced with [`Up::Dropped`] — from here on the round treats
/// it exactly like a churned client (no byte charge, no further downloads),
/// which is the equivalence the clocked differential verifies bit-for-bit.
fn close_lanes(
    phase: usize,
    lanes: &mut [Lane<'_>],
    clock: Option<(&crate::sim::clock::ClockSchedule, &TimeoutPolicy)>,
    timeline: &mut RoundTimeline,
    stats: &mut NetStats,
) {
    let Some((sched, policy)) = clock else { return };
    // expected = every lane still in the round this phase (it produced
    // *some* outbox); candidates = the subset whose output is a real
    // protocol delivery. A churned/failed lane never delivers, so a real
    // server sits out the full deadline waiting on it — `close_phase`
    // charges that to the phase's elapsed time.
    let expected = lanes.iter().filter(|l| l.outbox.is_some()).count();
    let candidates: Vec<usize> = lanes
        .iter()
        .enumerate()
        .filter(|(_, l)| {
            matches!(
                &l.outbox,
                Some(Up::Adv(_) | Up::Shares(_) | Up::Masked(_) | Up::Unmask(_) | Up::Warm(_))
            )
        })
        .map(|(id, _)| id)
        .collect();
    let closure = crate::sim::clock::close_phase(phase, &candidates, expected, sched, policy);
    for &id in &closure.timed_out {
        // a timed-out delivery is discarded unread: replace it with the
        // same `Dropped` marker a churned client produces, so the drain
        // loop treats both identically (trace-logged, never charged)
        lanes[id].outbox = Some(Up::Dropped(id, phase as u8));
        stats.record_timeout_drop(phase);
    }
    timeline.phase_elapsed_us[phase] = closure.elapsed_us;
    timeline.dropped[phase] = closure.timed_out;
}

fn run_round_event_loop_inner<'m>(
    cfg: &ProtocolConfig,
    models: &'m [Vec<u64>],
    workers: usize,
    sink: Option<Box<dyn RoundSink>>,
    clock: Option<(&crate::sim::clock::ClockSchedule, &TimeoutPolicy)>,
    timeline: &mut RoundTimeline,
) -> Result<(CoordRoundResult, LoopTelemetry, Vec<ClientSm<'m>>)> {
    assert_eq!(models.len(), cfg.n);
    let workers = workers.max(1);
    let RoundSetup { graph, survives, plan, streams } = derive_round_setup(cfg, models);
    // The per-machine Step-2 mask budget splits the host budget across the
    // sweep workers, so sweep × mask parallelism never exceeds
    // `par::threads()` live threads — the "no thread-per-client" claim
    // holds at any dim, not just when vectors are too short to shard.
    let mask_workers = (crate::par::threads() / workers).max(1);
    let mut lanes: Vec<Lane<'_>> = crate::par::map_indexed(cfg.n, workers, |id| {
        let (mut key_rng, share_rng) = streams[id].clone();
        let mut sm = ClientSm::new(
            id,
            cfg.t,
            cfg.mask_bits,
            graph.neighbors(id).to_vec(),
            &mut key_rng,
            share_rng,
            &models[id],
            plan.clone(),
            survives[id],
        );
        sm.set_mask_workers(mask_workers);
        Lane { sm, inbox: Some(Down::Start), outbox: None }
    });
    drop(streams); // lanes cloned their pairs; free ~2n ChaCha states

    let mut server = Server::new(cfg.n, cfg.t, cfg.mask_bits, plan, graph.clone());
    if let Some(sink) = sink {
        server.set_sink(sink);
    }
    let mut stats = NetStats::new(cfg.n);
    let live = AtomicUsize::new(0);
    let peak = AtomicUsize::new(0);
    let mut sweeps = 0usize;

    // ---- phase 0: advertise keys
    sweep_lanes(&mut lanes, workers, &live, &peak);
    sweeps += 1;
    close_lanes(0, &mut lanes, clock, timeline, &mut stats);
    let mut advs = Vec::new();
    for lane in lanes.iter_mut() {
        match lane.outbox.take() {
            Some(Up::Adv(a)) => {
                stats.record(0, Dir::Up, a.id, a.size_bytes());
                advs.push(a);
            }
            Some(Up::Dropped(id, step)) => log::trace!("client {id} dropped at step {step}"),
            Some(Up::Failed(id, step, e)) => {
                log::debug!("client {id} failed step {step}: {e}")
            }
            Some(_) => bail!("protocol order violation in phase 0"),
            None => bail!("client {} produced no phase-0 output", lane.sm.id()),
        }
    }
    let bundles = server.step0_route_keys(advs)?;
    for (id, b) in bundles {
        stats.record(0, Dir::Down, id, b.size_bytes());
        lanes[id].inbox = Some(Down::Bundle(b));
    }

    // ---- phase 1: share keys
    sweep_lanes(&mut lanes, workers, &live, &peak);
    sweeps += 1;
    close_lanes(1, &mut lanes, clock, timeline, &mut stats);
    let mut uploads = Vec::new();
    for lane in lanes.iter_mut() {
        match lane.outbox.take() {
            Some(Up::Shares(u)) => {
                stats.record(1, Dir::Up, u.from, u.size_bytes());
                uploads.push(u);
            }
            Some(Up::Dropped(id, step)) => log::trace!("client {id} dropped at step {step}"),
            Some(Up::Failed(id, step, e)) => {
                log::debug!("client {id} withdrew step {step}: {e}")
            }
            Some(_) => bail!("protocol order violation in phase 1"),
            None => {}
        }
    }
    let deliveries = server.step1_route_shares(uploads)?;
    for (id, d) in deliveries {
        stats.record(1, Dir::Down, id, d.size_bytes());
        lanes[id].inbox = Some(Down::Delivery(d));
    }

    // ---- phase 2: masked inputs
    sweep_lanes(&mut lanes, workers, &live, &peak);
    sweeps += 1;
    close_lanes(2, &mut lanes, clock, timeline, &mut stats);
    let mut masked = Vec::new();
    for lane in lanes.iter_mut() {
        match lane.outbox.take() {
            Some(Up::Masked(m)) => {
                stats.record(2, Dir::Up, m.id, m.size_bytes());
                stats.record_masked_payload(m.payload_bytes());
                masked.push(m);
            }
            Some(Up::Dropped(id, step)) => log::trace!("client {id} dropped at step {step}"),
            Some(Up::Failed(id, step, e)) => {
                log::debug!("client {id} failed step {step}: {e}")
            }
            Some(_) => bail!("protocol order violation in phase 2"),
            None => {}
        }
    }
    let announce = Arc::new(server.step2_collect_masked(masked)?);
    for &id in &announce.v3 {
        stats.record(2, Dir::Down, id, announce.size_bytes());
        lanes[id].inbox = Some(Down::Announce(announce.clone()));
    }

    // ---- phase 3: unmask shares
    sweep_lanes(&mut lanes, workers, &live, &peak);
    sweeps += 1;
    close_lanes(3, &mut lanes, clock, timeline, &mut stats);
    let mut responses = Vec::new();
    for lane in lanes.iter_mut() {
        match lane.outbox.take() {
            Some(Up::Unmask(u)) => {
                stats.record(3, Dir::Up, u.from, u.size_bytes());
                responses.push(u);
            }
            Some(Up::Dropped(id, step)) => log::trace!("client {id} dropped at step {step}"),
            Some(Up::Failed(id, step, e)) => {
                log::debug!("client {id} failed step {step}: {e}")
            }
            Some(_) => bail!("protocol order violation in phase 3"),
            None => {}
        }
    }
    let RoundOutput { sum, reliable, sets } = server.finalize(responses)?;

    let telemetry = LoopTelemetry {
        workers,
        peak_live_workers: peak.load(Ordering::SeqCst).max(1),
        sweeps,
        kernel_backend: crate::kernels::selected().name(),
    };
    let machines = lanes.into_iter().map(|l| l.sm).collect();
    Ok((CoordRoundResult { sum, reliable, sets, stats, timeline: None }, telemetry, machines))
}

/// Inputs of one warm (session-resume) round through the event loop: the
/// participants' already-`warm_begin`-ed state machines, the warm server
/// built from the session's caches, and the byte charge of the
/// server-assembled coordinate-map download (0 for derived-map codecs).
pub(crate) struct WarmLoopIo<'m> {
    pub machines: Vec<ClientSm<'m>>,
    pub server: Server,
    /// Per-recipient coordinate-map download bytes (union support × 4,
    /// TopK only) charged with the phase-0 plan and excluded from
    /// [`NetStats::setup_bytes`].
    pub map_bytes: usize,
    pub workers: usize,
}

/// Run one warm round's four phases through the worker-pool event loop.
///
/// The machines and the server are handed back even when the round errors
/// (a |V_k| < t abort), so the session layer can re-seat its clients and
/// stay usable — an aborted warm round burns its ratchet round number,
/// nothing else.
pub(crate) fn run_warm_event_loop(
    io: WarmLoopIo<'_>,
) -> (Result<CoordRoundResult>, Server, Vec<ClientSm<'_>>) {
    let WarmLoopIo { machines, mut server, map_bytes, workers } = io;
    let workers = workers.max(1);
    let mask_workers = (crate::par::threads() / workers).max(1);
    let mut lane_of: Vec<Option<usize>> = vec![None; server.n()];
    let mut lanes: Vec<Lane<'_>> = machines
        .into_iter()
        .enumerate()
        .map(|(idx, mut sm)| {
            sm.set_mask_workers(mask_workers);
            lane_of[sm.id()] = Some(idx);
            Lane { sm, inbox: Some(Down::Start), outbox: None }
        })
        .collect();
    let mut stats = NetStats::new(server.n());
    let res = warm_loop_phases(&mut lanes, &lane_of, &mut server, &mut stats, map_bytes, workers);
    let machines = lanes.into_iter().map(|l| l.sm).collect();
    let res = res.map(|RoundOutput { sum, reliable, sets }| CoordRoundResult {
        sum,
        reliable,
        sets,
        stats,
        timeline: None,
    });
    (res, server, machines)
}

fn warm_loop_phases(
    lanes: &mut [Lane<'_>],
    lane_of: &[Option<usize>],
    server: &mut Server,
    stats: &mut NetStats,
    map_bytes: usize,
    workers: usize,
) -> Result<RoundOutput> {
    let live = AtomicUsize::new(0);
    let peak = AtomicUsize::new(0);

    // ---- phase 0: session resume (supports + re-key announcements)
    sweep_lanes(lanes, workers, &live, &peak);
    let mut resumes = Vec::new();
    for lane in lanes.iter_mut() {
        match lane.outbox.take() {
            Some(Up::Warm(r)) => {
                stats.record(0, Dir::Up, r.id, r.size_bytes());
                stats.record_coord_map(r.support_bytes());
                stats.record_rekey(Dir::Up, r.rekey_bytes());
                resumes.push(r);
            }
            Some(Up::Dropped(id, step)) => log::trace!("client {id} dropped at step {step}"),
            Some(Up::Failed(id, step, e)) => {
                log::debug!("client {id} failed step {step}: {e}")
            }
            Some(_) => bail!("protocol order violation in warm phase 0"),
            None => bail!("client {} produced no phase-0 output", lane.sm.id()),
        }
    }
    let plans = server.warm_step0_resume(resumes)?;
    for (id, wp) in plans {
        stats.record(0, Dir::Down, id, wp.size_bytes() + map_bytes);
        stats.record_coord_map(map_bytes);
        stats.record_rekey(Dir::Down, wp.rekey_bytes());
        let lane = lane_of[id].expect("warm plan for a client without a lane");
        lanes[lane].inbox = Some(Down::WarmPlan(wp));
    }

    // ---- phase 1: share keys (ratcheted pads / re-key AEAD re-deals)
    sweep_lanes(lanes, workers, &live, &peak);
    let mut uploads = Vec::new();
    for lane in lanes.iter_mut() {
        match lane.outbox.take() {
            Some(Up::Shares(u)) => {
                stats.record(1, Dir::Up, u.from, u.size_bytes());
                uploads.push(u);
            }
            Some(Up::Dropped(id, step)) => log::trace!("client {id} dropped at step {step}"),
            Some(Up::Failed(id, step, e)) => {
                log::debug!("client {id} withdrew step {step}: {e}")
            }
            Some(_) => bail!("protocol order violation in warm phase 1"),
            None => {}
        }
    }
    let deliveries = server.step1_route_shares(uploads)?;
    for (id, d) in deliveries {
        stats.record(1, Dir::Down, id, d.size_bytes());
        let lane = lane_of[id].expect("delivery for a client without a lane");
        lanes[lane].inbox = Some(Down::Delivery(d));
    }

    // ---- phase 2: masked inputs
    sweep_lanes(lanes, workers, &live, &peak);
    let mut masked = Vec::new();
    for lane in lanes.iter_mut() {
        match lane.outbox.take() {
            Some(Up::Masked(m)) => {
                stats.record(2, Dir::Up, m.id, m.size_bytes());
                stats.record_masked_payload(m.payload_bytes());
                masked.push(m);
            }
            Some(Up::Dropped(id, step)) => log::trace!("client {id} dropped at step {step}"),
            Some(Up::Failed(id, step, e)) => {
                log::debug!("client {id} failed step {step}: {e}")
            }
            Some(_) => bail!("protocol order violation in warm phase 2"),
            None => {}
        }
    }
    let announce = Arc::new(server.step2_collect_masked(masked)?);
    for &id in &announce.v3 {
        stats.record(2, Dir::Down, id, announce.size_bytes());
        let lane = lane_of[id].expect("announce for a client without a lane");
        lanes[lane].inbox = Some(Down::Announce(announce.clone()));
    }

    // ---- phase 3: unmask shares
    sweep_lanes(lanes, workers, &live, &peak);
    let mut responses = Vec::new();
    for lane in lanes.iter_mut() {
        match lane.outbox.take() {
            Some(Up::Unmask(u)) => {
                stats.record(3, Dir::Up, u.from, u.size_bytes());
                responses.push(u);
            }
            Some(Up::Dropped(id, step)) => log::trace!("client {id} dropped at step {step}"),
            Some(Up::Failed(id, step, e)) => {
                log::debug!("client {id} failed step {step}: {e}")
            }
            Some(_) => bail!("protocol order violation in warm phase 3"),
            None => {}
        }
    }
    server.finalize(responses)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::Codec;
    use crate::protocol::dropout::DropoutModel;
    use crate::protocol::engine;
    use crate::protocol::Topology;

    fn models(n: usize, dim: usize, seed: u64) -> Vec<Vec<u64>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect())
            .collect()
    }

    /// Σ over the given clients in Z_{2^32} — the tests' sum oracle.
    fn expected_sum(m: &[Vec<u64>], ids: impl Iterator<Item = usize>, dim: usize) -> Vec<u64> {
        let mut expect = vec![0u64; dim];
        for i in ids {
            for (a, x) in expect.iter_mut().zip(&m[i]) {
                *a = a.wrapping_add(*x) & 0xFFFF_FFFF;
            }
        }
        expect
    }

    /// A [`RoundRunner`] on the default event-loop executor.
    fn loop_runner() -> RoundRunner {
        RoundRunner::new(RoundOptions::default())
    }

    /// The event loop against the sync engine, field by field.
    fn assert_matches_engine(cfg: &ProtocolConfig, m: &[Vec<u64>]) {
        let sync = engine::run_round(cfg, m).unwrap();
        let r = loop_runner().run(cfg, m).unwrap();
        assert_eq!(r.reliable, sync.reliable, "event-loop: reliable");
        assert_eq!(r.sets, sync.sets, "event-loop: survivor sets");
        assert_eq!(r.sum, sync.sum, "event-loop: sum");
        assert_eq!(r.stats, sync.stats, "event-loop: NetStats");
    }

    #[test]
    fn event_loop_matches_sync_engine_no_dropout() {
        let n = 12;
        let dim = 40;
        let cfg = ProtocolConfig::for_test(n, 5, dim, Topology::ErdosRenyi { p: 0.7 }, 2024);
        let m = models(n, dim, 3);
        assert_matches_engine(&cfg, &m);
    }

    #[test]
    fn event_loop_matches_sync_engine_targeted_dropout() {
        let n = 10;
        let dim = 16;
        let cfg = ProtocolConfig {
            dropout: DropoutModel::Targeted {
                per_step: [vec![1], vec![3], vec![5], vec![7]],
            },
            ..ProtocolConfig::for_test(n, 4, dim, Topology::Complete, 77)
        };
        let m = models(n, dim, 4);
        assert_matches_engine(&cfg, &m);
    }

    #[test]
    fn event_loop_matches_sync_engine_under_sparse_codecs() {
        let n = 10;
        let dim = 32;
        let m = models(n, dim, 5);
        for codec in [Codec::TopK { k: 5 }, Codec::RandK { k: 5 }] {
            let cfg = ProtocolConfig {
                codec,
                dropout: DropoutModel::Targeted {
                    per_step: [vec![], vec![2], vec![6], vec![]],
                },
                ..ProtocolConfig::for_test(n, 4, dim, Topology::ErdosRenyi { p: 0.85 }, 88)
            };
            assert_matches_engine(&cfg, &m);
        }
    }

    #[test]
    fn event_loop_sum_is_true_sum_across_worker_counts() {
        // the result must not depend on how lanes shard across workers
        let n = 9;
        let dim = 20;
        let cfg = ProtocolConfig::for_test(n, 4, dim, Topology::Complete, 6);
        let m = models(n, dim, 7);
        let expect = expected_sum(&m, 0..n, dim);
        for workers in [1usize, 2, 3, 8] {
            let opts = RoundOptions::builder().workers(workers).build().unwrap();
            let (r, tel) = RoundRunner::new(opts).run_with_telemetry(&cfg, &m).unwrap();
            assert!(r.reliable, "workers={workers}");
            assert_eq!(r.sum.as_ref().unwrap(), &expect, "workers={workers}");
            assert!(tel.peak_live_workers <= workers.max(1), "workers={workers}");
            assert_eq!(tel.sweeps, 4);
        }
    }

    #[test]
    fn event_loop_worker_default_scales_with_population() {
        assert_eq!(event_loop_workers(0), 1);
        assert_eq!(event_loop_workers(MIN_CLIENTS_PER_WORKER - 1), 1);
        let big = event_loop_workers(MIN_CLIENTS_PER_WORKER * 1024);
        assert!(big >= 1 && big <= crate::par::threads());
        assert!(event_loop_workers(MIN_CLIENTS_PER_WORKER * 2) <= 2);
    }

    #[test]
    fn aborted_round_terminates_and_errors() {
        // every client dropping at step 0 leaves |V1| = 0 < t: the server
        // aborts mid-protocol; the event loop must return Err
        let n = 6;
        let cfg = ProtocolConfig {
            dropout: DropoutModel::Targeted {
                per_step: [(0..n).collect(), vec![], vec![], vec![]],
            },
            ..ProtocolConfig::for_test(n, 3, 4, Topology::Complete, 3)
        };
        let m = models(n, 4, 3);
        assert!(loop_runner().run(&cfg, &m).is_err());
    }

    #[test]
    fn abort_after_step1_terminates_and_errors() {
        // all clients past V1 drop at step 2 → |V3| = 0 < t: abort happens
        // after lanes have consumed one phase input
        let n = 5;
        let cfg = ProtocolConfig {
            dropout: DropoutModel::Targeted {
                per_step: [vec![], vec![], (0..n).collect(), vec![]],
            },
            ..ProtocolConfig::for_test(n, 2, 4, Topology::Complete, 4)
        };
        let m = models(n, 4, 4);
        assert!(loop_runner().run(&cfg, &m).is_err());
    }

    #[test]
    fn materialized_iid_dropout_terminates_and_is_consistent() {
        // Bit-identity between the engine and the event loop is promised
        // for rng-free dropout only (the engine draws Iid lazily over
        // survivors, the loop pre-draws all n×4 decisions — different
        // stream positions once anyone drops). Materializing the Iid model
        // into an explicit schedule, exactly as the sim scenario compiler
        // does, restores a shared schedule: the round must terminate and,
        // when reliable, produce exactly the V3 sum in engine agreement.
        for seed in 0..5 {
            let n = 14;
            let per_step =
                DropoutModel::Iid { q: 0.15 }.materialize(n, &mut Rng::new(0x1D1D + seed));
            let cfg = ProtocolConfig {
                dropout: DropoutModel::Targeted { per_step },
                ..ProtocolConfig::for_test(n, 5, 8, Topology::ErdosRenyi { p: 0.8 }, 100 + seed)
            };
            let m = models(n, 8, seed);
            let sync = engine::run_round(&cfg, &m);
            let looped = loop_runner().run(&cfg, &m);
            match (sync, looped) {
                (Ok(a), Ok(b)) => {
                    assert_eq!(a.sets, b.sets, "seed={seed}");
                    assert_eq!(a.sum, b.sum, "seed={seed}");
                    assert_eq!(a.stats, b.stats, "seed={seed}");
                    if b.reliable {
                        let expect = expected_sum(&m, b.sets.v3.iter().copied(), 8);
                        assert_eq!(b.sum.unwrap(), expect, "seed={seed}");
                    }
                }
                (Err(_), Err(_)) => { /* |V_k| < t abort is acceptable under dropout */ }
                (a, b) => panic!("shapes disagree on abort: seed={seed} {a:?} vs {b:?}"),
            }
        }
    }

    /// Every `build()` rejection must name the offending field and, for
    /// cross-knob conflicts, the conflicting pair — so a failed build tells
    /// the caller *which* constraint fired without reading this module.
    #[track_caller]
    fn build_err(b: RoundOptionsBuilder, wants: &[&str]) {
        let msg = b.build().expect_err("expected a validation error").to_string();
        for want in wants {
            assert!(msg.contains(want), "error {msg:?} should mention {want:?}");
        }
    }

    #[test]
    fn round_options_builder_validates_cross_knob_rules() {
        let sched = || Arc::new(crate::sim::clock::ClockSchedule { delay_us: vec![[0; 4]; 4] });
        let policy = || TimeoutPolicy::uniform(Duration::from_millis(5));

        // defaults: event loop, nothing else
        let d = RoundOptions::builder().build().unwrap();
        assert_eq!(d.executor, Executor::EventLoop);
        assert!(d.workers.is_none() && d.journal_dir.is_none() && d.stop_after.is_none());
        assert!(d.timeout_policy.is_none() && d.clock.is_none());

        // -- workers ----------------------------------------------------
        build_err(RoundOptions::builder().workers(0), &["workers = 0"]);
        build_err(
            RoundOptions::builder().executor(Executor::Wire).workers(2),
            &["workers conflicts with executor = wire"],
        );
        build_err(
            RoundOptions::builder().executor(Executor::Engine).workers(2),
            &["workers conflicts with executor = engine"],
        );

        // -- journal ----------------------------------------------------
        build_err(
            RoundOptions::builder().executor(Executor::Engine).journal("/tmp/j"),
            &["journal_dir conflicts with executor = engine"],
        );

        // -- stop_after -------------------------------------------------
        // needs a journal AND the wire executor; the journal rule fires first
        build_err(
            RoundOptions::builder().executor(Executor::Wire).stop_after(StopAfter::Setup),
            &["stop_after requires journal_dir"],
        );
        build_err(
            RoundOptions::builder().journal("/tmp/j").stop_after(StopAfter::Setup),
            &["stop_after conflicts with executor = event-loop"],
        );

        // -- timeout_policy / clock ------------------------------------
        build_err(
            RoundOptions::builder().executor(Executor::Engine).timeout_policy(policy()),
            &["timeout_policy conflicts with executor = engine"],
        );
        build_err(
            RoundOptions::builder().timeout_policy(policy()),
            &["timeout_policy requires clock on the event-loop executor"],
        );
        build_err(
            RoundOptions::builder().clock(sched()),
            &["clock requires timeout_policy"],
        );
        build_err(
            RoundOptions::builder().executor(Executor::Wire).clock(sched()),
            &["clock conflicts with executor = wire"],
        );
        build_err(
            RoundOptions::builder().executor(Executor::Engine).clock(sched()),
            &["clock conflicts with executor = engine"],
        );

        // -- valid combinations ----------------------------------------
        let ok = RoundOptions::builder()
            .executor(Executor::Wire)
            .journal("/tmp/j")
            .stop_after(StopAfter::Phase(2))
            .timeout(Duration::from_secs(5))
            .build()
            .unwrap();
        assert_eq!(ok.stop_after, Some(StopAfter::Phase(2)));
        assert_eq!(ok.timeout_or_default(), Duration::from_secs(5));

        // wire maps phase deadlines to wall time — no clock needed
        let wire = RoundOptions::builder()
            .executor(Executor::Wire)
            .timeout_policy(policy())
            .build()
            .unwrap();
        assert_eq!(wire.timeout_policy, Some(policy()));

        // event loop: schedule + policy together is the virtual-clock path
        let clocked = RoundOptions::builder()
            .clock(sched())
            .timeout_policy(policy().with_min_survivors(3))
            .build()
            .unwrap();
        assert_eq!(clocked.timeout_policy.as_ref().unwrap().min_survivors, 3);
        assert!(clocked.clock.is_some());

        assert!(RoundOptions::builder().workers(4).build().is_ok());
        assert!(RoundOptions::builder().journal("/tmp/j").build().is_ok());
        assert!(RoundOptions::builder().executor(Executor::Wire).journal("/tmp/j").build().is_ok());
    }

    #[test]
    fn engine_and_wire_executors_agree_through_the_runner() {
        let n = 8;
        let dim = 12;
        let cfg = ProtocolConfig::for_test(n, 4, dim, Topology::ErdosRenyi { p: 0.8 }, 909);
        let m = models(n, dim, 11);
        let reference = RoundRunner::new(
            RoundOptions::builder().executor(Executor::Engine).build().unwrap(),
        )
        .run(&cfg, &m)
        .unwrap();
        for e in Executor::non_reference() {
            let opts = RoundOptions::builder().executor(e).build().unwrap();
            let r = RoundRunner::new(opts).run(&cfg, &m).unwrap();
            assert_eq!(r.sets, reference.sets, "{}", e.name());
            assert_eq!(r.sum, reference.sum, "{}", e.name());
            assert!(r.stats.logical_eq(&reference.stats), "{}", e.name());
        }
    }
}
