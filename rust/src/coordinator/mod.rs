//! Deployment shapes for one aggregation round: how n client state
//! machines and one server actually execute.
//!
//! `protocol::engine` is the deterministic synchronous core used by tests
//! and benches. This module provides two "real service" arrangements built
//! on the same poll-able [`ClientSm`]:
//!
//! * [`run_round_event_loop`] — **the scaling shape.** A single event loop
//!   multiplexes all n client state machines over a fixed worker pool
//!   (`par::threads()`-sized): clients are sharded deterministically across
//!   workers, each protocol phase is one parallel sweep over the shards,
//!   and the server drains the resulting `Up` messages in client-id order.
//!   Thread cost is O(workers), independent of n — a 10⁵-client round runs
//!   on a handful of OS threads.
//! * [`run_round_threaded`] — the legacy thread-per-client shape: one OS
//!   thread per client exchanging the same `Up`/`Down` messages over mpsc
//!   channels. It caps out at a few thousand clients (thread-spawn cost and
//!   scheduler pressure) and is kept only as a differential witness until
//!   the event loop's equivalence suite has proven itself everywhere; it is
//!   scheduled for deletion (see ROADMAP).
//!
//! With `DropoutModel::None` or `Targeted` (rng-free models), both shapes
//! produce sums, survivor sets and `NetStats` bit-identical to the sync
//! engine for the same seed (asserted in tests and in the randomized
//! differential harness, `sim::differential`).

use crate::net::{Dir, NetStats};
use crate::protocol::client::ClientSm;
use crate::protocol::messages::*;
use crate::protocol::server::{RoundOutput, Server};
use crate::protocol::{ClientId, ProtocolConfig, SurvivorSets};
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

/// Outcome of a coordinated round (mirrors the engine's essentials).
#[derive(Debug)]
pub struct CoordRoundResult {
    pub sum: Option<Vec<u64>>,
    pub reliable: bool,
    pub sets: SurvivorSets,
    pub stats: NetStats,
}

/// How the event loop actually ran — the observable for "no thread-per-
/// client" assertions.
#[derive(Debug, Clone, Copy)]
pub struct LoopTelemetry {
    /// Worker budget the loop ran with.
    pub workers: usize,
    /// Maximum number of concurrently live pool threads observed across
    /// all sweeps (1 when a sweep ran inline on the caller's thread).
    pub peak_live_workers: usize,
    /// Parallel sweeps executed — one per protocol phase reached.
    pub sweeps: usize,
}

/// Minimum clients a pool worker should own before a sweep is worth its
/// thread spawns: a client step costs tens of µs of crypto (x25519
/// agreements, Shamir splits), so ~16 clients dwarf the ~10 µs spawn+join.
/// Below `workers · MIN_CLIENTS_PER_WORKER` clients the sweep degrades
/// toward fewer workers (1 at simulation sizes) and runs inline,
/// bit-identically.
pub const MIN_CLIENTS_PER_WORKER: usize = 16;

/// Default worker count for an n-client event loop: [`crate::par::threads`]
/// capped so each worker owns at least [`MIN_CLIENTS_PER_WORKER`] clients.
pub fn event_loop_workers(n: usize) -> usize {
    crate::par::threads().min(n / MIN_CLIENTS_PER_WORKER).max(1)
}

/// Pre-draw every client's per-step dropout decision in the sync engine's
/// draw order (step-major, client-minor), so rng-free models produce
/// identical survivor sets in every execution shape.
fn predraw_survivals(cfg: &ProtocolConfig, dropout_rng: &mut Rng) -> Vec<[bool; 4]> {
    let mut survives = vec![[true; 4]; cfg.n];
    for step in 0..4 {
        for (id, s) in survives.iter_mut().enumerate() {
            s[step] = cfg.dropout.survives(step, id, dropout_rng);
        }
    }
    survives
}

/// One client's slot in the event loop: its state machine plus single-entry
/// mailboxes. The loop writes `inbox` while routing, a sweep moves
/// `inbox → step → outbox`, and the drain empties `outbox` in id order.
struct Lane<'m> {
    sm: ClientSm<'m>,
    inbox: Option<Down>,
    outbox: Option<Up>,
}

/// One parallel sweep: step every lane holding a phase input, sharding the
/// lane vector contiguously across at most `workers` pool threads. The
/// gauge pair records the peak number of concurrently live workers.
fn sweep_lanes(lanes: &mut [Lane<'_>], workers: usize, live: &AtomicUsize, peak: &AtomicUsize) {
    crate::par::for_each_slice(lanes, workers, |_, chunk| {
        let cur = live.fetch_add(1, Ordering::SeqCst) + 1;
        peak.fetch_max(cur, Ordering::SeqCst);
        for lane in chunk.iter_mut() {
            if let Some(down) = lane.inbox.take() {
                lane.outbox = Some(lane.sm.step(down));
            }
        }
        live.fetch_sub(1, Ordering::SeqCst);
    });
}

/// Run one aggregation round through the worker-pool event loop with the
/// default worker count ([`event_loop_workers`]).
pub fn run_round_event_loop(
    cfg: &ProtocolConfig,
    models: &[Vec<u64>],
) -> Result<CoordRoundResult> {
    run_round_event_loop_with(cfg, models, event_loop_workers(cfg.n)).map(|(r, _)| r)
}

/// [`run_round_event_loop`] with an explicit worker budget, returning the
/// loop telemetry alongside the result.
pub fn run_round_event_loop_with(
    cfg: &ProtocolConfig,
    models: &[Vec<u64>],
    workers: usize,
) -> Result<(CoordRoundResult, LoopTelemetry)> {
    assert_eq!(models.len(), cfg.n);
    let workers = workers.max(1);
    let mut rng = Rng::new(cfg.seed);
    let graph = cfg.build_graph_with(&mut rng);
    let mut dropout_rng = rng.split(0xD20);
    let survives = predraw_survivals(cfg, &mut dropout_rng);

    // RNG derivation is order-dependent (`split` advances the base), so the
    // per-client streams are drawn serially — that part is cheap. The
    // expensive part, key generation (two x25519 ladders per client inside
    // `Client::new`), derives only from the already-split streams, so lane
    // construction itself runs on the worker pool.
    let streams: Vec<(Rng, Rng)> = (0..cfg.n)
        .map(|id| (rng.split(0xC11E27 + id as u64), rng.split(0x5A12E + id as u64)))
        .collect();
    // The per-machine Step-2 mask budget splits the host budget across the
    // sweep workers, so sweep × mask parallelism never exceeds
    // `par::threads()` live threads — the "no thread-per-client" claim
    // holds at any dim, not just when vectors are too short to shard.
    let mask_workers = (crate::par::threads() / workers).max(1);
    let mut lanes: Vec<Lane<'_>> = crate::par::map_indexed(cfg.n, workers, |id| {
        let (mut key_rng, share_rng) = streams[id].clone();
        let mut sm = ClientSm::new(
            id,
            cfg.t,
            cfg.mask_bits,
            graph.neighbors(id).to_vec(),
            &mut key_rng,
            share_rng,
            &models[id],
            survives[id],
        );
        sm.set_mask_workers(mask_workers);
        Lane { sm, inbox: Some(Down::Start), outbox: None }
    });
    drop(streams); // lanes cloned their pairs; free ~2n ChaCha states

    let mut server = Server::new(cfg.n, cfg.t, cfg.mask_bits, cfg.dim, graph.clone());
    let mut stats = NetStats::new(cfg.n);
    let live = AtomicUsize::new(0);
    let peak = AtomicUsize::new(0);
    let mut sweeps = 0usize;

    // ---- phase 0: advertise keys
    sweep_lanes(&mut lanes, workers, &live, &peak);
    sweeps += 1;
    let mut advs = Vec::new();
    for lane in lanes.iter_mut() {
        match lane.outbox.take() {
            Some(Up::Adv(a)) => {
                stats.record(0, Dir::Up, a.id, a.size_bytes());
                advs.push(a);
            }
            Some(Up::Dropped(id, step)) => log::trace!("client {id} dropped at step {step}"),
            Some(Up::Failed(id, step, e)) => {
                log::debug!("client {id} failed step {step}: {e}")
            }
            Some(_) => bail!("protocol order violation in phase 0"),
            None => bail!("client {} produced no phase-0 output", lane.sm.id()),
        }
    }
    let bundles = server.step0_route_keys(advs)?;
    for (id, b) in bundles {
        stats.record(0, Dir::Down, id, b.size_bytes());
        lanes[id].inbox = Some(Down::Bundle(b));
    }

    // ---- phase 1: share keys
    sweep_lanes(&mut lanes, workers, &live, &peak);
    sweeps += 1;
    let mut uploads = Vec::new();
    for lane in lanes.iter_mut() {
        match lane.outbox.take() {
            Some(Up::Shares(u)) => {
                stats.record(1, Dir::Up, u.from, u.size_bytes());
                uploads.push(u);
            }
            Some(Up::Dropped(id, step)) => log::trace!("client {id} dropped at step {step}"),
            Some(Up::Failed(id, step, e)) => {
                log::debug!("client {id} withdrew step {step}: {e}")
            }
            Some(_) => bail!("protocol order violation in phase 1"),
            None => {}
        }
    }
    let deliveries = server.step1_route_shares(uploads)?;
    for (id, d) in deliveries {
        stats.record(1, Dir::Down, id, d.size_bytes());
        lanes[id].inbox = Some(Down::Delivery(d));
    }

    // ---- phase 2: masked inputs
    sweep_lanes(&mut lanes, workers, &live, &peak);
    sweeps += 1;
    let mut masked = Vec::new();
    for lane in lanes.iter_mut() {
        match lane.outbox.take() {
            Some(Up::Masked(m)) => {
                stats.record(2, Dir::Up, m.id, m.size_bytes());
                masked.push(m);
            }
            Some(Up::Dropped(id, step)) => log::trace!("client {id} dropped at step {step}"),
            Some(Up::Failed(id, step, e)) => {
                log::debug!("client {id} failed step {step}: {e}")
            }
            Some(_) => bail!("protocol order violation in phase 2"),
            None => {}
        }
    }
    let announce = Arc::new(server.step2_collect_masked(masked)?);
    for &id in &announce.v3 {
        stats.record(2, Dir::Down, id, announce.size_bytes());
        lanes[id].inbox = Some(Down::Announce(announce.clone()));
    }

    // ---- phase 3: unmask shares
    sweep_lanes(&mut lanes, workers, &live, &peak);
    sweeps += 1;
    let mut responses = Vec::new();
    for lane in lanes.iter_mut() {
        match lane.outbox.take() {
            Some(Up::Unmask(u)) => {
                stats.record(3, Dir::Up, u.from, u.size_bytes());
                responses.push(u);
            }
            Some(Up::Dropped(id, step)) => log::trace!("client {id} dropped at step {step}"),
            Some(Up::Failed(id, step, e)) => {
                log::debug!("client {id} failed step {step}: {e}")
            }
            Some(_) => bail!("protocol order violation in phase 3"),
            None => {}
        }
    }
    let RoundOutput { sum, reliable, sets } = server.finalize(responses)?;

    let telemetry = LoopTelemetry {
        workers,
        peak_live_workers: peak.load(Ordering::SeqCst).max(1),
        sweeps,
    };
    Ok((CoordRoundResult { sum, reliable, sets, stats }, telemetry))
}

/// Run one aggregation round with real threads — one OS thread per client.
///
/// Legacy shape: scales to a few thousand clients at most. Kept as the
/// differential witness for the event loop; new code should call
/// [`run_round_event_loop`].
pub fn run_round_threaded(cfg: &ProtocolConfig, models: &[Vec<u64>]) -> Result<CoordRoundResult> {
    assert_eq!(models.len(), cfg.n);
    let mut rng = Rng::new(cfg.seed);
    let graph = cfg.build_graph_with(&mut rng);
    let mut dropout_rng = rng.split(0xD20);
    let survives = predraw_survivals(cfg, &mut dropout_rng);

    let (tx_up, rx_up) = mpsc::channel::<Up>();
    let mut to_clients: BTreeMap<ClientId, mpsc::Sender<Down>> = BTreeMap::new();

    std::thread::scope(|scope| -> Result<CoordRoundResult> {
        // spawn one worker per client, each driving its own state machine
        for id in 0..cfg.n {
            let (tx_down, rx_down) = mpsc::channel::<Down>();
            to_clients.insert(id, tx_down);
            let tx_up = tx_up.clone();
            let mut key_rng = rng.split(0xC11E27 + id as u64);
            let share_rng = rng.split(0x5A12E + id as u64);
            let neighbors = graph.neighbors(id).to_vec();
            let model: &[u64] = &models[id];
            let surv = survives[id];
            let t = cfg.t;
            let bits = cfg.mask_bits;
            scope.spawn(move || {
                // key generation stays on the worker thread (parallel
                // across clients), fed by the pre-split stream
                let mut sm =
                    ClientSm::new(id, t, bits, neighbors, &mut key_rng, share_rng, model, surv);
                let mut up = sm.step(Down::Start);
                loop {
                    let finished = sm.done();
                    let _ = tx_up.send(up);
                    if finished {
                        return;
                    }
                    match rx_down.recv() {
                        // Finish (or a closed channel) ends the worker
                        // without a protocol response
                        Ok(Down::Finish) | Err(_) => return,
                        Ok(down) => up = sm.step(down),
                    }
                }
            });
        }
        drop(tx_up);

        // The server phases run in an inner closure so that EVERY exit path
        // — including a mid-protocol abort like |V_k| < t — falls through to
        // the wake-up loop below. Without it, an early `?` return would
        // leave worker threads parked on `rx_down.recv()` with their senders
        // still alive, and `thread::scope` would deadlock joining them.
        let result = (|| -> Result<CoordRoundResult> {
            let mut server = Server::new(cfg.n, cfg.t, cfg.mask_bits, cfg.dim, graph.clone());
            let mut stats = NetStats::new(cfg.n);

            // ---- phase 0: every client reports (advert or drop)
            let mut advs = Vec::new();
            for _ in 0..cfg.n {
                match rx_up.recv().map_err(|_| anyhow!("client channel closed"))? {
                    Up::Adv(a) => {
                        stats.record(0, Dir::Up, a.id, a.size_bytes());
                        advs.push(a);
                    }
                    Up::Dropped(id, step) => log::trace!("client {id} dropped at step {step}"),
                    Up::Failed(id, step, e) => log::debug!("client {id} failed step {step}: {e}"),
                    _ => return Err(anyhow!("protocol order violation in phase 0")),
                }
            }
            // deterministic drain order regardless of thread scheduling
            advs.sort_by_key(|a| a.id);
            let bundles = server.step0_route_keys(advs)?;
            let expect1 = bundles.len();
            for (id, b) in bundles {
                stats.record(0, Dir::Down, id, b.size_bytes());
                let _ = to_clients[&id].send(Down::Bundle(b));
            }

            // ---- phase 1
            let mut uploads = Vec::new();
            for _ in 0..expect1 {
                match rx_up.recv()? {
                    Up::Shares(u) => {
                        stats.record(1, Dir::Up, u.from, u.size_bytes());
                        uploads.push(u);
                    }
                    Up::Dropped(id, step) => log::trace!("client {id} dropped at step {step}"),
                    Up::Failed(id, step, e) => {
                        log::debug!("client {id} withdrew step {step}: {e}")
                    }
                    _ => return Err(anyhow!("protocol order violation in phase 1")),
                }
            }
            uploads.sort_by_key(|u| u.from);
            let deliveries = server.step1_route_shares(uploads)?;
            let expect2 = deliveries.len();
            for (id, d) in deliveries {
                stats.record(1, Dir::Down, id, d.size_bytes());
                let _ = to_clients[&id].send(Down::Delivery(d));
            }

            // ---- phase 2
            let mut masked = Vec::new();
            for _ in 0..expect2 {
                match rx_up.recv()? {
                    Up::Masked(m) => {
                        stats.record(2, Dir::Up, m.id, m.size_bytes());
                        masked.push(m);
                    }
                    Up::Dropped(id, step) => log::trace!("client {id} dropped at step {step}"),
                    Up::Failed(id, step, e) => log::debug!("client {id} failed step {step}: {e}"),
                    _ => return Err(anyhow!("protocol order violation in phase 2")),
                }
            }
            masked.sort_by_key(|m| m.id);
            let announce = Arc::new(server.step2_collect_masked(masked)?);
            let expect3 = announce.v3.len();
            for &id in &announce.v3 {
                stats.record(2, Dir::Down, id, announce.size_bytes());
                let _ = to_clients[&id].send(Down::Announce(announce.clone()));
            }

            // ---- phase 3
            let mut responses = Vec::new();
            for _ in 0..expect3 {
                match rx_up.recv()? {
                    Up::Unmask(u) => {
                        stats.record(3, Dir::Up, u.from, u.size_bytes());
                        responses.push(u);
                    }
                    Up::Dropped(id, step) => log::trace!("client {id} dropped at step {step}"),
                    Up::Failed(id, step, e) => log::debug!("client {id} failed step {step}: {e}"),
                    _ => return Err(anyhow!("protocol order violation in phase 3")),
                }
            }
            responses.sort_by_key(|r| r.from);
            let RoundOutput { sum, reliable, sets } = server.finalize(responses)?;
            Ok(CoordRoundResult { sum, reliable, sets, stats })
        })();

        // Unblock every worker that is still waiting for its next phase
        // input; workers that already returned just drop the send.
        for tx in to_clients.values() {
            let _ = tx.send(Down::Finish);
        }
        result
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::dropout::DropoutModel;
    use crate::protocol::engine;
    use crate::protocol::Topology;

    fn models(n: usize, dim: usize, seed: u64) -> Vec<Vec<u64>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect())
            .collect()
    }

    /// Σ over the given clients in Z_{2^32} — the tests' sum oracle.
    fn expected_sum(m: &[Vec<u64>], ids: impl Iterator<Item = usize>, dim: usize) -> Vec<u64> {
        let mut expect = vec![0u64; dim];
        for i in ids {
            for (a, x) in expect.iter_mut().zip(&m[i]) {
                *a = a.wrapping_add(*x) & 0xFFFF_FFFF;
            }
        }
        expect
    }

    /// Both deployment shapes against the sync engine.
    fn assert_all_shapes_match_engine(cfg: &ProtocolConfig, m: &[Vec<u64>]) {
        let sync = engine::run_round(cfg, m).unwrap();
        for (name, r) in [
            ("threaded", run_round_threaded(cfg, m).unwrap()),
            ("event-loop", run_round_event_loop(cfg, m).unwrap()),
        ] {
            assert_eq!(r.reliable, sync.reliable, "{name}: reliable");
            assert_eq!(r.sets, sync.sets, "{name}: survivor sets");
            assert_eq!(r.sum, sync.sum, "{name}: sum");
            assert_eq!(r.stats, sync.stats, "{name}: NetStats");
        }
    }

    #[test]
    fn both_shapes_match_sync_engine_no_dropout() {
        let n = 12;
        let dim = 40;
        let cfg = ProtocolConfig::new(n, 5, dim, Topology::ErdosRenyi { p: 0.7 }, 2024);
        let m = models(n, dim, 3);
        assert_all_shapes_match_engine(&cfg, &m);
    }

    #[test]
    fn both_shapes_match_sync_engine_targeted_dropout() {
        let n = 10;
        let dim = 16;
        let cfg = ProtocolConfig {
            dropout: DropoutModel::Targeted {
                per_step: [vec![1], vec![3], vec![5], vec![7]],
            },
            ..ProtocolConfig::new(n, 4, dim, Topology::Complete, 77)
        };
        let m = models(n, dim, 4);
        assert_all_shapes_match_engine(&cfg, &m);
    }

    #[test]
    fn threaded_sum_is_true_sum() {
        let n = 8;
        let dim = 30;
        let cfg = ProtocolConfig::new(n, 4, dim, Topology::Complete, 5);
        let m = models(n, dim, 6);
        let r = run_round_threaded(&cfg, &m).unwrap();
        assert!(r.reliable);
        assert_eq!(r.sum.unwrap(), expected_sum(&m, 0..n, dim));
    }

    #[test]
    fn event_loop_sum_is_true_sum_across_worker_counts() {
        // the result must not depend on how lanes shard across workers
        let n = 9;
        let dim = 20;
        let cfg = ProtocolConfig::new(n, 4, dim, Topology::Complete, 6);
        let m = models(n, dim, 7);
        let expect = expected_sum(&m, 0..n, dim);
        for workers in [1usize, 2, 3, 8] {
            let (r, tel) = run_round_event_loop_with(&cfg, &m, workers).unwrap();
            assert!(r.reliable, "workers={workers}");
            assert_eq!(r.sum.as_ref().unwrap(), &expect, "workers={workers}");
            assert!(tel.peak_live_workers <= workers.max(1), "workers={workers}");
            assert_eq!(tel.sweeps, 4);
        }
    }

    #[test]
    fn event_loop_worker_default_scales_with_population() {
        assert_eq!(event_loop_workers(0), 1);
        assert_eq!(event_loop_workers(MIN_CLIENTS_PER_WORKER - 1), 1);
        let big = event_loop_workers(MIN_CLIENTS_PER_WORKER * 1024);
        assert!(big >= 1 && big <= crate::par::threads());
        assert!(event_loop_workers(MIN_CLIENTS_PER_WORKER * 2) <= 2);
    }

    #[test]
    fn aborted_round_terminates_and_errors() {
        // every client dropping at step 0 leaves |V1| = 0 < t: the server
        // aborts mid-protocol; both shapes must return Err — the threaded
        // one without deadlocking on workers that never got phase input
        let n = 6;
        let cfg = ProtocolConfig {
            dropout: DropoutModel::Targeted {
                per_step: [(0..n).collect(), vec![], vec![], vec![]],
            },
            ..ProtocolConfig::new(n, 3, 4, Topology::Complete, 3)
        };
        let m = models(n, 4, 3);
        assert!(run_round_threaded(&cfg, &m).is_err());
        assert!(run_round_event_loop(&cfg, &m).is_err());
    }

    #[test]
    fn abort_after_step1_terminates_and_errors() {
        // all clients past V1 drop at step 2 → |V3| = 0 < t: abort happens
        // after workers have consumed one phase input — the late-phase
        // unblocking path
        let n = 5;
        let cfg = ProtocolConfig {
            dropout: DropoutModel::Targeted {
                per_step: [vec![], vec![], (0..n).collect(), vec![]],
            },
            ..ProtocolConfig::new(n, 2, 4, Topology::Complete, 4)
        };
        let m = models(n, 4, 4);
        assert!(run_round_threaded(&cfg, &m).is_err());
        assert!(run_round_event_loop(&cfg, &m).is_err());
    }

    #[test]
    fn iid_dropout_terminates_and_is_consistent() {
        // Iid dropout draws happen in a fixed pre-pass, so each shape is
        // deterministic; the protocol must terminate and, when reliable,
        // produce exactly the V3 sum. Both shapes share the pre-pass, so
        // they also agree with each other.
        for seed in 0..5 {
            let n = 14;
            let cfg = ProtocolConfig {
                dropout: DropoutModel::Iid { q: 0.15 },
                ..ProtocolConfig::new(n, 5, 8, Topology::ErdosRenyi { p: 0.8 }, 100 + seed)
            };
            let m = models(n, 8, seed);
            let threaded = run_round_threaded(&cfg, &m);
            let looped = run_round_event_loop(&cfg, &m);
            match (threaded, looped) {
                (Ok(a), Ok(b)) => {
                    assert_eq!(a.sets, b.sets, "seed={seed}");
                    assert_eq!(a.sum, b.sum, "seed={seed}");
                    assert_eq!(a.stats, b.stats, "seed={seed}");
                    if a.reliable {
                        let expect = expected_sum(&m, a.sets.v3.iter().copied(), 8);
                        assert_eq!(a.sum.unwrap(), expect, "seed={seed}");
                    }
                }
                (Err(_), Err(_)) => { /* |V_k| < t abort is acceptable under dropout */ }
                (a, b) => panic!("shapes disagree on abort: seed={seed} {a:?} vs {b:?}"),
            }
        }
    }
}
