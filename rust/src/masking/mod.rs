//! Fixed-point quantization and masked-vector arithmetic over Z_{2^b}.
//!
//! Secure aggregation operates on integers modulo 2^b (the paper uses
//! F_{2^16}; we default to b = 32 for training headroom — see DESIGN.md).
//! The pipeline per round:
//!
//! 1. each client **quantizes** its f32 model delta into Z_{2^b} with a
//!    shared (clip, scale) so that the modular sum of up to `n_max`
//!    client vectors never wraps ambiguously;
//! 2. clients add PRG masks (Eq. 3) — [`crate::crypto::prg`], whose
//!    multi-seed application runs on the fused keystream-major kernel
//!    ([`crate::kernels::apply_masks_fused`]);
//! 3. the server sums masked vectors mod 2^b, cancels masks (Eq. 4), and
//!    **dequantizes** the exact integer sum back to f32.
//!
//! Signed values are centered: x ↦ round(x·scale) + 2^(b-1) is *not* used;
//! instead we use two's-complement semantics (negative values wrap), which
//! makes the sum decode exact as long as |Σ x_i|·scale < 2^(b-1).

use crate::util::{mod_mask, rng::Rng};

/// Quantization parameters shared by all clients in a round.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Quantizer {
    /// Mask/aggregation word width b (1..=64). Domain is Z_{2^b}.
    pub bits: u32,
    /// Values are clipped to [-clip, clip] before scaling.
    pub clip: f32,
    /// Multiplicative scale; chosen via [`Quantizer::for_sum_of`].
    pub scale: f64,
}

impl Quantizer {
    /// Build a quantizer that can represent the *sum* of up to `n_max`
    /// clipped vectors without modular ambiguity:
    /// scale = 2^(b-1) / (n_max · clip) with a 2× safety margin.
    ///
    /// The raw masked domain ([`crate::util::mod_mask`]) allows b ∈ 1..=64;
    /// the quantizer additionally needs b ≥ 2 because one bit is the
    /// two's-complement sign.
    pub fn for_sum_of(bits: u32, clip: f32, n_max: usize) -> Quantizer {
        assert!((2..=64).contains(&bits), "quantizer needs a sign bit: bits must be in 2..=64");
        assert!(clip > 0.0 && n_max > 0);
        let headroom = 2.0 * n_max as f64 * clip as f64;
        let scale = (1u64 << (bits - 1)) as f64 / headroom;
        Quantizer { bits, clip, scale }
    }

    #[inline]
    pub fn modulus_mask(&self) -> u64 {
        mod_mask(self.bits)
    }

    /// Quantize one value to Z_{2^b} (two's complement wrap).
    #[inline]
    pub fn quantize_one(&self, x: f32) -> u64 {
        let clipped = x.clamp(-self.clip, self.clip) as f64;
        let v = (clipped * self.scale).round() as i64;
        (v as u64) & self.modulus_mask()
    }

    /// Decode one aggregated word back to f64, interpreting the b-bit word
    /// as two's complement.
    #[inline]
    pub fn dequantize_one(&self, w: u64) -> f64 {
        let b = self.bits;
        let half = 1u64 << (b - 1);
        let w = w & self.modulus_mask();
        let signed = if w >= half {
            w as i64 - (self.modulus_mask() as i64 + 1)
        } else {
            w as i64
        };
        signed as f64 / self.scale
    }

    /// Quantize a vector.
    pub fn quantize(&self, xs: &[f32]) -> Vec<u64> {
        xs.iter().map(|&x| self.quantize_one(x)).collect()
    }

    /// Dequantize a vector of aggregated words.
    pub fn dequantize(&self, ws: &[u64]) -> Vec<f64> {
        ws.iter().map(|&w| self.dequantize_one(w)).collect()
    }

    /// Worst-case absolute rounding error of a sum of `k` quantized values.
    pub fn sum_error_bound(&self, k: usize) -> f64 {
        0.5 * k as f64 / self.scale
    }
}

/// c = a + b (mod 2^bits), in place on `a`.
pub fn add_assign(a: &mut [u64], b: &[u64], bits: u32) {
    debug_assert_eq!(a.len(), b.len());
    let mask = mod_mask(bits);
    for (x, y) in a.iter_mut().zip(b) {
        *x = x.wrapping_add(*y) & mask;
    }
}

/// c = a − b (mod 2^bits), in place on `a`.
pub fn sub_assign(a: &mut [u64], b: &[u64], bits: u32) {
    debug_assert_eq!(a.len(), b.len());
    let mask = mod_mask(bits);
    for (x, y) in a.iter_mut().zip(b) {
        *x = x.wrapping_sub(*y) & mask;
    }
}

/// Random vector in Z_{2^bits} (test helper / privacy-attack baseline).
pub fn random_vector(len: usize, bits: u32, rng: &mut Rng) -> Vec<u64> {
    let mask = mod_mask(bits);
    (0..len).map(|_| rng.next_u64() & mask).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_round_trip_single() {
        let q = Quantizer::for_sum_of(32, 4.0, 100);
        for x in [-4.0f32, -1.5, -1e-3, 0.0, 1e-3, 0.7, 3.999, 4.0] {
            let w = q.quantize_one(x);
            let back = q.dequantize_one(w);
            assert!((back - x as f64).abs() < 1.0 / q.scale, "x={x} back={back}");
        }
    }

    #[test]
    fn clipping_applied() {
        let q = Quantizer::for_sum_of(32, 1.0, 10);
        assert_eq!(q.quantize_one(5.0), q.quantize_one(1.0));
        assert_eq!(q.quantize_one(-5.0), q.quantize_one(-1.0));
    }

    #[test]
    fn modular_sum_decodes_exactly() {
        // sum of n quantized vectors, with masks added and removed, decodes
        // to the true sum within rounding error
        let n = 50;
        let dim = 200;
        let q = Quantizer::for_sum_of(32, 2.0, n);
        let mut rng = Rng::new(0x9A5);
        let vecs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.normal_f32(0.0, 0.5)).collect())
            .collect();
        let mut acc = vec![0u64; dim];
        for v in &vecs {
            let qv = q.quantize(v);
            add_assign(&mut acc, &qv, q.bits);
        }
        let decoded = q.dequantize(&acc);
        for d in 0..dim {
            let truth: f64 = vecs.iter().map(|v| v[d].clamp(-2.0, 2.0) as f64).sum();
            assert!(
                (decoded[d] - truth).abs() <= q.sum_error_bound(n) + 1e-9,
                "dim {d}: decoded={} truth={truth}",
                decoded[d]
            );
        }
    }

    #[test]
    fn negative_sum_wraps_correctly() {
        let q = Quantizer::for_sum_of(16, 1.0, 4);
        let mut acc = vec![0u64; 1];
        for _ in 0..4 {
            add_assign(&mut acc, &q.quantize(&[-1.0]), q.bits);
        }
        let s = q.dequantize(&acc)[0];
        assert!((s + 4.0).abs() < q.sum_error_bound(4) + 1e-9, "s={s}");
    }

    #[test]
    fn add_sub_are_inverse() {
        let mut rng = Rng::new(0xC3);
        for bits in [16u32, 32, 64] {
            let a0 = random_vector(128, bits, &mut rng);
            let b = random_vector(128, bits, &mut rng);
            let mut a = a0.clone();
            add_assign(&mut a, &b, bits);
            sub_assign(&mut a, &b, bits);
            assert_eq!(a, a0, "bits={bits}");
        }
    }

    #[test]
    fn mask_cancellation_identity() {
        // the algebraic heart of secure aggregation: pairwise masks with
        // the i<j sign convention cancel in the sum (Eq. 1 → Eq. 2)
        use crate::crypto::prg::{apply_mask, NONCE_PAIRWISE};
        let bits = 32;
        let dim = 300;
        let n = 6;
        let mut rng = Rng::new(0x11);
        // symmetric seeds s[i][j] = s[j][i]
        let mut seeds = vec![vec![[0u8; 32]; n]; n];
        for i in 0..n {
            for j in (i + 1)..n {
                let mut s = [0u8; 32];
                rng.fill_bytes(&mut s);
                seeds[i][j] = s;
                seeds[j][i] = s;
            }
        }
        let q = Quantizer::for_sum_of(bits, 1.0, n);
        let models: Vec<Vec<f32>> =
            (0..n).map(|_| (0..dim).map(|_| rng.normal_f32(0.0, 0.2)).collect()).collect();
        // each client masks
        let mut total = vec![0u64; dim];
        for i in 0..n {
            let mut masked = q.quantize(&models[i]);
            for j in 0..n {
                if j == i {
                    continue;
                }
                apply_mask(&mut masked, &seeds[i][j], &NONCE_PAIRWISE, bits, i > j);
            }
            add_assign(&mut total, &masked, bits);
        }
        // masks cancel: total == Σ quantized models
        let mut expect = vec![0u64; dim];
        for m in &models {
            add_assign(&mut expect, &q.quantize(m), bits);
        }
        assert_eq!(total, expect);
    }

    #[test]
    fn sum_error_bound_sane() {
        let q = Quantizer::for_sum_of(32, 4.0, 1000);
        // resolution fine enough for gradient sums
        assert!(q.sum_error_bound(1000) < 1e-2);
    }

    #[test]
    fn sixteen_bit_field_like_paper_table51() {
        let q = Quantizer::for_sum_of(16, 1.0, 10);
        let w = q.quantize_one(0.5);
        assert!(w < 1 << 16);
        let b = q.dequantize_one(w);
        assert!((b - 0.5).abs() < 1.0 / q.scale);
    }
}
