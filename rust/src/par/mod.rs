//! Dependency-free parallel execution layer (`std::thread::scope` only).
//!
//! The masking/unmasking hot path is embarrassingly parallel *by element*:
//! Z_{2^b} addition is elementwise, and the ChaCha20 PRG is counter-seekable
//! (`crypto::prg::apply_mask_range`), so a mask vector can be sharded into
//! disjoint contiguous slices and each worker can regenerate exactly the
//! keystream range its slice consumes. No atomics or locks touch the data:
//! every worker owns a disjoint `&mut` slice (enforced by `split_at_mut`),
//! and the result is bit-identical to the serial pass for *any* partition
//! because per-element operation order is unchanged.
//!
//! Thread count selection: explicit argument everywhere (config-selectable
//! by callers), with [`threads`] as the process-wide default — the
//! `CCESA_THREADS` environment variable if set, else the host parallelism.

use std::ops::Range;
use std::sync::OnceLock;

/// Process-wide default worker count: `CCESA_THREADS` if set to a positive
/// integer, else `std::thread::available_parallelism()`, else 1. Cached on
/// first use (the hot path asks per round).
pub fn threads() -> usize {
    static CACHE: OnceLock<usize> = OnceLock::new();
    *CACHE.get_or_init(|| {
        if let Ok(v) = std::env::var("CCESA_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                if n >= 1 {
                    return n;
                }
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// Minimum elements a worker should own before sharding is worth a thread
/// spawn: ~32 KiB of keystream at b ≤ 32 versus tens of µs of spawn+join.
/// Below this, the protocol paths run the serial (1-chunk) case — still
/// bit-identical, just without the spawn overhead the simulation suite's
/// tiny dims would otherwise pay.
pub const MIN_SHARD_LEN: usize = 8192;

/// Default worker count for an `len`-element vector: [`threads`] capped so
/// every worker owns at least [`MIN_SHARD_LEN`] elements (1 for short
/// vectors).
pub fn threads_for_len(len: usize) -> usize {
    threads().min(len / MIN_SHARD_LEN).max(1)
}

/// Deterministic partition of `0..len` into at most `max_chunks` contiguous,
/// disjoint, in-order ranges covering every index exactly once. The first
/// `len % k` chunks are one element longer (balanced to ±1). `len == 0`
/// yields no chunks.
pub fn partition(len: usize, max_chunks: usize) -> Vec<Range<usize>> {
    if len == 0 {
        return Vec::new();
    }
    let k = max_chunks.clamp(1, len);
    let base = len / k;
    let extra = len % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0;
    for i in 0..k {
        let size = base + usize::from(i < extra);
        out.push(start..start + size);
        start += size;
    }
    debug_assert_eq!(start, len);
    out
}

/// Run `f(offset, chunk)` over disjoint contiguous chunks of `data`, one
/// worker per chunk, at most `threads` workers. `offset` is the chunk's
/// start index in `data`, so counter-seekable consumers can resume streams
/// mid-vector. With one chunk (or `threads <= 1`) the closure runs inline
/// on the caller's thread — no spawn overhead on the serial path.
pub fn for_each_slice<T, F>(data: &mut [T], threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let ranges = partition(data.len(), threads);
    match ranges.len() {
        0 => {}
        1 => f(0, data),
        _ => {
            std::thread::scope(|s| {
                let mut rest = data;
                for r in &ranges {
                    let (head, tail) = std::mem::take(&mut rest).split_at_mut(r.len());
                    rest = tail;
                    let fref = &f;
                    let offset = r.start;
                    s.spawn(move || fref(offset, head));
                }
            });
        }
    }
}

/// Evaluate `f(0), …, f(n - 1)` on up to `threads` workers and return the
/// results in index order. Work is claimed dynamically (an atomic cursor —
/// scheduling only, the job results never race), and the output order is
/// fixed by index, so the result is deterministic for any interleaving.
pub fn map_indexed<R, F>(n: usize, threads: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let k = threads.clamp(1, n.max(1));
    if n == 0 {
        return Vec::new();
    }
    if k <= 1 {
        return (0..n).map(f).collect();
    }
    let cursor = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..k)
            .map(|_| {
                s.spawn(|| {
                    let mut local: Vec<(usize, R)> = Vec::new();
                    loop {
                        let i = cursor.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(i)));
                    }
                    local
                })
            })
            .collect();
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for h in handles {
            for (i, r) in h.join().expect("par worker panicked") {
                slots[i] = Some(r);
            }
        }
        slots.into_iter().map(|o| o.expect("par job not executed")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_disjoint_ordered() {
        for len in [0usize, 1, 2, 7, 256, 257, 600, 1000] {
            for k in [1usize, 2, 3, 4, 8, 64] {
                let ranges = partition(len, k);
                if len == 0 {
                    assert!(ranges.is_empty());
                    continue;
                }
                assert!(ranges.len() <= k && ranges.len() <= len);
                let mut next = 0;
                for r in &ranges {
                    assert_eq!(r.start, next, "len={len} k={k}");
                    assert!(!r.is_empty());
                    next = r.end;
                }
                assert_eq!(next, len);
                // balanced to ±1
                let sizes: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
                let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(mx - mn <= 1, "len={len} k={k} sizes={sizes:?}");
            }
        }
    }

    #[test]
    fn partition_is_deterministic() {
        assert_eq!(partition(600, 8), partition(600, 8));
        assert_eq!(partition(5, 2), vec![0..3, 3..5]);
    }

    #[test]
    fn for_each_slice_offsets_are_global() {
        for threads in [1usize, 2, 4, 8] {
            let mut data = vec![0usize; 601];
            for_each_slice(&mut data, threads, |offset, chunk| {
                for (i, x) in chunk.iter_mut().enumerate() {
                    *x = offset + i;
                }
            });
            let expect: Vec<usize> = (0..601).collect();
            assert_eq!(data, expect, "threads={threads}");
        }
    }

    #[test]
    fn for_each_slice_empty_and_tiny() {
        let mut empty: Vec<u8> = Vec::new();
        for_each_slice(&mut empty, 4, |_, _| panic!("must not run on empty input"));
        let mut one = vec![7u8];
        for_each_slice(&mut one, 4, |off, c| {
            assert_eq!(off, 0);
            c[0] += 1;
        });
        assert_eq!(one, vec![8]);
    }

    #[test]
    fn map_indexed_preserves_order() {
        for threads in [1usize, 2, 4, 8] {
            let out = map_indexed(37, threads, |i| i * i);
            let expect: Vec<usize> = (0..37).map(|i| i * i).collect();
            assert_eq!(out, expect, "threads={threads}");
        }
        assert!(map_indexed(0, 4, |i| i).is_empty());
    }

    #[test]
    fn threads_is_positive() {
        assert!(threads() >= 1);
    }

    #[test]
    fn threads_for_len_scales_with_work() {
        assert_eq!(threads_for_len(0), 1);
        assert_eq!(threads_for_len(MIN_SHARD_LEN - 1), 1);
        let big = threads_for_len(MIN_SHARD_LEN * 64);
        assert!(big >= 1 && big <= threads());
        // never more workers than MIN_SHARD_LEN-sized shards
        assert!(threads_for_len(MIN_SHARD_LEN * 2) <= 2);
    }
}
