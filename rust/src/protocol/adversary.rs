//! The eavesdropper of Definition 2 and the constructive privacy attack.
//!
//! The adversary can read *everything transmitted* between clients and the
//! server: advertised public keys, (encrypted) share ciphertexts, masked
//! models θ̃_i, the survivor set V3, and the Step-3 plaintext shares. It
//! cannot read client-local state (b_i, s_i^SK, plaintext models).
//!
//! The attack implements the converse direction of Theorem 2: if the
//! induced survivor graph G₃ is *disconnected* and some component C_l has
//! every node of C_l⁺ informative, the adversary reconstructs the partial
//! sum Σ_{i∈C_l} θ_i from the transcript alone — a privacy breach. If G₃
//! is connected (or every component has a non-informative closed-neighbor),
//! the attack provably cannot succeed; tests assert both directions.

use super::messages::ShareKind;
use super::ClientId;
use crate::crypto::dh::{self, PublicKey};
use crate::crypto::prg::{apply_mask, NONCE_PAIRWISE, NONCE_SELF};
use crate::graph::Graph;
use crate::shamir::{self, Share};
use std::collections::BTreeMap;

/// Everything the eavesdropper observed in one round.
#[derive(Debug, Clone)]
pub struct Transcript {
    pub n: usize,
    pub t: usize,
    pub mask_bits: u32,
    pub dim: usize,
    /// Length of the masked payload vectors on the wire: `dim` under the
    /// dense codec, k under a sparse one. The eavesdropper sees (and the
    /// attack recovers) packed vectors — the coordinate map is public
    /// derived knowledge either way.
    pub payload_len: usize,
    /// The assignment graph (public: implied by the key routing).
    pub graph: Graph,
    /// Advertised public keys.
    pub keys: BTreeMap<ClientId, (PublicKey, PublicKey)>,
    /// Senders of Step-1 uploads (V2 is observable on the wire).
    pub v2: Vec<ClientId>,
    /// The announced survivor set V3.
    pub v3: Vec<ClientId>,
    /// Masked models (i, θ̃_i) for i ∈ V3.
    pub masked: Vec<(ClientId, Vec<u64>)>,
    /// Step-3 plaintext shares: (holder, owner, kind, share).
    pub unmask_shares: Vec<(ClientId, ClientId, ShareKind, Share)>,
}

/// A successful partial-sum recovery: the client subset and the recovered
/// Σ_{i∈subset} θ_i (mod 2^b), in the wire (packed) payload domain.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Breach {
    pub subset: Vec<ClientId>,
    pub partial_sum: Vec<u64>,
}

fn in_sorted(set: &[ClientId], id: ClientId) -> bool {
    set.binary_search(&id).is_ok()
}

/// Run the Theorem-2-converse attack on a transcript. Returns every
/// breached proper subset of V3 (empty ⇒ the round was private against
/// this adversary).
pub fn attack(tr: &Transcript) -> Vec<Breach> {
    if tr.v3.len() < 2 {
        return Vec::new(); // no proper nonempty subset exists
    }
    // Collect shares by (owner, kind).
    let mut shares: BTreeMap<(ClientId, ShareKind), Vec<Share>> = BTreeMap::new();
    for (_, owner, kind, share) in &tr.unmask_shares {
        shares.entry((*owner, *kind)).or_default().push(share.clone());
    }
    let masked: BTreeMap<ClientId, &Vec<u64>> =
        tr.masked.iter().map(|(id, v)| (*id, v)).collect();

    // G3 and its components.
    let (g3, map) = tr.graph.induced(&tr.v3);
    let comps = g3.components();
    if comps.len() < 2 {
        return Vec::new(); // connected ⇒ Lemma 1 ⇒ private
    }

    let modmask = crate::util::mod_mask(tr.mask_bits);
    let mut breaches = Vec::new();

    'component: for comp in &comps {
        let subset: Vec<ClientId> = comp.iter().map(|&v| map[v]).collect();
        if subset.len() == tr.v3.len() {
            continue; // not a proper subset
        }
        // Accumulate Σ θ̃_i over the component (wire payload domain).
        let mut acc = vec![0u64; tr.payload_len];
        for &i in &subset {
            let Some(v) = masked.get(&i) else { continue 'component };
            for (a, x) in acc.iter_mut().zip(v.iter()) {
                *a = a.wrapping_add(*x) & modmask;
            }
        }
        // Cancel self masks: need b_i for every i in the component.
        for &i in &subset {
            let Some(sh) = shares.get(&(i, ShareKind::SelfMask)) else {
                continue 'component;
            };
            let Ok(b) = shamir::reconstruct(sh, tr.t, 32) else {
                continue 'component;
            };
            let b: [u8; 32] = b.try_into().unwrap();
            apply_mask(&mut acc, &b, &NONCE_SELF, tr.mask_bits, true);
        }
        // Cancel pairwise masks toward V2\V3 dropouts adjacent to the
        // component (within-component edges cancel algebraically; edges to
        // other components of G3 do not exist by definition).
        for &j in &tr.v2 {
            if in_sorted(&tr.v3, j) {
                continue;
            }
            let touching: Vec<ClientId> = tr
                .graph
                .neighbors(j)
                .iter()
                .copied()
                .filter(|&i| subset.contains(&i))
                .collect();
            if touching.is_empty() {
                continue;
            }
            let Some(sh) = shares.get(&(j, ShareKind::SecretKey)) else {
                continue 'component;
            };
            let Ok(skv) = shamir::reconstruct(sh, tr.t, 32) else {
                continue 'component;
            };
            let sk = crate::crypto::x25519::clamp_scalar(skv.try_into().unwrap());
            for &i in &touching {
                let Some((_, s_pk_i)) = tr.keys.get(&i) else { continue 'component };
                let seed = dh::agree_mask_seed(&sk, s_pk_i);
                // survivor i applied sign(i < j ? + : −); cancel it
                apply_mask(&mut acc, &seed, &NONCE_PAIRWISE, tr.mask_bits, i < j);
            }
        }
        breaches.push(Breach { subset, partial_sum: acc });
    }
    breaches
}

/// The Theorem-2 predicate from the adversary's viewpoint: is the round
/// private? (G ∈ G_C ∪ G_NI of the paper.)
pub fn theorem2_private(tr: &Transcript, v4: &[ClientId]) -> bool {
    let (g3, map) = tr.graph.induced(&tr.v3);
    if g3.is_connected() {
        return true;
    }
    // disconnected: private iff every component C_l has some node of C_l⁺
    // that is NOT informative (|（Adj(i)∪{i})∩V4| < t)
    let informative = |i: ClientId| {
        let mut cnt = tr
            .graph
            .neighbors(i)
            .iter()
            .filter(|&&j| in_sorted(v4, j))
            .count();
        if in_sorted(v4, i) {
            cnt += 1;
        }
        cnt >= tr.t
    };
    for comp in g3.components() {
        let c: Vec<ClientId> = comp.iter().map(|&v| map[v]).collect();
        // C_l⁺ = C_l ∪ {i ∈ V2 : Adj(i) ∩ C_l ≠ ∅}
        let mut c_plus = c.clone();
        for &i in &tr.v2 {
            if c.contains(&i) {
                continue;
            }
            if tr.graph.neighbors(i).iter().any(|&j| c.contains(&j)) {
                c_plus.push(i);
            }
        }
        if c_plus.iter().all(|&i| informative(i)) {
            return false; // fully informative component ⇒ breachable
        }
    }
    true
}

/// Appendix E's *unmasking attack* feasibility check for a malicious
/// server: with threshold t, the server can recover θ_i by requesting
/// b_i-shares from one set of t live share holders and s_i^SK-shares from
/// a *disjoint* set of t holders — possible iff client i has at least 2t
/// live holders (Prop. 1 ties this to the design rule for t).
pub fn unmasking_attack_feasible(
    graph: &Graph,
    v4: &[ClientId],
    t: usize,
    target: ClientId,
) -> bool {
    let mut holders = graph
        .neighbors(target)
        .iter()
        .filter(|&&j| in_sorted(v4, j))
        .count();
    if in_sorted(v4, target) {
        holders += 1;
    }
    holders >= 2 * t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::dropout::DropoutModel;
    use crate::protocol::engine::run_round;
    use crate::protocol::{ProtocolConfig, Topology};
    use crate::util::rng::Rng;

    fn models(n: usize, dim: usize, seed: u64) -> Vec<Vec<u64>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect())
            .collect()
    }

    #[test]
    fn connected_graph_resists_attack() {
        let n = 10;
        let cfg = ProtocolConfig::for_test(n, 4, 12, Topology::Complete, 31);
        let m = models(n, 12, 1);
        let r = run_round(&cfg, &m).unwrap();
        assert!(attack(&r.transcript).is_empty());
        assert!(theorem2_private(&r.transcript, &r.sets.v4));
    }

    #[test]
    fn disconnected_informative_graph_is_breached() {
        // two cliques {0..4} and {5..9} with no cross edges: G3 is
        // disconnected and every node informative (t=3 < clique size)
        let n = 10;
        let mut g = Graph::empty(n);
        for base in [0usize, 5] {
            for i in 0..5 {
                for j in (i + 1)..5 {
                    g.add_edge(base + i, base + j);
                }
            }
        }
        let cfg = ProtocolConfig::for_test(n, 3, 6, Topology::Custom(g), 77);
        let m = models(n, 6, 2);
        let r = run_round(&cfg, &m).unwrap();
        assert!(r.reliable, "both cliques are self-sufficient");
        assert!(!theorem2_private(&r.transcript, &r.sets.v4));

        let breaches = attack(&r.transcript);
        assert_eq!(breaches.len(), 2, "both components breached");
        // verify the recovered partial sums equal the true partial sums
        for b in &breaches {
            let mut expect = vec![0u64; 6];
            for &i in &b.subset {
                for (a, x) in expect.iter_mut().zip(&m[i]) {
                    *a = a.wrapping_add(*x) & 0xFFFF_FFFF;
                }
            }
            assert_eq!(b.partial_sum, expect, "subset {:?}", b.subset);
        }
    }

    #[test]
    fn breach_matches_theorem2_on_random_instances() {
        // empirical ⟺: attack succeeds exactly when Theorem 2 says the
        // system is NOT private
        let mut breached = 0;
        let mut private = 0;
        for seed in 0..60 {
            let n = 14;
            let cfg = ProtocolConfig {
                dropout: DropoutModel::Iid { q: 0.05 },
                ..ProtocolConfig::for_test(n, 2, 4, Topology::ErdosRenyi { p: 0.25 }, 9000 + seed)
            };
            let m = models(n, 4, seed);
            let Ok(r) = run_round(&cfg, &m) else { continue };
            let breaches = attack(&r.transcript);
            let t2 = theorem2_private(&r.transcript, &r.sets.v4);
            if t2 {
                assert!(
                    breaches.is_empty(),
                    "seed={seed}: theorem says private but attack succeeded"
                );
                private += 1;
            } else {
                assert!(
                    !breaches.is_empty(),
                    "seed={seed}: theorem says breachable but attack failed"
                );
                // verify correctness of at least one recovered sum
                let b = &breaches[0];
                let mut expect = vec![0u64; 4];
                for &i in &b.subset {
                    for (a, x) in expect.iter_mut().zip(&m[i]) {
                        *a = a.wrapping_add(*x) & 0xFFFF_FFFF;
                    }
                }
                assert_eq!(b.partial_sum, expect);
                breached += 1;
            }
        }
        // at p=0.22 on n=12 both outcomes must occur
        assert!(breached > 0, "no breaches observed — test not exercising converse");
        assert!(private > 0, "no private rounds observed");
    }

    #[test]
    fn dropped_neighbor_blocks_partial_sum_when_uninformative() {
        // two cliques bridged by node 10 that drops after step 1: the
        // bridge's s^SK shares are held only by its neighbors; with t
        // larger than surviving holders in one clique... simpler: check
        // theorem2_private consistency via the iff test above; here check
        // that a bridge node makes G3 connected and blocks the attack.
        let n = 11;
        let mut g = Graph::empty(n);
        for base in [0usize, 5] {
            for i in 0..5 {
                for j in (i + 1)..5 {
                    g.add_edge(base + i, base + j);
                }
            }
        }
        for i in 0..10 {
            g.add_edge(10, i); // bridge connects everything
        }
        let cfg = ProtocolConfig::for_test(n, 3, 4, Topology::Custom(g), 55);
        let m = models(n, 4, 3);
        let r = run_round(&cfg, &m).unwrap();
        // bridge alive: G3 connected, attack fails
        assert!(attack(&r.transcript).is_empty());
    }

    #[test]
    fn attack_recovers_packed_partial_sums_under_sparse_codec() {
        // two 5-cliques, RandK payload: the eavesdropper's recovered
        // partial sums live in the packed wire domain and equal the
        // encoded true partial sums coordinate for coordinate
        use crate::codec::Codec;
        let n = 10;
        let dim = 9;
        let k = 4;
        let mut g = Graph::empty(n);
        for base_id in [0usize, 5] {
            for i in 0..5 {
                for j in (i + 1)..5 {
                    g.add_edge(base_id + i, base_id + j);
                }
            }
        }
        let cfg = ProtocolConfig {
            codec: Codec::RandK { k },
            ..ProtocolConfig::for_test(n, 3, dim, Topology::Custom(g), 91)
        };
        let m = models(n, dim, 6);
        let r = run_round(&cfg, &m).unwrap();
        assert!(r.reliable);
        assert_eq!(r.transcript.payload_len, k);
        let plan = cfg.codec.plan(dim, cfg.mask_bits, cfg.seed, &m);
        let breaches = attack(&r.transcript);
        assert_eq!(breaches.len(), 2, "both components breached");
        for b in &breaches {
            let mut dense = vec![0u64; dim];
            for &i in &b.subset {
                for (a, x) in dense.iter_mut().zip(&m[i]) {
                    *a = a.wrapping_add(*x) & 0xFFFF_FFFF;
                }
            }
            assert_eq!(b.partial_sum, plan.encode(&dense, 32), "subset {:?}", b.subset);
        }
    }

    #[test]
    fn unmasking_attack_threshold() {
        let g = Graph::complete(9); // degree 8, +1 self = 9 holders
        let v4: Vec<ClientId> = (0..9).collect();
        assert!(unmasking_attack_feasible(&g, &v4, 4, 0)); // 9 ≥ 8
        assert!(!unmasking_attack_feasible(&g, &v4, 5, 0)); // 9 < 10
        // Remark 4's t ≈ (n−1)p/2 + O(√(n log n)) makes 2t > degree+1 w.h.p.
    }
}
