//! Server-side state machine: collection, routing, Shamir reconstruction
//! and mask cancellation (Eq. 4), with Theorem-1 reliability detection.
//!
//! The server is *honest-but-curious infrastructure* in the paper's model:
//! it routes ciphertexts it cannot read and learns only the aggregate. The
//! structural guard [`Server::finalize`] enforces that it never combines
//! `b_i` and `s_i^SK` shares for the same owner (the unmasking attack of
//! Appendix E is modeled separately in `protocol::adversary`).

use super::messages::*;
use super::{ClientId, SurvivorSets};
use crate::codec::IndexPlan;
use crate::crypto::dh::{self, PublicKey};
use crate::crypto::prg::{apply_mask_jobs_range, MaskJob};
use crate::graph::Graph;
use crate::shamir::{self, Share};
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Outcome of one aggregation round at the server.
#[derive(Debug, Clone)]
pub struct RoundOutput {
    /// Σ_{i∈V3} θ_i in Z_{2^b}, or `None` if the round is unreliable
    /// (Theorem 1 predicate violated — the server *detects* this).
    pub sum: Option<Vec<u64>>,
    /// True iff the server could cancel every mask.
    pub reliable: bool,
    pub sets: SurvivorSets,
}

/// Durability hook the server invokes at every state transition, *before*
/// applying the batch — journal-then-apply, so the log is never behind the
/// state a crash can lose. `crate::journal::JournalSink` is the production
/// implementation (append-only fsync'd record log); the trait lives here so
/// `protocol` never depends on `journal`.
///
/// The hooks receive borrowed batches in the exact order the step will
/// consume them; an implementation that persists them verbatim can replay
/// the round bit-identically (all server collections are `BTreeMap`s and
/// per-entry push order equals batch iteration order). A sink error aborts
/// the step — a round that cannot be made durable must not advance.
pub trait RoundSink: Send {
    /// Phase-0 batch: the advertisements `step0_route_keys` is about to
    /// consume.
    fn record_step0(&mut self, advs: &[AdvertiseKeys]) -> Result<()>;
    /// Warm-round phase-0 batch: the session resumes
    /// `warm_step0_resume` is about to consume.
    fn record_warm_step0(&mut self, resumes: &[WarmResume]) -> Result<()>;
    /// Phase-1 batch of share uploads.
    fn record_step1(&mut self, uploads: &[ShareUpload]) -> Result<()>;
    /// Phase-2 batch of masked inputs.
    fn record_step2(&mut self, inputs: &[MaskedInput]) -> Result<()>;
    /// The survivor announce computed by `step2_collect_masked` (recorded
    /// after the batch applied, as a replay cross-check).
    fn record_announce(&mut self, announce: &SurvivorAnnounce) -> Result<()>;
    /// Phase-3 batch of unmask responses.
    fn record_step3(&mut self, responses: &[UnmaskShares]) -> Result<()>;
    /// The packed accumulator Σ_{i∈V3} θ̃_i (masks still on) checkpointed
    /// at finalize entry — recovery recomputes and must match.
    fn record_checkpoint(&mut self, acc: &[u64]) -> Result<()>;
    /// The finished round output.
    fn record_final(&mut self, out: &RoundOutput) -> Result<()>;
}

/// Cross-round session state the server carries into a warm round.
///
/// Owned by `protocol::session::ServerSession` between rounds, moved into
/// the round's [`Server`] (and read back after it) so the wire transport
/// and journal recovery can rebuild a warm server from one record.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WarmCtx {
    /// Session round counter k ≥ 1 (the cold round is 0).
    pub round: u64,
    /// Per client: the last round it completed phase 1 (processed its
    /// session delta), 0 = the cold round. Key-update deltas cover every
    /// re-key after this.
    pub last_seen: Vec<u64>,
    /// Per client: the round its current key pairs were announced in,
    /// 0 = the cold round.
    pub rekeyed_at: Vec<u64>,
}

/// Server state across one round.
pub struct Server {
    n: usize,
    t: usize,
    mask_bits: u32,
    /// The round's shared payload plan: masked inputs arrive packed to
    /// `plan.len()` elements and the aggregate scatters back to
    /// `plan.dim()` at the end.
    plan: Arc<IndexPlan>,
    graph: Graph,
    /// advertised keys: id → (c_pk, s_pk)
    keys: BTreeMap<ClientId, (PublicKey, PublicKey)>,
    /// step-1 ciphertexts routed by recipient
    outbox: BTreeMap<ClientId, Vec<EncryptedShare>>,
    /// masked (packed) inputs by sender
    masked: BTreeMap<ClientId, Vec<u64>>,
    /// step-3 shares: (owner, kind) → shares received
    shares: BTreeMap<(ClientId, ShareKind), Vec<Share>>,
    sets: SurvivorSets,
    /// Optional durability sink (journal): consulted before each state
    /// transition. `None` (the default) costs nothing on the hot path.
    sink: Option<Box<dyn RoundSink>>,
    /// Warm-round session context; `None` on cold rounds.
    warm: Option<WarmCtx>,
}

impl Server {
    pub fn new(n: usize, t: usize, mask_bits: u32, plan: Arc<IndexPlan>, graph: Graph) -> Server {
        assert_eq!(graph.n(), n);
        Server {
            n,
            t,
            mask_bits,
            plan,
            graph,
            keys: BTreeMap::new(),
            outbox: BTreeMap::new(),
            masked: BTreeMap::new(),
            shares: BTreeMap::new(),
            sets: SurvivorSets::default(),
            sink: None,
            warm: None,
        }
    }

    /// Build a warm-round server: the session's cached public keys replace
    /// phase-0 advertisements, and `warm` carries the ratchet round plus
    /// the per-client delta clocks.
    pub fn new_warm(
        n: usize,
        t: usize,
        mask_bits: u32,
        plan: Arc<IndexPlan>,
        graph: Graph,
        keys: BTreeMap<ClientId, (PublicKey, PublicKey)>,
        warm: WarmCtx,
    ) -> Server {
        assert_eq!(warm.last_seen.len(), n);
        assert_eq!(warm.rekeyed_at.len(), n);
        assert!(warm.round >= 1, "warm rounds are numbered from 1");
        let mut s = Server::new(n, t, mask_bits, plan, graph);
        s.keys = keys;
        s.warm = Some(warm);
        s
    }

    /// The warm session context (updated in place as the round progresses),
    /// or `None` for a cold round.
    pub fn warm(&self) -> Option<&WarmCtx> {
        self.warm.as_ref()
    }

    /// Attach a durability sink; every subsequent step records its batch
    /// before applying it.
    pub fn set_sink(&mut self, sink: Box<dyn RoundSink>) {
        self.sink = Some(sink);
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn t(&self) -> usize {
        self.t
    }

    pub fn mask_bits(&self) -> u32 {
        self.mask_bits
    }

    pub fn plan(&self) -> &Arc<IndexPlan> {
        &self.plan
    }

    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    pub fn sets(&self) -> &SurvivorSets {
        &self.sets
    }

    /// Advertised public keys (the adversary model makes these public).
    pub fn advertised_keys(&self) -> &BTreeMap<ClientId, (PublicKey, PublicKey)> {
        &self.keys
    }

    /// **Step 0** — collect advertisements (their senders form V1) and
    /// build per-client key bundles restricted to Adj(j) ∩ V1.
    pub fn step0_route_keys(
        &mut self,
        advertisements: Vec<AdvertiseKeys>,
    ) -> Result<Vec<(ClientId, KeyBundle)>> {
        if let Some(sink) = self.sink.as_mut() {
            sink.record_step0(&advertisements)?;
        }
        for adv in advertisements {
            if adv.id >= self.n {
                bail!("advertisement from unknown client {}", adv.id);
            }
            self.keys.insert(adv.id, (adv.c_pk, adv.s_pk));
        }
        self.sets.v1 = self.keys.keys().copied().collect();
        if self.sets.v1.len() < self.t {
            bail!(
                "|V1|={} < t={}: not enough clients to continue",
                self.sets.v1.len(),
                self.t
            );
        }
        Ok(self
            .sets
            .v1
            .iter()
            .map(|&j| {
                let entries = self
                    .graph
                    .neighbors(j)
                    .iter()
                    .filter_map(|&i| self.keys.get(&i).map(|(c, s)| (i, *c, *s)))
                    .collect();
                (j, KeyBundle { entries })
            })
            .collect())
    }

    /// **Warm step 0** — collect session resumes (their senders form V1),
    /// apply announced re-keys, and build each survivor's session delta:
    /// the alive bitmap over its adjacency row plus replacement keys for
    /// every neighbor that re-keyed after the recipient last completed
    /// phase 1 (this round's re-keys included — `rekeyed_at` is bumped
    /// before the plans are assembled).
    pub fn warm_step0_resume(
        &mut self,
        resumes: Vec<WarmResume>,
    ) -> Result<Vec<(ClientId, WarmPlan)>> {
        if self.warm.is_none() {
            bail!("warm resume batch on a cold-round server");
        }
        if let Some(sink) = self.sink.as_mut() {
            sink.record_warm_step0(&resumes)?;
        }
        let round = self.warm.as_ref().unwrap().round;
        let mut batch = std::collections::BTreeSet::new();
        for wr in resumes {
            if wr.id >= self.n {
                bail!("warm resume from unknown client {}", wr.id);
            }
            // first message wins, like every other phase batch
            if !batch.insert(wr.id) {
                log::debug!("duplicate warm resume from client {} ignored", wr.id);
                continue;
            }
            if let Some((c_pk, s_pk)) = wr.rekey {
                self.keys.insert(wr.id, (c_pk, s_pk));
                self.warm.as_mut().unwrap().rekeyed_at[wr.id] = round;
            }
        }
        self.sets.v1 = batch.into_iter().collect();
        if self.sets.v1.len() < self.t {
            bail!(
                "|V1|={} < t={}: not enough clients to continue",
                self.sets.v1.len(),
                self.t
            );
        }
        let warm = self.warm.as_ref().unwrap();
        Ok(self
            .sets
            .v1
            .iter()
            .map(|&j| {
                let neigh = self.graph.neighbors(j);
                let mut alive_bitmap = vec![0u8; neigh.len().div_ceil(8)];
                for (b, &i) in neigh.iter().enumerate() {
                    if SurvivorSets::contains(&self.sets.v1, i) {
                        alive_bitmap[b / 8] |= 1u8 << (b % 8);
                    }
                }
                let keys = neigh
                    .iter()
                    .filter(|&&i| warm.rekeyed_at[i] > warm.last_seen[j])
                    .filter_map(|&i| self.keys.get(&i).map(|(c, s)| (i, *c, *s)))
                    .collect();
                (j, WarmPlan { to: j, alive_bitmap, keys })
            })
            .collect())
    }

    /// **Step 1** — collect encrypted-share uploads (senders form V2) and
    /// route each ciphertext to its recipient.
    pub fn step1_route_shares(
        &mut self,
        uploads: Vec<ShareUpload>,
    ) -> Result<Vec<(ClientId, ShareDelivery)>> {
        if let Some(sink) = self.sink.as_mut() {
            sink.record_step1(&uploads)?;
        }
        let mut batch = std::collections::BTreeSet::new();
        for up in uploads {
            if !SurvivorSets::contains(&self.sets.v1, up.from) {
                bail!("share upload from client {} not in V1", up.from);
            }
            // A replayed upload must not double-count toward |V2| ≥ t or
            // route its ciphertexts twice. First message wins; duplicates
            // are dropped without failing the round — wire retries and
            // duplicated frames are benign, not protocol violations.
            if SurvivorSets::contains(&self.sets.v2, up.from) || !batch.insert(up.from) {
                log::debug!("duplicate share upload from client {} ignored", up.from);
                continue;
            }
            for es in up.shares {
                if es.from != up.from {
                    bail!("spoofed share sender {} != {}", es.from, up.from);
                }
                self.outbox.entry(es.to).or_default().push(es);
            }
            self.sets.v2.push(up.from);
        }
        self.sets.v2.sort_unstable();
        if self.sets.v2.len() < self.t {
            bail!("|V2|={} < t={}", self.sets.v2.len(), self.t);
        }
        // V2 membership proves the client processed this round's session
        // delta (the plan precedes the upload), so its key-update clock
        // advances — a client that got the plan but never dealt is re-sent
        // the same (idempotent) delta on its next appearance.
        if let Some(warm) = self.warm.as_mut() {
            for &j in &self.sets.v2 {
                warm.last_seen[j] = warm.round;
            }
        }
        // deliver only to V2 members (others have dropped)
        let v2 = self.sets.v2.clone();
        Ok(v2
            .iter()
            .map(|&j| {
                let shares: Vec<EncryptedShare> = self
                    .outbox
                    .remove(&j)
                    .unwrap_or_default()
                    .into_iter()
                    .filter(|es| SurvivorSets::contains(&v2, es.from))
                    .collect();
                (j, ShareDelivery { to: j, shares })
            })
            .collect())
    }

    /// **Step 2** — collect masked inputs (senders form V3) and announce
    /// the survivor set.
    pub fn step2_collect_masked(
        &mut self,
        inputs: Vec<MaskedInput>,
    ) -> Result<SurvivorAnnounce> {
        if let Some(sink) = self.sink.as_mut() {
            sink.record_step2(&inputs)?;
        }
        for mi in inputs {
            if !SurvivorSets::contains(&self.sets.v2, mi.id) {
                bail!("masked input from client {} not in V2", mi.id);
            }
            // Idempotent dedupe: a replayed masked input must not inflate
            // |V3| or duplicate its id in the survivor announce (the
            // `masked` map would silently keep one copy, but v3 would not).
            // First message wins, across calls too.
            if self.masked.contains_key(&mi.id) {
                log::debug!("duplicate masked input from client {} ignored", mi.id);
                continue;
            }
            if mi.update.values.len() != self.plan.len() || mi.bits != self.mask_bits {
                bail!(
                    "masked input shape mismatch from {}: len={} bits={}",
                    mi.id,
                    mi.update.values.len(),
                    mi.bits
                );
            }
            // A client masking a different coordinate set than the round's
            // plan would silently corrupt the aggregate — misaligned windows
            // never cancel. Pointer equality is the hot path (all drivers
            // share one Arc); the structural compare catches byzantine or
            // handcrafted inputs.
            if !Arc::ptr_eq(&mi.update.plan, &self.plan) && *mi.update.plan != *self.plan {
                bail!("masked input from client {} encoded under a different index plan", mi.id);
            }
            self.masked.insert(mi.id, mi.update.values);
            self.sets.v3.push(mi.id);
        }
        self.sets.v3.sort_unstable();
        if self.sets.v3.len() < self.t {
            bail!("|V3|={} < t={}", self.sets.v3.len(), self.t);
        }
        let announce = SurvivorAnnounce { v3: self.sets.v3.clone() };
        if let Some(sink) = self.sink.as_mut() {
            sink.record_announce(&announce)?;
        }
        Ok(announce)
    }

    /// The packed accumulator Σ θ̃_i over every masked input received so
    /// far, masks still on — the pre-finalize checkpoint the journal
    /// records and recovery recomputes as an integrity cross-check. Serial
    /// on purpose: it runs once per round, only when a sink is attached.
    pub fn packed_accumulator(&self) -> Vec<u64> {
        let mask = crate::util::mod_mask(self.mask_bits);
        let mut acc = vec![0u64; self.plan.len()];
        for v in self.masked.values() {
            for (a, x) in acc.iter_mut().zip(v.iter()) {
                *a = a.wrapping_add(*x) & mask;
            }
        }
        acc
    }

    /// V3⁺ of Theorem 1: V3 plus the V2-neighbors of V3.
    pub fn v3_plus(graph: &Graph, v2: &[ClientId], v3: &[ClientId]) -> Vec<ClientId> {
        let mut out: Vec<ClientId> = v3.to_vec();
        for &i in v2 {
            if SurvivorSets::contains(v3, i) {
                continue;
            }
            if graph.neighbors(i).iter().any(|&j| SurvivorSets::contains(v3, j)) {
                out.push(i);
            }
        }
        out.sort_unstable();
        out
    }

    /// **Step 3** — collect unmasking shares (senders form V4), reconstruct
    /// the needed secrets, cancel masks per Eq. (4).
    ///
    /// §Perf: plan-then-execute. The method first *plans* — batch-
    /// reconstructs every needed secret ([`shamir::reconstruct_batch`]: one
    /// Lagrange basis per distinct holder set) and collects every mask-
    /// cancellation job (self masks for V3, pairwise seeds for V2∖V3
    /// dropouts adjacent to V3) — then *executes* one parallel pass where
    /// each worker owns a disjoint accumulator slice and applies every
    /// job's keystream range to it in one fused keystream-major walk
    /// (`prg::apply_mask_jobs_range` → `kernels::apply_masks_fused`: all
    /// jobs expand per accumulator block, so the slice is traversed once,
    /// not once per job). No atomics or locks: slices are disjoint, and
    /// the result is bit-identical to the serial pass because Z_{2^b}
    /// addition is elementwise and each element sees the same keystream
    /// words with the same signs. The Shamir reconstructions behind the
    /// jobs run on the dispatched GF(2^16) kernel backend
    /// (`kernels::selected`) — every backend is field-exact, so round
    /// outputs are backend-independent (the CI `kernel-matrix` job pins
    /// this).
    pub fn finalize(&mut self, responses: Vec<UnmaskShares>) -> Result<RoundOutput> {
        if self.sink.is_some() {
            // journal-then-apply, plus the pre-finalize accumulator
            // checkpoint recovery recomputes as an integrity cross-check
            let acc = self.packed_accumulator();
            let sink = self.sink.as_mut().unwrap();
            sink.record_step3(&responses)?;
            sink.record_checkpoint(&acc)?;
        }
        let out = self.finalize_inner(responses)?;
        if let Some(sink) = self.sink.as_mut() {
            sink.record_final(&out)?;
        }
        Ok(out)
    }

    fn finalize_inner(&mut self, responses: Vec<UnmaskShares>) -> Result<RoundOutput> {
        let mut batch = std::collections::BTreeSet::new();
        for resp in responses {
            if !SurvivorSets::contains(&self.sets.v3, resp.from) {
                bail!("unmask response from client {} not in V3", resp.from);
            }
            // Same first-wins dedupe as steps 1–2: a replayed unmask
            // response must not double-count toward |V4| ≥ t.
            if SurvivorSets::contains(&self.sets.v4, resp.from) || !batch.insert(resp.from) {
                log::debug!("duplicate unmask response from client {} ignored", resp.from);
                continue;
            }
            self.sets.v4.push(resp.from);
            for (owner, kind, share) in resp.shares {
                let entry = self.shares.entry((owner, kind)).or_default();
                // Dedupe by evaluation point: two shares at the same x for
                // one (owner, kind) reach `shamir::reconstruct_batch` as a
                // duplicate interpolation point and abort the whole
                // reconstruction. Honest responders drain in ascending id
                // order and x = holder id + 1, so arrivals are ascending
                // and the append fast path is O(1); the linear scan runs
                // only for out-of-order (or duplicated) points.
                match entry.last() {
                    Some(last) if share.x <= last.x => {
                        if entry.iter().any(|s| s.x == share.x) {
                            log::debug!(
                                "duplicate share x={} for owner {owner} ignored",
                                share.x
                            );
                        } else {
                            entry.push(share);
                        }
                    }
                    _ => entry.push(share),
                }
            }
        }
        self.sets.v4.sort_unstable();

        // Structural guard: refuse to hold both kinds for one owner.
        for &(owner, kind) in self.shares.keys() {
            let other = match kind {
                ShareKind::SelfMask => ShareKind::SecretKey,
                ShareKind::SecretKey => ShareKind::SelfMask,
            };
            if self.shares.contains_key(&(owner, other)) {
                bail!(
                    "protocol violation: both b and s^SK shares for owner {owner} \
                     (would enable the unmasking attack)"
                );
            }
        }

        let sets = self.sets.clone();
        if sets.v4.len() < self.t {
            return Ok(RoundOutput { sum: None, reliable: false, sets });
        }

        // ---- Plan: collect reconstruction jobs ---------------------------
        // Self masks: b_i for every i ∈ V3.
        let mut b_jobs: Vec<&[Share]> = Vec::with_capacity(sets.v3.len());
        for &i in &sets.v3 {
            let Some(shares) = self.shares.get(&(i, ShareKind::SelfMask)) else {
                return Ok(RoundOutput { sum: None, reliable: false, sets });
            };
            if shares.len() < self.t {
                return Ok(RoundOutput { sum: None, reliable: false, sets });
            }
            b_jobs.push(shares);
        }
        // Pairwise masks left by V2\V3 dropouts adjacent to V3: s_i^SK.
        let dropped: Vec<ClientId> = sets
            .v2
            .iter()
            .copied()
            .filter(|i| !SurvivorSets::contains(&sets.v3, *i))
            .collect();
        let mut sk_jobs: Vec<&[Share]> = Vec::new();
        let mut sk_owners: Vec<(ClientId, Vec<ClientId>)> = Vec::new();
        for &i in &dropped {
            let alive_neigh: Vec<ClientId> = self
                .graph
                .neighbors(i)
                .iter()
                .copied()
                .filter(|j| SurvivorSets::contains(&sets.v3, *j))
                .collect();
            if alive_neigh.is_empty() {
                continue; // i ∉ V3⁺: its masks never entered any θ̃
            }
            let Some(shares) = self.shares.get(&(i, ShareKind::SecretKey)) else {
                return Ok(RoundOutput { sum: None, reliable: false, sets });
            };
            if shares.len() < self.t {
                return Ok(RoundOutput { sum: None, reliable: false, sets });
            }
            sk_jobs.push(shares);
            sk_owners.push((i, alive_neigh));
        }

        // Batched Shamir: one Lagrange basis per distinct holder set,
        // reused across all owners and all 16 chunks of each 32-byte
        // secret. In the common no-dropout complete-graph round this is a
        // single O(t²) solve for the whole step instead of |V3| of them.
        let b_secrets = match shamir::reconstruct_batch(&b_jobs, self.t, 32) {
            Ok(batch) => batch.secrets,
            Err(_) => return Ok(RoundOutput { sum: None, reliable: false, sets }),
        };
        let sk_secrets = match shamir::reconstruct_batch(&sk_jobs, self.t, 32) {
            Ok(batch) => batch.secrets,
            Err(_) => return Ok(RoundOutput { sum: None, reliable: false, sets }),
        };

        // Mask-cancellation job list, in the exact order the serial path
        // applied them: V3 self masks (ascending id), then per dropped
        // owner its surviving neighbors' pairwise seeds.
        let mut jobs: Vec<MaskJob> = Vec::with_capacity(b_secrets.len());
        for b in b_secrets {
            // A malformed (short-y) share set reconstructs to the wrong
            // length; treat it as an unreliable round, not a panic.
            let Ok(seed) = <[u8; 32]>::try_from(b) else {
                return Ok(RoundOutput { sum: None, reliable: false, sets });
            };
            jobs.push(MaskJob { seed, pairwise: false, negate: true });
        }
        for ((i, alive_neigh), skv) in sk_owners.iter().zip(sk_secrets) {
            let Ok(sk) = <[u8; 32]>::try_from(skv) else {
                return Ok(RoundOutput { sum: None, reliable: false, sets });
            };
            let sk = crate::crypto::x25519::clamp_scalar(sk);
            for &j in alive_neigh {
                let Some((_, s_pk_j)) = self.keys.get(&j) else {
                    return Ok(RoundOutput { sum: None, reliable: false, sets });
                };
                let base = dh::agree_mask_seed(&sk, s_pk_j);
                // Warm rounds mask with the round-k ratchet of the pairwise
                // base, so cancellation ratchets identically.
                let seed = match &self.warm {
                    Some(w) => crate::crypto::prg::ratchet_seed(&base, w.round),
                    None => base,
                };
                // The survivor j applied sign(j<i ? + : −); cancel it.
                jobs.push(MaskJob { seed, pairwise: true, negate: j < *i });
            }
        }

        // ---- Execute: one parallel pass over disjoint accumulator slices
        // of the *packed* domain (= the dense vector under the identity
        // plan). Each worker sums the masked inputs over its slice, then
        // applies every job's keystream range at the slice's offset — the
        // shared plan guarantees position p means the same dense coordinate
        // in every input and every mask stream.
        let mask = crate::util::mod_mask(self.mask_bits);
        let bits = self.mask_bits;
        let masked: Vec<&Vec<u64>> = self.masked.values().collect();
        let mut acc = vec![0u64; self.plan.len()];
        let workers = crate::par::threads_for_len(acc.len());
        crate::par::for_each_slice(&mut acc, workers, |offset, slice| {
            let n = slice.len();
            for v in &masked {
                for (a, x) in slice.iter_mut().zip(v[offset..offset + n].iter()) {
                    *a = a.wrapping_add(*x) & mask;
                }
            }
            apply_mask_jobs_range(slice, &jobs, bits, offset);
        });

        // Lift the packed aggregate back to the dense domain (identity plan:
        // a straight copy) so callers always see a dim-length sum.
        Ok(RoundOutput { sum: Some(self.plan.scatter(&acc)), reliable: true, sets })
    }
}

/// The Theorem-1 predicate, evaluated from the graph and survivor sets:
/// the round is reliable iff every i ∈ V3⁺ is informative, i.e.
/// |(Adj(i) ∪ {i}) ∩ V4| ≥ t.
pub fn theorem1_predicate(graph: &Graph, sets: &SurvivorSets, t: usize) -> bool {
    let v3p = Server::v3_plus(graph, &sets.v2, &sets.v3);
    v3p.iter().all(|&i| {
        let mut holders = graph
            .neighbors(i)
            .iter()
            .filter(|&&j| SurvivorSets::contains(&sets.v4, j))
            .count();
        if SurvivorSets::contains(&sets.v4, i) {
            holders += 1;
        }
        holders >= t
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    #[test]
    fn v3_plus_includes_dropped_neighbors_of_survivors() {
        // path 0-1-2, plus isolated 3; v2 = all, v3 = {0, 2}
        let mut g = Graph::empty(4);
        g.add_edge(0, 1);
        g.add_edge(1, 2);
        let v2 = vec![0, 1, 2, 3];
        let v3 = vec![0, 2];
        let v3p = Server::v3_plus(&g, &v2, &v3);
        assert_eq!(v3p, vec![0, 1, 2]); // 1 is a V2-neighbor of V3; 3 is not
    }

    #[test]
    fn theorem1_predicate_cases() {
        let g = Graph::complete(4);
        let full = SurvivorSets {
            v1: vec![0, 1, 2, 3],
            v2: vec![0, 1, 2, 3],
            v3: vec![0, 1, 2, 3],
            v4: vec![0, 1, 2, 3],
        };
        assert!(theorem1_predicate(&g, &full, 3));
        // only 2 respond in step 3 → not informative for t=3
        let thin = SurvivorSets { v4: vec![0, 1], ..full.clone() };
        assert!(!theorem1_predicate(&g, &thin, 3));
        // exactly t respond
        let edge = SurvivorSets { v4: vec![0, 1, 2], ..full };
        assert!(theorem1_predicate(&g, &edge, 3));
    }

    #[test]
    fn server_rejects_protocol_violations() {
        let g = Graph::complete(3);
        let mut s = Server::new(3, 2, 32, IndexPlan::identity(4), g);
        // unknown client id
        assert!(s
            .step0_route_keys(vec![AdvertiseKeys { id: 9, c_pk: [0; 32], s_pk: [0; 32] }])
            .is_err());
        // below threshold
        let mut s2 = Server::new(3, 3, 32, IndexPlan::identity(4), Graph::complete(3));
        assert!(s2
            .step0_route_keys(vec![AdvertiseKeys { id: 0, c_pk: [0; 32], s_pk: [0; 32] }])
            .is_err());
    }

    #[test]
    fn unmasking_attack_guard_trips() {
        let g = Graph::complete(3);
        let plan = IndexPlan::identity(1);
        let mut s = Server::new(3, 1, 32, plan.clone(), g);
        let advs = (0..3)
            .map(|id| AdvertiseKeys { id, c_pk: [id as u8; 32], s_pk: [id as u8; 32] })
            .collect();
        let _ = s.step0_route_keys(advs).unwrap();
        let _ = s
            .step1_route_shares(
                (0..3).map(|id| ShareUpload { from: id, shares: vec![] }).collect(),
            )
            .unwrap();
        let _ = s
            .step2_collect_masked(
                (0..3)
                    .map(|id| MaskedInput {
                        id,
                        update: crate::codec::EncodedUpdate {
                            values: vec![0],
                            plan: plan.clone(),
                        },
                        bits: 32,
                    })
                    .collect(),
            )
            .unwrap();
        // malicious: both kinds for owner 0
        let sh = Share { x: 1, y: vec![0; 16] };
        let bad = vec![UnmaskShares {
            from: 0,
            shares: vec![
                (0, ShareKind::SelfMask, sh.clone()),
                (0, ShareKind::SecretKey, sh),
            ],
        }];
        assert!(s.finalize(bad).is_err());
    }

    /// Drive a server through steps 0–2 with n clients, empty share
    /// uploads and zero masked inputs — the minimal honest transcript the
    /// duplicate-message regressions replay against.
    fn primed_server(n: usize, t: usize) -> (Server, Arc<IndexPlan>) {
        let plan = IndexPlan::identity(1);
        let mut s = Server::new(n, t, 32, plan.clone(), Graph::complete(n));
        let advs = (0..n)
            .map(|id| AdvertiseKeys { id, c_pk: [id as u8; 32], s_pk: [id as u8; 32] })
            .collect();
        s.step0_route_keys(advs).unwrap();
        (s, plan)
    }

    fn masked_zero(id: ClientId, plan: &Arc<IndexPlan>) -> MaskedInput {
        MaskedInput {
            id,
            update: crate::codec::EncodedUpdate { values: vec![0], plan: plan.clone() },
            bits: 32,
        }
    }

    #[test]
    fn duplicate_share_uploads_count_once() {
        let (mut s, _) = primed_server(3, 3);
        let ct = EncryptedShare { from: 0, to: 1, ciphertext: vec![9; 8] };
        let up0 = ShareUpload { from: 0, shares: vec![ct] };
        // client 0's upload arrives twice in one batch (retry / replay):
        // without dedupe |V2| = 4 ≥ t even though only 3 clients uploaded,
        // and client 1 would be delivered 0's ciphertext twice
        let uploads = vec![
            up0.clone(),
            up0,
            ShareUpload { from: 1, shares: vec![] },
            ShareUpload { from: 2, shares: vec![] },
        ];
        let deliveries = s.step1_route_shares(uploads).unwrap();
        assert_eq!(s.sets().v2, vec![0, 1, 2]);
        let to_1 = deliveries.iter().find(|(id, _)| *id == 1).unwrap();
        assert_eq!(to_1.1.shares.len(), 1, "replayed ciphertext routed twice");
    }

    #[test]
    fn duplicate_masked_inputs_count_once() {
        let (mut s, plan) = primed_server(3, 3);
        s.step1_route_shares((0..3).map(|id| ShareUpload { from: id, shares: vec![] }).collect())
            .unwrap();
        let inputs = vec![
            masked_zero(0, &plan),
            masked_zero(1, &plan),
            masked_zero(0, &plan), // replay
            masked_zero(2, &plan),
        ];
        let announce = s.step2_collect_masked(inputs).unwrap();
        assert_eq!(announce.v3, vec![0, 1, 2], "duplicate id in SurvivorAnnounce");
        // replay across calls is equally idempotent
        let announce2 = s.step2_collect_masked(vec![masked_zero(1, &plan)]).unwrap();
        assert_eq!(announce2.v3, vec![0, 1, 2]);
    }

    #[test]
    fn replayed_unmask_shares_are_deduped() {
        // Two servers over the same transcript; one sees every Step-3
        // message twice plus an in-message duplicate share. Before the
        // dedupe fixes the replay double-counted |V4| and fed
        // `reconstruct_batch` duplicate evaluation points (x collision →
        // the whole round degraded to unreliable).
        let run = |duplicate: bool| {
            let (mut s, plan) = primed_server(3, 1);
            s.step1_route_shares(
                (0..3).map(|id| ShareUpload { from: id, shares: vec![] }).collect(),
            )
            .unwrap();
            s.step2_collect_masked((0..3).map(|id| masked_zero(id, &plan)).collect()).unwrap();
            let resp = |from: ClientId| UnmaskShares {
                from,
                shares: vec![(from, ShareKind::SelfMask, Share { x: 1, y: vec![0; 16] })],
            };
            let mut responses: Vec<UnmaskShares> = (0..3).map(resp).collect();
            if duplicate {
                // replay every message, and double one share in-message
                responses.extend((0..3).map(resp));
                responses[0]
                    .shares
                    .push((0, ShareKind::SelfMask, Share { x: 1, y: vec![0; 16] }));
            }
            s.finalize(responses).unwrap()
        };
        let clean = run(false);
        let replayed = run(true);
        assert!(clean.reliable);
        assert!(replayed.reliable, "duplicate shares degraded reconstruction");
        assert_eq!(replayed.sets.v4, vec![0, 1, 2], "|V4| inflated by replay");
        assert_eq!(clean.sum, replayed.sum);
        assert_eq!(clean.sets, replayed.sets);
    }

    #[test]
    fn warm_step0_builds_alive_bitmaps_and_key_deltas() {
        // path 0-1-2; client 2 absent this round; client 0 re-keys now;
        // client 1 last completed phase 1 at round 2, client 0 at round 1
        let mut g = Graph::empty(3);
        g.add_edge(0, 1);
        g.add_edge(1, 2);
        let keys: BTreeMap<_, _> =
            (0..3).map(|id| (id, ([id as u8; 32], [0x40 | id as u8; 32]))).collect();
        let warm = WarmCtx { round: 3, last_seen: vec![1, 2, 2], rekeyed_at: vec![0, 2, 0] };
        let mut s = Server::new_warm(3, 2, 32, IndexPlan::identity(4), g, keys, warm);
        let resumes = vec![
            WarmResume { id: 0, support: None, rekey: Some(([9; 32], [10; 32])) },
            WarmResume { id: 1, support: None, rekey: None },
        ];
        let plans = s.warm_step0_resume(resumes).unwrap();
        assert_eq!(s.sets().v1, vec![0, 1]);
        assert_eq!(s.advertised_keys()[&0], ([9; 32], [10; 32]), "re-key applied");
        assert_eq!(s.warm().unwrap().rekeyed_at, vec![3, 2, 0]);

        // client 0: neighbor 1 alive; 1 re-keyed at round 2 > last_seen[0]=1
        let p0 = &plans.iter().find(|(id, _)| *id == 0).unwrap().1;
        assert_eq!(p0.alive_bitmap, vec![0x01]);
        assert_eq!(p0.keys, vec![(1, [1; 32], [0x41; 32])]);
        // client 1: neighbors [0, 2] → bit 0 alive only; 0's re-key (this
        // round) is in the delta, absent 2's cold keys are not
        let p1 = &plans.iter().find(|(id, _)| *id == 1).unwrap().1;
        assert_eq!(p1.alive_bitmap, vec![0x01]);
        assert_eq!(p1.keys, vec![(0, [9; 32], [10; 32])]);

        // V2 membership advances the delta clock
        s.step1_route_shares(vec![
            ShareUpload { from: 0, shares: vec![] },
            ShareUpload { from: 1, shares: vec![] },
        ])
        .unwrap();
        assert_eq!(s.warm().unwrap().last_seen, vec![3, 3, 2]);
    }

    #[test]
    fn server_rejects_misaligned_index_plan() {
        // a client masking a different coordinate set than the round's plan
        // must be refused: misaligned windows would never cancel
        let plan = IndexPlan::sparse(vec![0, 2], 4);
        let mut s = Server::new(3, 1, 32, plan, Graph::complete(3));
        let advs = (0..3)
            .map(|id| AdvertiseKeys { id, c_pk: [1; 32], s_pk: [2; 32] })
            .collect();
        s.step0_route_keys(advs).unwrap();
        s.step1_route_shares((0..3).map(|id| ShareUpload { from: id, shares: vec![] }).collect())
            .unwrap();
        // same payload length, different support
        let rogue = MaskedInput {
            id: 0,
            update: crate::codec::EncodedUpdate {
                values: vec![0, 0],
                plan: IndexPlan::sparse(vec![1, 3], 4),
            },
            bits: 32,
        };
        assert!(s.step2_collect_masked(vec![rogue]).is_err());
    }
}
