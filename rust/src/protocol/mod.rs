//! The CCESA / SA secure-aggregation protocol (Algorithm 1 of the paper).
//!
//! Module layout:
//! * [`messages`] — wire messages with exact byte sizes, plus the
//!   [`messages::Up`]/[`messages::Down`] phase envelopes both deployment
//!   shapes exchange;
//! * [`client`] — the client state machine (Steps 0–3), and
//!   [`client::ClientSm`], its explicit poll-able `step(Down) -> Up` form
//!   multiplexed by `crate::coordinator`;
//! * [`server`] — the server state machine: collection, Shamir
//!   reconstruction, mask cancellation (Eq. 4), Theorem-1 reliability
//!   detection;
//! * [`engine`] — single-round synchronous driver wiring n clients and the
//!   server through the byte-accounted simnet with dropout injection;
//! * [`dropout`] — dropout models (i.i.d. per-step q, targeted, none);
//! * [`adversary`] — the eavesdropper of Definition 2 and the constructive
//!   privacy attack from the converse of Theorem 2.
//!
//! SA (Bonawitz et al. 2017) is obtained with [`Topology::Complete`]; the
//! paper's scheme with [`Topology::ErdosRenyi`].

pub mod adversary;
pub mod client;
pub mod dropout;
pub mod engine;
pub mod messages;
pub mod server;
pub mod session;

use crate::codec::Codec;
use crate::graph::Graph;
use crate::util::rng::Rng;
use anyhow::{bail, Result};

/// Client identifier: index in 0..n.
pub type ClientId = usize;

/// Assignment-graph family.
#[derive(Debug, Clone)]
pub enum Topology {
    /// Complete graph — conventional SA.
    Complete,
    /// Erdős–Rényi G(n, p) — the paper's CCESA.
    ErdosRenyi { p: f64 },
    /// Harary H_{k,n} — Bell et al. 2020 comparison.
    Harary { k: usize },
    /// Explicit graph (tests, ablations).
    Custom(Graph),
    /// Two-level sharded aggregation: clients run the flat protocol inside
    /// `shards` contiguous shards (each on its own `intra` graph and
    /// mask-seed domain), then the shard aggregators rerun it over the
    /// shard sums on the `root` graph. Driven by `crate::hier::HierRunner`;
    /// the flat engine/coordinator reject it by name.
    Hierarchical { shards: usize, intra: Box<Topology>, root: Box<Topology> },
}

impl Topology {
    /// Materialize the assignment graph (deterministic in `rng`).
    ///
    /// Panics on [`Topology::Hierarchical`]: a two-level topology has no
    /// single flat graph — per-level graphs are built by
    /// `crate::hier::ShardPlan` from the `intra`/`root` families.
    pub fn build(&self, n: usize, rng: &mut Rng) -> Graph {
        match self {
            Topology::Complete => Graph::complete(n),
            Topology::ErdosRenyi { p } => Graph::erdos_renyi(n, *p, rng),
            Topology::Harary { k } => Graph::harary(n, *k),
            Topology::Custom(g) => {
                assert_eq!(g.n(), n, "custom topology size mismatch");
                g.clone()
            }
            Topology::Hierarchical { .. } => {
                panic!("Topology::Hierarchical has no flat graph; use hier::HierRunner")
            }
        }
    }

    /// True for the [`Topology::Hierarchical`] arm — the one family the
    /// flat drivers must refuse (they'd otherwise build a nonsense graph).
    pub fn is_hierarchical(&self) -> bool {
        matches!(self, Topology::Hierarchical { .. })
    }
}

/// Validate one *flat* topology family against a population of `n` nodes.
/// Shared by the builder's top-level check and the per-level checks of the
/// `Hierarchical` arm (`ctx` names the level in error messages).
fn validate_flat_topology(topology: &Topology, n: usize, ctx: &str) -> Result<()> {
    match topology {
        Topology::ErdosRenyi { p } => {
            if !p.is_finite() || !(0.0..=1.0).contains(p) {
                bail!("ProtocolConfig: {ctx} Erdős–Rényi p={p} must be in [0, 1]");
            }
        }
        Topology::Harary { k } => {
            if *k >= n {
                bail!("ProtocolConfig: {ctx} Harary degree k={k} must be < n={n}");
            }
        }
        Topology::Complete => {}
        Topology::Custom(g) => {
            if g.n() != n {
                bail!("ProtocolConfig: {ctx} custom topology has {} nodes, expected n={n}", g.n());
            }
        }
        Topology::Hierarchical { .. } => {
            bail!("ProtocolConfig: {ctx} nested Hierarchical topologies are not supported");
        }
    }
    Ok(())
}

/// Static protocol parameters for one aggregation round.
///
/// Construct with [`ProtocolConfig::builder`], which validates every knob
/// at construction time (threshold vs population, codec k vs dimension,
/// topology parameters, mask width) instead of surfacing nonsense as a
/// mid-round panic. Fields stay public for inspection and struct-update in
/// tests; the builder is the only construction surface.
#[derive(Debug, Clone)]
pub struct ProtocolConfig {
    /// Number of clients n.
    pub n: usize,
    /// Secret-sharing threshold t (same for all clients; Remark 4 gives the
    /// design rule — see `analysis::bounds::t_rule`).
    pub t: usize,
    /// Masked-domain width b: aggregation in Z_{2^b}.
    pub mask_bits: u32,
    /// Model dimension m.
    pub dim: usize,
    /// Assignment-graph family.
    pub topology: Topology,
    /// Dropout model applied per step.
    pub dropout: dropout::DropoutModel,
    /// Payload codec: which coordinates of the dense update travel (and
    /// get masked) this round. [`Codec::Dense`] is the pre-codec protocol.
    pub codec: Codec,
    /// Master seed (graph, keys, shares, dropout — and the RandK index
    /// plan — all derive from it).
    pub seed: u64,
}

impl ProtocolConfig {
    /// Start a validated configuration:
    /// `ProtocolConfig::builder().clients(n).threshold(t).model_dim(d)
    /// .topology(..).codec(..).seed(..).build()?`.
    pub fn builder() -> ProtocolConfigBuilder {
        ProtocolConfigBuilder::default()
    }

    /// Unit-test shorthand for the common (n, t, dim, topology, seed)
    /// shape — one definition instead of a builder chain per test module.
    /// Panics on invalid parameters; production code goes through
    /// [`ProtocolConfig::builder`].
    #[cfg(test)]
    pub(crate) fn for_test(
        n: usize,
        t: usize,
        dim: usize,
        topology: Topology,
        seed: u64,
    ) -> ProtocolConfig {
        ProtocolConfig::builder()
            .clients(n)
            .threshold(t)
            .model_dim(dim)
            .topology(topology)
            .seed(seed)
            .build()
            .expect("test config must be valid")
    }

    /// Materialize the assignment graph from an explicit RNG — the single
    /// construction point shared by the sync engine and the threaded
    /// coordinator, so the two drivers can never diverge on topology.
    pub fn build_graph_with(&self, rng: &mut crate::util::rng::Rng) -> Graph {
        self.topology.build(self.n, rng)
    }

    /// Replay helper: the exact graph a round under this config runs on.
    /// Both drivers derive their graph from the first draws of
    /// `Rng::new(seed)`, so external observers (the `sim` scenario compiler,
    /// adaptive churn models, shrinker reports) can reconstruct it without
    /// running the round.
    pub fn build_graph(&self) -> Graph {
        self.build_graph_with(&mut crate::util::rng::Rng::new(self.seed))
    }
}

/// Typed builder for [`ProtocolConfig`]: `clients`, `threshold` and
/// `model_dim` are required; topology defaults to [`Topology::Complete`],
/// the codec to [`Codec::Dense`], `mask_bits` to 32, dropout to none and
/// the seed to 0. [`ProtocolConfigBuilder::build`] validates the whole
/// combination and is the only way errors surface — a successfully built
/// config never fails a round on a *static* parameter.
#[derive(Debug, Clone, Default)]
pub struct ProtocolConfigBuilder {
    n: Option<usize>,
    t: Option<usize>,
    dim: Option<usize>,
    mask_bits: Option<u32>,
    topology: Option<Topology>,
    dropout: Option<dropout::DropoutModel>,
    codec: Option<Codec>,
    seed: u64,
}

impl ProtocolConfigBuilder {
    /// Population size n (required).
    pub fn clients(mut self, n: usize) -> Self {
        self.n = Some(n);
        self
    }

    /// Secret-sharing threshold t (required; 1 ≤ t ≤ n).
    pub fn threshold(mut self, t: usize) -> Self {
        self.t = Some(t);
        self
    }

    /// Model dimension m (required; 0 is allowed with [`Codec::Dense`]).
    pub fn model_dim(mut self, dim: usize) -> Self {
        self.dim = Some(dim);
        self
    }

    /// Aggregation-domain width b ∈ 1..=64 (default 32).
    pub fn mask_bits(mut self, bits: u32) -> Self {
        self.mask_bits = Some(bits);
        self
    }

    /// Assignment-graph family (default [`Topology::Complete`]).
    pub fn topology(mut self, topology: Topology) -> Self {
        self.topology = Some(topology);
        self
    }

    /// Dropout model (default [`dropout::DropoutModel::None`]).
    pub fn dropout(mut self, dropout: dropout::DropoutModel) -> Self {
        self.dropout = Some(dropout);
        self
    }

    /// Payload codec (default [`Codec::Dense`]).
    pub fn codec(mut self, codec: Codec) -> Self {
        self.codec = Some(codec);
        self
    }

    /// Master seed (default 0).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Validate and produce the config.
    pub fn build(self) -> Result<ProtocolConfig> {
        let Some(n) = self.n else {
            bail!("ProtocolConfig: clients(n) is required");
        };
        let Some(t) = self.t else {
            bail!("ProtocolConfig: threshold(t) is required");
        };
        let Some(dim) = self.dim else {
            bail!("ProtocolConfig: model_dim(d) is required");
        };
        if n == 0 {
            bail!("ProtocolConfig: n must be ≥ 1");
        }
        if t == 0 || t > n {
            bail!("ProtocolConfig: threshold t={t} must satisfy 1 ≤ t ≤ n={n}");
        }
        let mask_bits = self.mask_bits.unwrap_or(32);
        if !(1..=64).contains(&mask_bits) {
            bail!("ProtocolConfig: mask_bits={mask_bits} must be in 1..=64");
        }
        let topology = self.topology.unwrap_or(Topology::Complete);
        if let Topology::Hierarchical { shards, intra, root } = &topology {
            let shards = *shards;
            if shards == 0 {
                bail!("ProtocolConfig: hierarchical shards must be ≥ 1");
            }
            if shards > n {
                bail!("ProtocolConfig: hierarchical shards={shards} must be ≤ n={n}");
            }
            // Contiguous partition: the first n % shards shards get one
            // extra client, so the *smallest* shard holds n / shards. Every
            // shard runs the flat protocol at threshold t, and a shard that
            // cannot lose even one client (m ≤ t) would abort on any churn —
            // reject the footgun at build time.
            let min_shard = n / shards;
            if min_shard < t + 1 {
                bail!(
                    "ProtocolConfig: hierarchical shard size n/shards = {min_shard} \
                     must be ≥ t+1 = {} (shrink t or use fewer shards)",
                    t + 1
                );
            }
            validate_flat_topology(intra, min_shard, "intra-shard")?;
            if let Topology::Custom(_) = **intra {
                if n % shards != 0 {
                    bail!(
                        "ProtocolConfig: custom intra-shard topology requires uniform \
                         shard sizes (n={n} is not divisible by shards={shards})"
                    );
                }
            }
            validate_flat_topology(root, shards, "root-level")?;
        } else {
            validate_flat_topology(&topology, n, "flat")?;
        }
        let codec = self.codec.unwrap_or(Codec::Dense);
        match codec {
            Codec::Dense => {}
            Codec::TopK { k } | Codec::RandK { k } => {
                if k == 0 || k > dim {
                    bail!(
                        "ProtocolConfig: {} k={k} must satisfy 1 ≤ k ≤ dim={dim}",
                        codec.name()
                    );
                }
            }
        }
        Ok(ProtocolConfig {
            n,
            t,
            mask_bits,
            dim,
            topology,
            dropout: self.dropout.unwrap_or(dropout::DropoutModel::None),
            codec,
            seed: self.seed,
        })
    }
}

/// The surviving client sets after each step (paper notation V1 ⊇ … ⊇ V4).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SurvivorSets {
    pub v1: Vec<ClientId>,
    pub v2: Vec<ClientId>,
    pub v3: Vec<ClientId>,
    pub v4: Vec<ClientId>,
}

impl SurvivorSets {
    pub fn contains(set: &[ClientId], id: ClientId) -> bool {
        set.binary_search(&id).is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_and_required_fields() {
        let cfg = ProtocolConfig::builder()
            .clients(8)
            .threshold(4)
            .model_dim(16)
            .seed(7)
            .build()
            .unwrap();
        assert_eq!(cfg.n, 8);
        assert_eq!(cfg.t, 4);
        assert_eq!(cfg.dim, 16);
        assert_eq!(cfg.mask_bits, 32);
        assert_eq!(cfg.seed, 7);
        assert!(matches!(cfg.topology, Topology::Complete));
        assert!(matches!(cfg.dropout, dropout::DropoutModel::None));
        assert_eq!(cfg.codec, Codec::Dense);

        assert!(ProtocolConfig::builder().threshold(2).model_dim(4).build().is_err());
        assert!(ProtocolConfig::builder().clients(4).model_dim(4).build().is_err());
        assert!(ProtocolConfig::builder().clients(4).threshold(2).build().is_err());
    }

    #[test]
    fn builder_rejects_static_nonsense() {
        let base = || ProtocolConfig::builder().clients(6).threshold(3).model_dim(10);
        assert!(base().build().is_ok());
        // threshold out of range
        assert!(base().threshold(0).build().is_err());
        assert!(base().threshold(7).build().is_err());
        // mask width out of range
        assert!(base().mask_bits(0).build().is_err());
        assert!(base().mask_bits(65).build().is_err());
        assert!(base().mask_bits(64).build().is_ok());
        // topology parameters
        assert!(base().topology(Topology::ErdosRenyi { p: 1.5 }).build().is_err());
        assert!(base().topology(Topology::ErdosRenyi { p: f64::NAN }).build().is_err());
        assert!(base().topology(Topology::Harary { k: 6 }).build().is_err());
        assert!(base().topology(Topology::Harary { k: 4 }).build().is_ok());
        assert!(base()
            .topology(Topology::Custom(crate::graph::Graph::complete(5)))
            .build()
            .is_err());
        // codec k bounds
        assert!(base().codec(Codec::TopK { k: 0 }).build().is_err());
        assert!(base().codec(Codec::TopK { k: 11 }).build().is_err());
        assert!(base().codec(Codec::TopK { k: 10 }).build().is_ok());
        assert!(base().codec(Codec::RandK { k: 1 }).build().is_ok());
        // dim 0 is fine for Dense only
        let degenerate = ProtocolConfig::builder().clients(4).threshold(2).model_dim(0);
        assert!(degenerate.clone().build().is_ok());
        assert!(degenerate.codec(Codec::RandK { k: 1 }).build().is_err());
    }
}
