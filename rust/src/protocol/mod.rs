//! The CCESA / SA secure-aggregation protocol (Algorithm 1 of the paper).
//!
//! Module layout:
//! * [`messages`] — wire messages with exact byte sizes, plus the
//!   [`messages::Up`]/[`messages::Down`] phase envelopes both deployment
//!   shapes exchange;
//! * [`client`] — the client state machine (Steps 0–3), and
//!   [`client::ClientSm`], its explicit poll-able `step(Down) -> Up` form
//!   multiplexed by `crate::coordinator`;
//! * [`server`] — the server state machine: collection, Shamir
//!   reconstruction, mask cancellation (Eq. 4), Theorem-1 reliability
//!   detection;
//! * [`engine`] — single-round synchronous driver wiring n clients and the
//!   server through the byte-accounted simnet with dropout injection;
//! * [`dropout`] — dropout models (i.i.d. per-step q, targeted, none);
//! * [`adversary`] — the eavesdropper of Definition 2 and the constructive
//!   privacy attack from the converse of Theorem 2.
//!
//! SA (Bonawitz et al. 2017) is obtained with [`Topology::Complete`]; the
//! paper's scheme with [`Topology::ErdosRenyi`].

pub mod adversary;
pub mod client;
pub mod dropout;
pub mod engine;
pub mod messages;
pub mod server;

use crate::graph::Graph;
use crate::util::rng::Rng;

/// Client identifier: index in 0..n.
pub type ClientId = usize;

/// Assignment-graph family.
#[derive(Debug, Clone)]
pub enum Topology {
    /// Complete graph — conventional SA.
    Complete,
    /// Erdős–Rényi G(n, p) — the paper's CCESA.
    ErdosRenyi { p: f64 },
    /// Harary H_{k,n} — Bell et al. 2020 comparison.
    Harary { k: usize },
    /// Explicit graph (tests, ablations).
    Custom(Graph),
}

impl Topology {
    /// Materialize the assignment graph (deterministic in `rng`).
    pub fn build(&self, n: usize, rng: &mut Rng) -> Graph {
        match self {
            Topology::Complete => Graph::complete(n),
            Topology::ErdosRenyi { p } => Graph::erdos_renyi(n, *p, rng),
            Topology::Harary { k } => Graph::harary(n, *k),
            Topology::Custom(g) => {
                assert_eq!(g.n(), n, "custom topology size mismatch");
                g.clone()
            }
        }
    }
}

/// Static protocol parameters for one aggregation round.
#[derive(Debug, Clone)]
pub struct ProtocolConfig {
    /// Number of clients n.
    pub n: usize,
    /// Secret-sharing threshold t (same for all clients; Remark 4 gives the
    /// design rule — see `analysis::bounds::t_rule`).
    pub t: usize,
    /// Masked-domain width b: aggregation in Z_{2^b}.
    pub mask_bits: u32,
    /// Model dimension m.
    pub dim: usize,
    /// Assignment-graph family.
    pub topology: Topology,
    /// Dropout model applied per step.
    pub dropout: dropout::DropoutModel,
    /// Master seed (graph, keys, shares, dropout all derive from it).
    pub seed: u64,
}

impl ProtocolConfig {
    /// Convenience constructor with no dropout.
    pub fn new(n: usize, t: usize, dim: usize, topology: Topology, seed: u64) -> Self {
        ProtocolConfig {
            n,
            t,
            mask_bits: 32,
            dim,
            topology,
            dropout: dropout::DropoutModel::None,
            seed,
        }
    }

    /// Materialize the assignment graph from an explicit RNG — the single
    /// construction point shared by the sync engine and the threaded
    /// coordinator, so the two drivers can never diverge on topology.
    pub fn build_graph_with(&self, rng: &mut crate::util::rng::Rng) -> Graph {
        self.topology.build(self.n, rng)
    }

    /// Replay helper: the exact graph a round under this config runs on.
    /// Both drivers derive their graph from the first draws of
    /// `Rng::new(seed)`, so external observers (the `sim` scenario compiler,
    /// adaptive churn models, shrinker reports) can reconstruct it without
    /// running the round.
    pub fn build_graph(&self) -> Graph {
        self.build_graph_with(&mut crate::util::rng::Rng::new(self.seed))
    }
}

/// The surviving client sets after each step (paper notation V1 ⊇ … ⊇ V4).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SurvivorSets {
    pub v1: Vec<ClientId>,
    pub v2: Vec<ClientId>,
    pub v3: Vec<ClientId>,
    pub v4: Vec<ClientId>,
}

impl SurvivorSets {
    pub fn contains(set: &[ClientId], id: ClientId) -> bool {
        set.binary_search(&id).is_ok()
    }
}
