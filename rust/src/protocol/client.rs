//! Client-side state machine for Algorithm 1.
//!
//! A [`Client`] is driven through `step0_advertise → step1_share_keys →
//! step2_masked_input → step3_unmask`. Any step may simply not be called
//! (dropout); the state carries everything needed by later steps.
//!
//! [`ClientSm`] wraps a [`Client`] into an explicit poll-able machine with
//! a single `step(Down) -> Up` transition — the unit both deployment
//! shapes in `crate::coordinator` multiplex: the thread-per-client
//! coordinator drives one per worker thread, the event-loop coordinator
//! sweeps thousands of them per pool worker.

use super::messages::*;
use super::ClientId;
use crate::codec::{EncodedUpdate, IndexPlan};
use crate::crypto::aead;
use crate::crypto::dh::{self, KeyPair, PublicKey};
use crate::crypto::prg::{apply_mask_jobs_range, ratchet_seed, warm_share_pad, MaskJob};
use crate::shamir::{self, Share};
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Warm-round share ciphertext length: the 32 share bytes (the 16 GF(2^16)
/// chunk evaluations of a 32-byte secret, x implicit) XORed with
/// [`warm_share_pad`]. Distinguishes pad-transport cts from the 86-byte
/// AEAD cold format on the receive path.
const WARM_CT_BYTES: usize = 32;

/// Per-pair AEAD nonce: direction-dependent so the shared key `c_{i,j}` is
/// never reused with the same nonce for both directions.
fn pair_nonce(from: ClientId, to: ClientId) -> [u8; 12] {
    let mut n = [0u8; 12];
    n[..4].copy_from_slice(&(from as u32).to_le_bytes());
    n[4..8].copy_from_slice(&(to as u32).to_le_bytes());
    n[8..12].copy_from_slice(b"shr1");
    n
}

/// Cross-round caches built by [`Client::establish_session`] after a
/// completed cold round. Everything a warm round reuses instead of
/// re-advertising keys: the per-neighbor channel secrets (derived once per
/// DH agreement, ratcheted per round) and the Shamir shares of each
/// neighbor's `s^SK` that cold Step 1 delivered.
#[derive(Debug, Clone)]
struct SessionCache {
    /// j → HKDF(x25519(s_i^SK, s_j^PK)) — the pairwise mask base the
    /// per-round seed is ratcheted from.
    mask_bases: BTreeMap<ClientId, [u8; 32]>,
    /// j → HKDF(x25519(c_i^SK, c_j^PK)) — the pairwise channel key warm
    /// share transport is padded (or, on re-key rounds, AEAD-sealed) with.
    enc_bases: BTreeMap<ClientId, [u8; 32]>,
    /// owner → our share of s^SK_owner, from the owner's last successful
    /// deal. Deleted when the owner re-keys (stale shares reconstruct a
    /// retired secret); re-cached from the owner's next AEAD re-deal.
    cached_sk_shares: BTreeMap<ClientId, Share>,
}

/// Per-warm-round state, reset by [`Client::warm_begin`].
#[derive(Debug, Clone)]
struct WarmRound {
    /// Session round counter k (cold round = 0).
    round: u64,
    /// This client announces fresh key pairs this round.
    rekeying: bool,
    /// owner → owner's fresh b^{(k)}-share for us, parsed from this
    /// round's delivery. Parsed in Step 2 — not Step 3 like the cold path —
    /// so a V2 \ V3 recipient still caches a re-keying neighbor's re-dealt
    /// sk-share even though it never sees the survivor announce.
    b_shares: BTreeMap<ClientId, Share>,
}

/// Client state across the four protocol steps.
pub struct Client {
    pub id: ClientId,
    /// Encryption key pair (c_i^PK, c_i^SK).
    pub c_keys: KeyPair,
    /// Mask key pair (s_i^PK, s_i^SK).
    pub s_keys: KeyPair,
    /// Self-mask PRG seed b_i.
    pub b_seed: [u8; 32],
    t: usize,
    mask_bits: u32,
    /// Neighborhood Adj(i) in the assignment graph, in the graph's
    /// adjacency order (grown in lock-step with server-side graph repair —
    /// warm alive-bitmaps index into this order).
    neighbors: Vec<ClientId>,
    /// Public keys received in the Step-0 bundle: j → (c_j^PK, s_j^PK).
    peer_keys: BTreeMap<ClientId, (PublicKey, PublicKey)>,
    /// Own (kept) shares of b_i and s_i^SK.
    own_b_share: Option<Share>,
    own_sk_share: Option<Share>,
    /// Ciphertexts received in Step 1, by sender.
    received: BTreeMap<ClientId, Vec<u8>>,
    /// Neighbors that were alive in Step 1 (senders of `received`) — the
    /// paper's V2 ∩ Adj(i), fixed when the delivery arrives.
    alive_neighbors_v2: Vec<ClientId>,
    /// Cross-round caches; `None` until a cold round established them.
    session: Option<SessionCache>,
    /// In-flight warm-round state; `None` on cold rounds.
    warm: Option<WarmRound>,
}

impl Client {
    /// Create a client with fresh keys. `neighbors` is Adj(id) in G.
    pub fn new(
        id: ClientId,
        t: usize,
        mask_bits: u32,
        neighbors: Vec<ClientId>,
        rng: &mut Rng,
    ) -> Client {
        let c_keys = KeyPair::generate(rng);
        let s_keys = KeyPair::generate(rng);
        let mut b_seed = [0u8; 32];
        rng.fill_bytes(&mut b_seed);
        Client {
            id,
            c_keys,
            s_keys,
            b_seed,
            t,
            mask_bits,
            neighbors,
            peer_keys: BTreeMap::new(),
            own_b_share: None,
            own_sk_share: None,
            received: BTreeMap::new(),
            alive_neighbors_v2: Vec::new(),
            session: None,
            warm: None,
        }
    }

    pub fn neighbors(&self) -> &[ClientId] {
        &self.neighbors
    }

    /// Append a repair edge's far endpoint to Adj(i). Must be called in the
    /// same global order the server calls `Graph::add_edge` so the warm
    /// alive-bitmap indices keep matching the server's adjacency rows.
    pub fn add_neighbor(&mut self, j: ClientId) {
        if j != self.id && !self.neighbors.contains(&j) {
            self.neighbors.push(j);
        }
    }

    /// Cross-round caches are in place (a cold round completed and
    /// [`Client::establish_session`] ran).
    pub fn has_session(&self) -> bool {
        self.session.is_some()
    }

    /// Decrypt + parse one cold-format AEAD share ciphertext from `owner`:
    /// `len-prefixed b-share || sk-share` under the pairwise channel key.
    fn open_pair_ct(&self, owner: ClientId, ct: &[u8]) -> Result<(Share, Share)> {
        let (c_pk, _) = self
            .peer_keys
            .get(&owner)
            .with_context(|| format!("no enc public key for owner {owner}"))?;
        let key = dh::agree_enc_key(&self.c_keys.sk, c_pk);
        let pt = aead::open(&key, &pair_nonce(owner, self.id), b"ccesa-share", ct)
            .with_context(|| format!("decrypting shares from {owner}"))?;
        if pt.len() < 2 {
            bail!("short share plaintext from {owner}");
        }
        let blen = u16::from_le_bytes([pt[0], pt[1]]) as usize;
        if pt.len() < 2 + blen {
            bail!("truncated share plaintext from {owner}");
        }
        let b_share = Share::from_bytes(&pt[2..2 + blen])
            .map_err(|e| anyhow::anyhow!("bad b-share from {owner}: {e}"))?;
        let sk_share = Share::from_bytes(&pt[2 + blen..])
            .map_err(|e| anyhow::anyhow!("bad sk-share from {owner}: {e}"))?;
        Ok((b_share, sk_share))
    }

    /// **Step 0** — advertise public keys.
    pub fn step0_advertise(&self) -> AdvertiseKeys {
        AdvertiseKeys { id: self.id, c_pk: self.c_keys.pk, s_pk: self.s_keys.pk }
    }

    /// **Step 1** — receive the key bundle for Adj(i) ∩ V1, secret-share
    /// `b_i` and `s_i^SK` among those neighbors (plus self), and upload the
    /// AEAD-encrypted shares.
    pub fn step1_share_keys(&mut self, bundle: &KeyBundle, rng: &mut Rng) -> Result<ShareUpload> {
        for (id, c_pk, s_pk) in &bundle.entries {
            self.peer_keys.insert(*id, (*c_pk, *s_pk));
        }
        // Share holders: alive neighbors (in the bundle) + self.
        let mut holders: Vec<ClientId> =
            bundle.entries.iter().map(|(id, _, _)| *id).collect();
        holders.push(self.id);
        holders.sort_unstable();
        let points: Vec<u16> = holders.iter().map(|&h| shamir::point_for_client(h)).collect();
        if self.t > points.len() {
            bail!(
                "client {}: threshold t={} exceeds |Adj(i)∩V1|+1={}",
                self.id,
                self.t,
                points.len()
            );
        }
        let b_shares = shamir::split(&self.b_seed, self.t, &points, rng)
            .context("splitting b_i")?;
        let sk_shares = shamir::split(&self.s_keys.sk, self.t, &points, rng)
            .context("splitting s_i^SK")?;

        let mut out = Vec::with_capacity(holders.len() - 1);
        for ((holder, b), sk) in holders.iter().zip(b_shares).zip(sk_shares) {
            if *holder == self.id {
                self.own_b_share = Some(b);
                self.own_sk_share = Some(sk);
                continue;
            }
            let (c_pk, _) = self
                .peer_keys
                .get(holder)
                .with_context(|| format!("missing public key for holder {holder}"))?;
            let key = dh::agree_enc_key(&self.c_keys.sk, c_pk);
            // plaintext: len-prefixed b-share || sk-share
            let bb = b.to_bytes();
            let sb = sk.to_bytes();
            let mut pt = Vec::with_capacity(2 + bb.len() + sb.len());
            pt.extend_from_slice(&(bb.len() as u16).to_le_bytes());
            pt.extend_from_slice(&bb);
            pt.extend_from_slice(&sb);
            let ct = aead::seal(&key, &pair_nonce(self.id, *holder), b"ccesa-share", &pt);
            out.push(EncryptedShare { from: self.id, to: *holder, ciphertext: ct });
        }
        Ok(ShareUpload { from: self.id, shares: out })
    }

    /// **Step 2** — receive the ciphertexts addressed to us (their senders
    /// are exactly V2 ∩ Adj(i)), encode the model through the round's
    /// shared index plan, then mask the encoded windows per Eq. (3).
    ///
    /// The packed vector is its own mask domain: element p of the encoding
    /// consumes keystream element p, whatever dense coordinate it maps to.
    /// Because the plan is shared, every survivor's windows align and
    /// pairwise masks cancel positionally — with the identity plan this is
    /// bit-identical to the pre-codec dense path.
    ///
    /// §Perf: plan-then-execute. The d+1 mask seeds (self + one DH
    /// agreement per alive neighbor) are derived first; then one parallel
    /// pass shards the encoded vector across workers, each applying every
    /// seed's keystream range to its disjoint slice in one fused
    /// keystream-major walk (`prg::apply_mask_jobs_range` →
    /// `kernels::apply_masks_fused`: all d+1 seeds expand per slice block,
    /// so the slice is traversed once, not d+1 times) — bit-identical to
    /// the serial per-seed pass.
    pub fn step2_masked_input(
        &mut self,
        delivery: &ShareDelivery,
        model: &[u64],
        plan: &Arc<IndexPlan>,
    ) -> Result<MaskedInput> {
        let workers = crate::par::threads_for_len(plan.len());
        self.step2_masked_input_with(delivery, model, plan, workers)
    }

    /// [`Client::step2_masked_input`] with an explicit worker budget for
    /// the mask pass. Coordinators that step many clients from a worker
    /// pool pass a reduced budget (host threads ÷ pool workers) so nested
    /// parallelism cannot oversubscribe the host; the result is
    /// bit-identical for any worker count (see `crate::par`).
    pub fn step2_masked_input_with(
        &mut self,
        delivery: &ShareDelivery,
        model: &[u64],
        plan: &Arc<IndexPlan>,
        workers: usize,
    ) -> Result<MaskedInput> {
        for es in &delivery.shares {
            if es.to != self.id {
                bail!("misrouted ciphertext: to={} at client {}", es.to, self.id);
            }
            self.received.insert(es.from, es.ciphertext.clone());
        }
        self.alive_neighbors_v2 = self.received.keys().copied().collect();

        // Plan: self mask PRG(b_i), then pairwise masks ± PRG(s_{i,j}) for
        // j ∈ V2 ∩ Adj(i); sign convention: + if i < j, − if i > j.
        let mut jobs: Vec<MaskJob> = Vec::with_capacity(1 + self.alive_neighbors_v2.len());
        jobs.push(MaskJob { seed: self.b_seed, pairwise: false, negate: false });
        for &j in &self.alive_neighbors_v2 {
            let (_, s_pk) = self
                .peer_keys
                .get(&j)
                .with_context(|| format!("no mask public key for neighbor {j}"))?;
            let seed = dh::agree_mask_seed(&self.s_keys.sk, s_pk);
            jobs.push(MaskJob { seed, pairwise: true, negate: self.id > j });
        }

        // Execute: encode (gather + reduce into Z_{2^b}; the identity plan
        // is exactly the old dense copy), then one parallel pass over
        // disjoint slices of the encoding. Never more workers than the
        // vector length warrants, whatever the caller's budget.
        let bits = self.mask_bits;
        let mut values = plan.encode(model, bits);
        let workers = workers.clamp(1, crate::par::threads_for_len(values.len()));
        crate::par::for_each_slice(&mut values, workers, |offset, slice| {
            apply_mask_jobs_range(slice, &jobs, bits, offset);
        });
        Ok(MaskedInput {
            id: self.id,
            update: EncodedUpdate { values, plan: plan.clone() },
            bits,
        })
    }

    /// **Step 3** — after learning V3, decrypt the stored ciphertexts and
    /// reveal to the server: `b`-shares of surviving owners, `s^SK`-shares
    /// of owners that dropped between Steps 1 and 2.
    pub fn step3_unmask(&mut self, announce: &SurvivorAnnounce) -> Result<UnmaskShares> {
        let v3 = &announce.v3;
        let in_v3 = |id: ClientId| v3.binary_search(&id).is_ok();
        let mut shares: Vec<(ClientId, ShareKind, Share)> = Vec::new();

        // own share of b_i (we are in V3 if we got this far)
        if in_v3(self.id) {
            if let Some(b) = &self.own_b_share {
                shares.push((self.id, ShareKind::SelfMask, b.clone()));
            }
        }
        for (&owner, ct) in &self.received {
            let (b_share, sk_share) = self.open_pair_ct(owner, ct)?;
            if in_v3(owner) {
                shares.push((owner, ShareKind::SelfMask, b_share));
            } else {
                // owner uploaded shares (∈ V2) but no masked input (∉ V3)
                shares.push((owner, ShareKind::SecretKey, sk_share));
            }
        }
        Ok(UnmaskShares { from: self.id, shares })
    }

    // ----- cross-round session (warm rounds) -----------------------------

    /// Promote a completed cold round into a session: derive every
    /// per-neighbor channel secret once and cache the sk-shares the cold
    /// Step-1 delivery carried. Warm rounds ratchet per-round secrets from
    /// these caches instead of repeating the O(|Adj|) DH + AEAD setup.
    pub fn establish_session(&mut self) -> Result<()> {
        let mut cache = SessionCache {
            mask_bases: BTreeMap::new(),
            enc_bases: BTreeMap::new(),
            cached_sk_shares: BTreeMap::new(),
        };
        for (&j, (c_pk, s_pk)) in &self.peer_keys {
            cache.mask_bases.insert(j, dh::agree_mask_seed(&self.s_keys.sk, s_pk));
            cache.enc_bases.insert(j, dh::agree_enc_key(&self.c_keys.sk, c_pk));
        }
        let received = std::mem::take(&mut self.received);
        for (&owner, ct) in &received {
            let (_, sk_share) = self
                .open_pair_ct(owner, ct)
                .with_context(|| format!("client {}: caching session shares", self.id))?;
            cache.cached_sk_shares.insert(owner, sk_share);
        }
        self.session = Some(cache);
        self.alive_neighbors_v2.clear();
        Ok(())
    }

    /// Begin warm round `k`: fresh per-round self-mask seed `b^{(k)}`, and
    /// — when the session layer forced a re-key (our `s^SK` was exposed by
    /// a V2 \ V3 reconstruction, or a repair edge touched us) — fresh key
    /// pairs plus a rebuild of every cached channel secret they feed.
    ///
    /// Draw order matches [`Client::new`] (c-keys, s-keys, seed) so warm
    /// rng streams line up across executors.
    pub fn warm_begin(&mut self, round: u64, rekey: bool, rng: &mut Rng) -> Result<()> {
        ensure!(self.session.is_some(), "client {}: warm round without a session", self.id);
        if rekey {
            self.c_keys = KeyPair::generate(rng);
            self.s_keys = KeyPair::generate(rng);
            let session = self.session.as_mut().unwrap();
            for (&j, (c_pk, s_pk)) in &self.peer_keys {
                session.mask_bases.insert(j, dh::agree_mask_seed(&self.s_keys.sk, s_pk));
                session.enc_bases.insert(j, dh::agree_enc_key(&self.c_keys.sk, c_pk));
            }
        }
        rng.fill_bytes(&mut self.b_seed);
        self.own_b_share = None;
        self.received.clear();
        self.alive_neighbors_v2.clear();
        self.warm = Some(WarmRound { round, rekeying: rekey, b_shares: BTreeMap::new() });
        Ok(())
    }

    /// **Warm phase 0** — resume the session: report our local TopK support
    /// proposal (sparse codecs) and fresh public keys when re-keying.
    pub fn warm_resume(&self, support: Option<Vec<u32>>) -> Result<WarmResume> {
        let warm = self
            .warm
            .as_ref()
            .with_context(|| format!("client {}: warm_resume before warm_begin", self.id))?;
        let rekey = warm.rekeying.then(|| (self.c_keys.pk, self.s_keys.pk));
        Ok(WarmResume { id: self.id, support, rekey })
    }

    /// **Warm phase 1** — consume the session delta and deal this round's
    /// shares.
    ///
    /// Applies neighbor re-keys first (replace cached public keys, rebuild
    /// the channel secrets, drop sk-shares the retired keys made stale),
    /// then deals the fresh `b^{(k)}` share to every alive neighbor as a
    /// 32-byte pad-XOR ciphertext over the cached channel key. A re-keying
    /// client falls back to the cold 86-byte AEAD format carrying both the
    /// `b^{(k)}`-share and the share of its *new* `s^SK`.
    pub fn warm_share_keys(&mut self, plan: &WarmPlan, rng: &mut Rng) -> Result<ShareUpload> {
        let (round, rekeying) = {
            let warm = self
                .warm
                .as_ref()
                .with_context(|| format!("client {}: warm plan before warm_begin", self.id))?;
            (warm.round, warm.rekeying)
        };
        ensure!(plan.to == self.id, "misrouted warm plan: to={} at client {}", plan.to, self.id);
        for (id, c_pk, s_pk) in &plan.keys {
            self.peer_keys.insert(*id, (*c_pk, *s_pk));
            let mask_base = dh::agree_mask_seed(&self.s_keys.sk, s_pk);
            let enc_base = dh::agree_enc_key(&self.c_keys.sk, c_pk);
            let session = self.session.as_mut().unwrap();
            session.mask_bases.insert(*id, mask_base);
            session.enc_bases.insert(*id, enc_base);
            session.cached_sk_shares.remove(id);
        }
        if plan.alive_bitmap.len() != self.neighbors.len().div_ceil(8) {
            bail!(
                "client {}: alive bitmap covers {} neighbors, have {}",
                self.id,
                plan.alive_bitmap.len() * 8,
                self.neighbors.len()
            );
        }
        let alive: Vec<ClientId> = self
            .neighbors
            .iter()
            .enumerate()
            .filter(|(b, _)| plan.alive_bitmap[b / 8] & (1u8 << (b % 8)) != 0)
            .map(|(_, &j)| j)
            .collect();

        let mut holders: Vec<ClientId> = alive.clone();
        holders.push(self.id);
        holders.sort_unstable();
        let points: Vec<u16> = holders.iter().map(|&h| shamir::point_for_client(h)).collect();
        if self.t > points.len() {
            bail!(
                "client {}: threshold t={} exceeds |Adj(i)∩V1|+1={}",
                self.id,
                self.t,
                points.len()
            );
        }
        let b_shares =
            shamir::split(&self.b_seed, self.t, &points, rng).context("splitting warm b_i")?;
        let sk_shares = if rekeying {
            Some(
                shamir::split(&self.s_keys.sk, self.t, &points, rng)
                    .context("splitting re-keyed s_i^SK")?,
            )
        } else {
            None
        };

        let mut out = Vec::with_capacity(holders.len() - 1);
        for (idx, (holder, b)) in holders.iter().zip(b_shares).enumerate() {
            if *holder == self.id {
                self.own_b_share = Some(b);
                if let Some(sks) = &sk_shares {
                    self.own_sk_share = Some(sks[idx].clone());
                }
                continue;
            }
            let enc_base = *self
                .session
                .as_ref()
                .unwrap()
                .enc_bases
                .get(holder)
                .with_context(|| format!("no cached channel key for holder {holder}"))?;
            let ct = if let Some(sks) = &sk_shares {
                // cold AEAD format under the fresh channel key; the nonce
                // is never reused with it (re-keying refreshed the key)
                let bb = b.to_bytes();
                let sb = sks[idx].to_bytes();
                let mut pt = Vec::with_capacity(2 + bb.len() + sb.len());
                pt.extend_from_slice(&(bb.len() as u16).to_le_bytes());
                pt.extend_from_slice(&bb);
                pt.extend_from_slice(&sb);
                aead::seal(&enc_base, &pair_nonce(self.id, *holder), b"ccesa-share", &pt)
            } else {
                // pad transport: y-chunks only, x is the holder's implicit
                // evaluation point
                let pad = warm_share_pad(&enc_base, (self.id < *holder) as u8, round);
                let mut ct = vec![0u8; WARM_CT_BYTES];
                for (c, chunk) in b.y.iter().enumerate() {
                    ct[2 * c..2 * c + 2].copy_from_slice(&chunk.to_le_bytes());
                }
                for (byte, p) in ct.iter_mut().zip(pad) {
                    *byte ^= p;
                }
                ct
            };
            out.push(EncryptedShare { from: self.id, to: *holder, ciphertext: ct });
        }
        Ok(ShareUpload { from: self.id, shares: out })
    }

    /// **Warm phase 2** — parse this round's share delivery (pad or AEAD
    /// per ciphertext length, caching re-dealt sk-shares immediately), then
    /// mask the encoded update with ratcheted pairwise seeds and the fresh
    /// `b^{(k)}` self seed.
    pub fn warm_masked_input_with(
        &mut self,
        delivery: &ShareDelivery,
        model: &[u64],
        plan: &Arc<IndexPlan>,
        workers: usize,
    ) -> Result<MaskedInput> {
        let round = self
            .warm
            .as_ref()
            .with_context(|| format!("client {}: warm delivery before warm_begin", self.id))?
            .round;
        let mut b_shares = BTreeMap::new();
        for es in &delivery.shares {
            if es.to != self.id {
                bail!("misrouted ciphertext: to={} at client {}", es.to, self.id);
            }
            if es.ciphertext.len() == WARM_CT_BYTES {
                let enc_base = *self
                    .session
                    .as_ref()
                    .unwrap()
                    .enc_bases
                    .get(&es.from)
                    .with_context(|| format!("no cached channel key for owner {}", es.from))?;
                let pad = warm_share_pad(&enc_base, (es.from < self.id) as u8, round);
                let mut y = Vec::with_capacity(WARM_CT_BYTES / 2);
                for c in 0..WARM_CT_BYTES / 2 {
                    let lo = es.ciphertext[2 * c] ^ pad[2 * c];
                    let hi = es.ciphertext[2 * c + 1] ^ pad[2 * c + 1];
                    y.push(u16::from_le_bytes([lo, hi]));
                }
                b_shares.insert(es.from, Share { x: shamir::point_for_client(self.id), y });
            } else {
                // a re-keying neighbor's AEAD re-deal: cache its fresh
                // sk-share now — Step 3 never runs for V2 \ V3 recipients
                let (b_share, sk_share) = self.open_pair_ct(es.from, &es.ciphertext)?;
                self.session.as_mut().unwrap().cached_sk_shares.insert(es.from, sk_share);
                b_shares.insert(es.from, b_share);
            }
        }
        self.alive_neighbors_v2 = b_shares.keys().copied().collect();
        self.warm.as_mut().unwrap().b_shares = b_shares;

        let mut jobs: Vec<MaskJob> = Vec::with_capacity(1 + self.alive_neighbors_v2.len());
        jobs.push(MaskJob { seed: self.b_seed, pairwise: false, negate: false });
        let session = self.session.as_ref().unwrap();
        for &j in &self.alive_neighbors_v2 {
            let base = session
                .mask_bases
                .get(&j)
                .with_context(|| format!("no cached mask base for neighbor {j}"))?;
            let seed = ratchet_seed(base, round);
            jobs.push(MaskJob { seed, pairwise: true, negate: self.id > j });
        }

        let bits = self.mask_bits;
        let mut values = plan.encode(model, bits);
        let workers = workers.clamp(1, crate::par::threads_for_len(values.len()));
        crate::par::for_each_slice(&mut values, workers, |offset, slice| {
            apply_mask_jobs_range(slice, &jobs, bits, offset);
        });
        Ok(MaskedInput {
            id: self.id,
            update: EncodedUpdate { values, plan: plan.clone() },
            bits,
        })
    }

    /// **Warm phase 3** — reveal this round's `b^{(k)}`-shares for V3
    /// owners; for owners that dropped in V2 \ V3, reveal the *cached*
    /// session sk-share (skipped when a missed re-deal left us without one
    /// — the holder set self-heals around absences, reconstruction only
    /// needs t of them).
    pub fn warm_unmask(&mut self, announce: &SurvivorAnnounce) -> Result<UnmaskShares> {
        let warm = self
            .warm
            .as_ref()
            .with_context(|| format!("client {}: warm announce before warm_begin", self.id))?;
        let v3 = &announce.v3;
        let in_v3 = |id: ClientId| v3.binary_search(&id).is_ok();
        let mut shares: Vec<(ClientId, ShareKind, Share)> = Vec::new();
        if in_v3(self.id) {
            if let Some(b) = &self.own_b_share {
                shares.push((self.id, ShareKind::SelfMask, b.clone()));
            }
        }
        let session = self.session.as_ref().unwrap();
        for (&owner, b_share) in &warm.b_shares {
            if in_v3(owner) {
                shares.push((owner, ShareKind::SelfMask, b_share.clone()));
            } else if let Some(sk) = session.cached_sk_shares.get(&owner) {
                shares.push((owner, ShareKind::SecretKey, sk.clone()));
            }
        }
        Ok(UnmaskShares { from: self.id, shares })
    }
}

/// Explicit poll-able per-client state machine: one [`step`](ClientSm::step)
/// call consumes the server's phase input ([`Down`]) and yields exactly one
/// phase output ([`Up`]).
///
/// The machine owns everything a round needs from the client side — the
/// [`Client`] crypto state, its Shamir share RNG, a borrow of its model
/// vector, and the pre-drawn per-step survival decisions — so a coordinator
/// only routes messages. Phases advance `0 → 1 → 2 → 3`; a dropout,
/// withdrawal (step-1 error), protocol-order violation, or [`Down::Finish`]
/// sends the machine to the terminal state ([`done`](ClientSm::done)).
pub struct ClientSm<'m> {
    client: Client,
    share_rng: Rng,
    model: &'m [u64],
    /// The round's shared payload plan (codec output) applied in Step 2.
    plan: Arc<IndexPlan>,
    /// Pre-drawn survival decision per phase (rng-free replay of the
    /// dropout model, in the sync engine's draw order).
    survives: [bool; 4],
    /// Phase whose input the machine expects next; > 3 means done.
    phase: u8,
    /// Worker budget for the Step-2 mask pass; `None` = auto per vector
    /// length (see [`ClientSm::set_mask_workers`]).
    mask_workers: Option<usize>,
    /// Warm-round phase-0 payload: the local TopK support proposal, taken
    /// when the resume message is emitted. `None` on cold rounds (and warm
    /// rounds of derived-map codecs).
    warm_support: Option<Vec<u32>>,
    /// This machine drives a warm (session-resume) round.
    warm: bool,
}

impl<'m> ClientSm<'m> {
    /// Build the machine. `key_rng` seeds the key pairs (consumed here, as
    /// `Client::new` draws from it); `share_rng` is retained for the
    /// Step-1 Shamir splits; `plan` is the round's shared index plan.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: ClientId,
        t: usize,
        mask_bits: u32,
        neighbors: Vec<ClientId>,
        key_rng: &mut Rng,
        share_rng: Rng,
        model: &'m [u64],
        plan: Arc<IndexPlan>,
        survives: [bool; 4],
    ) -> ClientSm<'m> {
        ClientSm {
            client: Client::new(id, t, mask_bits, neighbors, key_rng),
            share_rng,
            model,
            plan,
            survives,
            phase: 0,
            mask_workers: None,
            warm_support: None,
            warm: false,
        }
    }

    /// Build a warm-round machine around a session client ([`Client::warm_begin`]
    /// must already have run for this round). Phase 0 emits [`Up::Warm`]
    /// carrying `support`; phase 1 consumes [`Down::WarmPlan`]; phases 2–3
    /// run the ratcheted warm variants of masking and unmasking.
    pub fn resume(
        client: Client,
        support: Option<Vec<u32>>,
        share_rng: Rng,
        model: &'m [u64],
        plan: Arc<IndexPlan>,
        survives: [bool; 4],
    ) -> ClientSm<'m> {
        debug_assert!(client.warm.is_some(), "resume() requires warm_begin");
        ClientSm {
            client,
            share_rng,
            model,
            plan,
            survives,
            phase: 0,
            mask_workers: None,
            warm_support: support,
            warm: true,
        }
    }

    /// Take the client back out (with its updated session caches) after the
    /// round — the session layer re-seats it for the next warm round.
    pub fn into_client(self) -> Client {
        self.client
    }

    /// Cap the worker budget of this machine's Step-2 mask pass. A
    /// coordinator that steps many machines concurrently from a worker
    /// pool passes `par::threads() / pool_workers` so sweep × mask
    /// parallelism never exceeds the host budget; the masked result is
    /// bit-identical for any budget.
    pub fn set_mask_workers(&mut self, workers: usize) {
        self.mask_workers = Some(workers.max(1));
    }

    pub fn id(&self) -> ClientId {
        self.client.id
    }

    /// The round is over for this client: it completed Step 3, dropped,
    /// failed, or was finished by the server.
    pub fn done(&self) -> bool {
        self.phase > 3
    }

    /// Drive one phase transition. Every call yields exactly one [`Up`];
    /// the caller decides whether to deliver it (the threaded coordinator
    /// does not forward the response to a [`Down::Finish`]).
    pub fn step(&mut self, down: Down) -> Up {
        let id = self.client.id;
        let Some(phase) = down.phase() else {
            // Down::Finish — the server no longer needs this client.
            let at = self.phase.min(3);
            self.phase = 4;
            return Up::Dropped(id, at);
        };
        if phase != self.phase {
            let expected = self.phase;
            self.phase = 4;
            return Up::Failed(
                id,
                phase,
                format!("protocol order violation: phase-{phase} input, expected {expected}"),
            );
        }
        if !self.survives[phase as usize] {
            self.phase = 4;
            return Up::Dropped(id, phase);
        }
        match down {
            Down::Start if self.warm => {
                match self.client.warm_resume(self.warm_support.take()) {
                    Ok(wr) => {
                        self.phase = 1;
                        Up::Warm(wr)
                    }
                    Err(e) => {
                        self.phase = 4;
                        Up::Failed(id, 0, e.to_string())
                    }
                }
            }
            Down::Start => {
                self.phase = 1;
                Up::Adv(self.client.step0_advertise())
            }
            Down::Bundle(_) if self.warm => {
                self.phase = 4;
                Up::Failed(id, 1, "cold key bundle sent to a warm session client".into())
            }
            Down::Bundle(bundle) => {
                match self.client.step1_share_keys(&bundle, &mut self.share_rng) {
                    Ok(up) => {
                        self.phase = 2;
                        Up::Shares(up)
                    }
                    Err(e) => {
                        // small live neighborhood ⇒ secure withdrawal
                        self.phase = 4;
                        Up::Failed(id, 1, e.to_string())
                    }
                }
            }
            Down::WarmPlan(_) if !self.warm => {
                self.phase = 4;
                Up::Failed(id, 1, "warm session plan sent to a cold client".into())
            }
            Down::WarmPlan(plan) => {
                match self.client.warm_share_keys(&plan, &mut self.share_rng) {
                    Ok(up) => {
                        self.phase = 2;
                        Up::Shares(up)
                    }
                    Err(e) => {
                        // small live neighborhood ⇒ secure withdrawal
                        self.phase = 4;
                        Up::Failed(id, 1, e.to_string())
                    }
                }
            }
            Down::Delivery(delivery) => {
                let workers = self.mask_workers.unwrap_or_else(|| {
                    crate::par::threads_for_len(self.plan.len())
                });
                let stepped = if self.warm {
                    self.client.warm_masked_input_with(&delivery, self.model, &self.plan, workers)
                } else {
                    self.client.step2_masked_input_with(&delivery, self.model, &self.plan, workers)
                };
                match stepped {
                    Ok(mi) => {
                        self.phase = 3;
                        Up::Masked(mi)
                    }
                    Err(e) => {
                        self.phase = 4;
                        Up::Failed(id, 2, e.to_string())
                    }
                }
            }
            Down::Announce(announce) => {
                self.phase = 4; // Step 3 is the last transition either way
                let unmasked = if self.warm {
                    self.client.warm_unmask(&announce)
                } else {
                    self.client.step3_unmask(&announce)
                };
                match unmasked {
                    Ok(um) => Up::Unmask(um),
                    Err(e) => Up::Failed(id, 3, e.to_string()),
                }
            }
            Down::Finish => unreachable!("Finish handled above (phase() is None)"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(id: ClientId, t: usize, neighbors: Vec<ClientId>, seed: u64) -> Client {
        Client::new(id, t, 32, neighbors, &mut Rng::new(seed))
    }

    fn bundle_for(clients: &[&Client]) -> KeyBundle {
        KeyBundle {
            entries: clients
                .iter()
                .map(|c| (c.id, c.c_keys.pk, c.s_keys.pk))
                .collect(),
        }
    }

    #[test]
    fn step0_exposes_only_public_keys() {
        let c = mk(3, 2, vec![0, 1], 9);
        let adv = c.step0_advertise();
        assert_eq!(adv.id, 3);
        assert_eq!(adv.c_pk, c.c_keys.pk);
        assert_eq!(adv.s_pk, c.s_keys.pk);
    }

    #[test]
    fn step1_encrypts_one_pair_per_neighbor_and_keeps_self_share() {
        let mut rng = Rng::new(4);
        let mut a = mk(0, 2, vec![1, 2], 1);
        let b = mk(1, 2, vec![0, 2], 2);
        let c = mk(2, 2, vec![0, 1], 3);
        let up = a.step1_share_keys(&bundle_for(&[&b, &c]), &mut rng).unwrap();
        assert_eq!(up.shares.len(), 2);
        assert!(a.own_b_share.is_some() && a.own_sk_share.is_some());
        // ciphertext = 2 len + 2 shares (34B each) + tag
        assert_eq!(up.shares[0].ciphertext.len(), 2 + 34 + 34 + 16);
    }

    #[test]
    fn step1_rejects_too_high_threshold() {
        let mut rng = Rng::new(4);
        let mut a = mk(0, 5, vec![1], 1);
        let b = mk(1, 5, vec![0], 2);
        assert!(a.step1_share_keys(&bundle_for(&[&b]), &mut rng).is_err());
    }

    #[test]
    fn share_round_trip_between_two_clients() {
        // client 0 encrypts for client 1; client 1 decrypts in step 3
        let mut rng = Rng::new(77);
        let mut a = mk(0, 2, vec![1], 10);
        let mut b = mk(1, 2, vec![0], 11);
        let ba = bundle_for(&[&b]);
        let bb = bundle_for(&[&a]);
        let up_a = a.step1_share_keys(&ba, &mut rng).unwrap();
        let _up_b = b.step1_share_keys(&bb, &mut rng).unwrap();

        // deliver a's ciphertext to b, b masks
        let delivery = ShareDelivery { to: 1, shares: up_a.shares.clone() };
        let model = vec![5u64; 8];
        let plan = IndexPlan::identity(8);
        let _ = b.step2_masked_input(&delivery, &model, &plan).unwrap();

        // both 0 and 1 in V3 ⇒ b reveals a SelfMask share of owner 0
        let um = b.step3_unmask(&SurvivorAnnounce { v3: vec![0, 1] }).unwrap();
        let kinds: Vec<_> = um.shares.iter().map(|(o, k, _)| (*o, *k)).collect();
        assert!(kinds.contains(&(0, ShareKind::SelfMask)));
        assert!(kinds.contains(&(1, ShareKind::SelfMask))); // own share

        // if owner 0 dropped after step 1 ⇒ SecretKey share instead
        let mut b2 = mk(1, 2, vec![0], 11);
        let _ = b2.step1_share_keys(&bb, &mut rng).unwrap();
        let _ = b2.step2_masked_input(&delivery, &model, &plan).unwrap();
        let um2 = b2.step3_unmask(&SurvivorAnnounce { v3: vec![1] }).unwrap();
        let kinds2: Vec<_> = um2.shares.iter().map(|(o, k, _)| (*o, *k)).collect();
        assert!(kinds2.contains(&(0, ShareKind::SecretKey)));
        assert!(!kinds2.iter().any(|(o, k)| *o == 0 && *k == ShareKind::SelfMask));
    }

    #[test]
    fn step2_mask_is_reversible_with_seeds() {
        use crate::crypto::prg::{apply_mask, NONCE_PAIRWISE, NONCE_SELF};
        let mut rng = Rng::new(5);
        let mut a = mk(0, 2, vec![1], 20);
        let b = mk(1, 2, vec![0], 21);
        let _ = a.step1_share_keys(&bundle_for(&[&b]), &mut rng).unwrap();
        // fake a delivery from b so that b counts as alive
        let mut bmate = mk(1, 2, vec![0], 21);
        let _ = bmate.step1_share_keys(&bundle_for(&[&a]), &mut rng).unwrap();
        let model = vec![100u64; 16];
        let up_b = {
            let mut tmp = mk(1, 2, vec![0], 21);
            tmp.step1_share_keys(&bundle_for(&[&a]), &mut rng).unwrap()
        };
        let plan = IndexPlan::identity(16);
        let masked = a
            .step2_masked_input(&ShareDelivery { to: 0, shares: up_b.shares }, &model, &plan)
            .unwrap();
        // remove masks manually: PRG(b_0) and +PRG(s_01) (0 < 1 ⇒ plus)
        let mut rec = masked.update.values.clone();
        apply_mask(&mut rec, &a.b_seed, &NONCE_SELF, 32, true);
        let seed = dh::agree_mask_seed(&a.s_keys.sk, &b.s_keys.pk);
        apply_mask(&mut rec, &seed, &NONCE_PAIRWISE, 32, true);
        assert_eq!(rec, model);
        assert_ne!(masked.update.values, model, "mask must actually hide the model");
    }

    #[test]
    fn step3_rejects_tampered_ciphertext() {
        let mut rng = Rng::new(6);
        let mut a = mk(0, 2, vec![1], 30);
        let mut b = mk(1, 2, vec![0], 31);
        let up_a = a.step1_share_keys(&bundle_for(&[&b]), &mut rng).unwrap();
        let _ = b.step1_share_keys(&bundle_for(&[&a]), &mut rng).unwrap();
        let mut shares = up_a.shares.clone();
        shares[0].ciphertext[5] ^= 0xFF;
        let plan = IndexPlan::identity(4);
        let _ = b
            .step2_masked_input(&ShareDelivery { to: 1, shares }, &[0u64; 4], &plan)
            .unwrap();
        assert!(b.step3_unmask(&SurvivorAnnounce { v3: vec![0, 1] }).is_err());
    }

    #[test]
    fn step2_rejects_misrouted_delivery() {
        let mut rng = Rng::new(7);
        let mut a = mk(0, 1, vec![1], 40);
        let b = mk(1, 1, vec![0], 41);
        let _ = a.step1_share_keys(&bundle_for(&[&b]), &mut rng).unwrap();
        let bad = ShareDelivery {
            to: 0,
            shares: vec![EncryptedShare { from: 1, to: 2, ciphertext: vec![0; 32] }],
        };
        let plan = IndexPlan::identity(4);
        assert!(a.step2_masked_input(&bad, &[0u64; 4], &plan).is_err());
    }

    fn mk_sm(model: &[u64], survives: [bool; 4]) -> ClientSm<'_> {
        let mut key_rng = Rng::new(50);
        let plan = IndexPlan::identity(model.len());
        ClientSm::new(0, 1, 32, vec![], &mut key_rng, Rng::new(51), model, plan, survives)
    }

    #[test]
    fn sm_advertises_then_rejects_out_of_order_input() {
        let model = vec![1u64; 4];
        let mut sm = mk_sm(&model, [true; 4]);
        assert_eq!(sm.id(), 0);
        assert!(!sm.done());
        assert!(matches!(sm.step(Down::Start), Up::Adv(_)));
        assert!(!sm.done());
        // a second Start is a phase-0 input in phase 1: order violation
        match sm.step(Down::Start) {
            Up::Failed(0, 0, msg) => assert!(msg.contains("order violation"), "{msg}"),
            other => panic!("expected Failed, got {other:?}"),
        }
        assert!(sm.done());
    }

    #[test]
    fn sm_drop_decision_is_per_phase() {
        let model = vec![1u64; 4];
        let mut sm = mk_sm(&model, [false, true, true, true]);
        assert!(matches!(sm.step(Down::Start), Up::Dropped(0, 0)));
        assert!(sm.done());

        let mut sm = mk_sm(&model, [true, false, true, true]);
        assert!(matches!(sm.step(Down::Start), Up::Adv(_)));
        let bundle = KeyBundle { entries: vec![] };
        assert!(matches!(sm.step(Down::Bundle(bundle)), Up::Dropped(0, 1)));
        assert!(sm.done());
    }

    #[test]
    fn sm_finish_terminates_without_protocol_output() {
        let model = vec![1u64; 4];
        let mut sm = mk_sm(&model, [true; 4]);
        assert!(matches!(sm.step(Down::Start), Up::Adv(_)));
        assert!(matches!(sm.step(Down::Finish), Up::Dropped(0, 1)));
        assert!(sm.done());
    }

    /// Run a manual 2-client cold round so both ends hold each other's
    /// ciphertexts, then establish sessions on both.
    fn establish_pair() -> (Client, Client, Rng) {
        let mut rng = Rng::new(0x5E55);
        let mut a = mk(0, 2, vec![1], 100);
        let mut b = mk(1, 2, vec![0], 101);
        let up_a = a.step1_share_keys(&bundle_for(&[&b]), &mut rng).unwrap();
        let up_b = b.step1_share_keys(&bundle_for(&[&a]), &mut rng).unwrap();
        let model = vec![9u64; 8];
        let plan = IndexPlan::identity(8);
        let _ = a
            .step2_masked_input(&ShareDelivery { to: 0, shares: up_b.shares }, &model, &plan)
            .unwrap();
        let _ = b
            .step2_masked_input(&ShareDelivery { to: 1, shares: up_a.shares }, &model, &plan)
            .unwrap();
        a.establish_session().unwrap();
        b.establish_session().unwrap();
        (a, b, rng)
    }

    fn full_alive_plan(to: ClientId, n_neighbors: usize) -> WarmPlan {
        WarmPlan {
            to,
            alive_bitmap: vec![0xFF; n_neighbors.div_ceil(8)],
            keys: vec![],
        }
    }

    #[test]
    fn warm_round_trip_reveals_fresh_b_and_cached_sk() {
        let (mut a, mut b, mut rng) = establish_pair();
        assert!(a.has_session() && b.has_session());
        a.warm_begin(1, false, &mut rng).unwrap();
        b.warm_begin(1, false, &mut rng).unwrap();
        assert!(a.warm_resume(None).unwrap().rekey.is_none());
        let up_a = a.warm_share_keys(&full_alive_plan(0, 1), &mut rng).unwrap();
        let up_b = b.warm_share_keys(&full_alive_plan(1, 1), &mut rng).unwrap();
        // pad transport: exactly the 32 share-y bytes, no tag
        assert_eq!(up_a.shares[0].ciphertext.len(), WARM_CT_BYTES);
        let model = vec![3u64; 8];
        let plan = IndexPlan::identity(8);
        let masked_a = a
            .warm_masked_input_with(&ShareDelivery { to: 0, shares: up_b.shares }, &model, &plan, 1)
            .unwrap();
        let _ = b
            .warm_masked_input_with(&ShareDelivery { to: 1, shares: up_a.shares }, &model, &plan, 1)
            .unwrap();
        assert_ne!(masked_a.update.values, model);

        // both in V3: a reveals its own fresh b-share + b's fresh b-share
        let um = a.warm_unmask(&SurvivorAnnounce { v3: vec![0, 1] }).unwrap();
        let kinds: Vec<_> = um.shares.iter().map(|(o, k, _)| (*o, *k)).collect();
        assert_eq!(kinds, vec![(0, ShareKind::SelfMask), (1, ShareKind::SelfMask)]);

        // b dropped in V2 \ V3: a reveals the *cached* sk-share instead
        let um2 = a.warm_unmask(&SurvivorAnnounce { v3: vec![0] }).unwrap();
        let kinds2: Vec<_> = um2.shares.iter().map(|(o, k, _)| (*o, *k)).collect();
        assert_eq!(kinds2, vec![(0, ShareKind::SelfMask), (1, ShareKind::SecretKey)]);
    }

    #[test]
    fn warm_pairwise_masks_cancel_and_differ_per_round() {
        use crate::util::mod_mask;
        let model = vec![0u64; 8];
        let plan = IndexPlan::identity(8);
        let mut sums = Vec::new();
        for round in [1u64, 2] {
            let (mut a, mut b, mut rng) = establish_pair();
            a.warm_begin(round, false, &mut rng).unwrap();
            b.warm_begin(round, false, &mut rng).unwrap();
            let up_a = a.warm_share_keys(&full_alive_plan(0, 1), &mut rng).unwrap();
            let up_b = b.warm_share_keys(&full_alive_plan(1, 1), &mut rng).unwrap();
            let ma = a
                .warm_masked_input_with(
                    &ShareDelivery { to: 0, shares: up_b.shares },
                    &model,
                    &plan,
                    1,
                )
                .unwrap();
            let mb = b
                .warm_masked_input_with(
                    &ShareDelivery { to: 1, shares: up_a.shares },
                    &model,
                    &plan,
                    1,
                )
                .unwrap();
            // pairwise masks cancel in the sum; self masks remain
            let mask = mod_mask(32);
            let sum: Vec<u64> = ma
                .update
                .values
                .iter()
                .zip(&mb.update.values)
                .map(|(x, y)| x.wrapping_add(*y) & mask)
                .collect();
            use crate::crypto::prg::{apply_mask, NONCE_SELF};
            let mut rec = sum.clone();
            apply_mask(&mut rec, &a.b_seed, &NONCE_SELF, 32, true);
            apply_mask(&mut rec, &b.b_seed, &NONCE_SELF, 32, true);
            assert_eq!(rec, model, "round {round}: self-mask removal recovers the sum");
            sums.push(ma.update.values.clone());
        }
        assert_ne!(sums[0], sums[1], "ratcheted masks must differ across rounds");
    }

    #[test]
    fn warm_rekey_redeals_sk_over_aead_and_updates_recipient_cache() {
        let (mut a, mut b, mut rng) = establish_pair();
        let stale = b.session.as_ref().unwrap().cached_sk_shares[&0].clone();
        a.warm_begin(1, true, &mut rng).unwrap();
        b.warm_begin(1, false, &mut rng).unwrap();
        let wr = a.warm_resume(None).unwrap();
        let (new_c_pk, new_s_pk) = wr.rekey.expect("re-keying client must announce keys");
        assert_eq!(new_c_pk, a.c_keys.pk);

        // b's plan carries a's fresh keys: stale sk-share cache is dropped
        let plan_b = WarmPlan {
            to: 1,
            alive_bitmap: vec![0x01],
            keys: vec![(0, new_c_pk, new_s_pk)],
        };
        let up_b = b.warm_share_keys(&plan_b, &mut rng).unwrap();
        assert!(!b.session.as_ref().unwrap().cached_sk_shares.contains_key(&0));
        let up_a = a.warm_share_keys(&full_alive_plan(0, 1), &mut rng).unwrap();
        // re-keying sender uses the 86-byte AEAD format
        assert_eq!(up_a.shares[0].ciphertext.len(), 2 + 34 + 34 + 16);

        let model = vec![4u64; 8];
        let plan = IndexPlan::identity(8);
        let _ = a
            .warm_masked_input_with(&ShareDelivery { to: 0, shares: up_b.shares }, &model, &plan, 1)
            .unwrap();
        let _ = b
            .warm_masked_input_with(&ShareDelivery { to: 1, shares: up_a.shares }, &model, &plan, 1)
            .unwrap();
        // the AEAD re-deal re-cached a fresh share of the *new* sk
        let fresh = b.session.as_ref().unwrap().cached_sk_shares[&0].clone();
        assert_ne!(fresh, stale, "cached sk-share must track the re-key");
        let um = b.warm_unmask(&SurvivorAnnounce { v3: vec![1] }).unwrap();
        assert!(um.shares.contains(&(0, ShareKind::SecretKey, fresh)));
    }

    #[test]
    fn sm_runs_all_four_phases_solo() {
        // t = 1, no neighbors: the client shares only with itself, masks
        // with just its self mask, and reveals its own b-share
        let model = vec![7u64; 4];
        let mut sm = mk_sm(&model, [true; 4]);
        assert!(matches!(sm.step(Down::Start), Up::Adv(_)));
        let up = sm.step(Down::Bundle(KeyBundle { entries: vec![] }));
        match up {
            Up::Shares(s) => assert!(s.shares.is_empty(), "no neighbors, no ciphertexts"),
            other => panic!("expected Shares, got {other:?}"),
        }
        let delivery = ShareDelivery { to: 0, shares: vec![] };
        let masked = match sm.step(Down::Delivery(delivery)) {
            Up::Masked(m) => m,
            other => panic!("expected Masked, got {other:?}"),
        };
        assert_ne!(masked.update.values, model, "self mask must hide the model");
        let ann = std::sync::Arc::new(SurvivorAnnounce { v3: vec![0] });
        match sm.step(Down::Announce(ann)) {
            Up::Unmask(um) => {
                assert_eq!(um.shares.len(), 1);
                assert_eq!(um.shares[0].1, ShareKind::SelfMask);
            }
            other => panic!("expected Unmask, got {other:?}"),
        }
        assert!(sm.done());
    }
}
