//! Client dropout models.
//!
//! The paper's analysis (§4.3) assumes each client drops independently
//! with probability q at each of the protocol's steps; the total dropout
//! probability is `q_total = 1 − (1−q)^4`. Targeted dropout is provided
//! for adversarial tests (e.g. forcing Theorem-1 violations).

use super::ClientId;
use crate::util::rng::Rng;

/// Which clients fail at a given step.
#[derive(Debug, Clone)]
pub enum DropoutModel {
    /// No failures.
    None,
    /// Each surviving client independently drops with probability `q`
    /// at each step (4 opportunities: paper's Steps 0–3 responses).
    Iid { q: f64 },
    /// Explicit sets of clients that drop at each step (0..=3).
    Targeted { per_step: [Vec<ClientId>; 4] },
}

impl DropoutModel {
    /// Convert the paper's protocol-level dropout `q_total` into the
    /// per-step q: q_total = 1 − (1−q)^4.
    pub fn iid_from_total(q_total: f64) -> DropoutModel {
        assert!((0.0..1.0).contains(&q_total));
        DropoutModel::Iid { q: 1.0 - (1.0 - q_total).powf(0.25) }
    }

    /// Does `client` (currently alive) survive `step`?
    pub fn survives(&self, step: usize, client: ClientId, rng: &mut Rng) -> bool {
        match self {
            DropoutModel::None => true,
            DropoutModel::Iid { q } => !rng.bernoulli(*q),
            DropoutModel::Targeted { per_step } => !per_step[step].contains(&client),
        }
    }

    /// Pre-draw every (step, client) decision into an explicit per-step
    /// schedule, step-major over all `n` clients.
    ///
    /// Replay hook for the `sim` subsystem: a stochastic model becomes a
    /// [`DropoutModel::Targeted`] schedule that is rng-free, so the same
    /// failures replay bit-identically through both the sync engine and the
    /// threaded coordinator (whose lazy draw orders otherwise differ), and a
    /// failing schedule can be shrunk and reported as data.
    pub fn materialize(&self, n: usize, rng: &mut Rng) -> [Vec<ClientId>; 4] {
        let mut per_step: [Vec<ClientId>; 4] = std::array::from_fn(|_| Vec::new());
        for (step, drops) in per_step.iter_mut().enumerate() {
            for client in 0..n {
                if !self.survives(step, client, rng) {
                    drops.push(client);
                }
            }
        }
        per_step
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_never_drops() {
        let mut rng = Rng::new(1);
        let m = DropoutModel::None;
        assert!((0..4).all(|s| m.survives(s, 0, &mut rng)));
    }

    #[test]
    fn iid_frequency_matches_q() {
        let mut rng = Rng::new(2);
        let m = DropoutModel::Iid { q: 0.25 };
        let n = 20_000;
        let dropped = (0..n).filter(|&i| !m.survives(0, i, &mut rng)).count();
        assert!((dropped as f64 / n as f64 - 0.25).abs() < 0.02);
    }

    #[test]
    fn iid_from_total_composes() {
        let q_total = 0.1;
        let DropoutModel::Iid { q } = DropoutModel::iid_from_total(q_total) else {
            panic!()
        };
        let survive_all = (1.0 - q).powi(4);
        assert!((survive_all - (1.0 - q_total)).abs() < 1e-12);
    }

    #[test]
    fn materialize_matches_model() {
        // Targeted materializes to itself; Iid materializes to the exact
        // decisions an identically-seeded rng would draw in the same order.
        let t = DropoutModel::Targeted { per_step: [vec![1], vec![], vec![2, 3], vec![]] };
        let m = t.materialize(5, &mut Rng::new(0));
        assert_eq!(m, [vec![1], vec![], vec![2, 3], vec![]]);

        let iid = DropoutModel::Iid { q: 0.3 };
        let sched = iid.materialize(50, &mut Rng::new(9));
        let mut rng = Rng::new(9);
        for step in 0..4 {
            for client in 0..50 {
                let survived = iid.survives(step, client, &mut rng);
                assert_eq!(survived, !sched[step].contains(&client), "step={step} c={client}");
            }
        }
        assert!(sched.iter().any(|s| !s.is_empty()), "q=0.3 must drop someone");

        let none = DropoutModel::None.materialize(10, &mut Rng::new(1));
        assert!(none.iter().all(|s| s.is_empty()));
    }

    #[test]
    fn targeted_drops_exactly() {
        let m = DropoutModel::Targeted {
            per_step: [vec![1], vec![], vec![2, 3], vec![]],
        };
        let mut rng = Rng::new(3);
        assert!(!m.survives(0, 1, &mut rng));
        assert!(m.survives(0, 2, &mut rng));
        assert!(!m.survives(2, 3, &mut rng));
        assert!(m.survives(3, 3, &mut rng));
    }
}
