//! Cross-round sessions: amortized setup, ratcheted seeds, error-fed TopK.
//!
//! A cold round pays the full CCESA setup — x25519 advertisements, pairwise
//! key agreements, AEAD share ciphertexts. This module keeps what that
//! round established (pairwise channel secrets, graph membership, Shamir
//! share skeletons) alive in a [`Session`] so the rounds after it start
//! *warm*:
//!
//! * **Ratcheted seeds** — round k's pairwise mask seed is
//!   `prg::ratchet_seed(base, k)` over the cached x25519 agreement; the
//!   self-mask seed `b_i^(k)` is fresh per round and its shares travel as
//!   32-byte pad-XOR ciphertexts over the cached channel (no AEAD, no key
//!   exchange). Phase 0 shrinks from two public keys per client to a
//!   [`WarmResume`] that is empty unless the client re-keys.
//! * **Incremental re-key, not rebuild** — churn (a member skipping a
//!   round, an `s^SK` exposed by V2∖V3 reconstruction, a repair edge)
//!   re-keys only the touched clients: the server's per-client delta
//!   clocks ([`WarmCtx`]) tell each plan recipient exactly which neighbor
//!   keys it missed, and stale cached share skeletons are dropped by the
//!   recipients themselves.
//! * **Graph repair under churn** — when absences push a member's *active*
//!   degree below t−1, deterministic repair edges are added among the
//!   round's participants (both endpoints re-key; adjacency order stays
//!   lock-stepped between server graph and client neighbor lists).
//! * **Local TopK + error feedback** — warm TopK rounds rank coordinates
//!   locally over `eff_i = θ_i + residual_i` (mod 2^b), upload the k-index
//!   support in phase 0, and receive the server-assembled union support
//!   with the plan; coordinates that don't travel accumulate into
//!   `residual_i` for the next round. The cold round's driver-computed
//!   global-magnitude oracle survives only as the cold-start path.
//!
//! Execution goes through the same three shapes as cold rounds — a serial
//! engine driver (here), the worker-pool event loop
//! (`coordinator::run_warm_event_loop`) and the loopback wire
//! (`net::socket`) — selected via [`RoundOptions`]; all three are
//! bit-identical in sums, survivor sets and logical byte accounting.
//!
//! Simplifications (documented, asserted in tests): session membership is
//! fixed to the cold round's V3 (no late joins); an aborted warm round
//! burns its ratchet round number and leaves the session usable.

use super::client::{Client, ClientSm};
use super::messages::{Down, Up, ID_BYTES};
use super::server::{RoundOutput, Server, WarmCtx};
use super::{ClientId, ProtocolConfig, SurvivorSets};
use crate::codec::{local_topk, union_support, Codec, IndexPlan};
use crate::coordinator::{
    event_loop_workers, predraw_survivals, run_cold_round_capture, run_warm_event_loop,
    CoordRoundResult, Executor, RoundOptions, WarmLoopIo,
};
use crate::crypto::dh::PublicKey;
use crate::graph::Graph;
use crate::net::{Dir, NetStats};
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Per-round seed stride (the 64-bit golden ratio, same schedule the sim
/// scenario compiler uses for its multi-round seeds).
const ROUND_SEED_STRIDE: u64 = 0x9E37_79B9_7F4A_7C15;

/// The seed every round-k derivation (dropout schedule, per-client RNG
/// streams, RandK plan, journal round tag) runs under. k = 0 is the cold
/// round: `round_seed(seed, 0) == seed`.
pub fn round_seed(seed: u64, round: u64) -> u64 {
    seed ^ round.wrapping_mul(ROUND_SEED_STRIDE)
}

/// Everything a warm round derives before its first message moves — the
/// warm counterpart of `coordinator::RoundSetup`, plus the session-layer
/// decisions (participant set, re-key set, repair edges, effective inputs).
struct WarmSpec {
    round: u64,
    plan: Arc<IndexPlan>,
    /// `eff_i = (θ_i + residual_i) mod 2^b`, indexed by client id (empty
    /// for non-participants — they contribute nothing this round).
    effs: Vec<Vec<u64>>,
    /// Phase-0 support proposal per client (TopK participants only).
    supports: Vec<Option<Vec<u32>>>,
    survives: Vec<[bool; 4]>,
    share_rngs: Vec<Rng>,
    /// Active session members this round, ascending.
    participants: Vec<ClientId>,
    /// Snapshot of `pending_rekey` at prepare time: who announced fresh
    /// keys in this round's phase 0.
    rekeying: Vec<bool>,
    /// Per-recipient union-coordinate-map download bytes (TopK only).
    map_bytes: usize,
}

/// A live cross-round aggregation session: the server-side caches (graph,
/// advertised keys, delta clocks) plus the session members' [`Client`]s
/// with their pairwise secrets, and the per-client error-feedback
/// residuals. Built by [`Session::establish`] from one cold round; every
/// [`Session::run_round`] after that is warm.
pub struct Session {
    cfg: ProtocolConfig,
    graph: Graph,
    /// Session members' clients, by id. `None` only transiently (while a
    /// round's executor owns the machine) or for non-members.
    clients: Vec<Option<Client>>,
    member: Vec<bool>,
    /// Current advertised keys, id → (c_pk, s_pk) — the warm server's
    /// phase-0 substitute.
    keys: BTreeMap<ClientId, (PublicKey, PublicKey)>,
    last_seen: Vec<u64>,
    rekeyed_at: Vec<u64>,
    /// Who must announce fresh key pairs next round (exposed `s^SK`,
    /// repair-edge endpoint). Stays set until the re-deal lands (the
    /// client reaches V2 of a round it announced in).
    pending_rekey: Vec<bool>,
    /// Error-feedback residual per client, in the modular domain.
    residuals: Vec<Vec<u64>>,
    /// Last started round (0 = cold). Advanced at prepare time so an
    /// aborted round can never reuse a ratcheted seed.
    round: u64,
    /// Repair edges added so far: (round, i, j).
    repairs: Vec<(u64, ClientId, ClientId)>,
}

impl Session {
    /// Run the cold round (event-loop executor) and establish the session
    /// from its outcome: members are the cold V3, each caching its
    /// pairwise channel secrets and the share skeletons it received.
    pub fn establish(
        cfg: &ProtocolConfig,
        models: &[Vec<u64>],
    ) -> Result<(Session, CoordRoundResult)> {
        let (result, machines) =
            run_cold_round_capture(cfg, models, event_loop_workers(cfg.n))?;
        ensure!(result.reliable, "cold round unreliable: no session established");
        let mut clients: Vec<Option<Client>> =
            machines.into_iter().map(|sm| Some(sm.into_client())).collect();
        let mut member = vec![false; cfg.n];
        let mut keys = BTreeMap::new();
        for &i in &result.sets.v3 {
            let c = clients[i].as_mut().expect("cold round yields one client per id");
            c.establish_session().with_context(|| format!("client {i}: establish session"))?;
            member[i] = true;
            keys.insert(i, (c.c_keys.pk, c.s_keys.pk));
        }
        ensure!(
            result.sets.v3.len() >= cfg.t,
            "cold V3 smaller than t: session could never run a warm round"
        );
        let graph = {
            // same first draws as `derive_round_setup`
            let mut rng = Rng::new(cfg.seed);
            cfg.build_graph_with(&mut rng)
        };
        let session = Session {
            cfg: cfg.clone(),
            graph,
            clients,
            member,
            keys,
            last_seen: vec![0; cfg.n],
            rekeyed_at: vec![0; cfg.n],
            pending_rekey: vec![false; cfg.n],
            residuals: vec![vec![0u64; cfg.dim]; cfg.n],
            round: 0,
            repairs: Vec::new(),
        };
        Ok((session, result))
    }

    /// Last started round number (0 until the first warm round).
    pub fn round(&self) -> u64 {
        self.round
    }

    /// Session members (the cold round's V3), ascending.
    pub fn members(&self) -> Vec<ClientId> {
        (0..self.cfg.n).filter(|&i| self.member[i]).collect()
    }

    pub fn is_member(&self, id: ClientId) -> bool {
        self.member.get(id).copied().unwrap_or(false)
    }

    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    pub fn config(&self) -> &ProtocolConfig {
        &self.cfg
    }

    /// The client's error-feedback residual (modular domain).
    pub fn residual(&self, id: ClientId) -> &[u64] {
        &self.residuals[id]
    }

    pub fn is_rekey_pending(&self, id: ClientId) -> bool {
        self.pending_rekey[id]
    }

    /// Repair edges added so far, as (round, i, j).
    pub fn repair_edges(&self) -> &[(u64, ClientId, ClientId)] {
        &self.repairs
    }

    /// Run one warm round over `models` with the given per-client activity
    /// schedule (`active[i]` = client i shows up this round; non-members
    /// are ignored). The executor, worker budget and journal come from
    /// `opts` exactly as for a cold [`crate::coordinator::RoundRunner`]
    /// round.
    pub fn run_round(
        &mut self,
        models: &[Vec<u64>],
        active: &[bool],
        opts: &RoundOptions,
    ) -> Result<CoordRoundResult> {
        let spec = self.prepare(models, active)?;
        match opts.executor {
            Executor::Engine => {
                ensure!(
                    opts.journal_dir.is_none(),
                    "the sync engine executor does not journal"
                );
                let (result, server) = self.run_warm_engine(&spec)?;
                self.absorb(&spec, &server, &result);
                Ok(result)
            }
            Executor::EventLoop => {
                let mut server = self.warm_server(&spec);
                if let Some(dir) = &opts.journal_dir {
                    let sink = warm_journal_sink(dir, &self.cfg, &spec, &server)?;
                    server.set_sink(sink);
                }
                let workers = opts.workers.unwrap_or_else(|| event_loop_workers(self.cfg.n));
                let machines = self.take_warm_machines(&spec);
                let (res, server, machines) = run_warm_event_loop(WarmLoopIo {
                    machines,
                    server,
                    map_bytes: spec.map_bytes,
                    workers,
                });
                self.reseat(machines);
                let result = res?;
                self.absorb(&spec, &server, &result);
                Ok(result)
            }
            Executor::Wire => {
                let server = self.warm_server(&spec);
                let tag = crate::net::socket::round_tag(round_seed(self.cfg.seed, spec.round));
                let machines = self.take_warm_machines(&spec);
                let (res, server, machines) = crate::net::socket::run_warm_round_wire(
                    server,
                    machines,
                    spec.map_bytes,
                    tag,
                    opts,
                );
                self.reseat(machines);
                let result = res?;
                self.absorb(&spec, &server, &result);
                Ok(result)
            }
        }
    }

    /// Derive everything round k needs and mutate the session's pre-round
    /// state: repair the graph, advance the round counter (burned even if
    /// the round later aborts — ratcheted seeds are never reused), draw
    /// per-round secrets, and compute effective inputs + the payload plan.
    fn prepare(&mut self, models: &[Vec<u64>], active: &[bool]) -> Result<WarmSpec> {
        let n = self.cfg.n;
        ensure!(models.len() == n, "one model vector per client");
        ensure!(active.len() == n, "one activity flag per client");
        let round = self.round + 1;

        let participants: Vec<ClientId> =
            (0..n).filter(|&i| active[i] && self.member[i] && self.clients[i].is_some()).collect();
        ensure!(
            participants.len() >= self.cfg.t,
            "warm round {round}: {} active members < t = {}",
            participants.len(),
            self.cfg.t
        );

        // ---- graph repair: every participant needs t-1 active neighbors
        for (i, j) in plan_repairs(&self.graph, &participants, self.cfg.t)? {
            self.graph.add_edge(i, j);
            // same global order as the server graph so warm alive-bitmap
            // indices keep matching adjacency rows
            self.clients[i].as_mut().expect("participant client").add_neighbor(j);
            self.clients[j].as_mut().expect("participant client").add_neighbor(i);
            self.pending_rekey[i] = true;
            self.pending_rekey[j] = true;
            self.repairs.push((round, i, j));
        }

        // ---- per-round derivation, same recipe shape as a cold round
        let rseed = round_seed(self.cfg.seed, round);
        let mut rng = Rng::new(rseed);
        let mut dropout_rng = rng.split(0xD20);
        let survives = predraw_survivals(&self.cfg, &mut dropout_rng);
        let mut share_rngs = Vec::with_capacity(n);
        let rekeying = self.pending_rekey.clone();
        for id in 0..n {
            let mut key_rng = rng.split(0xC11E27 + id as u64);
            share_rngs.push(rng.split(0x5A12E + id as u64));
            if participants.binary_search(&id).is_ok() {
                self.clients[id]
                    .as_mut()
                    .expect("participant client")
                    .warm_begin(round, rekeying[id], &mut key_rng)
                    .with_context(|| format!("client {id}: warm_begin round {round}"))?;
            }
        }

        // ---- effective inputs: error feedback folds the residual in
        let modmask = crate::util::mod_mask(self.cfg.mask_bits);
        let mut effs = vec![Vec::new(); n];
        for &i in &participants {
            ensure!(models[i].len() == self.cfg.dim, "client {i} model dimension");
            effs[i] = models[i]
                .iter()
                .zip(&self.residuals[i])
                .map(|(&m, &r)| m.wrapping_add(r) & modmask)
                .collect();
        }

        // ---- payload plan: local ranking + server-assembled union for
        // TopK, seed-derived for RandK, identity for Dense
        let mut supports: Vec<Option<Vec<u32>>> = vec![None; n];
        let (plan, map_bytes) = match self.cfg.codec {
            Codec::Dense => (IndexPlan::identity(self.cfg.dim), 0),
            Codec::RandK { .. } => {
                (self.cfg.codec.plan(self.cfg.dim, self.cfg.mask_bits, rseed, &effs), 0)
            }
            Codec::TopK { k } => {
                for &i in &participants {
                    supports[i] = Some(local_topk(&effs[i], self.cfg.mask_bits, k));
                }
                // the union over predicted V1 (participants surviving
                // phase 0) — exactly the supports the server will receive
                // and union; both wire endpoints derive it identically
                let v1_supports: Vec<Vec<u32>> = participants
                    .iter()
                    .filter(|&&i| survives[i][0])
                    .map(|&i| supports[i].clone().expect("participant support"))
                    .collect();
                let union = union_support(&v1_supports, self.cfg.dim);
                let map_bytes = union.len() * ID_BYTES;
                (IndexPlan::sparse(union, self.cfg.dim), map_bytes)
            }
        };

        self.round = round;
        Ok(WarmSpec {
            round,
            plan,
            effs,
            supports,
            survives,
            share_rngs,
            participants,
            rekeying,
            map_bytes,
        })
    }

    /// The warm server for this round, seeded from the session caches.
    fn warm_server(&self, spec: &WarmSpec) -> Server {
        Server::new_warm(
            self.cfg.n,
            self.cfg.t,
            self.cfg.mask_bits,
            spec.plan.clone(),
            self.graph.clone(),
            self.keys.clone(),
            WarmCtx {
                round: spec.round,
                last_seen: self.last_seen.clone(),
                rekeyed_at: self.rekeyed_at.clone(),
            },
        )
    }

    /// Move the participants' clients into warm state machines for an
    /// executor. [`Session::reseat`] puts them back afterwards.
    fn take_warm_machines<'m>(&mut self, spec: &'m WarmSpec) -> Vec<ClientSm<'m>> {
        spec.participants
            .iter()
            .map(|&i| {
                let client = self.clients[i].take().expect("participant has a live client");
                ClientSm::resume(
                    client,
                    spec.supports[i].clone(),
                    spec.share_rngs[i].clone(),
                    &spec.effs[i],
                    spec.plan.clone(),
                    spec.survives[i],
                )
            })
            .collect()
    }

    fn reseat(&mut self, machines: Vec<ClientSm<'_>>) {
        for sm in machines {
            let client = sm.into_client();
            let id = client.id;
            self.clients[id] = Some(client);
        }
    }

    /// Post-round bookkeeping: copy back the server's delta clocks and
    /// (possibly re-keyed) advertised keys, settle the re-key ledger, and
    /// absorb untransmitted coordinates into the residuals.
    fn absorb(&mut self, spec: &WarmSpec, server: &Server, result: &CoordRoundResult) {
        let warm = server.warm().expect("warm round server carries its context");
        self.last_seen = warm.last_seen.clone();
        self.rekeyed_at = warm.rekeyed_at.clone();
        self.keys = server.advertised_keys().clone();

        let support = spec.plan.indices();
        for &i in &spec.participants {
            let in_v2 = SurvivorSets::contains(&result.sets.v2, i);
            let in_v3 = SurvivorSets::contains(&result.sets.v3, i);
            // a pending re-key completes when the re-deal landed (V2 of a
            // round it announced in) ...
            if spec.rekeying[i] && in_v2 {
                self.pending_rekey[i] = false;
            }
            // ... and V2∖V3 membership exposes s^SK to reconstruction, so
            // the key must rotate before its next pairwise use
            if in_v2 && !in_v3 {
                self.pending_rekey[i] = true;
            }
            // error feedback: transmitted coordinates reset, everything
            // else (including a whole update that never made V3) carries
            if result.reliable && in_v3 {
                let mut r = spec.effs[i].clone();
                match support {
                    Some(idx) => {
                        for &d in idx {
                            r[d as usize] = 0;
                        }
                    }
                    None => r.fill(0),
                }
                self.residuals[i] = r;
            } else {
                self.residuals[i] = spec.effs[i].clone();
            }
        }
    }

    /// The serial warm driver — the session's own "engine" executor,
    /// mirroring `protocol::engine::run_round` phase by phase (and charging
    /// logical bytes exactly like the warm event loop, so the two are
    /// `NetStats::logical_eq`).
    fn run_warm_engine(&mut self, spec: &WarmSpec) -> Result<(CoordRoundResult, Server)> {
        let mut server = self.warm_server(spec);
        let mut stats = NetStats::new(self.cfg.n);
        let mut alive = vec![false; self.cfg.n];
        for &i in &spec.participants {
            alive[i] = true;
        }
        let workers = crate::par::threads_for_len(spec.plan.len());

        // ---- phase 0: session resume
        let mut resumes = Vec::new();
        for &i in &spec.participants {
            if spec.survives[i][0] {
                let r = self.clients[i]
                    .as_ref()
                    .expect("participant client")
                    .warm_resume(spec.supports[i].clone())?;
                stats.record(0, Dir::Up, i, r.size_bytes());
                stats.record_coord_map(r.support_bytes());
                stats.record_rekey(Dir::Up, r.rekey_bytes());
                resumes.push(r);
            } else {
                alive[i] = false;
            }
        }
        let plans = server.warm_step0_resume(resumes)?;
        for (id, wp) in &plans {
            stats.record(0, Dir::Down, *id, wp.size_bytes() + spec.map_bytes);
            stats.record_coord_map(spec.map_bytes);
            stats.record_rekey(Dir::Down, wp.rekey_bytes());
        }

        // ---- phase 1: share keys over the cached channels
        let mut uploads = Vec::new();
        for (id, wp) in &plans {
            if alive[*id] && spec.survives[*id][1] {
                let mut srng = spec.share_rngs[*id].clone();
                match self.clients[*id]
                    .as_mut()
                    .expect("participant client")
                    .warm_share_keys(wp, &mut srng)
                {
                    Ok(up) => {
                        stats.record(1, Dir::Up, *id, up.size_bytes());
                        uploads.push(up);
                    }
                    Err(e) => {
                        log::debug!("client {id} withdraws in warm step 1: {e}");
                        alive[*id] = false;
                    }
                }
            } else {
                alive[*id] = false;
            }
        }
        let deliveries = server.step1_route_shares(uploads)?;
        for (id, d) in &deliveries {
            stats.record(1, Dir::Down, *id, d.size_bytes());
        }

        // ---- phase 2: masked effective inputs
        let mut masked = Vec::new();
        for (id, delivery) in &deliveries {
            if alive[*id] && spec.survives[*id][2] {
                let mi = self.clients[*id]
                    .as_mut()
                    .expect("participant client")
                    .warm_masked_input_with(delivery, &spec.effs[*id], &spec.plan, workers)?;
                stats.record(2, Dir::Up, *id, mi.size_bytes());
                stats.record_masked_payload(mi.payload_bytes());
                masked.push(mi);
            } else {
                alive[*id] = false;
            }
        }
        let announce = server.step2_collect_masked(masked)?;
        for &id in &announce.v3 {
            stats.record(2, Dir::Down, id, announce.size_bytes());
        }

        // ---- phase 3: unmask
        let mut responses = Vec::new();
        for &id in &announce.v3 {
            if alive[id] && spec.survives[id][3] {
                let um = self.clients[id]
                    .as_mut()
                    .expect("participant client")
                    .warm_unmask(&announce)?;
                stats.record(3, Dir::Up, id, um.size_bytes());
                responses.push(um);
            } else {
                alive[id] = false;
            }
        }
        let RoundOutput { sum, reliable, sets } = server.finalize(responses)?;
        Ok((CoordRoundResult { sum, reliable, sets, stats, timeline: None }, server))
    }
}

/// The deterministic repair plan: for each participant (ascending) whose
/// active degree is below t−1, add edges to the lowest-id participants it
/// isn't connected to yet. Pure so it can be property-tested; errors when
/// the participant pool is too small to reach the threshold.
fn plan_repairs(
    graph: &Graph,
    participants: &[ClientId],
    t: usize,
) -> Result<Vec<(ClientId, ClientId)>> {
    let mut part = vec![false; graph.n()];
    for &i in participants {
        part[i] = true;
    }
    // adjacency snapshot we update as we plan, so later participants see
    // earlier repairs
    let mut extra: Vec<Vec<ClientId>> = vec![Vec::new(); graph.n()];
    let mut edges = Vec::new();
    for &i in participants {
        let mut deg = graph.neighbors(i).iter().filter(|&&j| part[j]).count()
            + extra[i].len();
        if deg + 1 >= t {
            continue;
        }
        for &j in participants {
            if deg + 1 >= t {
                break;
            }
            if j == i || graph.has_edge(i, j) || extra[i].contains(&j) {
                continue;
            }
            edges.push((i, j));
            extra[i].push(j);
            extra[j].push(i);
            deg += 1;
        }
        if deg + 1 < t {
            bail!(
                "client {i}: only {} active neighbors reachable, needs {} (t = {t})",
                deg,
                t - 1
            );
        }
    }
    Ok(edges)
}

/// Create the warm round's fsync'd journal (setup record carries the
/// session caches so `journal::recover` rebuilds a warm server) and wrap
/// it as the server's durability sink.
fn warm_journal_sink(
    dir: &std::path::Path,
    cfg: &ProtocolConfig,
    spec: &WarmSpec,
    server: &Server,
) -> Result<Box<dyn super::server::RoundSink>> {
    let tag = crate::net::socket::round_tag(round_seed(cfg.seed, spec.round));
    let journal = crate::journal::Journal::create_warm(
        dir,
        tag,
        cfg.n,
        cfg.t,
        cfg.mask_bits,
        &spec.plan,
        server.graph(),
        server.advertised_keys(),
        server.warm().expect("warm server carries its context"),
        spec.map_bytes,
    )
    .context("create warm round journal")?;
    Ok(Box::new(crate::journal::JournalSink::new(journal)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::dropout::DropoutModel;
    use crate::protocol::Topology;
    use crate::util::mod_mask;

    fn models(n: usize, dim: usize, seed: u64) -> Vec<Vec<u64>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect())
            .collect()
    }

    fn expected_sum(m: &[Vec<u64>], ids: &[usize], dim: usize, bits: u32) -> Vec<u64> {
        let mm = mod_mask(bits);
        let mut expect = vec![0u64; dim];
        for &i in ids {
            for (a, x) in expect.iter_mut().zip(&m[i]) {
                *a = a.wrapping_add(*x) & mm;
            }
        }
        expect
    }

    fn engine_opts() -> RoundOptions {
        RoundOptions::builder().executor(Executor::Engine).build().unwrap()
    }

    #[test]
    fn warm_rounds_recover_exact_sums_and_amortize_setup() {
        let n = 12;
        let dim = 24;
        let cfg = ProtocolConfig::for_test(n, 5, dim, Topology::ErdosRenyi { p: 0.8 }, 4242);
        let cold_models = models(n, dim, 1);
        let (mut s, cold) = Session::establish(&cfg, &cold_models).unwrap();
        assert_eq!(s.members().len(), n);
        let active = vec![true; n];
        for k in 1..=3u64 {
            let m = models(n, dim, 100 + k);
            let r = s.run_round(&m, &active, &engine_opts()).unwrap();
            assert!(r.reliable, "round {k}");
            assert_eq!(s.round(), k);
            assert_eq!(
                r.sum.as_ref().unwrap(),
                &expected_sum(&m, &r.sets.v3, dim, cfg.mask_bits),
                "round {k}"
            );
            // the whole point: warm setup traffic is a fraction of cold
            // (the CI campaign asserts the <30% bound at realistic n)
            assert!(
                r.stats.setup_bytes() * 2 < cold.stats.setup_bytes(),
                "round {k}: warm setup {} not < 1/2 of cold {}",
                r.stats.setup_bytes(),
                cold.stats.setup_bytes()
            );
            assert_eq!(r.stats.rekey_up, 0, "no churn, no re-keys");
        }
    }

    #[test]
    fn ratchet_is_deterministic_across_sessions_and_fresh_per_round() {
        let n = 8;
        let dim = 10;
        let cfg = ProtocolConfig::for_test(n, 4, dim, Topology::Complete, 77);
        let cold_models = models(n, dim, 2);
        let warm_models = models(n, dim, 3);
        let active = vec![true; n];
        let run = |rounds: usize| -> Vec<CoordRoundResult> {
            let (mut s, _) = Session::establish(&cfg, &cold_models).unwrap();
            (0..rounds)
                .map(|_| s.run_round(&warm_models, &active, &engine_opts()).unwrap())
                .collect()
        };
        let a = run(2);
        let b = run(2);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.sum, y.sum);
            assert_eq!(x.sets, y.sets);
            assert!(x.stats.logical_eq(&y.stats));
        }
        // same inputs two rounds running: the sums agree (mask-free
        // aggregates), which only holds if each round's masks cancel
        // internally despite distinct ratcheted seeds
        assert_eq!(a[0].sum, a[1].sum);
    }

    #[test]
    fn topk_error_feedback_carries_untransmitted_coordinates() {
        let n = 6;
        let dim = 16;
        let k = 3;
        let cfg = ProtocolConfig {
            codec: Codec::TopK { k },
            ..ProtocolConfig::for_test(n, 3, dim, Topology::Complete, 99)
        };
        let m = models(n, dim, 5);
        let (mut s, _) = Session::establish(&cfg, &m).unwrap();
        let active = vec![true; n];
        let r = s.run_round(&m, &active, &engine_opts()).unwrap();
        assert!(r.reliable);
        let support: Vec<usize> = r.sum.as_ref().unwrap().iter().enumerate()
            .filter(|(_, &v)| v != 0).map(|(d, _)| d).collect();
        assert!(!support.is_empty() && support.len() <= n * k);
        let mm = mod_mask(cfg.mask_bits);
        for i in 0..n {
            let res = s.residual(i);
            // transmitted coordinates reset; the rest carry eff = θ + 0
            let mut nonzero_off_support = 0;
            for d in 0..dim {
                if support.contains(&d) {
                    // may or may not be in the union; if it was, residual 0
                } else {
                    assert_eq!(res[d], m[i][d] & mm, "client {i} coord {d}");
                    if res[d] != 0 {
                        nonzero_off_support += 1;
                    }
                }
            }
            assert!(nonzero_off_support > 0, "client {i}: residual must accumulate");
        }
        // second round: effs fold the residual in, so coordinates starved
        // in round 1 get ranked with doubled weight
        let r2 = s.run_round(&m, &active, &engine_opts()).unwrap();
        assert!(r2.reliable);
    }

    #[test]
    fn v2_minus_v3_membership_forces_a_rekey_that_lands_next_round() {
        let n = 8;
        let dim = 8;
        let victim = 3;
        let cfg = ProtocolConfig {
            dropout: DropoutModel::Targeted { per_step: [vec![], vec![], vec![victim], vec![]] },
            ..ProtocolConfig::for_test(n, 3, dim, Topology::Complete, 1234)
        };
        // cold round: victim ∈ V2∖V3 but is not a session member (members
        // are cold V3) — use a clean cold round instead
        let clean = ProtocolConfig { dropout: DropoutModel::None, ..cfg.clone() };
        let m = models(n, dim, 6);
        let (mut s, _) = Session::establish(&clean, &m).unwrap();
        // switch the live session to the leaky dropout schedule
        s.cfg = cfg;
        let active = vec![true; n];
        let r1 = s.run_round(&m, &active, &engine_opts()).unwrap();
        assert!(r1.reliable);
        assert!(SurvivorSets::contains(&r1.sets.v2, victim));
        assert!(!SurvivorSets::contains(&r1.sets.v3, victim));
        assert!(s.is_rekey_pending(victim), "exposed s^SK must force a re-key");
        let keys_before = s.keys[&victim];

        let r2 = s.run_round(&m, &active, &engine_opts()).unwrap();
        assert!(r2.reliable);
        assert!(r2.stats.rekey_up > 0, "round 2 carries the re-key announcement");
        assert_ne!(s.keys[&victim], keys_before, "advertised keys rotated");
        assert_eq!(s.rekeyed_at[victim], 2);
        // victim reaches V2 again in round 2 (drops only at step 2), so the
        // re-deal landed — but the fresh exposure re-arms the flag
        assert!(s.is_rekey_pending(victim));
    }

    #[test]
    fn absences_trigger_graph_repair_with_rekeyed_endpoints() {
        // path-ish sparse graph: knocking out a hub starves its neighbors
        let n = 10;
        let dim = 6;
        let cfg = ProtocolConfig::for_test(n, 4, dim, Topology::ErdosRenyi { p: 0.45 }, 2025);
        let m = models(n, dim, 8);
        let Ok((mut s, _)) = Session::establish(&cfg, &m) else {
            // p too thin for this seed — the cold round itself failed;
            // nothing to test
            return;
        };
        // drop two members for a round; if anyone's active degree dips
        // below t-1 the session must add repair edges and re-key endpoints
        let mut active = vec![true; n];
        active[1] = false;
        active[4] = false;
        let r = s.run_round(&m, &active, &engine_opts());
        if let Ok(r) = r {
            assert!(r.reliable);
            for &(_, i, j) in s.repair_edges() {
                assert!(s.graph().has_edge(i, j));
                // endpoints re-keyed this round or still pending
                assert!(
                    s.rekeyed_at[i] >= 1 || s.is_rekey_pending(i),
                    "repair endpoint {i} never re-keyed"
                );
                assert!(
                    s.rekeyed_at[j] >= 1 || s.is_rekey_pending(j),
                    "repair endpoint {j} never re-keyed"
                );
                // adjacency order stays lock-stepped client-side
                assert!(s.clients[i].as_ref().unwrap().neighbors().contains(&j));
                assert!(s.clients[j].as_ref().unwrap().neighbors().contains(&i));
            }
            // returning members resume cleanly
            let r2 = s.run_round(&m, &vec![true; n], &engine_opts()).unwrap();
            assert!(r2.reliable);
            assert_eq!(
                r2.sum.as_ref().unwrap(),
                &expected_sum(&m, &r2.sets.v3, dim, cfg.mask_bits)
            );
        }
    }

    #[test]
    fn repair_planner_tops_up_degrees_deterministically() {
        let mut g = Graph::empty(6);
        // a path 0-1-2-3-4, node 5 isolated
        g.add_edge(0, 1);
        g.add_edge(1, 2);
        g.add_edge(2, 3);
        g.add_edge(3, 4);
        let parts: Vec<usize> = (0..6).collect();
        let edges = plan_repairs(&g, &parts, 3).unwrap();
        // applying the plan leaves everyone with active degree >= t-1 = 2
        let mut g2 = g.clone();
        for &(i, j) in &edges {
            g2.add_edge(i, j);
        }
        for &i in &parts {
            assert!(g2.degree(i) >= 2, "node {i} degree {} after repair", g2.degree(i));
        }
        // deterministic: same inputs, same plan
        assert_eq!(edges, plan_repairs(&g, &parts, 3).unwrap());
        // an impossible ask errors instead of looping
        assert!(plan_repairs(&g, &[0, 5], 3).is_err());
    }

    #[test]
    fn aborted_warm_round_burns_its_round_number_but_keeps_the_session() {
        let n = 6;
        let dim = 6;
        let cfg = ProtocolConfig::for_test(n, 4, dim, Topology::Complete, 31);
        let m = models(n, dim, 9);
        let (mut s, _) = Session::establish(&cfg, &m).unwrap();
        // everyone inactive → prepare fails before any secrets are drawn
        assert!(s.run_round(&m, &vec![false; n], &engine_opts()).is_err());
        // dropout storm at phase 0 → server aborts (|V1| < t) after the
        // round number was burned
        s.cfg.dropout =
            DropoutModel::Targeted { per_step: [(0..n).collect(), vec![], vec![], vec![]] };
        assert!(s.run_round(&m, &vec![true; n], &engine_opts()).is_err());
        let burned = s.round();
        assert!(burned >= 1);
        // back to a clean schedule: the session still works, on a fresh
        // (never-reused) ratchet round
        s.cfg.dropout = DropoutModel::None;
        let r = s.run_round(&m, &vec![true; n], &engine_opts()).unwrap();
        assert!(r.reliable);
        assert_eq!(s.round(), burned + 1);
    }
}
