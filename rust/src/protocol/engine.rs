//! Synchronous single-round protocol driver.
//!
//! Wires `n` [`Client`]s and one [`Server`] through the byte-accounted
//! simnet with dropout injection, producing a [`RoundResult`] that carries
//! the aggregate, survivor sets, communication stats, per-step timings and
//! the eavesdropper transcript. The threaded deployment shape lives in
//! `crate::coordinator`; this engine is the deterministic core both use.

use super::adversary::Transcript;
use super::client::Client;
use super::messages::*;
use super::server::{theorem1_predicate, RoundOutput, Server};
use super::{ClientId, ProtocolConfig, SurvivorSets};
use crate::codec::IndexPlan;
use crate::net::{Dir, NetStats};
use crate::util::rng::Rng;
use crate::util::timer::StepTimes;
use anyhow::Result;
use std::sync::Arc;

/// Everything observable about one protocol round.
#[derive(Debug)]
pub struct RoundResult {
    /// Σ_{i∈V3} θ_i mod 2^b if the round was reliable.
    pub sum: Option<Vec<u64>>,
    pub reliable: bool,
    pub sets: SurvivorSets,
    pub stats: NetStats,
    pub times: StepTimes,
    /// What an eavesdropper on every link saw (Definition 2's E).
    pub transcript: Transcript,
    /// Ground truth Σ_{i∈V3} θ_i (oracle for tests/experiments; computed
    /// from the plaintext inputs, never transmitted).
    pub true_sum_v3: Vec<u64>,
    /// Whether Theorem 1's predicate held (must equal `reliable`).
    pub theorem1_holds: bool,
    /// The payload plan this round ran under (the codec's shared coordinate
    /// map) — callers that post-process `sum` per coordinate read the
    /// support from here instead of re-deriving it.
    pub plan: Arc<IndexPlan>,
}

/// Run one full aggregation round over quantized inputs
/// (`models[i].len() == cfg.dim` for every client i).
pub fn run_round(cfg: &ProtocolConfig, models: &[Vec<u64>]) -> Result<RoundResult> {
    if cfg.topology.is_hierarchical() {
        anyhow::bail!("hierarchical topology: drive rounds through hier::HierRunner");
    }
    assert_eq!(models.len(), cfg.n, "one model vector per client");
    for (i, m) in models.iter().enumerate() {
        assert_eq!(m.len(), cfg.dim, "client {i} model dimension");
    }
    let mut rng = Rng::new(cfg.seed);
    let graph = cfg.build_graph_with(&mut rng);
    let mut dropout_rng = rng.split(0xD20);
    // The round's shared payload plan — derived from public knowledge
    // (round seed / scoring oracle), never from the protocol RNG stream,
    // so Dense rounds stay bit-identical to the pre-codec engine.
    let plan = cfg.codec.plan(cfg.dim, cfg.mask_bits, cfg.seed, models);

    let mut clients: Vec<Client> = (0..cfg.n)
        .map(|i| {
            let mut crng = rng.split(0xC11E27 + i as u64);
            Client::new(i, cfg.t, cfg.mask_bits, graph.neighbors(i).to_vec(), &mut crng)
        })
        .collect();
    let mut server = Server::new(cfg.n, cfg.t, cfg.mask_bits, plan.clone(), graph.clone());
    let mut stats = NetStats::new(cfg.n);
    let mut times = StepTimes::new();
    let mut alive: Vec<bool> = vec![true; cfg.n];

    // ---- Step 0: advertise keys -----------------------------------------
    let mut advs = Vec::new();
    times.time("client_step0", || {
        for c in &clients {
            if alive[c.id] && cfg.dropout.survives(0, c.id, &mut dropout_rng) {
                let a = c.step0_advertise();
                stats.record(0, Dir::Up, c.id, a.size_bytes());
                advs.push(a);
            } else {
                alive[c.id] = false;
            }
        }
    });
    let bundles = times.time("server_step0", || server.step0_route_keys(advs))?;
    for (id, b) in &bundles {
        stats.record(0, Dir::Down, *id, b.size_bytes());
    }

    // ---- Step 1: share keys ---------------------------------------------
    let mut uploads = Vec::new();
    times.time("client_step1", || -> Result<()> {
        for (id, bundle) in &bundles {
            if alive[*id] && cfg.dropout.survives(1, *id, &mut dropout_rng) {
                let mut srng = rng.split(0x5A12E + *id as u64);
                match clients[*id].step1_share_keys(bundle, &mut srng) {
                    Ok(up) => {
                        stats.record(1, Dir::Up, *id, up.size_bytes());
                        uploads.push(up);
                    }
                    Err(e) => {
                        // A client whose live neighborhood is smaller than t
                        // cannot share securely (Remark 4) — it withdraws
                        // from the round rather than weakening its threshold.
                        log::debug!("client {id} withdraws in step 1: {e}");
                        alive[*id] = false;
                    }
                }
            } else {
                alive[*id] = false;
            }
        }
        Ok(())
    })?;
    // transcript: the adversary sees who uploaded (V2) and the ciphertexts
    let observed_v2: Vec<ClientId> = {
        let mut v: Vec<ClientId> = uploads.iter().map(|u| u.from).collect();
        v.sort_unstable();
        v
    };
    let deliveries = times.time("server_step1", || server.step1_route_shares(uploads))?;
    for (id, d) in &deliveries {
        stats.record(1, Dir::Down, *id, d.size_bytes());
    }

    // ---- Step 2: masked input collection ----------------------------------
    let mut masked_inputs = Vec::new();
    times.time("client_step2", || -> Result<()> {
        for (id, delivery) in &deliveries {
            if alive[*id] && cfg.dropout.survives(2, *id, &mut dropout_rng) {
                let mi = clients[*id].step2_masked_input(delivery, &models[*id], &plan)?;
                stats.record(2, Dir::Up, *id, mi.size_bytes());
                stats.record_masked_payload(mi.payload_bytes());
                masked_inputs.push(mi);
            } else {
                alive[*id] = false;
            }
        }
        Ok(())
    })?;
    let observed_masked: Vec<(ClientId, Vec<u64>)> =
        masked_inputs.iter().map(|m| (m.id, m.update.values.clone())).collect();
    let announce = times.time("server_step2", || server.step2_collect_masked(masked_inputs))?;
    for &id in &announce.v3 {
        stats.record(2, Dir::Down, id, announce.size_bytes());
    }

    // ---- Step 3: unmasking -------------------------------------------------
    let mut responses = Vec::new();
    times.time("client_step3", || -> Result<()> {
        for &id in &announce.v3 {
            if alive[id] && cfg.dropout.survives(3, id, &mut dropout_rng) {
                let um = clients[id].step3_unmask(&announce)?;
                stats.record(3, Dir::Up, id, um.size_bytes());
                responses.push(um);
            } else {
                alive[id] = false;
            }
        }
        Ok(())
    })?;
    let observed_unmask: Vec<(ClientId, ClientId, ShareKind, crate::shamir::Share)> = responses
        .iter()
        .flat_map(|r| {
            r.shares
                .iter()
                .map(move |(owner, kind, sh)| (r.from, *owner, *kind, sh.clone()))
        })
        .collect();

    let RoundOutput { sum, reliable, sets } =
        times.time("server_finalize", || server.finalize(responses))?;

    // Ground truth over V3 for validation: the dense modular sum projected
    // onto the round's support (identity projection for Dense) — exactly
    // what a reliable round's scattered aggregate must equal.
    let modmask = crate::util::mod_mask(cfg.mask_bits);
    let mut true_sum = vec![0u64; cfg.dim];
    for &i in &sets.v3 {
        for (a, x) in true_sum.iter_mut().zip(&models[i]) {
            *a = a.wrapping_add(*x) & modmask;
        }
    }
    plan.project(&mut true_sum);

    let theorem1_holds = theorem1_predicate(&graph, &sets, cfg.t);

    let transcript = Transcript {
        n: cfg.n,
        t: cfg.t,
        mask_bits: cfg.mask_bits,
        dim: cfg.dim,
        payload_len: plan.len(),
        graph,
        keys: server.advertised_keys().clone(),
        v2: observed_v2,
        v3: sets.v3.clone(),
        masked: observed_masked,
        unmask_shares: observed_unmask,
    };

    Ok(RoundResult {
        sum,
        reliable,
        sets,
        stats,
        times,
        transcript,
        true_sum_v3: true_sum,
        theorem1_holds,
        plan,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::dropout::DropoutModel;
    use crate::protocol::Topology;

    fn models(n: usize, dim: usize, seed: u64) -> Vec<Vec<u64>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect())
            .collect()
    }

    #[test]
    fn sa_no_dropout_recovers_exact_sum() {
        let n = 8;
        let dim = 50;
        let cfg = ProtocolConfig::for_test(n, 5, dim, Topology::Complete, 42);
        let m = models(n, dim, 7);
        let r = run_round(&cfg, &m).unwrap();
        assert!(r.reliable);
        assert!(r.theorem1_holds);
        assert_eq!(r.sets.v3.len(), n);
        assert_eq!(r.sum.as_ref().unwrap(), &r.true_sum_v3);
    }

    #[test]
    fn ccesa_er_no_dropout_recovers_exact_sum() {
        let n = 20;
        let dim = 30;
        let cfg = ProtocolConfig::for_test(n, 6, dim, Topology::ErdosRenyi { p: 0.7 }, 1234);
        let m = models(n, dim, 8);
        let r = run_round(&cfg, &m).unwrap();
        assert!(r.reliable, "sets={:?}", r.sets);
        assert_eq!(r.sum.as_ref().unwrap(), &r.true_sum_v3);
    }

    #[test]
    fn dropout_after_step1_still_recovers() {
        // clients 2 and 5 upload shares but never send masked input:
        // the server must reconstruct their s^SK and cancel pairwise masks
        let n = 10;
        let dim = 40;
        let cfg = ProtocolConfig {
            dropout: DropoutModel::Targeted {
                per_step: [vec![], vec![], vec![2, 5], vec![]],
            },
            ..ProtocolConfig::for_test(n, 4, dim, Topology::Complete, 99)
        };
        let m = models(n, dim, 9);
        let r = run_round(&cfg, &m).unwrap();
        assert!(r.reliable);
        assert_eq!(r.sets.v3.len(), n - 2);
        assert!(!SurvivorSets::contains(&r.sets.v3, 2));
        assert_eq!(r.sum.as_ref().unwrap(), &r.true_sum_v3);
    }

    #[test]
    fn dropout_at_every_step_recovers() {
        let n = 14;
        let dim = 25;
        let cfg = ProtocolConfig {
            dropout: DropoutModel::Targeted {
                per_step: [vec![0], vec![1], vec![2], vec![3]],
            },
            ..ProtocolConfig::for_test(n, 5, dim, Topology::Complete, 77)
        };
        let m = models(n, dim, 10);
        let r = run_round(&cfg, &m).unwrap();
        assert!(r.reliable);
        // v3 excludes 0,1,2 (dropped before masked input); 3 is in V3 but
        // not V4
        assert_eq!(r.sets.v3.len(), n - 3);
        assert_eq!(r.sets.v4.len(), n - 4);
        assert_eq!(r.sum.as_ref().unwrap(), &r.true_sum_v3);
    }

    #[test]
    fn unreliable_when_too_few_unmaskers() {
        // t=8 of n=10; drop 4 clients at step 3 → only 6 < t respond,
        // b_i cannot be reconstructed
        let n = 10;
        let cfg = ProtocolConfig {
            dropout: DropoutModel::Targeted {
                per_step: [vec![], vec![], vec![], vec![0, 1, 2, 3]],
            },
            ..ProtocolConfig::for_test(n, 8, 10, Topology::Complete, 5)
        };
        let m = models(n, 10, 11);
        let r = run_round(&cfg, &m).unwrap();
        assert!(!r.reliable);
        assert!(r.sum.is_none());
        assert!(!r.theorem1_holds);
    }

    #[test]
    fn engine_reliability_matches_theorem1_on_random_instances() {
        // the implementation must agree with the theorem exactly
        let mut agree = 0;
        let mut reliable_count = 0;
        let trials = 40;
        for seed in 0..trials {
            let n = 12;
            let cfg = ProtocolConfig {
                dropout: DropoutModel::Iid { q: 0.12 },
                ..ProtocolConfig::for_test(n, 5, 8, Topology::ErdosRenyi { p: 0.6 }, 1000 + seed)
            };
            let m = models(n, 8, seed);
            match run_round(&cfg, &m) {
                Ok(r) => {
                    assert_eq!(
                        r.reliable, r.theorem1_holds,
                        "seed={seed} sets={:?}",
                        r.sets
                    );
                    if r.reliable {
                        assert_eq!(r.sum.as_ref().unwrap(), &r.true_sum_v3, "seed={seed}");
                        reliable_count += 1;
                    }
                    agree += 1;
                }
                Err(_) => {
                    // |V_k| < t aborts are legitimate unreliable outcomes
                    agree += 1;
                }
            }
        }
        assert_eq!(agree, trials);
        assert!(reliable_count > 0, "at least some rounds must succeed");
    }

    #[test]
    fn sixteen_bit_masking_domain() {
        let n = 6;
        let dim = 20;
        let mut cfg = ProtocolConfig::for_test(n, 3, dim, Topology::Complete, 3);
        cfg.mask_bits = 16;
        let mut rng = Rng::new(12);
        let m: Vec<Vec<u64>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF).collect())
            .collect();
        let r = run_round(&cfg, &m).unwrap();
        assert!(r.reliable);
        assert_eq!(r.sum.as_ref().unwrap(), &r.true_sum_v3);
        assert!(r.sum.unwrap().iter().all(|&x| x < (1 << 16)));
    }

    #[test]
    fn comm_bytes_scale_with_topology() {
        // CCESA at p≈0.5 must use materially less bandwidth than SA
        let n = 40;
        let dim = 100;
        let m = models(n, dim, 13);
        let sa =
            run_round(&ProtocolConfig::for_test(n, 8, dim, Topology::Complete, 21), &m).unwrap();
        let cc = run_round(
            &ProtocolConfig::for_test(n, 8, dim, Topology::ErdosRenyi { p: 0.5 }, 21),
            &m,
        )
        .unwrap();
        assert!(cc.reliable && sa.reliable);
        // key/share traffic (steps 0,1,3) shrinks ≈ p; step 2 masked input
        // is identical
        let sa_key_traffic: u64 = sa.stats.bytes_up[0]
            + sa.stats.bytes_down[0]
            + sa.stats.bytes_up[1]
            + sa.stats.bytes_down[1]
            + sa.stats.bytes_up[3];
        let cc_key_traffic: u64 = cc.stats.bytes_up[0]
            + cc.stats.bytes_down[0]
            + cc.stats.bytes_up[1]
            + cc.stats.bytes_down[1]
            + cc.stats.bytes_up[3];
        assert!(
            (cc_key_traffic as f64) < 0.7 * sa_key_traffic as f64,
            "ccesa={cc_key_traffic} sa={sa_key_traffic}"
        );
        assert_eq!(cc.stats.bytes_up[2], sa.stats.bytes_up[2]);
    }

    #[test]
    fn sparse_codecs_recover_projected_sum_under_dropout() {
        use crate::codec::Codec;
        let n = 12;
        let dim = 40;
        let k = 7;
        let m = models(n, dim, 21);
        for codec in [Codec::RandK { k }, Codec::TopK { k }] {
            let cfg = ProtocolConfig {
                codec,
                dropout: DropoutModel::Targeted {
                    per_step: [vec![1], vec![], vec![5], vec![]],
                },
                ..ProtocolConfig::for_test(n, 4, dim, Topology::ErdosRenyi { p: 0.9 }, 2200)
            };
            let r = run_round(&cfg, &m).unwrap();
            assert!(r.reliable, "{codec:?}");
            let sum = r.sum.as_ref().unwrap();
            assert_eq!(sum.len(), dim, "{codec:?}: aggregate is always dense-length");
            assert_eq!(sum, &r.true_sum_v3, "{codec:?}");
            let nonzero = sum.iter().filter(|&&x| x != 0).count();
            assert!(nonzero <= k, "{codec:?}: {nonzero} nonzero coords > k={k}");
            // byte accounting shrinks with k: id + k·4 per masked input
            let v3 = r.sets.v3.len() as u64;
            assert_eq!(r.stats.bytes_up[2], v3 * (4 + k as u64 * 4), "{codec:?}");
            assert_eq!(r.stats.masked_payload_bytes, v3 * k as u64 * 4, "{codec:?}");
            assert_eq!(r.transcript.payload_len, k, "{codec:?}");
        }
    }

    #[test]
    fn transcript_captures_public_view() {
        let n = 6;
        let cfg = ProtocolConfig::for_test(n, 3, 5, Topology::Complete, 17);
        let m = models(n, 5, 14);
        let r = run_round(&cfg, &m).unwrap();
        let t = &r.transcript;
        assert_eq!(t.v3.len(), n);
        assert_eq!(t.masked.len(), n);
        assert_eq!(t.keys.len(), n);
        assert!(!t.unmask_shares.is_empty());
        // the transcript must NOT contain any plaintext model
        for (i, (_, masked)) in t.masked.iter().enumerate() {
            assert_ne!(masked, &m[i], "masked input equals plaintext model");
        }
    }
}
