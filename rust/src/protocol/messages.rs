//! Protocol wire messages with exact byte accounting.
//!
//! Sizes follow Appendix C's model: public keys cost `a_K` bytes each,
//! secret shares `a_S` bytes (2-byte evaluation point + 2 bytes per u16
//! chunk of the 32-byte secret), masked models `m · R/8` bytes. Framing
//! overhead (ids, lengths) is charged explicitly so measured bandwidth is
//! honest rather than formula-driven.

use super::ClientId;
use crate::codec::EncodedUpdate;
use crate::crypto::dh::PublicKey;
use crate::shamir::Share;

/// Bytes per public key (x25519).
pub const A_K: usize = 32;
/// Bytes per Shamir share of a 32-byte secret: 2 (x) + 16·2 (chunks).
pub const A_S: usize = 34;
/// Bytes per client id on the wire.
pub const ID_BYTES: usize = 4;
/// AEAD tag bytes.
pub const TAG_BYTES: usize = 16;

/// Step 0, client → server: advertise both public keys.
#[derive(Debug, Clone)]
pub struct AdvertiseKeys {
    pub id: ClientId,
    pub c_pk: PublicKey,
    pub s_pk: PublicKey,
}

impl AdvertiseKeys {
    pub fn size_bytes(&self) -> usize {
        ID_BYTES + 2 * A_K
    }
}

/// Step 0, server → client j: the public keys of Adj(j) ∩ V1.
#[derive(Debug, Clone)]
pub struct KeyBundle {
    pub entries: Vec<(ClientId, PublicKey, PublicKey)>,
}

impl KeyBundle {
    pub fn size_bytes(&self) -> usize {
        self.entries.len() * (ID_BYTES + 2 * A_K)
    }
}

/// Warm-round phase 0, client → server: resume an established session.
///
/// Replaces [`AdvertiseKeys`] on warm rounds: session keys are cached, so
/// the client only reports (a) its local TopK support — the k coordinates
/// it wants in this round's union coordinate map (sparse codecs only; the
/// bytes are charged to `NetStats::coord_map_bytes`, not setup) — and (b) a
/// fresh key pair when the ratchet forced a re-key (charged to
/// `NetStats::rekey_up`).
#[derive(Debug, Clone)]
pub struct WarmResume {
    pub id: ClientId,
    /// Local-top-k coordinate proposal (sorted ascending); `None` for
    /// codecs with a derived coordinate map (Dense, RandK).
    pub support: Option<Vec<u32>>,
    /// Fresh `(c_pk, s_pk)` when this client re-keys this round.
    pub rekey: Option<(PublicKey, PublicKey)>,
}

impl WarmResume {
    pub fn size_bytes(&self) -> usize {
        ID_BYTES + self.support_bytes() + self.rekey_bytes()
    }

    /// Coordinate-map bytes (the support proposal).
    pub fn support_bytes(&self) -> usize {
        self.support.as_ref().map_or(0, |s| s.len() * ID_BYTES)
    }

    /// Re-key traffic bytes (the fresh key pair, if any).
    pub fn rekey_bytes(&self) -> usize {
        if self.rekey.is_some() {
            2 * A_K
        } else {
            0
        }
    }
}

/// Warm-round phase 0, server → client: the session delta this client
/// needs before dealing warm shares.
///
/// Replaces [`KeyBundle`]: the neighbor keys are cached, so the server
/// sends only (a) which neighbors are alive this round (one bit each, over
/// the client's neighbor list in insertion order) and (b) replacement
/// public keys for neighbors that re-keyed — including re-keys the client
/// missed while absent (charged to `NetStats::rekey_down`).
#[derive(Debug, Clone)]
pub struct WarmPlan {
    pub to: ClientId,
    /// Bit b of byte b/8 = neighbor `neighbors(to)[b]` is in V1 this round.
    pub alive_bitmap: Vec<u8>,
    /// Fresh public keys of neighbors that re-keyed since this client last
    /// saw them.
    pub keys: Vec<(ClientId, PublicKey, PublicKey)>,
}

impl WarmPlan {
    pub fn size_bytes(&self) -> usize {
        ID_BYTES + self.alive_bitmap.len() + self.rekey_bytes()
    }

    /// Re-key traffic bytes (the replacement neighbor keys).
    pub fn rekey_bytes(&self) -> usize {
        self.keys.len() * (ID_BYTES + 2 * A_K)
    }
}

/// An encrypted pair of shares (b_{i,j}, s^{SK}_{i,j}) for one recipient.
#[derive(Debug, Clone)]
pub struct EncryptedShare {
    pub from: ClientId,
    pub to: ClientId,
    /// AEAD ciphertext of `b_share.to_bytes() || sk_share.to_bytes()`.
    pub ciphertext: Vec<u8>,
}

impl EncryptedShare {
    pub fn size_bytes(&self) -> usize {
        2 * ID_BYTES + self.ciphertext.len()
    }
}

/// Step 1, client → server: encrypted shares for every neighbor.
#[derive(Debug, Clone)]
pub struct ShareUpload {
    pub from: ClientId,
    pub shares: Vec<EncryptedShare>,
}

impl ShareUpload {
    pub fn size_bytes(&self) -> usize {
        ID_BYTES + self.shares.iter().map(|s| s.size_bytes()).sum::<usize>()
    }
}

/// Step 1, server → client j: the ciphertexts addressed to j.
#[derive(Debug, Clone)]
pub struct ShareDelivery {
    pub to: ClientId,
    pub shares: Vec<EncryptedShare>,
}

impl ShareDelivery {
    pub fn size_bytes(&self) -> usize {
        ID_BYTES + self.shares.iter().map(|s| s.size_bytes()).sum::<usize>()
    }
}

/// Step 2, client → server: the masked, codec-encoded update θ̃_i (Eq. 3).
///
/// Under [`crate::codec::Codec::Dense`] the value windows are the full
/// masked model — byte-identical to the pre-codec wire format. Sparse
/// codecs send only the round's selected coordinates; the coordinate map
/// itself is shared derived knowledge (round seed / public scoring) and
/// costs no wire bytes (see `crate::codec` module docs).
#[derive(Debug, Clone)]
pub struct MaskedInput {
    pub id: ClientId,
    /// Masked value windows + the round's shared coordinate map.
    pub update: EncodedUpdate,
    /// Wire width of each element (the aggregation domain Z_{2^bits}).
    pub bits: u32,
}

impl MaskedInput {
    pub fn size_bytes(&self) -> usize {
        ID_BYTES + self.payload_bytes()
    }

    /// Bytes of masked field elements alone (the per-codec payload that
    /// `NetStats::masked_payload_bytes` aggregates).
    pub fn payload_bytes(&self) -> usize {
        self.update.payload_bytes(self.bits)
    }
}

/// Step 2, server → client: the survivor set V3.
#[derive(Debug, Clone)]
pub struct SurvivorAnnounce {
    pub v3: Vec<ClientId>,
}

impl SurvivorAnnounce {
    pub fn size_bytes(&self) -> usize {
        self.v3.len() * ID_BYTES
    }
}

/// What secret a Step-3 share reveals.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ShareKind {
    /// Share of the PRG seed b_owner (owner survived to V3).
    SelfMask,
    /// Share of s^SK_owner (owner dropped in V2 \ V3).
    SecretKey,
}

/// Step 3, client → server: plaintext shares enabling unmasking.
#[derive(Debug, Clone)]
pub struct UnmaskShares {
    pub from: ClientId,
    /// (owner, kind, share)
    pub shares: Vec<(ClientId, ShareKind, Share)>,
}

impl UnmaskShares {
    pub fn size_bytes(&self) -> usize {
        ID_BYTES
            + self
                .shares
                .iter()
                .map(|(_, _, s)| ID_BYTES + 1 + s.size_bytes())
                .sum::<usize>()
    }
}

/// Client → server phase envelope: every live client emits exactly one
/// per phase. Shared by both deployment shapes — the thread-per-client
/// coordinator sends these over mpsc channels, the event-loop coordinator
/// collects them from per-client outbox slots after each parallel sweep.
#[derive(Debug)]
pub enum Up {
    Adv(AdvertiseKeys),
    /// Warm-round phase 0: session resume instead of key advertisement.
    Warm(WarmResume),
    Shares(ShareUpload),
    Masked(MaskedInput),
    Unmask(UnmaskShares),
    /// Client dropped during the given phase (0–3).
    Dropped(ClientId, u8),
    /// Client hit an internal error — treated as a drop, but logged.
    Failed(ClientId, u8, String),
}

impl Up {
    /// The protocol phase (0–3) this output answers — for `Dropped`/
    /// `Failed`, the phase the client was lost in. The socket server uses
    /// this to discard stale or replayed frames that arrive after their
    /// phase's barrier has passed.
    pub fn phase(&self) -> u8 {
        match self {
            Up::Adv(_) | Up::Warm(_) => 0,
            Up::Shares(_) => 1,
            Up::Masked(_) => 2,
            Up::Unmask(_) => 3,
            Up::Dropped(_, step) | Up::Failed(_, step, _) => *step,
        }
    }

    /// The client this message claims to come from.
    pub fn from(&self) -> ClientId {
        match self {
            Up::Adv(a) => a.id,
            Up::Warm(w) => w.id,
            Up::Shares(u) => u.from,
            Up::Masked(m) => m.id,
            Up::Unmask(u) => u.from,
            Up::Dropped(id, _) | Up::Failed(id, _, _) => *id,
        }
    }
}

/// Server → client phase input, consumed by [`super::client::ClientSm`].
///
/// The announce is shared (`Arc`): it is the one broadcast message — every
/// V3 member receives the same |V3|-entry survivor list, and cloning it per
/// recipient would cost O(n²) at n = 10⁵. Byte accounting still charges
/// every recipient the full `size_bytes()`.
#[derive(Debug)]
pub enum Down {
    /// Kick off phase 0 (no server payload — the round itself).
    Start,
    Bundle(KeyBundle),
    /// Warm-round phase 1 kick-off: the session delta (alive bitmap +
    /// re-keyed neighbor keys) instead of a full key bundle.
    WarmPlan(WarmPlan),
    Delivery(ShareDelivery),
    Announce(std::sync::Arc<SurvivorAnnounce>),
    /// Round over; the client is not needed further.
    Finish,
}

impl Down {
    /// The phase (0–3) this input drives, or `None` for [`Down::Finish`].
    pub fn phase(&self) -> Option<u8> {
        match self {
            Down::Start => Some(0),
            Down::Bundle(_) | Down::WarmPlan(_) => Some(1),
            Down::Delivery(_) => Some(2),
            Down::Announce(_) => Some(3),
            Down::Finish => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn share() -> Share {
        Share { x: 1, y: vec![0u16; 16] }
    }

    #[test]
    fn down_phase_indices() {
        assert_eq!(Down::Start.phase(), Some(0));
        assert_eq!(Down::Bundle(KeyBundle { entries: vec![] }).phase(), Some(1));
        assert_eq!(Down::Delivery(ShareDelivery { to: 0, shares: vec![] }).phase(), Some(2));
        let ann = std::sync::Arc::new(SurvivorAnnounce { v3: vec![] });
        assert_eq!(Down::Announce(ann).phase(), Some(3));
        assert_eq!(Down::Finish.phase(), None);
    }

    #[test]
    fn up_phase_and_sender() {
        let adv = Up::Adv(AdvertiseKeys { id: 4, c_pk: [0; 32], s_pk: [0; 32] });
        assert_eq!((adv.phase(), adv.from()), (0, 4));
        let sh = Up::Shares(ShareUpload { from: 2, shares: vec![] });
        assert_eq!((sh.phase(), sh.from()), (1, 2));
        let un = Up::Unmask(UnmaskShares { from: 7, shares: vec![] });
        assert_eq!((un.phase(), un.from()), (3, 7));
        let d = Up::Dropped(5, 2);
        assert_eq!((d.phase(), d.from()), (2, 5));
        let f = Up::Failed(6, 1, "x".into());
        assert_eq!((f.phase(), f.from()), (1, 6));
    }

    #[test]
    fn sizes_follow_appendix_c_model() {
        let adv = AdvertiseKeys { id: 0, c_pk: [0; 32], s_pk: [0; 32] };
        assert_eq!(adv.size_bytes(), 4 + 64);

        let bundle = KeyBundle { entries: vec![(1, [0; 32], [0; 32]); 7] };
        assert_eq!(bundle.size_bytes(), 7 * 68);

        assert_eq!(share().size_bytes(), A_S);

        let dense = crate::codec::IndexPlan::identity(100);
        let mi = MaskedInput {
            id: 3,
            update: EncodedUpdate { values: vec![0; 100], plan: dense.clone() },
            bits: 32,
        };
        assert_eq!(mi.size_bytes(), 4 + 400);
        assert_eq!(mi.payload_bytes(), 400);
        let mi16 = MaskedInput {
            id: 3,
            update: EncodedUpdate { values: vec![0; 100], plan: dense },
            bits: 16,
        };
        assert_eq!(mi16.size_bytes(), 4 + 200);

        // a sparse update charges only its value windows: the coordinate
        // map is derived, not transmitted
        let sparse = crate::codec::IndexPlan::sparse(vec![5, 9, 77], 100);
        let mi_sparse = MaskedInput {
            id: 3,
            update: EncodedUpdate { values: vec![0; 3], plan: sparse },
            bits: 32,
        };
        assert_eq!(mi_sparse.size_bytes(), 4 + 12);
        assert_eq!(mi_sparse.payload_bytes(), 12);

        let um = UnmaskShares {
            from: 0,
            shares: vec![(1, ShareKind::SelfMask, share()), (2, ShareKind::SecretKey, share())],
        };
        assert_eq!(um.size_bytes(), 4 + 2 * (4 + 1 + A_S));
    }

    #[test]
    fn warm_message_sizes_split_by_accounting_bucket() {
        let wr = WarmResume { id: 1, support: Some(vec![3, 9, 40]), rekey: None };
        assert_eq!(wr.support_bytes(), 12);
        assert_eq!(wr.rekey_bytes(), 0);
        assert_eq!(wr.size_bytes(), 4 + 12);
        let wr2 = WarmResume { id: 1, support: None, rekey: Some(([0; 32], [0; 32])) };
        assert_eq!(wr2.size_bytes(), 4 + 64);
        assert_eq!(wr2.rekey_bytes(), 64);

        let wp = WarmPlan {
            to: 2,
            alive_bitmap: vec![0xFF, 0x01],
            keys: vec![(5, [0; 32], [0; 32])],
        };
        assert_eq!(wp.rekey_bytes(), 68);
        assert_eq!(wp.size_bytes(), 4 + 2 + 68);

        let up = Up::Warm(WarmResume { id: 9, support: None, rekey: None });
        assert_eq!((up.phase(), up.from()), (0, 9));
        let down = Down::WarmPlan(WarmPlan { to: 0, alive_bitmap: vec![], keys: vec![] });
        assert_eq!(down.phase(), Some(1));
    }

    #[test]
    fn encrypted_share_size_tracks_ciphertext() {
        let e = EncryptedShare { from: 0, to: 1, ciphertext: vec![0u8; 2 * A_S + TAG_BYTES] };
        assert_eq!(e.size_bytes(), 8 + 68 + 16);
        let up = ShareUpload { from: 0, shares: vec![e.clone(), e] };
        assert_eq!(up.size_bytes(), 4 + 2 * 92);
    }
}
