//! Runtime-dispatched vectorized kernels for the two scalar inner loops
//! left on the hot path: GF(2^16) weight application (Shamir `split` /
//! Lagrange Step 3) and multi-seed mask application (client Step 2 /
//! server unmasking).
//!
//! # Backends
//!
//! | backend  | GF(2^16) multiply                         | availability      |
//! |----------|-------------------------------------------|-------------------|
//! | `scalar` | log/exp tables (`gf::gf65536::mul`)       | always (oracle)   |
//! | `table`  | 4-bit nibble split tables per constant    | always (fallback) |
//! | `clmul`  | carry-less multiply + Barrett reduction   | `pclmulqdq` (x86) |
//! |          |                                           | / `pmull` (arm)   |
//!
//! The backend is decided **once per process** by [`dispatch`]: the
//! `CCESA_KERNEL` environment variable (`scalar` / `table` / `clmul`) wins
//! when the named backend is available on this CPU, otherwise selection
//! falls back to the best available vector backend (`clmul` if the cpuid
//! feature is present, else `table`) and the fallback is recorded. The
//! decision is reported through `ccesa kernels` (JSON), the bench reports
//! (`Bench::to_json`'s `kernel_backend` field) and the event-loop
//! telemetry, so CI can assert which backend a run actually exercised.
//!
//! # Determinism
//!
//! Every backend computes the *same field product*: GF(2^16) arithmetic is
//! exact (no rounding, no reassociation hazard — addition is XOR), so
//! `scalar`, `table` and `clmul` are bit-identical on every input by
//! construction, and the property suite (`tests/gf_kernels.rs`, the
//! `kernel-matrix` CI job) verifies it against the scalar oracle. The
//! fused mask kernel applies exactly the same keystream word to each
//! accumulator element as the one-pass-per-seed form — Z_{2^b} addition is
//! elementwise and commutative — so fusing seeds changes memory traffic,
//! never results.

use crate::crypto::chacha20::{ChaCha20, BATCH_BLOCKS, WORDS_PER_BLOCK};
use crate::gf::gf65536 as gf;
use crate::util::json::Json;
use crate::util::mod_mask;
use std::sync::OnceLock;

/// The reduction polynomial of GF(2^16) as a u64 clmul operand.
const POLY64: u64 = gf::POLY as u64;

/// A GF(2^16) kernel backend. `Scalar` is the per-element log/exp-table
/// oracle; `Table` and `Clmul` are the vectorized implementations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    Scalar,
    Table,
    Clmul,
}

impl Backend {
    pub const ALL: [Backend; 3] = [Backend::Scalar, Backend::Table, Backend::Clmul];

    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Table => "table",
            Backend::Clmul => "clmul",
        }
    }

    /// Parse a `CCESA_KERNEL` value.
    pub fn parse(s: &str) -> Option<Backend> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Backend::Scalar),
            "table" => Some(Backend::Table),
            "clmul" => Some(Backend::Clmul),
            _ => None,
        }
    }

    /// Whether this backend can run on the current CPU.
    pub fn available(self) -> bool {
        match self {
            Backend::Scalar | Backend::Table => true,
            Backend::Clmul => clmul_supported(),
        }
    }
}

/// The backends runnable on this CPU, in `Backend::ALL` order.
pub fn available_backends() -> Vec<Backend> {
    Backend::ALL.into_iter().filter(|b| b.available()).collect()
}

#[cfg(target_arch = "x86_64")]
fn clmul_supported() -> bool {
    std::is_x86_feature_detected!("pclmulqdq")
}

#[cfg(target_arch = "aarch64")]
fn clmul_supported() -> bool {
    std::arch::is_aarch64_feature_detected!("pmull")
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn clmul_supported() -> bool {
    false
}

/// The process-wide dispatch decision and how it was reached.
#[derive(Debug, Clone)]
pub struct Dispatch {
    /// The backend every dispatched kernel call uses.
    pub selected: Backend,
    /// Raw `CCESA_KERNEL` value, if one was set.
    pub requested: Option<String>,
    /// The request named an unknown or unavailable backend and selection
    /// fell back to the default.
    pub fell_back: bool,
}

fn default_backend() -> Backend {
    if Backend::Clmul.available() {
        Backend::Clmul
    } else {
        Backend::Table
    }
}

/// Backend selection, decided once per process (first call wins): honor
/// `CCESA_KERNEL` when the named backend is available, otherwise the best
/// available vector backend. `Scalar` is never selected by default — it
/// exists as the explicit oracle/baseline.
pub fn dispatch() -> &'static Dispatch {
    static D: OnceLock<Dispatch> = OnceLock::new();
    D.get_or_init(|| {
        let requested = std::env::var("CCESA_KERNEL").ok().filter(|s| !s.is_empty());
        let (selected, fell_back) = match requested.as_deref().map(Backend::parse) {
            Some(Some(b)) if b.available() => (b, false),
            Some(_) => (default_backend(), true),
            None => (default_backend(), false),
        };
        Dispatch { selected, requested, fell_back }
    })
}

/// The backend dispatched kernel calls run on (see [`dispatch`]).
pub fn selected() -> Backend {
    dispatch().selected
}

/// Machine-readable dispatch report for `ccesa kernels` and the CI audit:
/// selected backend, the `CCESA_KERNEL` request (if any), whether the
/// request fell back, cpuid features and the available-backend list.
pub fn report_json() -> Json {
    let d = dispatch();
    Json::obj(vec![
        ("backend", Json::str(d.selected.name())),
        (
            "requested",
            match &d.requested {
                Some(r) => Json::str(r),
                None => Json::Null,
            },
        ),
        ("fell_back", Json::Bool(d.fell_back)),
        ("arch", Json::str(std::env::consts::ARCH)),
        ("features", Json::obj(vec![("clmul", Json::Bool(clmul_supported()))])),
        ("available", Json::arr(available_backends().into_iter().map(|b| Json::str(b.name())))),
    ])
}

// ---------------------------------------------------------------------------
// GF(2^16) slice primitives
// ---------------------------------------------------------------------------

/// `acc[k] = acc[k] · w` in GF(2^16) — multiply a whole share vector by one
/// scalar weight (Shamir Horner step), on the dispatched backend.
pub fn gf_mul_slice_const(acc: &mut [u16], w: u16) {
    gf_mul_slice_const_with(selected(), acc, w);
}

/// `acc[k] ^= src[k] · w` in GF(2^16) — Lagrange Step-3 weight
/// multiply-accumulate, on the dispatched backend.
pub fn gf_fma_slice(acc: &mut [u16], src: &[u16], w: u16) {
    gf_fma_slice_with(selected(), acc, src, w);
}

/// [`gf_mul_slice_const`] on an explicit backend (tests, benches; the
/// protocol paths use the dispatched form).
pub fn gf_mul_slice_const_with(backend: Backend, acc: &mut [u16], w: u16) {
    if w == 0 {
        acc.fill(0);
        return;
    }
    if w == 1 {
        return;
    }
    match backend {
        Backend::Scalar => {
            for a in acc.iter_mut() {
                *a = gf::mul(*a, w);
            }
        }
        Backend::Table => table_mul_slice(acc, w),
        Backend::Clmul => clmul_mul_slice(acc, w),
    }
}

/// [`gf_fma_slice`] on an explicit backend. Panics if the slice lengths
/// differ.
pub fn gf_fma_slice_with(backend: Backend, acc: &mut [u16], src: &[u16], w: u16) {
    assert_eq!(acc.len(), src.len(), "gf_fma_slice: length mismatch");
    if w == 0 {
        return;
    }
    if w == 1 {
        for (a, &x) in acc.iter_mut().zip(src) {
            *a ^= x;
        }
        return;
    }
    match backend {
        Backend::Scalar => {
            for (a, &x) in acc.iter_mut().zip(src) {
                *a ^= gf::mul(x, w);
            }
        }
        Backend::Table => table_fma_slice(acc, src, w),
        Backend::Clmul => clmul_fma_slice(acc, src, w),
    }
}

/// Below this length the per-call nibble-table build (60 scalar multiplies)
/// costs more than it saves; the table backend degrades to the scalar loop.
/// Purely a performance heuristic — results are identical either way.
const TABLE_MIN_LEN: usize = 64;

/// 4-bit nibble split tables for one constant multiplier `w`:
/// `t[n][v] = w · (v << 4n)`, so `w · x` is four L1-resident lookups and
/// three XORs per element — no zero-check branches, no dependent walks
/// through the 192 KiB log/exp tables.
#[inline]
fn nibble_tables(w: u16) -> [[u16; 16]; 4] {
    let mut t = [[0u16; 16]; 4];
    for (shift, tbl) in t.iter_mut().enumerate() {
        for (v, e) in tbl.iter_mut().enumerate().skip(1) {
            *e = gf::mul(w, (v as u16) << (4 * shift));
        }
    }
    t
}

fn table_mul_slice(acc: &mut [u16], w: u16) {
    if acc.len() < TABLE_MIN_LEN {
        for a in acc.iter_mut() {
            *a = gf::mul(*a, w);
        }
        return;
    }
    let t = nibble_tables(w);
    for a in acc.iter_mut() {
        let x = *a;
        *a = t[0][(x & 0xF) as usize]
            ^ t[1][((x >> 4) & 0xF) as usize]
            ^ t[2][((x >> 8) & 0xF) as usize]
            ^ t[3][((x >> 12) & 0xF) as usize];
    }
}

fn table_fma_slice(acc: &mut [u16], src: &[u16], w: u16) {
    if acc.len() < TABLE_MIN_LEN {
        for (a, &x) in acc.iter_mut().zip(src) {
            *a ^= gf::mul(x, w);
        }
        return;
    }
    let t = nibble_tables(w);
    for (a, &x) in acc.iter_mut().zip(src) {
        *a ^= t[0][(x & 0xF) as usize]
            ^ t[1][((x >> 4) & 0xF) as usize]
            ^ t[2][((x >> 8) & 0xF) as usize]
            ^ t[3][((x >> 12) & 0xF) as usize];
    }
}

fn clmul_mul_slice(acc: &mut [u16], w: u16) {
    // Soundness gate, not just a dispatch invariant: `_with(Backend::Clmul)`
    // is a safe public API, so executing the intrinsics must be guarded
    // here — on a CPU without the feature the call degrades to the portable
    // backend (identical results) instead of hitting UB/SIGILL. The cpuid
    // probe is cached by std, so the check is an atomic load.
    if !clmul_supported() {
        table_mul_slice(acc, w);
        return;
    }
    #[cfg(target_arch = "x86_64")]
    // SAFETY: pclmulqdq presence verified by `clmul_supported` above.
    unsafe {
        clmul_x86::mul_slice(acc, w);
    }
    #[cfg(target_arch = "aarch64")]
    // SAFETY: pmull presence verified by `clmul_supported` above.
    unsafe {
        clmul_arm::mul_slice(acc, w);
    }
}

fn clmul_fma_slice(acc: &mut [u16], src: &[u16], w: u16) {
    // Soundness gate — see `clmul_mul_slice`.
    if !clmul_supported() {
        table_fma_slice(acc, src, w);
        return;
    }
    #[cfg(target_arch = "x86_64")]
    // SAFETY: pclmulqdq presence verified by `clmul_supported` above.
    unsafe {
        clmul_x86::fma_slice(acc, src, w);
    }
    #[cfg(target_arch = "aarch64")]
    // SAFETY: pmull presence verified by `clmul_supported` above.
    unsafe {
        clmul_arm::fma_slice(acc, src, w);
    }
}

/// `x^32 div POLY` in GF(2) polynomial arithmetic — the Barrett quotient
/// constant for 16-bit reduction. Derivation (carry-less long division of
/// x^32 by 0x1100B) yields bits {16, 12, 8, 4, 3, 1}.
const BARRETT_MU: u64 = 0x1111A;

#[cfg(target_arch = "x86_64")]
mod clmul_x86 {
    //! `pclmulqdq` GF(2^16) slice kernels. Two u16 elements are packed at
    //! 32-bit spacing into one 64-bit clmul operand — their ≤31-bit
    //! carry-less products cannot overlap — and both products are
    //! Barrett-reduced in lock-step with two more packed clmuls: 3 clmuls
    //! per 2 elements, no table memory at all.

    use core::arch::x86_64::{_mm_clmulepi64_si128, _mm_cvtsi128_si64, _mm_cvtsi64_si128};

    #[inline]
    #[target_feature(enable = "pclmulqdq")]
    unsafe fn clmul(a: u64, b: u64) -> u64 {
        _mm_cvtsi128_si64(_mm_clmulepi64_si128(
            _mm_cvtsi64_si128(a as i64),
            _mm_cvtsi64_si128(b as i64),
            0,
        )) as u64
    }

    /// Reduce two ≤31-bit carry-less products packed at bits 0 and 32 to
    /// their GF(2^16) residues (same packing): for each product `c`,
    /// `q = ((c >> 16) · MU) >> 16` is the exact quotient `c div POLY`, so
    /// `c ^ q · POLY` is the remainder.
    #[inline]
    #[target_feature(enable = "pclmulqdq")]
    unsafe fn barrett_pair(c: u64) -> u64 {
        let h = ((c >> 16) & 0xFFFF) | ((c >> 48) << 32);
        let t = clmul(h, super::BARRETT_MU);
        let q = ((t >> 16) & 0xFFFF) | ((t >> 48) << 32);
        (c ^ clmul(q, super::POLY64)) & 0x0000_FFFF_0000_FFFF
    }

    #[target_feature(enable = "pclmulqdq")]
    pub unsafe fn mul_slice(acc: &mut [u16], w: u16) {
        let w = w as u64;
        let mut pairs = acc.chunks_exact_mut(2);
        for pair in pairs.by_ref() {
            let v = pair[0] as u64 | ((pair[1] as u64) << 32);
            let r = barrett_pair(clmul(v, w));
            pair[0] = r as u16;
            pair[1] = (r >> 32) as u16;
        }
        if let [last] = pairs.into_remainder() {
            let r = barrett_pair(clmul(*last as u64, w));
            *last = r as u16;
        }
    }

    #[target_feature(enable = "pclmulqdq")]
    pub unsafe fn fma_slice(acc: &mut [u16], src: &[u16], w: u16) {
        let w = w as u64;
        let mut apairs = acc.chunks_exact_mut(2);
        let mut spairs = src.chunks_exact(2);
        for (a, s) in apairs.by_ref().zip(spairs.by_ref()) {
            let v = s[0] as u64 | ((s[1] as u64) << 32);
            let r = barrett_pair(clmul(v, w));
            a[0] ^= r as u16;
            a[1] ^= (r >> 32) as u16;
        }
        if let ([a], [s]) = (apairs.into_remainder(), spairs.remainder()) {
            let r = barrett_pair(clmul(*s as u64, w));
            *a ^= r as u16;
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod clmul_arm {
    //! NEON `pmull` GF(2^16) slice kernels — the same packed-pair Barrett
    //! scheme as the x86 module (see there for the math).

    use core::arch::aarch64::vmull_p64;

    #[inline]
    #[target_feature(enable = "neon,aes")]
    unsafe fn clmul(a: u64, b: u64) -> u64 {
        vmull_p64(a, b) as u64
    }

    #[inline]
    #[target_feature(enable = "neon,aes")]
    unsafe fn barrett_pair(c: u64) -> u64 {
        let h = ((c >> 16) & 0xFFFF) | ((c >> 48) << 32);
        let t = clmul(h, super::BARRETT_MU);
        let q = ((t >> 16) & 0xFFFF) | ((t >> 48) << 32);
        (c ^ clmul(q, super::POLY64)) & 0x0000_FFFF_0000_FFFF
    }

    #[target_feature(enable = "neon,aes")]
    pub unsafe fn mul_slice(acc: &mut [u16], w: u16) {
        let w = w as u64;
        let mut pairs = acc.chunks_exact_mut(2);
        for pair in pairs.by_ref() {
            let v = pair[0] as u64 | ((pair[1] as u64) << 32);
            let r = barrett_pair(clmul(v, w));
            pair[0] = r as u16;
            pair[1] = (r >> 32) as u16;
        }
        if let [last] = pairs.into_remainder() {
            let r = barrett_pair(clmul(*last as u64, w));
            *last = r as u16;
        }
    }

    #[target_feature(enable = "neon,aes")]
    pub unsafe fn fma_slice(acc: &mut [u16], src: &[u16], w: u16) {
        let w = w as u64;
        let mut apairs = acc.chunks_exact_mut(2);
        let mut spairs = src.chunks_exact(2);
        for (a, s) in apairs.by_ref().zip(spairs.by_ref()) {
            let v = s[0] as u64 | ((s[1] as u64) << 32);
            let r = barrett_pair(clmul(v, w));
            a[0] ^= r as u16;
            a[1] ^= (r >> 32) as u16;
        }
        if let ([a], [s]) = (apairs.into_remainder(), spairs.remainder()) {
            let r = barrett_pair(clmul(*s as u64, w));
            *a ^= r as u16;
        }
    }
}

// ---------------------------------------------------------------------------
// Fused multi-seed mask application
// ---------------------------------------------------------------------------

/// One PRG mask stream for the fused application kernel: the ChaCha20 key,
/// its domain-separating nonce and the application sign.
#[derive(Debug, Clone)]
pub struct MaskStream {
    pub seed: [u8; 32],
    pub nonce: [u8; 12],
    pub negate: bool,
}

/// Keystream words per vectorized ChaCha20 batch (16 blocks × 16 words).
const BATCH_WORDS: usize = BATCH_BLOCKS * WORDS_PER_BLOCK;
/// Elements per block on the wide (b > 32) layout: two u32 words each.
const WIDE_PER_BLOCK: usize = WORDS_PER_BLOCK / 2;
/// Elements per vectorized batch on the wide layout.
const WIDE_PER_BATCH: usize = BATCH_BLOCKS * WIDE_PER_BLOCK;

/// Apply every stream's keystream range to `acc` (a shard whose first
/// element is global index `start`) in **one pass over the accumulator**:
/// keystream-major blocking expands all streams for one ≤256-word block of
/// the shard before moving to the next block, so the accumulator is read
/// and written once instead of once per seed — ~(d+1)× less accumulator
/// traffic for a degree-d client.
///
/// Element semantics are exactly those of the one-pass-per-seed form
/// (`prg::apply_mask_range` per stream): each element sees the same
/// keystream words with the same signs, and Z_{2^b} addition is
/// elementwise and commutative, so the result is bit-identical for any
/// stream count, block size or shard partition.
pub fn apply_masks_fused(acc: &mut [u64], streams: &[MaskStream], bits: u32, start: usize) {
    let ciphers: Vec<(ChaCha20, bool)> =
        streams.iter().map(|s| (ChaCha20::new(&s.seed, &s.nonce), s.negate)).collect();
    fused_pass(acc, &ciphers, bits, start);
}

/// Single-stream form of [`apply_masks_fused`] without the per-call
/// allocation — the implementation behind `prg::apply_mask_range` (and so
/// also behind the serial `prg::apply_mask`): one code path for serial,
/// sharded and fused application, so they can never diverge.
pub fn apply_mask_stream(
    acc: &mut [u64],
    seed: &[u8; 32],
    nonce: &[u8; 12],
    bits: u32,
    negate: bool,
    start: usize,
) {
    fused_pass(acc, &[(ChaCha20::new(seed, nonce), negate)], bits, start);
}

fn fused_pass(acc: &mut [u64], streams: &[(ChaCha20, bool)], bits: u32, start: usize) {
    if acc.is_empty() || streams.is_empty() {
        return;
    }
    let modmask = mod_mask(bits);
    let len = acc.len();
    let mut batch = [0u32; BATCH_WORDS];
    let mut pos = 0usize;
    if bits <= 32 {
        // One u32 of keystream per element: element `e` is word `e`, i.e.
        // lane `e % 16` of block `e / 16` (§Perf: x16 batches).
        while pos < len {
            let g = start + pos;
            let counter = (g / WORDS_PER_BLOCK) as u32;
            let skip = g % WORDS_PER_BLOCK;
            let take = (BATCH_WORDS - skip).min(len - pos);
            let chunk = &mut acc[pos..pos + take];
            for (cipher, negate) in streams {
                cipher.block_words_x16(counter, &mut batch);
                let ks = &batch[skip..skip + take];
                if *negate {
                    for (a, w) in chunk.iter_mut().zip(ks) {
                        *a = a.wrapping_sub(*w as u64 & modmask) & modmask;
                    }
                } else {
                    for (a, w) in chunk.iter_mut().zip(ks) {
                        *a = a.wrapping_add(*w as u64 & modmask) & modmask;
                    }
                }
            }
            pos += take;
        }
    } else {
        // Two u32s per element: element `e` is words 2e, 2e+1 of the
        // stream — 8 elements per block, 128 per x16 batch.
        while pos < len {
            let g = start + pos;
            let counter = (g / WIDE_PER_BLOCK) as u32;
            let skip = g % WIDE_PER_BLOCK;
            let take = (WIDE_PER_BATCH - skip).min(len - pos);
            let chunk = &mut acc[pos..pos + take];
            for (cipher, negate) in streams {
                cipher.block_words_x16(counter, &mut batch);
                for (k, a) in chunk.iter_mut().enumerate() {
                    let lo = batch[2 * (skip + k)] as u64;
                    let hi = batch[2 * (skip + k) + 1] as u64;
                    let m = (lo | (hi << 32)) & modmask;
                    *a = if *negate { a.wrapping_sub(m) } else { a.wrapping_add(m) } & modmask;
                }
            }
            pos += take;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn backend_names_parse_round_trip() {
        for b in Backend::ALL {
            assert_eq!(Backend::parse(b.name()), Some(b));
        }
        assert_eq!(Backend::parse(" CLMUL "), Some(Backend::Clmul));
        assert_eq!(Backend::parse("avx512"), None);
        assert_eq!(Backend::parse(""), None);
    }

    #[test]
    fn dispatch_selects_an_available_backend() {
        let d = dispatch();
        assert!(d.selected.available());
        // without an explicit request, scalar is never the default
        if d.requested.is_none() {
            assert_ne!(d.selected, Backend::Scalar);
        }
        // the report is parseable and names the selected backend
        let j = Json::parse(&report_json().to_string()).unwrap();
        assert_eq!(j.get("backend").as_str(), Some(d.selected.name()));
        assert!(j.get("available").as_arr().unwrap().len() >= 2);
    }

    #[test]
    fn scalar_and_table_always_available() {
        let av = available_backends();
        assert!(av.contains(&Backend::Scalar));
        assert!(av.contains(&Backend::Table));
        assert_eq!(av.contains(&Backend::Clmul), Backend::Clmul.available());
    }

    #[test]
    fn nibble_tables_reproduce_field_products() {
        let mut rng = Rng::new(0x7AB1E);
        for _ in 0..50 {
            let w = rng.next_u32() as u16;
            let t = nibble_tables(w);
            for _ in 0..20 {
                let x = rng.next_u32() as u16;
                let via = t[0][(x & 0xF) as usize]
                    ^ t[1][((x >> 4) & 0xF) as usize]
                    ^ t[2][((x >> 8) & 0xF) as usize]
                    ^ t[3][((x >> 12) & 0xF) as usize];
                assert_eq!(via, gf::mul(x, w), "x={x:#x} w={w:#x}");
            }
        }
    }

    #[test]
    fn every_available_backend_matches_scalar_mul() {
        let mut rng = Rng::new(0xBACE);
        let weights = [0u16, 1, 2, 3, 0x8000, 0xFFFF, 0x1001];
        for backend in available_backends() {
            for len in [0usize, 1, 2, 3, 16, 17, 63, 64, 65, 257] {
                let src: Vec<u16> = (0..len).map(|_| rng.next_u32() as u16).collect();
                for w in weights.into_iter().chain((0..4).map(|_| rng.next_u32() as u16)) {
                    let mut got = src.clone();
                    gf_mul_slice_const_with(backend, &mut got, w);
                    let expect: Vec<u16> = src.iter().map(|&x| gf::mul(x, w)).collect();
                    assert_eq!(got, expect, "{backend:?} len={len} w={w:#x}");

                    let mut acc: Vec<u16> = (0..len).map(|_| rng.next_u32() as u16).collect();
                    let manual: Vec<u16> =
                        acc.iter().zip(&src).map(|(&a, &x)| a ^ gf::mul(x, w)).collect();
                    gf_fma_slice_with(backend, &mut acc, &src, w);
                    assert_eq!(acc, manual, "{backend:?} fma len={len} w={w:#x}");
                }
            }
        }
    }

    #[test]
    fn fused_single_stream_equals_expand_then_add() {
        // independent oracle: materialize the stream via prg::expand_masks
        // (which does not go through the fused kernel) and add manually
        use crate::crypto::prg::{expand_masks, NONCE_SELF};
        let seed = [0x5Au8; 32];
        for bits in [16u32, 32, 48, 64] {
            let modm = mod_mask(bits);
            let mut full = vec![0u64; 700];
            expand_masks(&seed, &NONCE_SELF, bits, &mut full);
            for (start, len) in [(0usize, 700usize), (3, 300), (255, 258), (511, 150)] {
                let base: Vec<u64> = (0..len as u64).map(|i| (i * 977) & modm).collect();
                let mut got = base.clone();
                apply_mask_stream(&mut got, &seed, &NONCE_SELF, bits, false, start);
                let expect: Vec<u64> = base
                    .iter()
                    .zip(&full[start..start + len])
                    .map(|(b, m)| b.wrapping_add(*m) & modm)
                    .collect();
                assert_eq!(got, expect, "bits={bits} start={start} len={len}");
            }
        }
    }
}
