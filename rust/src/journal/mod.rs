//! Append-only round journal + crash recovery.
//!
//! The paper's Theorem 1 is about surviving *client* dropout; this module
//! removes the remaining single point of failure — the server process. A
//! journaled [`Server`](crate::protocol::server::Server) writes every state
//! transition to an append-only, length-prefixed, CRC-checksummed record
//! log *before* applying it (journal-then-apply, via the
//! [`RoundSink`](crate::protocol::server::RoundSink) hook), and
//! [`recover`] replays the log through a fresh server to a bit-identical
//! state: same survivor sets, same regenerated `Down` frames (byte-equal),
//! same final sum.
//!
//! ## Record format
//!
//! ```text
//! record := len:u32le  crc:u32le  body
//! body   := version:u8  rec_type:u8  round:u32le  payload
//! ```
//!
//! `len` counts the body only; `crc` is CRC-32 (IEEE) over the body. The
//! framing mirrors the `wire` codec deliberately — same length-prefix
//! discipline, same bounds-checked [`Reader`](crate::wire) cursor, same
//! contract: malformed bytes return [`JournalError`], never panic.
//!
//! ## Durability and the torn tail
//!
//! Every append is one `write_all` followed by `sync_data`, so at most the
//! *last* record can be torn by a crash. [`scan`] therefore treats an
//! incomplete trailing record (header or body running past EOF) as a torn
//! tail: it is dropped and recovery proceeds on the valid prefix (the
//! on-disk file is truncated back to the prefix before the journal is
//! reopened for appends). A *complete* record that fails its CRC is
//! corruption, not a torn write, and surfaces as a named error.
//!
//! ## Replay = re-execution
//!
//! Recovery does not deserialize server internals; it re-executes the
//! journaled batches through the ordinary `Server` step methods in record
//! order. That works because every server collection is a `BTreeMap` and
//! per-entry push order equals batch iteration order, so replay is
//! bit-identical by construction — including the regenerated `Down`
//! frames, which the crash harness asserts byte-equal against the
//! pre-crash originals. The `announce`/`checkpoint`/`final` records are
//! pure cross-checks: recovery recomputes each and refuses to resume on a
//! mismatch.
//!
//! Record types `0x40..` are reserved for callers of the raw
//! [`LogWriter`]/[`read_log`] API (the campaign runner journals per-round
//! outcomes there — see `sim::campaign::run_campaign_resumable`).

use crate::codec::IndexPlan;
use crate::crypto::dh::PublicKey;
use crate::graph::Graph;
use crate::protocol::messages::*;
use crate::protocol::server::{RoundOutput, RoundSink, Server, WarmCtx};
use crate::protocol::{ClientId, SurvivorSets};
use crate::wire::{self, Reader, WireError};
use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use thiserror::Error;

/// Journal format version carried in every record.
pub const JOURNAL_VERSION: u8 = 1;
/// Record bytes before the payload: version (1) + rec type (1) + round (4).
pub const BODY_HEADER: usize = 6;
/// Bytes of the per-record length + checksum prefix.
pub const PREFIX_BYTES: usize = 8;
/// Upper bound on one record body — same cap as `wire::MAX_FRAME`: a
/// length above this is corruption, not an allocation request.
pub const MAX_RECORD: usize = 1 << 30;

/// Round setup: config scalars + index plan + verbatim graph adjacency.
pub const RT_SETUP: u8 = 0x01;
/// One phase's `Up` batch, as concatenated wire frames.
pub const RT_UPS: u8 = 0x02;
/// The survivor announce (cross-check; replay recomputes it).
pub const RT_ANNOUNCE: u8 = 0x03;
/// Packed accumulator Σ θ̃ checkpoint at finalize entry (cross-check).
pub const RT_CHECKPOINT: u8 = 0x04;
/// The round output (cross-check; replay recomputes it).
pub const RT_FINAL: u8 = 0x05;
/// First record type available to raw-log users (campaign logs etc.).
pub const RT_USER_BASE: u8 = 0x40;

/// Everything that can go wrong writing, scanning or replaying a journal.
/// Decoders and the replay path return these; they never panic on input
/// bytes.
#[derive(Debug, Error)]
pub enum JournalError {
    #[error("journal io: {0}")]
    Io(#[from] std::io::Error),
    #[error(
        "journal record at byte {offset}: checksum mismatch \
         (stored {stored:08x}, computed {computed:08x})"
    )]
    Checksum { offset: u64, stored: u32, computed: u32 },
    #[error("journal record at byte {offset}: {what}")]
    Corrupt { offset: u64, what: &'static str },
    #[error("unsupported journal version {0}")]
    BadVersion(u8),
    #[error("unknown journal record type 0x{0:02x}")]
    BadRecordType(u8),
    #[error("malformed journal payload: {0}")]
    Malformed(#[from] WireError),
    #[error("journal setup record invalid: {0}")]
    BadSetup(String),
    #[error("journal record tagged round {found:08x}, journal is round {expected:08x}")]
    WrongRound { expected: u32, found: u32 },
    #[error("journal has no setup record")]
    MissingSetup,
    #[error("journal replay failed: {0}")]
    Replay(String),
    #[error("journaled accumulator checkpoint does not match the replayed server state")]
    CheckpointMismatch,
    #[error("journaled survivor announce does not match the replayed server state")]
    AnnounceMismatch,
    #[error("journaled final output does not match the replayed round output")]
    FinalMismatch,
}

// ---------------------------------------------------------------------------
// CRC-32 (IEEE 802.3, reflected, poly 0xEDB88320) — dependency-free.

const CRC_TABLE: [u32; 256] = crc_table();

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// CRC-32 over `bytes` (the checksum in every record prefix).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------------
// Raw record layer

/// One decoded record: type, round tag, payload bytes, and the byte offset
/// its prefix starts at (for truncation and error reporting).
#[derive(Debug, Clone)]
pub struct RawRecord {
    pub rec_type: u8,
    pub round: u32,
    pub payload: Vec<u8>,
    pub offset: u64,
}

/// Scan a journal byte buffer into records. Returns the records plus the
/// byte length of the valid prefix. An *incomplete* trailing record (fewer
/// bytes than its header or declared body) is a torn tail: dropped, never
/// an error. A *complete* record with a bad checksum, an absurd length or
/// an unknown version is corruption and returns a named error.
pub fn scan(bytes: &[u8]) -> Result<(Vec<RawRecord>, usize), JournalError> {
    let mut records = Vec::new();
    let mut pos = 0usize;
    while bytes.len() - pos >= PREFIX_BYTES {
        let offset = pos as u64;
        let len =
            u32::from_le_bytes([bytes[pos], bytes[pos + 1], bytes[pos + 2], bytes[pos + 3]])
                as usize;
        let stored = u32::from_le_bytes([
            bytes[pos + 4],
            bytes[pos + 5],
            bytes[pos + 6],
            bytes[pos + 7],
        ]);
        if len > MAX_RECORD {
            return Err(JournalError::Corrupt { offset, what: "record length exceeds MAX_RECORD" });
        }
        if len < BODY_HEADER {
            return Err(JournalError::Corrupt {
                offset,
                what: "record length shorter than the body header",
            });
        }
        if bytes.len() - pos - PREFIX_BYTES < len {
            break; // torn tail: body runs past EOF
        }
        let body = &bytes[pos + PREFIX_BYTES..pos + PREFIX_BYTES + len];
        let computed = crc32(body);
        if computed != stored {
            return Err(JournalError::Checksum { offset, stored, computed });
        }
        if body[0] != JOURNAL_VERSION {
            return Err(JournalError::BadVersion(body[0]));
        }
        let rec_type = body[1];
        let round = u32::from_le_bytes([body[2], body[3], body[4], body[5]]);
        records.push(RawRecord {
            rec_type,
            round,
            payload: body[BODY_HEADER..].to_vec(),
            offset,
        });
        pos += PREFIX_BYTES + len;
    }
    Ok((records, pos))
}

fn encode_record(rec_type: u8, round: u32, payload: &[u8]) -> Vec<u8> {
    let len = BODY_HEADER + payload.len();
    assert!(len <= MAX_RECORD, "journal record body {len} exceeds MAX_RECORD");
    let mut body = Vec::with_capacity(len);
    body.push(JOURNAL_VERSION);
    body.push(rec_type);
    wire::put_u32(&mut body, round);
    body.extend_from_slice(payload);
    let mut out = Vec::with_capacity(PREFIX_BYTES + len);
    wire::put_u32(&mut out, len as u32);
    wire::put_u32(&mut out, crc32(&body));
    out.extend_from_slice(&body);
    out
}

/// Append-only record writer over one file. Every [`LogWriter::append`] is
/// a single `write_all` + `sync_data`, so a crash can tear at most the
/// last record — the exact failure [`scan`] tolerates.
pub struct LogWriter {
    file: File,
    path: PathBuf,
}

impl LogWriter {
    /// Create (truncating any existing file — a fresh log).
    pub fn create(path: &Path) -> Result<LogWriter, JournalError> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let file = OpenOptions::new().create(true).write(true).truncate(true).open(path)?;
        Ok(LogWriter { file, path: path.to_path_buf() })
    }

    /// Open an existing log for appends (after [`scan`] validated it and
    /// any torn tail was truncated away).
    pub fn open_append(path: &Path) -> Result<LogWriter, JournalError> {
        let file = OpenOptions::new().append(true).open(path)?;
        Ok(LogWriter { file, path: path.to_path_buf() })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one record and fsync it (durability point: when this
    /// returns, the record survives a crash).
    pub fn append(&mut self, rec_type: u8, round: u32, payload: &[u8]) -> Result<(), JournalError> {
        self.file.write_all(&encode_record(rec_type, round, payload))?;
        self.file.sync_data()?;
        Ok(())
    }
}

/// Read every valid record from a log file, tolerating a torn tail (see
/// [`scan`]). The raw companion to [`recover`] — campaign logs and tests
/// use it directly.
pub fn read_log(path: &Path) -> Result<Vec<RawRecord>, JournalError> {
    let bytes = std::fs::read(path)?;
    Ok(scan(&bytes)?.0)
}

/// Truncate the last `k` records off a journal file (crash emulation: the
/// harness uses this to reconstruct the intermediate states a kill between
/// two appends of one step would leave behind, and the corruption tests to
/// build valid prefixes).
pub fn truncate_last_records(path: &Path, k: usize) -> Result<(), JournalError> {
    let bytes = std::fs::read(path)?;
    let (records, _) = scan(&bytes)?;
    if records.len() < k {
        return Err(JournalError::Replay(format!(
            "cannot drop {k} records from a {}-record journal",
            records.len()
        )));
    }
    let end = if k == 0 {
        bytes.len() as u64
    } else {
        records[records.len() - k].offset
    };
    let f = OpenOptions::new().write(true).open(path)?;
    f.set_len(end)?;
    f.sync_data()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Typed payload codecs

fn encode_setup(n: usize, t: usize, mask_bits: u32, plan: &IndexPlan, graph: &Graph) -> Vec<u8> {
    let mut p = Vec::new();
    wire::put_u32(&mut p, n as u32);
    wire::put_u32(&mut p, t as u32);
    p.push(mask_bits as u8);
    wire::put_u32(&mut p, plan.dim() as u32);
    match plan.indices() {
        None => p.push(0),
        Some(idx) => {
            p.push(1);
            wire::put_u32(&mut p, idx.len() as u32);
            for &i in idx {
                wire::put_u32(&mut p, i);
            }
        }
    }
    // adjacency rows verbatim: neighbors() order is load-bearing for
    // bit-identical replay (bundle entry order, mask job order)
    for i in 0..n {
        let row = graph.neighbors(i);
        wire::put_u32(&mut p, row.len() as u32);
        for &j in row {
            wire::put_u32(&mut p, j as u32);
        }
    }
    p
}

/// The session caches a warm round's setup record carries on top of the
/// cold fields, so [`recover`] rebuilds a warm `Server` (advertised keys,
/// delta clocks) without the session process.
struct WarmSetup {
    keys: BTreeMap<ClientId, (PublicKey, PublicKey)>,
    ctx: WarmCtx,
    map_bytes: usize,
}

/// Trailing warm section appended to the cold setup payload. Presence is
/// signaled by remaining bytes after the adjacency rows (version stays 1:
/// a cold journal is byte-identical to what it always was).
fn encode_setup_warm(
    cold: Vec<u8>,
    n: usize,
    keys: &BTreeMap<ClientId, (PublicKey, PublicKey)>,
    ctx: &WarmCtx,
    map_bytes: usize,
) -> Vec<u8> {
    assert_eq!(ctx.last_seen.len(), n, "one last_seen clock per client");
    assert_eq!(ctx.rekeyed_at.len(), n, "one rekeyed_at clock per client");
    let mut p = cold;
    p.push(1); // warm marker
    p.extend_from_slice(&ctx.round.to_le_bytes());
    wire::put_u32(&mut p, map_bytes as u32);
    wire::put_u32(&mut p, keys.len() as u32);
    for (&id, (c_pk, s_pk)) in keys {
        wire::put_u32(&mut p, id as u32);
        p.extend_from_slice(c_pk);
        p.extend_from_slice(s_pk);
    }
    for &k in &ctx.last_seen {
        p.extend_from_slice(&k.to_le_bytes());
    }
    for &k in &ctx.rekeyed_at {
        p.extend_from_slice(&k.to_le_bytes());
    }
    p
}

fn decode_setup_warm(r: &mut Reader<'_>, n: usize) -> Result<WarmSetup, JournalError> {
    if r.u8("warm marker")? != 1 {
        return Err(JournalError::BadSetup("unknown warm setup marker".into()));
    }
    let round = r.u64("warm round")?;
    if round == 0 {
        return Err(JournalError::BadSetup("warm round must be >= 1".into()));
    }
    let map_bytes = r.u32("warm map bytes")? as usize;
    let count = r.u32("warm key count")? as usize;
    let need = count.checked_mul(4 + 64).ok_or(WireError::BadValue("warm key count"))?;
    if r.remaining() < need {
        return Err(WireError::Truncated("warm key entries").into());
    }
    let mut keys = BTreeMap::new();
    for _ in 0..count {
        let id = r.client_id("warm key id")?;
        if id >= n {
            return Err(JournalError::BadSetup(format!("warm key id {id} out of range")));
        }
        let c_pk: [u8; 32] = r.take(32, "warm c_pk")?.try_into().unwrap();
        let s_pk: [u8; 32] = r.take(32, "warm s_pk")?.try_into().unwrap();
        if keys.insert(id, (c_pk, s_pk)).is_some() {
            return Err(JournalError::BadSetup(format!("duplicate warm key id {id}")));
        }
    }
    let mut last_seen = Vec::with_capacity(n);
    for _ in 0..n {
        last_seen.push(r.u64("warm last_seen clock")?);
    }
    let mut rekeyed_at = Vec::with_capacity(n);
    for _ in 0..n {
        rekeyed_at.push(r.u64("warm rekeyed_at clock")?);
    }
    for (&clock, what) in last_seen.iter().zip(std::iter::repeat("last_seen")).chain(
        rekeyed_at.iter().zip(std::iter::repeat("rekeyed_at")),
    ) {
        if clock >= round {
            return Err(JournalError::BadSetup(format!(
                "warm {what} clock {clock} not before round {round}"
            )));
        }
    }
    Ok(WarmSetup { keys, ctx: WarmCtx { round, last_seen, rekeyed_at }, map_bytes })
}

struct Setup {
    n: usize,
    t: usize,
    mask_bits: u32,
    plan: Arc<IndexPlan>,
    graph: Graph,
    warm: Option<WarmSetup>,
}

fn decode_setup(payload: &[u8]) -> Result<Setup, JournalError> {
    let mut r = Reader::new(payload);
    let n = r.u32("setup n")? as usize;
    let t = r.u32("setup t")? as usize;
    let mask_bits = r.u8("setup mask bits")? as u32;
    if n == 0 || t == 0 || t > n {
        return Err(JournalError::BadSetup(format!("n={n} t={t}")));
    }
    if !(1..=64).contains(&mask_bits) {
        return Err(JournalError::BadSetup(format!("mask_bits={mask_bits}")));
    }
    let dim = r.u32("setup plan dim")? as usize;
    let plan = match r.u8("setup plan kind")? {
        0 => IndexPlan::identity(dim),
        1 => {
            let count = r.u32("setup plan index count")? as usize;
            let need = count.checked_mul(4).ok_or(WireError::BadValue("plan index count"))?;
            if r.remaining() < need {
                return Err(WireError::Truncated("plan indices").into());
            }
            let mut idx = Vec::with_capacity(count);
            for _ in 0..count {
                idx.push(r.u32("plan index")?);
            }
            // IndexPlan::sparse asserts these; pre-validate so corrupt
            // bytes surface as an error, never a panic
            if !idx.windows(2).all(|w| w[0] < w[1]) {
                return Err(JournalError::BadSetup("plan indices not strictly ascending".into()));
            }
            if idx.last().is_some_and(|&last| last as usize >= dim) {
                return Err(JournalError::BadSetup("plan index out of dim".into()));
            }
            IndexPlan::sparse(idx, dim)
        }
        k => return Err(JournalError::BadSetup(format!("plan kind {k}"))),
    };
    let mut adj = Vec::with_capacity(n.min(r.remaining() / 4));
    for _ in 0..n {
        let deg = r.u32("adjacency row degree")? as usize;
        let need = deg.checked_mul(4).ok_or(WireError::BadValue("adjacency row degree"))?;
        if r.remaining() < need {
            return Err(WireError::Truncated("adjacency row").into());
        }
        let mut row = Vec::with_capacity(deg);
        for _ in 0..deg {
            row.push(r.u32("adjacency entry")? as usize);
        }
        adj.push(row);
    }
    // bytes past the adjacency rows are the warm (session) section
    let warm = if r.remaining() > 0 { Some(decode_setup_warm(&mut r, n)?) } else { None };
    r.done()?;
    let graph = Graph::from_adjacency(n, adj).map_err(JournalError::BadSetup)?;
    Ok(Setup { n, t, mask_bits, plan, graph, warm })
}

fn encode_ups(phase: u8, round: u32, ups: &[Up]) -> Vec<u8> {
    let mut p = Vec::new();
    p.push(phase);
    wire::put_u32(&mut p, ups.len() as u32);
    for up in ups {
        p.extend_from_slice(&wire::encode_up(round, up));
    }
    p
}

fn decode_ups(
    payload: &[u8],
    plan: &Arc<IndexPlan>,
    round: u32,
) -> Result<(u8, Vec<Up>), JournalError> {
    let mut r = Reader::new(payload);
    let phase = r.u8("ups phase")?;
    let count = r.u32("ups count")? as usize;
    let mut ups = Vec::new();
    for _ in 0..count {
        let len = r.u32("ups inner frame length")? as usize;
        if !(wire::HEADER_BYTES..=wire::MAX_FRAME).contains(&len) {
            return Err(WireError::BadValue("ups inner frame length").into());
        }
        let body = r.take(len, "ups inner frame body")?;
        let (rr, up) = wire::decode_up(body, plan)?;
        if rr != round {
            return Err(JournalError::WrongRound { expected: round, found: rr });
        }
        if up.phase() != phase {
            return Err(JournalError::Replay(format!(
                "phase-{} message inside a phase-{phase} ups record",
                up.phase()
            )));
        }
        ups.push(up);
    }
    r.done()?;
    Ok((phase, ups))
}

fn encode_ids(ids: &[ClientId]) -> Vec<u8> {
    let mut p = Vec::with_capacity(4 + ids.len() * 4);
    wire::put_u32(&mut p, ids.len() as u32);
    for &id in ids {
        wire::put_u32(&mut p, id as u32);
    }
    p
}

fn read_ids(r: &mut Reader<'_>, what: &'static str) -> Result<Vec<ClientId>, JournalError> {
    let count = r.u32(what)? as usize;
    let need = count.checked_mul(4).ok_or(WireError::BadValue(what))?;
    if r.remaining() < need {
        return Err(WireError::Truncated(what).into());
    }
    let mut ids = Vec::with_capacity(count);
    for _ in 0..count {
        ids.push(r.client_id(what)? as ClientId);
    }
    Ok(ids)
}

fn decode_announce(payload: &[u8]) -> Result<Vec<ClientId>, JournalError> {
    let mut r = Reader::new(payload);
    let v3 = read_ids(&mut r, "announce ids")?;
    r.done()?;
    Ok(v3)
}

fn encode_words(values: &[u64]) -> Vec<u8> {
    let mut p = Vec::with_capacity(4 + values.len() * 8);
    wire::put_u32(&mut p, values.len() as u32);
    for &v in values {
        p.extend_from_slice(&v.to_le_bytes());
    }
    p
}

fn read_words(r: &mut Reader<'_>, what: &'static str) -> Result<Vec<u64>, JournalError> {
    let count = r.u32(what)? as usize;
    let need = count.checked_mul(8).ok_or(WireError::BadValue(what))?;
    if r.remaining() < need {
        return Err(WireError::Truncated(what).into());
    }
    let mut values = Vec::with_capacity(count);
    for _ in 0..count {
        values.push(r.u64(what)?);
    }
    Ok(values)
}

fn decode_checkpoint(payload: &[u8]) -> Result<Vec<u64>, JournalError> {
    let mut r = Reader::new(payload);
    let acc = read_words(&mut r, "checkpoint words")?;
    r.done()?;
    Ok(acc)
}

fn encode_final(out: &RoundOutput) -> Vec<u8> {
    let mut p = Vec::new();
    p.push(out.reliable as u8);
    match &out.sum {
        None => p.push(0),
        Some(sum) => {
            p.push(1);
            p.extend_from_slice(&encode_words(sum));
        }
    }
    for set in [&out.sets.v1, &out.sets.v2, &out.sets.v3, &out.sets.v4] {
        p.extend_from_slice(&encode_ids(set));
    }
    p
}

fn decode_final(payload: &[u8]) -> Result<RoundOutput, JournalError> {
    let mut r = Reader::new(payload);
    let reliable = match r.u8("final reliable flag")? {
        0 => false,
        1 => true,
        _ => return Err(WireError::BadValue("final reliable flag").into()),
    };
    let sum = match r.u8("final sum flag")? {
        0 => None,
        1 => Some(read_words(&mut r, "final sum words")?),
        _ => return Err(WireError::BadValue("final sum flag").into()),
    };
    let v1 = read_ids(&mut r, "final v1")?;
    let v2 = read_ids(&mut r, "final v2")?;
    let v3 = read_ids(&mut r, "final v3")?;
    let v4 = read_ids(&mut r, "final v4")?;
    r.done()?;
    Ok(RoundOutput { sum, reliable, sets: SurvivorSets { v1, v2, v3, v4 } })
}

// ---------------------------------------------------------------------------
// The round journal

/// One round's append-only journal: a [`LogWriter`] bound to the round tag
/// every record is stamped with.
pub struct Journal {
    w: LogWriter,
    round: u32,
}

impl Journal {
    /// Canonical file name for a round journal inside a journal directory.
    pub fn path_for(dir: &Path, round: u32) -> PathBuf {
        dir.join(format!("round-{round:08x}.ccj"))
    }

    /// Start a fresh journal for one round: creates `dir` if needed,
    /// truncates any stale file for this round, and writes the setup
    /// record (the replay bootstrap: n, t, mask bits, index plan, and the
    /// graph's adjacency rows verbatim).
    pub fn create(
        dir: &Path,
        round: u32,
        n: usize,
        t: usize,
        mask_bits: u32,
        plan: &IndexPlan,
        graph: &Graph,
    ) -> Result<Journal, JournalError> {
        let mut w = LogWriter::create(&Self::path_for(dir, round))?;
        w.append(RT_SETUP, round, &encode_setup(n, t, mask_bits, plan, graph))?;
        Ok(Journal { w, round })
    }

    /// [`Journal::create`] for a warm (session) round: the setup record
    /// additionally carries the session caches — advertised keys, delta
    /// clocks, the session round number and the TopK coordinate-map charge
    /// — so [`recover`] rebuilds a warm `Server` from the log alone.
    #[allow(clippy::too_many_arguments)]
    pub fn create_warm(
        dir: &Path,
        round: u32,
        n: usize,
        t: usize,
        mask_bits: u32,
        plan: &IndexPlan,
        graph: &Graph,
        keys: &BTreeMap<ClientId, (PublicKey, PublicKey)>,
        warm: &WarmCtx,
        map_bytes: usize,
    ) -> Result<Journal, JournalError> {
        let mut w = LogWriter::create(&Self::path_for(dir, round))?;
        let cold = encode_setup(n, t, mask_bits, plan, graph);
        w.append(RT_SETUP, round, &encode_setup_warm(cold, n, keys, warm, map_bytes))?;
        Ok(Journal { w, round })
    }

    /// Reopen an already-recovered journal for further appends.
    pub fn open_append(path: &Path, round: u32) -> Result<Journal, JournalError> {
        Ok(Journal { w: LogWriter::open_append(path)?, round })
    }

    pub fn round(&self) -> u32 {
        self.round
    }

    pub fn path(&self) -> &Path {
        self.w.path()
    }

    fn append(&mut self, rec_type: u8, payload: &[u8]) -> Result<(), JournalError> {
        self.w.append(rec_type, self.round, payload)
    }

    /// Record one phase's `Up` batch (as full wire frames, so the journal
    /// shares the wire codec's golden bytes and validation).
    pub fn record_ups(&mut self, phase: u8, ups: &[Up]) -> Result<(), JournalError> {
        self.append(RT_UPS, &encode_ups(phase, self.round, ups))
    }

    pub fn record_announce(&mut self, v3: &[ClientId]) -> Result<(), JournalError> {
        self.append(RT_ANNOUNCE, &encode_ids(v3))
    }

    pub fn record_checkpoint(&mut self, acc: &[u64]) -> Result<(), JournalError> {
        self.append(RT_CHECKPOINT, &encode_words(acc))
    }

    pub fn record_final(&mut self, out: &RoundOutput) -> Result<(), JournalError> {
        self.append(RT_FINAL, &encode_final(out))
    }
}

/// The [`RoundSink`] a journaled server writes through: each hook clones
/// the typed batch into `Up` envelopes and appends one fsync'd record.
pub struct JournalSink {
    journal: Journal,
}

impl JournalSink {
    pub fn new(journal: Journal) -> JournalSink {
        JournalSink { journal }
    }
}

impl RoundSink for JournalSink {
    fn record_step0(&mut self, advs: &[AdvertiseKeys]) -> anyhow::Result<()> {
        let ups: Vec<Up> = advs.iter().map(|a| Up::Adv(a.clone())).collect();
        Ok(self.journal.record_ups(0, &ups)?)
    }

    fn record_warm_step0(&mut self, resumes: &[WarmResume]) -> anyhow::Result<()> {
        let ups: Vec<Up> = resumes.iter().map(|r| Up::Warm(r.clone())).collect();
        Ok(self.journal.record_ups(0, &ups)?)
    }

    fn record_step1(&mut self, uploads: &[ShareUpload]) -> anyhow::Result<()> {
        let ups: Vec<Up> = uploads.iter().map(|u| Up::Shares(u.clone())).collect();
        Ok(self.journal.record_ups(1, &ups)?)
    }

    fn record_step2(&mut self, inputs: &[MaskedInput]) -> anyhow::Result<()> {
        let ups: Vec<Up> = inputs.iter().map(|m| Up::Masked(m.clone())).collect();
        Ok(self.journal.record_ups(2, &ups)?)
    }

    fn record_announce(&mut self, announce: &SurvivorAnnounce) -> anyhow::Result<()> {
        Ok(self.journal.record_announce(&announce.v3)?)
    }

    fn record_step3(&mut self, responses: &[UnmaskShares]) -> anyhow::Result<()> {
        let ups: Vec<Up> = responses.iter().map(|u| Up::Unmask(u.clone())).collect();
        Ok(self.journal.record_ups(3, &ups)?)
    }

    fn record_checkpoint(&mut self, acc: &[u64]) -> anyhow::Result<()> {
        Ok(self.journal.record_checkpoint(acc)?)
    }

    fn record_final(&mut self, out: &RoundOutput) -> anyhow::Result<()> {
        Ok(self.journal.record_final(out)?)
    }
}

// ---------------------------------------------------------------------------
// Recovery

/// A recovered round: the replayed server plus everything the transport
/// needs to resume serving exactly where the dead process stopped.
pub struct Recovery {
    pub round: u32,
    pub n: usize,
    pub t: usize,
    pub mask_bits: u32,
    pub plan: Arc<IndexPlan>,
    /// Per-recipient coordinate-map bytes on warm plan downs (TopK warm
    /// rounds; 0 otherwise) — the transport re-charges these on resume.
    pub map_bytes: usize,
    /// The replayed server — bit-identical to the pre-crash instance (no
    /// sink attached; the caller reattaches via the returned journal). For
    /// a warm round's journal this is a warm server, session caches loaded
    /// from the setup record.
    pub server: Server,
    /// The phase whose collection is in progress (0–3), or 4 when the
    /// round already finalized.
    pub next_phase: u8,
    /// The `Down`s of `next_phase`, regenerated byte-identically — what a
    /// resuming transport re-sends to clients stuck one phase behind.
    /// Empty for phase 0 (the down is the broadcast `Start`) and phase 4.
    pub downs: Vec<(ClientId, Down)>,
    /// The survivor announce, when replay reached phase 3.
    pub announce: Option<Arc<SurvivorAnnounce>>,
    /// The round output, when replay reached finalize.
    pub output: Option<RoundOutput>,
    /// The journal reopened in append mode (torn tail already truncated
    /// away on disk), ready to be wrapped in a [`JournalSink`] again.
    pub journal: Journal,
}

/// Replay a journal into a [`Recovery`]. Tolerates a torn tail (dropped,
/// and truncated off the on-disk file); everything else that does not
/// replay to a consistent state is a named [`JournalError`].
pub fn recover(path: &Path) -> Result<Recovery, JournalError> {
    let bytes = std::fs::read(path)?;
    let (records, valid_len) = scan(&bytes)?;
    if valid_len < bytes.len() {
        log::warn!(
            "journal {}: dropping {} torn trailing bytes",
            path.display(),
            bytes.len() - valid_len
        );
        let f = OpenOptions::new().write(true).open(path)?;
        f.set_len(valid_len as u64)?;
        f.sync_data()?;
    }
    let mut it = records.into_iter();
    let first = it.next().ok_or(JournalError::MissingSetup)?;
    if first.rec_type != RT_SETUP {
        return Err(JournalError::MissingSetup);
    }
    let round = first.round;
    let Setup { n, t, mask_bits, plan, graph, warm } = decode_setup(&first.payload)?;
    let setup_payload = first.payload;

    let (mut server, map_bytes) = match warm {
        None => (Server::new(n, t, mask_bits, plan.clone(), graph), 0),
        Some(w) => (
            Server::new_warm(n, t, mask_bits, plan.clone(), graph, w.keys, w.ctx),
            w.map_bytes,
        ),
    };
    let mut next_phase = 0u8;
    let mut downs: Vec<(ClientId, Down)> = Vec::new();
    let mut announce: Option<Arc<SurvivorAnnounce>> = None;
    let mut output: Option<RoundOutput> = None;

    for rec in it {
        if rec.round != round {
            return Err(JournalError::WrongRound { expected: round, found: rec.round });
        }
        match rec.rec_type {
            RT_SETUP => {
                // an identical duplicate is benign; a conflicting one is not
                if rec.payload != setup_payload {
                    return Err(JournalError::Replay(
                        "conflicting duplicate setup record".into(),
                    ));
                }
            }
            RT_UPS => {
                let (phase, ups) = decode_ups(&rec.payload, &plan, round)?;
                // a duplicate of the just-applied batch replays through the
                // server's first-wins dedupe (regenerating identical downs);
                // anything else out of order cannot replay consistently
                let duplicate = phase + 1 == next_phase;
                if phase != next_phase && !duplicate {
                    return Err(JournalError::Replay(format!(
                        "out-of-order ups record: phase {phase} while expecting {next_phase}"
                    )));
                }
                match phase {
                    0 if server.warm().is_some() => {
                        let resumes = take_typed(ups, |u| match u {
                            Up::Warm(w) => Some(w),
                            _ => None,
                        })?;
                        let plans = server
                            .warm_step0_resume(resumes)
                            .map_err(|e| JournalError::Replay(format!("warm step 0: {e}")))?;
                        if !duplicate {
                            downs = plans
                                .into_iter()
                                .map(|(id, wp)| (id, Down::WarmPlan(wp)))
                                .collect();
                            next_phase = 1;
                        }
                    }
                    0 => {
                        let advs = take_typed(ups, |u| match u {
                            Up::Adv(a) => Some(a),
                            _ => None,
                        })?;
                        let bundles = server
                            .step0_route_keys(advs)
                            .map_err(|e| JournalError::Replay(format!("step 0: {e}")))?;
                        if !duplicate {
                            downs =
                                bundles.into_iter().map(|(id, b)| (id, Down::Bundle(b))).collect();
                            next_phase = 1;
                        }
                    }
                    1 => {
                        let uploads = take_typed(ups, |u| match u {
                            Up::Shares(s) => Some(s),
                            _ => None,
                        })?;
                        let deliveries = server
                            .step1_route_shares(uploads)
                            .map_err(|e| JournalError::Replay(format!("step 1: {e}")))?;
                        if !duplicate {
                            downs = deliveries
                                .into_iter()
                                .map(|(id, d)| (id, Down::Delivery(d)))
                                .collect();
                            next_phase = 2;
                        }
                    }
                    2 => {
                        let inputs = take_typed(ups, |u| match u {
                            Up::Masked(m) => Some(m),
                            _ => None,
                        })?;
                        let ann = Arc::new(
                            server
                                .step2_collect_masked(inputs)
                                .map_err(|e| JournalError::Replay(format!("step 2: {e}")))?,
                        );
                        if !duplicate {
                            downs = ann
                                .v3
                                .iter()
                                .map(|&id| (id, Down::Announce(ann.clone())))
                                .collect();
                            announce = Some(ann);
                            next_phase = 3;
                        }
                    }
                    3 => {
                        let responses = take_typed(ups, |u| match u {
                            Up::Unmask(r) => Some(r),
                            _ => None,
                        })?;
                        let out = server
                            .finalize(responses)
                            .map_err(|e| JournalError::Replay(format!("finalize: {e}")))?;
                        if !duplicate {
                            downs.clear();
                            output = Some(out);
                            next_phase = 4;
                        }
                    }
                    p => {
                        return Err(JournalError::Replay(format!("ups record for phase {p}")));
                    }
                }
            }
            RT_ANNOUNCE => {
                let v3 = decode_announce(&rec.payload)?;
                match &announce {
                    Some(a) if a.v3 == v3 => {}
                    _ => return Err(JournalError::AnnounceMismatch),
                }
            }
            RT_CHECKPOINT => {
                let acc = decode_checkpoint(&rec.payload)?;
                if server.packed_accumulator() != acc {
                    return Err(JournalError::CheckpointMismatch);
                }
            }
            RT_FINAL => {
                let rec_out = decode_final(&rec.payload)?;
                match &output {
                    Some(out)
                        if out.sum == rec_out.sum
                            && out.reliable == rec_out.reliable
                            && out.sets == rec_out.sets => {}
                    _ => return Err(JournalError::FinalMismatch),
                }
            }
            other => return Err(JournalError::BadRecordType(other)),
        }
    }

    let journal = Journal::open_append(path, round)?;
    Ok(Recovery {
        round,
        n,
        t,
        mask_bits,
        plan,
        map_bytes,
        server,
        next_phase,
        downs,
        announce,
        output,
        journal,
    })
}

/// Extract one typed message kind from a replayed `Up` batch; any other
/// variant inside the record means the journal was not written by the sink
/// and cannot be replayed.
fn take_typed<T>(ups: Vec<Up>, f: impl Fn(Up) -> Option<T>) -> Result<Vec<T>, JournalError> {
    let total = ups.len();
    let out: Vec<T> = ups.into_iter().filter_map(&f).collect();
    if out.len() != total {
        return Err(JournalError::Replay("mixed message kinds in one ups record".into()));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::IndexPlan;

    #[test]
    fn crc32_matches_the_ieee_check_value() {
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn records_round_trip_through_scan() {
        let a = encode_record(RT_SETUP, 7, b"alpha");
        let b = encode_record(RT_UPS, 7, b"");
        let mut stream = a.clone();
        stream.extend_from_slice(&b);
        let (recs, valid) = scan(&stream).unwrap();
        assert_eq!(valid, stream.len());
        assert_eq!(recs.len(), 2);
        assert_eq!((recs[0].rec_type, recs[0].round, recs[0].payload.as_slice()), (RT_SETUP, 7, &b"alpha"[..]));
        assert_eq!(recs[1].offset as usize, a.len());
    }

    #[test]
    fn torn_tail_is_dropped_at_every_byte_offset() {
        let a = encode_record(RT_SETUP, 1, b"payload");
        let b = encode_record(RT_UPS, 1, &[9; 40]);
        let mut stream = a.clone();
        stream.extend_from_slice(&b);
        for cut in a.len()..stream.len() {
            let (recs, valid) = scan(&stream[..cut]).expect("torn tail must not error");
            assert_eq!(recs.len(), 1, "cut={cut}");
            assert_eq!(valid, a.len(), "cut={cut}");
        }
        // cutting into the *first* record leaves an empty valid prefix
        for cut in 0..a.len() {
            let (recs, valid) = scan(&a[..cut]).unwrap();
            assert!(recs.is_empty(), "cut={cut}");
            assert_eq!(valid, 0);
        }
    }

    #[test]
    fn checksum_flip_is_a_named_error() {
        let mut stream = encode_record(RT_UPS, 3, b"some payload");
        for pos in 0..stream.len() {
            let mut bad = stream.clone();
            bad[pos] ^= 0x40;
            // every single-bit-flip outcome must be an Err or a clean
            // torn-tail drop — never a panic, never a silently different
            // record that still checksums
            match scan(&bad) {
                Ok((recs, _)) => {
                    assert!(recs.is_empty(), "flip at {pos} produced a valid record");
                }
                Err(
                    JournalError::Checksum { .. }
                    | JournalError::Corrupt { .. }
                    | JournalError::BadVersion(_),
                ) => {}
                Err(e) => panic!("flip at {pos}: unexpected error {e}"),
            }
        }
        // an explicit checksum-byte flip names the stored/computed pair
        stream[4] ^= 0xFF;
        assert!(matches!(scan(&stream), Err(JournalError::Checksum { offset: 0, .. })));
    }

    #[test]
    fn setup_payload_round_trips() {
        let mut g = Graph::empty(4);
        g.add_edge(0, 2);
        g.add_edge(0, 1);
        g.add_edge(3, 1);
        let plan = IndexPlan::sparse(vec![1, 5, 9], 12);
        let p = encode_setup(4, 2, 48, &plan, &g);
        let s = decode_setup(&p).unwrap();
        assert_eq!((s.n, s.t, s.mask_bits), (4, 2, 48));
        assert_eq!(*s.plan, *plan);
        // neighbor order is preserved verbatim, not sorted
        assert_eq!(s.graph.neighbors(0), &[2, 1]);
        assert_eq!(s.graph.neighbors(1), &[0, 3]);
        assert_eq!(s.graph, g);
    }

    #[test]
    fn corrupt_setup_payloads_error_never_panic() {
        let g = Graph::complete(3);
        let plan = IndexPlan::identity(4);
        let good = encode_setup(3, 2, 32, &plan, &g);
        // truncation at every length
        for cut in 0..good.len() {
            assert!(decode_setup(&good[..cut]).is_err(), "cut={cut}");
        }
        // t > n
        let mut bad = good.clone();
        bad[4..8].copy_from_slice(&9u32.to_le_bytes());
        assert!(matches!(decode_setup(&bad), Err(JournalError::BadSetup(_))));
        // mask_bits = 0
        let mut bad = good.clone();
        bad[8] = 0;
        assert!(matches!(decode_setup(&bad), Err(JournalError::BadSetup(_))));
        // non-ascending sparse indices
        let sparse = encode_setup(3, 2, 32, &IndexPlan::sparse(vec![1, 2], 4), &g);
        let mut bad = sparse.clone();
        // indices live at offset 9 (dim) + 4 .. : kind(1) count(4) idx..
        let idx_off = 9 + 4 + 1 + 4;
        bad[idx_off..idx_off + 4].copy_from_slice(&3u32.to_le_bytes());
        bad[idx_off + 4..idx_off + 8].copy_from_slice(&3u32.to_le_bytes());
        assert!(matches!(decode_setup(&bad), Err(JournalError::BadSetup(_))));
        // asymmetric adjacency
        let mut g2 = Graph::empty(2);
        g2.add_edge(0, 1);
        let mut enc = encode_setup(2, 1, 32, &plan, &g2);
        let row0 = enc.len() - 16; // two rows of deg(4)+entry(4)
        enc[row0 + 4..row0 + 8].copy_from_slice(&0u32.to_le_bytes()); // 0 -> 0 self-loop
        assert!(matches!(decode_setup(&enc), Err(JournalError::BadSetup(_))));
    }

    #[test]
    fn final_payload_round_trips() {
        let out = RoundOutput {
            sum: Some(vec![0, u64::MAX, 17]),
            reliable: true,
            sets: SurvivorSets {
                v1: vec![0, 1, 2, 3],
                v2: vec![0, 1, 3],
                v3: vec![0, 3],
                v4: vec![0, 3],
            },
        };
        let back = decode_final(&encode_final(&out)).unwrap();
        assert_eq!(back.sum, out.sum);
        assert_eq!(back.reliable, out.reliable);
        assert_eq!(back.sets, out.sets);
        let none = RoundOutput { sum: None, reliable: false, sets: SurvivorSets::default() };
        let back = decode_final(&encode_final(&none)).unwrap();
        assert_eq!(back.sum, None);
        assert!(!back.reliable);
    }

    #[test]
    fn log_writer_appends_survive_reopen() {
        let dir = std::env::temp_dir().join(format!("ccesa-journal-unit-{}", std::process::id()));
        let path = dir.join("unit.ccl");
        let mut w = LogWriter::create(&path).unwrap();
        w.append(RT_USER_BASE, 5, b"one").unwrap();
        drop(w);
        let mut w = LogWriter::open_append(&path).unwrap();
        w.append(RT_USER_BASE + 1, 5, b"two").unwrap();
        drop(w);
        let recs = read_log(&path).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].payload, b"one");
        assert_eq!(recs[1].rec_type, RT_USER_BASE + 1);
        // drop the last record; the first survives
        truncate_last_records(&path, 1).unwrap();
        let recs = read_log(&path).unwrap();
        assert_eq!(recs.len(), 1);
        assert!(truncate_last_records(&path, 2).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
