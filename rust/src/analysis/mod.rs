//! Theoretical analysis of the paper, made executable.
//!
//! * [`bounds`] — Theorems 3–6, the threshold probability p* (Eq. 5), the
//!   secret-sharing design rule for t (Remark 4) — regenerates Fig 4.1 and
//!   Table F.4;
//! * [`costs`] — Appendix C's communication/computation cost models for
//!   CCESA, SA and FedAvg, plus the Turbo-aggregate comparison from §1 —
//!   regenerates Table 1's concrete columns;
//! * [`montecarlo`] — fast graph-only estimators of the empirical
//!   reliability/privacy failure rates, used to validate the bounds.

pub mod bounds;
pub mod costs;
pub mod montecarlo;
