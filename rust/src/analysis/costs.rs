//! Appendix C's communication/computation cost models — the concrete
//! functions behind Table 1 — plus the Turbo-aggregate comparison of §1.
//!
//! Conventions follow the paper: `a_K` / `a_S` are the *bits* for one
//! public key / one secret share; models have `m` parameters of `R` bits.
//! Degrees use the expectation d = (n−1)p; the measured-bytes counterpart
//! (actual wire accounting) lives in `net::NetStats` and the Table-1 bench
//! compares the two.

/// Cost-model parameters.
#[derive(Debug, Clone, Copy)]
pub struct CostParams {
    pub n: usize,
    /// model parameters
    pub m: usize,
    /// bits per model parameter
    pub r_bits: usize,
    /// bits per public key
    pub a_k: usize,
    /// bits per secret share
    pub a_s: usize,
}

impl CostParams {
    /// Paper's running example: a_K = a_S = 256 bits, R = 32.
    pub fn paper_defaults(n: usize, m: usize) -> CostParams {
        CostParams { n, m, r_bits: 32, a_k: 256, a_s: 256 }
    }
}

/// Per-client *additional* communication (bits) of CCESA over FedAvg, for
/// expected degree d = (n−1)p:  B_CCESA = 2(d+1)a_K + (5d+1)a_S.
pub fn ccesa_client_extra_bits(cp: &CostParams, p: f64) -> f64 {
    let d = (cp.n as f64 - 1.0) * p;
    2.0 * (d + 1.0) * cp.a_k as f64 + (5.0 * d + 1.0) * cp.a_s as f64
}

/// Per-client additional communication (bits) of SA:
/// B_SA = 2n·a_K + (5n−4)·a_S.
pub fn sa_client_extra_bits(cp: &CostParams) -> f64 {
    2.0 * cp.n as f64 * cp.a_k as f64 + (5.0 * cp.n as f64 - 4.0) * cp.a_s as f64
}

/// Total per-client communication (bits), including the masked model mR.
pub fn client_total_bits(cp: &CostParams, scheme: Scheme, p: f64) -> f64 {
    let model = (cp.m * cp.r_bits) as f64;
    match scheme {
        Scheme::FedAvg => model,
        Scheme::Sa => model + sa_client_extra_bits(cp),
        Scheme::Ccesa => model + ccesa_client_extra_bits(cp, p),
    }
}

/// Server communication (bits): sum over clients of both directions ≈
/// n × client cost (star topology).
pub fn server_total_bits(cp: &CostParams, scheme: Scheme, p: f64) -> f64 {
    cp.n as f64 * client_total_bits(cp, scheme, p)
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    FedAvg,
    Sa,
    Ccesa,
}

/// Abstract per-client computation cost (operation count, Appendix C.2):
/// key agreements O(d) + share generation O(d²) + masking O(m·d).
pub fn client_compute_ops(cp: &CostParams, scheme: Scheme, p: f64) -> f64 {
    match scheme {
        Scheme::FedAvg => 0.0,
        Scheme::Sa => {
            let n = cp.n as f64;
            n * n + cp.m as f64 * n
        }
        Scheme::Ccesa => {
            let d = (cp.n as f64 - 1.0) * p;
            d * d + cp.m as f64 * (d + 1.0)
        }
    }
}

/// Abstract server computation cost (Appendix C.2): reconstruction
/// O(Σ d_i²) + unmasking O(m · Σ d_i).
pub fn server_compute_ops(cp: &CostParams, scheme: Scheme, p: f64) -> f64 {
    let n = cp.n as f64;
    match scheme {
        Scheme::FedAvg => cp.m as f64 * n,
        Scheme::Sa => n * n * n + cp.m as f64 * n * n,
        Scheme::Ccesa => {
            let d = (n - 1.0) * p;
            n * d * d + cp.m as f64 * n * d
        }
    }
}

/// Turbo-aggregate per-client communication (§1): ≥ 4·m·n·R/L bits.
pub fn turbo_aggregate_client_bits(m: usize, n: usize, r_bits: usize, l_groups: usize) -> f64 {
    4.0 * m as f64 * n as f64 * r_bits as f64 / l_groups as f64
}

/// CCESA per-client bits in the §1 comparison form:
/// √(n ln n)(2a_K + 5a_S) + mR.
pub fn ccesa_client_bits_asymptotic(cp: &CostParams) -> f64 {
    let n = cp.n as f64;
    (n * n.ln()).sqrt() * (2.0 * cp.a_k as f64 + 5.0 * cp.a_s as f64)
        + (cp.m * cp.r_bits) as f64
}

/// The §1 headline: CCESA / Turbo-aggregate bandwidth ratio for the
/// paper's example (m=1e6, R=32, n=100, L=10, a_K=a_S=256) ≈ 3%.
pub fn turbo_comparison_ratio(m: usize, n: usize, r_bits: usize, l_groups: usize) -> f64 {
    let cp = CostParams { n, m, r_bits, a_k: 256, a_s: 256 };
    ccesa_client_bits_asymptotic(&cp) / turbo_aggregate_client_bits(m, n, r_bits, l_groups)
}

/// One formatted row of Table 1 (the concrete version with paper defaults).
pub fn table1_row(n: usize, m: usize, p: f64) -> String {
    let cp = CostParams::paper_defaults(n, m);
    format!(
        "n={n:>5} m={m:>8}  client comm (bits): ccesa={:.3e} sa={:.3e} fedavg={:.3e} | \
         client ops: ccesa={:.3e} sa={:.3e} | server ops: ccesa={:.3e} sa={:.3e}",
        client_total_bits(&cp, Scheme::Ccesa, p),
        client_total_bits(&cp, Scheme::Sa, p),
        client_total_bits(&cp, Scheme::FedAvg, p),
        client_compute_ops(&cp, Scheme::Ccesa, p),
        client_compute_ops(&cp, Scheme::Sa, p),
        server_compute_ops(&cp, Scheme::Ccesa, p),
        server_compute_ops(&cp, Scheme::Sa, p),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::bounds::p_star;
    use crate::util::stats::power_law_exponent;

    #[test]
    fn turbo_claim_reproduces_three_percent() {
        // §1: "our scheme requires only 3% of the communication bandwidth
        // used in Turbo-aggregate" at m=1e6, R=32, n=100, L=10
        let ratio = turbo_comparison_ratio(1_000_000, 100, 32, 10);
        assert!(
            (0.02..0.04).contains(&ratio),
            "ratio={ratio:.4}, paper claims ≈0.03"
        );
    }

    #[test]
    fn sa_dominates_ccesa_extra_bandwidth() {
        for n in [50usize, 100, 500, 1000] {
            let cp = CostParams::paper_defaults(n, 10_000);
            let p = p_star(n, 0.0);
            assert!(ccesa_client_extra_bits(&cp, p) < sa_client_extra_bits(&cp));
            // the reduction factor approaches p as n grows
            let ratio = ccesa_client_extra_bits(&cp, p) / sa_client_extra_bits(&cp);
            assert!((ratio - p).abs() < 0.12, "n={n} ratio={ratio} p={p}");
        }
    }

    #[test]
    fn asymptotic_exponents_match_table1() {
        // extra client bandwidth: CCESA ~ √(n log n) (slope ~0.55–0.65),
        // SA ~ n (slope ~1.0)
        let ns: Vec<f64> = [100.0f64, 200.0, 400.0, 800.0, 1600.0, 3200.0].to_vec();
        let ccesa: Vec<f64> = ns
            .iter()
            .map(|&n| {
                let cp = CostParams::paper_defaults(n as usize, 0);
                ccesa_client_extra_bits(&cp, p_star(n as usize, 0.0))
            })
            .collect();
        let sa: Vec<f64> = ns
            .iter()
            .map(|&n| sa_client_extra_bits(&CostParams::paper_defaults(n as usize, 0)))
            .collect();
        let (k_ccesa, r2c) = power_law_exponent(&ns, &ccesa);
        let (k_sa, r2s) = power_law_exponent(&ns, &sa);
        assert!(r2c > 0.99 && r2s > 0.999);
        assert!((0.5..0.75).contains(&k_ccesa), "ccesa slope {k_ccesa}");
        assert!((0.95..1.05).contains(&k_sa), "sa slope {k_sa}");
    }

    #[test]
    fn compute_costs_ordering() {
        let cp = CostParams::paper_defaults(500, 10_000);
        let p = p_star(500, 0.0);
        assert!(client_compute_ops(&cp, Scheme::Ccesa, p) < client_compute_ops(&cp, Scheme::Sa, p));
        assert!(server_compute_ops(&cp, Scheme::Ccesa, p) < server_compute_ops(&cp, Scheme::Sa, p));
        assert_eq!(client_compute_ops(&cp, Scheme::FedAvg, p), 0.0);
    }

    #[test]
    fn resource_fraction_20_to_30_percent_at_large_n(){
        // abstract claim: CCESA uses ~20-30% of SA resources at n≈500-1000
        for n in [500usize, 1000] {
            let p = p_star(n, 0.0);
            assert!((0.15..0.40).contains(&p), "n={n}: resource fraction ≈ p = {p}");
        }
    }

    #[test]
    fn table1_row_formats() {
        let row = table1_row(100, 10_000, 0.64);
        assert!(row.contains("ccesa"));
        assert!(row.contains("n=  100"));
    }
}
