//! Fast Monte-Carlo estimators of the empirical reliability/privacy
//! failure probabilities.
//!
//! These simulate only the *combinatorial* layer (graph + dropouts +
//! Theorem-1/2 predicates) — no crypto — so thousands of trials per
//! parameter point are cheap. The full-crypto engine agrees with these
//! predicates exactly (asserted in `protocol::engine` and
//! `protocol::adversary` tests), so the estimates transfer.

use crate::graph::Graph;
use crate::protocol::server::theorem1_predicate;
use crate::protocol::SurvivorSets;
use crate::util::rng::Rng;

/// One simulated protocol evolution (graph + survivor sets).
pub struct Evolution {
    pub graph: Graph,
    pub sets: SurvivorSets,
}

/// Sample the protocol evolution: G(n,p), then 4 rounds of i.i.d. per-step
/// dropout with probability q. Clients whose live neighborhood at Step 1 is
/// below t withdraw (mirroring the engine's behavior).
pub fn sample_evolution(n: usize, p: f64, q: f64, t: usize, rng: &mut Rng) -> Evolution {
    let graph = Graph::erdos_renyi(n, p, rng);
    let mut alive: Vec<bool> = (0..n).map(|_| !rng.bernoulli(q)).collect();
    let v1: Vec<usize> = (0..n).filter(|&i| alive[i]).collect();
    // step-1 withdrawals: |Adj(i) ∩ V1| + 1 < t
    let mut v2 = Vec::new();
    for &i in &v1 {
        if rng.bernoulli(q) {
            alive[i] = false;
            continue;
        }
        let live_neigh = graph
            .neighbors(i)
            .iter()
            .filter(|&&j| SurvivorSets::contains(&v1, j))
            .count();
        if live_neigh + 1 < t {
            alive[i] = false;
            continue;
        }
        v2.push(i);
    }
    let v3: Vec<usize> = v2
        .iter()
        .copied()
        .filter(|&_i| {
            let s = !rng.bernoulli(q);
            s
        })
        .collect();
    let v4: Vec<usize> = v3.iter().copied().filter(|_| !rng.bernoulli(q)).collect();
    Evolution { graph, sets: SurvivorSets { v1, v2, v3, v4 } }
}

/// Theorem-2 privacy predicate on a bare evolution (graph form of
/// `adversary::theorem2_private`).
pub fn theorem2_predicate(ev: &Evolution, t: usize) -> bool {
    let (g3, map) = ev.graph.induced(&ev.sets.v3);
    if g3.is_connected() {
        return true;
    }
    let informative = |i: usize| {
        let mut cnt = ev
            .graph
            .neighbors(i)
            .iter()
            .filter(|&&j| SurvivorSets::contains(&ev.sets.v4, j))
            .count();
        if SurvivorSets::contains(&ev.sets.v4, i) {
            cnt += 1;
        }
        cnt >= t
    };
    for comp in g3.components() {
        let c: Vec<usize> = comp.iter().map(|&v| map[v]).collect();
        let mut c_plus = c.clone();
        for &i in &ev.sets.v2 {
            if c.contains(&i) {
                continue;
            }
            if ev.graph.neighbors(i).iter().any(|&j| c.contains(&j)) {
                c_plus.push(i);
            }
        }
        if c_plus.iter().all(|&i| informative(i)) {
            return false;
        }
    }
    true
}

/// Monte-Carlo estimates over `trials` runs.
#[derive(Debug, Clone, Copy)]
pub struct FailureRates {
    pub p_e_reliability: f64,
    pub p_e_privacy: f64,
    pub trials: usize,
}

pub fn estimate_failure_rates(
    n: usize,
    p: f64,
    q: f64,
    t: usize,
    trials: usize,
    seed: u64,
) -> FailureRates {
    let mut rng = Rng::new(seed);
    let mut rel_fail = 0usize;
    let mut priv_fail = 0usize;
    for _ in 0..trials {
        let ev = sample_evolution(n, p, q, t, &mut rng);
        // Reliability per Definition 1: the server must actually obtain the
        // sum — impossible when fewer than t clients reach Step 2, and
        // (Theorem 1) when some node of V3⁺ is not informative.
        if ev.sets.v3.len() < t || !theorem1_predicate(&ev.graph, &ev.sets, t) {
            rel_fail += 1;
        }
        if !theorem2_predicate(&ev, t) {
            priv_fail += 1;
        }
    }
    FailureRates {
        p_e_reliability: rel_fail as f64 / trials as f64,
        p_e_privacy: priv_fail as f64 / trials as f64,
        trials,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::bounds::{p_star, per_step_q, t_rule, theorem5_reliability_bound, theorem6_privacy_bound};

    #[test]
    fn complete_graph_never_fails_without_dropout() {
        let r = estimate_failure_rates(30, 1.0, 0.0, 16, 50, 1);
        assert_eq!(r.p_e_reliability, 0.0);
        assert_eq!(r.p_e_privacy, 0.0);
    }

    #[test]
    fn empirical_rates_respect_theorem_bounds() {
        // The Chernoff/union bounds must upper-bound the empirical rates.
        let n = 120;
        for q_total in [0.0, 0.1] {
            let q = per_step_q(q_total);
            let p = p_star(n, q_total);
            let t = t_rule(n, p);
            let est = estimate_failure_rates(n, p, q, t, 400, 7);
            let b5 = theorem5_reliability_bound(n, p, q, t);
            let b6 = theorem6_privacy_bound(n, p, q);
            let ci = 1.96 * (est.p_e_reliability * (1.0 - est.p_e_reliability) / 400.0)
                .sqrt()
                .max(0.01);
            assert!(
                est.p_e_reliability <= b5 + ci,
                "q_total={q_total}: empirical rel fail {} > bound {b5}",
                est.p_e_reliability
            );
            assert!(
                est.p_e_privacy <= b6 + 0.01,
                "q_total={q_total}: empirical priv fail {} > bound {b6:e}",
                est.p_e_privacy
            );
        }
    }

    #[test]
    fn privacy_fails_often_for_tiny_p() {
        // far below the connectivity threshold with a permissive t, the
        // attack surface opens up
        let r = estimate_failure_rates(40, 0.06, 0.0, 2, 300, 3);
        assert!(r.p_e_privacy > 0.05, "priv fail rate {}", r.p_e_privacy);
    }

    #[test]
    fn reliability_fails_for_aggressive_threshold() {
        // t close to n with dropout: some client will miss shares
        let r = estimate_failure_rates(30, 0.5, 0.1, 25, 200, 5);
        assert!(r.p_e_reliability > 0.5, "rel fail rate {}", r.p_e_reliability);
    }

    #[test]
    fn evolution_sets_are_nested() {
        let mut rng = Rng::new(11);
        for _ in 0..20 {
            let ev = sample_evolution(50, 0.3, 0.1, 5, &mut rng);
            let contains = |sup: &[usize], sub: &[usize]| {
                sub.iter().all(|&x| SurvivorSets::contains(sup, x))
            };
            assert!(contains(&ev.sets.v1, &ev.sets.v2));
            assert!(contains(&ev.sets.v2, &ev.sets.v3));
            assert!(contains(&ev.sets.v3, &ev.sets.v4));
        }
    }
}
