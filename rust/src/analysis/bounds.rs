//! Executable forms of the paper's Theorems 3–6, the threshold connection
//! probability p* (Eq. 5) and the design rule for t (Remark 4 / Prop. 1).
//!
//! All logarithms are natural, matching the proofs in Appendix B (the
//! paper's `log` is `ln`; this reproduces Table F.4 exactly, e.g.
//! p*(100, q_total=0) = 0.6362).

/// Natural-log of n! via a cached cumulative table (n ≤ 1 << 20).
fn ln_factorial(n: usize) -> f64 {
    use std::sync::OnceLock;
    static TABLE: OnceLock<Vec<f64>> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = Vec::with_capacity(4097);
        t.push(0.0);
        for k in 1..=4096usize {
            t.push(t[k - 1] + (k as f64).ln());
        }
        t
    });
    if n < table.len() {
        return table[n];
    }
    // Stirling with correction for the (rare) large-n case
    let x = n as f64;
    x * x.ln() - x + 0.5 * (2.0 * std::f64::consts::PI * x).ln() + 1.0 / (12.0 * x)
}

/// ln C(n, k).
pub fn ln_choose(n: usize, k: usize) -> f64 {
    assert!(k <= n);
    ln_factorial(n) - ln_factorial(k) - ln_factorial(n - k)
}

/// Bernoulli KL divergence D(a ‖ b), natural log.
pub fn kl_div(a: f64, b: f64) -> f64 {
    assert!((0.0..=1.0).contains(&a) && (0.0 < b && b < 1.0));
    let term = |x: f64, y: f64| if x == 0.0 { 0.0 } else { x * (x / y).ln() };
    term(a, b) + term(1.0 - a, 1.0 - b)
}

/// Per-step dropout q from protocol-level q_total = 1 − (1−q)^4.
pub fn per_step_q(q_total: f64) -> f64 {
    assert!((0.0..1.0).contains(&q_total));
    1.0 - (1.0 - q_total).powf(0.25)
}

/// Remark 4: t = ⌈((n−1)p + √((n−1)ln(n−1)) + 1)/2⌉ — the minimum
/// threshold that defeats the unmasking attack (Prop. 1) while maximizing
/// dropout tolerance.
pub fn t_rule(n: usize, p: f64) -> usize {
    assert!(n >= 2);
    let nf = (n - 1) as f64;
    (((nf * p) + (nf * nf.ln()).sqrt() + 1.0) / 2.0).ceil() as usize
}

/// Theorem 3's reliability threshold on p (a.a.s. reliable above it):
/// p > (3√((n−1)ln(n−1)) − 1) / ((n−1)(2(1−q)^4 − 1)).
pub fn theorem3_threshold(n: usize, q: f64) -> f64 {
    let nf = (n - 1) as f64;
    let denom = nf * (2.0 * (1.0 - q).powi(4) - 1.0);
    assert!(denom > 0.0, "reliability threshold requires (1-q)^4 > 1/2");
    (3.0 * (nf * nf.ln()).sqrt() - 1.0) / denom
}

/// Theorem 4's privacy threshold on p (a.a.s. private above it):
/// p > ln(⌈n(1−q)^3 − √(n ln n)⌉) / ⌈n(1−q)^3 − √(n ln n)⌉.
pub fn theorem4_threshold(n: usize, q: f64) -> f64 {
    let nf = n as f64;
    let l = (nf * (1.0 - q).powi(3) - (nf * nf.ln()).sqrt()).ceil();
    assert!(l >= 2.0, "n too small for the Theorem-4 bound");
    l.ln() / l
}

/// Eq. (5): p* = max(privacy threshold, reliability threshold), given the
/// protocol-level dropout q_total (Table F.4 / Fig 4.1 parameterization).
pub fn p_star(n: usize, q_total: f64) -> f64 {
    let q = per_step_q(q_total);
    theorem4_threshold(n, q).max(theorem3_threshold(n, q)).min(1.0)
}

/// Theorem 5: upper bound on the reliability failure probability,
/// P_e^(r) ≤ n · exp(−(n−1) · D((t−1)/(n−1) ‖ p(1−q)^4)).
///
/// The Chernoff bound is valid (and returned) only when the success rate
/// p(1−q)^4 exceeds (t−1)/(n−1); otherwise returns 1.0 (vacuous).
pub fn theorem5_reliability_bound(n: usize, p: f64, q: f64, t: usize) -> f64 {
    let nf = (n - 1) as f64;
    let a = (t - 1) as f64 / nf;
    let b = (p * (1.0 - q).powi(4)).clamp(1e-12, 1.0 - 1e-12);
    if a >= b {
        return 1.0;
    }
    ((n as f64).ln() - nf * kl_div(a, b)).exp().min(1.0)
}

/// Theorem 6: upper bound on the privacy failure probability,
/// P_e^(p) ≤ Σ_m C(n,m)(1−q)^{3m}(1−(1−q)^3)^{n−m} Σ_k C(m,k)(1−p)^{k(m−k)}.
///
/// Evaluated in log space; values below ~1e-300 underflow to 0, which is
/// fine for plotting Fig 4.1 (the paper reports ≤ 1e-40).
pub fn theorem6_privacy_bound(n: usize, p: f64, q: f64) -> f64 {
    let s3 = (1.0 - q).powi(3); // P(client alive at step 2)
    let ln_s3 = s3.ln();
    let ln_not_s3 = (1.0 - s3).max(1e-300).ln();
    let ln_1mp = (1.0 - p).max(1e-300).ln();
    let mut total = 0.0f64;
    for m in 2..=n {
        let ln_am = ln_choose(n, m) + (m as f64) * ln_s3 + ((n - m) as f64) * ln_not_s3;
        let mut bm = 0.0f64;
        for k in 1..=m / 2 {
            let ln_term = ln_choose(m, k) + (k * (m - k)) as f64 * ln_1mp;
            bm += ln_term.exp();
        }
        total += ln_am.exp() * bm.min(1.0);
    }
    total.min(1.0)
}

/// Asymptotic reliability guarantee from Table 1:
/// P(reliable) ≥ 1 − O(n e^{−√(n log n)}) at p = p*.
pub fn table1_reliability_guarantee(n: usize, q_total: f64) -> f64 {
    let q = per_step_q(q_total);
    let p = p_star(n, q_total);
    let t = t_rule(n, p);
    1.0 - theorem5_reliability_bound(n, p, q, t)
}

/// A row of Table F.4: (n, q_total) → p*.
pub fn table_f4() -> Vec<(usize, f64, f64)> {
    let mut rows = Vec::new();
    for &q_total in &[0.0, 0.01, 0.05, 0.1] {
        for n in (100..=1000).step_by(100) {
            rows.push((n, q_total, p_star(n, q_total)));
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_choose_small_values() {
        assert!((ln_choose(5, 2) - (10f64).ln()).abs() < 1e-12);
        assert!((ln_choose(10, 0)).abs() < 1e-12);
        assert!((ln_choose(10, 10)).abs() < 1e-12);
        // large n via Stirling fallback: C(10000, 2) = 49995000
        assert!((ln_choose(10_000, 2) - (49_995_000f64).ln()).abs() < 1e-6);
    }

    #[test]
    fn kl_properties() {
        assert_eq!(kl_div(0.3, 0.3), 0.0);
        assert!(kl_div(0.1, 0.5) > 0.0);
        assert!(kl_div(0.0, 0.5) > 0.0);
    }

    #[test]
    fn reproduces_table_f4_values() {
        // Table F.4 of the paper, rounded to 3 decimals
        let cases = [
            (100, 0.0, 0.636),
            (300, 0.0, 0.411),
            (500, 0.0, 0.333),
            (1000, 0.0, 0.248),
            (100, 0.01, 0.649),
            (500, 0.05, 0.370),
            (100, 0.1, 0.795),
            (300, 0.1, 0.513),
            (500, 0.1, 0.416),
            (1000, 0.1, 0.311),
        ];
        for (n, qt, expect) in cases {
            let p = p_star(n, qt);
            assert!(
                (p - expect).abs() < 0.0015,
                "p*({n},{qt}) = {p:.4}, paper says {expect}"
            );
        }
    }

    #[test]
    fn reproduces_table51_thresholds() {
        // Table 5.1's t column for CCESA: (n, q_total) → t at p = p*
        let cases = [(100, 0.0, 43), (100, 0.1, 51), (300, 0.0, 83), (500, 0.0, 112), (500, 0.1, 133)];
        for (n, qt, expect_t) in cases {
            let t = t_rule(n, p_star(n, qt));
            assert!(
                (t as i64 - expect_t as i64).abs() <= 1,
                "t({n},{qt}) = {t}, paper says {expect_t}"
            );
        }
        // and SA's convention t = n/2 + 1 is just a special case the
        // benches set explicitly (paper used 51/151/251)
    }

    #[test]
    fn p_star_decreasing_in_n() {
        let mut prev = f64::INFINITY;
        for n in (100..=1000).step_by(100) {
            let p = p_star(n, 0.05);
            assert!(p < prev, "p* must decrease with n");
            prev = p;
        }
    }

    #[test]
    fn p_star_increasing_in_dropout() {
        for n in [100, 500, 1000] {
            assert!(p_star(n, 0.1) > p_star(n, 0.0));
        }
    }

    #[test]
    fn theorem5_bound_behaves() {
        // at p = p*, the bound must be < 10^-2-ish for moderate n (Fig 4.1
        // shows ≤ 1e-2 across the range)
        for n in [100usize, 300, 500, 1000] {
            let p = p_star(n, 0.1);
            let q = per_step_q(0.1);
            let t = t_rule(n, p);
            let b = theorem5_reliability_bound(n, p, q, t);
            assert!(b < 0.05, "n={n}: P_e^(r) bound {b}");
            // monotone: larger p ⇒ smaller bound
            let b_hi = theorem5_reliability_bound(n, (p * 1.3).min(1.0), q, t);
            assert!(b_hi <= b * 1.001);
        }
        // vacuous regime: success rate below (t-1)/(n-1)
        assert_eq!(theorem5_reliability_bound(100, 0.1, 0.5, 90), 1.0);
    }

    #[test]
    fn theorem6_bound_tiny_at_p_star() {
        // Fig 4.1: privacy failure bound ≤ 1e-40 at p = p*
        for n in [100usize, 500, 1000] {
            let p = p_star(n, 0.1);
            let q = per_step_q(0.1);
            let b = theorem6_privacy_bound(n, p, q);
            assert!(b < 1e-20, "n={n}: P_e^(p) bound {b:e}");
        }
    }

    #[test]
    fn theorem6_bound_large_when_p_small() {
        // sanity: with p near 0 the graph is a.s. disconnected
        let b = theorem6_privacy_bound(50, 0.01, 0.0);
        assert!(b > 0.5, "bound {b}");
    }

    #[test]
    fn per_step_q_inverts_total() {
        for qt in [0.0, 0.01, 0.05, 0.1, 0.5] {
            let q = per_step_q(qt);
            assert!((1.0 - (1.0 - q).powi(4) - qt).abs() < 1e-12);
        }
    }

    #[test]
    fn table1_guarantee_close_to_one() {
        assert!(table1_reliability_guarantee(500, 0.0) > 0.95);
    }
}
