//! Cooperative shutdown on SIGTERM/SIGINT.
//!
//! `ccesa serve` installs the handlers once at startup; the transport's
//! poll loops check [`requested`] every sweep and bail with the named
//! "round interrupted, resumable" error instead of dying mid-write. The
//! journal needs no extra flushing on that path — every record is
//! `write_all` + `sync_data` before the state transition it describes
//! takes effect, so whatever is on disk is already consistent.
//!
//! No `libc` crate: `std` links the platform C library on unix anyway, so
//! the two signal numbers and `signal(2)` are declared directly. The
//! handler only stores a relaxed atomic flag — async-signal-safe by
//! construction. Non-unix builds compile to a no-op install and the same
//! flag, which tests drive through [`trigger`].

use std::sync::atomic::{AtomicBool, Ordering};

static REQUESTED: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
mod sys {
    extern "C" {
        pub fn signal(signum: i32, handler: usize) -> usize;
    }

    pub const SIGINT: i32 = 2;
    pub const SIGTERM: i32 = 15;

    pub extern "C" fn on_signal(_signum: i32) {
        super::REQUESTED.store(true, std::sync::atomic::Ordering::Relaxed);
    }
}

/// Install SIGTERM/SIGINT handlers that set the shutdown flag. Idempotent;
/// a no-op off unix (use [`trigger`] there, and in tests).
pub fn install_handlers() {
    #[cfg(unix)]
    unsafe {
        let handler = sys::on_signal as extern "C" fn(i32) as usize;
        sys::signal(sys::SIGINT, handler);
        sys::signal(sys::SIGTERM, handler);
    }
}

/// Has a shutdown been requested (by signal or [`trigger`])?
pub fn requested() -> bool {
    REQUESTED.load(Ordering::Relaxed)
}

/// Request a shutdown in-process — what the signal handler does, exposed
/// for tests and embedders.
pub fn trigger() {
    REQUESTED.store(true, Ordering::Relaxed);
}

/// Clear the flag (tests run many shutdowns in one process).
pub fn reset() {
    REQUESTED.store(false, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_round_trips() {
        reset();
        assert!(!requested());
        trigger();
        assert!(requested());
        reset();
        assert!(!requested());
    }

    #[test]
    fn install_is_idempotent() {
        install_handlers();
        install_handlers();
    }
}
