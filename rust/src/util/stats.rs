//! Descriptive statistics and regression fits.
//!
//! Used by the bench harness (medians, percentiles), the Table-1 scaling
//! experiment (log–log slope fits for asymptotic-complexity validation),
//! and accuracy reporting.

/// Summary statistics of a sample.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "summary of empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 0.50),
            p95: percentile_sorted(&sorted, 0.95),
            max: sorted[n - 1],
        }
    }
}

/// Percentile (linear interpolation) of an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty() && (0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

pub fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn median(xs: &[f64]) -> f64 {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&s, 0.5)
}

/// Ordinary least squares fit y = a + b·x. Returns (a, b, r²).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = mean(xs);
    let my = mean(ys);
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let syy: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    let _ = n;
    (a, b, r2)
}

/// Fit y = c·x^k via log–log OLS; returns (k, r²).
///
/// This is how the Table-1 scaling benches validate asymptotics: measured
/// client bandwidth vs n should fit slope ≈ 0.5–0.6 for CCESA (√(n log n))
/// and ≈ 1.0 for SA.
pub fn power_law_exponent(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    let lx: Vec<f64> = xs.iter().map(|x| x.ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|y| y.ln()).collect();
    let (_, k, r2) = linear_fit(&lx, &ly);
    (k, r2)
}

/// Binomial confidence half-width (normal approx) for a proportion.
pub fn proportion_ci95(p_hat: f64, n: usize) -> f64 {
    1.96 * (p_hat * (1.0 - p_hat) / n as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_known_sample() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std - (2.0f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [10.0, 20.0, 30.0, 40.0];
        assert!((percentile_sorted(&s, 0.0) - 10.0).abs() < 1e-12);
        assert!((percentile_sorted(&s, 1.0) - 40.0).abs() < 1e-12);
        assert!((percentile_sorted(&s, 0.5) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn linear_fit_exact_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-10);
        assert!((b - 2.0).abs() < 1e-10);
        assert!((r2 - 1.0).abs() < 1e-10);
    }

    #[test]
    fn power_law_recovers_exponent() {
        let xs = [10.0f64, 100.0, 1000.0, 10000.0];
        let ys: Vec<f64> = xs.iter().map(|x| 5.0 * x.powf(1.5)).collect();
        let (k, r2) = power_law_exponent(&xs, &ys);
        assert!((k - 1.5).abs() < 1e-9, "k={k}");
        assert!(r2 > 0.999999);
    }

    #[test]
    fn sqrt_nlogn_fits_between_half_and_one() {
        // sanity for the Table-1 methodology: √(n log n) has local log-log
        // slope slightly above 0.5 over our n range.
        let xs: Vec<f64> = [50.0, 100.0, 200.0, 400.0, 800.0, 1600.0].to_vec();
        let ys: Vec<f64> = xs.iter().map(|n| (n * n.ln()).sqrt()).collect();
        let (k, _) = power_law_exponent(&xs, &ys);
        assert!(k > 0.5 && k < 0.75, "k={k}");
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }
}
