//! Hex encoding/decoding (test vectors, key display, transcript dumps).

/// Encode bytes as lowercase hex.
pub fn encode(bytes: &[u8]) -> String {
    const HEX: &[u8; 16] = b"0123456789abcdef";
    let mut out = String::with_capacity(bytes.len() * 2);
    for &b in bytes {
        out.push(HEX[(b >> 4) as usize] as char);
        out.push(HEX[(b & 0xf) as usize] as char);
    }
    out
}

/// Decode a hex string (case-insensitive, no separators).
pub fn decode(s: &str) -> Result<Vec<u8>, String> {
    let s = s.trim();
    if s.len() % 2 != 0 {
        return Err(format!("odd-length hex string ({})", s.len()));
    }
    fn nibble(c: u8) -> Result<u8, String> {
        match c {
            b'0'..=b'9' => Ok(c - b'0'),
            b'a'..=b'f' => Ok(c - b'a' + 10),
            b'A'..=b'F' => Ok(c - b'A' + 10),
            _ => Err(format!("invalid hex char {:?}", c as char)),
        }
    }
    let b = s.as_bytes();
    (0..s.len() / 2)
        .map(|i| Ok(nibble(b[2 * i])? << 4 | nibble(b[2 * i + 1])?))
        .collect()
}

/// Decode into a fixed-size array.
pub fn decode_array<const N: usize>(s: &str) -> Result<[u8; N], String> {
    let v = decode(s)?;
    v.try_into().map_err(|v: Vec<u8>| format!("expected {N} bytes, got {}", v.len()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let data: Vec<u8> = (0..=255).collect();
        assert_eq!(decode(&encode(&data)).unwrap(), data);
    }

    #[test]
    fn known_values() {
        assert_eq!(encode(&[0xde, 0xad, 0xbe, 0xef]), "deadbeef");
        assert_eq!(decode("DEADbeef").unwrap(), vec![0xde, 0xad, 0xbe, 0xef]);
        assert_eq!(encode(&[]), "");
        assert_eq!(decode("").unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn rejects_bad_input() {
        assert!(decode("abc").is_err());
        assert!(decode("zz").is_err());
        assert!(decode_array::<4>("aabb").is_err());
        assert_eq!(decode_array::<2>("aabb").unwrap(), [0xaa, 0xbb]);
    }
}
