//! Declarative command-line flag parsing (clap is unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, defaults, and auto-generated `--help`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone)]
struct FlagSpec {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    boolean: bool,
}

/// A small declarative argument parser.
#[derive(Debug, Default)]
pub struct Args {
    program: String,
    about: &'static str,
    specs: Vec<FlagSpec>,
    values: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    pub fn new(program: &str, about: &'static str) -> Self {
        Args { program: program.to_string(), about, ..Default::default() }
    }

    /// Declare a flag taking a value, with an optional default.
    pub fn flag(mut self, name: &'static str, default: Option<&str>, help: &'static str) -> Self {
        self.specs.push(FlagSpec {
            name,
            help,
            default: default.map(|s| s.to_string()),
            boolean: false,
        });
        self
    }

    /// Declare a boolean switch (present = true).
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(FlagSpec { name, help, default: None, boolean: true });
        self
    }

    /// Parse an explicit token list (tests) — returns Err(help) on `--help`
    /// or parse failure.
    pub fn parse_from<I: IntoIterator<Item = String>>(mut self, args: I) -> Result<Args, String> {
        let mut it = args.into_iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(self.help_text());
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| format!("unknown flag --{name}\n\n{}", self.help_text()))?
                    .clone();
                let value = if spec.boolean {
                    if inline.is_some() {
                        return Err(format!("--{name} is a switch and takes no value"));
                    }
                    "true".to_string()
                } else {
                    match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("flag --{name} requires a value"))?,
                    }
                };
                self.values.insert(name, value);
            } else {
                self.positional.push(tok);
            }
        }
        Ok(self)
    }

    /// Parse from `std::env::args()`, printing help and exiting on demand.
    pub fn parse(self) -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        match self.parse_from(argv) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(if msg.starts_with("usage:") { 0 } else { 2 });
            }
        }
    }

    fn lookup(&self, name: &str) -> Option<String> {
        if let Some(v) = self.values.get(name) {
            return Some(v.clone());
        }
        self.specs.iter().find(|s| s.name == name).and_then(|s| s.default.clone())
    }

    pub fn get_str(&self, name: &str) -> Option<String> {
        self.lookup(name)
    }

    pub fn get<T: std::str::FromStr>(&self, name: &str) -> Option<T> {
        self.lookup(name).and_then(|v| v.parse().ok())
    }

    /// Value with declared default; panics if the flag was never declared
    /// and has no default (programming error, not user error).
    pub fn req<T: std::str::FromStr>(&self, name: &str) -> T
    where
        T::Err: std::fmt::Debug,
    {
        let v = self
            .lookup(name)
            .unwrap_or_else(|| panic!("required flag --{name} missing and has no default"));
        v.parse().unwrap_or_else(|e| panic!("invalid value for --{name}: {v:?} ({e:?})"))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.lookup(name).map(|v| v == "true" || v == "1").unwrap_or(false)
    }

    /// True iff the flag was passed explicitly on the command line.
    /// Declared defaults do *not* count — spec-file resolution uses this
    /// to decide which flags override the file (`--spec` + overrides).
    pub fn is_set(&self, name: &str) -> bool {
        self.values.contains_key(name)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn help_text(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "usage: {} [flags] [args]\n\n{}\n\nflags:", self.program, self.about);
        for spec in &self.specs {
            let kind = if spec.boolean { "" } else { " <value>" };
            let dflt = spec
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            let _ = writeln!(s, "  --{}{kind}\n      {}{dflt}", spec.name, spec.help);
        }
        let _ = writeln!(s, "  --help\n      show this message");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(toks: &[&str]) -> Vec<String> {
        toks.iter().map(|s| s.to_string()).collect()
    }

    fn base() -> Args {
        Args::new("test", "about")
            .flag("n", Some("100"), "clients")
            .flag("p", None, "probability")
            .switch("verbose", "noise")
    }

    #[test]
    fn parses_values_and_defaults() {
        let a = base().parse_from(argv(&["--n", "50", "--p=0.3"])).unwrap();
        assert_eq!(a.req::<usize>("n"), 50);
        assert_eq!(a.get::<f64>("p"), Some(0.3));
        assert!(!a.get_bool("verbose"));

        let a = base().parse_from(argv(&[])).unwrap();
        assert_eq!(a.req::<usize>("n"), 100);
        assert_eq!(a.get::<f64>("p"), None);
    }

    #[test]
    fn switch_and_positional() {
        let a = base().parse_from(argv(&["--verbose", "cmd", "x"])).unwrap();
        assert!(a.get_bool("verbose"));
        assert_eq!(a.positional(), &["cmd".to_string(), "x".to_string()]);
    }

    #[test]
    fn is_set_distinguishes_explicit_flags_from_defaults() {
        let a = base().parse_from(argv(&["--n", "50"])).unwrap();
        assert!(a.is_set("n"));
        assert!(!a.is_set("p"), "never passed");
        assert!(!a.is_set("verbose"), "switches count only when present");
        let a = base().parse_from(argv(&["--verbose"])).unwrap();
        assert!(a.is_set("verbose"));
        assert!(!a.is_set("n"), "defaulted flags are not explicitly set");
    }

    #[test]
    fn rejects_unknown_and_missing_value() {
        assert!(base().parse_from(argv(&["--bogus"])).is_err());
        assert!(base().parse_from(argv(&["--p"])).is_err());
        assert!(base().parse_from(argv(&["--verbose=1"])).is_err());
    }

    #[test]
    fn help_lists_flags() {
        let err = base().parse_from(argv(&["--help"])).unwrap_err();
        assert!(err.contains("--n"));
        assert!(err.contains("[default: 100]"));
    }
}
