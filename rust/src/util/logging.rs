//! Minimal leveled logger implementing the `log` facade.
//!
//! `env_logger` is unavailable offline; this provides the same ergonomics:
//! level from `CCESA_LOG` (error|warn|info|debug|trace), timestamps relative
//! to process start, module targets.

use log::{Level, LevelFilter, Log, Metadata, Record};
use std::sync::OnceLock;
use std::time::Instant;

struct Logger {
    start: Instant,
    level: LevelFilter,
}

impl Log for Logger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<Logger> = OnceLock::new();

/// Parse a level name; defaults to Info on unknown input.
pub fn parse_level(s: &str) -> LevelFilter {
    match s.to_ascii_lowercase().as_str() {
        "off" => LevelFilter::Off,
        "error" => LevelFilter::Error,
        "warn" => LevelFilter::Warn,
        "debug" => LevelFilter::Debug,
        "trace" => LevelFilter::Trace,
        _ => LevelFilter::Info,
    }
}

/// Install the logger once; level from `CCESA_LOG` env (default info).
/// Safe to call multiple times.
pub fn init() {
    init_with(parse_level(&std::env::var("CCESA_LOG").unwrap_or_default()))
}

pub fn init_with(level: LevelFilter) {
    let logger = LOGGER.get_or_init(|| Logger { start: Instant::now(), level });
    // set_logger fails if already set — that's fine.
    let _ = log::set_logger(logger);
    log::set_max_level(logger.level);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(parse_level("error"), LevelFilter::Error);
        assert_eq!(parse_level("TRACE"), LevelFilter::Trace);
        assert_eq!(parse_level(""), LevelFilter::Info);
        assert_eq!(parse_level("bogus"), LevelFilter::Info);
        assert_eq!(parse_level("off"), LevelFilter::Off);
    }

    #[test]
    fn init_is_idempotent() {
        init_with(LevelFilter::Warn);
        init_with(LevelFilter::Debug); // second call must not panic
        log::info!("smoke");
    }
}
