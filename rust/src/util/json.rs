//! Minimal JSON parser and writer.
//!
//! Used for experiment configs (`configs/*.json`), the artifact manifest
//! emitted by `python/compile/aot.py`, and CSV-adjacent result dumps.
//! Supports the full JSON grammar except `\u` surrogate pairs are combined
//! best-effort; numbers are kept as f64 (adequate for configs/manifests).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use BTreeMap for deterministic serialization.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser { s: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.s.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; returns Null for missing keys on non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }
    /// Path lookup, e.g. `j.at(&["protocol", "graph", "p"])`.
    pub fn at(&self, path: &[&str]) -> &Json {
        path.iter().fold(self, |j, k| j.get(k))
    }

    // -- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn num<T: Into<f64>>(n: T) -> Json {
        Json::Num(n.into())
    }
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    s: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.s[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{lit}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                // surrogate pair
                                if self.s[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo.wrapping_sub(0xDC00) & 0x3FF);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(c.ok_or_else(|| self.err("invalid \\u escape"))?);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    let len = utf8_len(self.s[start]);
                    let end = (start + len).min(self.s.len());
                    let chunk = std::str::from_utf8(&self.s[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.s.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hx = std::str::from_utf8(&self.s[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(hx, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.s[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// --- writer ---------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(j.at(&["a"]).as_arr().unwrap()[2].get("b").as_str(), Some("x"));
        assert_eq!(*j.get("c"), Json::Null);
        assert_eq!(*j.get("missing"), Json::Null);
    }

    #[test]
    fn round_trips() {
        let cases = [
            r#"{"a":[1,2.5,true,null,"s"],"b":{"c":-1}}"#,
            r#"[[],{},""]"#,
            r#"{"unicode":"héllo ⊕ world"}"#,
        ];
        for c in cases {
            let j = Json::parse(c).unwrap();
            let again = Json::parse(&j.to_string()).unwrap();
            assert_eq!(j, again, "case {c}");
        }
    }

    #[test]
    fn escape_round_trip() {
        let j = Json::Str("line\nquote\"tab\tback\\end".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn surrogate_pair() {
        let j = Json::parse(r#""😀""#).unwrap();
        assert_eq!(j, Json::Str("😀".into()));
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "\"abc", "{\"a\" 1}", "01x", "nul", "[1]]", "", "{'a':1}"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn typed_accessors() {
        let j = Json::parse(r#"{"n": 5, "f": 5.5, "neg": -1}"#).unwrap();
        assert_eq!(j.get("n").as_u64(), Some(5));
        assert_eq!(j.get("n").as_usize(), Some(5));
        assert_eq!(j.get("f").as_u64(), None);
        assert_eq!(j.get("neg").as_u64(), None);
        assert_eq!(j.get("f").as_f64(), Some(5.5));
    }
}
