//! Minimal TOML-subset parser (the round-spec surface; toml-rs is
//! unavailable offline, like clap and serde).
//!
//! Supported grammar — deliberately the flat subset a round spec needs:
//! top-level keys, one level of `[section]` tables, `key = value` with
//! basic strings (`"…"` with `\"` `\\` `\n` `\t` escapes), integers,
//! floats, booleans, and single-line arrays of those scalars; `#`
//! comments and blank lines. No nested/inline tables, dotted keys,
//! multi-line strings, or datetimes — a spec using them gets a named
//! error with the offending line number, not silent misparsing.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed scalar (or flat array of scalars).
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_i64().filter(|i| *i >= 0).map(|i| i as u64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|u| u as usize)
    }
    /// Floats, with integer coercion (`qtotal = 0` means `0.0`).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Arr(a) => Some(a),
            _ => None,
        }
    }

    fn type_name(&self) -> &'static str {
        match self {
            TomlValue::Str(_) => "string",
            TomlValue::Int(_) => "integer",
            TomlValue::Float(_) => "float",
            TomlValue::Bool(_) => "boolean",
            TomlValue::Arr(_) => "array",
        }
    }
}

/// A parse error, carrying the 1-based source line.
#[derive(Debug, Clone)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error at line {}: {}", self.line, self.msg)
    }
}
impl std::error::Error for TomlError {}

/// A parsed document: the root table (section `""`) plus one level of
/// named `[section]` tables. BTreeMap keeps iteration deterministic.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Toml {
    sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl Toml {
    pub fn parse(input: &str) -> Result<Toml, TomlError> {
        let mut doc = Toml::default();
        let mut section = String::new();
        for (idx, raw) in input.lines().enumerate() {
            let lineno = idx + 1;
            let err = |msg: String| TomlError { line: lineno, msg };
            let line = strip_comment(raw, lineno)?;
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err(format!("unclosed section header {line:?}")))?
                    .trim();
                if name.is_empty() || name.starts_with('[') {
                    return Err(err(format!(
                        "bad section header {line:?} (only flat [section] tables are supported)"
                    )));
                }
                if !name.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'-' || b == b'_') {
                    return Err(err(format!(
                        "bad section name {name:?} (letters, digits, '-', '_')"
                    )));
                }
                section = name.to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| err(format!("expected `key = value`, got {line:?}")))?;
            let key = key.trim();
            if key.is_empty()
                || !key.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'-' || b == b'_')
            {
                return Err(err(format!("bad key {key:?} (letters, digits, '-', '_')")));
            }
            let value = parse_value(value.trim(), lineno)?;
            let table = doc.sections.entry(section.clone()).or_default();
            if table.insert(key.to_string(), value).is_some() {
                return Err(err(format!(
                    "duplicate key {key:?} in section {:?}",
                    if section.is_empty() { "(root)" } else { section.as_str() }
                )));
            }
        }
        Ok(doc)
    }

    /// Look up `key` in `[section]` (`""` = root). None when absent.
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section).and_then(|t| t.get(key))
    }

    /// Whether `[section]` appeared at all (even empty).
    pub fn has_section(&self, section: &str) -> bool {
        self.sections.contains_key(section)
    }

    /// Section names in deterministic order (the root is `""`).
    pub fn section_names(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }

    /// Keys of one section in deterministic order.
    pub fn keys(&self, section: &str) -> Vec<&str> {
        self.sections
            .get(section)
            .map(|t| t.keys().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    /// Typed lookup helper with a named type-mismatch error.
    pub fn typed<T>(
        &self,
        section: &str,
        key: &str,
        want: &str,
        cast: impl Fn(&TomlValue) -> Option<T>,
    ) -> Result<Option<T>, TomlError> {
        match self.get(section, key) {
            None => Ok(None),
            Some(v) => cast(v).map(Some).ok_or_else(|| TomlError {
                line: 0,
                msg: format!(
                    "key {key:?} in section {:?}: expected {want}, got {}",
                    if section.is_empty() { "(root)" } else { section },
                    v.type_name()
                ),
            }),
        }
    }
}

/// Drop a trailing `# comment`, respecting `#` inside quoted strings.
fn strip_comment(line: &str, lineno: usize) -> Result<&str, TomlError> {
    let bytes = line.as_bytes();
    let mut in_str = false;
    let mut escaped = false;
    for (i, &b) in bytes.iter().enumerate() {
        if in_str {
            if escaped {
                escaped = false;
            } else if b == b'\\' {
                escaped = true;
            } else if b == b'"' {
                in_str = false;
            }
        } else if b == b'"' {
            in_str = true;
        } else if b == b'#' {
            return Ok(&line[..i]);
        }
    }
    if in_str {
        return Err(TomlError { line: lineno, msg: "unterminated string".into() });
    }
    Ok(line)
}

fn parse_value(s: &str, lineno: usize) -> Result<TomlValue, TomlError> {
    let err = |msg: String| TomlError { line: lineno, msg };
    if s.is_empty() {
        return Err(err("missing value after `=`".into()));
    }
    if let Some(rest) = s.strip_prefix('"') {
        return parse_string(rest, lineno).map(TomlValue::Str);
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body
            .strip_suffix(']')
            .ok_or_else(|| err("unclosed array (arrays must fit on one line)".into()))?;
        let mut items = Vec::new();
        for part in split_array(body, lineno)? {
            let item = parse_value(&part, lineno)?;
            if matches!(item, TomlValue::Arr(_)) {
                return Err(err("nested arrays are not supported".into()));
            }
            items.push(item);
        }
        return Ok(TomlValue::Arr(items));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    // numbers: TOML-style `_` separators allowed; hex for seeds
    let clean: String = s.chars().filter(|c| *c != '_').collect();
    if let Some(hex) = clean.strip_prefix("0x").or_else(|| clean.strip_prefix("0X")) {
        return i64::from_str_radix(hex, 16)
            .map(TomlValue::Int)
            .map_err(|_| err(format!("bad hex integer {s:?}")));
    }
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        if f.is_finite() {
            return Ok(TomlValue::Float(f));
        }
    }
    Err(err(format!("unrecognized value {s:?} (string/integer/float/boolean/array)")))
}

/// Parse the body of a basic string (after the opening quote), rejecting
/// trailing junk after the closing quote.
fn parse_string(rest: &str, lineno: usize) -> Result<String, TomlError> {
    let err = |msg: String| TomlError { line: lineno, msg };
    let mut out = String::new();
    let mut chars = rest.chars();
    while let Some(c) = chars.next() {
        match c {
            '"' => {
                let tail = chars.as_str().trim();
                if !tail.is_empty() {
                    return Err(err(format!("trailing characters after string: {tail:?}")));
                }
                return Ok(out);
            }
            '\\' => match chars.next() {
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                other => {
                    return Err(err(format!("unsupported escape \\{}", other.unwrap_or(' '))))
                }
            },
            c => out.push(c),
        }
    }
    Err(err("unterminated string".into()))
}

/// Split an array body on top-level commas (commas inside strings don't
/// count); returns trimmed item substrings.
fn split_array(body: &str, lineno: usize) -> Result<Vec<String>, TomlError> {
    let mut items = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    let mut escaped = false;
    for c in body.chars() {
        if in_str {
            cur.push(c);
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                in_str = false;
            }
        } else if c == '"' {
            in_str = true;
            cur.push(c);
        } else if c == ',' {
            items.push(cur.trim().to_string());
            cur.clear();
        } else {
            cur.push(c);
        }
    }
    if in_str {
        return Err(TomlError { line: lineno, msg: "unterminated string in array".into() });
    }
    let last = cur.trim();
    if !last.is_empty() {
        items.push(last.to_string());
    }
    items.retain(|s| !s.is_empty());
    Ok(items)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_scalars_and_arrays() {
        let doc = Toml::parse(
            r#"
# round spec
title = "straggler sweep"   # inline comment
[round]
n = 12
qtotal = 0.1
seed = 0xC10C
sa = false
[timeouts]
sweep_ms = [5, 100, 1_000]
phase_ms = [1, 1, 1, 1]
"#,
        )
        .unwrap();
        assert_eq!(doc.get("", "title").unwrap().as_str(), Some("straggler sweep"));
        assert_eq!(doc.get("round", "n").unwrap().as_usize(), Some(12));
        assert_eq!(doc.get("round", "qtotal").unwrap().as_f64(), Some(0.1));
        assert_eq!(doc.get("round", "seed").unwrap().as_u64(), Some(0xC10C));
        assert_eq!(doc.get("round", "sa").unwrap().as_bool(), Some(false));
        let sweep: Vec<u64> = doc
            .get("timeouts", "sweep_ms")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_u64().unwrap())
            .collect();
        assert_eq!(sweep, vec![5, 100, 1000]);
        assert!(doc.has_section("timeouts"));
        assert!(!doc.has_section("clock"));
    }

    #[test]
    fn integer_coerces_to_float_but_not_reverse() {
        let doc = Toml::parse("a = 3\nb = 0.5").unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_f64(), Some(3.0));
        assert_eq!(doc.get("", "b").unwrap().as_i64(), None);
    }

    #[test]
    fn string_escapes_and_hash_inside_string() {
        let doc = Toml::parse(r#"path = "runs/j#1\t\"q\"" "#).unwrap();
        assert_eq!(doc.get("", "path").unwrap().as_str(), Some("runs/j#1\t\"q\""));
    }

    #[test]
    fn named_errors_carry_line_numbers() {
        for (src, needle) in [
            ("x = ", "missing value"),
            ("x == 3", "unrecognized value"),
            ("[open\nx = 1", "unclosed section"),
            ("[a.b]\n", "bad section name"),
            ("x = \"oops", "unterminated string"),
            ("x = [1, [2]]", "nested arrays"),
            ("x = [1, 2", "unclosed array"),
            ("x = 1\nx = 2", "duplicate key"),
            ("just words", "expected `key = value`"),
        ] {
            let e = Toml::parse(src).unwrap_err();
            assert!(e.to_string().contains(needle), "{src:?} → {e}");
            assert!(e.line >= 1, "{src:?}");
        }
        assert_eq!(Toml::parse("a = 1\nb = ").unwrap_err().line, 2);
    }

    #[test]
    fn typed_lookup_names_the_mismatch() {
        let doc = Toml::parse("[round]\nn = \"twelve\"").unwrap();
        let e = doc.typed("round", "n", "integer", TomlValue::as_usize).unwrap_err();
        assert!(e.to_string().contains("\"n\""), "{e}");
        assert!(e.to_string().contains("expected integer, got string"), "{e}");
        assert_eq!(doc.typed("round", "absent", "integer", TomlValue::as_usize).unwrap(), None);
    }
}
