//! Wall-clock timers and a scoped stopwatch for per-step protocol timing
//! (Table 5.1 reproduces per-step client/server running time).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug, Clone, Copy)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
    pub fn elapsed_us(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e6
    }
}

/// Accumulates named durations; used to attribute protocol wall time to
/// Steps 0–3 separately for client and server roles.
#[derive(Debug, Default, Clone)]
pub struct StepTimes {
    totals: BTreeMap<&'static str, Duration>,
    counts: BTreeMap<&'static str, u64>,
}

impl StepTimes {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under a named bucket.
    pub fn time<R>(&mut self, name: &'static str, f: impl FnOnce() -> R) -> R {
        let t = Instant::now();
        let r = f();
        self.add(name, t.elapsed());
        r
    }

    pub fn add(&mut self, name: &'static str, d: Duration) {
        *self.totals.entry(name).or_default() += d;
        *self.counts.entry(name).or_default() += 1;
    }

    pub fn merge(&mut self, other: &StepTimes) {
        for (k, v) in &other.totals {
            *self.totals.entry(k).or_default() += *v;
        }
        for (k, c) in &other.counts {
            *self.counts.entry(k).or_default() += *c;
        }
    }

    pub fn total_ms(&self, name: &str) -> f64 {
        self.totals.get(name).map(|d| d.as_secs_f64() * 1e3).unwrap_or(0.0)
    }

    /// Mean per-invocation milliseconds.
    pub fn mean_ms(&self, name: &str) -> f64 {
        let c = self.counts.get(name).copied().unwrap_or(0);
        if c == 0 {
            0.0
        } else {
            self.total_ms(name) / c as f64
        }
    }

    pub fn names(&self) -> Vec<&'static str> {
        self.totals.keys().copied().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.elapsed_ms() >= 1.0);
        assert!(t.elapsed_us() >= t.elapsed_ms()); // µs number ≥ ms number
    }

    #[test]
    fn step_times_accumulate_and_merge() {
        let mut s = StepTimes::new();
        s.add("step0", Duration::from_millis(10));
        s.add("step0", Duration::from_millis(20));
        s.add("step1", Duration::from_millis(5));
        assert!((s.total_ms("step0") - 30.0).abs() < 1e-9);
        assert!((s.mean_ms("step0") - 15.0).abs() < 1e-9);
        assert_eq!(s.total_ms("nope"), 0.0);
        assert_eq!(s.mean_ms("nope"), 0.0);

        let mut t = StepTimes::new();
        t.add("step1", Duration::from_millis(5));
        t.merge(&s);
        assert!((t.total_ms("step1") - 10.0).abs() < 1e-9);
        assert_eq!(t.names(), vec!["step0", "step1"]);
    }

    #[test]
    fn time_closure_returns_value() {
        let mut s = StepTimes::new();
        let v = s.time("work", || 7 * 6);
        assert_eq!(v, 42);
        assert!(s.total_ms("work") >= 0.0);
    }
}
