//! Deterministic random number generation.
//!
//! All stochastic components of the system (Erdős–Rényi graph sampling,
//! dropout injection, key generation, dataset synthesis, client selection)
//! draw from this module so that every experiment is exactly reproducible
//! from a single 64-bit seed recorded in the config.
//!
//! The core generator is the ChaCha20 block function (RFC 8439) run in
//! counter mode over a key derived from the seed with SplitMix64 — the same
//! primitive the protocol uses as `PRG(·)`, but with an independent domain
//! separation constant so simulation randomness never collides with
//! protocol mask streams.

use crate::crypto::chacha20::ChaCha20;

/// SplitMix64 step: the standard seeding mixer (Steele et al.).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Deterministic ChaCha20-backed RNG.
///
/// Buffers one 64-byte ChaCha block at a time; `next_u64` drains the buffer
/// 8 bytes per call. Cloning an `Rng` forks an identical stream; use
/// [`Rng::split`] to derive an independent stream instead.
#[derive(Clone)]
pub struct Rng {
    core: ChaCha20,
    buf: [u8; 64],
    pos: usize,
    counter: u32,
}

impl Rng {
    /// Create an RNG from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut s = seed;
        let mut key = [0u8; 32];
        for chunk in key.chunks_mut(8) {
            chunk.copy_from_slice(&splitmix64(&mut s).to_le_bytes());
        }
        // Domain-separated nonce: "sim" randomness, not protocol masks.
        let nonce = *b"ccesa-sim\0\0\0";
        Self { core: ChaCha20::new(&key, &nonce), buf: [0u8; 64], pos: 64, counter: 0 }
    }

    /// Create an RNG directly from a 32-byte key (used by the protocol PRG).
    pub fn from_key(key: &[u8; 32], nonce: &[u8; 12]) -> Self {
        Self { core: ChaCha20::new(key, nonce), buf: [0u8; 64], pos: 64, counter: 0 }
    }

    /// Derive an independent child stream; deterministic in (self, tag).
    pub fn split(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    #[inline]
    fn refill(&mut self) {
        self.core.block(self.counter, &mut self.buf);
        self.counter = self.counter.wrapping_add(1);
        self.pos = 0;
    }

    /// Next uniform u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        if self.pos + 8 > 64 {
            self.refill();
        }
        let v = u64::from_le_bytes(self.buf[self.pos..self.pos + 8].try_into().unwrap());
        self.pos += 8;
        v
    }

    /// Next uniform u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        self.next_u64() as u32
    }

    /// Fill a byte slice with uniform random bytes.
    pub fn fill_bytes(&mut self, out: &mut [u8]) {
        let mut i = 0;
        while i < out.len() {
            if self.pos >= 64 {
                self.refill();
            }
            let n = (out.len() - i).min(64 - self.pos);
            out[i..i + n].copy_from_slice(&self.buf[self.pos..self.pos + n]);
            self.pos += n;
            i += n;
        }
    }

    /// Uniform in [0, bound) without modulo bias (Lemire rejection).
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "gen_range bound must be positive");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut lo = m as u64;
        if lo < bound {
            let t = bound.wrapping_neg() % bound;
            while lo < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Bernoulli(p) draw.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple, adequate
    /// for dataset synthesis — not on any protocol hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std as f32 (dataset synthesis convenience).
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (Floyd's algorithm for small k,
    /// shuffle for large k).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all.sort_unstable();
            return all;
        }
        let mut chosen = std::collections::BTreeSet::new();
        for j in (n - k)..n {
            let t = self.gen_range(j as u64 + 1) as usize;
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        chosen.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn split_streams_are_independent_and_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let mut ca = a.split(1);
        let mut cb = b.split(1);
        for _ in 0..100 {
            assert_eq!(ca.next_u64(), cb.next_u64());
        }
        let mut c2 = Rng::new(7).split(2);
        assert_ne!(Rng::new(7).split(1).next_u64(), c2.next_u64());
    }

    #[test]
    fn gen_range_in_bounds_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn bernoulli_frequency() {
        let mut r = Rng::new(13);
        let hits = (0..10_000).filter(|_| r.bernoulli(0.3)).count();
        assert!((hits as f64 / 10_000.0 - 0.3).abs() < 0.02);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "100-element identity shuffle");
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(19);
        for (n, k) in [(100usize, 5usize), (100, 80), (10, 10), (1, 1), (50, 0)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            assert!(s.windows(2).all(|w| w[0] < w[1]), "distinct+sorted");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn fill_bytes_matches_next_u64_stream() {
        let mut a = Rng::new(23);
        let mut b = Rng::new(23);
        let mut buf = [0u8; 24];
        a.fill_bytes(&mut buf);
        for chunk in buf.chunks(8) {
            assert_eq!(u64::from_le_bytes(chunk.try_into().unwrap()), b.next_u64());
        }
    }
}
