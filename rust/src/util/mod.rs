//! Utility substrates built from scratch for the offline environment:
//! deterministic RNG, hex encoding, JSON (config + artifact manifests),
//! CLI flag parsing, descriptive statistics and regression fits, timers
//! and a minimal leveled logger.

pub mod cli;
pub mod hex;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod timer;
