//! Utility substrates built from scratch for the offline environment:
//! deterministic RNG, hex encoding, JSON (config + artifact manifests),
//! a TOML subset (round specs), CLI flag parsing, descriptive statistics
//! and regression fits, timers and a minimal leveled logger.

pub mod cli;
pub mod hex;
pub mod json;
pub mod logging;
pub mod rng;
pub mod shutdown;
pub mod stats;
pub mod timer;
pub mod toml;

/// The modulus mask of the aggregation domain Z_{2^bits}.
///
/// **This is the single definition of the mask-width domain:** `bits`
/// ∈ [1, 64], where 64 means the full u64 word. Every module that
/// reduces values into the masked domain (`masking`, `crypto::prg`,
/// `protocol::{client,server,engine}`, `sim`) goes through this helper
/// rather than re-deriving `(1 << bits) - 1` inline. The quantizer
/// additionally requires `bits ≥ 2` because it spends one bit on the
/// two's-complement sign (see `masking::Quantizer`).
#[inline]
pub fn mod_mask(bits: u32) -> u64 {
    assert!((1..=64).contains(&bits), "mask width must be in 1..=64, got {bits}");
    if bits == 64 {
        u64::MAX
    } else {
        (1u64 << bits) - 1
    }
}

#[cfg(test)]
mod mod_mask_tests {
    use super::mod_mask;

    #[test]
    fn boundary_widths() {
        assert_eq!(mod_mask(1), 1);
        assert_eq!(mod_mask(16), 0xFFFF);
        assert_eq!(mod_mask(32), 0xFFFF_FFFF);
        assert_eq!(mod_mask(63), u64::MAX >> 1);
        assert_eq!(mod_mask(64), u64::MAX);
    }

    #[test]
    #[should_panic(expected = "mask width")]
    fn rejects_zero() {
        mod_mask(0);
    }

    #[test]
    #[should_panic(expected = "mask width")]
    fn rejects_over_64() {
        mod_mask(65);
    }
}
