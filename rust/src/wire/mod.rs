//! Byte-level wire codec for the round protocol.
//!
//! Every [`Up`]/[`Down`] message in `protocol::messages` has an explicit
//! frame encoding here, so a round can run over a real socket
//! (`net::socket`) instead of in-process function calls. The format is
//! deliberately simple and versioned:
//!
//! ```text
//! frame    := len:u32le  body
//! body     := version:u8  msg_type:u8  round:u32le  payload
//! ```
//!
//! `len` counts the body only (so `HEADER_BYTES ≤ len ≤ MAX_FRAME`);
//! `version` is [`WIRE_VERSION`] and a peer speaking a different version is
//! rejected at decode (the error names the byte, which is the whole
//! negotiation story for v1: both sides are this binary); `round` tags
//! every frame with the round id so frames from a stale or misconfigured
//! peer never splice into a live round.
//!
//! Decoding malformed bytes must return [`WireError`], never panic: the
//! decoder reads through a bounds-checked cursor, validates counts against
//! the remaining bytes before allocating, and rejects trailing garbage.
//! These properties are pinned by the round-trip, golden-bytes and
//! malformed-frame fuzz tests at the bottom of this file.
//!
//! Note the two byte vocabularies in play: logical `size_bytes()` (the
//! Appendix-C cost model `NetStats` charges) and the framed bytes actually
//! written here, which add the length prefix, header and explicit counts.
//! The socket path records both — see `NetStats::framed_up`/`framed_down`.

use crate::codec::{EncodedUpdate, IndexPlan};
use crate::protocol::messages::*;
use crate::protocol::ClientId;
use crate::shamir::Share;
use crate::util::mod_mask;
use std::sync::Arc;
use thiserror::Error;

/// Wire format version carried in every frame.
pub const WIRE_VERSION: u8 = 1;
/// Body bytes before the payload: version (1) + msg type (1) + round (4).
pub const HEADER_BYTES: usize = 6;
/// Bytes of the frame length prefix.
pub const LEN_BYTES: usize = 4;
/// Upper bound on one frame's body; a length prefix above this is treated
/// as corruption (or an attack) rather than an allocation request.
pub const MAX_FRAME: usize = 1 << 30;

// msg_type bytes: server → client in 0x00.., client → server in 0x10..
const MT_START: u8 = 0x00;
const MT_BUNDLE: u8 = 0x01;
const MT_DELIVERY: u8 = 0x02;
const MT_ANNOUNCE: u8 = 0x03;
const MT_FINISH: u8 = 0x04;
const MT_WARM_PLAN: u8 = 0x05;
const MT_ADV: u8 = 0x10;
const MT_SHARES: u8 = 0x11;
const MT_MASKED: u8 = 0x12;
const MT_UNMASK: u8 = 0x13;
const MT_DROPPED: u8 = 0x14;
const MT_FAILED: u8 = 0x15;
const MT_WARM: u8 = 0x16;

/// Everything that can go wrong decoding a frame. Decoders return these;
/// they never panic on input bytes.
#[derive(Debug, Error, PartialEq, Eq)]
pub enum WireError {
    #[error("frame truncated while reading {0}")]
    Truncated(&'static str),
    #[error("frame length {0} exceeds MAX_FRAME")]
    Oversized(u64),
    #[error("frame length {0} shorter than the fixed header")]
    ShortFrame(usize),
    #[error("unsupported wire version {0}")]
    BadVersion(u8),
    #[error("unknown message type 0x{0:02x}")]
    BadMsgType(u8),
    #[error("{0} bytes of trailing garbage after the payload")]
    TrailingBytes(usize),
    #[error("invalid {0}")]
    BadValue(&'static str),
}

/// Bounds-checked forward reader over a frame body. `pub(crate)` so the
/// journal record decoder (`crate::journal`) shares the same never-panic
/// cursor discipline instead of re-implementing it.
pub(crate) struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub(crate) fn new(b: &'a [u8]) -> Reader<'a> {
        Reader { b, pos: 0 }
    }

    pub(crate) fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }

    pub(crate) fn take(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated(what));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub(crate) fn u8(&mut self, what: &'static str) -> Result<u8, WireError> {
        Ok(self.take(1, what)?[0])
    }

    pub(crate) fn u16(&mut self, what: &'static str) -> Result<u16, WireError> {
        let s = self.take(2, what)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    pub(crate) fn u32(&mut self, what: &'static str) -> Result<u32, WireError> {
        let s = self.take(4, what)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    pub(crate) fn u64(&mut self, what: &'static str) -> Result<u64, WireError> {
        let s = self.take(8, what)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }

    pub(crate) fn client_id(&mut self, what: &'static str) -> Result<ClientId, WireError> {
        Ok(self.u32(what)? as ClientId)
    }

    pub(crate) fn done(&self) -> Result<(), WireError> {
        if self.remaining() > 0 {
            return Err(WireError::TrailingBytes(self.remaining()));
        }
        Ok(())
    }
}

pub(crate) fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_id(out: &mut Vec<u8>, id: ClientId) {
    debug_assert!(id <= u32::MAX as usize, "client id {id} overflows the wire");
    put_u32(out, id as u32);
}

/// Wrap a payload into a complete frame (length prefix included).
fn frame(msg_type: u8, round: u32, payload: &[u8]) -> Vec<u8> {
    let len = HEADER_BYTES + payload.len();
    assert!(len <= MAX_FRAME, "frame body {len} exceeds MAX_FRAME");
    let mut out = Vec::with_capacity(LEN_BYTES + len);
    put_u32(&mut out, len as u32);
    out.push(WIRE_VERSION);
    out.push(msg_type);
    put_u32(&mut out, round);
    out.extend_from_slice(payload);
    out
}

/// Split a frame body into (msg_type, round, payload), validating version.
fn split_body(body: &[u8]) -> Result<(u8, u32, &[u8]), WireError> {
    if body.len() < HEADER_BYTES {
        return Err(WireError::ShortFrame(body.len()));
    }
    if body[0] != WIRE_VERSION {
        return Err(WireError::BadVersion(body[0]));
    }
    let round = u32::from_le_bytes([body[2], body[3], body[4], body[5]]);
    Ok((body[1], round, &body[HEADER_BYTES..]))
}

fn put_encrypted_share(out: &mut Vec<u8>, es: &EncryptedShare) {
    put_id(out, es.from);
    put_id(out, es.to);
    put_u32(out, es.ciphertext.len() as u32);
    out.extend_from_slice(&es.ciphertext);
}

fn read_encrypted_share(r: &mut Reader<'_>) -> Result<EncryptedShare, WireError> {
    let from = r.client_id("encrypted-share sender")?;
    let to = r.client_id("encrypted-share recipient")?;
    let ct_len = r.u32("ciphertext length")? as usize;
    let ciphertext = r.take(ct_len, "ciphertext")?.to_vec();
    Ok(EncryptedShare { from, to, ciphertext })
}

fn put_share(out: &mut Vec<u8>, s: &Share) {
    let bytes = s.to_bytes();
    debug_assert!(bytes.len() <= u16::MAX as usize, "share exceeds the u16 length field");
    out.extend_from_slice(&(bytes.len() as u16).to_le_bytes());
    out.extend_from_slice(&bytes);
}

fn read_share(r: &mut Reader<'_>) -> Result<Share, WireError> {
    let len = r.u16("share length")? as usize;
    let bytes = r.take(len, "share bytes")?;
    Share::from_bytes(bytes).map_err(|_| WireError::BadValue("shamir share"))
}

/// Encode a server → client message as a complete frame.
pub fn encode_down(round: u32, down: &Down) -> Vec<u8> {
    match down {
        Down::Start => frame(MT_START, round, &[]),
        Down::Bundle(b) => {
            let mut p = Vec::with_capacity(4 + b.entries.len() * 72);
            put_u32(&mut p, b.entries.len() as u32);
            for (id, c_pk, s_pk) in &b.entries {
                put_id(&mut p, *id);
                p.extend_from_slice(c_pk);
                p.extend_from_slice(s_pk);
            }
            frame(MT_BUNDLE, round, &p)
        }
        Down::Delivery(d) => {
            let mut p = Vec::new();
            put_id(&mut p, d.to);
            put_u32(&mut p, d.shares.len() as u32);
            for es in &d.shares {
                put_encrypted_share(&mut p, es);
            }
            frame(MT_DELIVERY, round, &p)
        }
        Down::Announce(a) => {
            let mut p = Vec::with_capacity(4 + a.v3.len() * 4);
            put_u32(&mut p, a.v3.len() as u32);
            for &id in &a.v3 {
                put_id(&mut p, id);
            }
            frame(MT_ANNOUNCE, round, &p)
        }
        Down::WarmPlan(w) => {
            let mut p = Vec::with_capacity(12 + w.alive_bitmap.len() + w.keys.len() * 72);
            put_id(&mut p, w.to);
            put_u32(&mut p, w.alive_bitmap.len() as u32);
            p.extend_from_slice(&w.alive_bitmap);
            put_u32(&mut p, w.keys.len() as u32);
            for (id, c_pk, s_pk) in &w.keys {
                put_id(&mut p, *id);
                p.extend_from_slice(c_pk);
                p.extend_from_slice(s_pk);
            }
            frame(MT_WARM_PLAN, round, &p)
        }
        Down::Finish => frame(MT_FINISH, round, &[]),
    }
}

/// Encode a client → server message as a complete frame.
///
/// Masked values are written packed: `bits.div_ceil(8)` little-endian
/// bytes per element, exactly the payload width `size_bytes()` models.
pub fn encode_up(round: u32, up: &Up) -> Vec<u8> {
    match up {
        Up::Adv(a) => {
            let mut p = Vec::with_capacity(4 + 64);
            put_id(&mut p, a.id);
            p.extend_from_slice(&a.c_pk);
            p.extend_from_slice(&a.s_pk);
            frame(MT_ADV, round, &p)
        }
        Up::Shares(u) => {
            let mut p = Vec::new();
            put_id(&mut p, u.from);
            put_u32(&mut p, u.shares.len() as u32);
            for es in &u.shares {
                put_encrypted_share(&mut p, es);
            }
            frame(MT_SHARES, round, &p)
        }
        Up::Masked(m) => {
            let nbytes = m.bits.div_ceil(8) as usize;
            let mut p = Vec::with_capacity(9 + m.update.values.len() * nbytes);
            put_id(&mut p, m.id);
            p.push(m.bits as u8);
            put_u32(&mut p, m.update.values.len() as u32);
            let mask = mod_mask(m.bits);
            for &v in &m.update.values {
                p.extend_from_slice(&(v & mask).to_le_bytes()[..nbytes]);
            }
            frame(MT_MASKED, round, &p)
        }
        Up::Unmask(u) => {
            let mut p = Vec::new();
            put_id(&mut p, u.from);
            put_u32(&mut p, u.shares.len() as u32);
            for (owner, kind, share) in &u.shares {
                put_id(&mut p, *owner);
                p.push(match kind {
                    ShareKind::SelfMask => 0,
                    ShareKind::SecretKey => 1,
                });
                put_share(&mut p, share);
            }
            frame(MT_UNMASK, round, &p)
        }
        Up::Dropped(id, step) => {
            let mut p = Vec::with_capacity(5);
            put_id(&mut p, *id);
            p.push(*step);
            frame(MT_DROPPED, round, &p)
        }
        Up::Warm(w) => {
            // payload: id | flags (bit0 = support, bit1 = rekey) | parts
            let mut p = Vec::with_capacity(
                5 + w.support.as_ref().map_or(0, |s| 4 + s.len() * 4)
                    + if w.rekey.is_some() { 64 } else { 0 },
            );
            put_id(&mut p, w.id);
            let flags =
                w.support.is_some() as u8 | ((w.rekey.is_some() as u8) << 1);
            p.push(flags);
            if let Some(support) = &w.support {
                put_u32(&mut p, support.len() as u32);
                for &i in support {
                    put_u32(&mut p, i);
                }
            }
            if let Some((c_pk, s_pk)) = &w.rekey {
                p.extend_from_slice(c_pk);
                p.extend_from_slice(s_pk);
            }
            frame(MT_WARM, round, &p)
        }
        Up::Failed(id, step, msg) => {
            // diagnostics only: cap at the u16 length field on a char
            // boundary so the frame stays bounded and valid UTF-8
            let mut end = msg.len().min(u16::MAX as usize);
            while !msg.is_char_boundary(end) {
                end -= 1;
            }
            let msg = &msg[..end];
            let mut p = Vec::with_capacity(7 + msg.len());
            put_id(&mut p, *id);
            p.push(*step);
            p.extend_from_slice(&(msg.len() as u16).to_le_bytes());
            p.extend_from_slice(msg.as_bytes());
            frame(MT_FAILED, round, &p)
        }
    }
}

/// Decode a server → client frame body (length prefix already stripped).
pub fn decode_down(body: &[u8]) -> Result<(u32, Down), WireError> {
    let (mt, round, payload) = split_body(body)?;
    let mut r = Reader::new(payload);
    let down = match mt {
        MT_START => Down::Start,
        MT_BUNDLE => {
            let count = r.u32("bundle entry count")? as usize;
            let need = count
                .checked_mul(4 + 2 * A_K)
                .ok_or(WireError::BadValue("bundle entry count"))?;
            if r.remaining() < need {
                return Err(WireError::Truncated("bundle entries"));
            }
            let mut entries = Vec::with_capacity(count);
            for _ in 0..count {
                let id = r.client_id("bundle entry id")?;
                let c_pk: [u8; 32] = r.take(A_K, "c_pk")?.try_into().unwrap();
                let s_pk: [u8; 32] = r.take(A_K, "s_pk")?.try_into().unwrap();
                entries.push((id, c_pk, s_pk));
            }
            Down::Bundle(KeyBundle { entries })
        }
        MT_DELIVERY => {
            let to = r.client_id("delivery recipient")?;
            let count = r.u32("delivery share count")? as usize;
            let mut shares = Vec::new();
            for _ in 0..count {
                shares.push(read_encrypted_share(&mut r)?);
            }
            Down::Delivery(ShareDelivery { to, shares })
        }
        MT_ANNOUNCE => {
            let count = r.u32("announce count")? as usize;
            let need = count.checked_mul(4).ok_or(WireError::BadValue("announce count"))?;
            if r.remaining() < need {
                return Err(WireError::Truncated("announce ids"));
            }
            let mut v3 = Vec::with_capacity(count);
            for _ in 0..count {
                v3.push(r.client_id("announce id")?);
            }
            Down::Announce(Arc::new(SurvivorAnnounce { v3 }))
        }
        MT_WARM_PLAN => {
            let to = r.client_id("warm-plan recipient")?;
            let bm_len = r.u32("warm-plan bitmap length")? as usize;
            let alive_bitmap = r.take(bm_len, "warm-plan bitmap")?.to_vec();
            let count = r.u32("warm-plan key count")? as usize;
            let need = count
                .checked_mul(4 + 2 * A_K)
                .ok_or(WireError::BadValue("warm-plan key count"))?;
            if r.remaining() < need {
                return Err(WireError::Truncated("warm-plan keys"));
            }
            let mut keys = Vec::with_capacity(count);
            for _ in 0..count {
                let id = r.client_id("warm-plan key id")?;
                let c_pk: [u8; 32] = r.take(A_K, "c_pk")?.try_into().unwrap();
                let s_pk: [u8; 32] = r.take(A_K, "s_pk")?.try_into().unwrap();
                keys.push((id, c_pk, s_pk));
            }
            Down::WarmPlan(WarmPlan { to, alive_bitmap, keys })
        }
        MT_FINISH => Down::Finish,
        other => return Err(WireError::BadMsgType(other)),
    };
    r.done()?;
    Ok((round, down))
}

/// Decode a client → server frame body. Masked inputs decode against the
/// round's shared [`IndexPlan`]: the element count must equal `plan.len()`
/// and every value must lie in `Z_{2^bits}` — anything else is a malformed
/// (or misaligned) frame, reported as an `Err` before it can reach the
/// aggregation path.
pub fn decode_up(body: &[u8], plan: &Arc<IndexPlan>) -> Result<(u32, Up), WireError> {
    let (mt, round, payload) = split_body(body)?;
    let mut r = Reader::new(payload);
    let up = match mt {
        MT_ADV => {
            let id = r.client_id("advertise id")?;
            let c_pk: [u8; 32] = r.take(A_K, "c_pk")?.try_into().unwrap();
            let s_pk: [u8; 32] = r.take(A_K, "s_pk")?.try_into().unwrap();
            Up::Adv(AdvertiseKeys { id, c_pk, s_pk })
        }
        MT_SHARES => {
            let from = r.client_id("upload sender")?;
            let count = r.u32("upload share count")? as usize;
            let mut shares = Vec::new();
            for _ in 0..count {
                shares.push(read_encrypted_share(&mut r)?);
            }
            Up::Shares(ShareUpload { from, shares })
        }
        MT_MASKED => {
            let id = r.client_id("masked sender")?;
            let bits = r.u8("masked bit width")? as u32;
            if !(1..=64).contains(&bits) {
                return Err(WireError::BadValue("masked bit width"));
            }
            let count = r.u32("masked value count")? as usize;
            if count != plan.len() {
                return Err(WireError::BadValue("masked value count vs round plan"));
            }
            let nbytes = bits.div_ceil(8) as usize;
            let need = count.checked_mul(nbytes).ok_or(WireError::BadValue("masked value count"))?;
            if r.remaining() < need {
                return Err(WireError::Truncated("masked values"));
            }
            let mask = mod_mask(bits);
            let mut values = Vec::with_capacity(count);
            for _ in 0..count {
                let chunk = r.take(nbytes, "masked value")?;
                let mut le = [0u8; 8];
                le[..nbytes].copy_from_slice(chunk);
                let v = u64::from_le_bytes(le);
                if v & !mask != 0 {
                    return Err(WireError::BadValue("masked value outside Z_{2^bits}"));
                }
                values.push(v);
            }
            Up::Masked(MaskedInput {
                id,
                update: EncodedUpdate { values, plan: plan.clone() },
                bits,
            })
        }
        MT_UNMASK => {
            let from = r.client_id("unmask sender")?;
            let count = r.u32("unmask share count")? as usize;
            let mut shares = Vec::new();
            for _ in 0..count {
                let owner = r.client_id("share owner")?;
                let kind = match r.u8("share kind")? {
                    0 => ShareKind::SelfMask,
                    1 => ShareKind::SecretKey,
                    _ => return Err(WireError::BadValue("share kind")),
                };
                shares.push((owner, kind, read_share(&mut r)?));
            }
            Up::Unmask(UnmaskShares { from, shares })
        }
        MT_WARM => {
            let id = r.client_id("warm id")?;
            let flags = r.u8("warm flags")?;
            if flags & !0b11 != 0 {
                return Err(WireError::BadValue("warm flags"));
            }
            let support = if flags & 1 != 0 {
                let count = r.u32("warm support count")? as usize;
                let need = count.checked_mul(4).ok_or(WireError::BadValue("warm support count"))?;
                if r.remaining() < need {
                    return Err(WireError::Truncated("warm support ids"));
                }
                let mut support = Vec::with_capacity(count);
                for _ in 0..count {
                    support.push(r.u32("warm support id")?);
                }
                if !support.windows(2).all(|w| w[0] < w[1]) {
                    return Err(WireError::BadValue("warm support order"));
                }
                Some(support)
            } else {
                None
            };
            let rekey = if flags & 2 != 0 {
                let c_pk: [u8; 32] = r.take(A_K, "warm c_pk")?.try_into().unwrap();
                let s_pk: [u8; 32] = r.take(A_K, "warm s_pk")?.try_into().unwrap();
                Some((c_pk, s_pk))
            } else {
                None
            };
            Up::Warm(WarmResume { id, support, rekey })
        }
        MT_DROPPED => {
            let id = r.client_id("dropped id")?;
            let step = r.u8("dropped step")?;
            Up::Dropped(id, step)
        }
        MT_FAILED => {
            let id = r.client_id("failed id")?;
            let step = r.u8("failed step")?;
            let len = r.u16("failure message length")? as usize;
            let bytes = r.take(len, "failure message")?;
            let msg = std::str::from_utf8(bytes)
                .map_err(|_| WireError::BadValue("failure message utf-8"))?
                .to_string();
            Up::Failed(id, step, msg)
        }
        other => return Err(WireError::BadMsgType(other)),
    };
    r.done()?;
    Ok((round, up))
}

/// Incremental frame reassembly for a nonblocking stream: feed raw reads
/// in with [`FrameBuffer::extend`], pop complete frame bodies with
/// [`FrameBuffer::next_frame`]. Corrupt length prefixes surface as
/// [`WireError`] (the connection should be dropped — the byte stream has
/// lost framing).
#[derive(Default)]
pub struct FrameBuffer {
    buf: Vec<u8>,
    start: usize,
}

impl FrameBuffer {
    pub fn new() -> FrameBuffer {
        FrameBuffer::default()
    }

    pub fn extend(&mut self, bytes: &[u8]) {
        // compact before growing: the consumed prefix is dead weight
        if self.start > 0 && self.start * 2 >= self.buf.len() {
            self.buf.drain(..self.start);
            self.start = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Pop the next complete frame body (length prefix stripped), `None`
    /// when more bytes are needed.
    pub fn next_frame(&mut self) -> Result<Option<Vec<u8>>, WireError> {
        let avail = &self.buf[self.start..];
        if avail.len() < LEN_BYTES {
            return Ok(None);
        }
        let len = u32::from_le_bytes([avail[0], avail[1], avail[2], avail[3]]) as usize;
        if len > MAX_FRAME {
            return Err(WireError::Oversized(len as u64));
        }
        if len < HEADER_BYTES {
            return Err(WireError::ShortFrame(len));
        }
        if avail.len() < LEN_BYTES + len {
            return Ok(None);
        }
        let body = avail[LEN_BYTES..LEN_BYTES + len].to_vec();
        self.start += LEN_BYTES + len;
        Ok(Some(body))
    }
}

/// Blocking read of one frame from a stream. Returns `Ok(None)` on clean
/// EOF at a frame boundary; a corrupt length prefix or EOF mid-frame maps
/// to `io::ErrorKind::InvalidData`/`UnexpectedEof`.
pub fn read_frame<R: std::io::Read>(r: &mut R) -> std::io::Result<Option<Vec<u8>>> {
    let mut len4 = [0u8; LEN_BYTES];
    let mut got = 0;
    while got < LEN_BYTES {
        match r.read(&mut len4[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "eof inside a frame length prefix",
                ))
            }
            Ok(k) => got += k,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    let len = u32::from_le_bytes(len4) as usize;
    if !(HEADER_BYTES..=MAX_FRAME).contains(&len) {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("invalid frame length {len}"),
        ));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(Some(body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// `Up`/`Down` carry no `PartialEq` (the `Arc`'d announce and the
    /// plan-bearing update make derive awkward); their `Debug` output is
    /// total over every field, so it serves as the equality witness.
    fn dbg<T: std::fmt::Debug>(v: &T) -> String {
        format!("{v:?}")
    }

    fn sample_share(x: u16) -> Share {
        Share { x, y: (0..16).map(|i| x.wrapping_mul(251).wrapping_add(i)).collect() }
    }

    fn sample_ups(plan: &Arc<IndexPlan>, bits: u32) -> Vec<Up> {
        let mask = mod_mask(bits);
        let es = |from: ClientId, to: ClientId| EncryptedShare {
            from,
            to,
            ciphertext: (0..84u8).collect(),
        };
        vec![
            Up::Adv(AdvertiseKeys { id: 3, c_pk: [7; 32], s_pk: [9; 32] }),
            Up::Shares(ShareUpload { from: 2, shares: vec![es(2, 0), es(2, 5)] }),
            Up::Shares(ShareUpload { from: 4, shares: vec![] }),
            Up::Masked(MaskedInput {
                id: 6,
                update: EncodedUpdate {
                    values: (0..plan.len() as u64)
                        .map(|i| i.wrapping_mul(0x9E37_79B9) & mask)
                        .collect(),
                    plan: plan.clone(),
                },
                bits,
            }),
            Up::Unmask(UnmaskShares {
                from: 1,
                shares: vec![
                    (0, ShareKind::SelfMask, sample_share(2)),
                    (5, ShareKind::SecretKey, sample_share(3)),
                ],
            }),
            Up::Dropped(11, 2),
            Up::Failed(12, 1, "secure withdrawal: neighborhood too small".to_string()),
            Up::Failed(13, 0, String::new()),
            Up::Warm(WarmResume { id: 8, support: None, rekey: None }),
            Up::Warm(WarmResume { id: 9, support: Some(vec![0, 3, 17]), rekey: None }),
            Up::Warm(WarmResume {
                id: 10,
                support: Some(vec![]),
                rekey: Some(([5; 32], [6; 32])),
            }),
        ]
    }

    fn sample_downs() -> Vec<Down> {
        let es = |from: ClientId, to: ClientId| EncryptedShare {
            from,
            to,
            ciphertext: vec![0xAB; 84],
        };
        vec![
            Down::Start,
            Down::Bundle(KeyBundle { entries: vec![(0, [1; 32], [2; 32]), (7, [3; 32], [4; 32])] }),
            Down::Bundle(KeyBundle { entries: vec![] }),
            Down::Delivery(ShareDelivery { to: 3, shares: vec![es(0, 3), es(1, 3)] }),
            Down::Announce(Arc::new(SurvivorAnnounce { v3: vec![0, 2, 5, 9] })),
            Down::Announce(Arc::new(SurvivorAnnounce { v3: vec![] })),
            Down::WarmPlan(WarmPlan {
                to: 4,
                alive_bitmap: vec![0b1011_0110, 0b0000_0001],
                keys: vec![(2, [8; 32], [9; 32])],
            }),
            Down::WarmPlan(WarmPlan { to: 0, alive_bitmap: vec![], keys: vec![] }),
            Down::Finish,
        ]
    }

    #[test]
    fn every_up_variant_round_trips() {
        for (plan, bits) in [
            (IndexPlan::identity(9), 32u32),
            (IndexPlan::sparse(vec![1, 4, 7, 30], 40), 16),
            (IndexPlan::sparse(vec![0, 2], 5), 64),
        ] {
            for up in sample_ups(&plan, bits) {
                let bytes = encode_up(0xDEAD_BEEF, &up);
                let (round, back) = decode_up(&bytes[LEN_BYTES..], &plan).unwrap();
                assert_eq!(round, 0xDEAD_BEEF);
                assert_eq!(dbg(&back), dbg(&up));
                // the decoded update shares the round plan, not a copy
                if let Up::Masked(m) = &back {
                    assert!(Arc::ptr_eq(&m.update.plan, &plan));
                }
            }
        }
    }

    #[test]
    fn every_down_variant_round_trips() {
        for down in sample_downs() {
            let bytes = encode_down(7, &down);
            let (round, back) = decode_down(&bytes[LEN_BYTES..]).unwrap();
            assert_eq!(round, 7);
            assert_eq!(dbg(&back), dbg(&down));
        }
    }

    #[test]
    fn golden_frames_pin_the_v1_layout() {
        // Start, round 0x01020304: len=6 | v1 | type 0 | round le
        assert_eq!(
            encode_down(0x0102_0304, &Down::Start),
            vec![6, 0, 0, 0, 1, 0x00, 0x04, 0x03, 0x02, 0x01]
        );
        // Finish, round 2
        assert_eq!(encode_down(2, &Down::Finish), vec![6, 0, 0, 0, 1, 0x04, 2, 0, 0, 0]);
        // Dropped(7, step 3), round 2: payload = id le32 | step
        assert_eq!(
            encode_up(2, &Up::Dropped(7, 3)),
            vec![11, 0, 0, 0, 1, 0x14, 2, 0, 0, 0, 7, 0, 0, 0, 3]
        );
        // Announce {v3: [1, 258]}, round 0: count le32 | ids le32
        let ann = Down::Announce(Arc::new(SurvivorAnnounce { v3: vec![1, 258] }));
        assert_eq!(
            encode_down(0, &ann),
            vec![18, 0, 0, 0, 1, 0x03, 0, 0, 0, 0, 2, 0, 0, 0, 1, 0, 0, 0, 2, 1, 0, 0]
        );
        // Masked {id 1, bits 16, values [0x0102, 0xFFFF]} under identity(2),
        // round 9: id le32 | bits u8 | count le32 | packed le values
        let plan = IndexPlan::identity(2);
        let m = Up::Masked(MaskedInput {
            id: 1,
            update: EncodedUpdate { values: vec![0x0102, 0xFFFF], plan },
            bits: 16,
        });
        assert_eq!(
            encode_up(9, &m),
            vec![19, 0, 0, 0, 1, 0x12, 9, 0, 0, 0, 1, 0, 0, 0, 16, 2, 0, 0, 0, 2, 1, 255, 255]
        );
    }

    #[test]
    fn framed_bytes_exceed_logical_bytes() {
        // the frame always costs more than the Appendix-C logical model:
        // length prefix + header + explicit counts
        let up = Up::Adv(AdvertiseKeys { id: 0, c_pk: [0; 32], s_pk: [0; 32] });
        let logical = match &up {
            Up::Adv(a) => a.size_bytes(),
            _ => unreachable!(),
        };
        assert!(encode_up(0, &up).len() > logical);
    }

    #[test]
    fn truncation_at_every_length_errors_never_panics() {
        let plan = IndexPlan::sparse(vec![2, 3, 11], 16);
        let mut frames: Vec<Vec<u8>> =
            sample_ups(&plan, 32).iter().map(|u| encode_up(5, u)).collect();
        frames.extend(sample_downs().iter().map(|d| encode_down(5, d)));
        for f in &frames {
            let body = &f[LEN_BYTES..];
            for cut in 0..body.len() {
                // direct decode of a truncated body must be an Err
                assert!(decode_up(&body[..cut], &plan).is_err(), "up cut={cut}");
                assert!(decode_down(&body[..cut]).is_err(), "down cut={cut}");
            }
            // a truncated *frame* is just incomplete for the reassembler
            let mut fb = FrameBuffer::new();
            fb.extend(&f[..f.len() - 1]);
            assert_eq!(fb.next_frame().unwrap(), None);
            fb.extend(&f[f.len() - 1..]);
            assert_eq!(fb.next_frame().unwrap().unwrap(), body.to_vec());
        }
    }

    #[test]
    fn bad_version_and_msg_type_are_rejected() {
        let plan = IndexPlan::identity(3);
        let good = encode_down(1, &Down::Start);
        let mut bad_ver = good[LEN_BYTES..].to_vec();
        bad_ver[0] = 2;
        assert_eq!(decode_down(&bad_ver), Err(WireError::BadVersion(2)));
        assert_eq!(decode_up(&bad_ver, &plan), Err(WireError::BadVersion(2)));
        let mut bad_type = good[LEN_BYTES..].to_vec();
        bad_type[1] = 0x7F;
        assert_eq!(decode_down(&bad_type), Err(WireError::BadMsgType(0x7F)));
        assert_eq!(decode_up(&bad_type, &plan), Err(WireError::BadMsgType(0x7F)));
        // down types don't decode as ups and vice versa
        assert!(matches!(decode_up(&good[LEN_BYTES..], &plan), Err(WireError::BadMsgType(_))));
        let adv = encode_up(1, &Up::Adv(AdvertiseKeys { id: 0, c_pk: [0; 32], s_pk: [0; 32] }));
        assert!(matches!(decode_down(&adv[LEN_BYTES..]), Err(WireError::BadMsgType(_))));
    }

    #[test]
    fn oversized_and_undersized_length_prefixes_are_rejected() {
        let mut fb = FrameBuffer::new();
        fb.extend(&((MAX_FRAME as u32) + 1).to_le_bytes());
        fb.extend(&[0u8; 16]);
        assert!(matches!(fb.next_frame(), Err(WireError::Oversized(_))));
        let mut fb = FrameBuffer::new();
        fb.extend(&3u32.to_le_bytes()); // shorter than the header
        fb.extend(&[0u8; 3]);
        assert!(matches!(fb.next_frame(), Err(WireError::ShortFrame(3))));
        // blocking reader rejects the same prefixes
        let mut bad: &[u8] = &[0xFF, 0xFF, 0xFF, 0xFF, 0, 0];
        assert!(read_frame(&mut bad).is_err());
    }

    #[test]
    fn trailing_garbage_is_rejected() {
        let plan = IndexPlan::identity(2);
        for up in sample_ups(&plan, 32) {
            let mut body = encode_up(1, &up)[LEN_BYTES..].to_vec();
            body.push(0);
            assert!(
                matches!(decode_up(&body, &plan), Err(WireError::TrailingBytes(1))),
                "{up:?}"
            );
        }
        for down in sample_downs() {
            let mut body = encode_down(1, &down)[LEN_BYTES..].to_vec();
            body.extend_from_slice(&[0, 0]);
            assert!(
                matches!(decode_down(&body), Err(WireError::TrailingBytes(2))),
                "{down:?}"
            );
        }
    }

    #[test]
    fn masked_input_is_validated_against_the_round_plan() {
        let plan = IndexPlan::sparse(vec![1, 5], 9);
        let m = Up::Masked(MaskedInput {
            id: 0,
            update: EncodedUpdate { values: vec![1, 2], plan: plan.clone() },
            bits: 16,
        });
        let body = encode_up(0, &m)[LEN_BYTES..].to_vec();
        // wrong plan length → count mismatch
        let other = IndexPlan::sparse(vec![1, 5, 6], 9);
        assert_eq!(
            decode_up(&body, &other),
            Err(WireError::BadValue("masked value count vs round plan"))
        );
        // narrowing the declared width to 8 bits leaves the 2-byte values
        // as trailing garbage — still an Err, never a mis-parse
        let mut wide = body.clone();
        wide[HEADER_BYTES + 4] = 8; // payload layout: id(4) bits(1) count(4) values
        assert!(decode_up(&wide, &plan).is_err());
        // a hand-built frame carrying a value outside Z_{2^bits}: bits=12
        // packs to 2 bytes, so 0xFFFF overflows the 12-bit domain
        let mut p = Vec::new();
        p.extend_from_slice(&1u32.to_le_bytes()); // id
        p.push(12); // bits
        p.extend_from_slice(&2u32.to_le_bytes()); // count = plan.len()
        p.extend_from_slice(&[0xFF, 0xFF, 0x01, 0x00]);
        let mut body12 = vec![WIRE_VERSION, 0x12, 0, 0, 0, 0];
        body12.extend_from_slice(&p);
        assert_eq!(
            decode_up(&body12, &plan),
            Err(WireError::BadValue("masked value outside Z_{2^bits}"))
        );
        // zero / too-wide bit widths
        let mut zero = body.clone();
        zero[HEADER_BYTES + 4] = 0;
        assert_eq!(decode_up(&zero, &plan), Err(WireError::BadValue("masked bit width")));
        let mut huge = body;
        huge[HEADER_BYTES + 4] = 65;
        assert_eq!(decode_up(&huge, &plan), Err(WireError::BadValue("masked bit width")));
    }

    #[test]
    fn warm_support_must_be_strictly_ascending() {
        let plan = IndexPlan::identity(4);
        let up = Up::Warm(WarmResume { id: 1, support: Some(vec![2, 2]), rekey: None });
        // hand-encode the out-of-order support (encode_up would emit it too;
        // the decoder is the gate)
        let body = encode_up(0, &up)[LEN_BYTES..].to_vec();
        assert_eq!(decode_up(&body, &plan), Err(WireError::BadValue("warm support order")));
        // unknown flag bits are rejected
        let good = encode_up(0, &Up::Warm(WarmResume { id: 1, support: None, rekey: None }));
        let mut bad = good[LEN_BYTES..].to_vec();
        bad[HEADER_BYTES + 4] = 0b100;
        assert_eq!(decode_up(&bad, &plan), Err(WireError::BadValue("warm flags")));
    }

    #[test]
    fn random_byte_flips_never_panic() {
        let plan = IndexPlan::sparse(vec![0, 3, 4], 8);
        let mut rng = Rng::new(0xF122);
        let mut frames: Vec<Vec<u8>> =
            sample_ups(&plan, 16).iter().map(|u| encode_up(3, u)).collect();
        frames.extend(sample_downs().iter().map(|d| encode_down(3, d)));
        for f in &frames {
            for _ in 0..64 {
                let mut body = f[LEN_BYTES..].to_vec();
                let pos = rng.gen_range(body.len() as u64) as usize;
                body[pos] ^= (rng.gen_range(255) + 1) as u8;
                // any outcome is fine except a panic; Ok is possible when
                // the flip lands in a value byte
                let _ = decode_up(&body, &plan);
                let _ = decode_down(&body);
            }
        }
    }

    #[test]
    fn frame_buffer_reassembles_split_and_concatenated_frames() {
        let a = encode_down(1, &Down::Start);
        let b = encode_down(1, &Down::Announce(Arc::new(SurvivorAnnounce { v3: vec![4] })));
        let mut stream = a.clone();
        stream.extend_from_slice(&b);
        // feed one byte at a time: frames pop exactly at their boundaries
        let mut fb = FrameBuffer::new();
        let mut popped = Vec::new();
        for byte in stream {
            fb.extend(&[byte]);
            while let Some(body) = fb.next_frame().unwrap() {
                popped.push(body);
            }
        }
        assert_eq!(popped.len(), 2);
        assert_eq!(popped[0], a[LEN_BYTES..].to_vec());
        assert_eq!(popped[1], b[LEN_BYTES..].to_vec());
        assert_eq!(fb.next_frame().unwrap(), None);
    }

    #[test]
    fn failed_message_is_capped_on_a_char_boundary() {
        let long = "é".repeat(40_000); // 80k bytes of 2-byte chars
        let up = Up::Failed(1, 2, long);
        let bytes = encode_up(0, &up);
        let (_, back) = decode_up(&bytes[LEN_BYTES..], &IndexPlan::identity(1)).unwrap();
        match back {
            Up::Failed(1, 2, msg) => {
                assert!(msg.len() <= u16::MAX as usize);
                assert!(msg.chars().all(|c| c == 'é'));
            }
            other => panic!("{other:?}"),
        }
    }
}
