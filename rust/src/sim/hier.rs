//! Hierarchical-round scenarios: the sharded analogues of [`super::scenario`]
//! and [`super::campaign`].
//!
//! A [`HierScenario`] is one declarative hierarchical round: population,
//! shard count, per-level graph families, payload codec, baseline churn, a
//! *per-shard churn storm* (one shard's clients drop at a much higher
//! rate), scheduled aggregator failures, and a cross-level adversary
//! (colluding clients plus compromised shard aggregators). Like the flat
//! scenarios, all stochastic churn is pre-drawn from the scenario seed into
//! an rng-free `Targeted` schedule, so a scenario replays bit-identically
//! through every executor — the property `DiffSpec::Hier`
//! (`super::differential`) checks, with the flat engine as the sum oracle.
//!
//! **Privacy metric.** The flat campaign scores `exposed_honest` from the
//! eavesdropper transcript; the hierarchical analogue is structural: a
//! compromised shard aggregator knows its shard's plaintext sum, so an
//! honest client is *exposed* when it is the only non-colluding member of a
//! compromised shard's V3 (the colluders subtract their own inputs and
//! recover the client's update exactly). The Theorem-1 reliability
//! predicate is checked per level graph and recorded per shard and for the
//! root round.

use super::scenario::CodecSpec;
use crate::coordinator::Executor;
use crate::hier::{HierOptions, HierRoundResult, HierRunner, ShardPlan};
use crate::protocol::dropout::DropoutModel;
use crate::protocol::{ClientId, ProtocolConfig, Topology};
use crate::util::mod_mask;
use crate::util::rng::Rng;
use anyhow::{ensure, Result};

/// One declarative hierarchical round.
#[derive(Debug, Clone)]
pub struct HierScenario {
    pub name: String,
    /// Total clients across all shards.
    pub n: usize,
    pub dim: usize,
    pub mask_bits: u32,
    /// Shard count (1 = the flat degenerate case).
    pub shards: usize,
    /// Intra-shard secret-sharing threshold.
    pub t: usize,
    /// Intra-shard graph family (flat families only).
    pub intra: Topology,
    /// Root-level graph family over the aggregators.
    pub root: Topology,
    pub codec: CodecSpec,
    /// Baseline i.i.d. per-step drop probability for every client.
    pub churn_q: f64,
    /// Per-shard churn storm: `(shard, q)` — that shard's clients drop at
    /// `q` per step instead of `churn_q`.
    pub storm: Option<(usize, f64)>,
    /// Scheduled aggregator failures per root step (shard indices).
    pub agg_dropout: [Vec<usize>; 4],
    /// Colluding clients (global ids) — combine with `compromised_aggs`
    /// for cross-level collusion.
    pub colluders: Vec<ClientId>,
    /// Compromised shard aggregators: they learn their shard's sum.
    pub compromised_aggs: Vec<usize>,
    pub seed: u64,
}

impl HierScenario {
    pub fn shard_plan(&self) -> Result<ShardPlan> {
        ShardPlan::new(self.n, self.shards)
    }

    /// Pre-draw the per-step drop schedule (baseline + storm) from the
    /// scenario seed — the same step-major, client-minor draw order as
    /// `DropoutModel::materialize`, so it is rng-free data afterwards.
    pub fn dropout_schedule(&self) -> Result<[Vec<ClientId>; 4]> {
        let plan = self.shard_plan()?;
        if let Some((shard, q)) = self.storm {
            ensure!(shard < plan.shards(), "storm shard {shard} out of range");
            ensure!((0.0..=1.0).contains(&q), "storm q={q} out of range");
        }
        ensure!(
            (0.0..=1.0).contains(&self.churn_q),
            "churn_q={} out of range",
            self.churn_q
        );
        let mut rng = Rng::new(self.seed ^ 0xC4021);
        let mut per_step: [Vec<ClientId>; 4] = std::array::from_fn(|_| Vec::new());
        for drops in per_step.iter_mut() {
            for c in 0..self.n {
                let q = match self.storm {
                    Some((shard, q)) if plan.shard_of(c) == shard => q,
                    _ => self.churn_q,
                };
                if rng.bernoulli(q) {
                    drops.push(c);
                }
            }
        }
        Ok(per_step)
    }

    /// Compile to a validated hierarchical [`ProtocolConfig`] with the
    /// pre-drawn `Targeted` schedule.
    pub fn config(&self) -> Result<ProtocolConfig> {
        ProtocolConfig::builder()
            .clients(self.n)
            .threshold(self.t)
            .model_dim(self.dim)
            .mask_bits(self.mask_bits)
            .topology(Topology::Hierarchical {
                shards: self.shards,
                intra: Box::new(self.intra.clone()),
                root: Box::new(self.root.clone()),
            })
            .codec(self.codec.resolve(self.dim))
            .dropout(DropoutModel::Targeted { per_step: self.dropout_schedule()? })
            .seed(self.seed)
            .build()
    }

    /// Deterministic client inputs: full-entropy words in Z_{2^mask_bits}
    /// (the flat scenarios' derivation).
    pub fn models(&self) -> Vec<Vec<u64>> {
        let modmask = mod_mask(self.mask_bits);
        let mut rng = Rng::new(self.seed ^ 0x0DE1);
        (0..self.n)
            .map(|_| (0..self.dim).map(|_| rng.next_u64() & modmask).collect())
            .collect()
    }

    /// Runner options for this scenario under `executor` (Theorem-1 and
    /// truth checks on — this is the validation path, not the bench path).
    pub fn options(&self, executor: Executor) -> HierOptions {
        HierOptions {
            executor,
            agg_dropout: self.agg_dropout.clone(),
            check_theorem1: true,
            check_truth: true,
            ..HierOptions::default()
        }
    }

    /// Run the scenario once and score it.
    pub fn run(&self, executor: Executor) -> Result<HierRoundRecord> {
        let cfg = self.config()?;
        let models = self.models();
        let result = HierRunner::new(self.options(executor)).run(&cfg, &models)?;
        Ok(score(self, result))
    }
}

/// One scored hierarchical round.
#[derive(Debug)]
pub struct HierRoundRecord {
    /// The root level produced a sum.
    pub completed: bool,
    pub reliable: bool,
    /// `sum == true_sum` (`None` when the round aborted).
    pub sum_matches_truth: Option<bool>,
    /// Shards whose aggregator did not make the root V3 (dropped, aborted
    /// or withheld-as-unreliable). 0 for the single-shard degenerate.
    pub shards_dropped: usize,
    /// Honest clients exposed to the compromised-aggregator adversary
    /// (global ids): sole non-colluding members of a compromised shard's V3.
    pub exposed_honest: Vec<ClientId>,
    /// Every shard's Theorem-1 predicate agreed with its reliability flag.
    pub shard_theorem1_agrees: bool,
    /// Root-level Theorem-1 agreement (`None` for single-shard rounds).
    pub root_theorem1_agrees: Option<bool>,
    pub result: HierRoundResult,
}

fn score(sc: &HierScenario, result: HierRoundResult) -> HierRoundRecord {
    let completed = result.sum.is_some();
    let sum_matches_truth = match (&result.sum, &result.true_sum) {
        (Some(s), Some(t)) => Some(s == t),
        _ => None,
    };
    let shards_dropped = match &result.root {
        Some(root) => result.shard_plan.shards() - root.sets.v3.len(),
        None => 0,
    };
    let mut exposed = Vec::new();
    for &a in &sc.compromised_aggs {
        if a >= result.shard_reports.len() || !result.shard_reports[a].completed {
            continue;
        }
        let lo = result.shard_plan.range(a).0;
        let honest: Vec<ClientId> = result.shard_reports[a]
            .sets
            .v3
            .iter()
            .map(|&c| c + lo)
            .filter(|g| !sc.colluders.contains(g))
            .collect();
        if honest.len() == 1 {
            exposed.push(honest[0]);
        }
    }
    exposed.sort_unstable();
    exposed.dedup();
    let shard_theorem1_agrees = result
        .shard_reports
        .iter()
        .all(|r| r.theorem1_holds.map(|h| h == r.reliable).unwrap_or(true));
    let root_theorem1_agrees = result
        .root
        .as_ref()
        .and_then(|r| r.theorem1_holds.map(|h| h == r.reliable));
    HierRoundRecord {
        completed,
        reliable: result.reliable,
        sum_matches_truth,
        shards_dropped,
        exposed_honest: exposed,
        shard_theorem1_agrees,
        root_theorem1_agrees,
        result,
    }
}

/// Aggregate outcomes of a hierarchical campaign.
#[derive(Debug, Clone, Default)]
pub struct HierCampaignReport {
    pub rounds: usize,
    pub completed: usize,
    pub reliable: usize,
    /// Rounds where the secure sum disagreed with the plaintext truth —
    /// must stay 0; any nonzero count is a soundness bug.
    pub truth_mismatches: usize,
    pub shards_dropped_total: usize,
    pub exposed_honest_total: usize,
    /// Per-level Theorem-1 vs reliability disagreements (flat campaigns
    /// track the same signal as `theorem1_agrees`).
    pub theorem1_disagreements: usize,
}

/// Run a batch of hierarchical scenarios and aggregate the scores.
pub fn run_hier_campaign(
    scenarios: &[HierScenario],
    executor: Executor,
) -> Result<HierCampaignReport> {
    let mut report = HierCampaignReport::default();
    for sc in scenarios {
        let r = sc.run(executor)?;
        report.rounds += 1;
        report.completed += usize::from(r.completed);
        report.reliable += usize::from(r.reliable);
        report.truth_mismatches += usize::from(r.sum_matches_truth == Some(false));
        report.shards_dropped_total += r.shards_dropped;
        report.exposed_honest_total += r.exposed_honest.len();
        if !r.shard_theorem1_agrees || r.root_theorem1_agrees == Some(false) {
            report.theorem1_disagreements += 1;
        }
    }
    Ok(report)
}

/// The per-shard-churn campaign: `rounds` hierarchical rounds over a fixed
/// population where the storm rotates across shards (round r storms shard
/// `r % shards` at `q = 0.4` against a 5% baseline), with one compromised
/// aggregator and a two-client colluding set — the CI workload exercising
/// shard dropout degradation and the cross-level privacy metric together.
pub fn storm_scenarios(base_seed: u64, rounds: usize, n: usize, shards: usize) -> Vec<HierScenario> {
    (0..rounds)
        .map(|r| HierScenario {
            name: format!("hier-storm-r{r}"),
            n,
            dim: 16,
            mask_bits: 32,
            shards,
            t: 3,
            intra: Topology::ErdosRenyi { p: 0.9 },
            root: Topology::Complete,
            codec: CodecSpec::Dense,
            churn_q: 0.05,
            storm: Some((r % shards.max(1), 0.4)),
            agg_dropout: std::array::from_fn(|_| Vec::new()),
            colluders: vec![0, 1],
            compromised_aggs: vec![0],
            seed: base_seed ^ (r as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        })
        .collect()
}

/// Seeded random hierarchical scenario for the differential harness: small
/// populations, every codec, shard counts 1–4 (1 exercises the flat
/// degeneracy), churn with occasional per-shard storms, occasional
/// aggregator failures and cross-level collusion. Shard sizes always
/// respect the builder's `≥ t+1` floor by construction.
pub fn random_hier_scenario(seed: u64) -> HierScenario {
    let mut rng = Rng::new(seed ^ 0x41E2_5EED);
    let t = 2 + rng.gen_range(2) as usize; // 2..=3
    let shards = 1 + rng.gen_range(4) as usize; // 1..=4
    // n ≥ shards·(t+1) keeps every shard at or above the builder floor
    let per_shard = t + 1 + rng.gen_range(4) as usize;
    let n = shards * per_shard + rng.gen_range(3) as usize;
    let min_shard = n / shards;
    let dim = 1 + rng.gen_range(16) as usize;
    let mask_bits = [16u32, 32, 32, 64][rng.gen_range(4) as usize];
    let intra = match rng.gen_range(3) {
        0 => Topology::Complete,
        1 => Topology::ErdosRenyi { p: 0.7 + 0.3 * rng.next_f64() },
        _ => Topology::Harary { k: t + rng.gen_range((min_shard - t) as u64) as usize },
    };
    let root = if rng.gen_range(2) == 0 {
        Topology::Complete
    } else {
        Topology::ErdosRenyi { p: 0.8 + 0.2 * rng.next_f64() }
    };
    let codec = match rng.gen_range(4) {
        0 | 1 => CodecSpec::Dense,
        2 => CodecSpec::TopK { frac: 0.25 + 0.5 * rng.next_f64() },
        _ => CodecSpec::RandK { frac: 0.25 + 0.5 * rng.next_f64() },
    };
    let churn_q = [0.0, 0.0, 0.05, 0.1, 0.2][rng.gen_range(5) as usize];
    let storm = (shards >= 2 && rng.gen_range(3) == 0)
        .then(|| (rng.gen_range(shards as u64) as usize, 0.3 + 0.3 * rng.next_f64()));
    let mut agg_dropout: [Vec<usize>; 4] = std::array::from_fn(|_| Vec::new());
    if shards >= 3 && rng.gen_range(4) == 0 {
        agg_dropout[rng.gen_range(4) as usize].push(rng.gen_range(shards as u64) as usize);
    }
    let colluders = if rng.gen_range(3) == 0 {
        let mut c = vec![rng.gen_range(n as u64) as usize, rng.gen_range(n as u64) as usize];
        c.sort_unstable();
        c.dedup();
        c
    } else {
        Vec::new()
    };
    let compromised_aggs = if shards >= 2 && rng.gen_range(3) == 0 {
        vec![rng.gen_range(shards as u64) as usize]
    } else {
        Vec::new()
    };
    HierScenario {
        name: format!("hier-rand-{seed:#x}"),
        n,
        dim,
        mask_bits,
        shards,
        t,
        intra,
        root,
        codec,
        churn_q,
        storm,
        agg_dropout,
        colluders,
        compromised_aggs,
        seed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_hier_scenarios_are_deterministic_and_valid() {
        for seed in 0..60u64 {
            let a = random_hier_scenario(seed);
            let b = random_hier_scenario(seed);
            assert_eq!(format!("{a:?}"), format!("{b:?}"), "seed={seed}");
            // every scenario must compile to a valid hierarchical config
            let cfg = a.config().unwrap_or_else(|e| panic!("seed={seed}: {e}"));
            assert_eq!(cfg.n, a.n);
            assert!(cfg.topology.is_hierarchical());
            assert_eq!(a.models().len(), a.n);
        }
        // the axes are actually sampled
        let any =
            |f: &dyn Fn(&HierScenario) -> bool| (0..60u64).any(|s| f(&random_hier_scenario(s)));
        assert!(any(&|sc| sc.shards == 1));
        assert!(any(&|sc| sc.shards >= 3));
        assert!(any(&|sc| sc.storm.is_some()));
        assert!(any(&|sc| sc.agg_dropout.iter().any(|v| !v.is_empty())));
        assert!(any(&|sc| !sc.compromised_aggs.is_empty()));
        assert!(any(&|sc| !matches!(sc.codec, CodecSpec::Dense)));
    }

    #[test]
    fn dropout_schedule_is_rng_free_replayable() {
        let sc = random_hier_scenario(5);
        assert_eq!(sc.dropout_schedule().unwrap(), sc.dropout_schedule().unwrap());
    }

    #[test]
    fn storm_concentrates_drops_in_the_storm_shard() {
        let sc = HierScenario {
            storm: Some((1, 0.9)),
            churn_q: 0.0,
            ..storm_scenarios(7, 1, 40, 4).remove(0)
        };
        let plan = sc.shard_plan().unwrap();
        let sched = sc.dropout_schedule().unwrap();
        assert!(sched.iter().flatten().all(|&c| plan.shard_of(c) == 1));
        assert!(sched.iter().map(|s| s.len()).sum::<usize>() > 0);
    }

    #[test]
    fn healthy_campaign_is_fully_reliable_and_private() {
        let scs = vec![HierScenario {
            churn_q: 0.0,
            storm: None,
            colluders: vec![],
            compromised_aggs: vec![],
            intra: Topology::Complete,
            ..storm_scenarios(11, 1, 24, 3).remove(0)
        }];
        let rep = run_hier_campaign(&scs, Executor::Engine).unwrap();
        assert_eq!(rep.rounds, 1);
        assert_eq!(rep.completed, 1);
        assert_eq!(rep.reliable, 1);
        assert_eq!(rep.truth_mismatches, 0);
        assert_eq!(rep.shards_dropped_total, 0);
        assert_eq!(rep.exposed_honest_total, 0);
        assert_eq!(rep.theorem1_disagreements, 0);
    }

    #[test]
    fn compromised_shard_with_one_honest_member_is_exposed() {
        // shard 0 of 3 holds clients 0..4; colluders are 3 of its 4
        // members, so the sole remaining honest client is exposed to a
        // compromised aggregator — and nobody is without the compromise
        let base = HierScenario {
            churn_q: 0.0,
            storm: None,
            colluders: vec![0, 1, 2],
            compromised_aggs: vec![0],
            intra: Topology::Complete,
            ..storm_scenarios(13, 1, 12, 3).remove(0)
        };
        let r = base.run(Executor::Engine).unwrap();
        assert_eq!(r.exposed_honest, vec![3]);
        let clean = HierScenario { compromised_aggs: vec![], ..base };
        assert!(clean.run(Executor::Engine).unwrap().exposed_honest.is_empty());
    }

    #[test]
    fn storm_campaign_degrades_by_dropping_shards_not_corrupting_sums() {
        let scs = storm_scenarios(0xCAFE, 4, 40, 4);
        let rep = run_hier_campaign(&scs, Executor::Engine).unwrap();
        assert_eq!(rep.rounds, 4);
        // the invariant that matters: no completed round ever disagrees
        // with the plaintext truth, storm or not
        assert_eq!(rep.truth_mismatches, 0);
        assert_eq!(rep.theorem1_disagreements, 0);
    }
}
