//! Scenario simulation subsystem: declarative multi-round campaigns,
//! pluggable churn models, and the engine↔coordinator differential harness.
//!
//! The paper's claims (Theorems 1–6, the §5 experiments) are statements
//! about what happens across *many* rounds under dropout, churn and
//! collusion. This module makes those regimes first-class:
//!
//! * [`scenario`] — a [`Scenario`] spec (population, topology schedule,
//!   churn, adversary, payload codec, quantizer config, rounds) compiled
//!   into rng-free [`scenario::RoundPlan`]s for exact replay;
//! * [`churn`] — multi-round churn processes (i.i.d., bursty Markov,
//!   correlated-regional outages, targeted-adaptive hub attacks, scripted)
//!   compiled to explicit per-step schedules;
//! * [`campaign`] — runs a scenario through any [`campaign::Executor`]
//!   (sync engine, worker-pool event loop), scoring reliability, Theorem-1
//!   agreement and eavesdropper/collusion privacy;
//! * [`differential`] — asserts every executor produces bit-identical sums,
//!   survivor sets and [`crate::net::NetStats`] on randomized scenarios
//!   (the payload codec is one of the randomized axes), with a shrinker
//!   that minimizes failures to a reportable seed; every scenario kind
//!   (flat, clocked, session, hier, crash) enters through one
//!   [`differential::DiffSpec`] dispatcher;
//! * [`clock`] — virtual-clock event scheduler: pre-materialized per-link
//!   latency / compute-delay schedules, deadline-driven phase closure
//!   ([`clock::close_phase`]) that drops stragglers exactly like churn,
//!   and the timeout-sweep campaign axis
//!   ([`clock::run_timeout_sweep`]: reliability/privacy/latency vs
//!   phase deadline);
//! * [`hier`] — hierarchical (sharded) round scenarios: per-shard churn
//!   storms, dropped/compromised shard aggregators, cross-level collusion,
//!   scored by [`hier::run_hier_campaign`] and differential-tested via
//!   [`differential::DiffSpec::Hier`] with the flat engine as oracle;
//! * [`crash`] — kills a journaled server at every phase boundary
//!   ([`crash::CrashPoint`]) and requires the journal-recovered server to
//!   finish the round bit-identically to the uninterrupted engine;
//! * [`session`] — cross-round *warm* campaigns over one established
//!   [`crate::protocol::session::Session`] (steady-state and churn-storm
//!   attendance axes), measuring setup amortization and re-key traffic,
//!   with [`differential::DiffSpec::Session`] extending the
//!   bit-identical guarantee to warm rounds.
//!
//! Every future scale or performance PR validates against this substrate:
//! change an executor, run the differential; add a churn regime, add a
//! variant here and every harness picks it up.

pub mod campaign;
pub mod churn;
pub mod clock;
pub mod crash;
pub mod differential;
pub mod hier;
pub mod scenario;
pub mod session;

pub use campaign::{
    resume_campaign, run_campaign, run_plan, CampaignReport, Executor, RoundRecord,
};
pub use clock::{
    random_clocked_scenario, run_clocked_plan, run_timeout_sweep, straggler_scenario,
    ClockSchedule, ClockSpec, ClockedRoundOutcome, ClockedScenario, LatencyModel, PhaseClosure,
    SweepPoint, TimeoutSweepReport,
};
pub use crash::{diff_crash_round, run_round_crashy, CrashPoint};
pub use churn::ChurnModel;
pub use differential::{
    run_clocked_differential, run_differential, run_differential_batch, run_hier_differential,
    shrink, DiffSpec, DifferentialReport, Failure, HierDifferentialReport, Mismatch,
};
pub use hier::{
    random_hier_scenario, run_hier_campaign, storm_scenarios, HierCampaignReport,
    HierRoundRecord, HierScenario,
};
pub use scenario::{
    random_scenario, AdversarySpec, CodecSpec, RoundPlan, Scenario, ThresholdRule,
    TopologySchedule,
};
pub use session::{
    run_session_campaign, Attendance, SessionReport, SessionRoundRecord, SessionScenario,
};
