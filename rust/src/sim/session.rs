//! Session campaigns: multi-round *warm* aggregation over one established
//! [`Session`], as a first-class scenario axis.
//!
//! The cold-round campaigns in [`super::campaign`] re-run the full setup
//! every round — that is the baseline the session layer amortizes. A
//! [`SessionScenario`] instead establishes one session and then drives N
//! warm rounds through any [`Executor`], recording per-round traffic so
//! the amortization claim ("steady-state setup bytes are a small fraction
//! of cold start") is a measured, CI-assertable quantity rather than
//! prose. Two presets pin the regimes the ISSUE names:
//!
//! * [`SessionScenario::steady_state`] — full attendance every round; the
//!   best case for amortization (no re-keys, no repairs after round 1).
//! * [`SessionScenario::churn_storm`] — a rotating block of members skips
//!   each round mid-campaign, forcing graph repairs, pending re-keys and
//!   missed-rekey catch-up downloads when absentees return.
//!
//! [`super::differential::DiffSpec::Session`] runs these scenarios
//! through every executor and requires bit-identical sums, survivor sets
//! and logical [`NetStats`] — the warm extension of the cold differential
//! harness.

use super::campaign::Executor;
use super::scenario::CodecSpec;
use crate::coordinator::{CoordRoundResult, RoundOptions};
use crate::net::NetStats;
use crate::protocol::session::Session;
use crate::protocol::{ClientId, ProtocolConfig, SurvivorSets, Topology};
use anyhow::{Context, Result};

/// Who shows up for each warm round.
#[derive(Debug, Clone, PartialEq)]
pub enum Attendance {
    /// Every session member attends every round (steady state).
    Full,
    /// From `start` (1-based warm round index) onward, a rotating block of
    /// `absent` members skips each round entirely — the block shifts by
    /// `absent` ids per round so every member eventually misses rounds and
    /// later returns (exercising missed-rekey catch-up).
    Storm { start: u64, absent: usize },
}

/// A declarative cross-round session campaign. Everything derives from
/// `seed`; two scenarios with equal fields run bit-identically.
#[derive(Debug, Clone)]
pub struct SessionScenario {
    pub name: String,
    pub n: usize,
    pub dim: usize,
    pub mask_bits: u32,
    /// Secret-sharing threshold (fixed across the session — the session
    /// keeps one graph, so per-round threshold rules do not apply).
    pub t: usize,
    pub topology: Topology,
    pub codec: CodecSpec,
    /// Number of warm rounds after the cold establishing round.
    pub warm_rounds: u64,
    pub attendance: Attendance,
    pub seed: u64,
}

impl SessionScenario {
    /// Full-attendance campaign: the amortization best case. Harary
    /// topology keeps degrees deterministic, so the establishing cold
    /// round is reliable by construction (degree ≥ t − 1, no dropout).
    pub fn steady_state(codec: CodecSpec, warm_rounds: u64, seed: u64) -> SessionScenario {
        SessionScenario {
            name: format!("steady-state-{}", codec.name()),
            n: 14,
            dim: 32,
            mask_bits: 32,
            t: 6,
            topology: Topology::Harary { k: 6 },
            codec,
            warm_rounds,
            attendance: Attendance::Full,
            seed,
        }
    }

    /// Mid-campaign absence storm: from warm round 3 on, a rotating block
    /// of 3 members skips each round. Degree-6 Harary with t = 6 means a
    /// participant whose neighborhood absorbs the absences drops below
    /// t − 1 active neighbors, so the storm forces graph repairs, the
    /// repairs force re-keys, and returning absentees download the key
    /// deltas they missed.
    pub fn churn_storm(codec: CodecSpec, warm_rounds: u64, seed: u64) -> SessionScenario {
        SessionScenario {
            name: format!("churn-storm-{}", codec.name()),
            attendance: Attendance::Storm { start: 3, absent: 3 },
            ..SessionScenario::steady_state(codec, warm_rounds, seed)
        }
    }

    /// The protocol config the session establishes under (dropout-free:
    /// session churn is modeled as attendance, which — unlike stochastic
    /// mid-round dropout — replays identically through every executor by
    /// construction).
    pub fn config(&self) -> Result<ProtocolConfig> {
        ProtocolConfig::builder()
            .clients(self.n)
            .threshold(self.t)
            .model_dim(self.dim)
            .mask_bits(self.mask_bits)
            .topology(self.topology.clone())
            .codec(self.codec.resolve(self.dim))
            .seed(self.seed)
            .build()
            .context("session scenario compiles to a valid protocol config")
    }

    /// Deterministic per-round client inputs (round 0 = the cold round).
    pub fn round_models(&self, round: u64) -> Vec<Vec<u64>> {
        let modmask = crate::util::mod_mask(self.mask_bits);
        let mut rng = crate::util::rng::Rng::new(
            crate::protocol::session::round_seed(self.seed, round) ^ 0x5E55_10DE,
        );
        (0..self.n)
            .map(|_| (0..self.dim).map(|_| rng.next_u64() & modmask).collect())
            .collect()
    }

    /// The attendance flags for warm round `round` (1-based), restricted
    /// to `members` (non-members are always inactive).
    pub fn active_for(&self, round: u64, members: &[ClientId]) -> Vec<bool> {
        let mut active = vec![false; self.n];
        for &i in members {
            active[i] = true;
        }
        if let Attendance::Storm { start, absent } = self.attendance {
            if round >= start && !members.is_empty() {
                // rotate the absent block so every member cycles through
                // absence and return
                let shift = ((round - start) as usize).wrapping_mul(absent);
                for k in 0..absent.min(members.len().saturating_sub(self.t)) {
                    active[members[(shift + k) % members.len()]] = false;
                }
            }
        }
        active
    }
}

/// One warm round's outcome in a session campaign.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SessionRoundRecord {
    /// Warm round index (1-based; the cold round is not in this list).
    pub round: u64,
    /// The round aborted (the session itself survives — its ratchet burns
    /// the round number and the campaign continues).
    pub aborted: bool,
    pub reliable: bool,
    pub sum: Option<Vec<u64>>,
    pub sets: SurvivorSets,
    pub stats: NetStats,
}

/// Aggregated outcome of one session campaign.
#[derive(Debug, Clone)]
pub struct SessionReport {
    pub scenario: String,
    pub seed: u64,
    pub executor: Executor,
    /// The establishing cold round's traffic — the amortization baseline.
    pub cold_stats: NetStats,
    pub warm: Vec<SessionRoundRecord>,
}

impl SessionReport {
    pub fn warm_rounds(&self) -> usize {
        self.warm.len()
    }

    pub fn aborted_rounds(&self) -> usize {
        self.warm.iter().filter(|r| r.aborted).count()
    }

    /// Mean setup bytes per completed warm round (handshake traffic minus
    /// coordinate-map bytes, as in [`NetStats::setup_bytes`]).
    pub fn mean_warm_setup_bytes(&self) -> f64 {
        let done: Vec<&SessionRoundRecord> = self.warm.iter().filter(|r| !r.aborted).collect();
        if done.is_empty() {
            return f64::NAN;
        }
        done.iter().map(|r| r.stats.setup_bytes()).sum::<u64>() as f64 / done.len() as f64
    }

    /// Steady-state setup bytes as a fraction of the cold round's — the
    /// amortization headline the CI campaign gates on (< 0.30).
    pub fn setup_fraction_of_cold(&self) -> f64 {
        self.mean_warm_setup_bytes() / self.cold_stats.setup_bytes() as f64
    }

    /// Total session re-key traffic across the campaign (both directions).
    pub fn rekey_total(&self) -> u64 {
        self.warm.iter().map(|r| r.stats.rekey_up + r.stats.rekey_down).sum()
    }

    pub fn one_line(&self) -> String {
        format!(
            "{} [{}]: cold + {} warm rounds ({} aborted), setup {:.1}% of cold, {} rekey bytes",
            self.scenario,
            self.executor.name(),
            self.warm_rounds(),
            self.aborted_rounds(),
            self.setup_fraction_of_cold() * 100.0,
            self.rekey_total(),
        )
    }
}

/// Establish a session and drive the scenario's warm rounds through the
/// chosen executor. A warm round that aborts (e.g. a storm leaves fewer
/// than t members active) is recorded and the campaign continues — the
/// session outliving a failed round is exactly the property under test.
pub fn run_session_campaign(sc: &SessionScenario, executor: Executor) -> Result<SessionReport> {
    let cfg = sc.config()?;
    let opts = RoundOptions::builder()
        .executor(executor)
        .build()
        .expect("an executor alone is always a valid round configuration");
    let cold_models = sc.round_models(0);
    let (mut session, cold) =
        Session::establish(&cfg, &cold_models).context("establish session campaign")?;
    let members = session.members();
    let mut warm = Vec::with_capacity(sc.warm_rounds as usize);
    for round in 1..=sc.warm_rounds {
        let models = sc.round_models(round);
        let active = sc.active_for(round, &members);
        match session.run_round(&models, &active, &opts) {
            Ok(r) => warm.push(SessionRoundRecord {
                round,
                aborted: false,
                reliable: r.reliable,
                sum: r.sum,
                sets: r.sets,
                stats: r.stats,
            }),
            Err(_) => warm.push(SessionRoundRecord {
                round,
                aborted: true,
                reliable: false,
                sum: None,
                sets: SurvivorSets::default(),
                stats: NetStats::new(sc.n),
            }),
        }
    }
    Ok(SessionReport {
        scenario: sc.name.clone(),
        seed: sc.seed,
        executor,
        cold_stats: cold.stats,
        warm,
    })
}

/// Convenience for tests and tools: the result type a single warm round
/// produces, re-exported so callers need not import the coordinator.
pub type WarmRoundResult = CoordRoundResult;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn steady_state_amortizes_setup_for_every_codec() {
        for codec in [
            CodecSpec::Dense,
            CodecSpec::TopK { frac: 0.25 },
            CodecSpec::RandK { frac: 0.25 },
        ] {
            let sc = SessionScenario::steady_state(codec, 4, 0x5E55);
            let rep = run_session_campaign(&sc, Executor::EventLoop).unwrap();
            assert_eq!(rep.warm_rounds(), 4, "{}", sc.name);
            assert_eq!(rep.aborted_rounds(), 0, "{}", sc.name);
            assert!(
                rep.warm.iter().all(|r| r.reliable),
                "{}: all steady-state rounds reliable",
                sc.name
            );
            // the headline: warm handshakes cost a small fraction of cold
            // start (the 20-round CI campaign pins the < 0.30 bound; this
            // in-crate smoke test allows slack for tiny populations)
            assert!(
                rep.setup_fraction_of_cold() < 0.5,
                "{}: setup fraction {:.3}",
                sc.name,
                rep.setup_fraction_of_cold()
            );
            // full attendance, no repairs → no re-key traffic at all
            assert_eq!(rep.rekey_total(), 0, "{}", sc.name);
        }
    }

    #[test]
    fn churn_storm_forces_repairs_and_rekeys_but_session_survives() {
        let sc = SessionScenario::churn_storm(CodecSpec::Dense, 8, 0x5702);
        let rep = run_session_campaign(&sc, Executor::EventLoop).unwrap();
        assert_eq!(rep.warm_rounds(), 8);
        // the pre-storm rounds are clean
        assert!(rep.warm[0].reliable && rep.warm[1].reliable);
        // storm rounds complete: aborting would mean the session state
        // machine cannot cope with absences
        assert_eq!(rep.aborted_rounds(), 0);
        // at least one sum is produced during the storm
        assert!(rep.warm[3..].iter().any(|r| r.sum.is_some()));
    }

    #[test]
    fn attendance_never_drops_below_threshold() {
        let sc = SessionScenario::churn_storm(CodecSpec::Dense, 6, 1);
        let members: Vec<ClientId> = (0..sc.n).collect();
        for round in 1..=sc.warm_rounds {
            let active = sc.active_for(round, &members);
            assert!(active.iter().filter(|&&a| a).count() >= sc.t, "round {round}");
        }
    }

    #[test]
    fn storm_rotation_gives_every_member_time_off_and_a_return() {
        let sc = SessionScenario::churn_storm(CodecSpec::Dense, 12, 2);
        let members: Vec<ClientId> = (0..sc.n).collect();
        let mut missed = vec![false; sc.n];
        let mut returned = vec![false; sc.n];
        for round in 1..=sc.warm_rounds {
            let active = sc.active_for(round, &members);
            for i in 0..sc.n {
                if !active[i] {
                    missed[i] = true;
                } else if missed[i] {
                    returned[i] = true;
                }
            }
        }
        assert!(missed.iter().filter(|&&m| m).count() >= sc.n / 2);
        assert!(returned.iter().zip(&missed).all(|(r, m)| r == m), "every absentee returns");
    }
}
