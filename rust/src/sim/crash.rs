//! Crash-injection harness: kill the server at every phase boundary and
//! prove the journal finishes the round bit-identically anyway.
//!
//! The harness drives [`ClientSm`] lanes in-process, exactly like the
//! event loop, against a journaled [`Server`]. At the chosen
//! [`CrashPoint`] the server value is dropped — the process-death
//! equivalent for everything the protocol owns, since all server state is
//! in that value — and the round continues on a server rebuilt solely by
//! `journal::recover`. Crash points the sink writes inside a single server
//! call (`AfterStep2`, `AfterStep3`, `PreFinalize`) are emulated by
//! truncating trailing records off the on-disk log, which is byte-for-byte
//! what an earlier death would have left behind.
//!
//! Two invariants are asserted on every recovery, not just at the end:
//! the replayed server regenerates the pending `Down`s **byte-identically**
//! (compared as wire frames), and the finished round's sum, survivor sets
//! and reliability verdict match the uninterrupted engine — the
//! crash-vs-engine differential of DESIGN.md §13.
//!
//! The lanes (and the harness-side `NetStats`) survive the crash like real
//! remote clients survive a server death, which is what lets the harness
//! assert *full* logical stats parity with the engine.

use super::campaign::RoundRecord;
use crate::coordinator::{derive_round_setup, CoordRoundResult};
use crate::journal::{self, Journal, JournalSink};
use crate::net::{Dir, NetStats};
use crate::protocol::client::ClientSm;
use crate::protocol::messages::*;
use crate::protocol::server::{RoundOutput, Server};
use crate::protocol::{engine, ClientId, ProtocolConfig};
use crate::wire;
use anyhow::{bail, ensure, Context, Result};
use std::path::Path;
use std::sync::Arc;

/// Phase boundary at which the server dies. Variants whose journal record
/// lands mid-call are emulated by truncating the log (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CrashPoint {
    /// Journal holds only the setup record; phase 0 was never applied.
    AfterSetup,
    /// Step 0 applied and journaled; its bundles never delivered.
    AfterStep0,
    /// Step 1 applied and journaled; its deliveries never delivered.
    AfterStep1,
    /// Step 2's masked batch journaled but the crash beat the announce
    /// record (emulated: run to [`CrashPoint::AfterAnnounce`], truncate 1).
    AfterStep2,
    /// Step 2 and the announce record journaled; announce never delivered.
    AfterAnnounce,
    /// Step 3's unmask batch journaled; checkpoint and final records lost
    /// (emulated: full run, truncate 2).
    AfterStep3,
    /// Everything but the final record journaled (emulated: truncate 1).
    PreFinalize,
}

impl CrashPoint {
    /// Every crash point, in protocol order — the DESIGN.md §13 matrix.
    pub const ALL: [CrashPoint; 7] = [
        CrashPoint::AfterSetup,
        CrashPoint::AfterStep0,
        CrashPoint::AfterStep1,
        CrashPoint::AfterStep2,
        CrashPoint::AfterAnnounce,
        CrashPoint::AfterStep3,
        CrashPoint::PreFinalize,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            CrashPoint::AfterSetup => "after-setup",
            CrashPoint::AfterStep0 => "after-step0",
            CrashPoint::AfterStep1 => "after-step1",
            CrashPoint::AfterStep2 => "after-step2",
            CrashPoint::AfterAnnounce => "after-announce",
            CrashPoint::AfterStep3 => "after-step3",
            CrashPoint::PreFinalize => "pre-finalize",
        }
    }
}

/// One in-process client lane (the event loop's shape, driven serially).
struct Lane<'m> {
    sm: ClientSm<'m>,
    inbox: Option<Down>,
    outbox: Option<Up>,
}

fn sweep(lanes: &mut [Lane<'_>]) {
    for lane in lanes.iter_mut() {
        if let Some(down) = lane.inbox.take() {
            lane.outbox = Some(lane.sm.step(down));
        }
    }
}

/// Harvest one phase's answers in lane (= client id) order, charging
/// logical Up stats exactly like the event loop.
fn drain(lanes: &mut [Lane<'_>], phase: u8, stats: &mut NetStats) -> Result<Vec<Up>> {
    let mut ups = Vec::new();
    for lane in lanes.iter_mut() {
        match lane.outbox.take() {
            None => {}
            Some(Up::Dropped(id, step)) => log::trace!("client {id} dropped at step {step}"),
            Some(Up::Failed(id, step, e)) => log::debug!("client {id} failed step {step}: {e}"),
            Some(up) => {
                if up.phase() != phase {
                    bail!("protocol order violation in phase {phase}: {up:?}");
                }
                match &up {
                    Up::Adv(a) => stats.record(0, Dir::Up, a.id, a.size_bytes()),
                    Up::Shares(u) => stats.record(1, Dir::Up, u.from, u.size_bytes()),
                    Up::Masked(m) => {
                        stats.record(2, Dir::Up, m.id, m.size_bytes());
                        stats.record_masked_payload(m.payload_bytes());
                    }
                    Up::Unmask(u) => stats.record(3, Dir::Up, u.from, u.size_bytes()),
                    _ => unreachable!("terminal variants matched above"),
                }
                ups.push(up);
            }
        }
    }
    Ok(ups)
}

/// Route one phase's answers into the server; returns the `Down`s to
/// deliver (empty after phase 3) and the output (phase 3 only).
fn apply(
    server: &mut Server,
    phase: u8,
    ups: Vec<Up>,
) -> Result<(Vec<(ClientId, Down)>, Option<RoundOutput>)> {
    match phase {
        0 => {
            let advs = ups
                .into_iter()
                .map(|u| match u {
                    Up::Adv(a) => a,
                    other => unreachable!("drain checked phases: {other:?}"),
                })
                .collect();
            let downs = server
                .step0_route_keys(advs)?
                .into_iter()
                .map(|(id, b)| (id, Down::Bundle(b)))
                .collect();
            Ok((downs, None))
        }
        1 => {
            let uploads = ups
                .into_iter()
                .map(|u| match u {
                    Up::Shares(s) => s,
                    other => unreachable!("drain checked phases: {other:?}"),
                })
                .collect();
            let downs = server
                .step1_route_shares(uploads)?
                .into_iter()
                .map(|(id, d)| (id, Down::Delivery(d)))
                .collect();
            Ok((downs, None))
        }
        2 => {
            let inputs = ups
                .into_iter()
                .map(|u| match u {
                    Up::Masked(m) => m,
                    other => unreachable!("drain checked phases: {other:?}"),
                })
                .collect();
            let ann = Arc::new(server.step2_collect_masked(inputs)?);
            let downs = ann.v3.iter().map(|&id| (id, Down::Announce(ann.clone()))).collect();
            Ok((downs, None))
        }
        3 => {
            let responses = ups
                .into_iter()
                .map(|u| match u {
                    Up::Unmask(r) => r,
                    other => unreachable!("drain checked phases: {other:?}"),
                })
                .collect();
            Ok((Vec::new(), Some(server.finalize(responses)?)))
        }
        p => bail!("apply called with out-of-range phase {p}"),
    }
}

/// Deliver one phase's `Down`s into the lanes, charging logical Down stats
/// exactly like the event loop (`Start`/`Finish` cost nothing).
fn deliver(lanes: &mut [Lane<'_>], phase: u8, stats: &mut NetStats, downs: Vec<(ClientId, Down)>) {
    for (id, down) in downs {
        let bytes = match &down {
            Down::Bundle(b) => b.size_bytes(),
            Down::Delivery(d) => d.size_bytes(),
            Down::Announce(a) => a.size_bytes(),
            Down::Start | Down::Finish => 0,
        };
        stats.record(phase as usize, Dir::Down, id, bytes);
        lanes[id].inbox = Some(down);
    }
}

/// "The process dies here": drop the server (journal and all), optionally
/// chop emulated-crash records off the log, and rebuild everything from
/// disk. Verifies the recovery resumed at the expected phase.
fn crash_and_recover(
    server: Server,
    path: &Path,
    round: u32,
    truncate: usize,
    expect_phase: u8,
) -> Result<(Server, Vec<(ClientId, Down)>, Option<RoundOutput>)> {
    drop(server);
    if truncate > 0 {
        journal::truncate_last_records(path, truncate)
            .with_context(|| format!("truncate {truncate} records (emulated crash)"))?;
    }
    let rec = journal::recover(path).context("recover after injected crash")?;
    ensure!(rec.round == round, "recovered round {:08x}, expected {round:08x}", rec.round);
    ensure!(
        rec.next_phase == expect_phase,
        "recovered at phase {}, expected {expect_phase}",
        rec.next_phase
    );
    let mut server = rec.server;
    server.set_sink(Box::new(JournalSink::new(rec.journal)));
    Ok((server, rec.downs, rec.output))
}

/// The recovered server must regenerate the pending `Down`s byte-for-byte
/// (compared as encoded wire frames — the strictest equality we can ask).
fn ensure_downs_match(
    round: u32,
    expected: &[(ClientId, Down)],
    recovered: &[(ClientId, Down)],
) -> Result<()> {
    ensure!(
        expected.len() == recovered.len(),
        "recovery regenerated {} downs, expected {}",
        recovered.len(),
        expected.len()
    );
    for ((eid, ed), (rid, rd)) in expected.iter().zip(recovered) {
        ensure!(eid == rid, "recovery down order diverged: client {rid}, expected {eid}");
        let ef = wire::encode_down(round, ed);
        let rf = wire::encode_down(round, rd);
        ensure!(ef == rf, "recovered down for client {rid} is not byte-identical");
    }
    Ok(())
}

fn ensure_outputs_match(expected: &RoundOutput, recovered: &RoundOutput) -> Result<()> {
    ensure!(
        expected.sum == recovered.sum
            && expected.reliable == recovered.reliable
            && expected.sets == recovered.sets,
        "recovered round output diverged:\n  expected {expected:?}\n  recovered {recovered:?}"
    );
    Ok(())
}

/// Run one round with a server crash injected at `point`, recovering from
/// the journal in `dir` and finishing the round on the replayed server.
///
/// Lanes and byte accounting live on the harness side (the "clients"), so
/// the returned [`CoordRoundResult`] carries full logical stats — callers
/// can demand `logical_eq` with the uninterrupted engine, not just equal
/// sums.
pub fn run_round_crashy(
    cfg: &ProtocolConfig,
    models: &[Vec<u64>],
    dir: &Path,
    point: CrashPoint,
) -> Result<CoordRoundResult> {
    assert_eq!(models.len(), cfg.n);
    let round = crate::net::socket::round_tag(cfg.seed);
    let setup = derive_round_setup(cfg, models);
    let path = Journal::path_for(dir, round);
    let journal = Journal::create(dir, round, cfg.n, cfg.t, cfg.mask_bits, &setup.plan, &setup.graph)
        .context("create round journal")?;
    let mut server = Server::new(cfg.n, cfg.t, cfg.mask_bits, setup.plan.clone(), setup.graph.clone());
    server.set_sink(Box::new(JournalSink::new(journal)));
    let mut stats = NetStats::new(cfg.n);
    let mut lanes: Vec<Lane<'_>> = (0..cfg.n)
        .map(|id| {
            let (mut key_rng, share_rng) = setup.streams[id].clone();
            let sm = ClientSm::new(
                id,
                cfg.t,
                cfg.mask_bits,
                setup.graph.neighbors(id).to_vec(),
                &mut key_rng,
                share_rng,
                &models[id],
                setup.plan.clone(),
                setup.survives[id],
            );
            Lane { sm, inbox: Some(Down::Start), outbox: None }
        })
        .collect();

    // ---- phase 0
    sweep(&mut lanes);
    let ups = drain(&mut lanes, 0, &mut stats)?;
    if point == CrashPoint::AfterSetup {
        let (s, downs, _) = crash_and_recover(server, &path, round, 0, 0)?;
        server = s;
        ensure!(downs.is_empty(), "phase-0 recovery owes no downs");
    }
    let (mut downs, _) = apply(&mut server, 0, ups)?;
    if point == CrashPoint::AfterStep0 {
        let (s, rdowns, _) = crash_and_recover(server, &path, round, 0, 1)?;
        server = s;
        ensure_downs_match(round, &downs, &rdowns)?;
        downs = rdowns; // finish the round on recovery's regenerated downs
    }
    deliver(&mut lanes, 0, &mut stats, downs);

    // ---- phase 1
    sweep(&mut lanes);
    let ups = drain(&mut lanes, 1, &mut stats)?;
    let (mut downs, _) = apply(&mut server, 1, ups)?;
    if point == CrashPoint::AfterStep1 {
        let (s, rdowns, _) = crash_and_recover(server, &path, round, 0, 2)?;
        server = s;
        ensure_downs_match(round, &downs, &rdowns)?;
        downs = rdowns;
    }
    deliver(&mut lanes, 1, &mut stats, downs);

    // ---- phase 2
    sweep(&mut lanes);
    let ups = drain(&mut lanes, 2, &mut stats)?;
    let (mut downs, _) = apply(&mut server, 2, ups)?;
    match point {
        CrashPoint::AfterStep2 => {
            // die between the ups record and the announce record
            let (s, rdowns, _) = crash_and_recover(server, &path, round, 1, 3)?;
            server = s;
            ensure_downs_match(round, &downs, &rdowns)?;
            downs = rdowns;
        }
        CrashPoint::AfterAnnounce => {
            let (s, rdowns, _) = crash_and_recover(server, &path, round, 0, 3)?;
            server = s;
            ensure_downs_match(round, &downs, &rdowns)?;
            downs = rdowns;
        }
        _ => {}
    }
    deliver(&mut lanes, 2, &mut stats, downs);

    // ---- phase 3
    sweep(&mut lanes);
    let ups = drain(&mut lanes, 3, &mut stats)?;
    let (_, output) = apply(&mut server, 3, ups)?;
    let mut output = output.expect("phase 3 yields the round output");
    match point {
        CrashPoint::AfterStep3 => {
            // lose the checkpoint and final records
            let (_, rdowns, rout) = crash_and_recover(server, &path, round, 2, 4)?;
            ensure!(rdowns.is_empty(), "phase-4 recovery owes no downs");
            let rout = rout.expect("phase-4 recovery carries the round output");
            ensure_outputs_match(&output, &rout)?;
            output = rout;
        }
        CrashPoint::PreFinalize => {
            // lose only the final record; the checkpoint must cross-check
            let (_, rdowns, rout) = crash_and_recover(server, &path, round, 1, 4)?;
            ensure!(rdowns.is_empty(), "phase-4 recovery owes no downs");
            let rout = rout.expect("phase-4 recovery carries the round output");
            ensure_outputs_match(&output, &rout)?;
            output = rout;
        }
        _ => {}
    }

    // round over: the executors' Finish costs no logical bytes
    for lane in lanes.iter_mut() {
        if !lane.sm.done() {
            let _ = lane.sm.step(Down::Finish);
        }
    }
    let RoundOutput { sum, reliable, sets } = output;
    Ok(CoordRoundResult { sum, reliable, sets, stats, timeline: None })
}

/// The crash-vs-engine differential for one round config: every crash
/// point must finish the round `logical_eq`-identical to the uninterrupted
/// engine (or abort exactly when the engine aborts).
pub fn diff_crash_round(cfg: &ProtocolConfig, models: &[Vec<u64>], dir: &Path) -> Result<()> {
    let reference = engine::run_round(cfg, models);
    for point in CrashPoint::ALL {
        let crashed = run_round_crashy(cfg, models, &dir.join(point.name()), point);
        match (&reference, crashed) {
            (Err(_), Err(_)) => {}
            (Err(e), Ok(_)) => {
                bail!("{}: engine aborted ({e}) but the crashed round finished", point.name())
            }
            (Ok(_), Err(e)) => bail!("{}: crashed round failed: {e}", point.name()),
            (Ok(r), Ok(c)) => {
                ensure!(c.sum == r.sum, "{}: sum diverged from engine", point.name());
                ensure!(c.sets == r.sets, "{}: survivor sets diverged", point.name());
                ensure!(c.reliable == r.reliable, "{}: reliability diverged", point.name());
                ensure!(
                    c.stats.logical_eq(&r.stats),
                    "{}: logical stats diverged from engine",
                    point.name()
                );
            }
        }
    }
    Ok(())
}

/// Shape `run_round_crashy`'s outcome like a campaign round record so the
/// differential harness can reuse its comparators.
pub fn crash_record(
    cfg: &ProtocolConfig,
    models: &[Vec<u64>],
    dir: &Path,
    point: CrashPoint,
    round: usize,
) -> RoundRecord {
    match run_round_crashy(cfg, models, dir, point) {
        Ok(r) => RoundRecord {
            round,
            aborted: false,
            reliable: r.reliable,
            sum: r.sum,
            sets: r.sets,
            stats: r.stats,
            theorem1_agrees: None,
            sum_matches_truth: None,
            breaches: 0,
            exposed_honest: 0,
        },
        Err(_) => RoundRecord::aborted(round, cfg.n),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::Topology;
    use crate::util::rng::Rng;

    fn models(n: usize, dim: usize, seed: u64) -> Vec<Vec<u64>> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF).collect()).collect()
    }

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("ccesa-crash-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn every_crash_point_finishes_the_round_like_the_engine() {
        let n = 8;
        let dim = 6;
        let cfg = ProtocolConfig::for_test(n, 3, dim, Topology::Complete, 42);
        let m = models(n, dim, 5);
        let dir = tmp_dir("matrix");
        diff_crash_round(&cfg, &m, &dir).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn crash_recovery_survives_mid_round_dropouts() {
        use crate::protocol::dropout::DropoutModel;
        let n = 9;
        let dim = 5;
        let cfg = ProtocolConfig {
            dropout: DropoutModel::Targeted { per_step: [vec![1], vec![4], vec![7], vec![]] },
            ..ProtocolConfig::for_test(n, 3, dim, Topology::Complete, 17)
        };
        let m = models(n, dim, 23);
        let dir = tmp_dir("churny");
        diff_crash_round(&cfg, &m, &dir).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn aborting_rounds_abort_under_every_crash_point_too() {
        use crate::protocol::dropout::DropoutModel;
        let n = 5;
        let cfg = ProtocolConfig {
            dropout: DropoutModel::Targeted {
                per_step: [(0..n).collect(), vec![], vec![], vec![]],
            },
            ..ProtocolConfig::for_test(n, 3, 4, Topology::Complete, 7)
        };
        let m = models(n, 4, 7);
        let dir = tmp_dir("abort");
        diff_crash_round(&cfg, &m, &dir).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
