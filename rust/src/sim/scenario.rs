//! Declarative scenario specs and their compilation into replayable round
//! plans.
//!
//! A [`Scenario`] describes a whole campaign — population, per-round
//! topology schedule, churn process, adversary, quantizer config, round
//! count — as data. [`Scenario::compile`] pre-draws all stochastic choices
//! into [`RoundPlan`]s whose dropout is an explicit
//! [`DropoutModel::Targeted`] schedule, so the same plan replays
//! bit-identically through `protocol::engine` and `coordinator`, and a
//! failing scenario shrinks to a quotable seed (`sim::differential`).

use super::churn::ChurnModel;
use crate::analysis::bounds::t_rule;
use crate::codec::Codec;
use crate::graph::Graph;
use crate::protocol::dropout::DropoutModel;
use crate::protocol::{ClientId, ProtocolConfig, Topology};
use crate::util::rng::Rng;

/// Which collusion the privacy scoring assumes.
#[derive(Debug, Clone)]
pub enum AdversarySpec {
    /// The passive eavesdropper of Definition 2, alone.
    Eavesdropper,
    /// Eavesdropper whose operator additionally knows the plaintext inputs
    /// of these clients: a breached partial sum over a subset whose honest
    /// remainder is a single client exposes that client's model exactly.
    Colluding(Vec<ClientId>),
}

impl AdversarySpec {
    pub fn colluders(&self) -> &[ClientId] {
        match self {
            AdversarySpec::Eavesdropper => &[],
            AdversarySpec::Colluding(ids) => ids,
        }
    }
}

/// How the secret-sharing threshold is chosen each round.
#[derive(Debug, Clone)]
pub enum ThresholdRule {
    /// Use this t for every round.
    Fixed(usize),
    /// Per-topology design rule, mirroring `fl::rounds`: Remark 4's
    /// `t_rule` for Erdős–Rényi, ⌊n/2⌋+1 for the complete graph, half the
    /// degree plus one for Harary.
    Auto,
}

/// Dimension-relative payload codec choice — the scenario axis form of
/// [`Codec`]: sparsity is a *fraction* of the model dimension so one spec
/// sweeps across populations and dims, and [`CodecSpec::resolve`] pins the
/// concrete k at compile time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CodecSpec {
    /// Full dense payload (the pre-codec protocol).
    Dense,
    /// Global top-k at `k = round(frac · dim)`, clamped to 1..=dim.
    TopK { frac: f64 },
    /// Random-k at `k = round(frac · dim)`, clamped to 1..=dim.
    RandK { frac: f64 },
}

impl CodecSpec {
    fn k_of(frac: f64, dim: usize) -> usize {
        ((dim as f64 * frac).round() as usize).clamp(1, dim.max(1))
    }

    /// The concrete codec for a `dim`-dimensional round.
    pub fn resolve(&self, dim: usize) -> Codec {
        match self {
            CodecSpec::Dense => Codec::Dense,
            CodecSpec::TopK { frac } => Codec::TopK { k: Self::k_of(*frac, dim) },
            CodecSpec::RandK { frac } => Codec::RandK { k: Self::k_of(*frac, dim) },
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CodecSpec::Dense => "dense",
            CodecSpec::TopK { .. } => "topk",
            CodecSpec::RandK { .. } => "randk",
        }
    }
}

/// Per-round assignment-graph schedule.
#[derive(Debug, Clone)]
pub enum TopologySchedule {
    /// The same family every round.
    Static(Topology),
    /// Round-robin over the list (models between-round reconfiguration).
    /// Must be non-empty.
    Rotating(Vec<Topology>),
    /// Erdős–Rényi with the connection probability ramping linearly:
    /// round r uses p = clamp(p0 + r·dp, 0, 1) — densifying or sparsifying
    /// deployments.
    ErRamp { p0: f64, dp: f64 },
}

impl TopologySchedule {
    pub fn topology_for(&self, round: usize) -> Topology {
        match self {
            TopologySchedule::Static(t) => t.clone(),
            TopologySchedule::Rotating(ts) => {
                assert!(!ts.is_empty(), "empty rotating topology schedule");
                ts[round % ts.len()].clone()
            }
            TopologySchedule::ErRamp { p0, dp } => {
                Topology::ErdosRenyi { p: (p0 + dp * round as f64).clamp(0.0, 1.0) }
            }
        }
    }
}

/// A declarative multi-round campaign spec. Everything derives from `seed`.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    /// Client population per round.
    pub n: usize,
    /// Model dimension.
    pub dim: usize,
    /// Aggregation domain width b (Z_{2^b}).
    pub mask_bits: u32,
    /// Number of aggregation rounds.
    pub rounds: usize,
    pub topology: TopologySchedule,
    pub churn: ChurnModel,
    pub adversary: AdversarySpec,
    pub threshold: ThresholdRule,
    /// Payload codec applied to every round's client updates — swept by
    /// the campaign runner and diffed by the differential harness like any
    /// other scenario axis.
    pub codec: CodecSpec,
    /// Quantizer clip used when the campaign drives f32 updates through
    /// `fl::rounds::run_fl_scenario` (protocol-level campaigns over u64
    /// inputs ignore it).
    pub clip: f32,
    pub seed: u64,
}

/// One round, fully materialized: a config whose dropout is an explicit
/// targeted schedule, plus the assignment graph that config builds —
/// everything needed to replay or inspect the round without re-drawing
/// randomness.
#[derive(Debug, Clone)]
pub struct RoundPlan {
    pub round: usize,
    pub cfg: ProtocolConfig,
    pub graph: Graph,
}

impl Scenario {
    /// Resolve the threshold for one round's topology.
    pub fn resolve_t(&self, topo: &Topology) -> usize {
        match &self.threshold {
            ThresholdRule::Fixed(t) => *t,
            ThresholdRule::Auto => match topo {
                Topology::Complete => self.n / 2 + 1,
                Topology::ErdosRenyi { p } => t_rule(self.n, *p).min(self.n),
                Topology::Harary { k } => (k / 2 + 1).max(2),
                Topology::Custom(_) => self.n / 2 + 1,
                // Intra-shard rounds run at a threshold sized to the shard,
                // not the population; recurse on the intra family over the
                // smallest shard (hier scenarios use sim::hier, which sizes
                // this itself — this arm only keeps the match total).
                Topology::Hierarchical { shards, intra, .. } => {
                    let m = (self.n / shards.max(&1)).max(1);
                    match intra.as_ref() {
                        Topology::Complete | Topology::Custom(_) => m / 2 + 1,
                        Topology::ErdosRenyi { p } => t_rule(m, *p).min(m),
                        Topology::Harary { k } => (k / 2 + 1).max(2),
                        Topology::Hierarchical { .. } => m / 2 + 1,
                    }
                }
            },
        }
    }

    fn round_seed(&self, round: usize) -> u64 {
        self.seed ^ (round as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
    }

    /// Deterministic per-round client inputs: full-entropy words in
    /// Z_{2^mask_bits}.
    pub fn round_models(&self, round: usize) -> Vec<Vec<u64>> {
        let modmask = crate::util::mod_mask(self.mask_bits);
        let mut rng = Rng::new(self.round_seed(round) ^ 0x0DE1);
        (0..self.n)
            .map(|_| (0..self.dim).map(|_| rng.next_u64() & modmask).collect())
            .collect()
    }

    /// Compile into per-round plans. Stochastic churn is pre-drawn here
    /// (graphs are built first so adaptive churn can see degrees), after
    /// which every plan is rng-free data.
    pub fn compile(&self) -> Vec<RoundPlan> {
        let mut cfgs = Vec::with_capacity(self.rounds);
        let mut graphs = Vec::with_capacity(self.rounds);
        for round in 0..self.rounds {
            let topo = self.topology.topology_for(round);
            let t = self.resolve_t(&topo);
            let cfg = ProtocolConfig::builder()
                .clients(self.n)
                .threshold(t)
                .model_dim(self.dim)
                .mask_bits(self.mask_bits)
                .topology(topo)
                .codec(self.codec.resolve(self.dim))
                .seed(self.round_seed(round))
                .build()
                .expect("scenario compiles to a valid protocol config");
            graphs.push(cfg.build_graph());
            cfgs.push(cfg);
        }
        let mut churn_rng = Rng::new(self.seed ^ 0xC4021);
        let schedules = self.churn.compile(self.n, &graphs, &mut churn_rng);
        cfgs.into_iter()
            .zip(graphs)
            .zip(schedules)
            .enumerate()
            .map(|(round, ((mut cfg, graph), per_step))| {
                cfg.dropout = DropoutModel::Targeted { per_step };
                RoundPlan { round, cfg, graph }
            })
            .collect()
    }
}

/// Seeded random scenario for the differential harness: small populations
/// (both drivers stay fast), mixed topology schedules, every churn model,
/// occasional collusion, thresholds both sane and deliberately too high
/// (aborts are an outcome the drivers must agree on too).
pub fn random_scenario(seed: u64) -> Scenario {
    let mut rng = Rng::new(seed ^ 0x5CEA_A210);
    let n = 5 + rng.gen_range(9) as usize; // 5..=13
    let dim = 1 + rng.gen_range(24) as usize; // 1..=24
    let mask_bits = [16u32, 32, 32, 64][rng.gen_range(4) as usize];
    let rounds = 1 + rng.gen_range(3) as usize; // 1..=3
    let topology = match rng.gen_range(5) {
        0 => TopologySchedule::Static(Topology::Complete),
        1 => TopologySchedule::Static(Topology::ErdosRenyi { p: 0.5 + 0.5 * rng.next_f64() }),
        2 => {
            let k = 2 + rng.gen_range((n - 3) as u64) as usize; // 2..=n-2
            TopologySchedule::Static(Topology::Harary { k })
        }
        3 => TopologySchedule::Rotating(vec![
            Topology::Complete,
            Topology::ErdosRenyi { p: 0.6 + 0.4 * rng.next_f64() },
        ]),
        _ => TopologySchedule::ErRamp { p0: 0.5 + 0.3 * rng.next_f64(), dp: 0.1 },
    };
    let churn = match rng.gen_range(5) {
        0 => ChurnModel::None,
        1 => ChurnModel::Iid { q: 0.08 * rng.next_f64() },
        2 => ChurnModel::Bursty { q_calm: 0.02, q_storm: 0.25, p_enter: 0.4, p_exit: 0.5 },
        3 => ChurnModel::CorrelatedRegional {
            regions: 2 + rng.gen_range(2) as usize,
            q_region: 0.15,
            q_local: 0.02,
        },
        _ => ChurnModel::TargetedAdaptive {
            count: 1 + rng.gen_range(2) as usize,
            step: rng.gen_range(4) as usize,
        },
    };
    let adversary = if rng.bernoulli(0.3) {
        let count = (1 + rng.gen_range(2) as usize).min(n);
        AdversarySpec::Colluding(rng.sample_indices(n, count))
    } else {
        AdversarySpec::Eavesdropper
    };
    let threshold = if rng.bernoulli(0.5) {
        ThresholdRule::Fixed(2 + rng.gen_range((n / 2) as u64) as usize)
    } else {
        ThresholdRule::Auto
    };
    // Payload codec axis: dense keeps its weight (the reference path), the
    // rest splits between the two sparse families at fractions well inside
    // (0, 1) so every k ∈ 1..dim is reachable across seeds.
    let codec = match rng.gen_range(5) {
        0 | 1 => CodecSpec::Dense,
        2 | 3 => CodecSpec::RandK { frac: 0.15 + 0.5 * rng.next_f64() },
        _ => CodecSpec::TopK { frac: 0.15 + 0.5 * rng.next_f64() },
    };
    Scenario {
        name: format!("random-{seed:#x}"),
        n,
        dim,
        mask_bits,
        rounds,
        topology,
        churn,
        adversary,
        threshold,
        codec,
        clip: 4.0,
        seed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> Scenario {
        Scenario {
            name: "base".to_string(),
            n: 8,
            dim: 4,
            mask_bits: 32,
            rounds: 3,
            topology: TopologySchedule::Static(Topology::Complete),
            churn: ChurnModel::Iid { q: 0.1 },
            adversary: AdversarySpec::Eavesdropper,
            threshold: ThresholdRule::Fixed(3),
            codec: CodecSpec::Dense,
            clip: 4.0,
            seed: 42,
        }
    }

    #[test]
    fn compile_is_deterministic_and_targeted() {
        let sc = base();
        let a = sc.compile();
        let b = sc.compile();
        assert_eq!(a.len(), 3);
        for (pa, pb) in a.iter().zip(&b) {
            assert_eq!(pa.graph, pb.graph);
            assert_eq!(pa.cfg.seed, pb.cfg.seed);
            let (DropoutModel::Targeted { per_step: sa }, DropoutModel::Targeted { per_step: sb }) =
                (&pa.cfg.dropout, &pb.cfg.dropout)
            else {
                panic!("compiled dropout must be Targeted");
            };
            assert_eq!(sa, sb);
        }
        // different rounds get different seeds (graphs/models decorrelate)
        assert_ne!(a[0].cfg.seed, a[1].cfg.seed);
    }

    #[test]
    fn round_models_respect_mask_bits() {
        let mut sc = base();
        sc.mask_bits = 16;
        let m = sc.round_models(0);
        assert_eq!(m.len(), sc.n);
        assert!(m.iter().flatten().all(|&x| x < (1 << 16)));
        // deterministic and round-dependent
        assert_eq!(sc.round_models(1), sc.round_models(1));
        assert_ne!(sc.round_models(0), sc.round_models(1));
    }

    #[test]
    fn topology_schedules_resolve() {
        let rot = TopologySchedule::Rotating(vec![
            Topology::Complete,
            Topology::ErdosRenyi { p: 0.7 },
        ]);
        assert!(matches!(rot.topology_for(0), Topology::Complete));
        assert!(matches!(rot.topology_for(1), Topology::ErdosRenyi { .. }));
        assert!(matches!(rot.topology_for(2), Topology::Complete));

        let ramp = TopologySchedule::ErRamp { p0: 0.9, dp: 0.2 };
        let Topology::ErdosRenyi { p } = ramp.topology_for(3) else { panic!() };
        assert!((p - 1.0).abs() < 1e-12, "ramp must clamp to 1, got {p}");
    }

    #[test]
    fn auto_threshold_mirrors_fl_rules() {
        let sc = Scenario { threshold: ThresholdRule::Auto, ..base() };
        assert_eq!(sc.resolve_t(&Topology::Complete), sc.n / 2 + 1);
        assert_eq!(sc.resolve_t(&Topology::Harary { k: 6 }), 4);
        let t_er = sc.resolve_t(&Topology::ErdosRenyi { p: 0.8 });
        assert!(t_er >= 2 && t_er <= sc.n);
    }

    #[test]
    fn codec_spec_resolves_fraction_to_bounded_k() {
        assert_eq!(CodecSpec::Dense.resolve(100), Codec::Dense);
        assert_eq!(CodecSpec::TopK { frac: 0.1 }.resolve(100), Codec::TopK { k: 10 });
        assert_eq!(CodecSpec::RandK { frac: 0.5 }.resolve(7), Codec::RandK { k: 4 });
        // clamped at both ends
        assert_eq!(CodecSpec::RandK { frac: 0.0 }.resolve(10), Codec::RandK { k: 1 });
        assert_eq!(CodecSpec::TopK { frac: 2.0 }.resolve(10), Codec::TopK { k: 10 });
        assert_eq!(CodecSpec::TopK { frac: 0.3 }.resolve(1), Codec::TopK { k: 1 });
    }

    #[test]
    fn sparse_scenario_compiles_with_codec_in_every_plan() {
        let sc = Scenario { codec: CodecSpec::RandK { frac: 0.5 }, ..base() };
        for plan in sc.compile() {
            assert_eq!(plan.cfg.codec, Codec::RandK { k: 2 }, "round {}", plan.round);
        }
    }

    #[test]
    fn random_scenarios_are_deterministic_and_varied() {
        for seed in 0..50u64 {
            let a = random_scenario(seed);
            let b = random_scenario(seed);
            assert_eq!(format!("{a:?}"), format!("{b:?}"), "seed={seed}");
            assert!((5..=13).contains(&a.n));
            assert!((1..=3).contains(&a.rounds));
            // every scenario must compile without panicking
            let plans = a.compile();
            assert_eq!(plans.len(), a.rounds);
            for plan in &plans {
                assert_eq!(plan.graph.n(), a.n);
            }
        }
        // the space is actually sampled: at least two distinct churn kinds
        let kinds: std::collections::BTreeSet<u8> = (0..50u64)
            .map(|s| match random_scenario(s).churn {
                ChurnModel::None => 0,
                ChurnModel::Iid { .. } => 1,
                ChurnModel::Bursty { .. } => 2,
                ChurnModel::CorrelatedRegional { .. } => 3,
                ChurnModel::TargetedAdaptive { .. } => 4,
                ChurnModel::Scripted { .. } => 5,
            })
            .collect();
        assert!(kinds.len() >= 4, "churn kinds seen: {kinds:?}");
        // and every codec family appears
        let codecs: std::collections::BTreeSet<&str> =
            (0..50u64).map(|s| random_scenario(s).codec.name()).collect();
        assert_eq!(codecs.len(), 3, "codec kinds seen: {codecs:?}");
    }
}
