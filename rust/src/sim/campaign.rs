//! Multi-round campaign runner: drive a compiled [`Scenario`] through any
//! [`Executor`] (sync engine, worker-pool event loop, or the loopback
//! socket wire) and aggregate what happened.
//!
//! The engine driver additionally scores each round's transcript with the
//! Definition-2 eavesdropper attack and checks Theorem 1's predicate
//! against the implementation — a campaign is simultaneously a reliability
//! experiment (§4.3), a privacy experiment (§4.4) and a regression suite.

use super::scenario::{RoundPlan, Scenario};
use crate::coordinator::{CoordRoundResult, RoundOptions, RoundRunner};
use crate::net::NetStats;
use crate::protocol::adversary::{attack, Breach};
use crate::protocol::engine::run_round;
use crate::protocol::{ClientId, SurvivorSets};
use anyhow::Result;

// The executor axis lives with the round runner now ([`RoundOptions`]
// selects it); campaigns re-export it so existing `sim::Executor` imports
// keep working.
pub use crate::coordinator::Executor;

/// Everything recorded about one campaign round.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RoundRecord {
    pub round: usize,
    /// The server aborted before finalize (|V_k| < t at some step).
    pub aborted: bool,
    pub reliable: bool,
    pub sum: Option<Vec<u64>>,
    pub sets: SurvivorSets,
    pub stats: NetStats,
    /// Engine executor only: whether Theorem 1's predicate agreed with the
    /// implementation's reliability outcome.
    pub theorem1_agrees: Option<bool>,
    /// Engine executor only: whether the unmasked aggregate equals the
    /// independently computed plain sum (`true_sum_v3`). A `Some(false)`
    /// means mask cancellation itself is broken — e.g. a diverging GF/mask
    /// kernel backend — and the differential harness reports it as a named
    /// `sum_vs_truth` mismatch rather than a downstream flake.
    pub sum_matches_truth: Option<bool>,
    /// Engine executor only: partial-sum breaches the Definition-2
    /// eavesdropper extracted from this round's transcript.
    pub breaches: usize,
    /// Engine executor only: honest clients whose individual model the
    /// scenario's colluding set reads off a breached partial sum.
    pub exposed_honest: usize,
}

impl RoundRecord {
    pub(crate) fn aborted(round: usize, n: usize) -> RoundRecord {
        RoundRecord {
            round,
            aborted: true,
            reliable: false,
            sum: None,
            sets: SurvivorSets::default(),
            stats: NetStats::new(n),
            theorem1_agrees: None,
            sum_matches_truth: None,
            breaches: 0,
            exposed_honest: 0,
        }
    }
}

/// Aggregated campaign outcome.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    pub scenario: String,
    pub seed: u64,
    pub executor: Executor,
    pub records: Vec<RoundRecord>,
    pub total_stats: NetStats,
}

impl CampaignReport {
    pub fn rounds(&self) -> usize {
        self.records.len()
    }
    pub fn reliable_rounds(&self) -> usize {
        self.records.iter().filter(|r| r.reliable).count()
    }
    pub fn aborted_rounds(&self) -> usize {
        self.records.iter().filter(|r| r.aborted).count()
    }
    pub fn breached_rounds(&self) -> usize {
        self.records.iter().filter(|r| r.breaches > 0).count()
    }
    pub fn exposed_honest_total(&self) -> usize {
        self.records.iter().map(|r| r.exposed_honest).sum()
    }
    /// Rounds where the implementation disagreed with Theorem 1 — any
    /// nonzero value is a bug.
    pub fn theorem1_violations(&self) -> usize {
        self.records
            .iter()
            .filter(|r| r.theorem1_agrees == Some(false))
            .count()
    }
    pub fn one_line(&self) -> String {
        format!(
            "{}: {} rounds, {} reliable, {} aborted, {} breached, {} exposed, {:.1} KiB through server",
            self.scenario,
            self.rounds(),
            self.reliable_rounds(),
            self.aborted_rounds(),
            self.breached_rounds(),
            self.exposed_honest_total(),
            self.total_stats.server_total() as f64 / 1024.0,
        )
    }
}

/// How many breaches expose exactly one honest client to the colluders.
fn exposed_honest(breaches: &[Breach], colluders: &[ClientId]) -> usize {
    breaches
        .iter()
        .filter(|b| b.subset.iter().filter(|i| !colluders.contains(i)).count() == 1)
        .count()
}

/// Run one pre-compiled round plan through the chosen executor.
pub fn run_plan(
    plan: &RoundPlan,
    models: &[Vec<u64>],
    executor: Executor,
    colluders: &[ClientId],
) -> RoundRecord {
    let coord_record = |r: Result<CoordRoundResult>| match r {
        Ok(r) => RoundRecord {
            round: plan.round,
            aborted: false,
            reliable: r.reliable,
            sum: r.sum,
            sets: r.sets,
            stats: r.stats,
            theorem1_agrees: None,
            sum_matches_truth: None,
            breaches: 0,
            exposed_honest: 0,
        },
        Err(_) => RoundRecord::aborted(plan.round, plan.cfg.n),
    };
    match executor {
        Executor::Engine => match run_round(&plan.cfg, models) {
            Ok(r) => {
                let breaches = attack(&r.transcript);
                let sum_matches_truth = r.sum.as_deref().map(|s| s == &r.true_sum_v3[..]);
                RoundRecord {
                    round: plan.round,
                    aborted: false,
                    reliable: r.reliable,
                    sum: r.sum,
                    sets: r.sets,
                    stats: r.stats,
                    theorem1_agrees: Some(r.theorem1_holds == r.reliable),
                    sum_matches_truth,
                    breaches: breaches.len(),
                    exposed_honest: exposed_honest(&breaches, colluders),
                }
            }
            Err(_) => RoundRecord::aborted(plan.round, plan.cfg.n),
        },
        Executor::EventLoop | Executor::Wire => {
            let opts = RoundOptions::builder()
                .executor(executor)
                .build()
                .expect("an executor alone is always a valid round configuration");
            coord_record(RoundRunner::new(opts).run(&plan.cfg, models))
        }
    }
}

/// Run a full scenario campaign through the chosen executor.
///
/// §Perf: compiled plans are rng-free data, so rounds are independent —
/// each round's per-client work (model materialization, the full protocol
/// round, transcript scoring) runs on a `crate::par` worker. Records are
/// merged back in round order, so the report (including the `NetStats`
/// accumulation order) is bit-identical to the serial runner's.
pub fn run_campaign(sc: &Scenario, executor: Executor) -> Result<CampaignReport> {
    let plans = sc.compile();
    let colluders = sc.adversary.colluders();
    let workers = match executor {
        // Rounds whose vectors are too short to shard internally (the
        // simulation regime — exactly the rounds step2/finalize run
        // serially) parallelize across rounds here. Rounds that do shard
        // internally run one at a time: parallelizing both levels would
        // oversubscribe CPU ~threads² and hold several rounds' full model
        // sets in memory at once.
        Executor::Engine if crate::par::threads_for_len(sc.dim) == 1 => crate::par::threads(),
        Executor::Engine => 1,
        // the event loop parallelizes internally across pool workers;
        // running its rounds concurrently on top would multiply that —
        // and the wire executor additionally owns real sockets per round
        Executor::EventLoop | Executor::Wire => 1,
    };
    let records = crate::par::map_indexed(plans.len(), workers, |i| {
        let plan = &plans[i];
        let models = sc.round_models(plan.round);
        run_plan(plan, &models, executor, colluders)
    });
    let mut total_stats = NetStats::new(sc.n);
    for record in &records {
        total_stats.merge(&record.stats);
    }
    Ok(CampaignReport { scenario: sc.name.clone(), seed: sc.seed, executor, records, total_stats })
}

// ---------------------------------------------------------------------------
// Resumable campaigns — every finished round is one durable log record
// ---------------------------------------------------------------------------

/// Record type for one serialized [`RoundRecord`] in a campaign log (the
/// journal's raw user range, so `journal::read_log` tooling just works).
const RT_CAMPAIGN_ROUND: u8 = crate::journal::RT_USER_BASE;

/// Where [`resume_campaign`] keeps a scenario's on-disk progress.
pub fn campaign_log_path(dir: &std::path::Path, sc: &Scenario, executor: Executor) -> std::path::PathBuf {
    dir.join(format!("campaign-{}-{}-{:016x}.ccl", sc.name, executor.name(), sc.seed))
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_ids(out: &mut Vec<u8>, ids: &[ClientId]) {
    crate::wire::put_u32(out, ids.len() as u32);
    for &id in ids {
        crate::wire::put_u32(out, id as u32);
    }
}

fn put_u64s(out: &mut Vec<u8>, xs: &[u64]) {
    crate::wire::put_u32(out, xs.len() as u32);
    for &x in xs {
        put_u64(out, x);
    }
}

/// Optional-bool as one byte: 0 = None, 2 = Some(false), 3 = Some(true).
fn put_opt_bool(out: &mut Vec<u8>, v: Option<bool>) {
    out.push(match v {
        None => 0,
        Some(false) => 2,
        Some(true) => 3,
    });
}

fn encode_round_record(r: &RoundRecord) -> Vec<u8> {
    let mut out = Vec::new();
    put_u64(&mut out, r.round as u64);
    out.push(u8::from(r.aborted) | (u8::from(r.reliable) << 1) | (u8::from(r.sum.is_some()) << 2));
    if let Some(sum) = &r.sum {
        put_u64s(&mut out, sum);
    }
    put_ids(&mut out, &r.sets.v1);
    put_ids(&mut out, &r.sets.v2);
    put_ids(&mut out, &r.sets.v3);
    put_ids(&mut out, &r.sets.v4);
    let s = &r.stats;
    for step in 0..4 {
        put_u64(&mut out, s.bytes_up[step]);
        put_u64(&mut out, s.bytes_down[step]);
        put_u64(&mut out, s.msgs_up[step]);
        put_u64(&mut out, s.msgs_down[step]);
    }
    put_u64(&mut out, s.masked_payload_bytes);
    put_u64(&mut out, s.framed_up);
    put_u64(&mut out, s.framed_down);
    put_u64s(&mut out, &s.client_up);
    put_u64s(&mut out, &s.client_down);
    put_opt_bool(&mut out, r.theorem1_agrees);
    put_opt_bool(&mut out, r.sum_matches_truth);
    put_u64(&mut out, r.breaches as u64);
    put_u64(&mut out, r.exposed_honest as u64);
    // session-era counters ride at the tail so logs written before they
    // existed still decode (they read back as zero)
    put_u64(&mut out, s.coord_map_bytes);
    put_u64(&mut out, s.rekey_up);
    put_u64(&mut out, s.rekey_down);
    // virtual-clock era: timeout-dropout classification, same tail-extension
    // backward compatibility
    for step in 0..4 {
        put_u64(&mut out, s.timeout_drops[step]);
    }
    out
}

fn decode_round_record(payload: &[u8]) -> Result<RoundRecord> {
    use crate::wire::Reader;
    fn ids(rd: &mut Reader<'_>) -> Result<Vec<ClientId>> {
        let len = rd.u32("set length")? as usize;
        (0..len).map(|_| Ok(rd.u32("client id")? as ClientId)).collect()
    }
    fn u64s(rd: &mut Reader<'_>) -> Result<Vec<u64>> {
        let len = rd.u32("vector length")? as usize;
        (0..len).map(|_| Ok(rd.u64("u64 element")?)).collect()
    }
    fn opt_bool(rd: &mut Reader<'_>) -> Result<Option<bool>> {
        match rd.u8("optional bool")? {
            0 => Ok(None),
            2 => Ok(Some(false)),
            3 => Ok(Some(true)),
            b => anyhow::bail!("campaign record: invalid optional-bool byte 0x{b:02x}"),
        }
    }
    let mut rd = Reader::new(payload);
    let round = rd.u64("round index")? as usize;
    let flags = rd.u8("flags")?;
    let aborted = flags & 1 != 0;
    let reliable = flags & 2 != 0;
    let sum = if flags & 4 != 0 { Some(u64s(&mut rd)?) } else { None };
    let sets = SurvivorSets {
        v1: ids(&mut rd)?,
        v2: ids(&mut rd)?,
        v3: ids(&mut rd)?,
        v4: ids(&mut rd)?,
    };
    let mut stats = NetStats::new(0);
    for step in 0..4 {
        stats.bytes_up[step] = rd.u64("bytes_up")?;
        stats.bytes_down[step] = rd.u64("bytes_down")?;
        stats.msgs_up[step] = rd.u64("msgs_up")?;
        stats.msgs_down[step] = rd.u64("msgs_down")?;
    }
    stats.masked_payload_bytes = rd.u64("masked_payload_bytes")?;
    stats.framed_up = rd.u64("framed_up")?;
    stats.framed_down = rd.u64("framed_down")?;
    stats.client_up = u64s(&mut rd)?;
    stats.client_down = u64s(&mut rd)?;
    let theorem1_agrees = opt_bool(&mut rd)?;
    let sum_matches_truth = opt_bool(&mut rd)?;
    let breaches = rd.u64("breaches")? as usize;
    let exposed_honest = rd.u64("exposed_honest")? as usize;
    if rd.remaining() > 0 {
        stats.coord_map_bytes = rd.u64("coord_map_bytes")?;
        stats.rekey_up = rd.u64("rekey_up")?;
        stats.rekey_down = rd.u64("rekey_down")?;
    }
    if rd.remaining() > 0 {
        for step in 0..4 {
            stats.timeout_drops[step] = rd.u64("timeout_drops")?;
        }
    }
    rd.done()?;
    Ok(RoundRecord {
        round,
        aborted,
        reliable,
        sum,
        sets,
        stats,
        theorem1_agrees,
        sum_matches_truth,
        breaches,
        exposed_honest,
    })
}

/// Run a campaign as a durable on-disk artifact: every finished round is
/// appended (checksummed, fsynced) to a journal-format log under `dir`,
/// and a rerun after a crash — or a deliberate kill — replays the recorded
/// rounds from disk and computes only the remainder.
///
/// Rounds run serially (append order *is* round order), so a resumed
/// report is bit-identical to an uninterrupted [`run_campaign`] of the
/// same scenario: same records, same `total_stats` accumulation order.
/// The log is keyed by scenario name, executor and seed; a log whose
/// records disagree with the compiled plan sequence (edited file, seed
/// collision) is rejected with a named error rather than silently merged.
pub fn resume_campaign(
    sc: &Scenario,
    executor: Executor,
    dir: &std::path::Path,
) -> Result<CampaignReport> {
    use anyhow::{bail, Context};
    let plans = sc.compile();
    let colluders = sc.adversary.colluders();
    let path = campaign_log_path(dir, sc, executor);
    let tag = crate::net::socket::round_tag(sc.seed);
    let mut records: Vec<RoundRecord> = Vec::new();
    let mut log = if path.exists() {
        for raw in crate::journal::read_log(&path).context("read campaign log")? {
            if raw.rec_type != RT_CAMPAIGN_ROUND {
                bail!("campaign log {}: unexpected record type 0x{:02x}", path.display(), raw.rec_type);
            }
            if raw.round != tag {
                bail!(
                    "campaign log {}: round tag {:08x} does not match scenario seed (expected {tag:08x})",
                    path.display(),
                    raw.round
                );
            }
            let rec = decode_round_record(&raw.payload)
                .with_context(|| format!("campaign log {}: corrupt round record", path.display()))?;
            match plans.get(records.len()) {
                Some(plan) if plan.round == rec.round => records.push(rec),
                Some(plan) => bail!(
                    "campaign log {}: recorded round {} where the scenario expects round {}",
                    path.display(),
                    rec.round,
                    plan.round
                ),
                None => bail!(
                    "campaign log {}: more rounds recorded than the scenario has",
                    path.display()
                ),
            }
        }
        crate::journal::LogWriter::open_append(&path).context("reopen campaign log")?
    } else {
        crate::journal::LogWriter::create(&path).context("create campaign log")?
    };
    if !records.is_empty() {
        log::info!(
            "campaign {}: resuming at round {} of {} from {}",
            sc.name,
            records.len(),
            plans.len(),
            path.display()
        );
    }
    for plan in plans.iter().skip(records.len()) {
        let models = sc.round_models(plan.round);
        let rec = run_plan(plan, &models, executor, colluders);
        log.append(RT_CAMPAIGN_ROUND, tag, &encode_round_record(&rec))
            .with_context(|| format!("append round {} to campaign log", plan.round))?;
        records.push(rec);
    }
    let mut total_stats = NetStats::new(sc.n);
    for record in &records {
        total_stats.merge(&record.stats);
    }
    Ok(CampaignReport { scenario: sc.name.clone(), seed: sc.seed, executor, records, total_stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::churn::ChurnModel;
    use super::super::scenario::{AdversarySpec, CodecSpec, ThresholdRule, TopologySchedule};
    use crate::protocol::Topology;

    fn scenario(churn: ChurnModel, rounds: usize) -> Scenario {
        Scenario {
            name: "campaign-test".to_string(),
            n: 10,
            dim: 6,
            mask_bits: 32,
            rounds,
            topology: TopologySchedule::Static(Topology::Complete),
            churn,
            adversary: AdversarySpec::Eavesdropper,
            threshold: ThresholdRule::Fixed(4),
            codec: CodecSpec::Dense,
            clip: 4.0,
            seed: 0xCA3F,
        }
    }

    #[test]
    fn churn_free_campaign_is_fully_reliable() {
        let sc = scenario(ChurnModel::None, 4);
        let rep = run_campaign(&sc, Executor::Engine).unwrap();
        assert_eq!(rep.rounds(), 4);
        assert_eq!(rep.reliable_rounds(), 4);
        assert_eq!(rep.aborted_rounds(), 0);
        assert_eq!(rep.theorem1_violations(), 0);
        assert!(rep.total_stats.server_total() > 0);
        // every round's sum is the true V3 sum of that round's models
        for rec in &rep.records {
            let models = sc.round_models(rec.round);
            let mut expect = vec![0u64; sc.dim];
            for &i in &rec.sets.v3 {
                for (a, x) in expect.iter_mut().zip(&models[i]) {
                    *a = a.wrapping_add(*x) & 0xFFFF_FFFF;
                }
            }
            assert_eq!(rec.sum.as_ref().unwrap(), &expect, "round {}", rec.round);
        }
    }

    #[test]
    fn whole_cohort_churn_aborts_not_panics() {
        let script = vec![[(0..10).collect::<Vec<_>>(), vec![], vec![], vec![]]];
        let sc = scenario(ChurnModel::Scripted { rounds: script }, 2);
        let rep = run_campaign(&sc, Executor::Engine).unwrap();
        assert!(rep.records[0].aborted);
        assert!(!rep.records[1].aborted, "round 2 is failure-free and recovers");
        assert_eq!(rep.aborted_rounds(), 1);
    }

    #[test]
    fn every_executor_reports_same_shape() {
        let sc = scenario(ChurnModel::TargetedAdaptive { count: 1, step: 2 }, 2);
        let e = run_campaign(&sc, Executor::Engine).unwrap();
        for alt in Executor::non_reference() {
            let c = run_campaign(&sc, alt).unwrap();
            assert_eq!(c.executor, alt);
            assert_eq!(e.rounds(), c.rounds(), "{}", alt.name());
            for (re, rc) in e.records.iter().zip(&c.records) {
                assert_eq!(re.sum, rc.sum, "{} round {}", alt.name(), re.round);
                assert_eq!(re.sets, rc.sets, "{} round {}", alt.name(), re.round);
                // framed byte counters are transport-specific; the logical
                // accounting must match bit-for-bit
                assert!(
                    re.stats.logical_eq(&rc.stats),
                    "{} round {}: logical stats diverge",
                    alt.name(),
                    re.round
                );
            }
        }
    }

    #[test]
    fn executor_axis_is_complete_and_named() {
        assert_eq!(Executor::ALL.len(), 3);
        let names: Vec<&str> = Executor::ALL.iter().map(|e| e.name()).collect();
        assert_eq!(names, vec!["engine", "event-loop", "wire"]);
        let non_ref: Vec<Executor> = Executor::non_reference().collect();
        assert_eq!(non_ref.len(), Executor::ALL.len() - 1);
        assert!(!non_ref.contains(&Executor::Engine));
    }

    #[test]
    fn sparse_codec_campaign_reports_payload_savings() {
        let dense = scenario(ChurnModel::None, 2);
        let mut sparse = scenario(ChurnModel::None, 2);
        sparse.codec = CodecSpec::RandK { frac: 0.5 };
        let dense_rep = run_campaign(&dense, Executor::Engine).unwrap();
        let sparse_rep = run_campaign(&sparse, Executor::Engine).unwrap();
        assert_eq!(sparse_rep.reliable_rounds(), 2);
        // dim 6 at frac 0.5 → k = 3: payload bytes halve exactly
        assert_eq!(
            sparse_rep.total_stats.masked_payload_bytes * 2,
            dense_rep.total_stats.masked_payload_bytes
        );
        // every executor agrees on the sparse campaign too
        for alt in Executor::non_reference() {
            let c = run_campaign(&sparse, alt).unwrap();
            for (re, rc) in sparse_rep.records.iter().zip(&c.records) {
                assert_eq!(re.sum, rc.sum, "{} round {}", alt.name(), re.round);
                assert!(
                    re.stats.logical_eq(&rc.stats),
                    "{} round {}: logical stats diverge",
                    alt.name(),
                    re.round
                );
            }
        }
    }

    #[test]
    fn round_record_codec_round_trips() {
        let sc = scenario(ChurnModel::TargetedAdaptive { count: 1, step: 2 }, 2);
        let rep = run_campaign(&sc, Executor::Engine).unwrap();
        for rec in &rep.records {
            let decoded = decode_round_record(&encode_round_record(rec)).unwrap();
            assert_eq!(rec, &decoded);
        }
        // the aborted shape (None sum, empty sets) round-trips too
        let ab = RoundRecord::aborted(7, 10);
        assert_eq!(ab, decode_round_record(&encode_round_record(&ab)).unwrap());
    }

    #[test]
    fn resumable_campaign_is_bit_identical_and_resumes_after_truncation() {
        let dir = std::env::temp_dir().join(format!("ccesa-campaign-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let sc = scenario(ChurnModel::TargetedAdaptive { count: 1, step: 2 }, 3);
        let full = run_campaign(&sc, Executor::Engine).unwrap();
        // fresh log: resumable run matches the in-memory runner bit-for-bit
        let first = resume_campaign(&sc, Executor::Engine, &dir).unwrap();
        assert_eq!(full.records, first.records);
        assert_eq!(full.total_stats, first.total_stats);
        // kill the campaign after round 2 of 3 (chop the last record) and
        // resume: only the missing round reruns, and the report still
        // matches the uninterrupted run exactly
        let path = campaign_log_path(&dir, &sc, Executor::Engine);
        crate::journal::truncate_last_records(&path, 1).unwrap();
        let resumed = resume_campaign(&sc, Executor::Engine, &dir).unwrap();
        assert_eq!(full.records, resumed.records);
        assert_eq!(full.total_stats, resumed.total_stats);
        // a completed log replays entirely from disk
        let replayed = resume_campaign(&sc, Executor::Engine, &dir).unwrap();
        assert_eq!(full.records, replayed.records);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn campaign_log_for_a_different_seed_is_rejected() {
        let dir = std::env::temp_dir().join(format!("ccesa-campaign-foreign-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let sc = scenario(ChurnModel::None, 2);
        let path = campaign_log_path(&dir, &sc, Executor::Engine);
        let tag = crate::net::socket::round_tag(sc.seed);
        let mut w = crate::journal::LogWriter::create(&path).unwrap();
        let rec = RoundRecord::aborted(0, sc.n);
        w.append(RT_CAMPAIGN_ROUND, tag ^ 1, &encode_round_record(&rec)).unwrap();
        drop(w);
        let err = resume_campaign(&sc, Executor::Engine, &dir).unwrap_err();
        assert!(err.to_string().contains("round tag"), "unexpected error: {err:#}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn exposed_honest_counts_singletons() {
        let b = |subset: Vec<usize>| Breach { subset, partial_sum: vec![] };
        let breaches = vec![b(vec![0, 1, 2]), b(vec![3, 4]), b(vec![5])];
        // colluders {1, 2, 4}: first breach leaves honest {0} → exposed;
        // second leaves honest {3} → exposed; third leaves honest {5} →
        // exposed (a singleton component is public anyway)
        assert_eq!(exposed_honest(&breaches, &[1, 2, 4]), 3);
        // no colluders: only the singleton component exposes a model
        assert_eq!(exposed_honest(&breaches, &[]), 1);
    }
}
