//! Multi-round campaign runner: drive a compiled [`Scenario`] through any
//! [`Executor`] (sync engine, worker-pool event loop, or the loopback
//! socket wire) and aggregate what happened.
//!
//! The engine driver additionally scores each round's transcript with the
//! Definition-2 eavesdropper attack and checks Theorem 1's predicate
//! against the implementation — a campaign is simultaneously a reliability
//! experiment (§4.3), a privacy experiment (§4.4) and a regression suite.

use super::scenario::{RoundPlan, Scenario};
use crate::coordinator::{run_round_event_loop, CoordRoundResult};
use crate::net::NetStats;
use crate::protocol::adversary::{attack, Breach};
use crate::protocol::engine::run_round;
use crate::protocol::{ClientId, SurvivorSets};
use anyhow::Result;

/// Which execution shape drives the campaign's rounds.
///
/// The legacy thread-per-client `Threaded` executor was deleted with its
/// coordinator once the event loop's equivalence suite had green CI cycles
/// (ROADMAP follow-up): the event loop is now pinned against the engine
/// directly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Executor {
    /// The deterministic synchronous engine (`protocol::engine`).
    Engine,
    /// The worker-pool event-loop coordinator (the scaling shape).
    EventLoop,
    /// The loopback socket transport (`net::socket`) — every message
    /// crosses a real TCP stream as wire frames.
    Wire,
}

impl Executor {
    /// Every executor, in reference-first order.
    pub const ALL: [Executor; 3] = [Executor::Engine, Executor::EventLoop, Executor::Wire];

    /// Every executor except the [`Executor::Engine`] reference — the list
    /// the differential harness and equivalence suites iterate, derived
    /// from [`Executor::ALL`] so a future executor joins them by
    /// construction.
    pub fn non_reference() -> impl Iterator<Item = Executor> {
        Executor::ALL.into_iter().filter(|e| *e != Executor::Engine)
    }

    pub fn name(&self) -> &'static str {
        match self {
            Executor::Engine => "engine",
            Executor::EventLoop => "event-loop",
            Executor::Wire => "wire",
        }
    }
}

/// Everything recorded about one campaign round.
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub round: usize,
    /// The server aborted before finalize (|V_k| < t at some step).
    pub aborted: bool,
    pub reliable: bool,
    pub sum: Option<Vec<u64>>,
    pub sets: SurvivorSets,
    pub stats: NetStats,
    /// Engine executor only: whether Theorem 1's predicate agreed with the
    /// implementation's reliability outcome.
    pub theorem1_agrees: Option<bool>,
    /// Engine executor only: whether the unmasked aggregate equals the
    /// independently computed plain sum (`true_sum_v3`). A `Some(false)`
    /// means mask cancellation itself is broken — e.g. a diverging GF/mask
    /// kernel backend — and the differential harness reports it as a named
    /// `sum_vs_truth` mismatch rather than a downstream flake.
    pub sum_matches_truth: Option<bool>,
    /// Engine executor only: partial-sum breaches the Definition-2
    /// eavesdropper extracted from this round's transcript.
    pub breaches: usize,
    /// Engine executor only: honest clients whose individual model the
    /// scenario's colluding set reads off a breached partial sum.
    pub exposed_honest: usize,
}

impl RoundRecord {
    fn aborted(round: usize, n: usize) -> RoundRecord {
        RoundRecord {
            round,
            aborted: true,
            reliable: false,
            sum: None,
            sets: SurvivorSets::default(),
            stats: NetStats::new(n),
            theorem1_agrees: None,
            sum_matches_truth: None,
            breaches: 0,
            exposed_honest: 0,
        }
    }
}

/// Aggregated campaign outcome.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    pub scenario: String,
    pub seed: u64,
    pub executor: Executor,
    pub records: Vec<RoundRecord>,
    pub total_stats: NetStats,
}

impl CampaignReport {
    pub fn rounds(&self) -> usize {
        self.records.len()
    }
    pub fn reliable_rounds(&self) -> usize {
        self.records.iter().filter(|r| r.reliable).count()
    }
    pub fn aborted_rounds(&self) -> usize {
        self.records.iter().filter(|r| r.aborted).count()
    }
    pub fn breached_rounds(&self) -> usize {
        self.records.iter().filter(|r| r.breaches > 0).count()
    }
    pub fn exposed_honest_total(&self) -> usize {
        self.records.iter().map(|r| r.exposed_honest).sum()
    }
    /// Rounds where the implementation disagreed with Theorem 1 — any
    /// nonzero value is a bug.
    pub fn theorem1_violations(&self) -> usize {
        self.records
            .iter()
            .filter(|r| r.theorem1_agrees == Some(false))
            .count()
    }
    pub fn one_line(&self) -> String {
        format!(
            "{}: {} rounds, {} reliable, {} aborted, {} breached, {} exposed, {:.1} KiB through server",
            self.scenario,
            self.rounds(),
            self.reliable_rounds(),
            self.aborted_rounds(),
            self.breached_rounds(),
            self.exposed_honest_total(),
            self.total_stats.server_total() as f64 / 1024.0,
        )
    }
}

/// How many breaches expose exactly one honest client to the colluders.
fn exposed_honest(breaches: &[Breach], colluders: &[ClientId]) -> usize {
    breaches
        .iter()
        .filter(|b| b.subset.iter().filter(|i| !colluders.contains(i)).count() == 1)
        .count()
}

/// Run one pre-compiled round plan through the chosen executor.
pub fn run_plan(
    plan: &RoundPlan,
    models: &[Vec<u64>],
    executor: Executor,
    colluders: &[ClientId],
) -> RoundRecord {
    let coord_record = |r: Result<CoordRoundResult>| match r {
        Ok(r) => RoundRecord {
            round: plan.round,
            aborted: false,
            reliable: r.reliable,
            sum: r.sum,
            sets: r.sets,
            stats: r.stats,
            theorem1_agrees: None,
            sum_matches_truth: None,
            breaches: 0,
            exposed_honest: 0,
        },
        Err(_) => RoundRecord::aborted(plan.round, plan.cfg.n),
    };
    match executor {
        Executor::Engine => match run_round(&plan.cfg, models) {
            Ok(r) => {
                let breaches = attack(&r.transcript);
                let sum_matches_truth = r.sum.as_deref().map(|s| s == &r.true_sum_v3[..]);
                RoundRecord {
                    round: plan.round,
                    aborted: false,
                    reliable: r.reliable,
                    sum: r.sum,
                    sets: r.sets,
                    stats: r.stats,
                    theorem1_agrees: Some(r.theorem1_holds == r.reliable),
                    sum_matches_truth,
                    breaches: breaches.len(),
                    exposed_honest: exposed_honest(&breaches, colluders),
                }
            }
            Err(_) => RoundRecord::aborted(plan.round, plan.cfg.n),
        },
        Executor::EventLoop => coord_record(run_round_event_loop(&plan.cfg, models)),
        Executor::Wire => coord_record(crate::net::socket::run_round_wire(&plan.cfg, models)),
    }
}

/// Run a full scenario campaign through the chosen executor.
///
/// §Perf: compiled plans are rng-free data, so rounds are independent —
/// each round's per-client work (model materialization, the full protocol
/// round, transcript scoring) runs on a `crate::par` worker. Records are
/// merged back in round order, so the report (including the `NetStats`
/// accumulation order) is bit-identical to the serial runner's.
pub fn run_campaign(sc: &Scenario, executor: Executor) -> Result<CampaignReport> {
    let plans = sc.compile();
    let colluders = sc.adversary.colluders();
    let workers = match executor {
        // Rounds whose vectors are too short to shard internally (the
        // simulation regime — exactly the rounds step2/finalize run
        // serially) parallelize across rounds here. Rounds that do shard
        // internally run one at a time: parallelizing both levels would
        // oversubscribe CPU ~threads² and hold several rounds' full model
        // sets in memory at once.
        Executor::Engine if crate::par::threads_for_len(sc.dim) == 1 => crate::par::threads(),
        Executor::Engine => 1,
        // the event loop parallelizes internally across pool workers;
        // running its rounds concurrently on top would multiply that —
        // and the wire executor additionally owns real sockets per round
        Executor::EventLoop | Executor::Wire => 1,
    };
    let records = crate::par::map_indexed(plans.len(), workers, |i| {
        let plan = &plans[i];
        let models = sc.round_models(plan.round);
        run_plan(plan, &models, executor, colluders)
    });
    let mut total_stats = NetStats::new(sc.n);
    for record in &records {
        total_stats.merge(&record.stats);
    }
    Ok(CampaignReport { scenario: sc.name.clone(), seed: sc.seed, executor, records, total_stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::churn::ChurnModel;
    use super::super::scenario::{AdversarySpec, CodecSpec, ThresholdRule, TopologySchedule};
    use crate::protocol::Topology;

    fn scenario(churn: ChurnModel, rounds: usize) -> Scenario {
        Scenario {
            name: "campaign-test".to_string(),
            n: 10,
            dim: 6,
            mask_bits: 32,
            rounds,
            topology: TopologySchedule::Static(Topology::Complete),
            churn,
            adversary: AdversarySpec::Eavesdropper,
            threshold: ThresholdRule::Fixed(4),
            codec: CodecSpec::Dense,
            clip: 4.0,
            seed: 0xCA3F,
        }
    }

    #[test]
    fn churn_free_campaign_is_fully_reliable() {
        let sc = scenario(ChurnModel::None, 4);
        let rep = run_campaign(&sc, Executor::Engine).unwrap();
        assert_eq!(rep.rounds(), 4);
        assert_eq!(rep.reliable_rounds(), 4);
        assert_eq!(rep.aborted_rounds(), 0);
        assert_eq!(rep.theorem1_violations(), 0);
        assert!(rep.total_stats.server_total() > 0);
        // every round's sum is the true V3 sum of that round's models
        for rec in &rep.records {
            let models = sc.round_models(rec.round);
            let mut expect = vec![0u64; sc.dim];
            for &i in &rec.sets.v3 {
                for (a, x) in expect.iter_mut().zip(&models[i]) {
                    *a = a.wrapping_add(*x) & 0xFFFF_FFFF;
                }
            }
            assert_eq!(rec.sum.as_ref().unwrap(), &expect, "round {}", rec.round);
        }
    }

    #[test]
    fn whole_cohort_churn_aborts_not_panics() {
        let script = vec![[(0..10).collect::<Vec<_>>(), vec![], vec![], vec![]]];
        let sc = scenario(ChurnModel::Scripted { rounds: script }, 2);
        let rep = run_campaign(&sc, Executor::Engine).unwrap();
        assert!(rep.records[0].aborted);
        assert!(!rep.records[1].aborted, "round 2 is failure-free and recovers");
        assert_eq!(rep.aborted_rounds(), 1);
    }

    #[test]
    fn every_executor_reports_same_shape() {
        let sc = scenario(ChurnModel::TargetedAdaptive { count: 1, step: 2 }, 2);
        let e = run_campaign(&sc, Executor::Engine).unwrap();
        for alt in Executor::non_reference() {
            let c = run_campaign(&sc, alt).unwrap();
            assert_eq!(c.executor, alt);
            assert_eq!(e.rounds(), c.rounds(), "{}", alt.name());
            for (re, rc) in e.records.iter().zip(&c.records) {
                assert_eq!(re.sum, rc.sum, "{} round {}", alt.name(), re.round);
                assert_eq!(re.sets, rc.sets, "{} round {}", alt.name(), re.round);
                // framed byte counters are transport-specific; the logical
                // accounting must match bit-for-bit
                assert!(
                    re.stats.logical_eq(&rc.stats),
                    "{} round {}: logical stats diverge",
                    alt.name(),
                    re.round
                );
            }
        }
    }

    #[test]
    fn executor_axis_is_complete_and_named() {
        assert_eq!(Executor::ALL.len(), 3);
        let names: Vec<&str> = Executor::ALL.iter().map(|e| e.name()).collect();
        assert_eq!(names, vec!["engine", "event-loop", "wire"]);
        let non_ref: Vec<Executor> = Executor::non_reference().collect();
        assert_eq!(non_ref.len(), Executor::ALL.len() - 1);
        assert!(!non_ref.contains(&Executor::Engine));
    }

    #[test]
    fn sparse_codec_campaign_reports_payload_savings() {
        let dense = scenario(ChurnModel::None, 2);
        let mut sparse = scenario(ChurnModel::None, 2);
        sparse.codec = CodecSpec::RandK { frac: 0.5 };
        let dense_rep = run_campaign(&dense, Executor::Engine).unwrap();
        let sparse_rep = run_campaign(&sparse, Executor::Engine).unwrap();
        assert_eq!(sparse_rep.reliable_rounds(), 2);
        // dim 6 at frac 0.5 → k = 3: payload bytes halve exactly
        assert_eq!(
            sparse_rep.total_stats.masked_payload_bytes * 2,
            dense_rep.total_stats.masked_payload_bytes
        );
        // every executor agrees on the sparse campaign too
        for alt in Executor::non_reference() {
            let c = run_campaign(&sparse, alt).unwrap();
            for (re, rc) in sparse_rep.records.iter().zip(&c.records) {
                assert_eq!(re.sum, rc.sum, "{} round {}", alt.name(), re.round);
                assert!(
                    re.stats.logical_eq(&rc.stats),
                    "{} round {}: logical stats diverge",
                    alt.name(),
                    re.round
                );
            }
        }
    }

    #[test]
    fn exposed_honest_counts_singletons() {
        let b = |subset: Vec<usize>| Breach { subset, partial_sum: vec![] };
        let breaches = vec![b(vec![0, 1, 2]), b(vec![3, 4]), b(vec![5])];
        // colluders {1, 2, 4}: first breach leaves honest {0} → exposed;
        // second leaves honest {3} → exposed; third leaves honest {5} →
        // exposed (a singleton component is public anyway)
        assert_eq!(exposed_honest(&breaches, &[1, 2, 4]), 3);
        // no colluders: only the singleton component exposes a model
        assert_eq!(exposed_honest(&breaches, &[]), 1);
    }
}
