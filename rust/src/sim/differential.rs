//! Differential harness: every scenario must produce bit-identical results
//! through every executor — the sync engine (reference) and the
//! worker-pool event loop.
//!
//! The coordinator module's contract ("bit-identical to the sync engine for
//! the same seed" under rng-free dropout) was previously pinned by
//! hand-written cases; this harness turns it into a property checked over
//! randomized scenario campaigns — mixed topology schedules, churn models,
//! adversary sets and payload codecs — with a shrinker that minimizes any
//! failing scenario to a small, quotable reproduction seed. Each
//! non-reference executor is diffed against the engine independently, so a
//! mismatch names the shape that diverged.
//!
//! Every scenario kind enters through **one** dispatcher:
//! [`run_differential`] over a [`DiffSpec`] — flat, clocked
//! (virtual-clock timeouts), warm-session, hierarchical, or
//! crash-recovery. New differential axes register as a `DiffSpec` variant,
//! not as another parallel `diff_*` entry point.

use super::campaign::{run_plan, Executor, RoundRecord};
use super::churn::ChurnModel;
use super::clock::{clock_seed, random_clocked_scenario, run_clocked_plan, ClockedScenario};
use super::scenario::{random_scenario, AdversarySpec, CodecSpec, Scenario, TopologySchedule};
use crate::protocol::Topology;
use std::sync::Arc;

/// A divergence between the engine and one executor on one round.
#[derive(Debug, Clone)]
pub struct Mismatch {
    pub scenario: String,
    pub seed: u64,
    pub round: usize,
    /// The non-reference executor that diverged from the engine.
    pub executor: Executor,
    pub field: &'static str,
    pub detail: String,
}

/// One confirmed failure: the mismatch observed on the *minimized*
/// scenario, plus that scenario itself for replay.
#[derive(Debug, Clone)]
pub struct Failure {
    pub mismatch: Mismatch,
    pub shrunk: Scenario,
}

/// Outcome of a randomized differential run.
#[derive(Debug, Clone, Default)]
pub struct DifferentialReport {
    pub scenarios_run: usize,
    pub rounds_run: usize,
    pub failures: Vec<Failure>,
}

impl DifferentialReport {
    pub fn ok(&self) -> bool {
        self.failures.is_empty()
    }
}

/// One differential work item. All five scenario kinds dispatch through
/// [`run_differential`]; the per-kind comparison logic is private to this
/// module.
#[derive(Debug, Clone, Copy)]
pub enum DiffSpec<'a> {
    /// A flat multi-round scenario through every executor.
    Flat(&'a Scenario),
    /// A flat scenario under a virtual clock and timeout policy: the
    /// clocked event loop vs the sync engine re-run with the observed
    /// timeout drops merged into the churn schedule.
    Clocked(&'a ClockedScenario),
    /// A warm-session campaign (cold establish + warm rounds).
    Session(&'a super::session::SessionScenario),
    /// A hierarchical scenario: engine self-check, executor parity, and
    /// the flat-engine oracle.
    Hier(&'a super::hier::HierScenario),
    /// A scenario killed at every crash point, finished on the
    /// journal-recovered server; journals are written under `journal_dir`.
    Crash { scenario: &'a Scenario, journal_dir: &'a std::path::Path },
}

/// Run one differential work item; the first divergence from the reference
/// wins. `None` means the spec's bit-identical guarantee held.
pub fn run_differential(spec: &DiffSpec<'_>) -> Option<Mismatch> {
    match spec {
        DiffSpec::Flat(sc) => flat_mismatch(sc),
        DiffSpec::Clocked(csc) => clocked_mismatch(csc),
        DiffSpec::Session(sc) => session_mismatch(sc),
        DiffSpec::Hier(sc) => hier_mismatch(sc).0,
        DiffSpec::Crash { scenario, journal_dir } => crash_mismatch(scenario, journal_dir),
    }
}

fn diff_records(e: &RoundRecord, c: &RoundRecord, who: &str) -> Option<(&'static str, String)> {
    if e.aborted != c.aborted {
        return Some((
            "abort",
            format!("engine aborted={}, {who} aborted={}", e.aborted, c.aborted),
        ));
    }
    if e.aborted {
        return None; // both aborted: nothing further to compare
    }
    if e.reliable != c.reliable {
        return Some((
            "reliable",
            format!("engine reliable={}, {who} reliable={}", e.reliable, c.reliable),
        ));
    }
    if e.sets != c.sets {
        return Some(("survivor_sets", format!("engine {:?} vs {who} {:?}", e.sets, c.sets)));
    }
    if e.sum != c.sum {
        return Some(("sum", format!("engine {:?} vs {who} {:?}", e.sum, c.sum)));
    }
    // logical accounting only: the wire executor legitimately carries
    // nonzero framed-byte counters that in-process executors cannot
    if !e.stats.logical_eq(&c.stats) {
        return Some(("net_stats", format!("engine {:?} vs {who} {:?}", e.stats, c.stats)));
    }
    None
}

/// Run one scenario campaign under every executor round by round; the first
/// divergence from the engine (sums, survivor sets, NetStats, or abort
/// behavior) wins.
fn flat_mismatch(sc: &Scenario) -> Option<Mismatch> {
    let plans = sc.compile();
    let colluders = sc.adversary.colluders();
    for plan in &plans {
        let models = sc.round_models(plan.round);
        let e = run_plan(plan, &models, Executor::Engine, colluders);
        // The reference itself must unmask to the independently computed
        // plain sum: a broken mask-cancellation path (e.g. a diverging
        // GF/mask kernel backend) corrupts every executor identically, so
        // only this check can name it. Running the harness once under
        // `CCESA_KERNEL=scalar` and once under the default backend turns
        // any backend divergence into this mismatch.
        if e.sum_matches_truth == Some(false) {
            return Some(Mismatch {
                scenario: sc.name.clone(),
                seed: sc.seed,
                round: plan.round,
                executor: Executor::Engine,
                field: "sum_vs_truth",
                detail: "engine aggregate != plain sum of V3 models".to_string(),
            });
        }
        for alt in Executor::non_reference() {
            let c = run_plan(plan, &models, alt, colluders);
            if let Some((field, detail)) = diff_records(&e, &c, alt.name()) {
                return Some(Mismatch {
                    scenario: sc.name.clone(),
                    seed: sc.seed,
                    round: plan.round,
                    executor: alt,
                    field,
                    detail,
                });
            }
        }
    }
    None
}

/// Warm-round differential: run the session scenario's campaign (one cold
/// establishing round + N warm rounds) through every executor and require
/// bit-identical sums, survivor sets, abort behavior and logical
/// [`crate::net::NetStats`] — including the session-era coordinate-map and
/// re-key counters — on every warm round. The engine executor is the
/// reference, exactly as in [`DiffSpec::Flat`].
fn session_mismatch(sc: &super::session::SessionScenario) -> Option<Mismatch> {
    use super::session::{run_session_campaign, SessionReport};
    let run = |executor: Executor| -> Result<SessionReport, Mismatch> {
        run_session_campaign(sc, executor).map_err(|e| Mismatch {
            scenario: sc.name.clone(),
            seed: sc.seed,
            round: 0,
            executor,
            field: "campaign",
            detail: format!("session campaign failed to run: {e:#}"),
        })
    };
    let e = match run(Executor::Engine) {
        Ok(rep) => rep,
        Err(m) => return Some(m),
    };
    for alt in Executor::non_reference() {
        let c = match run(alt) {
            Ok(rep) => rep,
            Err(m) => return Some(m),
        };
        for (re, rc) in e.warm.iter().zip(&c.warm) {
            let mismatch = |field: &'static str, detail: String| Mismatch {
                scenario: sc.name.clone(),
                seed: sc.seed,
                round: re.round as usize,
                executor: alt,
                field,
                detail,
            };
            if re.aborted != rc.aborted {
                return Some(mismatch(
                    "abort",
                    format!("engine aborted={}, {} aborted={}", re.aborted, alt.name(), rc.aborted),
                ));
            }
            if re.aborted {
                continue;
            }
            if re.reliable != rc.reliable {
                return Some(mismatch(
                    "reliable",
                    format!(
                        "engine reliable={}, {} reliable={}",
                        re.reliable,
                        alt.name(),
                        rc.reliable
                    ),
                ));
            }
            if re.sets != rc.sets {
                return Some(mismatch(
                    "survivor_sets",
                    format!("engine {:?} vs {} {:?}", re.sets, alt.name(), rc.sets),
                ));
            }
            if re.sum != rc.sum {
                return Some(mismatch(
                    "sum",
                    format!("engine {:?} vs {} {:?}", re.sum, alt.name(), rc.sum),
                ));
            }
            if !re.stats.logical_eq(&rc.stats) {
                return Some(mismatch(
                    "net_stats",
                    format!("engine {:?} vs {} {:?}", re.stats, alt.name(), rc.stats),
                ));
            }
        }
    }
    None
}

/// Hierarchical differential: one [`super::hier::HierScenario`] through
/// three lenses.
///
/// 1. **Engine self-check** — the hierarchical engine run's secure sum must
///    equal the independently computed plaintext truth over `global_v3`
///    whenever the round is reliable (the hier analogue of
///    [`DiffSpec::Flat`]'s `sum_vs_truth`).
/// 2. **Executor parity** — the hierarchical event-loop run must match the
///    hierarchical engine run bit-for-bit: sum, covered clients, per-level
///    survivor sets, reliability, and logical per-level `NetStats`.
/// 3. **Flat oracle** — a *flat* engine round over the same population,
///    master seed (→ identical payload plan), codec and global dropout
///    schedule on a complete graph. Whenever both rounds complete and
///    cover exactly the same clients (`flat V3 == hier global_v3`), the two
///    sums must be equal — hierarchy must not change the answer, only the
///    topology. (Differing coverage — shard-level withdrawals, dropped
///    aggregators — legitimately skips the comparison; `run_hier_differential`
///    counts how often it fired.)
fn hier_mismatch(sc: &super::hier::HierScenario) -> (Option<Mismatch>, bool) {
    use crate::hier::HierRunner;
    let mismatch = |executor: Executor, field: &'static str, detail: String| Mismatch {
        scenario: sc.name.clone(),
        seed: sc.seed,
        round: 0,
        executor,
        field,
        detail,
    };
    let cfg = match sc.config() {
        Ok(cfg) => cfg,
        Err(e) => {
            return (
                Some(mismatch(Executor::Engine, "config", format!("scenario invalid: {e:#}"))),
                false,
            )
        }
    };
    let models = sc.models();
    let run = |executor: Executor| HierRunner::new(sc.options(executor)).run(&cfg, &models);
    let e = match run(Executor::Engine) {
        Ok(r) => r,
        Err(err) => {
            return (
                Some(mismatch(Executor::Engine, "campaign", format!("hier run failed: {err:#}"))),
                false,
            )
        }
    };
    if e.reliable && e.sum != e.true_sum {
        return (
            Some(mismatch(
                Executor::Engine,
                "hier_sum_vs_truth",
                "hierarchical aggregate != plain sum over global V3".to_string(),
            )),
            false,
        );
    }
    let c = match run(Executor::EventLoop) {
        Ok(r) => r,
        Err(err) => {
            return (
                Some(mismatch(
                    Executor::EventLoop,
                    "campaign",
                    format!("hier run failed: {err:#}"),
                )),
                false,
            )
        }
    };
    let el = Executor::EventLoop;
    if e.sum.is_none() != c.sum.is_none() {
        return (
            Some(mismatch(
                el,
                "abort",
                format!(
                    "engine completed={}, event-loop completed={}",
                    e.sum.is_some(),
                    c.sum.is_some()
                ),
            )),
            false,
        );
    }
    if e.reliable != c.reliable {
        return (
            Some(mismatch(
                el,
                "reliable",
                format!("engine reliable={}, event-loop reliable={}", e.reliable, c.reliable),
            )),
            false,
        );
    }
    if e.global_v3 != c.global_v3 {
        return (
            Some(mismatch(
                el,
                "global_v3",
                format!("engine {:?} vs event-loop {:?}", e.global_v3, c.global_v3),
            )),
            false,
        );
    }
    if e.sum != c.sum {
        return (Some(mismatch(el, "sum", format!("engine {:?} vs event-loop {:?}", e.sum, c.sum))), false);
    }
    for (s, (re, rc)) in e.shard_reports.iter().zip(&c.shard_reports).enumerate() {
        if re.sets != rc.sets {
            return (
                Some(mismatch(
                    el,
                    "shard_sets",
                    format!("shard {s}: engine {:?} vs event-loop {:?}", re.sets, rc.sets),
                )),
                false,
            );
        }
    }
    match (&e.root, &c.root) {
        (Some(re), Some(rc)) if re.sets != rc.sets => {
            return (
                Some(mismatch(
                    el,
                    "root_sets",
                    format!("engine {:?} vs event-loop {:?}", re.sets, rc.sets),
                )),
                false,
            )
        }
        _ => {}
    }
    if !e.stats.intra.logical_eq(&c.stats.intra) || !e.stats.root.logical_eq(&c.stats.root) {
        return (
            Some(mismatch(
                el,
                "net_stats",
                "per-level logical NetStats diverged between engine and event loop".to_string(),
            )),
            false,
        );
    }

    // Flat-engine oracle: same clients, same master seed (hence the same
    // payload plan), same global dropout — on one complete graph.
    let flat_cfg = match crate::protocol::ProtocolConfig::builder()
        .clients(sc.n)
        .threshold(sc.t)
        .model_dim(sc.dim)
        .mask_bits(sc.mask_bits)
        .topology(Topology::Complete)
        .codec(sc.codec.resolve(sc.dim))
        .dropout(crate::protocol::dropout::DropoutModel::Targeted {
            per_step: match sc.dropout_schedule() {
                Ok(p) => p,
                Err(err) => {
                    return (
                        Some(mismatch(Executor::Engine, "config", format!("{err:#}"))),
                        false,
                    )
                }
            },
        })
        .seed(sc.seed)
        .build()
    {
        Ok(cfg) => cfg,
        Err(err) => {
            return (Some(mismatch(Executor::Engine, "config", format!("oracle config: {err:#}"))), false)
        }
    };
    let flat = match crate::protocol::engine::run_round(&flat_cfg, &models) {
        Ok(r) => r,
        Err(err) => {
            return (
                Some(mismatch(Executor::Engine, "campaign", format!("flat oracle failed: {err:#}"))),
                false,
            )
        }
    };
    let comparable = e.sum.is_some() && flat.sum.is_some() && flat.sets.v3 == e.global_v3;
    if comparable && e.sum != flat.sum {
        return (
            Some(mismatch(
                Executor::Engine,
                "flat_oracle_sum",
                format!(
                    "hier sum {:?} != flat-engine sum {:?} over identical V3",
                    e.sum, flat.sum
                ),
            )),
            true,
        );
    }
    (None, comparable)
}

/// Generate `count` random hierarchical scenarios from `base_seed` and
/// differential-test each. `oracle_compared` counts the scenarios where the
/// flat-oracle sum comparison actually fired (both rounds completed with
/// identical coverage) — callers assert it stays a healthy fraction so the
/// oracle can't silently rot into vacuous truth.
pub fn run_hier_differential(base_seed: u64, count: usize) -> HierDifferentialReport {
    let mut report = HierDifferentialReport::default();
    for i in 0..count {
        let sc = super::hier::random_hier_scenario(base_seed.wrapping_add(i as u64));
        report.scenarios_run += 1;
        let (mismatch, compared) = hier_mismatch(&sc);
        report.oracle_compared += usize::from(compared);
        if let Some(m) = mismatch {
            report.failures.push(m);
        }
    }
    report
}

/// Outcome of a randomized hierarchical differential run.
#[derive(Debug, Clone, Default)]
pub struct HierDifferentialReport {
    pub scenarios_run: usize,
    /// Scenarios where the flat-oracle exact-sum comparison fired.
    pub oracle_compared: usize,
    pub failures: Vec<Mismatch>,
}

impl HierDifferentialReport {
    pub fn ok(&self) -> bool {
        self.failures.is_empty()
    }
}

/// Crash-recovery differential: every round of the scenario, killed at
/// every [`crate::sim::crash::CrashPoint`], must finish — on the
/// journal-recovered server — bit-identically to the uninterrupted engine
/// (or abort exactly when the engine aborts). Journals are written under
/// `dir`. The first divergence wins; its `detail` names the crash point.
fn crash_mismatch(sc: &Scenario, dir: &std::path::Path) -> Option<Mismatch> {
    use super::crash::{crash_record, CrashPoint};
    let plans = sc.compile();
    let colluders = sc.adversary.colluders();
    for plan in &plans {
        let models = sc.round_models(plan.round);
        let e = run_plan(plan, &models, Executor::Engine, colluders);
        for point in CrashPoint::ALL {
            let round_dir = dir.join(format!("r{}-{}", plan.round, point.name()));
            let c = crash_record(&plan.cfg, &models, &round_dir, point, plan.round);
            let who = format!("crash@{}", point.name());
            if let Some((field, detail)) = diff_records(&e, &c, &who) {
                return Some(Mismatch {
                    scenario: sc.name.clone(),
                    seed: sc.seed,
                    round: plan.round,
                    // the crash harness drives the event-loop shape
                    executor: Executor::EventLoop,
                    field,
                    detail: format!("[{who}] {detail}"),
                });
            }
        }
    }
    None
}

/// Clocked differential: every round of the scenario runs through the
/// clocked event loop, whose observed timeout classification is then
/// merged into the churn schedule of a sync-engine reference run
/// ([`run_clocked_plan`]). The two must agree bit-for-bit on survivor
/// sets, sums, reliability, abort behavior and logical
/// [`crate::net::NetStats`] *including the timeout-dropout counters* —
/// the literal statement that a timeout-dropped client behaves exactly
/// like a churned client.
fn clocked_mismatch(csc: &ClockedScenario) -> Option<Mismatch> {
    let sc = &csc.base;
    let plans = sc.compile();
    let colluders = sc.adversary.colluders();
    for plan in &plans {
        let models = sc.round_models(plan.round);
        let sched = Arc::new(csc.schedule_for(plan.round));
        let out = run_clocked_plan(plan, &models, &sched, &csc.policy, colluders);
        if out.engine.sum_matches_truth == Some(false) {
            return Some(Mismatch {
                scenario: sc.name.clone(),
                seed: sc.seed,
                round: plan.round,
                executor: Executor::Engine,
                field: "sum_vs_truth",
                detail: "engine aggregate != plain sum of V3 models (timeout drops merged)"
                    .to_string(),
            });
        }
        if let Some((field, detail)) =
            diff_records(&out.engine, &out.clocked, "clocked event-loop")
        {
            return Some(Mismatch {
                scenario: sc.name.clone(),
                seed: sc.seed,
                round: plan.round,
                executor: Executor::EventLoop,
                field,
                detail: format!(
                    "[clock seed {:#x}, drops {:?}] {detail}",
                    clock_seed(sc.seed, plan.round),
                    out.timeline.dropped
                ),
            });
        }
    }
    None
}

/// Generate `count` random clocked scenarios from `base_seed` and
/// differential-test each. There is no clocked shrinker yet (a ROADMAP
/// follow-up), so a failure reports the *unshrunk* base scenario.
pub fn run_clocked_differential(base_seed: u64, count: usize) -> DifferentialReport {
    let mut report = DifferentialReport::default();
    for i in 0..count {
        let csc = random_clocked_scenario(base_seed.wrapping_add(i as u64));
        report.scenarios_run += 1;
        report.rounds_run += csc.base.rounds;
        if let Some(mismatch) = run_differential(&DiffSpec::Clocked(&csc)) {
            report.failures.push(Failure { mismatch, shrunk: csc.base.clone() });
        }
    }
    report
}

/// Keep a scenario structurally valid while its knobs shrink.
fn clamp_to_n(sc: &mut Scenario) {
    let n = sc.n;
    let fix = |t: &mut Topology| {
        if let Topology::Harary { k } = t {
            *k = (*k).min(n.saturating_sub(2)).max(1);
        }
    };
    match &mut sc.topology {
        TopologySchedule::Static(t) => fix(t),
        TopologySchedule::Rotating(ts) => ts.iter_mut().for_each(fix),
        TopologySchedule::ErRamp { .. } => {}
    }
    if let AdversarySpec::Colluding(ids) = &mut sc.adversary {
        ids.retain(|&i| i < n);
    }
}

/// Candidate simplifications, most aggressive first.
fn candidates(sc: &Scenario, failing_round: usize) -> Vec<Scenario> {
    let mut out = Vec::new();
    let mut push = |mut c: Scenario| {
        clamp_to_n(&mut c);
        out.push(c);
    };
    // truncate to the failing prefix, then to a single round
    if failing_round + 1 < sc.rounds {
        push(Scenario { rounds: failing_round + 1, ..sc.clone() });
    }
    if sc.rounds > 1 {
        push(Scenario { rounds: 1, ..sc.clone() });
    }
    // shrink the population
    if sc.n / 2 >= 4 {
        push(Scenario { n: sc.n / 2, ..sc.clone() });
    }
    if sc.n > 4 {
        push(Scenario { n: sc.n - 1, ..sc.clone() });
    }
    // trivialize the payload
    if sc.dim > 1 {
        push(Scenario { dim: 1, ..sc.clone() });
    }
    // fall back to the dense reference codec
    if !matches!(sc.codec, CodecSpec::Dense) {
        push(Scenario { codec: CodecSpec::Dense, ..sc.clone() });
    }
    // remove stochastic structure
    if !matches!(sc.churn, ChurnModel::None) {
        push(Scenario { churn: ChurnModel::None, ..sc.clone() });
    }
    if !matches!(sc.adversary, AdversarySpec::Eavesdropper) {
        push(Scenario { adversary: AdversarySpec::Eavesdropper, ..sc.clone() });
    }
    if !matches!(sc.topology, TopologySchedule::Static(Topology::Complete)) {
        push(Scenario {
            topology: TopologySchedule::Static(Topology::Complete),
            ..sc.clone()
        });
    }
    out
}

/// Minimize a failing scenario: greedily keep any simplification that still
/// reproduces a mismatch, until none applies. Returns the input unchanged
/// if it does not fail to begin with.
pub fn shrink(sc: &Scenario) -> Scenario {
    match flat_mismatch(sc) {
        Some(mismatch) => shrink_from(sc, mismatch).0,
        None => sc.clone(),
    }
}

/// Shrink loop for a scenario already known to fail with `mismatch` — keeps
/// the witnessed mismatch alongside the minimized scenario so callers never
/// re-run the differential just to recover it.
fn shrink_from(sc: &Scenario, mut mismatch: Mismatch) -> (Scenario, Mismatch) {
    let mut current = sc.clone();
    loop {
        let mut progressed = false;
        for cand in candidates(&current, mismatch.round) {
            if let Some(m) = flat_mismatch(&cand) {
                current = cand;
                mismatch = m;
                progressed = true;
                break;
            }
        }
        if !progressed {
            current.name = format!("{} (shrunk)", sc.name);
            mismatch.scenario = current.name.clone();
            return (current, mismatch);
        }
    }
}

/// Generate `count` random flat scenarios from `base_seed` and
/// differential-test each; failures are shrunk before reporting.
pub fn run_differential_batch(base_seed: u64, count: usize) -> DifferentialReport {
    let mut report = DifferentialReport::default();
    for i in 0..count {
        let sc = random_scenario(base_seed.wrapping_add(i as u64));
        report.scenarios_run += 1;
        report.rounds_run += sc.rounds;
        if let Some(first) = flat_mismatch(&sc) {
            let (shrunk, mismatch) = shrink_from(&sc, first);
            report.failures.push(Failure { mismatch, shrunk });
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::scenario::ThresholdRule;

    fn small(seed: u64, rounds: usize) -> Scenario {
        Scenario {
            name: format!("diff-test-{seed}"),
            n: 8,
            dim: 3,
            mask_bits: 32,
            rounds,
            topology: TopologySchedule::Static(Topology::ErdosRenyi { p: 0.8 }),
            churn: ChurnModel::Iid { q: 0.05 },
            adversary: AdversarySpec::Eavesdropper,
            threshold: ThresholdRule::Fixed(3),
            codec: CodecSpec::Dense,
            clip: 4.0,
            seed,
        }
    }

    #[test]
    fn healthy_sparse_scenarios_have_no_mismatch() {
        for (seed, codec) in [
            (11u64, CodecSpec::TopK { frac: 0.5 }),
            (12, CodecSpec::RandK { frac: 0.5 }),
        ] {
            let sc = Scenario { codec, ..small(seed, 2) };
            assert!(
                run_differential(&DiffSpec::Flat(&sc)).is_none(),
                "seed={seed} codec={}",
                codec.name()
            );
        }
    }

    #[test]
    fn healthy_scenarios_have_no_mismatch() {
        for seed in 0..5 {
            let sc = small(seed, 2);
            assert!(run_differential(&DiffSpec::Flat(&sc)).is_none(), "seed={seed}");
        }
    }

    #[test]
    fn shrink_of_passing_scenario_is_identity() {
        let sc = small(1, 3);
        let shrunk = shrink(&sc);
        assert_eq!(shrunk.rounds, sc.rounds);
        assert_eq!(shrunk.n, sc.n);
    }

    #[test]
    fn candidates_stay_structurally_valid() {
        let mut sc = small(2, 3);
        sc.topology = TopologySchedule::Static(Topology::Harary { k: 6 });
        sc.adversary = AdversarySpec::Colluding(vec![0, 7]);
        for cand in candidates(&sc, 1) {
            if let TopologySchedule::Static(Topology::Harary { k }) = &cand.topology {
                assert!(*k < cand.n, "harary k={k} vs n={}", cand.n);
            }
            if let AdversarySpec::Colluding(ids) = &cand.adversary {
                assert!(ids.iter().all(|&i| i < cand.n));
            }
            // every candidate must still compile and run end to end
            assert!(cand.compile().len() == cand.rounds);
        }
    }

    #[test]
    fn warm_session_scenarios_match_across_executors() {
        use super::super::session::SessionScenario;
        // one steady-state per sparse family plus a storm: warm phase-0
        // resumes, union coordinate maps and re-key deltas must replay
        // bit-identically through the event loop and the real wire
        for sc in [
            SessionScenario::steady_state(CodecSpec::Dense, 2, 0xD1FF),
            SessionScenario::steady_state(CodecSpec::TopK { frac: 0.25 }, 2, 0xD1FF),
            SessionScenario::churn_storm(CodecSpec::RandK { frac: 0.25 }, 4, 0xD1FF),
        ] {
            if let Some(m) = run_differential(&DiffSpec::Session(&sc)) {
                panic!("{}: {:?}", sc.name, m);
            }
        }
    }

    #[test]
    fn small_randomized_batch_is_clean() {
        // the full 200-scenario sweep lives in tests/scenario_differential.rs;
        // this is the in-crate smoke version
        let report = run_differential_batch(0xBA5E, 10);
        assert_eq!(report.scenarios_run, 10);
        assert!(report.ok(), "failures: {:?}", report.failures);
    }

    #[test]
    fn small_clocked_batch_is_clean() {
        // the ≥100-scenario acceptance sweep lives in
        // tests/virtual_clock.rs; this is the in-crate smoke version
        let report = run_clocked_differential(0xC10C_BA5E, 6);
        assert_eq!(report.scenarios_run, 6);
        assert!(report.ok(), "failures: {:?}", report.failures);
    }
}
