//! Multi-round churn models, beyond the per-step [`DropoutModel`].
//!
//! The protocol layer only understands one round of per-step failures; real
//! deployments churn across rounds in structured ways — flash crowds
//! leaving, rack outages, adversaries picking off hubs. Each model here
//! *compiles* to one explicit per-step schedule per round (consumed as
//! [`DropoutModel::Targeted`]), which buys two properties at once:
//!
//! 1. **driver equivalence** — targeted schedules are rng-free, so the sync
//!    engine and the threaded coordinator (whose lazy draw orders differ)
//!    see bit-identical failures; the differential harness depends on this;
//! 2. **replayability** — a compiled schedule is plain data: the shrinker
//!    can minimize it and a report can quote it verbatim.
//!
//! [`DropoutModel`]: crate::protocol::dropout::DropoutModel

use crate::graph::Graph;
use crate::protocol::ClientId;
use crate::util::rng::Rng;

/// Per-round client-failure process for a scenario campaign.
#[derive(Debug, Clone)]
pub enum ChurnModel {
    /// No failures.
    None,
    /// Every client independently drops with probability `q` at each of the
    /// four protocol steps of every round (the paper's §4.3 model, extended
    /// across rounds).
    Iid { q: f64 },
    /// Two-state Markov weather: each round is calm or stormy. A calm round
    /// becomes stormy with probability `p_enter`; a stormy round calms down
    /// with probability `p_exit`. Clients drop i.i.d. per step with
    /// `q_calm` or `q_storm` according to the round's state — dropout
    /// arrives in correlated bursts, the regime Theorem 5's i.i.d. bound
    /// does not cover.
    Bursty { q_calm: f64, q_storm: f64, p_enter: f64, p_exit: f64 },
    /// Clients are partitioned into `regions` contiguous blocks. Each round
    /// every region fails wholesale with probability `q_region` (all its
    /// members drop at step 0 — a rack or regional outage), and every
    /// client additionally drops i.i.d. per step with `q_local`.
    CorrelatedRegional { regions: usize, q_region: f64, q_local: f64 },
    /// An adaptive adversary that each round knocks out the `count`
    /// highest-degree clients of that round's assignment graph at protocol
    /// step `step` (0..=3) — targeting hubs maximizes damage to Theorem 1's
    /// informativeness predicate.
    TargetedAdaptive { count: usize, step: usize },
    /// Explicit per-round schedules (replay and shrinker output). Rounds
    /// beyond the script run failure-free.
    Scripted { rounds: Vec<[Vec<ClientId>; 4]> },
}

impl ChurnModel {
    /// Compile the model into one explicit per-step dropout schedule per
    /// round. `graphs[r]` is round r's assignment graph (only
    /// [`ChurnModel::TargetedAdaptive`] inspects it). Deterministic in
    /// `rng`; the number of rounds is `graphs.len()`.
    pub fn compile(&self, n: usize, graphs: &[Graph], rng: &mut Rng) -> Vec<[Vec<ClientId>; 4]> {
        let mut out = Vec::with_capacity(graphs.len());
        let mut stormy = false;
        for (round, graph) in graphs.iter().enumerate() {
            let mut drops: [Vec<ClientId>; 4] = std::array::from_fn(|_| Vec::new());
            match self {
                ChurnModel::None => {}
                ChurnModel::Iid { q } => {
                    iid_drops(&mut drops, n, *q, rng);
                }
                ChurnModel::Bursty { q_calm, q_storm, p_enter, p_exit } => {
                    stormy = if stormy {
                        !rng.bernoulli(*p_exit)
                    } else {
                        rng.bernoulli(*p_enter)
                    };
                    iid_drops(&mut drops, n, if stormy { *q_storm } else { *q_calm }, rng);
                }
                ChurnModel::CorrelatedRegional { regions, q_region, q_local } => {
                    let regions = (*regions).clamp(1, n.max(1));
                    for r in 0..regions {
                        if rng.bernoulli(*q_region) {
                            drops[0].extend(r * n / regions..(r + 1) * n / regions);
                        }
                    }
                    iid_drops(&mut drops, n, *q_local, rng);
                }
                ChurnModel::TargetedAdaptive { count, step } => {
                    let step = (*step).min(3);
                    let mut by_degree: Vec<ClientId> = (0..n).collect();
                    // highest degree first; ties broken by id for determinism
                    by_degree.sort_by_key(|&c| std::cmp::Reverse((graph.degree(c), c)));
                    by_degree.truncate((*count).min(n));
                    by_degree.sort_unstable();
                    drops[step] = by_degree;
                }
                ChurnModel::Scripted { rounds } => {
                    if let Some(s) = rounds.get(round) {
                        drops = s.clone();
                    }
                }
            }
            out.push(drops);
        }
        out
    }
}

/// Add i.i.d. per-step drops (duplicates against already-scheduled drops are
/// harmless: `Targeted` only tests membership).
fn iid_drops(drops: &mut [Vec<ClientId>; 4], n: usize, q: f64, rng: &mut Rng) {
    for step_drops in drops.iter_mut() {
        for client in 0..n {
            if rng.bernoulli(q) {
                step_drops.push(client);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn graphs(n: usize, rounds: usize) -> Vec<Graph> {
        (0..rounds).map(|_| Graph::complete(n)).collect()
    }

    #[test]
    fn none_compiles_empty() {
        let g = graphs(10, 3);
        let s = ChurnModel::None.compile(10, &g, &mut Rng::new(1));
        assert_eq!(s.len(), 3);
        assert!(s.iter().all(|round| round.iter().all(|step| step.is_empty())));
    }

    #[test]
    fn compile_is_deterministic() {
        let g = graphs(20, 4);
        let m = ChurnModel::Bursty { q_calm: 0.02, q_storm: 0.3, p_enter: 0.5, p_exit: 0.5 };
        let a = m.compile(20, &g, &mut Rng::new(7));
        let b = m.compile(20, &g, &mut Rng::new(7));
        assert_eq!(a, b);
    }

    #[test]
    fn iid_rate_roughly_q() {
        let rounds = 50;
        let n = 40;
        let g = graphs(n, rounds);
        let s = ChurnModel::Iid { q: 0.2 }.compile(n, &g, &mut Rng::new(3));
        let dropped: usize = s.iter().flat_map(|r| r.iter()).map(|d| d.len()).sum();
        let total = (rounds * 4 * n) as f64;
        assert!((dropped as f64 / total - 0.2).abs() < 0.02, "rate {}", dropped as f64 / total);
    }

    #[test]
    fn bursty_has_calm_and_storm_rounds() {
        let rounds = 60;
        let n = 30;
        let g = graphs(n, rounds);
        let m = ChurnModel::Bursty { q_calm: 0.0, q_storm: 0.5, p_enter: 0.3, p_exit: 0.5 };
        let s = m.compile(n, &g, &mut Rng::new(11));
        let per_round: Vec<usize> =
            s.iter().map(|r| r.iter().map(|d| d.len()).sum()).collect();
        let calm = per_round.iter().filter(|&&d| d == 0).count();
        let stormy = per_round.iter().filter(|&&d| d > n / 4).count();
        assert!(calm > 0, "no calm rounds");
        assert!(stormy > 0, "no stormy rounds");
    }

    #[test]
    fn regional_outage_drops_contiguous_block() {
        let n = 30;
        let g = graphs(n, 200);
        let m = ChurnModel::CorrelatedRegional { regions: 3, q_region: 0.2, q_local: 0.0 };
        let s = m.compile(n, &g, &mut Rng::new(5));
        let mut saw_outage = false;
        for round in &s {
            if round[0].is_empty() {
                continue;
            }
            saw_outage = true;
            // step-0 drops are whole 10-client blocks
            assert_eq!(round[0].len() % 10, 0, "partial region {:?}", round[0]);
            for chunk in round[0].chunks(10) {
                assert!(chunk.windows(2).all(|w| w[1] == w[0] + 1), "gap in {chunk:?}");
                assert_eq!(chunk[0] % 10, 0);
            }
        }
        assert!(saw_outage, "q_region=0.2 over 200 rounds must fire");
    }

    #[test]
    fn targeted_adaptive_hits_highest_degree() {
        let n = 8;
        let mut g = Graph::ring(n);
        g.add_edge(0, 4); // 0 and 4 now have degree 3, everyone else 2
        let m = ChurnModel::TargetedAdaptive { count: 2, step: 1 };
        let s = m.compile(n, &[g], &mut Rng::new(1));
        assert_eq!(s[0][1], vec![0, 4]);
        assert!(s[0][0].is_empty() && s[0][2].is_empty() && s[0][3].is_empty());
    }

    #[test]
    fn scripted_replays_and_pads() {
        let script = vec![[vec![1], vec![], vec![2], vec![]]];
        let m = ChurnModel::Scripted { rounds: script.clone() };
        let s = m.compile(5, &graphs(5, 2), &mut Rng::new(1));
        assert_eq!(s[0], script[0]);
        assert!(s[1].iter().all(|d| d.is_empty()), "past the script: failure-free");
    }
}
